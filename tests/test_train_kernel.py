"""Validation of the fused multi-sweep TRAINING kernel and its dispatch
(DESIGN.md §Train-kernel).

The three implementations — Pallas kernel (interpret mode), blocked-jnp
fast path, per-document ref oracle — share the counter-hash PRNG, the op
order, and the block-local delayed-count refresh, so equality is asserted
EXACTLY, not to a tolerance.  The shared-uniforms contract is
`kernels.slda_train.train_uniforms` (the train twin of
`predict_uniforms`)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (SLDAConfig, apply_count_deltas,
                        counts_from_assignments, train_chain)
from repro.data import make_slda_corpus
from repro.kernels import ops, ref
from repro.kernels.slda_train import train_uniforms


def _setup(n_docs, n_topics, vocab, doc_len, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 7)
    tokens = jax.random.randint(ks[0], (n_docs, doc_len), 0, vocab, jnp.int32)
    lens = jax.random.randint(ks[1], (n_docs,), max(2, doc_len // 3),
                              doc_len + 1)
    mask = (jnp.arange(doc_len)[None, :] < lens[:, None]).astype(jnp.float32)
    z0 = jax.random.randint(ks[2], (n_docs, doc_len), 0, n_topics, jnp.int32)
    ndt0 = jnp.zeros((n_docs, n_topics), jnp.float32)
    ndt0 = ndt0.at[jnp.arange(n_docs)[:, None], z0].add(mask)
    ntw = jnp.zeros((n_topics, vocab), jnp.float32).at[z0, tokens].add(mask)
    nt = ntw.sum(-1)
    y = jax.random.normal(ks[3], (n_docs,))
    inv_len = 1.0 / jnp.maximum(mask.sum(-1), 1.0)
    eta = jax.random.normal(ks[4], (n_topics,))
    seeds = jax.random.randint(ks[5], (n_docs,), 0, 2 ** 31 - 1, jnp.int32)
    return tokens, mask, z0, ndt0, y, inv_len, ntw, nt, eta, seeds


_HYPERS = dict(alpha=0.1, beta=0.01, rho=0.5)


# ------------------------------------------------------ oracle equivalence

@pytest.mark.parametrize("n_docs,n_topics,vocab,doc_len,doc_block", [
    (16, 8, 100, 30, 8),
    (10, 16, 64, 20, 4),         # D not a doc_block multiple (pads)
    (8, 128, 200, 16, 8),        # full-lane topic dim
])
@pytest.mark.parametrize("n_sweeps,supervised", [(3, True), (1, True),
                                                 (4, False)])
def test_train_kernel_matches_ref(n_docs, n_topics, vocab, doc_len,
                                  doc_block, n_sweeps, supervised):
    """Interpret-mode kernel == ref oracle fed the SAME uniforms, exactly
    — including the block-local delayed-count refresh between sweeps."""
    (tokens, mask, z0, ndt0, y, inv_len, ntw, nt, eta,
     seeds) = _setup(n_docs, n_topics, vocab, doc_len)
    z_k, ndt_k = ops.slda_train_sweeps(
        tokens, mask, z0, ndt0, y, inv_len, ntw, nt, eta, seeds,
        n_sweeps=n_sweeps, supervised=supervised, doc_block=doc_block,
        **_HYPERS)
    uniforms = train_uniforms(seeds, n_sweeps, doc_len)
    z_r, ndt_r = ref.ref_slda_train_sweeps(
        tokens, mask, uniforms, z0, ndt0, y, inv_len, ntw.T, nt, eta,
        _HYPERS["alpha"], _HYPERS["beta"], _HYPERS["rho"], supervised,
        doc_block)
    assert np.array_equal(np.asarray(z_k), np.asarray(z_r))
    np.testing.assert_allclose(np.asarray(ndt_k), np.asarray(ndt_r), atol=0)


def test_train_jnp_fast_path_matches_kernel():
    """use_pallas=False (the CPU fast path) is bit-identical to the kernel."""
    args = _setup(12, 8, 80, 24, seed=1)
    kw = dict(n_sweeps=4, doc_block=4, **_HYPERS)
    z_k, ndt_k = ops.slda_train_sweeps(*args, **kw)
    z_j, ndt_j = ops.slda_train_sweeps(*args, use_pallas=False, **kw)
    assert np.array_equal(np.asarray(z_k), np.asarray(z_j))
    np.testing.assert_allclose(np.asarray(ndt_k), np.asarray(ndt_j), atol=0)


def test_single_sweep_launch_agrees_with_seed_sweep():
    """n_sweeps=1 is exactly one seed-semantics sweep: it must reproduce
    the single-sweep slda_gibbs path bit-for-bit under shared uniforms
    (the `sweeps_per_launch=1 reproduces seed semantics` contract)."""
    (tokens, mask, z0, ndt0, y, inv_len, ntw, nt, eta,
     seeds) = _setup(10, 8, 60, 18, seed=2)
    z_f, ndt_f = ops.slda_train_sweeps(
        tokens, mask, z0, ndt0, y, inv_len, ntw, nt, eta, seeds,
        n_sweeps=1, doc_block=4, **_HYPERS)
    us = train_uniforms(seeds, 1, 18)[:, 0]
    z_s, ndt_s = ops.slda_gibbs_sweep(
        tokens, mask, us, z0, ndt0, y, inv_len, ntw, nt, eta,
        doc_block=4, **_HYPERS)
    assert np.array_equal(np.asarray(z_f), np.asarray(z_s))
    np.testing.assert_allclose(np.asarray(ndt_f), np.asarray(ndt_s), atol=0)


# ------------------------------------------------------------- invariants

@pytest.mark.parametrize("use_pallas", [False, True])
def test_train_sweeps_conserve_counts_and_padding(use_pallas):
    """ndt stays exact w.r.t. z after a fused launch; z stays in range;
    padded tokens never move; the caller's global delta refresh lands on
    exactly the rebuilt tables."""
    (tokens, mask, z0, ndt0, y, inv_len, ntw, nt, eta,
     seeds) = _setup(10, 6, 50, 20, seed=3)
    z, ndt = ops.slda_train_sweeps(
        tokens, mask, z0, ndt0, y, inv_len, ntw, nt, eta, seeds,
        n_sweeps=3, doc_block=4, use_pallas=use_pallas, **_HYPERS)
    assert int(z.min()) >= 0 and int(z.max()) < 6
    pad = np.asarray(mask) == 0
    assert np.array_equal(np.asarray(z)[pad], np.asarray(z0)[pad])
    ndt_r, ntw_r, nt_r = counts_from_assignments(tokens, mask, z, 6, 50)
    np.testing.assert_allclose(np.asarray(ndt), np.asarray(ndt_r), atol=0)
    ntw2, nt2 = apply_count_deltas(ntw, nt, tokens, mask, z0, z)
    np.testing.assert_allclose(np.asarray(ntw2), np.asarray(ntw_r), atol=0)
    np.testing.assert_allclose(np.asarray(nt2), np.asarray(nt_r), atol=0)


@pytest.mark.parametrize("cap", [0, 8, 96, None])
def test_apply_count_deltas_compaction_matches_dense(cap):
    """The changed-token compaction form equals the dense 2-scatter for
    every cap, including tiny caps that force the lax.cond overflow
    fallback and cap=0 (dense short-circuit)."""
    (tokens, mask, z0, _, _, _, ntw, nt, _, _) = _setup(8, 6, 40, 16,
                                                        seed=4)
    z_new = jnp.where(jax.random.uniform(jax.random.PRNGKey(9),
                                         z0.shape) > 0.6,
                      z0, jax.random.randint(jax.random.PRNGKey(10),
                                             z0.shape, 0, 6, jnp.int32))
    ntw_d, nt_d = apply_count_deltas(ntw, nt, tokens, mask, z0, z_new,
                                     cap=0)
    ntw_c, nt_c = jax.jit(
        lambda *a: apply_count_deltas(*a, cap=cap))(ntw, nt, tokens, mask,
                                                    z0, z_new)
    np.testing.assert_allclose(np.asarray(ntw_c), np.asarray(ntw_d), atol=0)
    np.testing.assert_allclose(np.asarray(nt_c), np.asarray(nt_d), atol=0)


# --------------------------------------------------------- chain routing

def test_fused_train_chain_counts_stay_exact():
    """train_chain with sweeps_per_launch>1 (incremental global refresh
    between launches) ends with tables exactly consistent with z."""
    cfg = SLDAConfig(n_topics=8, vocab_size=64, n_iters=10,
                     sweeps_per_launch=3, count_rebuild_every=0)
    corpus, _ = make_slda_corpus(jax.random.PRNGKey(11), 24, 64, 8, 20)
    state, _ = jax.jit(train_chain, static_argnums=(2,))(
        jax.random.PRNGKey(12), corpus, cfg)
    ndt, ntw, nt = counts_from_assignments(corpus.tokens, corpus.mask,
                                           state.z, cfg.n_topics,
                                           cfg.vocab_size)
    np.testing.assert_allclose(np.asarray(state.ndt), np.asarray(ndt), atol=0)
    np.testing.assert_allclose(np.asarray(state.ntw), np.asarray(ntw), atol=0)
    np.testing.assert_allclose(np.asarray(state.nt), np.asarray(nt), atol=0)


def test_fused_train_chain_learns_signal():
    """The fused multi-sweep trainer still fits the supervised signal."""
    cfg = SLDAConfig(n_topics=8, vocab_size=100, n_iters=20, rho=0.25,
                     sweeps_per_launch=4)
    corpus, _ = make_slda_corpus(jax.random.PRNGKey(13), 120, 100, 8, 30,
                                 rho=0.25)
    _, model = jax.jit(train_chain, static_argnums=(2,))(
        jax.random.PRNGKey(14), corpus, cfg)
    assert float(model.train_mse) < 0.6 * float(jnp.var(corpus.y))
