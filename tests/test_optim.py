"""Optimizer substrate tests: per-chain semantics + compression tricks."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import (OptConfig, adamw_update, init_opt_state,
                         clip_by_global_norm_per_chain, lr_schedule,
                         quantize_grads)


def make_params(chains=3, d=16, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    return {"w": jax.random.normal(ks[0], (chains, d, d)),
            "b": jax.random.normal(ks[1], (chains, d))}


def test_per_chain_clip_is_independent():
    params = make_params()
    grads = jax.tree.map(lambda p: jnp.ones_like(p), params)
    # blow up only chain 1's grads
    grads = jax.tree.map(lambda g: g.at[1].mul(1e6), grads)
    clipped, norms = clip_by_global_norm_per_chain(grads, 1.0)
    # every chain's post-clip norm is ≤ 1, including the exploded one
    for i in range(3):
        ni = np.sqrt(sum(float(jnp.sum(jnp.square(g[i])))
                         for g in jax.tree.leaves(clipped)))
        assert ni <= 1.0 + 1e-4
    assert float(norms[1]) > 1e5       # reported pre-clip norm per chain


def test_adamw_step_decreases_simple_quadratic():
    cfg = OptConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                    total_steps=100)
    params = {"w": jnp.asarray([[1.0, -2.0], [3.0, 0.5]])}
    state = init_opt_state(params, cfg)
    for _ in range(60):
        grads = jax.tree.map(lambda p: 2 * p, params)   # d/dp ||p||²
        params, state, _ = adamw_update(params, grads, state, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.2


def test_chain_updates_do_not_mix():
    """Feeding zero grads to chain 0 must leave chain 0's params unchanged
    by the gradient term (only weight decay moves them)."""
    cfg = OptConfig(lr=1e-2, weight_decay=0.0, warmup_steps=0)
    params = make_params(chains=2)
    state = init_opt_state(params, cfg)
    grads = jax.tree.map(lambda p: p * 0, params)
    grads = jax.tree.map(lambda g: g.at[1].set(1.0), grads)
    new_params, _, _ = adamw_update(params, grads, state, cfg)
    for p, q in zip(jax.tree.leaves(params), jax.tree.leaves(new_params)):
        np.testing.assert_allclose(np.asarray(p[0]), np.asarray(q[0]),
                                   atol=1e-7)
        assert np.abs(np.asarray(p[1] - q[1])).max() > 1e-4


def test_bf16_opt_state_dtype():
    cfg = OptConfig(opt_dtype="bfloat16")
    params = make_params()
    state = init_opt_state(params, cfg)
    assert state["m"]["w"].dtype == jnp.bfloat16
    grads = jax.tree.map(jnp.ones_like, params)
    _, state2, _ = adamw_update(params, grads, state, cfg)
    assert state2["v"]["w"].dtype == jnp.bfloat16


def test_quantize_grads_unbiased_and_bounded():
    key = jax.random.PRNGKey(0)
    g = {"w": jax.random.normal(key, (64, 64))}
    qs = [quantize_grads(g, jax.random.PRNGKey(i))["w"] for i in range(16)]
    err = jnp.stack([q - g["w"] for q in qs])
    scale = float(jnp.max(jnp.abs(g["w"]))) / 127
    assert float(jnp.abs(err).max()) <= scale + 1e-6        # ≤ 1 ulp
    assert float(jnp.abs(jnp.mean(err))) < scale * 0.1      # ≈ unbiased


def test_lr_schedule_shape():
    cfg = OptConfig(lr=1.0, warmup_steps=10, total_steps=100,
                    min_lr_frac=0.1)
    assert float(lr_schedule(cfg, 0)) == 0.0
    assert abs(float(lr_schedule(cfg, 10)) - 1.0) < 1e-6
    assert abs(float(lr_schedule(cfg, 100)) - 0.1) < 1e-6
    assert float(lr_schedule(cfg, 55)) < 1.0
