"""The sparse two-stage sampler (DESIGN.md §Sparse-sampler), contract by
contract:

  * COLLAPSE — with the identity index (cap = T, everything occupied) the
    two-stage draw is BITWISE the dense inverse-CDF draw under shared
    uniforms: the stages degenerate (empty residual, stage 2 never
    fires), so the decomposition provably changes nothing at the point
    where the two samplers coincide.
  * DISTRIBUTIONAL EXACTNESS — for ANY index content (including caps far
    below the true occupancy, forcing the stage-2 residual correction),
    the measure of uniforms mapped to each topic equals the dense
    sampler's, asserted deterministically on a fine u-grid (the preimage
    of a topic is at most two intervals, so the grid bound is sharp).
  * CROSS-BACKEND BITWISE — pallas-interpret kernel ≡ blocked-jnp twin ≡
    ref oracle in sparse mode for the train, predict, and single-sweep
    entry points (the same three-way pin dense mode has).
  * DISPATCH MATRIX — plan-routed sparse cells over (layout × M ×
    spl): jnp and pallas-interpret agree bitwise per cell, counts stay
    exactly consistent with z, and the model still learns.  Sparse is
    its OWN sampler family (not bit-equal to dense; the Geweke tier in
    test_statistical.py pins its distribution to the model).
  * SERVING — switching `sampler_mode` on a live service allocates a
    DISTINCT jitted callable (the cfg is inside ExecutionPlan.cache_key)
    and `stats()` reports the active mode.
  * a hypothesis property over occupancy distributions × M ∈ {1, 4}.
"""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (SLDAConfig, bucket_corpus, counts_from_assignments,
                        partition, topic_occupancy_index)
from repro.core.parallel import train_chains_keyed
from repro.data import make_slda_corpus, train_test_split
from repro.kernels import ops, ref
from repro.kernels.slda_predict import predict_uniforms
from repro.kernels.slda_train import train_uniforms
from repro.kernels.sparse import sparse_two_stage_draw
from repro.mathutil import upper_tri_ones


def _dense_draw(p, u):
    c = jnp.dot(p, upper_tri_ones(p.shape[-1]))
    return jnp.sum((c < (u * c[..., -1])[..., None]).astype(jnp.int32),
                   axis=-1)


# ----------------------------------------------------------- collapse

@pytest.mark.parametrize("t_dim", [3, 8, 17, 32])
def test_collapse_identity_index_bitwise_equals_dense(t_dim):
    """cap = T, identity index, everything occupied: every uniform maps
    to the SAME topic as the dense draw, bit for bit (the oracle
    contract the refactor rests on)."""
    B = 257
    p = jax.random.uniform(jax.random.PRNGKey(t_dim), (B, t_dim)) ** 3
    u = jax.random.uniform(jax.random.PRNGKey(t_dim + 100), (B,))
    idx = jnp.broadcast_to(jnp.arange(t_dim, dtype=jnp.int32), (B, t_dim))
    ones = jnp.ones((B, t_dim), jnp.float32)
    z_sp = sparse_two_stage_draw(p, u, idx, ones, ones)
    assert np.array_equal(np.asarray(z_sp), np.asarray(_dense_draw(p, u)))


# ----------------------------------------- deterministic distributional

@pytest.mark.parametrize("cap", [1, 2, 4])
def test_two_stage_distributionally_exact_any_index(cap):
    """Fine u-grid measure per topic == the dense sampler's, for random
    count tables indexed at caps BELOW the true occupancy (stage 2 must
    fire).  Each topic's preimage is ≤ 2 intervals under the two-stage
    map and 1 under dense, so |measure difference| ≤ 4/n_grid exactly —
    a deterministic statement of distributional equality, no Monte
    Carlo slack."""
    T, W, n = 11, 5, 40_000
    table = (jax.random.uniform(jax.random.PRNGKey(3), (W, T)) > 0.5) \
        .astype(jnp.float32) * 7.0
    idx, vm, om = topic_occupancy_index(table, cap)
    pw = jax.random.uniform(jax.random.PRNGKey(4), (W, T)) ** 2 + 1e-4
    us = (jnp.arange(n, dtype=jnp.float32) + 0.5) / n
    for w in range(W):
        p = jnp.broadcast_to(pw[w], (n, T))
        z = sparse_two_stage_draw(
            p, us, jnp.broadcast_to(idx[w], (n, cap)),
            jnp.broadcast_to(vm[w], (n, cap)),
            jnp.broadcast_to(om[w], (n, T)))
        frac = np.asarray(jnp.bincount(z, length=T)) / n
        ref_frac = np.asarray(pw[w] / pw[w].sum())
        np.testing.assert_allclose(frac, ref_frac, atol=4.0 / n,
                                   err_msg=f"word {w} cap {cap}")


# ------------------------------------------------ cross-backend bitwise

_T, _W, _DL = 8, 40, 9
_corpus_small, _ = make_slda_corpus(jax.random.PRNGKey(7), 12, _W, _T, _DL)


def _small_state(key):
    tokens, mask = _corpus_small.tokens, _corpus_small.mask
    k1, k2 = jax.random.split(key)
    z0 = jax.random.randint(k1, tokens.shape, 0, _T, jnp.int32)
    ndt0, ntw, nt = counts_from_assignments(tokens, mask, z0, _T, _W)
    seeds = jax.random.randint(k2, (tokens.shape[0],), 0, 2 ** 31 - 1,
                               jnp.int32)
    inv_len = 1.0 / jnp.maximum(mask.sum(-1), 1.0)
    return z0, ndt0, ntw, nt, seeds, inv_len


@pytest.mark.parametrize("cap", [2, 4])
def test_train_sparse_kernel_twin_oracle_bitwise(cap):
    tokens, mask, y = (_corpus_small.tokens, _corpus_small.mask,
                       _corpus_small.y)
    z0, ndt0, ntw, nt, seeds, inv_len = _small_state(jax.random.PRNGKey(1))
    eta = jnp.linspace(-1, 1, _T)
    kw = dict(alpha=0.1, beta=0.01, rho=0.5, n_sweeps=3, supervised=True,
              doc_block=4, sampler_mode="sparse", sparse_topic_cap=cap)
    zj, nj = ops.slda_train_sweeps(tokens, mask, z0, ndt0, y, inv_len,
                                   ntw, nt, eta, seeds, use_pallas=False,
                                   **kw)
    zp, np_ = ops.slda_train_sweeps(tokens, mask, z0, ndt0, y, inv_len,
                                    ntw, nt, eta, seeds, use_pallas=True,
                                    **kw)
    us = train_uniforms(seeds, 3, tokens.shape[1])
    zo, no = ref.ref_slda_train_sweeps(
        tokens, mask, us, z0, ndt0, y, inv_len, jnp.swapaxes(ntw, -1, -2),
        nt, eta, 0.1, 0.01, 0.5, True, 4, sampler_mode="sparse",
        sparse_topic_cap=cap)
    for a, b, tag in ((zj, zp, "twin/kernel z"), (zj, zo, "twin/oracle z"),
                      (nj, np_, "twin/kernel ndt"),
                      (nj, no, "twin/oracle ndt")):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=0,
                                   err_msg=f"cap={cap} {tag}")
    # sparse is its own family: must DIFFER from dense somewhere
    zd, _ = ops.slda_train_sweeps(tokens, mask, z0, ndt0, y, inv_len, ntw,
                                  nt, eta, seeds, use_pallas=False,
                                  **dict(kw, sampler_mode="dense"))
    assert np.any(np.asarray(zd) != np.asarray(zj))


def test_predict_and_single_sweep_sparse_bitwise():
    tokens, mask, y = (_corpus_small.tokens, _corpus_small.mask,
                       _corpus_small.y)
    z0, ndt0, ntw, nt, seeds, inv_len = _small_state(jax.random.PRNGKey(2))
    phi = jax.random.dirichlet(jax.random.PRNGKey(9),
                               jnp.full((_W,), 0.1), (_T,))
    pkw = dict(alpha=0.1, n_burnin=1, n_samples=2, doc_block=4,
               sampler_mode="sparse", sparse_topic_cap=3)
    aj, zj = ops.slda_predict_sweeps(tokens, mask, z0, ndt0, phi, seeds,
                                     use_pallas=False, **pkw)
    ap, zp = ops.slda_predict_sweeps(tokens, mask, z0, ndt0, phi, seeds,
                                     use_pallas=True, **pkw)
    up = predict_uniforms(seeds, 3, tokens.shape[1])
    ao, zo = ref.ref_slda_predict_sweeps(
        tokens, mask, up, z0, ndt0, jnp.swapaxes(phi, -1, -2), 0.1, 1,
        sampler_mode="sparse", sparse_topic_cap=3)
    for a, b in ((aj, ap), (aj, ao), (zj, zp), (zj, zo)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=0)

    eta = jnp.linspace(-1, 1, _T)
    uni = jax.random.uniform(jax.random.PRNGKey(11), tokens.shape)
    skw = dict(alpha=0.1, beta=0.01, rho=0.5, sampler_mode="sparse",
               sparse_topic_cap=3)
    gj = ops.slda_gibbs_sweep(tokens, mask, uni, z0, ndt0, y, inv_len,
                              ntw, nt, eta, use_pallas=False, **skw)
    gp = ops.slda_gibbs_sweep(tokens, mask, uni, z0, ndt0, y, inv_len,
                              ntw, nt, eta, use_pallas=True, doc_block=4,
                              **skw)
    for a, b in zip(gj, gp):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=0)


# ------------------------------------------------------ dispatch matrix

_CFG = SLDAConfig(n_topics=4, vocab_size=24, n_iters=5, rho=0.25,
                  n_pred_burnin=1, n_pred_samples=2, count_rebuild_every=2,
                  sampler_mode="sparse", sparse_topic_cap=2)
_D_TOTAL, _MAXLEN = 32, 12
_corp, _ = make_slda_corpus(jax.random.PRNGKey(0), _D_TOTAL + 16, 24, 4,
                            _MAXLEN, rho=0.25, doc_len_dist="lognormal")
_train, _test = train_test_split(_corp, _D_TOTAL)


def _sp_cfg(backend, spl, layout):
    return dataclasses.replace(
        _CFG, use_pallas=(backend == "pallas-interpret"),
        sweeps_per_launch=spl, n_iters=_CFG.n_iters if spl == 1 else 9,
        length_buckets=3 if layout == "bucketed" else 0,
        bucket_overhead_docs=0.0)


def _sched(layout, shards):
    return bucket_corpus(shards, 3, overhead_docs=0) \
        if layout == "bucketed" else shards


@pytest.mark.parametrize("spl", [1, 4])
@pytest.mark.parametrize("m", [1, 4])
@pytest.mark.parametrize("layout", ["padded", "bucketed"])
def test_dispatch_matrix_sparse_train(layout, m, spl):
    """Sparse plan cells (cap=2 < T=4 keeps stage 2 live), holding the
    SAME contract as the dense dispatch matrix: spl=1 cells bitwise-agree
    across backends; spl>1 cells are each their own exact member of the
    fused-sampler family (the stair executor's whole-corpus in-launch
    refresh vs the blocks executor's per-bucket refresh — not bitwise
    comparable, dense or sparse), so both backends are instead held to
    exact count consistency and the learnability guard.  Covers the
    blocks AND stair executors (bucketed/jnp/spl>1)."""
    shards = partition(_train, m)
    keys = jax.random.split(jax.random.PRNGKey(1), m)
    out = {}
    for backend in ("jnp", "pallas-interpret"):
        cfg = _sp_cfg(backend, spl, layout)
        out[backend] = jax.jit(train_chains_keyed, static_argnums=(2,))(
            keys, _sched(layout, shards), cfg)
    (state, model), (state_p, model_p) = (out["jnp"],
                                          out["pallas-interpret"])
    if spl == 1:
        for f in ("z", "ndt", "ntw", "nt", "eta"):
            np.testing.assert_allclose(
                np.asarray(getattr(state, f)),
                np.asarray(getattr(state_p, f)),
                atol=0, err_msg=f"{layout}/{m}/spl{spl} state.{f}")
    for st, mdl in ((state, model), (state_p, model_p)):
        nd, nw, nt = jax.vmap(
            lambda t, mm, z: counts_from_assignments(t, mm, z, 4, 24))(
            shards.tokens, shards.mask, st.z)
        np.testing.assert_allclose(np.asarray(nd), np.asarray(st.ndt),
                                   atol=0)
        np.testing.assert_allclose(np.asarray(nw), np.asarray(st.ntw),
                                   atol=0)
        np.testing.assert_allclose(np.asarray(nt), np.asarray(st.nt),
                                   atol=0)
        assert float(jnp.mean(mdl.train_mse)) < \
            0.6 * float(jnp.var(shards.y))


# -------------------------------------------------------------- serving

def test_service_mode_switch_allocates_distinct_callable():
    """`set_sampler_mode` flips the cfg inside every future plan cache
    key: the next flush compiles a NEW jitted callable (count grows),
    switching back reuses the old one (count stays), and `stats()`
    reports the active mode + plan-cache key count."""
    from repro.core import train_chains
    from repro.serving import ServiceConfig, SLDAPredictionService

    cfg = SLDAConfig(n_topics=8, vocab_size=64, n_iters=3,
                     n_pred_burnin=1, n_pred_samples=2)
    corp, _ = make_slda_corpus(jax.random.PRNGKey(0), 48, 64, 8, 32,
                               doc_len_dist="lognormal")
    models = train_chains(jax.random.PRNGKey(1), partition(corp, 2), cfg)
    lens = np.asarray(corp.mask.sum(-1)).astype(int)
    svc_cfg = ServiceConfig.calibrated(lens, max_doc_len=32, batch_docs=8,
                                       n_buckets=2)
    svc = SLDAPredictionService(models, cfg, svc_cfg,
                                key=jax.random.PRNGKey(9))
    toks = np.asarray(corp.tokens)
    docs = [toks[d, :max(int(lens[d]), 1)] for d in range(16)]

    for d in docs[:8]:
        svc.submit(d)
    st = svc.stats()
    assert st["sampler_mode"] == "dense"
    assert st["plan_cache_keys"] == st["compiled_plans"] == 1

    svc.set_sampler_mode("sparse")
    for d in docs[8:16]:
        svc.submit(d)
    svc.drain()
    st = svc.stats()
    assert st["sampler_mode"] == "sparse"
    assert st["plan_cache_keys"] == 2        # distinct jitted callable

    svc.set_sampler_mode("dense")            # switching back is free
    for d in docs[:8]:
        svc.submit(d)
    svc.drain()
    st = svc.stats()
    assert st["sampler_mode"] == "dense"
    assert st["plan_cache_keys"] == 2
    with pytest.raises(ValueError):
        svc.set_sampler_mode("dense-ish")


# -------------------------------------------------- hypothesis property

try:  # the rest of this module must still run without hypothesis
    from hypothesis import given, settings, strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    _HAVE_HYPOTHESIS = False
    given = settings = lambda *a, **k: (lambda f: f)

    class st:  # noqa: N801 — placeholder so the decorators below parse
        sampled_from = integers = floats = data = staticmethod(
            lambda *a, **k: None)


@pytest.mark.skipif(not _HAVE_HYPOTHESIS, reason=(
    "property tests need hypothesis (pip install -r requirements-dev.txt)"))
@settings(max_examples=15, deadline=None)
@given(
    m=st.sampled_from([1, 4]),
    cap=st.integers(1, 6),
    conc=st.floats(0.05, 4.0),
    data=st.data(),
)
def test_sparse_property_occupancy_and_chain_batching(m, cap, conc, data):
    """For every occupancy regime (peaked to flat φ via the corpus
    concentration knob), every cap (1 to > T), and M ∈ {1, 4}: the
    chain-batched sparse train equals the vmapped single-chain sparse
    train bitwise, padded tokens never move, and ndt stays exactly
    consistent with z."""
    seed = data.draw(st.integers(0, 2 ** 16))
    n_topics, vocab, n_docs, doc_len = 5, 24, 6, 8
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    corp, _ = make_slda_corpus(ks[0], m * n_docs, vocab, n_topics, doc_len,
                               phi_concentration=conc)
    tokens = corp.tokens.reshape(m, n_docs, doc_len)
    mask = corp.mask.reshape(m, n_docs, doc_len)
    y = corp.y.reshape(m, n_docs)
    z0 = jax.random.randint(ks[1], (m, n_docs, doc_len), 0, n_topics,
                            jnp.int32)
    d_idx = jnp.arange(n_docs)[:, None]
    ndt0 = jax.vmap(lambda z, mm: jnp.zeros((n_docs, n_topics))
                    .at[d_idx, z].add(mm))(z0, mask)
    ntw = jax.vmap(lambda z, t, mm: jnp.zeros((n_topics, vocab))
                   .at[z, t].add(mm))(z0, tokens, mask)
    nt = ntw.sum(-1)
    inv_len = 1.0 / jnp.maximum(mask.sum(-1), 1.0)
    eta = jax.random.normal(ks[3], (m, n_topics))
    seeds = jax.random.randint(ks[4], (m, n_docs), 0, 2 ** 31 - 1,
                               jnp.int32)
    kw = dict(alpha=0.1, beta=0.01, rho=0.5, n_sweeps=2, doc_block=4,
              use_pallas=False, sampler_mode="sparse",
              sparse_topic_cap=cap)
    z_v, ndt_v = jax.vmap(functools.partial(ops.slda_train_sweeps, **kw))(
        tokens, mask, z0, ndt0, y, inv_len, ntw, nt, eta, seeds)
    z_c, ndt_c = ops.slda_train_sweeps(
        tokens, mask, z0, ndt0, y, inv_len, ntw, nt, eta, seeds,
        chain_axis=True, **kw)
    assert np.array_equal(np.asarray(z_v), np.asarray(z_c))
    np.testing.assert_allclose(np.asarray(ndt_v), np.asarray(ndt_c),
                               atol=0)
    pad = np.asarray(mask) == 0
    assert np.array_equal(np.asarray(z_c)[pad], np.asarray(z0)[pad])
    ndt_r = jax.vmap(lambda z, mm: jnp.zeros((n_docs, n_topics))
                     .at[d_idx, z].add(mm))(z_c, mask)
    np.testing.assert_allclose(np.asarray(ndt_c), np.asarray(ndt_r),
                               atol=0)
