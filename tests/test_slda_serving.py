"""Continuous-batching sLDA prediction service (serving/slda_service.py):
retrace-free plan cache, bucketed-vs-padded bitwise parity through the
service path, the theta/ŷ result cache, and exact mid-stream
drop/revive — plus the `bucket_signature` cache-key surface."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (SLDAConfig, bucket_corpus, bucket_signature,
                        build_plan, partition, train_chains)
from repro.core.plan import as_bucketed
from repro.data import make_slda_corpus
from repro.serving import ServiceConfig, SLDAPredictionService
from repro.serving.slda_service import _combine_yhat, calibrate_slots

CFG = SLDAConfig(n_topics=8, vocab_size=64, n_iters=3, n_pred_burnin=2,
                 n_pred_samples=2)
MAXLEN, M, BATCH = 48, 2, 16

_corpus, _ = make_slda_corpus(jax.random.PRNGKey(0), 64, CFG.vocab_size,
                              CFG.n_topics, MAXLEN,
                              doc_len_dist="lognormal", len_sigma=1.0)
MODELS = train_chains(jax.random.PRNGKey(1), partition(_corpus, M), CFG)
LENS = np.asarray(_corpus.mask.sum(-1)).astype(int)
TOKS = np.asarray(_corpus.tokens)
DOCS = [TOKS[d, :LENS[d]] for d in range(_corpus.n_docs)]
SVC = ServiceConfig.calibrated(LENS, max_doc_len=MAXLEN, batch_docs=BATCH,
                               n_buckets=3)


def make_service(**kw):
    svc = dataclasses.replace(SVC, **kw) if kw else SVC
    return SLDAPredictionService(MODELS, CFG, svc,
                                 key=jax.random.PRNGKey(9))


# --------------------------------------------------- retrace-free cache

def test_steady_state_traffic_never_retraces():
    """Recurring traffic has ONE bucket signature, hence one compiled
    plan: the trace counter must stop growing after the first batch."""
    svc = make_service(cache_results=False)   # every doc really dispatches
    for d in DOCS[:BATCH]:
        svc.submit(d)
    warm = svc.stats()["traces"]
    assert warm == 1 and svc.stats()["compiled_plans"] == 1
    for rep in range(3):                      # steady state: reuse + drain
        for d in DOCS[rep * 8: rep * 8 + 20]:
            svc.submit(d)
        svc.drain()
    st = svc.stats()
    assert st["traces"] == warm               # ZERO retraces after warmup
    assert st["compiled_plans"] == 1
    assert st["dispatches"] >= 4


def test_dispatch_matches_uncached_plan_layer():
    """The serving machinery (slot packing, plan cache, combine
    plumbing) must add zero numerical deviation: a service whose
    dispatch calls the plan layer through a FRESH jit every flush (the
    retrace-every-batch anti-pattern the cache exists to fix) returns
    bit-identical results."""
    class OfflineService(SLDAPredictionService):
        def _dispatch_fn(self, plan_key):
            rule = self.svc.combine

            def run(keys, models, plan, chain_weights):
                zb = plan.predict_zbar(keys, models)
                yhat = jax.vmap(lambda z, e: z @ e)(zb, models.eta)
                return zb, yhat, _combine_yhat(rule, yhat, chain_weights,
                                               models.train_mse)
            return jax.jit(run)               # fresh cache → retraces

    svc = make_service()
    off = OfflineService(MODELS, CFG, SVC, key=jax.random.PRNGKey(9))
    rids_a = [svc.submit(d) for d in DOCS[:24]]
    rids_b = [off.submit(d) for d in DOCS[:24]]
    svc.drain(), off.drain()
    for ra, rb in zip(rids_a, rids_b):
        a, b = svc.result(ra), off.result(rb)
        assert a.yhat == b.yhat
        np.testing.assert_array_equal(a.yhat_chains, b.yhat_chains)
        np.testing.assert_array_equal(a.zbar, b.zbar)


# ----------------------------------------------- bucketed/padded parity

def test_bucketed_vs_padded_bitwise_parity():
    """Identical traffic through the bucketed and the padded dispatch
    layouts: per-document results must match BITWISE (the ctr_stride
    pinning contract of DESIGN.md §Ragged-execution, now through the
    service path; prediction is spl-free, the sampler runs sweep by
    sweep)."""
    bkt = make_service(bucketed=True)
    pad = make_service(bucketed=False)
    rids_a = [bkt.submit(d) for d in DOCS[:40]]
    rids_b = [pad.submit(d) for d in DOCS[:40]]
    bkt.drain(), pad.drain()
    assert bkt.stats()["compiled_plans"] == 1
    assert pad.stats()["compiled_plans"] == 1
    for ra, rb in zip(rids_a, rids_b):
        a, b = bkt.result(ra), pad.result(rb)
        assert a.yhat == b.yhat
        np.testing.assert_array_equal(a.yhat_chains, b.yhat_chains)
        np.testing.assert_array_equal(a.zbar, b.zbar)


# --------------------------------------------------------- result cache

def test_repeat_documents_hit_result_cache():
    svc = make_service()
    rid0 = [svc.submit(d) for d in DOCS[:BATCH]]
    svc.drain()
    st0 = svc.stats()
    assert st0["result_cache_hits"] == 0
    rid1 = [svc.submit(d) for d in DOCS[:BATCH]]   # same content again
    st = svc.stats()
    assert st["result_cache_hits"] == BATCH
    assert st["dispatches"] == st0["dispatches"]   # no new dispatch
    for a, b in zip(rid0, rid1):
        ra, rb = svc.result(a), svc.result(b)
        assert rb.from_cache and not ra.from_cache
        assert ra.yhat == rb.yhat
        np.testing.assert_array_equal(ra.zbar, rb.zbar)


def test_cache_hit_combines_under_current_weights():
    """A cached document re-served after drop_chain must combine the
    CACHED per-chain values under the NEW alive mask — with one of two
    chains dropped, the combined ŷ equals the survivor's ŷ."""
    svc = make_service()
    rid0 = svc.submit(DOCS[0])
    for d in DOCS[1:BATCH]:
        svc.submit(d)
    svc.drain()
    svc.drop_chain(1)
    rid1 = svc.submit(DOCS[0])                     # cache hit, new weights
    r0, r1 = svc.result(rid0), svc.result(rid1)
    assert r1.from_cache
    np.testing.assert_array_equal(r0.yhat_chains, r1.yhat_chains)
    assert r1.yhat == pytest.approx(float(r0.yhat_chains[0]))
    assert svc.combined(rid0) == r1.yhat           # re-derive == re-serve


# ----------------------------------------------- mid-stream drop/revive

def test_drop_revive_mid_stream_without_retrace():
    """chain_weights is a jit ARGUMENT of every cached plan: dropping a
    chain between batches changes the served combine but must not
    retrace, and reviving restores the original outputs exactly."""
    svc = make_service(cache_results=False)
    rids0 = [svc.submit(d) for d in DOCS[:BATCH]]
    svc.drain()
    traces = svc.stats()["traces"]

    svc.drop_chain(1)
    rids1 = [svc.submit(d) for d in DOCS[:BATCH]]  # same docs, same slots
    svc.drain()
    svc.revive_chain(1)
    rids2 = [svc.submit(d) for d in DOCS[:BATCH]]
    svc.drain()
    assert svc.stats()["traces"] == traces         # no retrace on either

    w_full = jnp.ones((M,), jnp.float32)
    for r0, r1, r2 in zip(rids0, rids1, rids2):
        a, b, c = svc.result(r0), svc.result(r1), svc.result(r2)
        # dropped mask: the served combine IS the survivor's ŷ …
        assert b.yhat == float(b.yhat_chains[0])
        assert b.yhat != a.yhat
        # … and after revive the full-ensemble combine is back (host
        # re-derivation through the same core.combine rule matches the
        # value combined inside the compiled dispatch bit-for-bit)
        exp = float(_combine_yhat(
            SVC.combine, jnp.asarray(c.yhat_chains)[:, None], w_full,
            MODELS.train_mse)[0])
        assert c.yhat == exp
        assert a.yhat == float(_combine_yhat(
            SVC.combine, jnp.asarray(a.yhat_chains)[:, None], w_full,
            MODELS.train_mse)[0])


# ------------------------------------------------ batching edge cases

def test_partial_batch_drain_pads_with_dummies():
    svc = make_service(cache_results=False)
    rids = [svc.submit(d) for d in DOCS[:3]]
    assert svc.stats()["dispatches"] == 0          # below batch_docs
    done = svc.drain()
    assert sorted(done) == sorted(rids)
    st = svc.stats()
    assert st["dispatches"] == 1
    assert st["dummy_slots"] == BATCH - 3


def test_rung_overflow_escalates_then_rolls_over():
    """More max-length docs than the widest rung's slots: escalation
    can't help (no wider rung), so the overflow rolls to further
    micro-batches — everything still gets served."""
    svc = make_service(cache_results=False)
    long_doc = np.arange(MAXLEN, dtype=np.int32) % CFG.vocab_size
    rids = [svc.submit(long_doc + i % 2) for i in range(BATCH)]
    svc.drain()
    assert svc.stats()["dispatches"] > 1
    for rid in rids:
        assert np.isfinite(svc.result(rid).yhat)


def test_short_doc_escalates_into_wider_free_slot():
    """When a narrow rung fills up, later short docs take wider slots
    (masked to their true length) instead of waiting."""
    svc = make_service(cache_results=False)
    w0, q0 = SVC.width_ladder[0], SVC.slot_quota[0]
    short = np.ones((max(1, w0 - 1),), np.int32)
    rids = [svc.submit(short + i) for i in range(q0 + 2)]
    done = svc.drain()
    assert svc.stats()["dispatches"] == 1          # all fit one batch
    assert sorted(done) == sorted(rids)


def test_submit_validation():
    svc = make_service()
    with pytest.raises(ValueError):
        svc.submit(np.ones((MAXLEN + 1,), np.int32))
    with pytest.raises(ValueError):
        svc.submit(np.asarray([], np.int32))
    with pytest.raises(ValueError):
        svc.submit(np.asarray([CFG.vocab_size], np.int32))


# ------------------------------------- cache-key / calibration surface

def test_bucket_signature_identifies_schedule_shape():
    sig = bucket_signature(bucket_corpus(_corpus, 3))
    sig2 = bucket_signature(bucket_corpus(_corpus, 3))
    assert sig == sig2 and hash(sig) == hash(sig2)
    assert sig != bucket_signature(as_bucketed(_corpus))
    plan = build_plan(bucket_corpus(_corpus, 3), CFG)
    assert plan.cache_key() == (sig, CFG, plan.backend)


def test_calibrate_slots_layout_invariants():
    widths, quota = calibrate_slots(LENS, BATCH, MAXLEN, n_buckets=3)
    assert sum(quota) == BATCH and min(quota) >= 1
    assert list(widths) == sorted(set(widths))
    assert widths[-1] == MAXLEN
    # degenerate: one giant rung
    w1, q1 = calibrate_slots([5, 5, 5], 4, MAXLEN, n_buckets=1)
    assert w1 == (MAXLEN,) and q1 == (4,)


def test_service_config_validation():
    with pytest.raises(ValueError):
        ServiceConfig(max_doc_len=64, batch_docs=4,
                      width_ladder=(32, 16, 64), slot_quota=(1, 1, 2))
    with pytest.raises(ValueError):
        ServiceConfig(max_doc_len=64, batch_docs=4,
                      width_ladder=(16, 32), slot_quota=(2, 2))
    with pytest.raises(ValueError):
        ServiceConfig(max_doc_len=64, batch_docs=4,
                      width_ladder=(16, 64), slot_quota=(2, 3))
