"""Chain-batched vs vmapped-single-chain equivalence (DESIGN.md
§Chain-batched).

The chain_axis forms of `ops.slda_train_sweeps` / `ops.slda_predict_sweeps`
/ `ops.slda_gibbs_sweep` and the chain-batched core runners
(`train_chains`, `predict_chains`) must reproduce the vmapped
single-chain paths EXACTLY:

  * jnp twins — asserted bitwise (the predict twin folds chains into the
    document-row axis around a stacked table; the train twin maps over
    chains × blocks — both must leave every chain's bits untouched);
  * interpret-mode Pallas chain grids — asserted allclose at atol=0
    against the jnp twins (shared counter-hash PRNG and op order);
  * `train_chains` at sweeps_per_launch=1 — bit-identical to
    `jax.vmap(train_chain)` (the seed-semantics contract);
  * a hypothesis property over ragged masks and M ∈ {1, 2, 5}.
"""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import SLDAConfig
from repro.core.parallel import (partition, predict_chains, train_chains,
                                 run_weighted_average)
from repro.data import make_slda_corpus, train_test_split
from repro.kernels import ops, ref
from repro.kernels.slda_predict import predict_uniforms
from repro.kernels.slda_train import train_uniforms

_HY = dict(alpha=0.1, beta=0.01, rho=0.5)


def _chain_setup(m, n_docs, n_topics, vocab, doc_len, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 8)
    tokens = jax.random.randint(ks[0], (m, n_docs, doc_len), 0, vocab,
                                jnp.int32)
    lens = jax.random.randint(ks[1], (m, n_docs), max(2, doc_len // 3),
                              doc_len + 1)
    mask = (jnp.arange(doc_len)[None, None] < lens[..., None]) \
        .astype(jnp.float32)
    z0 = jax.random.randint(ks[2], (m, n_docs, doc_len), 0, n_topics,
                            jnp.int32)
    d_idx = jnp.arange(n_docs)[:, None]
    ndt0 = jax.vmap(lambda z, mm: jnp.zeros((n_docs, n_topics))
                    .at[d_idx, z].add(mm))(z0, mask)
    ntw = jax.vmap(lambda z, t, mm: jnp.zeros((n_topics, vocab))
                   .at[z, t].add(mm))(z0, tokens, mask)
    nt = ntw.sum(-1)
    y = jax.random.normal(ks[3], (m, n_docs))
    inv_len = 1.0 / jnp.maximum(mask.sum(-1), 1.0)
    eta = jax.random.normal(ks[4], (m, n_topics))
    seeds = jax.random.randint(ks[5], (m, n_docs), 0, 2 ** 31 - 1,
                               jnp.int32)
    phi = jax.vmap(lambda k: jax.random.dirichlet(
        k, jnp.full((vocab,), 0.1), (n_topics,)))(
        jax.random.split(ks[6], m))
    return tokens, mask, z0, ndt0, ntw, nt, y, inv_len, eta, seeds, phi


# ------------------------------------------------------- train chain ops

@pytest.mark.parametrize("product_form", [False, True])
@pytest.mark.parametrize("m", [1, 3])
def test_train_chains_twin_bitwise_vs_vmapped(m, product_form):
    """chain_axis jnp twin == vmap of the single-chain jnp twin, exactly
    — both sampling forms, ragged masks, D not a doc_block multiple."""
    (tokens, mask, z0, ndt0, ntw, nt, y, inv_len, eta, seeds,
     _) = _chain_setup(m, 10, 8, 60, 18)
    kw = dict(n_sweeps=3, doc_block=4, use_pallas=False,
              product_form=product_form, **_HY)
    z_v, ndt_v = jax.vmap(functools.partial(ops.slda_train_sweeps, **kw))(
        tokens, mask, z0, ndt0, y, inv_len, ntw, nt, eta, seeds)
    z_c, ndt_c = ops.slda_train_sweeps(
        tokens, mask, z0, ndt0, y, inv_len, ntw, nt, eta, seeds,
        chain_axis=True, **kw)
    assert np.array_equal(np.asarray(z_v), np.asarray(z_c))
    np.testing.assert_allclose(np.asarray(ndt_v), np.asarray(ndt_c), atol=0)


@pytest.mark.parametrize("product_form", [False, True])
def test_train_chains_pallas_grid_matches_twin(product_form):
    """The grid-(M, B) interpret-mode kernel == the chain-batched twin."""
    (tokens, mask, z0, ndt0, ntw, nt, y, inv_len, eta, seeds,
     _) = _chain_setup(3, 12, 8, 60, 16, seed=1)
    kw = dict(n_sweeps=3, doc_block=4, chain_axis=True,
              product_form=product_form, **_HY)
    z_p, ndt_p = ops.slda_train_sweeps(
        tokens, mask, z0, ndt0, y, inv_len, ntw, nt, eta, seeds,
        use_pallas=True, **kw)
    z_j, ndt_j = ops.slda_train_sweeps(
        tokens, mask, z0, ndt0, y, inv_len, ntw, nt, eta, seeds,
        use_pallas=False, **kw)
    np.testing.assert_allclose(np.asarray(z_p), np.asarray(z_j), atol=0)
    np.testing.assert_allclose(np.asarray(ndt_p), np.asarray(ndt_j), atol=0)


def test_train_chains_oracle_coverage():
    """Chain-batched op == the vmap-of-single-chain oracle fed the SAME
    uniforms (ref_slda_train_sweeps_chains defines the semantics)."""
    (tokens, mask, z0, ndt0, ntw, nt, y, inv_len, eta, seeds,
     _) = _chain_setup(2, 10, 8, 50, 14, seed=2)
    kw = dict(n_sweeps=2, doc_block=4, chain_axis=True, **_HY)
    z_c, ndt_c = ops.slda_train_sweeps(
        tokens, mask, z0, ndt0, y, inv_len, ntw, nt, eta, seeds,
        use_pallas=False, **kw)
    us = jax.vmap(lambda s: train_uniforms(s, 2, 14))(seeds)
    z_r, ndt_r = ref.ref_slda_train_sweeps_chains(
        tokens, mask, us, z0, ndt0, y, inv_len,
        jnp.swapaxes(ntw, -1, -2), nt, eta,
        _HY["alpha"], _HY["beta"], _HY["rho"], True, 4)
    assert np.array_equal(np.asarray(z_c), np.asarray(z_r))
    np.testing.assert_allclose(np.asarray(ndt_c), np.asarray(ndt_r), atol=0)


def test_product_form_is_a_valid_sampler():
    """Product-form and log-form launches draw from the same conditionals:
    with frozen tables and ONE token position free, both must pick the
    same topic for almost every uniform (they differ only by rounding of
    the unnormalized categorical)."""
    (tokens, mask, z0, ndt0, ntw, nt, y, inv_len, eta, seeds,
     _) = _chain_setup(1, 64, 8, 40, 1, seed=3)
    kw = dict(n_sweeps=1, doc_block=8, chain_axis=True, use_pallas=False,
              **_HY)
    z_log, _ = ops.slda_train_sweeps(
        tokens, mask, z0, ndt0, y, inv_len, ntw, nt, eta, seeds,
        product_form=False, **kw)
    z_prod, _ = ops.slda_train_sweeps(
        tokens, mask, z0, ndt0, y, inv_len, ntw, nt, eta, seeds,
        product_form=True, **kw)
    agree = np.mean(np.asarray(z_log) == np.asarray(z_prod))
    assert agree > 0.95, agree


# ----------------------------------------------------- predict chain ops

def test_predict_chains_twin_bitwise_vs_vmapped_shared_corpus():
    """Folded-row chain twin (stacked φ̂, offset token ids) == vmap of the
    single-chain twin over a SHARED corpus, exactly."""
    (tokens, mask, z0, ndt0, _, _, _, _, _, seeds,
     phi) = _chain_setup(3, 11, 8, 60, 15, seed=4)
    tok_s, mask_s = tokens[0], mask[0]
    kw = dict(alpha=0.1, n_burnin=2, n_samples=3, use_pallas=False)
    a_v, z_v = jax.vmap(lambda s, z, nd, p: ops.slda_predict_sweeps(
        tok_s, mask_s, z, nd, p, s, **kw))(seeds, z0, ndt0, phi)
    a_c, z_c = ops.slda_predict_sweeps(tok_s, mask_s, z0, ndt0, phi, seeds,
                                       chain_axis=True, **kw)
    assert np.array_equal(np.asarray(z_v), np.asarray(z_c))
    np.testing.assert_allclose(np.asarray(a_v), np.asarray(a_c), atol=0)


def test_predict_chains_pallas_shared_token_tiles():
    """Grid-(M, B) interpret-mode kernel with SHARED token tiles == the
    folded twin == the chains oracle."""
    (tokens, mask, z0, ndt0, _, _, _, _, _, seeds,
     phi) = _chain_setup(3, 10, 8, 60, 15, seed=5)
    tok_s, mask_s = tokens[0], mask[0]
    kw = dict(alpha=0.1, n_burnin=2, n_samples=3, chain_axis=True)
    a_p, z_p = ops.slda_predict_sweeps(tok_s, mask_s, z0, ndt0, phi, seeds,
                                       use_pallas=True, doc_block=4, **kw)
    a_j, z_j = ops.slda_predict_sweeps(tok_s, mask_s, z0, ndt0, phi, seeds,
                                       use_pallas=False, **kw)
    np.testing.assert_allclose(np.asarray(a_p), np.asarray(a_j), atol=0)
    np.testing.assert_allclose(np.asarray(z_p), np.asarray(z_j), atol=0)
    us = jax.vmap(lambda s: predict_uniforms(s, 5, 15))(seeds)
    a_r, z_r = ref.ref_slda_predict_sweeps_chains(
        tok_s, mask_s, us, z0, ndt0, jnp.swapaxes(phi, -1, -2), 0.1, 2)
    np.testing.assert_allclose(np.asarray(a_r), np.asarray(a_j), atol=0)
    assert np.array_equal(np.asarray(z_r), np.asarray(z_j))


def test_predict_chains_per_chain_corpora():
    """chain_axis also accepts per-chain corpora [M, D, N] (the training
    shards of the Weighted Average weights at chains_per_device>1)."""
    (tokens, mask, z0, ndt0, _, _, _, _, _, seeds,
     phi) = _chain_setup(2, 9, 8, 50, 13, seed=6)
    kw = dict(alpha=0.1, n_burnin=1, n_samples=2, chain_axis=True)
    a_p, z_p = ops.slda_predict_sweeps(tokens, mask, z0, ndt0, phi, seeds,
                                       use_pallas=True, doc_block=4, **kw)
    a_j, z_j = ops.slda_predict_sweeps(tokens, mask, z0, ndt0, phi, seeds,
                                       use_pallas=False, **kw)
    np.testing.assert_allclose(np.asarray(a_p), np.asarray(a_j), atol=0)
    np.testing.assert_allclose(np.asarray(z_p), np.asarray(z_j), atol=0)


# -------------------------------------------------- gibbs sweep chain op

def test_gibbs_sweep_chain_axis_bitwise():
    (tokens, mask, z0, ndt0, ntw, nt, y, inv_len, eta, _,
     _) = _chain_setup(2, 10, 8, 50, 12, seed=7)
    u = jax.random.uniform(jax.random.PRNGKey(70), z0.shape)
    kw = dict(supervised=True, use_pallas=False, **_HY)
    z_v, ndt_v = jax.vmap(functools.partial(ops.slda_gibbs_sweep, **kw))(
        tokens, mask, u, z0, ndt0, y, inv_len, ntw, nt, eta)
    z_c, ndt_c = ops.slda_gibbs_sweep(
        tokens, mask, u, z0, ndt0, y, inv_len, ntw, nt, eta,
        chain_axis=True, **kw)
    assert np.array_equal(np.asarray(z_v), np.asarray(z_c))
    np.testing.assert_allclose(np.asarray(ndt_v), np.asarray(ndt_c), atol=0)


# ------------------------------------------------- core chain-batched EM
# (The spl=1 bit-identity of the chain-batched EM loop vs the
# seed-semantics reference — for every layout × M × backend cell — now
# lives in tests/test_dispatch_matrix.py.)

def test_weighted_average_fused_predict_matches_two_pass_statistically():
    """Fusing the test+train prediction passes changes the seed
    assignment, not the estimator: both forms must land in the same MSE
    ballpark on a learnable corpus."""
    cfg = SLDAConfig(n_topics=8, vocab_size=100, n_iters=15, rho=0.25,
                     sweeps_per_launch=5)
    corpus, _ = make_slda_corpus(jax.random.PRNGKey(15), 240, 100, 8, 24,
                                 rho=0.25)
    train, test = train_test_split(corpus, 192)
    var = float(jnp.var(test.y))
    for fuse in (True, False):
        c = dataclasses.replace(cfg, fuse_weighted_predict=fuse)
        yhat = jax.jit(run_weighted_average, static_argnums=(3, 4))(
            jax.random.PRNGKey(16), train, test, c, 4)
        mse = float(jnp.mean((yhat - test.y) ** 2))
        assert mse < 0.6 * var, (fuse, mse, var)


# -------------------------------------------------- hypothesis property

try:  # the rest of this module must still run without hypothesis
    from hypothesis import given, settings, strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    _HAVE_HYPOTHESIS = False
    given = settings = lambda *a, **k: (lambda f: f)

    class st:  # noqa: N801 — placeholder so the decorators below parse
        sampled_from = integers = lists = data = staticmethod(
            lambda *a, **k: None)


@pytest.mark.skipif(not _HAVE_HYPOTHESIS, reason=(
    "property tests need hypothesis (pip install -r requirements-dev.txt)"))
@settings(max_examples=15, deadline=None)
@given(
    m=st.sampled_from([1, 2, 5]),
    n_docs=st.integers(2, 9),
    doc_len=st.integers(2, 12),
    data=st.data(),
)
def test_chain_batched_property_ragged_masks(m, n_docs, doc_len, data):
    """For every M ∈ {1, 2, 5} and every ragged mask pattern (including
    all-padded documents), the chain-batched train twin equals the
    vmapped single-chain twin bitwise and conserves ndt against z."""
    seed = data.draw(st.integers(0, 2 ** 16))
    n_topics, vocab = 4, 24
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    tokens = jax.random.randint(ks[0], (m, n_docs, doc_len), 0, vocab,
                                jnp.int32)
    lens = data.draw(st.lists(st.integers(0, doc_len), min_size=m * n_docs,
                              max_size=m * n_docs))
    lens = jnp.asarray(lens, jnp.int32).reshape(m, n_docs)
    mask = (jnp.arange(doc_len)[None, None] < lens[..., None]) \
        .astype(jnp.float32)
    z0 = jax.random.randint(ks[1], (m, n_docs, doc_len), 0, n_topics,
                            jnp.int32)
    d_idx = jnp.arange(n_docs)[:, None]
    ndt0 = jax.vmap(lambda z, mm: jnp.zeros((n_docs, n_topics))
                    .at[d_idx, z].add(mm))(z0, mask)
    ntw = jax.vmap(lambda z, t, mm: jnp.zeros((n_topics, vocab))
                   .at[z, t].add(mm))(z0, tokens, mask)
    nt = ntw.sum(-1)
    y = jax.random.normal(ks[2], (m, n_docs))
    inv_len = 1.0 / jnp.maximum(mask.sum(-1), 1.0)
    eta = jax.random.normal(ks[3], (m, n_topics))
    seeds = jax.random.randint(ks[4], (m, n_docs), 0, 2 ** 31 - 1,
                               jnp.int32)
    kw = dict(n_sweeps=2, doc_block=4, use_pallas=False,
              product_form=True, **_HY)
    z_v, ndt_v = jax.vmap(functools.partial(ops.slda_train_sweeps, **kw))(
        tokens, mask, z0, ndt0, y, inv_len, ntw, nt, eta, seeds)
    z_c, ndt_c = ops.slda_train_sweeps(
        tokens, mask, z0, ndt0, y, inv_len, ntw, nt, eta, seeds,
        chain_axis=True, **kw)
    assert np.array_equal(np.asarray(z_v), np.asarray(z_c))
    np.testing.assert_allclose(np.asarray(ndt_v), np.asarray(ndt_c), atol=0)
    # padded tokens never move; ndt stays consistent with z
    pad = np.asarray(mask) == 0
    assert np.array_equal(np.asarray(z_c)[pad], np.asarray(z0)[pad])
    ndt_r = jax.vmap(lambda z, mm: jnp.zeros((n_docs, n_topics))
                     .at[d_idx, z].add(mm))(z_c, mask)
    np.testing.assert_allclose(np.asarray(ndt_c), np.asarray(ndt_r), atol=0)
