"""End-to-end behaviour tests for the paper's system.

The central scientific claims (Section IV) are asserted directly:
  * Naive Combination (pool sub-posteriors) suffers quasi-ergodicity →
    much worse test error,
  * Simple/Weighted Average (pool sub-PREDICTIONS) match Non-parallel.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import pytest

from repro.core import (SLDAConfig, run_naive, run_nonparallel,
                        run_simple_average, run_weighted_average,
                        train_chain, predict)
from repro.data import make_slda_corpus, train_test_split


@pytest.fixture(scope="module")
def corpus_pair():
    cfg = SLDAConfig(n_topics=8, vocab_size=200, n_iters=25, rho=0.25)
    corpus, _ = make_slda_corpus(jax.random.PRNGKey(0), 400, 200, 8, 50,
                                 rho=0.25)
    return (cfg,) + train_test_split(corpus, 320)


@pytest.fixture(scope="module")
def results(corpus_pair):
    cfg, train, test = corpus_pair
    k = jax.random.PRNGKey(7)
    out = {}
    out["nonparallel"] = jax.jit(run_nonparallel, static_argnums=(3,))(
        k, train, test, cfg)
    for name, fn in (("naive", run_naive), ("simple", run_simple_average),
                     ("weighted", run_weighted_average)):
        out[name] = jax.jit(fn, static_argnums=(3, 4))(k, train, test, cfg, 4)
    return {n: float(jnp.mean((y - test.y) ** 2)) for n, y in out.items()}


def test_slda_learns_signal(corpus_pair):
    """Single-chain sLDA beats the trivial predictor by a wide margin."""
    cfg, train, test = corpus_pair
    _, model = jax.jit(train_chain, static_argnums=(2,))(
        jax.random.PRNGKey(1), train, cfg)
    yhat = jax.jit(predict, static_argnums=(3,))(
        jax.random.PRNGKey(2), model, test, cfg)
    mse = float(jnp.mean((yhat - test.y) ** 2))
    assert mse < 0.6 * float(jnp.var(test.y))


def test_naive_combination_suffers_quasi_ergodicity(results):
    """Paper Fig. 6: naive sub-posterior pooling is much worse."""
    assert results["naive"] > 2.0 * results["simple"]
    assert results["naive"] > 2.0 * results["nonparallel"]


def test_prediction_combination_matches_nonparallel(results):
    """Paper Fig. 6: simple/weighted average ≈ non-parallel accuracy."""
    assert results["simple"] < 1.35 * results["nonparallel"]
    assert results["weighted"] < 1.35 * results["nonparallel"]


def test_weighted_no_worse_than_simple(results):
    assert results["weighted"] < 1.25 * results["simple"]


def test_shard_map_runner_is_communication_free():
    """The multi-device chain runner must contain NO collectives in the
    training phase; the only all-gather is the final prediction combine.
    Verified on 8 forced host devices in a subprocess (device count is
    locked at first jax use, so it cannot be changed in-process) — for
    BOTH chain implementations: the jnp fast paths and the use_pallas
    fused-kernel paths (interpret mode on the host mesh), the latter with
    multi-sweep launches so the fused train kernel is in the lowering."""
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp
        from repro.core import SLDAConfig
        from repro.data import make_slda_corpus, train_test_split
        from repro.launch.slda_parallel import parallel_slda_shard_map

        cfg = SLDAConfig(n_topics=4, vocab_size=64, n_iters=4,
                         n_pred_burnin=2, n_pred_samples=2)
        corpus, _ = make_slda_corpus(jax.random.PRNGKey(0), 64, 64, 4, 16)
        train, test = train_test_split(corpus, 48)
        mesh = jax.make_mesh((8, 1), ("data", "model"))

        fn = lambda key: parallel_slda_shard_map(key, train, test, cfg,
                                                 mesh, rule="simple")
        lowered = jax.jit(fn).lower(jax.random.PRNGKey(1))
        hlo = lowered.compile().as_text()
        assert "all-reduce(" not in hlo, "unexpected all-reduce in chains"
        assert "all-to-all(" not in hlo
        yhat = fn(jax.random.PRNGKey(1))
        assert yhat.shape == (16,)
        assert bool(jnp.all(jnp.isfinite(yhat)))

        # the fused-kernel chain runner must be collective-free too
        cfg_p = SLDAConfig(n_topics=4, vocab_size=64, n_iters=4,
                           n_pred_burnin=2, n_pred_samples=2,
                           use_pallas=True, sweeps_per_launch=2)
        fn_p = lambda key: parallel_slda_shard_map(key, train, test, cfg_p,
                                                   mesh, rule="simple")
        hlo_p = jax.jit(fn_p).lower(jax.random.PRNGKey(1)).compile().as_text()
        assert "all-reduce(" not in hlo_p, "all-reduce in pallas chains"
        assert "all-to-all(" not in hlo_p

        # chains_per_device>1: M = mesh x local chain batch decouples the
        # paper's M from the device count — still zero collectives
        cfg_c = SLDAConfig(n_topics=4, vocab_size=64, n_iters=4,
                           n_pred_burnin=2, n_pred_samples=2,
                           sweeps_per_launch=2, chains_per_device=2)
        fn_c = lambda key: parallel_slda_shard_map(key, train, test, cfg_c,
                                                   mesh, rule="weighted")
        hlo_c = jax.jit(fn_c).lower(jax.random.PRNGKey(1)).compile().as_text()
        assert "all-reduce(" not in hlo_c, "all-reduce in chain batch"
        assert "all-to-all(" not in hlo_c
        yhat_c = fn_c(jax.random.PRNGKey(1))
        assert yhat_c.shape == (16,)
        assert bool(jnp.all(jnp.isfinite(yhat_c)))
        print("OK")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, timeout=900, env=env, cwd="/root/repo")
    assert res.returncode == 0, res.stderr[-2000:]
    assert res.stdout.strip().endswith("OK")


def test_binary_label_pipeline():
    cfg = SLDAConfig(n_topics=8, vocab_size=128, n_iters=20,
                     label_type="binary", rho=0.25)
    corpus, _ = make_slda_corpus(jax.random.PRNGKey(3), 240, 128, 8, 40,
                                 label_type="binary")
    train, test = train_test_split(corpus, 200)
    yhat = jax.jit(run_weighted_average, static_argnums=(3, 4))(
        jax.random.PRNGKey(4), train, test, cfg, 4)
    acc = float(jnp.mean(((yhat > 0.5) == (test.y > 0.5))
                         .astype(jnp.float32)))
    assert acc > 0.7
