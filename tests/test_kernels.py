"""Per-kernel validation: Pallas (interpret mode) vs the pure-jnp oracle,
swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Corpus, SLDAConfig, init_state
from repro.data import make_slda_corpus
from repro.kernels import ops, ref


def keys(n, seed=0):
    return jax.random.split(jax.random.PRNGKey(seed), n)


# ---------------------------------------------------------------- attention

@pytest.mark.parametrize("b,hq,hkv,sq,sk,dh", [
    (1, 2, 2, 32, 32, 16),       # MHA, square
    (2, 4, 2, 64, 64, 32),       # GQA 2:1
    (1, 8, 1, 96, 96, 64),       # MQA; seq not a block multiple (pads)
    (2, 4, 4, 1, 128, 32),       # decode: 1 query vs cache
    (1, 4, 2, 16, 80, 32),       # ragged cache prefix
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(b, hq, hkv, sq, sk, dh, dtype):
    ks = keys(3)
    q = jax.random.normal(ks[0], (b, hq, sq, dh), dtype)
    k = jax.random.normal(ks[1], (b, hkv, sk, dh), dtype)
    v = jax.random.normal(ks[2], (b, hkv, sk, dh), dtype)
    out = ops.attention(q, k, v, causal=True, block_q=32, block_k=32)
    exp = ref.ref_attention(q, k, v, causal=True)
    tol = 2e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), atol=tol, rtol=tol)


def test_flash_attention_kv_len_masks_padded_cache():
    ks = keys(3)
    b, h, sk, dh = 2, 4, 64, 32
    q = jax.random.normal(ks[0], (b, h, 1, dh))
    k = jax.random.normal(ks[1], (b, h, sk, dh))
    v = jax.random.normal(ks[2], (b, h, sk, dh))
    kv_len = jnp.array([17, 50], jnp.int32)
    out = ops.attention(q, k, v, causal=True, kv_len=kv_len, block_k=32)
    exp = ref.ref_attention(q, k, v, causal=True, kv_len=kv_len)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=1e-5)
    # poisoning the masked tail must not change the output
    k2 = k.at[:, :, 55:].set(1e4)
    out2 = ops.attention(q, k2, v, causal=True, kv_len=kv_len, block_k=32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out2), atol=1e-5)


def test_flash_attention_noncausal():
    ks = keys(3)
    q = jax.random.normal(ks[0], (1, 2, 32, 16))
    k = jax.random.normal(ks[1], (1, 2, 64, 16))
    v = jax.random.normal(ks[2], (1, 2, 64, 16))
    out = ops.attention(q, k, v, causal=False, block_q=16, block_k=16)
    exp = ref.ref_attention(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=2e-6)


# ---------------------------------------------------------------------- ssd

@pytest.mark.parametrize("b,s,h,p,n,chunk", [
    (1, 64, 2, 8, 8, 16),
    (2, 128, 4, 16, 8, 32),
    (1, 96, 1, 32, 16, 32),      # s not a power of two
    (1, 50, 2, 8, 8, 16),        # s not a chunk multiple (pads)
])
def test_ssd_matches_ref(b, s, h, p, n, chunk):
    ks = keys(5, seed=3)
    x = jax.random.normal(ks[0], (b, s, h, p)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    B = jax.random.normal(ks[3], (b, s, n)) * 0.5
    C = jax.random.normal(ks[4], (b, s, n)) * 0.5
    out = ops.ssd(x, dt, A, B, C, chunk=chunk)
    exp = ref.ref_ssd(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               atol=2e-4, rtol=2e-4)


def test_ssd_decode_matches_scan():
    """Running the decode step token-by-token must equal the chunked scan."""
    ks = keys(5, seed=4)
    b, s, h, p, n = 2, 32, 2, 8, 8
    x = jax.random.normal(ks[0], (b, s, h, p)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    B = jax.random.normal(ks[3], (b, s, n)) * 0.5
    C = jax.random.normal(ks[4], (b, s, n)) * 0.5
    state = jnp.zeros((b, h, p, n))
    ys = []
    for t in range(s):
        state, y_t = ops.ssd_decode_step(state, x[:, t], dt[:, t], A,
                                         B[:, t], C[:, t])
        ys.append(y_t)
    got = jnp.stack(ys, axis=1)                       # [b, s, h, p]
    exp = ref.ref_ssd(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp),
                               atol=2e-4, rtol=2e-4)


# ------------------------------------------------------------------ rmsnorm

@pytest.mark.parametrize("shape", [(4, 64), (3, 7, 96), (130, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_matches_ref(shape, dtype):
    ks = keys(2, seed=5)
    x = jax.random.normal(ks[0], shape, dtype)
    w = jax.random.normal(ks[1], shape[-1:], jnp.float32)
    out = ops.rmsnorm(x, w)
    exp = ref.ref_rmsnorm(x, w)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), atol=tol, rtol=tol)


# --------------------------------------------------------------- slda gibbs

@pytest.mark.parametrize("n_docs,n_topics,vocab,doc_len,doc_block", [
    (16, 8, 100, 30, 8),
    (10, 16, 64, 20, 4),         # D not a doc_block multiple (pads)
    (8, 128, 200, 16, 8),        # full-lane topic dim
])
@pytest.mark.parametrize("supervised", [True, False])
def test_slda_gibbs_kernel_matches_ref(n_docs, n_topics, vocab, doc_len,
                                       doc_block, supervised):
    cfg = SLDAConfig(n_topics=n_topics, vocab_size=vocab)
    corpus, _ = make_slda_corpus(jax.random.PRNGKey(0), n_docs, vocab,
                                 n_topics, doc_len)
    state = init_state(jax.random.PRNGKey(1), corpus, cfg)
    eta = state.eta + 0.3                 # non-trivial η to exercise the
    uniforms = jax.random.uniform(jax.random.PRNGKey(2), corpus.tokens.shape)
    inv_len = 1.0 / jnp.maximum(corpus.mask.sum(-1), 1.0)
    args = (corpus.tokens, corpus.mask, uniforms, state.z, state.ndt,
            corpus.y, inv_len, state.ntw, state.nt, eta)
    kw = dict(alpha=cfg.alpha, beta=cfg.beta, rho=cfg.rho,
              supervised=supervised)
    z_k, ndt_k = ops.slda_gibbs_sweep(*args, doc_block=doc_block, **kw)
    z_r, ndt_r = ops.slda_gibbs_sweep(*args, use_pallas=False, **kw)
    assert np.array_equal(np.asarray(z_k), np.asarray(z_r))
    np.testing.assert_allclose(np.asarray(ndt_k), np.asarray(ndt_r), atol=0)


def test_slda_gibbs_counts_consistent():
    """ndt returned by the kernel must equal counts recomputed from z."""
    cfg = SLDAConfig(n_topics=8, vocab_size=64)
    corpus, _ = make_slda_corpus(jax.random.PRNGKey(3), 16, 64, 8, 24)
    state = init_state(jax.random.PRNGKey(4), corpus, cfg)
    uniforms = jax.random.uniform(jax.random.PRNGKey(5), corpus.tokens.shape)
    inv_len = 1.0 / jnp.maximum(corpus.mask.sum(-1), 1.0)
    z, ndt = ops.slda_gibbs_sweep(
        corpus.tokens, corpus.mask, uniforms, state.z, state.ndt, corpus.y,
        inv_len, state.ntw, state.nt, state.eta,
        alpha=cfg.alpha, beta=cfg.beta, rho=cfg.rho)
    d_idx = jnp.arange(corpus.n_docs)[:, None]
    expect = jnp.zeros_like(ndt).at[d_idx, z].add(corpus.mask)
    np.testing.assert_allclose(np.asarray(ndt), np.asarray(expect), atol=0)
