"""Per-architecture smoke tests: reduced config of the same family, one
forward + one train-step + one decode-step on CPU; asserts shapes + finite."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SMOKES, cells_for
from repro.models import (ModelConfig, decode_step, forward, init_cache,
                          init_params, loss_fn)

CHAINS = 2
BATCH = 2
SEQ = 16


def make_batch(cfg: ModelConfig, key, seq=SEQ, batch=BATCH, with_targets=True):
    ks = jax.random.split(key, 3)
    b = {"tokens": jax.random.randint(ks[0], (CHAINS, batch, seq), 0,
                                      cfg.vocab_size, jnp.int32)}
    if with_targets:
        b["targets"] = jax.random.randint(ks[1], (CHAINS, batch, seq), 0,
                                          cfg.vocab_size, jnp.int32)
    if cfg.frontend == "vision":
        b["embeds"] = jax.random.normal(
            ks[2], (CHAINS, batch, cfg.n_patches, cfg.d_model))
    elif cfg.frontend == "audio":
        b["embeds"] = jax.random.normal(ks[2], (CHAINS, batch, seq,
                                                cfg.d_model))
    return b


@pytest.mark.parametrize("name", sorted(SMOKES))
def test_forward_shapes_and_finite(name):
    cfg = SMOKES[name]
    params = init_params(jax.random.PRNGKey(0), cfg, CHAINS)
    batch = make_batch(cfg, jax.random.PRNGKey(1))
    logits, aux = forward(params, batch, cfg, compute_dtype=jnp.float32,
                          use_pallas=False, remat=False)
    assert logits.shape == (CHAINS, BATCH, SEQ, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert aux.shape == (CHAINS,)


@pytest.mark.parametrize("name", sorted(SMOKES))
def test_train_step_decreases_loss(name):
    """One SGD step on a repeated batch must reduce the loss (per chain)."""
    cfg = SMOKES[name]
    params = init_params(jax.random.PRNGKey(0), cfg, CHAINS)
    batch = make_batch(cfg, jax.random.PRNGKey(1))

    def total(p):
        return loss_fn(p, batch, cfg, compute_dtype=jnp.float32,
                       use_pallas=False, remat=False).sum()

    l0, grads = jax.value_and_grad(total)(params)
    assert np.isfinite(float(l0))
    gnorm = jax.tree_util.tree_reduce(
        lambda a, g: a + jnp.sum(jnp.square(g.astype(jnp.float32))), grads, 0.0)
    assert float(gnorm) > 0.0
    params2 = jax.tree.map(lambda p, g: p - 0.05 * g.astype(p.dtype)
                           / (jnp.linalg.norm(g.astype(jnp.float32)) + 1e-6),
                           params, grads)
    l1 = total(params2)
    assert float(l1) < float(l0), (name, float(l0), float(l1))


@pytest.mark.parametrize("name", sorted(SMOKES))
def test_decode_step_matches_forward(name):
    """Greedy next-token logits from the cache path must match the full
    forward pass at the same position (prefill via repeated decode)."""
    cfg = SMOKES[name]
    if cfg.frontend == "vision":
        pytest.skip("vision prefill path exercised in test_forward; decode "
                    "cache-parity needs image prefill, covered by shapes")
    params = init_params(jax.random.PRNGKey(0), cfg, CHAINS)
    seq = 8
    batch = make_batch(cfg, jax.random.PRNGKey(1), seq=seq,
                       with_targets=False)
    logits_full, _ = forward(params, batch, cfg, compute_dtype=jnp.float32,
                             use_pallas=False, remat=False)

    cache = init_cache(cfg, CHAINS, BATCH, max_len=seq, dtype=jnp.float32)
    outs = []
    for t in range(seq):
        step_batch = {"tokens": batch["tokens"][:, :, t:t + 1]}
        if cfg.frontend == "audio":
            step_batch["embeds"] = batch["embeds"][:, :, t:t + 1]
        lg, cache = decode_step(params, cache, step_batch, cfg,
                                compute_dtype=jnp.float32, use_pallas=False)
        outs.append(lg[:, :, 0])
    got = jnp.stack(outs, axis=2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(logits_full),
                               atol=2e-3, rtol=2e-3)


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_full_config_dimensions(name):
    """The FULL configs match the assignment table exactly."""
    cfg = ARCHS[name]
    table = {
        "qwen2.5-32b": (64, 5120, 40, 8, 27648, 152064),
        "codeqwen1.5-7b": (32, 4096, 32, 32, 13440, 92416),
        "internlm2-1.8b": (24, 2048, 16, 8, 8192, 92544),
        "qwen3-1.7b": (28, 2048, 16, 8, 6144, 151936),
        "arctic-480b": (35, 7168, 56, 8, 4864, 32000),
        "phi3.5-moe-42b-a6.6b": (32, 4096, 32, 8, 6400, 32064),
        "zamba2-2.7b": (54, 2560, 32, 32, 10240, 32000),
        "internvl2-2b": (24, 2048, 16, 8, 8192, 92553),
        "musicgen-medium": (48, 1536, 24, 24, 6144, 2048),
        "mamba2-1.3b": (48, 2048, 1, 1, 0, 50280),
    }
    L, D, H, KV, FF, V = table[name]
    assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
            cfg.d_ff, cfg.vocab_size) == (L, D, H, KV, FF, V)
    # family checks
    if name == "arctic-480b":
        assert cfg.n_experts == 128 and cfg.moe_top_k == 2
        assert cfg.moe_dense_d_ff > 0          # dense residual
    if name == "phi3.5-moe-42b-a6.6b":
        assert cfg.n_experts == 16 and cfg.moe_top_k == 2
    if name == "qwen3-1.7b":
        assert cfg.qk_norm
    if name in ("qwen2.5-32b", "codeqwen1.5-7b"):
        assert cfg.qkv_bias
    if name == "zamba2-2.7b":
        assert cfg.ssm_state == 64 and cfg.shared_attn_every > 0
    if name == "mamba2-1.3b":
        assert cfg.attention_free and cfg.ssm_state == 128
    # long_500k eligibility per DESIGN.md §5
    assert ("long_500k" in cells_for(cfg)) == (
        name in ("zamba2-2.7b", "mamba2-1.3b"))


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_param_counts_plausible(name):
    """param_count() must land near the advertised size."""
    expected = {
        "qwen2.5-32b": 32e9, "codeqwen1.5-7b": 7e9, "internlm2-1.8b": 1.8e9,
        "qwen3-1.7b": 1.7e9, "arctic-480b": 480e9,
        "phi3.5-moe-42b-a6.6b": 42e9, "zamba2-2.7b": 2.7e9,
        "internvl2-2b": 1.8e9, "musicgen-medium": 1.5e9,
        "mamba2-1.3b": 1.3e9,
    }[name]
    got = ARCHS[name].param_count()
    assert 0.55 * expected < got < 1.75 * expected, (name, got, expected)
