"""Data pipeline + prediction-path properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -r requirements-dev.txt)")
from hypothesis import HealthCheck, given, settings, strategies as st

from repro.core import (SLDAConfig, SLDAModel, partition, predict,
                        train_chain)
from repro.data import (make_slda_corpus, shuffle_corpus, synthetic_lm_batch,
                        train_test_split)


def test_corpus_generator_properties():
    corpus, eta = make_slda_corpus(jax.random.PRNGKey(0), 64, 100, 8, 30)
    assert corpus.tokens.shape == (64, 30)
    assert int(corpus.tokens.min()) >= 0
    assert int(corpus.tokens.max()) < 100
    # mask is a proper prefix mask with ragged lengths
    m = np.asarray(corpus.mask)
    assert set(np.unique(m)) <= {0.0, 1.0}
    lens = m.sum(1)
    assert lens.min() >= 15 and lens.max() <= 30
    for row, l in zip(m, lens):
        assert row[:int(l)].all() and not row[int(l):].any()


def test_binary_labels_are_balanced():
    corpus, _ = make_slda_corpus(jax.random.PRNGKey(1), 200, 100, 8, 30,
                                 label_type="binary")
    frac = float(corpus.y.mean())
    assert 0.4 < frac < 0.6          # median threshold → balanced


def test_partition_preserves_documents():
    corpus, _ = make_slda_corpus(jax.random.PRNGKey(2), 32, 64, 4, 16)
    shards = partition(corpus, 4)
    assert shards.tokens.shape == (4, 8, 16)
    np.testing.assert_array_equal(
        np.asarray(shards.tokens.reshape(32, 16)), np.asarray(corpus.tokens))


def test_shuffle_is_permutation():
    corpus, _ = make_slda_corpus(jax.random.PRNGKey(3), 32, 64, 4, 16)
    shuf = shuffle_corpus(jax.random.PRNGKey(4), corpus)
    assert sorted(np.asarray(shuf.y).tolist()) == \
        sorted(np.asarray(corpus.y).tolist())
    assert not np.array_equal(np.asarray(shuf.y), np.asarray(corpus.y))


def test_lm_batch_restartable():
    b1 = synthetic_lm_batch(7, 42, 4, 16, 100)
    b2 = synthetic_lm_batch(7, 42, 4, 16, 100)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    b3 = synthetic_lm_batch(7, 43, 4, 16, 100)
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))
    # targets are the shifted continuation of the same stream
    np.testing.assert_array_equal(np.asarray(b1["tokens"][:, 1:]),
                                  np.asarray(b1["targets"][:, :-1]))


def test_prediction_uses_phi_not_labels():
    """Predicting with a deliberately permuted η must permute predictions —
    i.e. ŷ depends on the model, not on any leaked test label."""
    cfg = SLDAConfig(n_topics=4, vocab_size=64, n_iters=10,
                     n_pred_burnin=4, n_pred_samples=4)
    corpus, _ = make_slda_corpus(jax.random.PRNGKey(5), 96, 64, 4, 24)
    train, test = train_test_split(corpus, 64)
    _, model = jax.jit(train_chain, static_argnums=(2,))(
        jax.random.PRNGKey(6), train, cfg)
    y1 = predict(jax.random.PRNGKey(7), model, test, cfg)
    flipped = SLDAModel(phi=model.phi, eta=-model.eta,
                        train_mse=model.train_mse, train_acc=model.train_acc)
    y2 = predict(jax.random.PRNGKey(7), flipped, test, cfg)
    np.testing.assert_allclose(np.asarray(y2), -np.asarray(y1), atol=1e-5)
