"""Metrics substrate tests: logger restart semantics + ensemble health."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.metrics import (MetricLogger, chain_divergence, ensemble_health,
                           throughput_tokens_per_s)


def test_logger_roundtrip_and_restart(tmp_path):
    path = str(tmp_path / "m.jsonl")
    log = MetricLogger(path)
    for s in range(5):
        log.log(s, loss=[1.0 / (s + 1), 2.0], lr=1e-3)
    # simulate restart from step 3: steps 3,4 re-logged with new values
    log2 = MetricLogger(path)
    log2.log(3, loss=[9.0, 9.0], lr=1e-3)
    rows = log2.read()
    assert [r["step"] for r in rows] == [0, 1, 2, 3, 4]
    assert rows[3]["loss"] == [9.0, 9.0]       # superseded


def test_logger_survives_partial_line(tmp_path):
    path = str(tmp_path / "m.jsonl")
    log = MetricLogger(path)
    log.log(0, loss=1.0)
    with open(path, "a") as f:
        f.write('{"step": 1, "loss"')           # crash mid-write
    assert [r["step"] for r in log.read()] == [0]


def test_throughput():
    assert throughput_tokens_per_s(256, 4096, 2.0) == 256 * 4096 / 2.0


def test_chain_divergence_zero_for_identical():
    logits = jnp.broadcast_to(jnp.arange(8.0), (3, 4, 8))
    kl = chain_divergence(logits)
    np.testing.assert_allclose(np.asarray(kl), 0.0, atol=1e-5)


def test_chain_divergence_positive_for_different():
    k = jax.random.PRNGKey(0)
    logits = jax.random.normal(k, (3, 4, 16)) * 3
    kl = np.asarray(chain_divergence(logits))
    off = kl[~np.eye(3, dtype=bool)]
    assert (off > 0.01).all()
    np.testing.assert_allclose(kl, kl.T, atol=1e-5)


def test_ensemble_health_drops_diverged_chain():
    loss = jnp.asarray([2.30, 2.28, 2.31, 45.0])      # chain 3 diverged
    alive, report = ensemble_health(loss)
    assert alive.tolist() == [1.0, 1.0, 1.0, 0.0]


def test_ensemble_health_drops_nan_chain():
    loss = jnp.asarray([2.3, jnp.nan, 2.31])
    alive, _ = ensemble_health(loss)
    assert alive.tolist() == [1.0, 0.0, 1.0]


def test_ensemble_health_flags_collapse():
    loss = jnp.asarray([2.3, 2.3])
    same = jnp.broadcast_to(jnp.arange(16.0), (2, 4, 16))
    _, report = ensemble_health(loss, logits=same)
    assert report["collapsed"]
    diff = jax.random.normal(jax.random.PRNGKey(1), (2, 4, 16)) * 3
    _, report = ensemble_health(loss, logits=diff)
    assert not report["collapsed"]
