"""Statistical-correctness tier for the sLDA samplers (@slow).

Bitwise equivalence (test_train_kernel.py) pins the three implementations
to each other; this tier pins them to the *model*.  Two instruments:

  * a Geweke-style joint-distribution test: the collapsed Gibbs transition
    (through the NEW fused multi-sweep train path), composed with exact
    word- and label-resampling conditionals, must leave the joint prior
    p(z, w, y) invariant — so marginal topic-count statistics of the
    successive-conditional chain must match independent forward samples
    from the generative model (Geweke 2004; Grosse & Duvenaud 2014).
    This catches the bugs bitwise tests cannot: a wrong -dn exclusion, a
    dropped prior term, or a mis-scaled supervised likelihood all shift
    these marginals even while all three implementations agree perfectly.

  * long-run count-invariant tests: after 50 sweeps of purely incremental
    (never-rebuilt) refresh, the ndt/ntw/nt tables must remain EXACTLY
    consistent with z — the ±1.0-float32-is-lossless claim of DESIGN.md
    §3, held to atol=0 over a horizon an order of magnitude past the
    tier-1 versions.

The Gibbs sweep freezes the topic-word table within a sweep (AD-LDA
delayed counts, DESIGN.md §3), so its transition is *approximately*
invariant; the corpus here is tiny with strong smoothing, keeping that
bias far below the test resolution (thresholds hold with >2x margin, and
the statistics have enough power to catch the gross errors above).
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (SLDAConfig, counts_from_assignments, init_state,
                        sweep, train_chain)
from repro.data import make_slda_corpus
from repro.kernels import ops

# tiny joint model: strong priors keep the delayed-count bias negligible
D, N, T, W = 2, 5, 3, 6
ALPHA, BETA, RHO = 0.8, 0.8, 0.5
ETA = jnp.asarray([1.0, -1.0, 0.5], jnp.float32)
MASK = jnp.ones((D, N), jnp.float32)
INV_LEN = jnp.full((D,), 1.0 / N, jnp.float32)


def _stats(z, w, y):
    """Statistics compared between the two samplers.  First moments
    (topic-0 total, one doc-topic cell, label mean, one topic-word cell)
    catch asymmetric shifts; the SECOND moments Σndt² / Σntw² catch
    concentration errors that topic symmetry hides from the means (a
    wrong α or β moves these several σ while leaving E[nt] untouched);
    Σ y·(z̄η) pins the supervised coupling (a mis-scaled ρ moves it)."""
    ndt = jnp.sum(jax.nn.one_hot(z, T), axis=1)            # [D, T]
    ntw = jnp.zeros((T, W)).at[z.ravel(), w.ravel()].add(1.0)
    return jnp.stack([
        jnp.sum((z == 0).astype(jnp.float32)),
        jnp.sum((z[0] == 0).astype(jnp.float32)),
        jnp.mean(y),
        jnp.sum(((z == 0) & (w == 0)).astype(jnp.float32)),
        jnp.sum(ndt ** 2),
        jnp.sum(ntw ** 2),
        jnp.sum(y * ((ndt / N) @ ETA)),
    ])


def _forward_samples(key, n_samples):
    """Independent draws of (z, w, y) from the generative model —
    Geweke's marginal-conditional sampler."""
    kt, kp, kz, kw, ky = jax.random.split(key, 5)
    theta = jax.random.dirichlet(kt, jnp.full((T,), ALPHA), (n_samples, D))
    z = jax.random.categorical(kz, jnp.log(theta)[:, :, None, :],
                               shape=(n_samples, D, N))
    phi = jax.random.dirichlet(kp, jnp.full((W,), BETA), (n_samples, T))
    logits = jnp.log(phi)[jnp.arange(n_samples)[:, None, None], z]
    w = jax.random.categorical(kw, logits)
    zbar = jnp.mean(jax.nn.one_hot(z, T), axis=2)          # [S, D, T]
    y = zbar @ ETA + jnp.sqrt(RHO) * jax.random.normal(ky, (n_samples, D))
    return jax.vmap(_stats)(z, w, y)


def _word_gibbs_sweep(key, w, z):
    """Exact sequential collapsed Gibbs over the words:
    w_{dn} | w_-dn, z  ∝  N_{z_dn, w}^{-dn} + β  (φ integrated out).
    Leaves p(w | z) invariant; nt is untouched (the topic is fixed)."""
    w_flat, z_flat = w.ravel(), z.ravel()
    ntw = jnp.zeros((T, W), jnp.float32).at[z_flat, w_flat].add(1.0)
    us = jax.random.uniform(key, (D * N,))

    def step(carry, inp):
        ntw, w_flat = carry
        i, u = inp
        zi, wi = z_flat[i], w_flat[i]
        ntw = ntw.at[zi, wi].add(-1.0)
        c = jnp.cumsum(ntw[zi] + BETA)
        wn = jnp.sum((c < u * c[-1]).astype(jnp.int32))
        return (ntw.at[zi, wn].add(1.0), w_flat.at[i].set(wn)), None

    (_, w_flat), _ = jax.lax.scan(
        step, (ntw, w_flat), (jnp.arange(D * N), us))
    return w_flat.reshape(D, N)


def _successive_samples(key, n_iters, product_form=False,
                        sampler_mode="dense"):
    """Geweke's successive-conditional sampler: alternate the sLDA Gibbs
    transition on z (the FUSED multi-sweep train path: 2 sweeps per
    launch, doc_block=1, so the in-launch block-local delayed-count
    refresh is exercised — and, with product_form, the one-exp sampling
    of DESIGN.md §Chain-batched), an exact word-Gibbs sweep, and an
    exact label redraw.  Collect the same statistics once per cycle.

    sampler_mode="sparse" routes every draw through the two-stage
    sparse draw (DESIGN.md §Sparse-sampler) — the strongest check that
    its exactness argument holds inside a real training transition, not
    just at the collapse contract."""
    k0, kc = jax.random.split(key)
    kt, kp, kz, kw, ky = jax.random.split(k0, 5)
    theta = jax.random.dirichlet(kt, jnp.full((T,), ALPHA), (D,))
    z = jax.random.categorical(kz, jnp.log(theta)[:, None, :],
                               shape=(D, N)).astype(jnp.int32)
    phi = jax.random.dirichlet(kp, jnp.full((W,), BETA), (T,))
    w = jax.random.categorical(kw, jnp.log(phi)[z]).astype(jnp.int32)
    zbar0 = jnp.mean(jax.nn.one_hot(z, T), axis=1)
    y = zbar0 @ ETA + jnp.sqrt(RHO) * jax.random.normal(ky, (D,))

    def cycle(carry, k):
        z, w, y = carry
        k1, k2, k3 = jax.random.split(k, 3)
        ndt, ntw, nt = counts_from_assignments(w, MASK, z, T, W)
        seeds = jax.random.randint(k1, (D,), 0, jnp.iinfo(jnp.int32).max,
                                   jnp.int32)
        z, ndt = ops.slda_train_sweeps(
            w, MASK, z, ndt, y, INV_LEN, ntw, nt, ETA, seeds,
            alpha=ALPHA, beta=BETA, rho=RHO, n_sweeps=2, doc_block=1,
            use_pallas=False, product_form=product_form,
            sampler_mode=sampler_mode, sparse_topic_cap=2)
        w = _word_gibbs_sweep(k2, w, z)
        y = (ndt / N) @ ETA + jnp.sqrt(RHO) * jax.random.normal(k3, (D,))
        return (z, w, y), _stats(z, w, y)

    _, stats = jax.lax.scan(cycle, (z, w, y),
                            jax.random.split(kc, n_iters))
    return stats


@pytest.mark.slow
@pytest.mark.parametrize("sampler_mode", ["dense", "sparse"])
@pytest.mark.parametrize("product_form", [False, True])
def test_geweke_joint_distribution_agreement(product_form, sampler_mode):
    """Successive-conditional vs forward marginals agree within Monte
    Carlo error (|z-score| < 4 per statistic, two-sample test with the
    chain thinned for autocorrelation) — for BOTH sampling forms of the
    fused multi-sweep path (log form and the product form of DESIGN.md
    §Chain-batched) × BOTH draw modes (dense inverse-CDF and the sparse
    two-stage draw with cap=2 < T=3, so the residual stage-2 correction
    fires for real — the distributional-exactness claim of DESIGN.md
    §Sparse-sampler under the full joint model)."""
    n_forward, n_chain, burn, thin = 6000, 6000, 500, 5
    fwd = np.asarray(jax.jit(_forward_samples, static_argnums=(1,))(
        jax.random.PRNGKey(0), n_forward))
    chain = np.asarray(jax.jit(_successive_samples,
                               static_argnums=(1, 2, 3))(
        jax.random.PRNGKey(1), n_chain, product_form,
        sampler_mode))[burn::thin]

    se = np.sqrt(fwd.var(0, ddof=1) / fwd.shape[0]
                 + chain.var(0, ddof=1) / chain.shape[0])
    zscores = (fwd.mean(0) - chain.mean(0)) / se
    assert np.all(np.abs(zscores) < 4.0), (
        f"Geweke z-scores {zscores} (stats: nt0, ndt00, ymean, ntw00, "
        f"Σndt², Σntw², Σy·z̄η); forward means {fwd.mean(0)}, chain means "
        f"{chain.mean(0)}")


@pytest.mark.slow
def test_incremental_counts_exact_after_50_sweeps_seed_path():
    """50 never-rebuilt incremental sweeps (seed per-sweep path) leave
    ndt/ntw/nt EXACTLY consistent with z."""
    cfg = SLDAConfig(n_topics=12, vocab_size=128, count_rebuild_every=0)
    corpus, _ = make_slda_corpus(jax.random.PRNGKey(20), 32, 128, 12, 24)
    state = init_state(jax.random.PRNGKey(21), corpus, cfg)
    step = jax.jit(functools.partial(sweep, supervised=True,
                                     exact_rebuild=False),
                   static_argnums=(3,))
    for k in range(50):
        state = step(jax.random.PRNGKey(100 + k), corpus, state, cfg)
    ndt, ntw, nt = counts_from_assignments(corpus.tokens, corpus.mask,
                                           state.z, cfg.n_topics,
                                           cfg.vocab_size)
    np.testing.assert_allclose(np.asarray(state.ndt), np.asarray(ndt), atol=0)
    np.testing.assert_allclose(np.asarray(state.ntw), np.asarray(ntw), atol=0)
    np.testing.assert_allclose(np.asarray(state.nt), np.asarray(nt), atol=0)


@pytest.mark.slow
def test_incremental_counts_exact_after_50_sweeps_fused_path():
    """The same 50-sweep horizon through the fused multi-sweep launches
    (block-local in-launch refresh + compacted global deltas between
    launches, never rebuilt): tables still exactly consistent with z."""
    cfg = SLDAConfig(n_topics=12, vocab_size=128, n_iters=50,
                     sweeps_per_launch=5, count_rebuild_every=0)
    corpus, _ = make_slda_corpus(jax.random.PRNGKey(22), 32, 128, 12, 24)
    state, _ = jax.jit(train_chain, static_argnums=(2,))(
        jax.random.PRNGKey(23), corpus, cfg)
    ndt, ntw, nt = counts_from_assignments(corpus.tokens, corpus.mask,
                                           state.z, cfg.n_topics,
                                           cfg.vocab_size)
    np.testing.assert_allclose(np.asarray(state.ndt), np.asarray(ndt), atol=0)
    np.testing.assert_allclose(np.asarray(state.ntw), np.asarray(ntw), atol=0)
    np.testing.assert_allclose(np.asarray(state.nt), np.asarray(nt), atol=0)
