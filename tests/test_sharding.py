"""Sharding-rule unit tests (no multi-device mesh needed: the rules are
pure functions of shapes + a mesh object built on 1 device via AbstractMesh
semantics — we use a real 1×1 mesh but with fake axis sizes through
jax.sharding.AbstractMesh)."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs import SMOKES
from repro.launch.sharding import (DistConfig, batch_specs, chain_axes,
                                   dp_axes, param_specs)
from repro.models import init_params


def mesh_single():
    # AbstractMesh takes a shape_tuple of (name, size) pairs
    return AbstractMesh((("data", 16), ("model", 16)))


def mesh_multi():
    return AbstractMesh((("pod", 2), ("data", 16), ("model", 16)))


def test_chain_axes_mapping():
    assert chain_axes(mesh_single(), 1) == ()
    assert chain_axes(mesh_single(), 16) == ("data",)
    assert chain_axes(mesh_multi(), 2) == ("pod",)
    assert chain_axes(mesh_multi(), 32) == ("pod", "data")
    with pytest.raises(ValueError):
        chain_axes(mesh_single(), 4)


def test_dp_axes_complement():
    assert dp_axes(mesh_single(), 1) == ("data",)
    assert dp_axes(mesh_single(), 16) == ()
    assert dp_axes(mesh_multi(), 2) == ("data",)
    assert dp_axes(mesh_multi(), 1) == ("pod", "data")


@pytest.mark.parametrize("name", sorted(SMOKES))
def test_param_specs_cover_tree_and_divide(name):
    """Every param leaf gets a spec whose sharded dims divide evenly."""
    cfg = SMOKES[name]
    mesh = AbstractMesh((("data", 4), ("model", 4)))
    params = jax.eval_shape(
        lambda k: init_params(k, cfg, 4), jax.ShapeDtypeStruct((2,),
                                                               jnp.uint32))
    dist = DistConfig(n_chains=4, fsdp=False)
    specs = param_specs(params, mesh, dist)
    sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))

    def check(leaf, spec):
        assert isinstance(spec, P)
        assert len(spec) <= leaf.ndim
        for dim, entry in zip(leaf.shape, tuple(spec)):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            total = 1
            for a in axes:
                total *= sizes[a]
            assert dim % total == 0, (name, leaf.shape, spec)

    jax.tree.map(check, params, specs,
                 is_leaf=lambda x: isinstance(x, P))
    # chain dim must be sharded over 'data' on every leaf (axis 0, or
    # axis 1 for scanned stacks whose leading dim is the layer index)
    leaves = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert all("data" in tuple(s)[:2] for s in leaves if len(tuple(s))), \
        "all leaves carry the chain axis"


def test_batch_specs_train_vs_serve():
    mesh = mesh_multi()
    batch = {"tokens": jax.ShapeDtypeStruct((2, 128, 512), jnp.int32)}
    train_spec = batch_specs(batch, mesh, DistConfig(n_chains=2))
    assert tuple(train_spec["tokens"]) == ("pod", "data", None)
    serve_spec = batch_specs(batch, mesh, DistConfig(n_chains=2),
                             replicated_serve=True)
    assert tuple(serve_spec["tokens"]) == ("pod", None, None)


def test_fsdp_only_when_data_free():
    """FSDP must silently disable when chains occupy the data axis."""
    mesh = mesh_single()
    params = {"lm_head": jax.ShapeDtypeStruct((16, 64, 64), jnp.float32)}
    spec_fsdp = param_specs(params, mesh, DistConfig(n_chains=1, fsdp=True))
    assert tuple(spec_fsdp["lm_head"]) == (None, "data", "model")
    spec_chain = param_specs(params, mesh, DistConfig(n_chains=16,
                                                      fsdp=True))
    assert tuple(spec_chain["lm_head"]) == ("data", None, "model")
