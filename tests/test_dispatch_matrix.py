"""The execution-plan dispatch matrix, cell by cell (DESIGN.md
§Execution-plan).

One parametrized sweep over (padded | bucketed) × (M ∈ {1, 4}) ×
(jnp | pallas-interpret) × (spl ∈ {1, 4}) asserting the documented
contract per cell:

  * spl=1 — BIT-IDENTITY: every cell reproduces the seed-semantics
    reference (per-sweep threefry uniforms, η solve every sweep,
    globally sweep-frozen counts) built here from the core primitives
    (`init_state`/`sweep`/`solve_eta` — the vmapped per-document
    oracle, independent of the plan loop), per document, under any
    bucketing/permutation.  State AND model — ndt/η live in original
    doc order at every EM boundary, so even cross-document reductions
    agree.
  * spl=4 — STATISTICAL EQUIVALENCE: each cell is its own member of
    the fused sampler family (counter-hash PRNG, delayed counts).
    Asserted: counts exactly consistent with the final z (exactness of
    the EM boundary never depends on the cell), the remainder launch
    keeps total sweeps == n_iters (covered by n_iters % spl != 0), and
    the model lands in the reference's quality ballpark.

Prediction cells: every (layout × M × backend) combination must be
bit-identical to the reference single-model fused pass (prediction is
document-independent under frozen φ̂ — no spl axis).

This file replaces the ad-hoc core-level parity asserts previously
spread over test_chain_batched.py / test_ragged.py; the ops-level and
kernel-parity tests stay where they were.
"""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (GibbsState, SLDAConfig, SLDAModel, bucket_corpus,
                        build_schedule, counts_from_assignments, init_state,
                        partition, phi_hat, solve_eta, sweep, zbar)
from repro.core.parallel import (predict_chains_keyed, run_weighted_average,
                                 train_chains_keyed)
from repro.data import make_slda_corpus, train_test_split

CFG = SLDAConfig(n_topics=4, vocab_size=24, n_iters=5, rho=0.25,
                 n_pred_burnin=1, n_pred_samples=2, count_rebuild_every=2)
D_TOTAL, MAX_LEN = 32, 12

_corpus, _ = make_slda_corpus(jax.random.PRNGKey(0), D_TOTAL + 16, 24, 4,
                              MAX_LEN, rho=0.25, doc_len_dist="lognormal")
_train, _test = train_test_split(_corpus, D_TOTAL)
_KEY = jax.random.PRNGKey(1)


def _cfg(backend, spl, layout):
    # spl>1 cells run 9 iters (2 full fused launches + a 1-sweep
    # remainder — the remainder path is part of the contract); the η
    # solve happens per LAUNCH there, so 5 iters would leave the fused
    # family visibly under-converged vs the per-sweep-solve reference
    return dataclasses.replace(
        CFG, use_pallas=(backend == "pallas-interpret"),
        sweeps_per_launch=spl, n_iters=CFG.n_iters if spl == 1 else 9,
        length_buckets=3 if layout == "bucketed" else 0,
        bucket_overhead_docs=0.0)


def _schedule_for(layout, shards, cfg):
    if layout == "bucketed":
        return bucket_corpus(shards, 3, overhead_docs=0)
    return shards


# ------------------------------------------------- seed-semantics reference

def _ref_chain(key, corpus, cfg):
    """The seed path, from primitives — a verbatim reconstruction of the
    pre-plan single-chain EM loop (one threefry sweep per η solve,
    count_rebuild_every cadence, the same lax.scan structure): what
    every spl=1 cell must hit bit-for-bit."""
    k_init, k_sweeps = jax.random.split(key)
    state0 = init_state(k_init, corpus, cfg)
    every = cfg.count_rebuild_every

    def em_step(state, inp):
        k, it = inp
        rebuild = (it % every == 0) if every > 0 else False
        state = sweep(k, corpus, state, cfg, supervised=True,
                      exact_rebuild=rebuild)
        eta = solve_eta(zbar(state, corpus), corpus.y, cfg)
        return GibbsState(state.z, state.ndt, state.ntw, state.nt,
                          eta), None

    state, _ = jax.lax.scan(
        em_step, state0, (jax.random.split(k_sweeps, cfg.n_iters),
                          jnp.arange(cfg.n_iters)))
    yhat = zbar(state, corpus) @ state.eta
    mse = jnp.mean((yhat - corpus.y) ** 2)
    acc = jnp.mean(((yhat > 0.5) == (corpus.y > 0.5)).astype(jnp.float32))
    model = SLDAModel(phi=phi_hat(state, cfg), eta=state.eta,
                      train_mse=mse, train_acc=acc)
    return state, model


@functools.lru_cache(maxsize=None)
def _reference(m):
    """Seed reference for M = m chains on the padded shards: the
    VMAPPED per-chain loop — the `jax.vmap(train_chain)` equivalence
    class every chain-batched path has been pinned to since the
    chain-batching PR (layout/backend/spl-independent by the dispatch
    contract)."""
    cfg = _cfg("jnp", 1, "padded")
    shards = partition(_train, m)
    keys = jax.random.split(_KEY, m)
    state, model = jax.jit(jax.vmap(_ref_chain, in_axes=(0, 0, None)),
                           static_argnums=(2,))(keys, shards, cfg)
    return jax.tree.map(np.asarray, (state, model))


def _ref_predict_one(key, phi, eta, cfg):
    """The pre-plan single-model fused prediction pass, verbatim —
    same key tree as predict_chains_keyed."""
    from repro.kernels import ops
    D = _test.n_docs
    k_init, k_seeds = jax.random.split(key)
    z0 = jax.random.randint(k_init, _test.tokens.shape, 0,
                            cfg.n_topics, jnp.int32)
    d_idx = jnp.arange(D)[:, None]
    ndt0 = jnp.zeros((D, cfg.n_topics), jnp.float32) \
        .at[d_idx, z0].add(_test.mask)
    seeds = jax.random.randint(k_seeds, (D,), 0,
                               jnp.iinfo(jnp.int32).max, jnp.int32)
    ndt_avg, _ = ops.slda_predict_sweeps(
        _test.tokens, _test.mask, z0, ndt0, phi, seeds, alpha=cfg.alpha,
        n_burnin=cfg.n_pred_burnin, n_samples=cfg.n_pred_samples,
        doc_block=cfg.pred_doc_block, use_pallas=False)
    zb = ndt_avg / jnp.maximum(_test.lengths(), 1.0)[:, None]
    return zb @ eta


@functools.lru_cache(maxsize=None)
def _ref_predictions(m):
    """Reference prediction: the vmapped pre-plan fused pass — the
    `jax.vmap(predict)` equivalence class.  Evaluated EAGERLY so the
    deterministic ŷ epilogue (division + Eq. (5) matmul) compiles as
    the same standalone batched ops as the plan cells' — whole-program
    jit would let XLA fuse the epilogue differently per producer, which
    costs a final-ulp on some documents without touching the
    per-document sampler bits."""
    _, model = _reference(m)
    keys = jax.random.split(jax.random.PRNGKey(2), m)
    cfg = _cfg("jnp", 1, "padded")
    out = jax.vmap(_ref_predict_one, in_axes=(0, 0, 0, None))(
        keys, jnp.asarray(model.phi), jnp.asarray(model.eta), cfg)
    return np.asarray(out)


# ------------------------------------------------------------ the matrix

@pytest.mark.parametrize("spl", [1, 4])
@pytest.mark.parametrize("backend", ["jnp", "pallas-interpret"])
@pytest.mark.parametrize("m", [1, 4])
@pytest.mark.parametrize("layout", ["padded", "bucketed"])
def test_dispatch_matrix_train(layout, m, backend, spl):
    cfg = _cfg(backend, spl, layout)
    shards = partition(_train, m)
    sched = _schedule_for(layout, shards, cfg)
    keys = jax.random.split(_KEY, m)
    state, model = jax.jit(train_chains_keyed, static_argnums=(2,))(
        keys, sched, cfg)
    ref_state, ref_model = _reference(m)

    if spl == 1:   # bit-identity cell
        for f in ("z", "ndt", "ntw", "nt", "eta"):
            np.testing.assert_allclose(
                np.asarray(getattr(state, f)), getattr(ref_state, f),
                atol=0, err_msg=f"{layout}/{m}/{backend}/spl1 state.{f}")
        for f in ("phi", "eta", "train_mse", "train_acc"):
            np.testing.assert_allclose(
                np.asarray(getattr(model, f)), getattr(ref_model, f),
                atol=0, err_msg=f"{layout}/{m}/{backend}/spl1 model.{f}")
        return

    # spl>1: own sampler family — exact count consistency with z (the
    # remainder launch is exercised: n_iters=9, spl=4), model learnable
    nd, nw, nt = jax.vmap(
        lambda t, mm, z: counts_from_assignments(
            t, mm, z, cfg.n_topics, cfg.vocab_size))(
        shards.tokens, shards.mask, state.z)
    np.testing.assert_allclose(np.asarray(nd), np.asarray(state.ndt),
                               atol=0)
    np.testing.assert_allclose(np.asarray(nw), np.asarray(state.ntw),
                               atol=0)
    np.testing.assert_allclose(np.asarray(nt), np.asarray(state.nt),
                               atol=0)
    # each spl>1 cell is a different (exact) member of the fused
    # family — pin quality to the label variance (the statistical
    # tier's Geweke test covers distribution-level correctness)
    assert float(jnp.mean(model.train_mse)) < \
        0.6 * float(jnp.var(shards.y))


@pytest.mark.parametrize("backend", ["jnp", "pallas-interpret"])
@pytest.mark.parametrize("m", [1, 4])
@pytest.mark.parametrize("layout", ["padded", "bucketed"])
def test_dispatch_matrix_predict(layout, m, backend):
    """Prediction cells: bit-identical to the reference fused pass for
    every layout × M × backend (no spl axis — prediction is
    document-independent under frozen φ̂)."""
    cfg = _cfg(backend, 1, layout)
    _, ref_model = _reference(m)
    models = jax.tree.map(jnp.asarray, ref_model)
    sched = (_test if layout == "padded"
             else bucket_corpus(_test, 3, overhead_docs=0))
    keys = jax.random.split(jax.random.PRNGKey(2), m)
    # eager like the reference — see _ref_predictions on why
    yhat = predict_chains_keyed(keys, models, sched, cfg)
    np.testing.assert_allclose(np.asarray(yhat), _ref_predictions(m),
                               atol=0,
                               err_msg=f"{layout}/{m}/{backend}")


def test_weighted_average_end_to_end_bitwise_padded_vs_bucketed():
    """The whole Weighted Average algorithm through the unified entry
    point: a length_buckets>0 config (host-side schedules) must equal
    the padded jit'd run bit-for-bit at spl=1 — the end-to-end
    inverse-permutation contract."""
    cfg_pad = _cfg("jnp", 1, "padded")
    cfg_bkt = _cfg("jnp", 1, "bucketed")
    key = jax.random.PRNGKey(3)
    # same phase-jit structure on both sides (the combine epilogue runs
    # eagerly either way) — only the schedule layout differs
    y_pad = run_weighted_average(key, _train, _test, cfg_pad, 4)
    y_bkt = run_weighted_average(key, _train, _test, cfg_bkt, 4)
    np.testing.assert_allclose(np.asarray(y_pad), np.asarray(y_bkt),
                               atol=0)
