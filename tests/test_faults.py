"""Chaos suite: deterministic fault injection against the supervisor
(DESIGN.md §Fault-model).

Every scenario drives the REAL end-to-end path — `supervised_run_average`
or `ChainSupervisor.train` over the chain-batched EM loop — with faults
injected inside the compiled scan by `repro.testing.faults`.  The
central assertion is the paper's fault-isolation dividend: because
chains never communicate, a poisoned chain's quarantine is EXACT — the
surviving lanes' models and predictions are bit-identical to a run where
the fault never happened, and the combined prediction equals the clean
per-chain predictions combined under the faulty run's alive mask.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (EnsembleHealthError, HealthConfig, RecoveryPolicy,
                        SLDAConfig, combine, supervised_run_average)
from repro.core.supervisor import (F_KILLED, F_MSE_OUTLIER, F_NAN_ETA,
                                   F_NDT_SUM, F_NTW_NEG, F_STRAGGLER,
                                   ChainSupervisor, describe_status)
from repro.core.plan import build_plan, build_schedule
from repro.core.types import partition
from repro.data import make_slda_corpus, train_test_split
from repro.testing import (FaultPlan, inject, no_faults, poison,
                           random_fault_plan, truncate_chain_file)

M = 4
NO_RESTART = RecoveryPolicy(max_restarts=0, min_alive_frac=0.0)


@pytest.fixture(scope="module")
def corpus():
    c, _ = make_slda_corpus(jax.random.PRNGKey(0), 48, 32, 4, 8)
    return train_test_split(c, 32)


@pytest.fixture(scope="module")
def cfg():
    return SLDAConfig(n_topics=4, vocab_size=32, n_iters=5,
                      n_pred_burnin=2, n_pred_samples=2)


def _run(corpus, cfg, **kw):
    train, test = corpus
    kw.setdefault("rule", "simple")
    return supervised_run_average(jax.random.PRNGKey(3), train, test, cfg,
                                  M, **kw)


def test_clean_run_all_alive_status_zero(corpus, cfg):
    yhat, rep = _run(corpus, cfg)
    assert rep.alive.all()
    assert (rep.status == 0).all()
    assert rep.restarts.sum() == 0
    assert np.isfinite(np.asarray(yhat)).all()


def test_nan_poison_detected_quarantined_and_drop_is_exact(corpus, cfg):
    """A NaN-poisoned chain is flagged within the round it fires,
    quarantined, and the combined prediction is BIT-IDENTICAL to the
    clean run's per-chain predictions combined under the faulty alive
    mask — the exactness-of-drop contract."""
    y_clean, rep_clean = _run(corpus, cfg)
    y_bad, rep_bad = _run(corpus, cfg, recovery=NO_RESTART,
                          fault_hook=poison(M, 1, 2, "nan").hook())
    assert list(rep_bad.alive) == [True, False, True, True]
    assert rep_bad.status[1] & F_NAN_ETA
    # surviving lanes never saw the fault: bit-identical predictions
    for c in (0, 2, 3):
        np.testing.assert_array_equal(rep_bad.yhat_chains[c],
                                      rep_clean.yhat_chains[c])
    # combined == clean per-chain predictions under the faulty mask
    want = combine.simple_average(jnp.asarray(rep_clean.yhat_chains),
                                  alive=rep_bad.alive_mask())
    np.testing.assert_array_equal(np.asarray(y_bad), np.asarray(want))
    assert np.isfinite(np.asarray(y_bad)).all()


def test_kill_restarts_from_checkpoint_and_completes(corpus, cfg, tmp_path):
    """One-shot state loss → restart from the round's checkpoint on a
    fresh PRNG lane; the run completes with every chain alive."""
    yhat, rep = _run(corpus, cfg, ckpt_dir=str(tmp_path), round_iters=2,
                     fault_hook=poison(M, 2, 1, "kill").hook())
    assert rep.alive.all()
    assert list(rep.restarts) == [0, 0, 1, 0]
    assert rep.status[2] & F_KILLED
    acts = [e["action"] for h in rep.history for e in h["events"]]
    assert any(a.startswith("restart_from_step_") for a in acts)
    assert np.isfinite(np.asarray(yhat)).all()


def test_persistent_poison_exhausts_budget_then_quarantines(corpus, cfg,
                                                            tmp_path):
    """A fault that reproduces after restart (persistent NaN) burns the
    restart budget and falls back to quarantine — bounded recovery."""
    yhat, rep = _run(corpus, cfg, ckpt_dir=str(tmp_path), round_iters=2,
                     recovery=RecoveryPolicy(max_restarts=1,
                                             min_alive_frac=0.0),
                     fault_hook=poison(M, 0, 0, "nan").hook())
    assert list(rep.alive) == [False, True, True, True]
    assert rep.restarts[0] == 1
    acts = [e["action"] for h in rep.history for e in h["events"]]
    assert any(a.startswith("restart_") for a in acts)
    assert "quarantine" in acts
    assert np.isfinite(np.asarray(yhat)).all()


def test_corrupt_counts_detected_by_invariant_probes(corpus, cfg):
    """Finite-but-wrong counts can only be caught by the count
    invariants (η stays finite): Σ ndt drift and negative ntw."""
    _, rep = _run(corpus, cfg, recovery=NO_RESTART,
                  fault_hook=poison(M, 3, 1, "corrupt").hook())
    assert not rep.alive[3] and rep.alive[[0, 1, 2]].all()
    assert rep.status[3] & F_NDT_SUM
    assert rep.status[3] & F_NTW_NEG
    assert set(describe_status(int(rep.status[3]))) >= {"ndt_sum",
                                                        "ntw_neg"}


def test_straggler_is_flag_only(corpus, cfg):
    """A late chain is correct — flagged for observability, never
    quarantined, and the output is bit-identical to the clean run."""
    y_clean, _ = _run(corpus, cfg)
    y_strag, rep = _run(corpus, cfg,
                        fault_hook=poison(M, 1, 1, "straggle").hook())
    assert rep.alive.all()
    assert rep.status[1] & F_STRAGGLER
    np.testing.assert_array_equal(np.asarray(y_strag), np.asarray(y_clean))


def test_truncated_checkpoint_isolated_to_fresh_init(corpus, cfg, tmp_path):
    """A torn chain file in the checkpoint must not sink the restart:
    the damaged chain alone falls back to fresh init and the run still
    completes with every chain alive."""
    train, test = corpus
    shards = build_schedule(partition(train, M), cfg)
    sup = ChainSupervisor(shards, cfg, ckpt_dir=str(tmp_path),
                          round_iters=2,
                          fault_hook=poison(M, 2, 1, "kill").hook())
    orig = sup._manager.maybe_save

    def sabotage(step, state, extra=None):
        path = orig(step, state, extra)
        if path is not None:       # tear chain 2's file in every save
            truncate_chain_file(str(tmp_path), step, 2)
        return path

    sup._manager.maybe_save = sabotage
    _, models, rep = sup.train(jax.random.split(jax.random.PRNGKey(3), M))
    assert rep.alive.all()
    acts = [e["action"] for h in rep.history for e in h["events"]]
    assert "checkpoint_corrupt" in acts
    assert "restart_fresh_init" in acts
    assert np.isfinite(np.asarray(models.eta)).all()


def test_min_alive_frac_aborts_the_run(corpus, cfg):
    with pytest.raises(EnsembleHealthError, match="alive"):
        _run(corpus, cfg,
             recovery=RecoveryPolicy(max_restarts=0, min_alive_frac=0.9),
             fault_hook=poison(M, 0, 1, "nan").hook())


def test_mse_outlier_soft_quarantine(corpus, cfg):
    """A finite-but-diverged chain (here: poisoned to a constant huge η
    via a custom hook) trips ONLY the statistical probe and is
    quarantined without a restart attempt."""
    train, test = corpus

    def diverge(state, it):
        eta = state.eta.at[1].set(jnp.where(it >= 1, 1e4,
                                            state.eta[1][0]))
        from repro.core.types import GibbsState
        bits = jnp.zeros((M,), jnp.uint32)
        return GibbsState(z=state.z, ndt=state.ndt, ntw=state.ntw,
                          nt=state.nt, eta=eta), bits

    _, rep = supervised_run_average(
        jax.random.PRNGKey(3), train, test, cfg, M,
        health=HealthConfig(mse_warmup=0),
        recovery=RecoveryPolicy(max_restarts=2, min_alive_frac=0.0),
        fault_hook=diverge)
    assert not rep.alive[1]
    assert rep.status[1] & F_MSE_OUTLIER
    assert rep.restarts[1] == 0     # soft fault: quarantine, not restart


def test_fault_plan_is_seed_deterministic():
    k = jax.random.PRNGKey(11)
    a = random_fault_plan(k, 8, 10)
    b = random_fault_plan(k, 8, 10)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    c = random_fault_plan(jax.random.PRNGKey(12), 8, 10)
    assert any(not np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(a, c))


def test_inject_is_jit_compatible_and_no_op_when_unarmed(corpus, cfg):
    train, _ = corpus
    plan = build_plan(build_schedule(partition(train, M), cfg), cfg)
    state, _ = plan.init_states(jax.random.split(jax.random.PRNGKey(0), M))
    out, bits = jax.jit(inject)(state, jnp.int32(3), no_faults(M))
    assert (np.asarray(bits) == 0).all()
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_train_em_hook_is_transparent(corpus, cfg):
    """`train_em(em_hook=None)` and an identity hook produce the same
    bits — the hook threading cannot perturb the sampler."""
    train, _ = corpus
    plan = build_plan(build_schedule(partition(train, M), cfg), cfg)
    ks = jax.vmap(jax.random.split)(
        jax.random.split(jax.random.PRNGKey(5), M))
    state0, _ = plan.init_states(ks[:, 0])
    plain = plan.train_em(ks[:, 1], state0)
    ident = lambda st, it, status: (st, status)
    hooked, status = plan.train_em(ks[:, 1], state0, em_hook=ident,
                                   status0=jnp.zeros((M,), jnp.uint32))
    assert (np.asarray(status) == 0).all()
    for a, b in zip(jax.tree.leaves(plain), jax.tree.leaves(hooked)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
