"""Length-bucketed (ragged) execution vs the padded path (DESIGN.md
§Ragged-execution).

The contract: because the counter-hash PRNG is keyed per (doc, sweep,
token) — with the counter stride pinned to the SOURCE corpus max_len —
and because prediction is document-independent under frozen φ̂ while
training at sweeps_per_launch=1 is document-independent within a sweep,
bucketed execution must be **per-document bit-identical** to the padded
path at spl=1 under ANY permutation/bucketing of the corpus:

  * ops level: per-bucket fused launches (jnp twin + interpret kernel,
    single-chain + chain-batched) == the padded op, bitwise;
  * core level: train_chain / predict / train_chains / predict_chains
    on a BucketedCorpus == their padded counterparts, bitwise (state,
    model, AND predictions — ndt/η live in original doc order at every
    EM boundary, so even the cross-document reductions agree);
  * a hypothesis property over random length distributions, bucket
    counts, and M ∈ {1, 2, 5} (degenerate all-same-length corpora and
    single-doc buckets included).

sweeps_per_launch>1 bucketed is its own member of the fused sampler
family (bucket-local block partition) — asserted self-consistent, not
bit-equal.
"""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (BucketedCorpus, Corpus, SLDAConfig, bucket_corpus,
                        partition, predict, train_chain)
from repro.data import make_slda_corpus, train_test_split
from repro.kernels import ops

_HY = dict(alpha=0.1, beta=0.01, rho=0.5)


def _setup(n_docs, n_topics, vocab, doc_len, seed=0, lens=None, m=None):
    shape = (n_docs, doc_len) if m is None else (m, n_docs, doc_len)
    dshape = shape[:-1]
    ks = jax.random.split(jax.random.PRNGKey(seed), 7)
    tokens = jax.random.randint(ks[0], shape, 0, vocab, jnp.int32)
    if lens is None:
        lens = jax.random.randint(ks[1], dshape, 0, doc_len + 1)
    mask = (jnp.arange(doc_len)[(None,) * len(dshape)]
            < jnp.asarray(lens)[..., None]).astype(jnp.float32)
    z0 = jax.random.randint(ks[2], shape, 0, n_topics, jnp.int32)
    d_idx = jnp.arange(n_docs)[:, None]
    scatter = lambda z, mm: jnp.zeros((n_docs, n_topics)) \
        .at[d_idx, z].add(mm)
    count = lambda z, t, mm: jnp.zeros((n_topics, vocab)).at[z, t].add(mm)
    if m is None:
        ndt0, ntw = scatter(z0, mask), count(z0, tokens, mask)
    else:
        ndt0 = jax.vmap(scatter)(z0, mask)
        ntw = jax.vmap(count)(z0, tokens, mask)
    y = jax.random.normal(ks[3], dshape)
    eta = jax.random.normal(ks[4], dshape[:-1] + (n_topics,))
    seeds = jax.random.randint(ks[5], dshape, 0, 2 ** 31 - 1, jnp.int32)
    phi = jax.random.dirichlet(ks[6], jnp.full((vocab,), 0.1),
                               dshape[:-1] + (n_topics,))
    inv_len = 1.0 / jnp.maximum(mask.sum(-1), 1.0)
    corpus = Corpus(tokens=tokens, mask=mask, y=y)
    return corpus, z0, ndt0, ntw, ntw.sum(-1), eta, seeds, phi, inv_len


# --------------------------------------------------------- schedule type

def test_bucket_corpus_structure_and_roundtrips():
    corpus, z0, *_ = _setup(23, 4, 40, 30, seed=1)
    bc = bucket_corpus(corpus, 4, token_block=8, overhead_docs=0)
    assert bc.n_docs == 23 and bc.ctr_stride == 30
    assert all(w % 8 == 0 or w == 30 for w in bc.widths)
    assert bc.padded_tokens() <= 23 * 30
    # every bucket holds all its docs' real tokens
    for b, w in zip(bc.buckets, bc.widths):
        assert float(b.mask.sum(-1).max()) <= w
    # doc-row and padded round-trips restore original order/values
    arr = jnp.arange(23 * 5, dtype=jnp.float32).reshape(23, 5)
    assert np.array_equal(np.asarray(bc.merge_docs(bc.split_docs(arr))),
                          np.asarray(arr))
    assert np.array_equal(
        np.asarray(bc.merge_padded(bc.split_padded(z0), z0)),
        np.asarray(z0))
    assert np.array_equal(np.asarray(bc.y), np.asarray(corpus.y))
    assert np.array_equal(np.asarray(bc.lengths()),
                          np.asarray(corpus.lengths()))


def test_bucket_corpus_degenerate_shapes():
    # all-same-length collapses to ONE bucket (padded path + permutation)
    corpus, *_ = _setup(12, 4, 40, 16, seed=2,
                        lens=jnp.full((12,), 16, jnp.int32))
    bc = bucket_corpus(corpus, 5)
    assert len(bc.buckets) == 1 and bc.widths == (16,)
    # single-doc corpus / more buckets than docs
    c1 = Corpus(tokens=corpus.tokens[:1], mask=corpus.mask[:1],
                y=corpus.y[:1])
    b1 = bucket_corpus(c1, 8)
    assert b1.n_docs == 1 and len(b1.buckets) == 1
    # all-empty docs still produce a sane (min-width) schedule
    c0 = Corpus(tokens=corpus.tokens, mask=jnp.zeros_like(corpus.mask),
                y=corpus.y)
    b0 = bucket_corpus(c0, 3, token_block=8)
    assert b0.widths == (8,)


def test_bucket_corpus_rejects_traced_corpora():
    corpus, *_ = _setup(8, 4, 40, 12, seed=3)
    with pytest.raises(Exception):
        jax.jit(lambda c: bucket_corpus(c, 2))(corpus)


# ------------------------------------------------------------- ops level

@pytest.mark.parametrize("use_pallas", [False, True])
def test_bucketed_predict_op_bitwise(use_pallas):
    corpus, z0, ndt0, _, _, _, seeds, phi, _ = _setup(17, 6, 50, 24, seed=4)
    kw = dict(alpha=0.1, n_burnin=2, n_samples=3, use_pallas=use_pallas,
              doc_block=4)
    a_pad, z_pad = ops.slda_predict_sweeps(
        corpus.tokens, corpus.mask, z0, ndt0, phi, seeds, **kw)
    bc = bucket_corpus(corpus, 3, overhead_docs=0)
    pieces_a, pieces_z = [], []
    for b, zb, ndb, sb in zip(bc.buckets, bc.split_padded(z0),
                              bc.split_docs(ndt0), bc.split_docs(seeds)):
        a_b, z_b = ops.slda_predict_sweeps(
            b.tokens, b.mask, zb, ndb, phi, sb, ctr_stride=bc.ctr_stride,
            **kw)
        pieces_a.append(a_b)
        pieces_z.append(z_b)
    np.testing.assert_allclose(np.asarray(bc.merge_docs(pieces_a)),
                               np.asarray(a_pad), atol=0)
    assert np.array_equal(np.asarray(bc.merge_padded(pieces_z, z0)),
                          np.asarray(z_pad))


@pytest.mark.parametrize("use_pallas", [False, True])
def test_bucketed_train_op_bitwise_spl1(use_pallas):
    (corpus, z0, ndt0, ntw, nt, eta, seeds, _,
     inv_len) = _setup(15, 6, 50, 20, seed=5)
    kw = dict(n_sweeps=1, doc_block=4, use_pallas=use_pallas, **_HY)
    z_pad, nd_pad = ops.slda_train_sweeps(
        corpus.tokens, corpus.mask, z0, ndt0, corpus.y, inv_len, ntw, nt,
        eta, seeds, **kw)
    bc = bucket_corpus(corpus, 3, overhead_docs=0)
    pieces_z, pieces_nd = [], []
    for b, zb, ndb, sb, ilb in zip(bc.buckets, bc.split_padded(z0),
                                   bc.split_docs(ndt0),
                                   bc.split_docs(seeds),
                                   bc.split_docs(inv_len)):
        z_b, nd_b = ops.slda_train_sweeps(
            b.tokens, b.mask, zb, ndb, b.y, ilb, ntw, nt, eta, sb,
            ctr_stride=bc.ctr_stride, **kw)
        pieces_z.append(z_b)
        pieces_nd.append(nd_b)
    np.testing.assert_allclose(np.asarray(bc.merge_docs(pieces_nd)),
                               np.asarray(nd_pad), atol=0)
    assert np.array_equal(np.asarray(bc.merge_padded(pieces_z, z0)),
                          np.asarray(z_pad))


def test_bucketed_chain_axis_ops_bitwise():
    """Chain-batched per-bucket launches (shared corpus for prediction,
    per-chain shards for training) == the padded chain_axis ops."""
    m = 3
    (corpus, z0, ndt0, ntw, nt, eta, seeds, phi,
     inv_len) = _setup(11, 6, 50, 18, seed=6, m=m)
    bc = bucket_corpus(corpus, 3, overhead_docs=0)
    kw = dict(n_sweeps=1, doc_block=4, use_pallas=False, chain_axis=True,
              **_HY)
    z_pad, nd_pad = ops.slda_train_sweeps(
        corpus.tokens, corpus.mask, z0, ndt0, corpus.y, inv_len, ntw, nt,
        eta, seeds, **kw)
    pieces_z, pieces_nd = [], []
    for b, zb, ndb, sb, ilb in zip(bc.buckets, bc.split_padded(z0),
                                   bc.split_docs(ndt0),
                                   bc.split_docs(seeds),
                                   bc.split_docs(inv_len)):
        z_b, nd_b = ops.slda_train_sweeps(
            b.tokens, b.mask, zb, ndb, b.y, ilb, ntw, nt, eta, sb,
            ctr_stride=bc.ctr_stride, **kw)
        pieces_z.append(z_b)
        pieces_nd.append(nd_b)
    np.testing.assert_allclose(np.asarray(bc.merge_docs(pieces_nd)),
                               np.asarray(nd_pad), atol=0)
    assert np.array_equal(np.asarray(bc.merge_padded(pieces_z, z0)),
                          np.asarray(z_pad))
    # prediction: ONE shared corpus, per-chain phi — bucket with 1D perm
    tok_s, mask_s = corpus.tokens[0], corpus.mask[0]
    shared = Corpus(tokens=tok_s, mask=mask_s, y=corpus.y[0])
    bs = bucket_corpus(shared, 3, overhead_docs=0)
    pkw = dict(alpha=0.1, n_burnin=2, n_samples=2, use_pallas=False,
               chain_axis=True)
    a_pad, _ = ops.slda_predict_sweeps(tok_s, mask_s, z0, ndt0, phi,
                                       seeds, **pkw)
    pieces = []
    for b, zb, ndb, sb in zip(bs.buckets,
                              bs.split_padded(z0, d_axis=1),
                              bs.split_docs(ndt0, d_axis=1),
                              bs.split_docs(seeds, d_axis=1)):
        a_b, _ = ops.slda_predict_sweeps(
            b.tokens, b.mask, zb, ndb, phi, sb, ctr_stride=bs.ctr_stride,
            **pkw)
        pieces.append(a_b)
    np.testing.assert_allclose(
        np.asarray(bs.merge_docs(pieces, d_axis=1)), np.asarray(a_pad),
        atol=0)


# ------------------------------------------------------------ core level
# (The spl=1 bit-identity of train_chain / predict / train_chains /
# predict_chains and the end-to-end Weighted Average on a BucketedCorpus
# vs the padded path is asserted cell-by-cell by the dispatch-matrix
# test — tests/test_dispatch_matrix.py.  This module keeps the
# schedule-type, ops-level, stair-executor, and hypothesis coverage.)

def test_bucketed_fused_spl_gt1_self_consistent():
    """spl>1 bucketed is its own sampler family — not bit-equal to the
    padded fused path, but counts must stay exactly consistent with z
    and the model must still learn."""
    cfg = SLDAConfig(n_topics=8, vocab_size=100, n_iters=9, rho=0.25,
                     sweeps_per_launch=4)
    corpus, _ = make_slda_corpus(jax.random.PRNGKey(20), 64, 100, 8, 32,
                                 rho=0.25, doc_len_dist="lognormal")
    bc = bucket_corpus(corpus, 3, overhead_docs=0)
    state, model = jax.jit(train_chain, static_argnums=2)(
        jax.random.PRNGKey(21), bc, cfg)
    # ndt/ntw/nt exactly consistent with the final z
    from repro.core import counts_from_assignments
    ndt_r, ntw_r, nt_r = counts_from_assignments(
        corpus.tokens, corpus.mask, state.z, cfg.n_topics, cfg.vocab_size)
    np.testing.assert_allclose(np.asarray(state.ndt), np.asarray(ndt_r),
                               atol=0)
    np.testing.assert_allclose(np.asarray(state.ntw), np.asarray(ntw_r),
                               atol=0)
    np.testing.assert_allclose(np.asarray(state.nt), np.asarray(nt_r),
                               atol=0)
    assert float(model.train_mse) < 0.6 * float(jnp.var(corpus.y))


def test_shard_map_runner_bucketed_routing():
    """cfg.length_buckets>0 routes the multi-device runner through the
    bucketed pytrees — bit-identical to the padded runner at spl=1."""
    from jax.sharding import Mesh
    from repro.launch.slda_parallel import parallel_slda_shard_map
    cfg = SLDAConfig(n_topics=8, vocab_size=80, n_iters=2, rho=0.25,
                     n_pred_burnin=1, n_pred_samples=1, length_buckets=3,
                     bucket_overhead_docs=0.0)
    corpus, _ = make_slda_corpus(jax.random.PRNGKey(22), 40, 80, 8, 20,
                                 rho=0.25, doc_len_dist="lognormal")
    train, test = train_test_split(corpus, 32)
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    y_bkt = parallel_slda_shard_map(jax.random.PRNGKey(23), train, test,
                                    cfg, mesh, chains_per_device=2)
    y_pad = parallel_slda_shard_map(
        jax.random.PRNGKey(23), train, test,
        dataclasses.replace(cfg, length_buckets=0), mesh,
        chains_per_device=2)
    np.testing.assert_allclose(np.asarray(y_pad), np.asarray(y_bkt),
                               atol=0)


# -------------------------------------------------- stair executors

def test_stair_train_bitwise_at_one_sweep():
    """The STAIRCASE fused-training twin at n_sweeps=1 (no in-launch
    refresh → document-independent) == the padded chain_axis op,
    bitwise per document — both sampling forms."""
    from repro.core.types import (_stair_segments, _take_docs,
                                  _unstair_segments)
    from repro.kernels.slda_train import slda_train_stair_jnp
    m, n_docs, vocab, n_topics, doc_len = 3, 11, 40, 6, 18
    (corpus, z0, ndt0, ntw, nt, eta, seeds, _,
     inv_len) = _setup(n_docs, n_topics, vocab, doc_len, seed=7, m=m)
    bc = bucket_corpus(corpus, 4, overhead_docs=0)
    d_m = bc.perm.shape[-1]
    fold = lambda a: jnp.swapaxes(a, 0, 1).reshape((-1,) + a.shape[2:])
    unfold = lambda a: jnp.swapaxes(
        a.reshape((-1, m) + a.shape[1:]), 0, 1)
    sort = lambda a: _take_docs(a, bc.perm, 1)
    off = (jnp.arange(m, dtype=jnp.int32) * vocab)[:, None, None]
    tok_segs = [fold(s + off) for s in _stair_segments(
        bc, [b.tokens for b in bc.buckets])]
    mask_segs = [fold(s) for s in _stair_segments(
        bc, [b.mask for b in bc.buckets])]
    starts = np.cumsum([0] + list(bc.counts))
    seg_r0 = [int(x) * m for x in starts[:-1]]
    seg_n0 = [0] + list(bc.widths[:-1])
    chain_of_row = jnp.tile(jnp.arange(m, dtype=jnp.int32), d_m)
    y_f = fold(jnp.concatenate([b.y for b in bc.buckets], axis=1))
    il_f = fold(sort(inv_len))
    for product_form in (False, True):
        z_pad, nd_pad = ops.slda_train_sweeps(
            corpus.tokens, corpus.mask, z0, ndt0, corpus.y, inv_len, ntw,
            nt, eta, seeds, n_sweeps=1, doc_block=4, use_pallas=False,
            chain_axis=True, product_form=product_form, **_HY)
        z_segs = [fold(s) for s in _stair_segments(
            bc, bc.split_padded(z0, d_axis=1))]
        z_f, nd_f = slda_train_stair_jnp(
            tok_segs, mask_segs, z_segs, seg_r0, seg_n0, fold(sort(seeds)),
            fold(sort(ndt0)), y_f, il_f,
            jnp.swapaxes(ntw, 1, 2).reshape(m * vocab, n_topics), nt, eta,
            chain_of_row, vocab_size=vocab, ctr_stride=bc.ctr_stride,
            n_sweeps=1, product_form=product_form, **_HY)
        z_b = _unstair_segments(bc, [unfold(z) for z in z_f])
        nd = _take_docs(unfold(nd_f), bc.inv_perm, 1)
        np.testing.assert_allclose(np.asarray(nd), np.asarray(nd_pad),
                                   atol=0, err_msg=str(product_form))
        assert np.array_equal(
            np.asarray(bc.merge_padded(z_b, z0, d_axis=1)),
            np.asarray(z_pad)), product_form


def test_stair_trainer_chain_level_consistency():
    """The stair fused trainer (jnp route of the bucketed chains path)
    keeps counts exactly consistent with z, and its model matches the
    padded fused path statistically (same estimator family)."""
    from repro.core import counts_from_assignments
    from repro.core.parallel import train_chains_keyed
    cfg = SLDAConfig(n_topics=8, vocab_size=100, n_iters=9, rho=0.25,
                     sweeps_per_launch=4)
    corpus, _ = make_slda_corpus(jax.random.PRNGKey(30), 96, 100, 8, 32,
                                 rho=0.25, doc_len_dist="lognormal")
    shards = partition(corpus, 4)
    ks = jax.random.split(jax.random.PRNGKey(31), 4)
    state, model = jax.jit(train_chains_keyed, static_argnums=2)(
        ks, bucket_corpus(shards, 4, overhead_docs=0), cfg)
    nd, nw, nt = jax.vmap(
        lambda t, mm, z: counts_from_assignments(t, mm, z, 8, 100))(
        shards.tokens, shards.mask, state.z)
    np.testing.assert_allclose(np.asarray(nd), np.asarray(state.ndt),
                               atol=0)
    np.testing.assert_allclose(np.asarray(nw), np.asarray(state.ntw),
                               atol=0)
    _, model_pad = jax.jit(train_chains_keyed, static_argnums=2)(
        ks, shards, cfg)
    # same family, same data → models land in the same quality ballpark
    assert float(jnp.mean(model.train_mse)) < \
        2.0 * float(jnp.mean(model_pad.train_mse)) + 0.1


# -------------------------------------------------- hypothesis property

try:  # the rest of this module must still run without hypothesis
    from hypothesis import given, settings, strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    _HAVE_HYPOTHESIS = False
    given = settings = lambda *a, **k: (lambda f: f)

    class st:  # noqa: N801 — placeholder so the decorators below parse
        sampled_from = integers = lists = data = staticmethod(
            lambda *a, **k: None)


@pytest.mark.skipif(not _HAVE_HYPOTHESIS, reason=(
    "property tests need hypothesis (pip install -r requirements-dev.txt)"))
@settings(max_examples=15, deadline=None)
@given(
    m=st.sampled_from([1, 2, 5]),
    n_docs=st.integers(1, 9),
    doc_len=st.integers(2, 14),
    n_buckets=st.integers(1, 6),
    data=st.data(),
)
def test_bucketed_property_bitwise_spl1(m, n_docs, doc_len, n_buckets,
                                        data):
    """For every M ∈ {1, 2, 5}, every length distribution (all-equal,
    all-empty, and single-doc buckets included) and every bucket count,
    the bucketed chain-batched train op at spl=1 equals the padded op
    bitwise per document after the inverse permutation."""
    seed = data.draw(st.integers(0, 2 ** 16))
    n_topics, vocab = 4, 24
    lens = data.draw(st.lists(st.integers(0, doc_len),
                              min_size=m * n_docs, max_size=m * n_docs))
    lens = jnp.asarray(lens, jnp.int32).reshape(m, n_docs)
    (corpus, z0, ndt0, ntw, nt, eta, seeds, _,
     inv_len) = _setup(n_docs, n_topics, vocab, doc_len, seed=seed,
                       lens=lens, m=m)
    kw = dict(n_sweeps=1, doc_block=4, use_pallas=False, chain_axis=True,
              **_HY)
    z_pad, nd_pad = ops.slda_train_sweeps(
        corpus.tokens, corpus.mask, z0, ndt0, corpus.y, inv_len, ntw, nt,
        eta, seeds, **kw)
    bc = bucket_corpus(corpus, n_buckets, token_block=4, overhead_docs=0)
    pieces_z, pieces_nd = [], []
    for b, zb, ndb, sb, ilb in zip(bc.buckets, bc.split_padded(z0),
                                   bc.split_docs(ndt0),
                                   bc.split_docs(seeds),
                                   bc.split_docs(inv_len)):
        z_b, nd_b = ops.slda_train_sweeps(
            b.tokens, b.mask, zb, ndb, b.y, ilb, ntw, nt, eta, sb,
            ctr_stride=bc.ctr_stride, **kw)
        pieces_z.append(z_b)
        pieces_nd.append(nd_b)
    np.testing.assert_allclose(np.asarray(bc.merge_docs(pieces_nd)),
                               np.asarray(nd_pad), atol=0)
    assert np.array_equal(np.asarray(bc.merge_padded(pieces_z, z0)),
                          np.asarray(z_pad))
