"""Property-based invariants of the collapsed-Gibbs sampler."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -r requirements-dev.txt)")
from hypothesis import HealthCheck, given, settings, strategies as st

from repro.core import (Corpus, SLDAConfig, counts_from_assignments,
                        init_state, sweep, zbar, phi_hat)
from repro.data import make_slda_corpus

# fixed shape menu so jit caches hit across hypothesis examples
_SHAPES = [(2, 32, 8, 10), (4, 64, 8, 16), (8, 32, 12, 20)]


@st.composite
def corpus_and_cfg(draw):
    n_topics, vocab, n_docs, doc_len = draw(st.sampled_from(_SHAPES))
    seed = draw(st.integers(0, 2 ** 16))
    cfg = SLDAConfig(n_topics=n_topics, vocab_size=vocab, n_iters=2)
    corpus, _ = make_slda_corpus(jax.random.PRNGKey(seed), n_docs, vocab,
                                 n_topics, doc_len)
    return cfg, corpus, seed


@given(corpus_and_cfg())
@settings(max_examples=8, deadline=None,
          suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large])
def test_sweep_preserves_count_invariants(args):
    """After any sweep: counts are consistent with z, totals are conserved,
    z stays in range, padding tokens never move."""
    cfg, corpus, seed = args
    state = init_state(jax.random.PRNGKey(seed + 1), corpus, cfg)
    z_before = state.z
    state2 = sweep(jax.random.PRNGKey(seed + 2), corpus, state, cfg)

    # z in range
    assert int(state2.z.min()) >= 0 and int(state2.z.max()) < cfg.n_topics
    # padded tokens unchanged
    pad = corpus.mask == 0
    assert np.array_equal(np.asarray(state2.z)[np.asarray(pad)],
                          np.asarray(z_before)[np.asarray(pad)])
    # counts exactly match assignments
    ndt, ntw, nt = counts_from_assignments(corpus.tokens, corpus.mask,
                                           state2.z, cfg.n_topics,
                                           cfg.vocab_size)
    np.testing.assert_allclose(np.asarray(state2.ndt), np.asarray(ndt))
    np.testing.assert_allclose(np.asarray(state2.ntw), np.asarray(ntw))
    np.testing.assert_allclose(np.asarray(state2.nt), np.asarray(nt))
    # token mass conserved
    total = float(corpus.mask.sum())
    assert abs(float(state2.ndt.sum()) - total) < 1e-3
    assert abs(float(state2.ntw.sum()) - total) < 1e-3


@given(corpus_and_cfg())
@settings(max_examples=6, deadline=None,
          suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large])
def test_zbar_and_phi_are_distributions(args):
    cfg, corpus, seed = args
    state = init_state(jax.random.PRNGKey(seed + 3), corpus, cfg)
    state = sweep(jax.random.PRNGKey(seed + 4), corpus, state, cfg)
    zb = np.asarray(zbar(state, corpus))
    assert (zb >= 0).all()
    np.testing.assert_allclose(zb.sum(-1), 1.0, atol=1e-4)
    ph = np.asarray(phi_hat(state, cfg))
    assert (ph > 0).all()
    np.testing.assert_allclose(ph.sum(-1), 1.0, atol=1e-4)


def test_sweep_deterministic_given_key():
    cfg = SLDAConfig(n_topics=4, vocab_size=32)
    corpus, _ = make_slda_corpus(jax.random.PRNGKey(0), 8, 32, 4, 12)
    state = init_state(jax.random.PRNGKey(1), corpus, cfg)
    s1 = sweep(jax.random.PRNGKey(2), corpus, state, cfg)
    s2 = sweep(jax.random.PRNGKey(2), corpus, state, cfg)
    assert np.array_equal(np.asarray(s1.z), np.asarray(s2.z))


def test_supervision_pulls_topics_toward_label_fit():
    """With a strongly informative η, the supervised term must change the
    sampled assignments relative to unsupervised sampling."""
    cfg = SLDAConfig(n_topics=4, vocab_size=64, rho=0.01)
    corpus, _ = make_slda_corpus(jax.random.PRNGKey(5), 16, 64, 4, 20)
    state = init_state(jax.random.PRNGKey(6), corpus, cfg)
    state = state.__class__(state.z, state.ndt, state.ntw, state.nt,
                            jnp.asarray([10.0, -10.0, 5.0, -5.0]))
    sup = sweep(jax.random.PRNGKey(7), corpus, state, cfg, supervised=True)
    uns = sweep(jax.random.PRNGKey(7), corpus, state, cfg, supervised=False)
    assert not np.array_equal(np.asarray(sup.z), np.asarray(uns.z))
