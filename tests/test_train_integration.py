"""Integration tests: LM training loop, accumulation equivalence,
checkpoint/restart determinism, serving combine."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.sharding import DistConfig
from repro.launch.steps import make_decode_step, make_train_step
from repro.launch.train import make_lm_batch, train
from repro.models import ModelConfig, init_cache, init_params
from repro.optim import OptConfig, init_opt_state

CFG = ModelConfig(name="ti-tiny", n_layers=2, d_model=64, n_heads=4,
                  n_kv_heads=2, d_ff=128, vocab_size=256, rope_theta=1e4)


def test_loss_decreases_over_training(tmp_path):
    _, _, history = train("internlm2-1.8b", smoke=True, steps=30, batch=4,
                          seq=32, chains=2, lr=3e-3,
                          ckpt_dir=str(tmp_path), save_interval=10,
                          log_every=100)
    # synthetic tokens are uniform-random: the learnable floor is the
    # uniform distribution (ln V), approached slowly — assert steady progress
    first = history[:5].mean(axis=0)
    last = history[-5:].mean(axis=0)
    assert (last < first - 0.03).all(), (first, last)


def test_restart_is_bitwise_deterministic(tmp_path):
    """Train 10 steps; separately train 6 + restart + 4 — same loss curve."""
    kw = dict(smoke=True, batch=2, seq=16, chains=2, lr=1e-3, log_every=100,
              schedule_steps=10)
    _, _, full = train("qwen3-1.7b", steps=10, **kw)
    _, _, _ = train("qwen3-1.7b", steps=6, ckpt_dir=str(tmp_path),
                    save_interval=6, **kw)
    _, _, tail = train("qwen3-1.7b", steps=10, ckpt_dir=str(tmp_path),
                       resume=True, save_interval=100, **kw)
    np.testing.assert_allclose(full[6:], tail, rtol=1e-4, atol=1e-5)


def test_accumulation_matches_single_batch():
    """accum_steps=2 over a split batch ≈ one step over the full batch."""
    opt = OptConfig(lr=1e-3, warmup_steps=0, clip_norm=1e9)
    params = init_params(jax.random.PRNGKey(0), CFG, 2)
    batch = make_lm_batch(0, 0, CFG, 2, 8, 16)

    s1 = jax.jit(make_train_step(
        CFG, DistConfig(n_chains=2, accum_steps=1, compute_dtype="float32",
                        remat=False), opt))
    s2 = jax.jit(make_train_step(
        CFG, DistConfig(n_chains=2, accum_steps=2, compute_dtype="float32",
                        remat=False), opt))
    p1, _, m1 = s1(params, init_opt_state(params, opt), batch)
    p2, _, m2 = s2(params, init_opt_state(params, opt), batch)
    np.testing.assert_allclose(np.asarray(m1["loss"]), np.asarray(m2["loss"]),
                               rtol=1e-5)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


def test_chains_never_mix_during_training():
    """Two chains fed IDENTICAL data + IDENTICAL init evolve identically;
    chain 1 fed different data diverges — and chain 0 is unaffected by what
    chain 1 sees (communication-freedom at the numerical level)."""
    opt = OptConfig(lr=1e-2, warmup_steps=0)
    one = init_params(jax.random.PRNGKey(3), CFG, 1)
    params = jax.tree.map(lambda x: jnp.concatenate([x, x]), one)
    state = init_opt_state(params, opt)
    step = jax.jit(make_train_step(
        CFG, DistConfig(n_chains=2, compute_dtype="float32", remat=False),
        opt))

    ba = make_lm_batch(0, 0, CFG, 1, 4, 16)
    bb = make_lm_batch(123, 0, CFG, 1, 4, 16)
    same = {k: jnp.concatenate([ba[k], ba[k]]) for k in ba}
    diff = {k: jnp.concatenate([ba[k], bb[k]]) for k in ba}

    p_same, _, _ = step(params, state, same)
    p_diff, _, _ = step(params, state, diff)
    for leaf in jax.tree.leaves(p_same):
        np.testing.assert_allclose(np.asarray(leaf[0]), np.asarray(leaf[1]),
                                   atol=1e-6)
    w_same = jax.tree.leaves(p_same)
    w_diff = jax.tree.leaves(p_diff)
    # chain 0 identical regardless of chain 1's data
    for a, b in zip(w_same, w_diff):
        np.testing.assert_allclose(np.asarray(a[0]), np.asarray(b[0]),
                                   atol=1e-6)
    # chain 1 did diverge
    assert any(np.abs(np.asarray(a[1] - b[1])).max() > 1e-6
               for a, b in zip(w_same, w_diff))


def test_decode_combine_rules():
    params = init_params(jax.random.PRNGKey(1), CFG, 3)
    dist = DistConfig(n_chains=3, compute_dtype="float32")
    cache = init_cache(CFG, 3, 2, 8, dtype=jnp.float32)
    toks = jnp.ones((3, 2, 1), jnp.int32)

    none_fn = jax.jit(make_decode_step(CFG, dist, combine="none"))
    simple_fn = jax.jit(make_decode_step(CFG, dist, combine="simple"))
    wt_fn = jax.jit(make_decode_step(CFG, dist, combine="weighted"))

    per_chain, _ = none_fn(params, cache, {"tokens": toks})
    assert per_chain.shape == (3, 2, 1, CFG.vocab_size)
    mixed, _ = simple_fn(params, cache, {"tokens": toks})
    assert mixed.shape == (2, 1, CFG.vocab_size)
    # simple average in prob space equals manual computation
    manual = jnp.log(jax.nn.softmax(per_chain, -1).mean(0))
    np.testing.assert_allclose(np.asarray(mixed), np.asarray(manual),
                               rtol=1e-4, atol=1e-5)
    # weighted with one-hot weight selects that chain's distribution
    w = jnp.asarray([1.0, 0.0, 0.0])
    sel, _ = wt_fn(params, cache, {"tokens": toks, "chain_weights": w})
    np.testing.assert_allclose(
        np.asarray(sel), np.asarray(jax.nn.log_softmax(per_chain[0], -1)),
        rtol=1e-4, atol=1e-5)
