"""Elastic runtime tests: dynamic placement, device loss, stragglers,
preemption/resume, async checkpointing (DESIGN.md §Elastic-training).

The load-bearing claims are all BITWISE, not approximate — the paper's
communication-free design makes elasticity exact, and these tests hold
it to that:

  * survivors of a device loss == the same lanes of an undisturbed run,
  * restored victims, after catch-up, == the undisturbed run entirely,
  * resume after preemption == the undisturbed run entirely,
  * async checkpointing == sync checkpointing, bit for bit,
  * and a repack never retraces the compiled round (placement is host
    metadata outside every jit cache key).
"""
import os
import signal

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.supervisor import F_KILLED, F_STRAGGLER
from repro.core.types import SLDAConfig, partition
from repro.core.plan import build_schedule
from repro.checkpoint import (latest_step, read_manifest,
                              restore_checkpoint, sweep_stale)
from repro.data import make_slda_corpus, train_test_split
from repro.launch.elastic import (DevicePool, ElasticConfig, ElasticRunner,
                                  PreemptionSignal, compute_placement,
                                  elastic_run_average)
from repro.testing import ElasticEvent, VirtualClock, random_elastic_events

M = 4
EL = ElasticConfig(round_iters=2)       # 6 iters → R = 3 logical rounds
ROOT = jax.random.PRNGKey(7)


@pytest.fixture(scope="module")
def corpus():
    c, _ = make_slda_corpus(jax.random.PRNGKey(0), 48, 32, 4, 8)
    return train_test_split(c, 32)


@pytest.fixture(scope="module")
def cfg():
    return SLDAConfig(n_topics=4, vocab_size=32, n_iters=6,
                      n_pred_burnin=2, n_pred_samples=2)


@pytest.fixture(scope="module")
def shards(corpus, cfg):
    train, _ = corpus
    return build_schedule(partition(train, M), cfg)


@pytest.fixture(scope="module")
def undisturbed(shards, cfg):
    """Reference run: no events, no checkpoints — what every elastic
    scenario must be bitwise-equal (or lane-equal) to."""
    r = ElasticRunner(shards, cfg, devices=2, elastic=EL)
    state, models, rep = r.train(ROOT)
    assert rep.alive.all() and (rep.progress == rep.logical_rounds).all()
    return state, models, rep


def leaves_equal(a, b, idx=None):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        x, y = np.asarray(x), np.asarray(y)
        if idx is not None:
            x, y = x[idx], y[idx]
        if not np.array_equal(x, y):
            return False
    return True


# ----------------------------------------------------- placement / membership

def test_compute_placement_balanced_and_deterministic():
    p = compute_placement(range(7), ["a", "b", "c"])
    assert p == {"a": (0, 1, 2), "b": (3, 4), "c": (5, 6)}
    assert p == compute_placement([6, 5, 4, 3, 2, 1, 0], ["a", "b", "c"])
    sizes = [len(v) for v in p.values()]
    assert max(sizes) - min(sizes) <= 1
    with pytest.raises(ValueError):
        compute_placement([0, 1], [])


def test_device_pool_membership_and_epoch():
    pool = DevicePool(3)
    assert pool.ids == (0, 1, 2) and pool.epoch == 0
    assert pool.lose(1) and pool.ids == (0, 2) and pool.epoch == 1
    assert not pool.lose(1)                  # already gone → no-op
    assert pool.join(5) and pool.ids == (0, 2, 5) and pool.epoch == 2
    assert not pool.join(5)
    pool.lose(0), pool.lose(2)
    with pytest.raises(RuntimeError, match="last pool member"):
        pool.lose(5)


def test_preemption_signal_latches_sigterm():
    sig = PreemptionSignal().install()
    try:
        assert not sig.triggered
        os.kill(os.getpid(), signal.SIGTERM)
        assert sig.triggered
        sig.clear()
        assert not sig.triggered
    finally:
        sig.uninstall()


# ------------------------------------------------------------- determinism

def test_clean_run_is_deterministic_and_traces_once(shards, cfg,
                                                    undisturbed):
    state0, _, rep0 = undisturbed
    r = ElasticRunner(shards, cfg, devices=2, elastic=EL)
    state, _, rep = r.train(ROOT)
    assert leaves_equal(state, state0)
    assert rep.round_traces == 1
    assert rep.wall_rounds == rep.logical_rounds == 3


def test_placement_is_bitwise_irrelevant(shards, cfg, undisturbed):
    """The same ensemble on 1, 2, or 4 devices produces identical bits —
    chain streams depend on chain ids, never on layout."""
    state0, _, _ = undisturbed
    for ndev in (1, 4):
        r = ElasticRunner(shards, cfg, devices=ndev, elastic=EL)
        state, _, _ = r.train(ROOT)
        assert leaves_equal(state, state0), f"devices={ndev} changed bits"


# ------------------------------------------------------------- device loss

def test_device_loss_without_ckpt_quarantines_exactly(shards, cfg,
                                                      undisturbed):
    state0, _, _ = undisturbed
    ev = [ElasticEvent("device_loss", at_round=2, device=1)]
    r = ElasticRunner(shards, cfg, devices=2, elastic=EL, events=ev)
    state, _, rep = r.train(ROOT)
    victims = np.nonzero(~rep.alive)[0]
    assert len(victims) == 2                 # device 1 held chains 2, 3
    assert all(rep.status[v] & F_KILLED for v in victims)
    survivors = np.nonzero(rep.alive)[0]
    # the exactness dividend: surviving lanes are bit-identical to the
    # run in which the loss never happened
    assert leaves_equal(state, state0, idx=survivors)
    assert rep.round_traces == 1             # repack never retraced


def test_device_loss_at_boundary_restores_with_zero_rewind(shards, cfg,
                                                           tmp_path,
                                                           undisturbed):
    """With the default save-every-round cadence, a boundary device loss
    restores its victims from the round that JUST published — no rewind,
    no catch-up rounds, and the result is still bitwise-undisturbed."""
    state0, _, _ = undisturbed
    ev = [ElasticEvent("device_loss", at_round=2, device=1)]
    r = ElasticRunner(shards, cfg, devices=2, elastic=EL, events=ev,
                      ckpt_dir=str(tmp_path))
    state, _, rep = r.train(ROOT)
    assert rep.alive.all()
    assert (rep.progress == rep.logical_rounds).all()
    assert rep.wall_rounds == rep.logical_rounds     # zero rounds lost
    assert leaves_equal(state, state0)
    assert rep.round_traces == 1


def test_device_loss_with_sparse_ckpt_catches_up_bitwise(corpus, shards,
                                                         cfg, tmp_path):
    """With checkpoints every 2 rounds, a loss at an unsaved boundary
    rewinds the victims to the last durable round; per-chain round keys
    replay the lost rounds exactly, so after catch-up the whole ensemble
    is bitwise-equal to the undisturbed run."""
    import dataclasses
    cfg8 = dataclasses.replace(cfg, n_iters=8)       # R = 4
    ref = ElasticRunner(shards, cfg8, devices=2, elastic=EL)
    state0, _, rep0 = ref.train(ROOT)
    assert rep0.wall_rounds == 4

    el = ElasticConfig(round_iters=2, ckpt_every=2)
    ev = [ElasticEvent("device_loss", at_round=3, device=1)]
    r = ElasticRunner(shards, cfg8, devices=2, elastic=el, events=ev,
                      ckpt_dir=str(tmp_path))
    state, _, rep = r.train(ROOT)
    assert rep.alive.all()
    assert (rep.progress == rep.logical_rounds).all()
    # victims rewound 3 → 2 (last durable), so one catch-up round
    assert rep.wall_rounds == 5
    # full bitwise equality, victims included
    assert leaves_equal(state, state0)
    assert rep.round_traces == 1             # catch-up reuses the round fn


def test_device_join_repacks_without_retrace(shards, cfg, undisturbed):
    state0, _, _ = undisturbed
    ev = [ElasticEvent("device_join", at_round=1, device=9)]
    r = ElasticRunner(shards, cfg, devices=2, elastic=EL, events=ev)
    state, _, rep = r.train(ROOT)
    assert 9 in r.pool
    assert leaves_equal(state, state0)
    assert rep.round_traces == 1


# ------------------------------------ property: random elastic scenarios

@pytest.mark.parametrize("seed,ndev,cpd", [(0, 2, 1), (1, 2, 2),
                                           (2, 4, 2)])
def test_repack_property_random_scenarios(corpus, cfg, seed, ndev, cpd):
    """Seed-driven form of the repack property (runs without
    hypothesis): for random (loss round, pool size, chains/device), the
    survivors of a device loss are bitwise-equal to the undisturbed
    run's same lanes."""
    train, _ = corpus
    m = ndev * cpd
    shards = build_schedule(partition(train, m), cfg)
    ref = ElasticRunner(shards, cfg, devices=ndev, elastic=EL)
    state0, _, _ = ref.train(ROOT)

    rng = np.random.default_rng(seed)
    ev = [ElasticEvent("device_loss",
                       at_round=int(rng.integers(1, 3)),
                       device=int(rng.integers(0, ndev)))]
    r = ElasticRunner(shards, cfg, devices=ndev, elastic=EL, events=ev)
    state, _, rep = r.train(ROOT)
    survivors = np.nonzero(rep.alive)[0]
    assert 0 < len(survivors) < m
    assert leaves_equal(state, state0, idx=survivors)
    assert rep.round_traces == 1


try:  # the rest of this module must still run without hypothesis
    from hypothesis import given, settings, strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    _HAVE_HYPOTHESIS = False
    given = settings = lambda *a, **k: (lambda f: f)

    class st:  # noqa: N801 — placeholder so the decorators below parse
        sampled_from = integers = data = staticmethod(lambda *a, **k: None)


@pytest.mark.slow
@pytest.mark.skipif(not _HAVE_HYPOTHESIS, reason=(
    "property tests need hypothesis (pip install -r requirements-dev.txt)"))
@settings(max_examples=8, deadline=None)
@given(ndev=st.sampled_from([2, 4]), cpd=st.sampled_from([1, 2]),
       data=st.data())
def test_repack_property_hypothesis(ndev, cpd, data):
    """Hypothesis form of the repack property over (device-loss round,
    pool size, M, chains_per_device)."""
    c, _ = make_slda_corpus(jax.random.PRNGKey(0), 48, 32, 4, 8)
    train, _ = train_test_split(c, 32)
    cfg = SLDAConfig(n_topics=4, vocab_size=32, n_iters=6,
                     n_pred_burnin=2, n_pred_samples=2)
    m = ndev * cpd
    shards = build_schedule(partition(train, m), cfg)
    ref = ElasticRunner(shards, cfg, devices=ndev, elastic=EL)
    state0, _, _ = ref.train(ROOT)
    ev = [ElasticEvent("device_loss",
                       at_round=data.draw(st.integers(1, 2)),
                       device=data.draw(st.integers(0, ndev - 1)))]
    r = ElasticRunner(shards, cfg, devices=ndev, elastic=EL, events=ev)
    state, _, rep = r.train(ROOT)
    survivors = np.nonzero(rep.alive)[0]
    assert leaves_equal(state, state0, idx=survivors)
    assert rep.round_traces == 1


# ------------------------------------------------------- preempt / resume

def test_preempt_then_resume_is_bitwise_transparent(shards, cfg, tmp_path,
                                                    undisturbed):
    state0, _, _ = undisturbed
    ev = [ElasticEvent("preempt", at_round=2)]
    r1 = ElasticRunner(shards, cfg, devices=2, elastic=EL, events=ev,
                       ckpt_dir=str(tmp_path))
    _, _, rep1 = r1.train(ROOT)
    assert rep1.preempted
    # ≤1 round lost: the drain published everything completed so far
    assert latest_step(str(tmp_path)) >= rep1.wall_rounds - 1

    r2 = ElasticRunner(shards, cfg, devices=2, elastic=EL,
                       ckpt_dir=str(tmp_path))
    state2, _, rep2 = r2.train(ROOT, resume=True)
    assert rep2.resume_round == rep1.wall_rounds
    # resume re-ran only the remaining rounds...
    assert rep2.wall_rounds == rep2.logical_rounds
    # ...and the result is indistinguishable from never preempting
    assert leaves_equal(state2, state0)


def test_preempt_during_flush_leaves_zero_corrupt_steps(shards, cfg,
                                                        tmp_path,
                                                        monkeypatch,
                                                        undisturbed):
    """Chaos: the preemption notice lands while the async writer is
    mid-flush AND the writer dies partway through a later write.  Every
    step the store publishes must still restore cleanly (atomic publish
    is untouched by the async path) and the run must resume bitwise."""
    import repro.checkpoint.store as store
    state0, _, _ = undisturbed
    calls = {"n": 0}
    real_savez = store.np.savez

    def flaky_savez(f, **kw):
        calls["n"] += 1
        if calls["n"] == 6:                 # die inside a later write
            raise OSError("killed mid-flush")
        return real_savez(f, **kw)

    monkeypatch.setattr(store.np, "savez", flaky_savez)
    ev = [ElasticEvent("preempt", at_round=2)]
    r1 = ElasticRunner(shards, cfg, devices=2, elastic=EL, events=ev,
                       ckpt_dir=str(tmp_path))
    try:
        r1.train(ROOT)
    except OSError:
        pass                                # the writer's death surfaced
    monkeypatch.undo()

    # zero corrupt steps: whatever got published is whole
    sweep_stale(str(tmp_path))
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path)
                   if d.startswith("step_"))
    assert steps, "nothing durable survived the chaos"
    helper = ElasticRunner(shards, cfg, devices=2, elastic=EL)
    ks = jax.vmap(jax.random.split)(jax.vmap(
        lambda c: jax.random.fold_in(ROOT, c))(jnp.arange(M)))
    tmpl, _ = helper.sup._init(helper.sup.plan, ks[:, 0])
    for s in steps:
        read_manifest(str(tmp_path), s)     # validates, raises if torn
        restore_checkpoint(str(tmp_path), s, tmpl)
    assert not any(d.startswith(".tmp_") for d in os.listdir(tmp_path))

    # and the run still resumes to the undisturbed answer
    r2 = ElasticRunner(shards, cfg, devices=2, elastic=EL,
                       ckpt_dir=str(tmp_path))
    state2, _, _ = r2.train(ROOT, resume=True)
    assert leaves_equal(state2, state0)


# ------------------------------------------------------------- stragglers

def test_straggler_flag_then_escalate_to_eviction(shards, cfg,
                                                  undisturbed):
    state0, _, _ = undisturbed
    clock = VirtualClock()
    ev = [ElasticEvent("straggle", at_round=1, device=1, delay_s=5.0,
                       rounds=3)]
    el = ElasticConfig(round_iters=2, device_round_s=1.0, deadline_s=2.0,
                       straggle_rounds=2)
    r = ElasticRunner(shards, cfg, devices=2, elastic=el, events=ev,
                      clock=clock)
    state, _, rep = r.train(ROOT)
    # flag on the slow device's chains only — and flag ONLY: slow is not
    # dead, nothing restores, nothing quarantines, bits don't move
    assert [bool(s & F_STRAGGLER) for s in rep.status] == [False, False,
                                                           True, True]
    assert rep.alive.all()
    assert leaves_equal(state, state0)
    # escalation after straggle_rounds consecutive misses evicts the
    # DEVICE; its chains repack onto the survivor
    assert r.pool.ids == (0,)
    acts = [e["action"] for h in rep.history for e in h["events"]]
    assert acts.count("deadline_miss") == 2
    assert "straggler_evicted" in acts
    assert rep.round_traces == 1
    # the virtual clock accumulated the straggler's delay
    assert rep.sim_seconds > rep.wall_rounds * el.device_round_s


def test_speculative_replace_moves_slowest_devices_chains(shards, cfg):
    clock = VirtualClock()
    ev = [ElasticEvent("straggle", at_round=1, device=0, delay_s=9.0,
                       rounds=3)]
    el = ElasticConfig(round_iters=2, device_round_s=1.0, deadline_s=2.0,
                       straggle_rounds=5, speculative_replace=True)
    r = ElasticRunner(shards, cfg, devices=2, elastic=el, events=ev,
                      clock=clock)
    _, _, rep = r.train(ROOT)
    spec = [e for h in rep.history for e in h["events"]
            if e["action"] == "speculative_replace"]
    assert spec and spec[0]["device"] == 0 and spec[0]["target"] == 1
    assert r.pool.ids == (0, 1)             # nothing evicted
    assert r.placement[1] == (0, 1, 2, 3)   # all chains moved off dev 0


def test_random_elastic_events_deterministic():
    a = random_elastic_events(5, n_rounds=6, n_devices=3, n_events=4)
    b = random_elastic_events(5, n_rounds=6, n_devices=3, n_events=4)
    assert a == b
    losses = sum(e.kind == "device_loss" for e in a)
    assert losses <= 2                      # never drains the pool
    with pytest.raises(ValueError):
        random_elastic_events(0, n_rounds=4, n_devices=2,
                              kinds=("nope",))


# --------------------------------------------------- async checkpointing

def test_async_and_sync_checkpointing_identical_bits(shards, cfg,
                                                     tmp_path):
    rs = ElasticRunner(shards, cfg, devices=2,
                       elastic=ElasticConfig(round_iters=2,
                                             async_ckpt=False),
                       ckpt_dir=str(tmp_path / "sync"))
    ra = ElasticRunner(shards, cfg, devices=2,
                       elastic=ElasticConfig(round_iters=2,
                                             async_ckpt=True),
                       ckpt_dir=str(tmp_path / "async"))
    state_s, _, _ = rs.train(ROOT)
    state_a, _, _ = ra.train(ROOT)
    assert leaves_equal(state_a, state_s)
    s_steps = latest_step(str(tmp_path / "sync"))
    a_steps = latest_step(str(tmp_path / "async"))
    assert s_steps == a_steps == 3
    # the published checkpoints are byte-equivalent too: same manifests,
    # same arrays in every chain file
    for step in (2, 3):
        ms = read_manifest(str(tmp_path / "sync"), step)
        ma = read_manifest(str(tmp_path / "async"), step)
        assert ms == ma
        for chain in range(M):
            name = f"step_{step:08d}/chain_{chain:03d}.npz"
            with np.load(tmp_path / "sync" / name) as a, \
                    np.load(tmp_path / "async" / name) as b:
                assert sorted(a.files) == sorted(b.files)
                for k in a.files:
                    assert np.array_equal(a[k], b[k]), (step, chain, k)


def test_manifest_extra_carries_resume_bookkeeping(shards, cfg, tmp_path):
    r = ElasticRunner(shards, cfg, devices=2, elastic=EL,
                      ckpt_dir=str(tmp_path))
    r.train(ROOT)
    extra = read_manifest(str(tmp_path), 3)["extra"]
    assert extra["progress"] == [3, 3, 3, 3]
    assert extra["alive"] == [True] * 4
    assert extra["wall_round"] == 3
    assert extra["pool"] == [0, 1]


# ----------------------------------------------------------- end-to-end

def test_elastic_run_average_end_to_end(corpus, cfg, tmp_path):
    train, test = corpus
    ev = [ElasticEvent("device_loss", at_round=2, device=0)]
    yhat, rep = elastic_run_average(
        jax.random.PRNGKey(3), train, test, cfg, M, devices=2,
        rule="simple", elastic=EL, events=ev, ckpt_dir=str(tmp_path))
    assert np.isfinite(np.asarray(yhat)).all()
    assert np.asarray(yhat).shape == (test.n_docs,)
    assert rep.alive.all()                  # restored + caught up
    assert (rep.progress == rep.logical_rounds).all()


def test_round_iters_must_divide_n_iters(shards, cfg):
    with pytest.raises(ValueError, match="must divide"):
        ElasticRunner(shards, cfg, devices=2,
                      elastic=ElasticConfig(round_iters=4))
