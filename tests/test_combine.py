"""Property-based tests (hypothesis) for the combination rules — the
system's central invariants."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import combine

floats = st.floats(-100, 100, allow_nan=False, width=32)


def yhat_strategy(min_chains=1, max_chains=8):
    return st.integers(min_chains, max_chains).flatmap(
        lambda m: st.integers(1, 6).flatmap(
            lambda d: st.lists(
                st.lists(floats, min_size=d, max_size=d),
                min_size=m, max_size=m)))


@given(yhat_strategy())
@settings(max_examples=60, deadline=None)
def test_simple_average_within_chain_range(rows):
    """Combined prediction is bounded by the per-chain min/max (convexity)."""
    yhat = jnp.asarray(rows, jnp.float32)
    out = np.asarray(combine.simple_average(yhat))
    lo, hi = np.min(rows, axis=0), np.max(rows, axis=0)
    assert (out >= lo - 1e-4).all() and (out <= hi + 1e-4).all()


@given(yhat_strategy(min_chains=2),
       st.lists(st.floats(0.015625, 10, width=32), min_size=2, max_size=8))
@settings(max_examples=60, deadline=None)
def test_weighted_average_is_convex_combination(rows, mses):
    yhat = jnp.asarray(rows, jnp.float32)
    m = yhat.shape[0]
    mse = jnp.asarray((mses * m)[:m], jnp.float32)
    out = np.asarray(combine.weighted_average(yhat, train_mse=mse))
    lo, hi = np.min(rows, axis=0), np.max(rows, axis=0)
    assert (out >= lo - 1e-4).all() and (out <= hi + 1e-4).all()


@given(yhat_strategy(min_chains=1))
@settings(max_examples=60, deadline=None)
def test_identical_chains_are_fixed_point(rows):
    """If every chain predicts the same thing, every rule returns it."""
    one = jnp.asarray(rows[:1], jnp.float32)
    yhat = jnp.tile(one, (4, 1))
    for out in (combine.simple_average(yhat),
                combine.weighted_average(yhat,
                                         train_mse=jnp.ones(4)),
                combine.median(yhat)):
        np.testing.assert_allclose(np.asarray(out), np.asarray(one[0]),
                                   rtol=1e-5, atol=1e-5)


@given(yhat_strategy(min_chains=3))
@settings(max_examples=60, deadline=None)
def test_dead_chains_are_ignored(rows):
    """Zeroing a chain via `alive` must equal removing it — the fault-
    tolerance contract."""
    yhat = jnp.asarray(rows, jnp.float32)
    m = yhat.shape[0]
    alive = jnp.ones(m).at[0].set(0.0)
    got = combine.simple_average(yhat, alive=alive)
    want = combine.simple_average(yhat[1:])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5,
                               atol=1e-5)
    got_w = combine.weighted_average(yhat, train_mse=jnp.ones(m),
                                     alive=alive)
    want_w = combine.weighted_average(yhat[1:], train_mse=jnp.ones(m - 1))
    np.testing.assert_allclose(np.asarray(got_w), np.asarray(want_w),
                               rtol=1e-5, atol=1e-5)
    got_m = combine.median(yhat, alive=alive)
    want_m = combine.median(yhat[1:])
    np.testing.assert_allclose(np.asarray(got_m), np.asarray(want_m),
                               rtol=1e-5, atol=1e-5)


def test_weighted_prefers_better_chain():
    """Lower train-MSE chain dominates the weighted combination (Eq. 8)."""
    yhat = jnp.asarray([[0.0, 0.0], [1.0, 1.0]], jnp.float32)
    out = np.asarray(combine.weighted_average(
        yhat, train_mse=jnp.asarray([0.01, 1.0])))
    assert (out < 0.1).all()


def test_median_robust_to_outlier_chain():
    yhat = jnp.asarray([[1.0], [1.1], [0.9], [1e6]], jnp.float32)
    out = float(combine.median(yhat)[0])
    assert 0.9 <= out <= 1.1


@given(yhat_strategy(min_chains=2))
@settings(max_examples=40, deadline=None)
def test_equal_mse_weighted_equals_simple(rows):
    """Equal training MSEs ⇒ Weighted Average degenerates to Simple (the
    paper's Eq. 8 with uniform weights)."""
    yhat = jnp.asarray(rows, jnp.float32)
    m = yhat.shape[0]
    got = combine.weighted_average(yhat, train_mse=jnp.full((m,), 0.5))
    want = combine.simple_average(yhat)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@given(yhat_strategy(min_chains=2),
       st.lists(st.booleans(), min_size=2, max_size=8))
@settings(max_examples=60, deadline=None)
def test_alive_renormalization_sums_to_one(rows, alive_bits):
    """The implied combination weights renormalize to EXACTLY one over the
    survivors: combining chains that all predict the constant c must
    return c, for any alive mask with at least one survivor."""
    m = len(rows)
    alive = jnp.asarray(((alive_bits * m)[:m]), jnp.float32)
    if float(alive.sum()) == 0.0:
        alive = alive.at[0].set(1.0)
    c = jnp.asarray(rows[0][:1], jnp.float32)[0]
    yhat = jnp.full((m, 3), c, jnp.float32)
    mse = jnp.linspace(0.1, 1.0, m)
    for out in (combine.simple_average(yhat, alive=alive),
                combine.weighted_average(yhat, train_mse=mse, alive=alive),
                combine.median(yhat, alive=alive)):
        np.testing.assert_allclose(np.asarray(out), float(c), rtol=1e-5,
                                   atol=1e-5)


@given(yhat_strategy(min_chains=2), st.integers(0, 7))
@settings(max_examples=60, deadline=None)
def test_single_survivor_reduces_to_identity(rows, which):
    """With exactly one alive chain, every rule returns that chain's
    prediction — the degenerate end of the fault-tolerance contract."""
    yhat = jnp.asarray(rows, jnp.float32)
    m = yhat.shape[0]
    k = which % m
    alive = jnp.zeros((m,), jnp.float32).at[k].set(1.0)
    mse = jnp.linspace(0.1, 1.0, m)
    for out in (combine.simple_average(yhat, alive=alive),
                combine.weighted_average(yhat, train_mse=mse, alive=alive),
                combine.median(yhat, alive=alive)):
        np.testing.assert_allclose(np.asarray(out), np.asarray(yhat[k]),
                                   rtol=1e-5, atol=1e-5)


@given(yhat_strategy(min_chains=1))
@settings(max_examples=60, deadline=None)
def test_all_dead_mask_falls_back_to_unmasked_combine(rows):
    """An all-dead mask must not divide by zero or emit NaN/inf; the
    defined degradation is the UNMASKED combine (with a warning) — every
    rule, one semantics (`combine._alive`).  A fleet that lost its last
    health signal serves the full ensemble rather than zeros."""
    yhat = jnp.asarray(rows, jnp.float32)
    m = yhat.shape[0]
    alive = jnp.zeros((m,), jnp.float32)
    mse = jnp.linspace(0.1, 1.0, m)
    assert combine.all_dead(alive) and not combine.all_dead(None)
    for masked, unmasked in (
            (lambda: combine.simple_average(yhat, alive=alive),
             lambda: combine.simple_average(yhat)),
            (lambda: combine.weighted_average(yhat, train_mse=mse,
                                              alive=alive),
             lambda: combine.weighted_average(yhat, train_mse=mse)),
            (lambda: combine.median(yhat, alive=alive),
             lambda: combine.median(yhat))):
        with pytest.warns(RuntimeWarning, match="all-dead"):
            out = masked()
        assert np.all(np.isfinite(np.asarray(out)))
        np.testing.assert_allclose(np.asarray(out),
                                   np.asarray(unmasked()),
                                   rtol=1e-5, atol=1e-5)


@given(yhat_strategy(min_chains=3))
@settings(max_examples=60, deadline=None)
def test_dead_nan_chain_cannot_contaminate(rows):
    """A dead chain full of NaN/inf must be arithmetically invisible —
    masking by multiplication would leak 0·NaN = NaN into every rule."""
    yhat = jnp.asarray(rows, jnp.float32).at[0].set(jnp.nan)
    m = yhat.shape[0]
    alive = jnp.ones((m,), jnp.float32).at[0].set(0.0)
    mse = jnp.linspace(0.1, 1.0, m).at[0].set(jnp.inf)
    pairs = (
        (combine.simple_average(yhat, alive=alive),
         combine.simple_average(yhat[1:])),
        (combine.weighted_average(yhat, train_mse=mse, alive=alive),
         combine.weighted_average(yhat[1:], train_mse=mse[1:])),
        (combine.median(yhat, alive=alive), combine.median(yhat[1:])))
    for got, want in pairs:
        assert np.all(np.isfinite(np.asarray(got)))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)


@given(yhat_strategy(min_chains=2), st.randoms(use_true_random=False))
@settings(max_examples=60, deadline=None)
def test_weighted_and_median_are_permutation_invariant(rows, rng):
    """Chains are exchangeable: permuting them (with their weights and
    alive flags) must not change any combined prediction."""
    yhat = jnp.asarray(rows, jnp.float32)
    m = yhat.shape[0]
    perm = list(range(m))
    rng.shuffle(perm)
    perm = jnp.asarray(perm)
    mse = jnp.linspace(0.1, 1.0, m)
    alive = jnp.ones((m,), jnp.float32).at[0].set(0.0)
    np.testing.assert_allclose(
        np.asarray(combine.simple_average(yhat[perm], alive=alive[perm])),
        np.asarray(combine.simple_average(yhat, alive=alive)),
        rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(
        np.asarray(combine.weighted_average(yhat[perm], train_mse=mse[perm],
                                            alive=alive[perm])),
        np.asarray(combine.weighted_average(yhat, train_mse=mse,
                                            alive=alive)),
        rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(
        np.asarray(combine.median(yhat[perm], alive=alive[perm])),
        np.asarray(combine.median(yhat, alive=alive)),
        rtol=1e-5, atol=1e-5)


@given(yhat_strategy(min_chains=2),
       st.floats(0.125, 8.0, width=32))
@settings(max_examples=40, deadline=None)
def test_combination_rules_commute_with_scaling(rows, scale):
    """ŷ are linear predictions: every rule must commute with an affine
    rescaling of the label space."""
    yhat = jnp.asarray(rows, jnp.float32)
    m = yhat.shape[0]
    mse = jnp.linspace(0.1, 1.0, m)
    for fn in (lambda y: combine.simple_average(y),
               lambda y: combine.weighted_average(y, train_mse=mse),
               lambda y: combine.median(y)):
        np.testing.assert_allclose(np.asarray(fn(yhat * scale)),
                                   np.asarray(fn(yhat)) * scale,
                                   rtol=1e-4, atol=1e-4)
