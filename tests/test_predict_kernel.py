"""Validation of the fused prediction-sweep kernel and the incremental
count refresh (DESIGN.md §Predict-kernel, §3).

The three implementations — Pallas kernel (interpret mode), batched-jnp
fast path, per-document ref oracle — share the counter-hash PRNG and op
order, so equality is asserted EXACTLY, not to a tolerance."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (SLDAConfig, apply_count_deltas,
                        counts_from_assignments, init_state, predict, sweep)
from repro.data import make_slda_corpus
from repro.kernels import ops, ref
from repro.kernels.slda_predict import counter_uniform, predict_uniforms


def _setup(n_docs, n_topics, vocab, doc_len, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    tokens = jax.random.randint(ks[0], (n_docs, doc_len), 0, vocab, jnp.int32)
    lens = jax.random.randint(ks[1], (n_docs,), max(2, doc_len // 3),
                              doc_len + 1)
    mask = (jnp.arange(doc_len)[None, :] < lens[:, None]).astype(jnp.float32)
    z0 = jax.random.randint(ks[2], (n_docs, doc_len), 0, n_topics, jnp.int32)
    ndt0 = jnp.zeros((n_docs, n_topics), jnp.float32)
    ndt0 = ndt0.at[jnp.arange(n_docs)[:, None], z0].add(mask)
    phi = jax.random.dirichlet(ks[3], jnp.full((vocab,), 0.1), (n_topics,))
    seeds = jax.random.randint(ks[4], (n_docs,), 0, 2 ** 31 - 1, jnp.int32)
    return tokens, mask, z0, ndt0, phi, seeds


# ------------------------------------------------------ oracle equivalence

@pytest.mark.parametrize("n_docs,n_topics,vocab,doc_len,doc_block", [
    (16, 8, 100, 30, 8),
    (10, 16, 64, 20, 4),         # D not a doc_block multiple (pads)
    (8, 128, 200, 16, 8),        # full-lane topic dim
])
@pytest.mark.parametrize("n_burnin,n_samples", [(3, 4), (0, 2)])
def test_predict_kernel_matches_ref(n_docs, n_topics, vocab, doc_len,
                                    doc_block, n_burnin, n_samples):
    """Interpret-mode kernel == ref oracle fed the SAME uniforms, exactly."""
    tokens, mask, z0, ndt0, phi, seeds = _setup(n_docs, n_topics, vocab,
                                                doc_len)
    kw = dict(alpha=0.1, n_burnin=n_burnin, n_samples=n_samples)
    avg_k, z_k = ops.slda_predict_sweeps(tokens, mask, z0, ndt0, phi, seeds,
                                         doc_block=doc_block, **kw)
    uniforms = predict_uniforms(seeds, n_burnin + n_samples, doc_len)
    avg_r, z_r = ref.ref_slda_predict_sweeps(tokens, mask, uniforms, z0,
                                             ndt0, phi.T, 0.1, n_burnin)
    assert np.array_equal(np.asarray(z_k), np.asarray(z_r))
    np.testing.assert_allclose(np.asarray(avg_k), np.asarray(avg_r), atol=0)


def test_predict_jnp_fast_path_matches_kernel():
    """use_pallas=False (the CPU fast path) is bit-identical to the kernel."""
    tokens, mask, z0, ndt0, phi, seeds = _setup(12, 8, 80, 24, seed=1)
    kw = dict(alpha=0.1, n_burnin=2, n_samples=3)
    avg_k, z_k = ops.slda_predict_sweeps(tokens, mask, z0, ndt0, phi, seeds,
                                         doc_block=4, **kw)
    avg_j, z_j = ops.slda_predict_sweeps(tokens, mask, z0, ndt0, phi, seeds,
                                         use_pallas=False, **kw)
    assert np.array_equal(np.asarray(z_k), np.asarray(z_j))
    np.testing.assert_allclose(np.asarray(avg_k), np.asarray(avg_j), atol=0)


def test_predict_sweeps_count_conservation():
    """Every per-sweep ndt sums to the document length, so the average
    must too; z stays in range; padded tokens never move."""
    tokens, mask, z0, ndt0, phi, seeds = _setup(10, 6, 50, 20, seed=2)
    avg, z = ops.slda_predict_sweeps(tokens, mask, z0, ndt0, phi, seeds,
                                     alpha=0.1, n_burnin=2, n_samples=3,
                                     use_pallas=False)
    np.testing.assert_allclose(np.asarray(avg.sum(-1)),
                               np.asarray(mask.sum(-1)), rtol=1e-6)
    assert int(z.min()) >= 0 and int(z.max()) < 6
    pad = np.asarray(mask) == 0
    assert np.array_equal(np.asarray(z)[pad], np.asarray(z0)[pad])


def test_counter_uniform_is_deterministic_and_uniform():
    seeds = jnp.arange(64, dtype=jnp.int32) * 7919 + 13
    u1 = predict_uniforms(seeds, 4, 32)
    u2 = predict_uniforms(seeds, 4, 32)
    assert np.array_equal(np.asarray(u1), np.asarray(u2))
    u = np.asarray(u1).ravel()
    assert u.min() >= 0.0 and u.max() < 1.0
    assert abs(u.mean() - 0.5) < 0.02          # 8192 samples
    # distinct counters decorrelate: no two consecutive tokens collide often
    assert np.mean(np.abs(np.diff(u)) < 1e-6) < 0.01
    # scalar form agrees with the batched helper
    one = counter_uniform(seeds[3], 2 * 32 + 5)
    np.testing.assert_allclose(np.asarray(u1)[3, 2, 5], np.asarray(one))


def test_predict_end_to_end_learns_signal():
    """core.predict routed through the fused path still predicts y."""
    cfg = SLDAConfig(n_topics=8, vocab_size=100, n_iters=20, rho=0.25)
    corpus, _ = make_slda_corpus(jax.random.PRNGKey(5), 120, 100, 8, 30,
                                 rho=0.25)
    from repro.core import train_chain
    _, model = jax.jit(train_chain, static_argnums=(2,))(
        jax.random.PRNGKey(6), corpus, cfg)
    yhat = jax.jit(predict, static_argnums=(3,))(
        jax.random.PRNGKey(7), model, corpus, cfg)
    mse = float(jnp.mean((yhat - corpus.y) ** 2))
    assert mse < 0.5 * float(jnp.var(corpus.y))


# --------------------------------------------------- incremental counts

@pytest.mark.parametrize("use_pallas", [False, True])
def test_incremental_counts_match_rebuild_after_k_sweeps(use_pallas):
    """K sweeps of delta updates == counts_from_assignments rebuild,
    exactly (±1.0 f32 updates are lossless at these magnitudes)."""
    cfg = SLDAConfig(n_topics=8, vocab_size=64, use_pallas=use_pallas)
    corpus, _ = make_slda_corpus(jax.random.PRNGKey(8), 24, 64, 8, 20)
    state = init_state(jax.random.PRNGKey(9), corpus, cfg)
    for k in range(5):
        state = sweep(jax.random.PRNGKey(10 + k), corpus, state, cfg,
                      exact_rebuild=False)
    ndt, ntw, nt = counts_from_assignments(corpus.tokens, corpus.mask,
                                           state.z, cfg.n_topics,
                                           cfg.vocab_size)
    np.testing.assert_allclose(np.asarray(state.ndt), np.asarray(ndt), atol=0)
    np.testing.assert_allclose(np.asarray(state.ntw), np.asarray(ntw), atol=0)
    np.testing.assert_allclose(np.asarray(state.nt), np.asarray(nt), atol=0)


def test_apply_count_deltas_identity_when_nothing_changes():
    cfg = SLDAConfig(n_topics=4, vocab_size=32)
    corpus, _ = make_slda_corpus(jax.random.PRNGKey(11), 8, 32, 4, 12)
    state = init_state(jax.random.PRNGKey(12), corpus, cfg)
    ntw, nt = apply_count_deltas(state.ntw, state.nt, corpus.tokens,
                                 corpus.mask, state.z, state.z)
    np.testing.assert_allclose(np.asarray(ntw), np.asarray(state.ntw), atol=0)
    np.testing.assert_allclose(np.asarray(nt), np.asarray(state.nt), atol=0)


def test_traced_rebuild_flag_under_cond():
    """sweep() accepts a traced exact_rebuild bool (the train_chain path)."""
    cfg = SLDAConfig(n_topics=4, vocab_size=32)
    corpus, _ = make_slda_corpus(jax.random.PRNGKey(13), 8, 32, 4, 12)
    state = init_state(jax.random.PRNGKey(14), corpus, cfg)

    def run(flag):
        return sweep(jax.random.PRNGKey(15), corpus, state, cfg,
                     exact_rebuild=flag)

    s_inc = jax.jit(run)(jnp.asarray(False))
    s_reb = jax.jit(run)(jnp.asarray(True))
    np.testing.assert_allclose(np.asarray(s_inc.ntw), np.asarray(s_reb.ntw),
                               atol=0)
    np.testing.assert_allclose(np.asarray(s_inc.nt), np.asarray(s_reb.nt),
                               atol=0)
    np.testing.assert_allclose(np.asarray(s_inc.ndt), np.asarray(s_reb.ndt),
                               atol=0)
