"""Scanned-layer (stacked-param lax.scan) parity with the unrolled stack."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import (ModelConfig, decode_step, forward, init_cache,
                          init_params)

BASE = ModelConfig(name="t", n_layers=3, d_model=64, n_heads=4, n_kv_heads=2,
                   d_ff=128, vocab_size=128, qkv_bias=True, rope_theta=1e4)
SCAN = dataclasses.replace(BASE, scan_layers=True)
C, B, S = 2, 2, 8


def _paired_params():
    key = jax.random.PRNGKey(0)
    pu = init_params(key, BASE, C)
    ps = init_params(key, SCAN, C)
    ps = dict(ps)
    ps["layers_stacked"] = jax.tree.map(lambda *xs: jnp.stack(xs),
                                        *pu["layers"])
    for k in ("embed", "final_norm", "lm_head"):
        ps[k] = pu[k]
    return pu, ps


def test_forward_parity():
    pu, ps = _paired_params()
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (C, B, S),
                                          0, 128, jnp.int32)}
    lu, _ = forward(pu, batch, BASE, compute_dtype=jnp.float32,
                    use_pallas=False, remat=False)
    ls, _ = forward(ps, batch, SCAN, compute_dtype=jnp.float32,
                    use_pallas=False, remat=False)
    np.testing.assert_allclose(np.asarray(lu), np.asarray(ls), atol=1e-5)


def test_forward_parity_with_remat():
    pu, ps = _paired_params()
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(2), (C, B, S),
                                          0, 128, jnp.int32)}
    lu, _ = forward(pu, batch, BASE, compute_dtype=jnp.float32,
                    use_pallas=False, remat=True)
    ls, _ = forward(ps, batch, SCAN, compute_dtype=jnp.float32,
                    use_pallas=False, remat=True)
    np.testing.assert_allclose(np.asarray(lu), np.asarray(ls), atol=1e-5)


def test_decode_parity():
    pu, ps = _paired_params()
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(3), (C, B, S),
                                          0, 128, jnp.int32)}
    cu = init_cache(BASE, C, B, S, jnp.float32)
    cs = init_cache(SCAN, C, B, S, jnp.float32)
    for t in range(4):
        tb = {"tokens": batch["tokens"][:, :, t:t + 1]}
        du, cu = decode_step(pu, cu, tb, BASE, compute_dtype=jnp.float32,
                             use_pallas=False)
        ds, cs = decode_step(ps, cs, tb, SCAN, compute_dtype=jnp.float32,
                             use_pallas=False)
        np.testing.assert_allclose(np.asarray(du), np.asarray(ds), atol=1e-5)


def test_gradients_flow_through_scan():
    _, ps = _paired_params()
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(4), (C, B, S),
                                          0, 128, jnp.int32),
             "targets": jax.random.randint(jax.random.PRNGKey(5), (C, B, S),
                                           0, 128, jnp.int32)}
    from repro.models import loss_fn
    g = jax.grad(lambda p: loss_fn(p, batch, SCAN,
                                   compute_dtype=jnp.float32,
                                   use_pallas=False, remat=True).sum())(ps)
    gn = sum(float(jnp.sum(jnp.square(x))) for x in jax.tree.leaves(g))
    assert np.isfinite(gn) and gn > 0
