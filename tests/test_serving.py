"""Serving engine tests: generation shapes, ensemble combination,
straggler cuts, determinism."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models import ModelConfig, init_params
from repro.serving import GenerationConfig, ServingEngine, sample_token

CFG = ModelConfig(name="srv", n_layers=2, d_model=64, n_heads=4,
                  n_kv_heads=2, d_ff=128, vocab_size=97, rope_theta=1e4)


def make_engine(chains=2, combine="simple", **kw):
    params = init_params(jax.random.PRNGKey(0), CFG, chains)
    gen = GenerationConfig(max_new_tokens=6, combine=combine, **kw)
    return ServingEngine(CFG, params, n_chains=chains, batch_slots=3,
                         max_len=32, gen=gen)


def test_generate_shapes_and_range():
    eng = make_engine()
    prompts = jnp.ones((3, 4), jnp.int32)
    out = eng.generate(prompts)
    assert out.shape == (3, 6)
    assert int(out.min()) >= 0 and int(out.max()) < CFG.vocab_size


def test_greedy_is_deterministic():
    out1 = make_engine().generate(jnp.ones((3, 4), jnp.int32))
    out2 = make_engine().generate(jnp.ones((3, 4), jnp.int32))
    assert np.array_equal(np.asarray(out1), np.asarray(out2))


def test_single_chain_equals_combine_none():
    p = init_params(jax.random.PRNGKey(0), CFG, 1)
    outs = []
    for combine in ("simple", "none"):
        eng = ServingEngine(CFG, p, n_chains=1, batch_slots=2, max_len=32,
                            gen=GenerationConfig(max_new_tokens=5,
                                                 combine=combine))
        outs.append(np.asarray(eng.generate(jnp.ones((2, 3), jnp.int32))))
    assert np.array_equal(outs[0], outs[1])


def test_straggler_cut_matches_smaller_ensemble():
    """Dropping chain 1's weight must reproduce the chain-0-only output."""
    params = init_params(jax.random.PRNGKey(0), CFG, 2)
    eng = ServingEngine(CFG, params, n_chains=2, batch_slots=2, max_len=32,
                        gen=GenerationConfig(max_new_tokens=5,
                                             combine="weighted"))
    eng.drop_chain(1)
    out_cut = np.asarray(eng.generate(jnp.ones((2, 3), jnp.int32)))

    solo_params = jax.tree.map(lambda x: x[:1], params)
    solo = ServingEngine(CFG, solo_params, n_chains=1, batch_slots=2,
                         max_len=32,
                         gen=GenerationConfig(max_new_tokens=5,
                                              combine="none"))
    out_solo = np.asarray(solo.generate(jnp.ones((2, 3), jnp.int32)))
    assert np.array_equal(out_cut, out_solo)


def test_straggler_cut_simple_rule_masks_dead_chains():
    """The 'simple' rule must renormalize over SURVIVING chains (the
    paper's alive-mask semantics) — dropping chain 1 reproduces the
    chain-0-only output instead of silently averaging the dead chain in."""
    params = init_params(jax.random.PRNGKey(0), CFG, 2)
    eng = ServingEngine(CFG, params, n_chains=2, batch_slots=2, max_len=32,
                        gen=GenerationConfig(max_new_tokens=5,
                                             combine="simple"))
    eng.drop_chain(1)
    out_cut = np.asarray(eng.generate(jnp.ones((2, 3), jnp.int32)))

    solo_params = jax.tree.map(lambda x: x[:1], params)
    solo = ServingEngine(CFG, solo_params, n_chains=1, batch_slots=2,
                         max_len=32,
                         gen=GenerationConfig(max_new_tokens=5,
                                              combine="none"))
    out_solo = np.asarray(solo.generate(jnp.ones((2, 3), jnp.int32)))
    assert np.array_equal(out_cut, out_solo)


def test_all_chains_dropped_falls_back_to_unmasked_combine():
    """Dropping EVERY chain must serve the unmasked combine
    (core.combine's all-dead fallback) rather than mixing to zeros and
    emitting log(1e-30) garbage — for both combine rules the output
    equals a healthy engine's."""
    for combine in ("simple", "weighted"):
        healthy = make_engine(combine=combine)
        out_ok = np.asarray(healthy.generate(jnp.ones((3, 4), jnp.int32)))
        dead = make_engine(combine=combine)
        dead.drop_chain(0)
        dead.drop_chain(1)
        out_dead = np.asarray(dead.generate(jnp.ones((3, 4), jnp.int32)))
        assert np.array_equal(out_ok, out_dead)


def test_drop_chain_reaches_compiled_decode_mid_stream():
    """chain_weights is a jit argument, not a trace-time constant: a
    drop_chain AFTER the first compiled decode still changes the mix."""
    from repro.models import init_cache
    eng = make_engine(combine="simple")
    prompts = jnp.ones((3, 4), jnp.int32)
    eng.generate(prompts)                    # compiles with both chains
    eng.drop_chain(1)
    eng.cache = init_cache(CFG, 2, 3, 32, jnp.float32)   # fresh stream
    out_cut = np.asarray(eng.generate(prompts))

    fresh = make_engine(combine="simple")
    fresh.drop_chain(1)
    out_fresh = np.asarray(fresh.generate(prompts))
    assert np.array_equal(out_cut, out_fresh)


def test_combine_none_serves_first_alive_chain():
    """combine='none' must serve the first ALIVE chain: after
    drop_chain(0) the engine must reproduce the chain-1-only output,
    not keep serving the dead chain 0's logits."""
    params = init_params(jax.random.PRNGKey(0), CFG, 2)
    eng = ServingEngine(CFG, params, n_chains=2, batch_slots=2, max_len=32,
                        gen=GenerationConfig(max_new_tokens=5,
                                             combine="none"))
    eng.drop_chain(0)
    out_cut = np.asarray(eng.generate(jnp.ones((2, 3), jnp.int32)))

    solo_params = jax.tree.map(lambda x: x[1:], params)
    solo = ServingEngine(CFG, solo_params, n_chains=1, batch_slots=2,
                         max_len=32,
                         gen=GenerationConfig(max_new_tokens=5,
                                              combine="none"))
    out_solo = np.asarray(solo.generate(jnp.ones((2, 3), jnp.int32)))
    assert np.array_equal(out_cut, out_solo)


def test_eos_freezes_slots_and_pads_output():
    """A slot that emits eos_id is frozen: every later column is eos,
    earlier columns are untouched, and slots that never emit eos are
    bit-identical to the eos-off run (slots are independent)."""
    prompts = jnp.arange(6, dtype=jnp.int32).reshape(3, 2) + 1
    out0 = np.asarray(make_engine().generate(prompts))
    eos = int(out0[0, 1])                       # slot 0's 2nd token
    out = np.asarray(make_engine(eos_id=eos).generate(prompts))
    assert out.shape == out0.shape
    for b in range(out0.shape[0]):
        hits = np.flatnonzero(out0[b] == eos)
        if hits.size == 0:
            assert np.array_equal(out[b], out0[b])
        else:
            j = hits[0]
            assert np.array_equal(out[b, :j + 1], out0[b, :j + 1])
            assert (out[b, j + 1:] == eos).all()


def test_eos_stops_decoding_early():
    """Once every slot has emitted eos the step loop must break — the
    remaining columns are padded without paying for decode steps."""
    eng = make_engine()
    prompts = jnp.ones((3, 4), jnp.int32)
    eos = int(np.asarray(eng.generate(prompts))[0, 0])  # same prompt all
    # slots → all finish at step 1

    def counted(eng):
        calls = [0]
        inner = eng._decode

        def wrap(*a, **kw):
            calls[0] += 1
            return inner(*a, **kw)
        eng._decode = wrap
        return calls

    eng_off = make_engine()
    n_off = counted(eng_off)
    eng_off.generate(prompts)
    eng_on = make_engine(eos_id=eos)
    n_on = counted(eng_on)
    out = np.asarray(eng_on.generate(prompts))
    assert (out == eos).all()
    assert n_on[0] < n_off[0]                   # early stop saved steps


def test_sample_token_topk_ties_keep_exactly_k():
    """Ties at the k-th value must NOT widen the support: top_k=2 over
    three tied maxima keeps exactly the 2 lowest-index candidates."""
    logits = jnp.asarray([[5.0, 5.0, 5.0, 0.0, 0.0]])
    seen = {int(sample_token(jax.random.fold_in(jax.random.PRNGKey(1), i),
                             logits, temperature=1.0, top_k=2)[0])
            for i in range(64)}
    assert seen <= {0, 1}


def test_sample_token_topk_overflow_clamps():
    """top_k >= V used to raise out of jnp.sort indexing; it must clamp
    and equal plain temperature sampling bitwise."""
    key = jax.random.PRNGKey(3)
    logits = jnp.asarray([[1.0, 3.0, 2.0, 0.5, -1.0]])
    t_over = sample_token(key, logits, temperature=1.0, top_k=12)
    t_plain = sample_token(key, logits, temperature=1.0, top_k=0)
    assert int(t_over[0]) == int(t_plain[0])


def test_sample_token_topk_respects_support():
    key = jax.random.PRNGKey(0)
    logits = jnp.asarray([[10.0, 9.0, -5.0, -5.0, -5.0]])
    for i in range(8):
        t = sample_token(jax.random.fold_in(key, i), logits,
                         temperature=1.0, top_k=2)
        assert int(t[0]) in (0, 1)


def test_sample_token_greedy():
    logits = jnp.asarray([[1.0, 5.0, 2.0]])
    assert int(sample_token(jax.random.PRNGKey(0), logits)[0]) == 1
