"""Checkpoint / fault-tolerance / elasticity tests."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (CheckpointManager, latest_step, list_chains,
                              restore_chain, restore_checkpoint,
                              restore_elastic, save_checkpoint)


def make_state(key, chains=4, d=8):
    ks = jax.random.split(key, 3)
    return {"params": {"w": jax.random.normal(ks[0], (chains, d, d)),
                       "b": jnp.zeros((chains, d))},
            "opt": {"m": jax.random.normal(ks[1], (chains, d, d)),
                    "step": jnp.full((chains,), 7, jnp.int32)}}


def trees_equal(a, b):
    flat_a, flat_b = jax.tree.leaves(a), jax.tree.leaves(b)
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(flat_a, flat_b))


def test_save_restore_roundtrip(tmp_path):
    state = make_state(jax.random.PRNGKey(0))
    save_checkpoint(str(tmp_path), 100, state)
    assert latest_step(str(tmp_path)) == 100
    assert list_chains(str(tmp_path), 100) == [0, 1, 2, 3]
    restored, manifest = restore_checkpoint(str(tmp_path), 100, state)
    assert manifest["step"] == 100
    assert trees_equal(state, restored)


def test_atomicity_no_partial_checkpoint_visible(tmp_path):
    """A crash mid-save must leave no step_* dir behind."""
    state = make_state(jax.random.PRNGKey(1))

    class Boom(RuntimeError):
        pass

    bad = dict(state)
    class Exploding:
        shape = (4, 4)
        def __array__(self):
            raise Boom()
    bad["opt"] = {"m": state["opt"]["m"], "step": state["opt"]["step"],
                  "bomb": Exploding()}
    with pytest.raises(Exception):
        save_checkpoint(str(tmp_path), 5, bad)
    assert latest_step(str(tmp_path)) is None
    assert not any(d.startswith("step_") for d in os.listdir(tmp_path))


def test_elastic_restore_fewer_and_more_chains(tmp_path):
    state = make_state(jax.random.PRNGKey(2), chains=4)
    save_checkpoint(str(tmp_path), 10, state)

    # fewer chains: prefix restore
    small = make_state(jax.random.PRNGKey(3), chains=2)
    restored, info = restore_elastic(str(tmp_path), 10, small,
                                     lambda i: None)
    assert info["restored_chains"] == [0, 1]
    assert trees_equal(jax.tree.map(lambda x: x[:2], state), restored)

    # more chains: fresh init for the newcomers
    big = make_state(jax.random.PRNGKey(4), chains=6)
    fresh = make_state(jax.random.PRNGKey(5), chains=1)
    init_fn = lambda i: jax.tree.map(lambda x: x[0] + i, fresh)
    restored, info = restore_elastic(str(tmp_path), 10, big, init_fn)
    assert info["restored_chains"] == [0, 1, 2, 3]
    assert trees_equal(jax.tree.map(lambda x: x[:4], state),
                       jax.tree.map(lambda x: x[:4], restored))


def test_chain_failure_isolated(tmp_path):
    """Corrupting one chain's file must not affect the others (the fault-
    isolation dividend of the paper's communication-free design)."""
    state = make_state(jax.random.PRNGKey(6), chains=4)
    save_checkpoint(str(tmp_path), 20, state)
    victim = os.path.join(str(tmp_path), "step_00000020", "chain_002.npz")
    with open(victim, "wb") as f:
        f.write(b"corrupted")

    fresh = make_state(jax.random.PRNGKey(7), chains=1)
    init_fn = lambda i: jax.tree.map(lambda x: x[0] * 0 - 1.0, fresh)
    restored, info = restore_elastic(str(tmp_path), 20, state, init_fn)
    assert info["restored_chains"] == [0, 1, 3]
    for i in (0, 1, 3):
        assert trees_equal(jax.tree.map(lambda x: x[i], state),
                           jax.tree.map(lambda x: x[i], restored))
    assert float(restored["params"]["w"][2, 0, 0]) == -1.0


def test_crash_mid_second_save_keeps_previous_step(tmp_path,
                                                   monkeypatch):
    """A crash partway through writing the chain files of a LATER
    checkpoint must leave the previous complete step as latest — the
    crash-consistency contract the supervisor's restart relies on."""
    import repro.checkpoint.store as store
    state = make_state(jax.random.PRNGKey(9))
    save_checkpoint(str(tmp_path), 1, state)

    calls = {"n": 0}
    real_savez = store.np.savez

    def dying_savez(f, **kw):
        calls["n"] += 1
        if calls["n"] == 3:        # die on the 3rd chain of the 2nd save
            raise OSError("disk gone")
        return real_savez(f, **kw)

    monkeypatch.setattr(store.np, "savez", dying_savez)
    with pytest.raises(OSError):
        save_checkpoint(str(tmp_path), 2, state)
    monkeypatch.undo()
    assert latest_step(str(tmp_path)) == 1
    restored, manifest = restore_checkpoint(str(tmp_path), 1, state)
    assert manifest["step"] == 1 and trees_equal(state, restored)
    assert not any(d.startswith(".tmp_") for d in os.listdir(tmp_path))


def test_truncated_chain_file_is_fault_isolated(tmp_path):
    """A torn write (file truncated mid-flush) on ONE chain must behave
    exactly like the corrupt-file case: every other chain restores, the
    victim falls back to init_fn."""
    from repro.testing import truncate_chain_file
    state = make_state(jax.random.PRNGKey(10), chains=4)
    save_checkpoint(str(tmp_path), 30, state)
    truncate_chain_file(str(tmp_path), 30, 1)

    fresh = make_state(jax.random.PRNGKey(11), chains=1)
    init_fn = lambda i: jax.tree.map(lambda x: x[0] * 0 - 2.0, fresh)
    restored, info = restore_elastic(str(tmp_path), 30, state, init_fn)
    assert info["restored_chains"] == [0, 2, 3]
    for i in (0, 2, 3):
        assert trees_equal(jax.tree.map(lambda x: x[i], state),
                           jax.tree.map(lambda x: x[i], restored))
    assert float(restored["params"]["w"][1, 0, 0]) == -2.0
    # the strict single-chain reader refuses the torn file outright
    tmpl = jax.tree.map(lambda x: x[0], state)
    with pytest.raises(Exception):
        restore_chain(str(tmp_path), 30, 1, tmpl)


def test_manifest_step_mismatch_raises(tmp_path):
    """A manifest disagreeing with its directory name means a torn or
    hand-copied checkpoint — restoring it would silently resume from the
    wrong point, so every reader must refuse."""
    state = make_state(jax.random.PRNGKey(12), chains=2)
    save_checkpoint(str(tmp_path), 40, state)
    mpath = os.path.join(str(tmp_path), "step_00000040", "manifest.json")
    with open(mpath) as f:
        manifest = json.load(f)
    manifest["step"] = 39
    with open(mpath, "w") as f:
        json.dump(manifest, f)
    with pytest.raises(ValueError, match="torn or mislabelled"):
        restore_checkpoint(str(tmp_path), 40, state)
    with pytest.raises(ValueError, match="torn or mislabelled"):
        restore_chain(str(tmp_path), 40, 0,
                      jax.tree.map(lambda x: x[0], state))
    with pytest.raises(ValueError, match="torn or mislabelled"):
        restore_elastic(str(tmp_path), 40, state, lambda i: None)


def test_restore_chain_roundtrip(tmp_path):
    """The supervisor's restart path: one chain's slice comes back
    bit-identical without touching any other chain's file."""
    state = make_state(jax.random.PRNGKey(13), chains=4)
    save_checkpoint(str(tmp_path), 50, state)
    tmpl = jax.tree.map(lambda x: x[0], state)
    for c in (0, 3):
        got = restore_chain(str(tmp_path), 50, c, tmpl)
        assert trees_equal(jax.tree.map(lambda x: x[c], state), got)
    with pytest.raises(FileNotFoundError):
        restore_chain(str(tmp_path), 50, 9, tmpl)


def test_manager_gc_keeps_last_k(tmp_path):
    mgr = CheckpointManager(str(tmp_path), interval=1, keep=2)
    state = make_state(jax.random.PRNGKey(8), chains=2)
    for step in range(1, 6):
        mgr.maybe_save(step, state)
    steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert steps == ["step_00000004", "step_00000005"]
