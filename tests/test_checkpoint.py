"""Checkpoint / fault-tolerance / elasticity tests."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (AsyncCheckpointManager, CheckpointManager,
                              CheckpointNotFoundError, latest_step,
                              list_chains, read_manifest, restore_chain,
                              restore_checkpoint, restore_elastic,
                              save_checkpoint, sweep_stale)


def make_state(key, chains=4, d=8):
    ks = jax.random.split(key, 3)
    return {"params": {"w": jax.random.normal(ks[0], (chains, d, d)),
                       "b": jnp.zeros((chains, d))},
            "opt": {"m": jax.random.normal(ks[1], (chains, d, d)),
                    "step": jnp.full((chains,), 7, jnp.int32)}}


def trees_equal(a, b):
    flat_a, flat_b = jax.tree.leaves(a), jax.tree.leaves(b)
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(flat_a, flat_b))


def test_save_restore_roundtrip(tmp_path):
    state = make_state(jax.random.PRNGKey(0))
    save_checkpoint(str(tmp_path), 100, state)
    assert latest_step(str(tmp_path)) == 100
    assert list_chains(str(tmp_path), 100) == [0, 1, 2, 3]
    restored, manifest = restore_checkpoint(str(tmp_path), 100, state)
    assert manifest["step"] == 100
    assert trees_equal(state, restored)


def test_atomicity_no_partial_checkpoint_visible(tmp_path):
    """A crash mid-save must leave no step_* dir behind."""
    state = make_state(jax.random.PRNGKey(1))

    class Boom(RuntimeError):
        pass

    bad = dict(state)
    class Exploding:
        shape = (4, 4)
        def __array__(self):
            raise Boom()
    bad["opt"] = {"m": state["opt"]["m"], "step": state["opt"]["step"],
                  "bomb": Exploding()}
    with pytest.raises(Exception):
        save_checkpoint(str(tmp_path), 5, bad)
    assert latest_step(str(tmp_path)) is None
    assert not any(d.startswith("step_") for d in os.listdir(tmp_path))


def test_elastic_restore_fewer_and_more_chains(tmp_path):
    state = make_state(jax.random.PRNGKey(2), chains=4)
    save_checkpoint(str(tmp_path), 10, state)

    # fewer chains: prefix restore
    small = make_state(jax.random.PRNGKey(3), chains=2)
    restored, info = restore_elastic(str(tmp_path), 10, small,
                                     lambda i: None)
    assert info["restored_chains"] == [0, 1]
    assert trees_equal(jax.tree.map(lambda x: x[:2], state), restored)

    # more chains: fresh init for the newcomers
    big = make_state(jax.random.PRNGKey(4), chains=6)
    fresh = make_state(jax.random.PRNGKey(5), chains=1)
    init_fn = lambda i: jax.tree.map(lambda x: x[0] + i, fresh)
    restored, info = restore_elastic(str(tmp_path), 10, big, init_fn)
    assert info["restored_chains"] == [0, 1, 2, 3]
    assert trees_equal(jax.tree.map(lambda x: x[:4], state),
                       jax.tree.map(lambda x: x[:4], restored))


def test_chain_failure_isolated(tmp_path):
    """Corrupting one chain's file must not affect the others (the fault-
    isolation dividend of the paper's communication-free design)."""
    state = make_state(jax.random.PRNGKey(6), chains=4)
    save_checkpoint(str(tmp_path), 20, state)
    victim = os.path.join(str(tmp_path), "step_00000020", "chain_002.npz")
    with open(victim, "wb") as f:
        f.write(b"corrupted")

    fresh = make_state(jax.random.PRNGKey(7), chains=1)
    init_fn = lambda i: jax.tree.map(lambda x: x[0] * 0 - 1.0, fresh)
    restored, info = restore_elastic(str(tmp_path), 20, state, init_fn)
    assert info["restored_chains"] == [0, 1, 3]
    for i in (0, 1, 3):
        assert trees_equal(jax.tree.map(lambda x: x[i], state),
                           jax.tree.map(lambda x: x[i], restored))
    assert float(restored["params"]["w"][2, 0, 0]) == -1.0


def test_crash_mid_second_save_keeps_previous_step(tmp_path,
                                                   monkeypatch):
    """A crash partway through writing the chain files of a LATER
    checkpoint must leave the previous complete step as latest — the
    crash-consistency contract the supervisor's restart relies on."""
    import repro.checkpoint.store as store
    state = make_state(jax.random.PRNGKey(9))
    save_checkpoint(str(tmp_path), 1, state)

    calls = {"n": 0}
    real_savez = store.np.savez

    def dying_savez(f, **kw):
        calls["n"] += 1
        if calls["n"] == 3:        # die on the 3rd chain of the 2nd save
            raise OSError("disk gone")
        return real_savez(f, **kw)

    monkeypatch.setattr(store.np, "savez", dying_savez)
    with pytest.raises(OSError):
        save_checkpoint(str(tmp_path), 2, state)
    monkeypatch.undo()
    assert latest_step(str(tmp_path)) == 1
    restored, manifest = restore_checkpoint(str(tmp_path), 1, state)
    assert manifest["step"] == 1 and trees_equal(state, restored)
    assert not any(d.startswith(".tmp_") for d in os.listdir(tmp_path))


def test_truncated_chain_file_is_fault_isolated(tmp_path):
    """A torn write (file truncated mid-flush) on ONE chain must behave
    exactly like the corrupt-file case: every other chain restores, the
    victim falls back to init_fn."""
    from repro.testing import truncate_chain_file
    state = make_state(jax.random.PRNGKey(10), chains=4)
    save_checkpoint(str(tmp_path), 30, state)
    truncate_chain_file(str(tmp_path), 30, 1)

    fresh = make_state(jax.random.PRNGKey(11), chains=1)
    init_fn = lambda i: jax.tree.map(lambda x: x[0] * 0 - 2.0, fresh)
    restored, info = restore_elastic(str(tmp_path), 30, state, init_fn)
    assert info["restored_chains"] == [0, 2, 3]
    for i in (0, 2, 3):
        assert trees_equal(jax.tree.map(lambda x: x[i], state),
                           jax.tree.map(lambda x: x[i], restored))
    assert float(restored["params"]["w"][1, 0, 0]) == -2.0
    # the strict single-chain reader refuses the torn file outright
    tmpl = jax.tree.map(lambda x: x[0], state)
    with pytest.raises(Exception):
        restore_chain(str(tmp_path), 30, 1, tmpl)


def test_manifest_step_mismatch_raises(tmp_path):
    """A manifest disagreeing with its directory name means a torn or
    hand-copied checkpoint — restoring it would silently resume from the
    wrong point, so every reader must refuse."""
    state = make_state(jax.random.PRNGKey(12), chains=2)
    save_checkpoint(str(tmp_path), 40, state)
    mpath = os.path.join(str(tmp_path), "step_00000040", "manifest.json")
    with open(mpath) as f:
        manifest = json.load(f)
    manifest["step"] = 39
    with open(mpath, "w") as f:
        json.dump(manifest, f)
    with pytest.raises(ValueError, match="torn or mislabelled"):
        restore_checkpoint(str(tmp_path), 40, state)
    with pytest.raises(ValueError, match="torn or mislabelled"):
        restore_chain(str(tmp_path), 40, 0,
                      jax.tree.map(lambda x: x[0], state))
    with pytest.raises(ValueError, match="torn or mislabelled"):
        restore_elastic(str(tmp_path), 40, state, lambda i: None)


def test_restore_chain_roundtrip(tmp_path):
    """The supervisor's restart path: one chain's slice comes back
    bit-identical without touching any other chain's file."""
    state = make_state(jax.random.PRNGKey(13), chains=4)
    save_checkpoint(str(tmp_path), 50, state)
    tmpl = jax.tree.map(lambda x: x[0], state)
    for c in (0, 3):
        got = restore_chain(str(tmp_path), 50, c, tmpl)
        assert trees_equal(jax.tree.map(lambda x: x[c], state), got)
    with pytest.raises(FileNotFoundError):
        restore_chain(str(tmp_path), 50, 9, tmpl)


def test_manager_gc_keeps_last_k(tmp_path):
    mgr = CheckpointManager(str(tmp_path), interval=1, keep=2)
    state = make_state(jax.random.PRNGKey(8), chains=2)
    for step in range(1, 6):
        mgr.maybe_save(step, state)
    steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert steps == ["step_00000004", "step_00000005"]


# ---------------------------------------------------------------------------
# stale-garbage sweep (satellite: kill-mid-save leaves no orphans forever)
# ---------------------------------------------------------------------------

def test_kill_mid_save_garbage_swept_by_next_save(tmp_path):
    """A kill -9 mid-save (simulated by planting the tmp dir a dead
    writer would leave) must be reclaimed by the next manager GC — the
    old behaviour left `.tmp_*` dirs forever."""
    state = make_state(jax.random.PRNGKey(20), chains=2)
    # a dead process's orphan: not in this process's _ACTIVE_TMP registry
    orphan = os.path.join(str(tmp_path), ".tmp_deadwriter")
    os.makedirs(orphan)
    with open(os.path.join(orphan, "chain_000.npz"), "wb") as f:
        f.write(b"half-written")

    mgr = CheckpointManager(str(tmp_path), interval=1, keep=2)
    assert not os.path.exists(orphan)       # swept on init
    os.makedirs(orphan)                      # dies again mid-run
    mgr.maybe_save(1, state)                 # next save's GC sweeps it
    assert not os.path.exists(orphan)
    assert not any(d.startswith(".tmp_") for d in os.listdir(tmp_path))
    assert latest_step(str(tmp_path)) == 1


def test_sweep_recovers_aside_when_publish_never_happened(tmp_path):
    """Crash in the rename-aside → publish window: the aside dir holds
    the only complete copy of that step; the sweep must rename it BACK
    so the old checkpoint survives."""
    state = make_state(jax.random.PRNGKey(21), chains=2)
    save_checkpoint(str(tmp_path), 3, state)
    final = os.path.join(str(tmp_path), "step_00000003")
    aside = os.path.join(str(tmp_path), ".prev_step_00000003")
    os.replace(final, aside)                # simulate crash mid-window
    assert latest_step(str(tmp_path)) is None
    out = sweep_stale(str(tmp_path))
    assert out["recovered"] == [3]
    assert latest_step(str(tmp_path)) == 3
    restored, _ = restore_checkpoint(str(tmp_path), 3, state)
    assert trees_equal(state, restored)


def test_crash_during_overwrite_keeps_old_version(tmp_path, monkeypatch):
    """Regression for the overwrite crash window: the old code did
    `rmtree(final)` then `os.replace(tmp, final)` — a crash between the
    two lost BOTH versions of the step.  Now a crash at any point in the
    publish leaves either the old or the new version restorable."""
    import repro.checkpoint.store as store
    old = make_state(jax.random.PRNGKey(22), chains=2)
    new = make_state(jax.random.PRNGKey(23), chains=2)
    save_checkpoint(str(tmp_path), 7, old)

    # crash exactly at the publish rename (after old moved aside)
    real_replace = os.replace

    def dying_replace(src, dst):
        if os.path.basename(src).startswith(".tmp_"):
            raise OSError("killed at publish")
        return real_replace(src, dst)

    monkeypatch.setattr(store.os, "replace", dying_replace)
    with pytest.raises(OSError, match="killed at publish"):
        save_checkpoint(str(tmp_path), 7, new)
    monkeypatch.undo()

    # the step is momentarily invisible, but the sweep restores the OLD
    # version — nothing is lost
    sweep_stale(str(tmp_path))
    assert latest_step(str(tmp_path)) == 7
    restored, _ = restore_checkpoint(str(tmp_path), 7, old)
    assert trees_equal(old, restored)

    # and an undisturbed overwrite publishes the NEW version cleanly
    save_checkpoint(str(tmp_path), 7, new)
    restored, _ = restore_checkpoint(str(tmp_path), 7, new)
    assert trees_equal(new, restored)
    assert not any(d.startswith((".tmp_", ".prev_"))
                   for d in os.listdir(tmp_path))


# ---------------------------------------------------------------------------
# typed missing-step error (satellite: no more bare FileNotFoundError)
# ---------------------------------------------------------------------------

def test_missing_step_raises_typed_error_naming_available(tmp_path):
    state = make_state(jax.random.PRNGKey(24), chains=2)
    save_checkpoint(str(tmp_path), 10, state)
    save_checkpoint(str(tmp_path), 20, state)
    for fn in (lambda: list_chains(str(tmp_path), 15),
               lambda: read_manifest(str(tmp_path), 15),
               lambda: restore_checkpoint(str(tmp_path), 15, state),
               lambda: restore_elastic(str(tmp_path), 15, state,
                                       lambda i: None)):
        with pytest.raises(CheckpointNotFoundError) as ei:
            fn()
        assert ei.value.step == 15
        assert ei.value.available_steps == [10, 20]
        assert "15" in str(ei.value) and "[10, 20]" in str(ei.value)
    # still a FileNotFoundError for legacy except clauses
    with pytest.raises(FileNotFoundError):
        read_manifest(str(tmp_path), 15)


def test_missing_manifest_raises_typed_error(tmp_path):
    """A step dir whose manifest vanished (partial rmtree) is as good as
    missing — readers get the same typed error, not a bare ENOENT."""
    state = make_state(jax.random.PRNGKey(25), chains=2)
    save_checkpoint(str(tmp_path), 5, state)
    os.remove(os.path.join(str(tmp_path), "step_00000005", "manifest.json"))
    with pytest.raises(CheckpointNotFoundError):
        read_manifest(str(tmp_path), 5)


# ---------------------------------------------------------------------------
# AsyncCheckpointManager (tentpole: background writer, bounded staleness)
# ---------------------------------------------------------------------------

def test_async_manager_publishes_identical_bits_to_sync(tmp_path):
    state = make_state(jax.random.PRNGKey(26), chains=3)
    sync_dir, async_dir = str(tmp_path / "sync"), str(tmp_path / "async")
    sm = CheckpointManager(sync_dir, interval=1, keep=3)
    am = AsyncCheckpointManager(async_dir, interval=1, keep=3)
    for step in (1, 2, 3):
        sm.maybe_save(step, state)
        am.maybe_save(step, state)
    am.close()
    assert latest_step(async_dir) == latest_step(sync_dir) == 3
    a, _ = restore_checkpoint(async_dir, 3, state)
    s, _ = restore_checkpoint(sync_dir, 3, state)
    assert trees_equal(a, s)


def test_async_manager_bounded_staleness(tmp_path, monkeypatch):
    """With the writer artificially slow, `maybe_save(r)` must block
    until step r-1 is DURABLE before accepting step r — so the published
    frontier never lags the loop by more than one save."""
    import time
    import repro.checkpoint.store as store
    state = make_state(jax.random.PRNGKey(27), chains=2)
    real_save = store.save_checkpoint

    def slow_save(*a, **kw):
        time.sleep(0.15)
        return real_save(*a, **kw)

    am = AsyncCheckpointManager(str(tmp_path), interval=1, keep=5)
    monkeypatch.setattr(store, "save_checkpoint", slow_save)
    try:
        for step in (1, 2, 3, 4):
            am.maybe_save(step, state)
            durable = latest_step(str(tmp_path)) or 0
            assert durable >= step - 1, (
                f"staleness bound violated: accepted step {step} with "
                f"durable frontier at {durable}")
        am.flush()
        assert latest_step(str(tmp_path)) == 4
        assert am.stats["waits"] >= 1       # the bound actually bit
    finally:
        am.close()


def test_async_manager_snapshot_isolated_from_later_mutation(tmp_path):
    """The host snapshot taken at maybe_save time is what gets written,
    even if the caller's buffers are donated/overwritten immediately
    after — the double-buffer contract."""
    state = {"x": np.arange(8, dtype=np.float32).reshape(2, 4)}
    am = AsyncCheckpointManager(str(tmp_path), interval=1, keep=3)
    am.maybe_save(1, state)
    state["x"] += 100.0                     # mutate AFTER enqueue
    am.close()
    tmpl = {"x": jnp.zeros((2, 4), jnp.float32)}
    restored, _ = restore_checkpoint(str(tmp_path), 1, tmpl)
    assert np.array_equal(np.asarray(restored["x"]),
                          np.arange(8, dtype=np.float32).reshape(2, 4))


def test_async_manager_writer_error_surfaces(tmp_path, monkeypatch):
    import repro.checkpoint.store as store
    state = make_state(jax.random.PRNGKey(28), chains=2)
    am = AsyncCheckpointManager(str(tmp_path), interval=1, keep=3)

    def boom(*a, **kw):
        raise OSError("disk full")

    monkeypatch.setattr(store, "save_checkpoint", boom)
    am.maybe_save(1, state)
    with pytest.raises(OSError, match="disk full"):
        am.flush()
    monkeypatch.undo()
    # after the error is surfaced once, the manager is usable again
    am.maybe_save(2, state)
    am.close()
    assert latest_step(str(tmp_path)) == 2
