"""Serving robustness chaos suite (DESIGN.md §Serving-robustness):
admission control + deadlines, serve-time health screening with exact
degraded mode, hot checkpoint reload, and deterministic overload
replay.  Companion to tests/test_slda_serving.py (happy path) and
tests/test_faults.py (training-time chaos): under every fault below the
service must never crash, must shed deterministically with TYPED
outcomes, and must keep surviving chains bit-identical to a clean
service."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import save_checkpoint
from repro.core import SLDAConfig, partition, train_chains
from repro.data import make_slda_corpus
from repro.serving import (InvalidDocument, ServiceConfig,
                           SLDAPredictionService, STATUS_EXPIRED,
                           STATUS_OK, STATUS_SHED_QUEUE, STATUS_SHED_RATE)
from repro.serving.slda_service import _combine_yhat
from repro.testing import (VirtualClock, burst_trace, inject_dispatch_delay,
                           mislabel_manifest, poison_model_table,
                           replay_open_loop, truncate_chain_file)

CFG = SLDAConfig(n_topics=8, vocab_size=64, n_iters=3, n_pred_burnin=2,
                 n_pred_samples=2)
MAXLEN, M, BATCH = 48, 4, 16

_corpus, _ = make_slda_corpus(jax.random.PRNGKey(0), 64, CFG.vocab_size,
                              CFG.n_topics, MAXLEN,
                              doc_len_dist="lognormal", len_sigma=1.0)
MODELS = train_chains(jax.random.PRNGKey(1), partition(_corpus, M), CFG)
MODELS_B = train_chains(jax.random.PRNGKey(7), partition(_corpus, M), CFG)
LENS = np.asarray(_corpus.mask.sum(-1)).astype(int)
TOKS = np.asarray(_corpus.tokens)
DOCS = [TOKS[d, :LENS[d]] for d in range(_corpus.n_docs)]
SVC = ServiceConfig.calibrated(LENS, max_doc_len=MAXLEN, batch_docs=BATCH,
                               n_buckets=3)


def make_service(models=MODELS, **kw):
    clock = kw.pop("clock", None)
    svc = dataclasses.replace(SVC, **kw) if kw else SVC
    return SLDAPredictionService(models, CFG, svc,
                                 key=jax.random.PRNGKey(9), clock=clock)


# ------------------------------------------- admission control + deadlines

def test_queue_bound_sheds_typed():
    """At the `max_pending` cap a new submission resolves to a typed
    STATUS_SHED_QUEUE Result — never an exception, never a silent
    drop — and the queued requests are untouched."""
    svc = make_service(max_pending=BATCH, auto_flush=False,
                       cache_results=False)
    kept = [svc.submit(DOCS[i]) for i in range(BATCH)]
    shed = [svc.submit(DOCS[BATCH + i]) for i in range(3)]
    st = svc.stats()
    assert st["queue_depth"] == BATCH
    assert st["shed_queue_full"] == 3
    for rid in shed:
        r = svc.result(rid)
        assert r.status == STATUS_SHED_QUEUE
        assert np.isnan(r.yhat) and r.yhat_chains is None
        with pytest.raises(ValueError):
            svc.combined(rid)
    svc.drain()
    for rid in kept:
        assert svc.result(rid).status == STATUS_OK


def test_rate_limiter_token_bucket():
    """Token bucket: `rate_burst` requests pass instantly, further ones
    shed STATUS_SHED_RATE until simulated time refills tokens at
    `rate_limit_per_s`."""
    clock = VirtualClock()
    svc = make_service(rate_limit_per_s=1.0, rate_burst=2,
                       auto_flush=False, cache_results=False, clock=clock)
    r0 = svc.submit(DOCS[0])
    r1 = svc.submit(DOCS[1])
    r2 = svc.submit(DOCS[2])                    # bucket empty
    assert svc.result(r2).status == STATUS_SHED_RATE
    assert r0 not in svc._results and r1 not in svc._results  # queued
    clock.advance(1.0)                          # one token refills
    r3 = svc.submit(DOCS[3])
    r4 = svc.submit(DOCS[4])
    assert r3 not in svc._results               # admitted
    assert svc.result(r4).status == STATUS_SHED_RATE
    assert svc.stats()["shed_rate_limit"] == 2


def test_deadline_expiry_sheds_before_dispatch():
    """A request whose deadline lapsed is shed at pack time, BEFORE it
    can occupy a slot: with every request expired the flush runs no
    dispatch at all."""
    clock = VirtualClock()
    svc = make_service(auto_flush=False, cache_results=False, clock=clock)
    rids = [svc.submit(DOCS[i], deadline_s=1.0) for i in range(4)]
    clock.advance(2.0)                          # all deadlines lapse
    svc.flush()
    st = svc.stats()
    assert st["dispatches"] == 0
    assert st["expired"] == 4
    for rid in rids:
        assert svc.result(rid).status == STATUS_EXPIRED


def test_mixed_expired_and_live_flush():
    clock = VirtualClock()
    svc = make_service(auto_flush=False, cache_results=False, clock=clock)
    dead = [svc.submit(DOCS[i], deadline_s=0.5) for i in range(3)]
    live = [svc.submit(DOCS[3 + i]) for i in range(3)]   # no deadline
    clock.advance(1.0)
    svc.flush()
    assert all(svc.result(r).status == STATUS_EXPIRED for r in dead)
    assert all(svc.result(r).status == STATUS_OK for r in live)
    assert svc.stats()["dispatches"] == 1


def test_earliest_deadline_first_packing():
    """When the widest rung oversubscribes, the request with the
    EARLIEST deadline gets a slot even though it was submitted last;
    a deadline-free (FIFO) request rolls over instead."""
    q_last = SVC.slot_quota[-1]
    svc = make_service(auto_flush=False, cache_results=False)
    long_doc = np.arange(MAXLEN, dtype=np.int32) % CFG.vocab_size
    fifo = [svc.submit((long_doc + i) % CFG.vocab_size)
            for i in range(q_last)]
    urgent = svc.submit((long_doc + 63) % CFG.vocab_size, deadline_s=100.0)
    done = svc.flush()
    assert urgent in done                       # EDF won the last slot
    assert fifo[-1] not in done                 # latest FIFO doc rolled
    assert svc.stats()["queue_depth"] == 1
    svc.drain()
    assert svc.result(fifo[-1]).status == STATUS_OK


def test_no_deadlines_reduces_to_fifo():
    """EDF with every deadline +inf must reproduce the original FIFO
    packing — same docs through a robust and a deadline-free service
    give bitwise-identical results."""
    a = make_service(cache_results=False)
    b = make_service(cache_results=False, max_pending=64,
                     default_deadline_s=1e6)
    rids_a = [a.submit(d) for d in DOCS[:24]]
    rids_b = [b.submit(d) for d in DOCS[:24]]
    a.drain(), b.drain()
    for ra, rb in zip(rids_a, rids_b):
        assert a.result(ra).yhat == b.result(rb).yhat
        np.testing.assert_array_equal(a.result(ra).yhat_chains,
                                      b.result(rb).yhat_chains)


def test_drain_deadline_bounds_wall_time():
    """`drain(deadline_s=...)` stops flushing at the bound; the
    remainder STAYS pending (not shed) and a later drain serves it."""
    clock = VirtualClock()
    svc = make_service(auto_flush=False, cache_results=False, clock=clock)
    undo = inject_dispatch_delay(svc, 1.0)      # 1 s per micro-batch
    rids = [svc.submit(DOCS[i % len(DOCS)][: 1 + i % MAXLEN] + 0)
            for i in range(3 * BATCH)]
    svc.drain(deadline_s=1.5)                   # time for 2 flushes only
    st = svc.stats()
    assert st["drain_timeouts"] == 1
    assert st["queue_depth"] == BATCH
    undo()
    svc.drain()
    assert svc.stats()["queue_depth"] == 0
    assert all(svc.result(r).status == STATUS_OK for r in rids)


def test_invalid_document_typed_rejections():
    svc = make_service()
    cases = [
        (np.asarray([], np.int32), "empty_doc"),
        (np.ones((MAXLEN + 1,), np.int32), "doc_too_long"),
        (np.asarray([CFG.vocab_size], np.int32), "bad_token_id"),
        (np.asarray([-1], np.int32), "bad_token_id"),
    ]
    for doc, reason in cases:
        with pytest.raises(InvalidDocument) as ei:
            svc.submit(doc)
        assert ei.value.reason == reason
        assert isinstance(ei.value, ValueError)   # old handlers still work
    assert svc.stats()["rejected_invalid"] == len(cases)
    assert svc.stats()["queue_depth"] == 0        # nothing half-admitted


# --------------------------------------- health screening + degraded mode

def test_poisoned_table_quarantined_at_load_degraded_exact():
    """A chain whose φ̂ table is NaN-poisoned is quarantined when the
    service loads — and the degraded service is EXACT: survivors'
    per-chain values and the combined ŷ are bit-identical to a clean
    service with the same chain manually dropped."""
    bad = make_service(poison_model_table(MODELS, 1, "nan_phi"),
                       cache_results=False)
    st = bad.stats()
    assert st["alive_chains"] == M - 1
    assert st["load_quarantines"] == 1
    assert "nan_phi" in st["chain_health"][1]
    clean = make_service(cache_results=False)
    clean.drop_chain(1)
    rids_a = [bad.submit(d) for d in DOCS[:BATCH]]
    rids_b = [clean.submit(d) for d in DOCS[:BATCH]]
    bad.drain(), clean.drain()
    survivors = [c for c in range(M) if c != 1]
    for ra, rb in zip(rids_a, rids_b):
        a, b = bad.result(ra), clean.result(rb)
        assert a.yhat == b.yhat
        np.testing.assert_array_equal(a.yhat_chains[survivors],
                                      b.yhat_chains[survivors])


@pytest.mark.parametrize("kind", ["nan_eta", "bad_rowsum", "nan_mse"])
def test_model_screen_catches_every_table_fault(kind):
    svc = make_service(poison_model_table(MODELS, 2, kind))
    st = svc.stats()
    assert st["alive_chains"] == M - 1
    assert float(np.asarray(svc.chain_weights)[2]) == 0.0


def test_checks_off_serves_unscreened():
    """robust_checks=False is the A/B baseline: the poisoned chain is
    NOT quarantined (its weight stays 1)."""
    svc = make_service(poison_model_table(MODELS, 1, "nan_phi"),
                       robust_checks=False)
    assert svc.stats()["alive_chains"] == M


def test_dispatch_nan_quarantine_recombines():
    """Silent corruption AFTER load (poison injected past the init
    screen): the first dispatch that produces a non-finite per-chain ŷ
    quarantines the chain and recombines, so the caller sees a finite
    prediction identical to a pre-dropped clean service."""
    svc = make_service(cache_results=False)
    svc.models = poison_model_table(MODELS, 3, "nan_eta")  # post-screen
    clean = make_service(cache_results=False)
    clean.drop_chain(3)
    rids_a = [svc.submit(d) for d in DOCS[:BATCH]]
    rids_b = [clean.submit(d) for d in DOCS[:BATCH]]
    svc.drain(), clean.drain()
    st = svc.stats()
    assert st["dispatch_quarantines"] == 1
    assert "nan_yhat" in st["chain_health"][3]
    assert float(np.asarray(svc.chain_weights)[3]) == 0.0
    for ra, rb in zip(rids_a, rids_b):
        a, b = svc.result(ra), clean.result(rb)
        assert np.isfinite(a.yhat)
        assert a.yhat == b.yhat


def test_all_chains_dead_warns_and_serves_fallback():
    """Every chain dropped: `combined()` follows core.combine's PR 6
    all-dead semantics — unmasked combine + RuntimeWarning — and a
    fresh dispatch under the all-dead mask still serves finite numbers
    instead of crashing or emitting 0/0 NaNs."""
    svc = make_service(cache_results=False)
    rids = [svc.submit(d) for d in DOCS[:BATCH]]
    svc.drain()
    for c in range(M):
        svc.drop_chain(c)
    r = svc.result(rids[0])
    with pytest.warns(RuntimeWarning, match="all-dead"):
        got = svc.combined(rids[0])
    exp = float(_combine_yhat(SVC.combine,
                              jnp.asarray(r.yhat_chains)[:, None],
                              jnp.ones((M,), jnp.float32),
                              MODELS.train_mse)[0])
    assert got == exp
    rids2 = [svc.submit(d) for d in DOCS[BATCH:2 * BATCH]]
    svc.drain()
    for rid in rids2:
        assert np.isfinite(svc.result(rid).yhat)


# ----------------------------------------------------- hot model reload

def test_hot_reload_bumps_epoch_invalidates_cache_no_retrace(tmp_path):
    """The reload protocol end-to-end: swap to a checkpointed model,
    epoch bumps, the (hash, epoch) cache key invalidates every cached
    result WITHOUT a scan, results under the new epoch are bit-equal
    to a fresh service on the new models — and nothing retraces."""
    save_checkpoint(str(tmp_path), 5, MODELS_B)
    svc = make_service()
    [svc.submit(d) for d in DOCS[:BATCH]]
    svc.drain()
    hit = svc.submit(DOCS[0])
    assert svc.result(hit).from_cache            # cache warm, epoch 0
    traces = svc.stats()["traces"]
    rep = svc.reload_from_checkpoint(str(tmp_path))
    assert rep["ok"] and rep["epoch"] == 1 and rep["ckpt_step"] == 5
    miss = svc.submit(DOCS[0])                   # same bytes, new epoch
    svc.drain()
    r = svc.result(miss)
    assert not r.from_cache                      # stale epoch never served
    fresh = make_service(MODELS_B)
    fresh._batches = svc._batches - 1            # align the PRNG stream
    rid = fresh.submit(DOCS[0])
    fresh.drain()
    assert r.yhat == fresh.result(rid).yhat
    st = svc.stats()
    assert st["traces"] == traces                # swap never retraces
    assert st["model_epoch"] == 1 and st["reloads_ok"] == 1


def test_torn_reload_rejected_old_epoch_keeps_serving(tmp_path):
    """A torn checkpoint (truncated chain file) must REJECT the reload:
    old models keep serving under the old epoch, the warm cache stays
    valid, and repeat traffic is bit-identical to before the attempt."""
    save_checkpoint(str(tmp_path), 3, MODELS_B)
    truncate_chain_file(str(tmp_path), 3, 1)
    svc = make_service()
    rid0 = [svc.submit(d) for d in DOCS[:BATCH]][0]
    y0 = svc.result(rid0).yhat
    rep = svc.reload_from_checkpoint(str(tmp_path))
    assert not rep["ok"] and rep["epoch"] == 0
    st = svc.stats()
    assert st["reloads_rejected"] == 1 and st["model_epoch"] == 0
    again = svc.submit(DOCS[0])
    assert svc.result(again).from_cache          # cache NOT invalidated
    assert svc.result(again).yhat == y0


def test_mislabelled_manifest_rejected(tmp_path):
    save_checkpoint(str(tmp_path), 4, MODELS_B)
    mislabel_manifest(str(tmp_path), 4, 99)
    svc = make_service()
    rep = svc.reload_from_checkpoint(str(tmp_path), step=4)
    assert not rep["ok"] and "mislabelled" in rep["reason"]


def test_chain_count_mismatch_rejected(tmp_path):
    half = jax.tree.map(lambda x: x[: M // 2], MODELS_B)
    save_checkpoint(str(tmp_path), 1, half)
    svc = make_service()
    rep = svc.reload_from_checkpoint(str(tmp_path))
    assert not rep["ok"] and "chains" in rep["reason"]
    assert svc.stats()["model_epoch"] == 0


def test_missing_checkpoint_rejected(tmp_path):
    svc = make_service()
    rep = svc.reload_from_checkpoint(str(tmp_path))
    assert not rep["ok"] and "no checkpoint" in rep["reason"]


def test_reload_quarantines_unhealthy_chains(tmp_path):
    """A checkpoint with one poisoned chain still swaps in — degraded:
    the bad chain is quarantined at screen time, survivors serve."""
    save_checkpoint(str(tmp_path), 2,
                    poison_model_table(MODELS_B, 0, "bad_rowsum"))
    svc = make_service()
    rep = svc.reload_from_checkpoint(str(tmp_path))
    assert rep["ok"] and rep["quarantined_chains"] == [0]
    st = svc.stats()
    assert st["alive_chains"] == M - 1
    rid = svc.submit(DOCS[0])
    svc.drain()
    assert np.isfinite(svc.result(rid).yhat)


def test_reload_all_chains_unhealthy_rejected(tmp_path):
    bad = MODELS_B
    for c in range(M):
        bad = poison_model_table(bad, c, "nan_phi")
    save_checkpoint(str(tmp_path), 6, bad)
    svc = make_service()
    rep = svc.reload_from_checkpoint(str(tmp_path))
    assert not rep["ok"] and rep["reason"] == "all_chains_unhealthy"
    rid = svc.submit(DOCS[0])
    svc.drain()
    assert np.isfinite(svc.result(rid).yhat)     # old model still serves


# ------------------------------------------------ deterministic overload

def test_burst_overload_admission_bounds_latency():
    """Open-loop burst replay under a virtual clock (zero real
    sleeping, bit-reproducible): WITH admission control + deadlines
    the served p99 stays bounded near the deadline and overload is
    shed; WITHOUT, every request is eventually served but tail latency
    blows past the bound."""
    d = 0.5                                      # seconds per dispatch
    deadline = 2.0
    trace = burst_trace(0, CFG.vocab_size, MAXLEN, base_rate=16.0,
                        burst_rate=320.0, n_steady=24, n_burst=128,
                        n_tail=24)

    def run(**kw):
        clock = VirtualClock()
        svc = make_service(auto_flush=False, cache_results=False,
                           clock=clock, **kw)
        inject_dispatch_delay(svc, d)
        replay_open_loop(svc, trace, clock)
        lat = [r.latency_s for r in svc._results.values()
               if r.status == STATUS_OK]
        shed = sum(1 for r in svc._results.values()
                   if r.status != STATUS_OK)
        return np.percentile(lat, 99), shed / len(svc._results), svc

    p99_admit, shed_admit, svc_a = run(max_pending=2 * BATCH,
                                       default_deadline_s=deadline)
    p99_open, shed_open, _ = run()
    assert shed_open == 0.0                      # baseline serves all …
    assert p99_open > p99_admit                  # … but with a worse tail
    assert p99_admit <= deadline + 2 * d         # bounded by policy
    assert shed_admit > 0.0                      # overload went somewhere
    st = svc_a.stats()
    assert st["expired"] + st["shed_queue_full"] > 0


def test_burst_replay_is_deterministic():
    trace = burst_trace(3, CFG.vocab_size, MAXLEN, base_rate=8.0,
                        burst_rate=64.0, n_steady=8, n_burst=32, n_tail=8)
    outs = []
    for _ in range(2):
        clock = VirtualClock()
        svc = make_service(auto_flush=False, cache_results=False,
                           clock=clock, max_pending=BATCH,
                           default_deadline_s=1.0)
        inject_dispatch_delay(svc, 0.25)
        replay_open_loop(svc, trace, clock)
        outs.append({rid: (r.status, r.yhat) for rid, r in
                     svc._results.items()})
    assert outs[0].keys() == outs[1].keys()
    for rid in outs[0]:
        s0, y0 = outs[0][rid]
        s1, y1 = outs[1][rid]
        assert s0 == s1
        assert (y0 == y1) or (np.isnan(y0) and np.isnan(y1))


# ------------------------------------------------------- observability

def test_stats_surface_robustness_counters():
    svc = make_service()
    st = svc.stats()
    for key in ("queue_depth", "shed_queue_full", "shed_rate_limit",
                "expired", "rejected_invalid", "dispatch_quarantines",
                "load_quarantines", "reloads_ok", "reloads_rejected",
                "model_epoch", "ckpt_step", "alive_chains",
                "chain_health", "drain_timeouts"):
        assert key in st
    assert st["model_epoch"] == 0 and st["alive_chains"] == M
    assert len(st["chain_health"]) == M
    assert all(h == [] for h in st["chain_health"])


def test_describe_reports_robustness_policy():
    svc = make_service(max_pending=32, default_deadline_s=0.5,
                       rate_limit_per_s=100.0)
    rob = svc.describe()["robustness"]
    assert rob["max_pending"] == 32
    assert rob["default_deadline_s"] == 0.5
    assert rob["rate_limit_per_s"] == 100.0
    assert rob["robust_checks"] is True
    assert "earliest-deadline" in rob["scheduling"]
