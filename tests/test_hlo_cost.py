"""Validation of the loop-aware HLO cost model against hand-computed
ground truth (this model is the §Roofline source, so it gets its own
tests)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_cost import HloCost, parse_module


def _cost(fn, *args):
    txt = jax.jit(fn).lower(*args).compile().as_text()
    return HloCost(txt).total()


def test_plain_matmul_flops_exact():
    a = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    b = jax.ShapeDtypeStruct((256, 64), jnp.float32)
    t = _cost(lambda a, b: a @ b, a, b)
    assert t.flops == 2 * 128 * 256 * 64


def test_scanned_matmul_flops_loop_expanded():
    def f(x, w):
        def body(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, None, length=8)
        return y

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    t = _cost(f, x, w)
    assert t.flops == 8 * 2 * 64 ** 3
    assert t.unknown_trip_loops == 0


def test_nested_scan_multiplies_trips():
    def f(x, w):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w, None
            c, _ = jax.lax.scan(inner, c, None, length=3)
            return c, None
        y, _ = jax.lax.scan(outer, x, None, length=4)
        return y

    x = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    w = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    t = _cost(f, x, w)
    assert t.flops == 12 * 2 * 32 ** 3


def test_batched_dot_counts_batch_dims():
    a = jax.ShapeDtypeStruct((4, 16, 32), jnp.float32)
    b = jax.ShapeDtypeStruct((4, 32, 8), jnp.float32)
    t = _cost(lambda a, b: jnp.einsum("bik,bkj->bij", a, b), a, b)
    assert t.flops == 2 * 4 * 16 * 32 * 8


def test_comment_stripping_in_big_tuples():
    """Loop states with >5 elements get /*index=N*/ comments in the HLO;
    parsing must survive them (regression: arctic train once cost 0 flops)."""
    def f(a, b, c, d, e, g, w):
        def body(carry, _):
            a, b, c, d, e, g = carry
            return (a @ w, b + 1, c, d, e, g), None
        (a, *_), _ = jax.lax.scan(body, (a, b, c, d, e, g), None, length=5)
        return a

    s = jax.ShapeDtypeStruct((16, 16), jnp.float32)
    t = _cost(f, s, s, s, s, s, s, s)
    assert t.flops == 5 * 2 * 16 ** 3


def test_hbm_includes_elementwise_traffic():
    a = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)
    t = _cost(lambda a: a * 2 + 1, a)
    # at least one read + one write of 4 MB
    assert t.hbm_bytes >= 2 * 4 * 1024 * 1024
