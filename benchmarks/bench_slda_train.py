"""Before/after wall-clock for the fused multi-sweep TRAIN path (ISSUE 2).

Measures `train_chain` — the training half of every chain's wall-clock —
with the stochastic-EM loop wired to

  * the PR 1 BASELINE (reconstructed below verbatim: per-sweep threefry
    uniforms, one vmap'd `_doc_sweep` + one dense-delta count refresh +
    one η solve per sweep), and
  * the fused path (`kernels.ops.slda_train_sweeps` via
    `SLDAConfig.sweeps_per_launch`: k sweeps per launch, counter-hash
    PRNG, block-local in-launch delayed counts, compacted global deltas
    between launches, η solve per launch),

sweeping `sweeps_per_launch` and `count_rebuild_every` to pick tuned
defaults.  Both sides run back-to-back in one process (this container
shows ~2× cross-run wall-clock swings) as distinct function objects (jit
caches by callable identity — static-arg cfg differences are safe, module
monkey-patching is not).  Writes BENCH_slda_train.json with the
methodology embedded.

Run:  PYTHONPATH=src python -m benchmarks.bench_slda_train [--scale 1.0]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import platform
import time

import jax
import jax.numpy as jnp

from repro.core import SLDAConfig, train_chain
from repro.core.gibbs import _doc_sweep, init_state, phi_hat, zbar
from repro.core.regression import solve_eta
from repro.core.types import (Corpus, GibbsState, SLDAModel,
                              apply_count_deltas, counts_from_assignments)
from repro.data import make_slda_corpus


# --------------------------------------------------------- PR 1 baseline
# Verbatim reconstruction of the pre-fusion train_chain (PR 1 commit),
# kept here so the "before" column stays measurable after the rewrite:
# one vmap'd document-parallel sweep per EM iteration, threefry uniforms
# materialized per sweep, DENSE-delta incremental refresh with the
# periodic exact rebuild, and an η solve per sweep.

def train_chain_pr1(key, corpus: Corpus, cfg: SLDAConfig):
    k_init, k_sweeps = jax.random.split(key)
    state0 = init_state(k_init, corpus, cfg)
    inv_len = 1.0 / jnp.maximum(corpus.lengths(), 1.0)
    every = cfg.count_rebuild_every

    def em_step(state, inp):
        k, it = inp
        uniforms = jax.random.uniform(k, corpus.tokens.shape)
        z, ndt = jax.vmap(
            _doc_sweep,
            in_axes=(0, 0, 0, 0, 0, 0, 0, None, None, None, None, None)
        )(corpus.tokens, corpus.mask, uniforms, state.z, state.ndt,
          corpus.y, inv_len, state.ntw, state.nt, state.eta, cfg, True)

        def rebuild(_):
            return counts_from_assignments(corpus.tokens, corpus.mask, z,
                                           cfg.n_topics, cfg.vocab_size)

        def incremental(_):
            ntw, nt = apply_count_deltas(state.ntw, state.nt, corpus.tokens,
                                         corpus.mask, state.z, z, cap=0)
            return ndt, ntw, nt

        rebuild_now = (it % every == 0) if every > 0 else False
        if isinstance(rebuild_now, bool):
            ndt, ntw, nt = rebuild(None) if rebuild_now else incremental(None)
        else:
            ndt, ntw, nt = jax.lax.cond(rebuild_now, rebuild, incremental,
                                        None)
        state = GibbsState(z=z, ndt=ndt, ntw=ntw, nt=nt, eta=state.eta)
        eta = solve_eta(zbar(state, corpus), corpus.y, cfg)
        return GibbsState(z, ndt, ntw, nt, eta), None

    state, _ = jax.lax.scan(
        em_step, state0, (jax.random.split(k_sweeps, cfg.n_iters),
                          jnp.arange(cfg.n_iters)))
    yhat_tr = zbar(state, corpus) @ state.eta
    mse = jnp.mean((yhat_tr - corpus.y) ** 2)
    acc = jnp.mean(((yhat_tr > 0.5) == (corpus.y > 0.5)).astype(jnp.float32))
    return state, SLDAModel(phi=phi_hat(state, cfg), eta=state.eta,
                            train_mse=mse, train_acc=acc)


# ------------------------------------------------------------- harness

def _timed_round_robin(fns, args, reps):
    """Time every fn min-of-`reps`, INTERLEAVED round-robin.

    This container shows ~2x wall-clock interference swings on a scale of
    minutes; measuring config A's reps and then config B's reps bakes
    that drift into the comparison.  Interleaving exposes every config to
    the same load profile, and the per-config minimum is the estimator
    least contaminated by interference spikes.
    """
    outs = []
    for fn in fns:                       # warm-up (compile excluded)
        outs.append(fn(*args))
        jax.block_until_ready(outs[-1])
    best = [float("inf")] * len(fns)
    for _ in range(reps):
        for i, fn in enumerate(fns):
            t0 = time.time()
            out = fn(*args)
            jax.block_until_ready(out)
            best[i] = min(best[i], time.time() - t0)
    return best, outs


def run(scale: float = 1.0, reps: int = 5):
    """Returns the result dict (also what lands in the JSON)."""
    d = max(int(256 * scale) // 8 * 8, 16)
    # n_iters stays at the SLDAConfig default (60): the fused path's win
    # scales with the per-sweep refresh cost it amortizes, and the η-solve
    # cadence quality cost shrinks as total solves grow
    base = SLDAConfig(n_topics=32, vocab_size=1000, rho=0.25)
    corpus, _ = make_slda_corpus(jax.random.PRNGKey(0), d, 1000, 32, 64,
                                 rho=0.25)
    key = jax.random.PRNGKey(7)
    jit_train = jax.jit(train_chain, static_argnums=(2,))

    # static grid, all measured interleaved: sweeps_per_launch at the
    # default rebuild cadence, plus the rebuild cadence at spl=8 (cadence
    # is counted in launches and is perf-only — both refresh forms exact)
    points = ([(spl, base.count_rebuild_every) for spl in (1, 2, 4, 8)]
              + [(8, every) for every in (1, 4, 0)])
    cfgs = [dataclasses.replace(base, sweeps_per_launch=spl,
                                count_rebuild_every=every)
            for spl, every in points]
    fns = [jax.jit(train_chain_pr1, static_argnums=(2,))] + [
        (lambda c: lambda k, corp, _=None: jit_train(k, corp, c))(cfg)
        for cfg in cfgs]
    times, outs = _timed_round_robin(fns, (key, corpus, base), reps=reps)

    # quality probe: train MSE averaged over extra seeds — the per-seed
    # spread (~20%) swamps any single-seed comparison across configs
    probe_keys = [jax.random.PRNGKey(s) for s in (17, 18)]
    def mean_mse(fn, first):
        mses = [first] + [float(fn(k, corpus, base)[1].train_mse)
                          for k in probe_keys]
        return sum(mses) / len(mses)

    results = {"train_chain_pr1_baseline_s": round(times[0], 4),
               "train_mse_pr1": round(
                   mean_mse(fns[0], float(outs[0][1].train_mse)), 4)}
    grid = [{"sweeps_per_launch": spl, "count_rebuild_every": every,
             "seconds": round(t, 4),
             "train_mse": round(
                 mean_mse(fn, float(out[1].train_mse)), 4)}
            for (spl, every), t, out, fn in zip(points, times[1:],
                                                outs[1:], fns[1:])]

    # tuned = fastest spl>1 point whose mean fit stays within 15% of the
    # spl=1 run — fusing η solves out too far trades model quality for
    # launches, which speed alone would mis-pick
    mse1 = next(r["train_mse"] for r in grid if r["sweeps_per_launch"] == 1)
    ok = [r for r in grid if r["sweeps_per_launch"] > 1
          and r["train_mse"] <= 1.15 * mse1]
    tuned = min(ok or grid, key=lambda r: r["seconds"])
    results["train_chain_fused_s"] = tuned["seconds"]
    results["train_chain_speedup"] = round(times[0] / tuned["seconds"], 2)
    results["tuned_defaults"] = {
        "sweeps_per_launch": tuned["sweeps_per_launch"],
        "count_rebuild_every": tuned["count_rebuild_every"],
        "train_doc_block": base.train_doc_block}
    results["train_mse_fused"] = tuned["train_mse"]

    return {
        "benchmark": "slda_train fused multi-sweep path (ISSUE 2)",
        "methodology": (
            f"train_chain ({base.n_iters} EM sweeps, supervised) on a "
            f"synthetic sLDA corpus [D={d}, W=1000, T=32, N=64]; the "
            "baseline row reconstructs the PR 1 implementation verbatim "
            "(per-sweep threefry uniforms, vmap'd _doc_sweep, dense-delta "
            "refresh w/ rebuild-every-16, eta solve per sweep); fused rows "
            "route through ops.slda_train_sweeps via "
            "SLDAConfig.sweeps_per_launch (total sweeps held fixed at "
            "n_iters; eta solves once per launch).  Tuned = fastest spl>1 "
            "whose train MSE, averaged over 3 seeds (per-seed spread "
            "~20%), stays within 15% of the spl=1 run (spl trades "
            "eta-solve cadence for launches).  All rows jit-compiled "
            f"distinct-static-config, warm-up excluded, MIN of {reps} "
            "INTERLEAVED round-robin reps in ONE process (this container "
            "shows ~2x wall-clock interference drift on the scale of "
            "minutes; interleaving exposes every config to the same load "
            "and the min discards the spikes); jnp fast path "
            f"(use_pallas=False) on {jax.default_backend()}."),
        "platform": {"backend": jax.default_backend(),
                     "machine": platform.machine(),
                     "jax": jax.__version__},
        "shapes": {"d": d, "vocab": 1000, "n_topics": 32, "doc_len": 64,
                   "n_iters": base.n_iters,
                   "train_doc_block": base.train_doc_block},
        "grid": grid,
        "results": results,
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=1.0,
                    help="corpus-size multiplier")
    ap.add_argument("--reps", type=int, default=5)
    ap.add_argument("--out", default="BENCH_slda_train.json")
    args = ap.parse_args(argv)
    payload = run(scale=args.scale, reps=args.reps)
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    r = payload["results"]
    print(f"train-chain: pr1 {r['train_chain_pr1_baseline_s']}s → fused "
          f"{r['train_chain_fused_s']}s ({r['train_chain_speedup']}x) at "
          f"{r['tuned_defaults']}; wrote {args.out}")


if __name__ == "__main__":
    main()
