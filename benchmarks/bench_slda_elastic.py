"""Elastic ensemble runtime: checkpoint overhead + exact-recovery guards
(ISSUE 9).

Three questions, one artifact:

  1. **Async checkpoint overhead** — what does crash consistency cost on
     the training path?  The elastic runner at M chains, three rows:
     no checkpointing, synchronous `CheckpointManager` (the EM loop
     blocks on np.savez + fsync every round), and
     `AsyncCheckpointManager` (host snapshot at the boundary, background
     publish overlapping the next round's compute).  The acceptance bar:
     async overhead vs sync ≤5% of EM-round time — in practice async
     should be FASTER than sync, since the only on-loop cost left is the
     device_get snapshot.

  2. **Exact elasticity** — the paper's placement-invariance dividend,
     asserted bitwise: kill one device mid-training and the survivors'
     final state equals the undisturbed run's same lanes bit-for-bit;
     preempt + resume loses at most one EM round and ends bitwise-equal
     to never preempting; and the repack causes zero steady-state
     retraces (the supervisor's trace counter stays at 1 — placement is
     host metadata outside every jit cache key).

  3. **Degraded quality** — lose a device with NO checkpoint directory
     (quarantine-only recovery) and combine the survivors; the 3-seed
     mean test MSE guard band is the BENCH_slda_robust one (degraded ≤
     1.25× full ensemble).

Timing reuses ONE runner instance per row across reps (per-instance jit
cache — fresh instances would re-trace inside the timed window), all
rows INTERLEAVED round-robin min-of-reps in one process (this container
shows ~2× cross-run wall-clock swings; the min discards interference
spikes).  Writes BENCH_slda_elastic.json.

Run:  PYTHONPATH=src python -m benchmarks.bench_slda_elastic [--quick]
"""
from __future__ import annotations

import argparse
import json
import platform
import shutil
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.plan import build_schedule
from repro.core.types import SLDAConfig, partition
from repro.data import make_slda_corpus, train_test_split
from repro.launch.elastic import (ElasticConfig, ElasticRunner,
                                  elastic_run_average)
from repro.testing import ElasticEvent


def _timed_round_robin(fns, reps):
    """min-of-`reps`, INTERLEAVED round-robin (see module docstring)."""
    for fn in fns:                       # warm-up (compile excluded)
        jax.block_until_ready(fn())
    best = [float("inf")] * len(fns)
    for _ in range(reps):
        for i, fn in enumerate(fns):
            t0 = time.time()
            out = fn()
            jax.block_until_ready(out)
            best[i] = min(best[i], time.time() - t0)
    return best


def _leaves_equal(a, b, idx=None):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        x, y = np.asarray(x), np.asarray(y)
        if idx is not None:
            x, y = x[idx], y[idx]
        if not np.array_equal(x, y):
            return False
    return True


def run(quick: bool = False, reps: int = 3):
    if quick:   # harness smoke for CI — tiny shapes, one rep
        d_tr, d_te, w, t, n, iters, spl, m = 64, 32, 128, 8, 16, 6, 3, 4
        r_iters, ndev, reps, probe_seeds = 2, 2, 1, ()
    else:
        d_tr, d_te, w, t, n, iters, spl, m = 320, 192, 1000, 32, 64, 60, \
            8, 8
        r_iters, ndev, probe_seeds = 10, 4, (17, 18)
    cfg = SLDAConfig(n_topics=t, vocab_size=w, rho=0.25, n_iters=iters,
                     sweeps_per_launch=spl)
    corpus, _ = make_slda_corpus(jax.random.PRNGKey(0), d_tr + d_te, w, t,
                                 n, rho=0.25)
    train, test = train_test_split(corpus, d_tr)
    shards = build_schedule(partition(train, m), cfg)
    root = jax.random.PRNGKey(7)
    n_rounds = iters // r_iters
    work = tempfile.mkdtemp(prefix="bench_elastic_")

    def make_runner(async_ckpt=None, subdir=None, events=()):
        el = ElasticConfig(round_iters=r_iters,
                           async_ckpt=bool(async_ckpt))
        ckpt = None if subdir is None else f"{work}/{subdir}"
        return ElasticRunner(shards, cfg, devices=ndev, elastic=el,
                             ckpt_dir=ckpt, events=list(events))

    # ---- timed rows: checkpoint policy cost (no events anywhere) -----
    run_none = make_runner()
    run_sync = make_runner(async_ckpt=False, subdir="sync")
    run_async = make_runner(async_ckpt=True, subdir="async")
    rows = ["elastic_no_ckpt", "elastic_sync_ckpt", "elastic_async_ckpt"]
    fns = [lambda: run_none.train(root)[0].eta,
           lambda: run_sync.train(root)[0].eta,
           lambda: run_async.train(root)[0].eta]
    times = _timed_round_robin(fns, reps=reps)
    sec = dict(zip(rows, times))
    grid = [{"row": r, "chains": m, "rounds": n_rounds,
             "seconds": round(s, 4)} for r, s in zip(rows, times)]

    # ---- exact-recovery probes (single-shot, not timed) ---------------
    state0, _, rep0 = make_runner().train(root)

    # kill one device mid-training, no checkpoints → quarantine-only;
    # survivors must be bit-identical to the undisturbed run
    loss_ev = [ElasticEvent("device_loss", at_round=n_rounds // 2,
                            device=ndev - 1)]
    kill_runner = make_runner(events=loss_ev)
    state_k, _, rep_k = kill_runner.train(root)
    survivors = np.nonzero(rep_k.alive)[0]
    kill_bitwise = _leaves_equal(state_k, state0, idx=survivors)
    zero_retrace = (rep_k.round_traces == 1)

    # preempt at the penultimate round, resume — bitwise + ≤1 round lost
    pre_ev = [ElasticEvent("preempt", at_round=max(n_rounds - 1, 1))]
    pre_runner = make_runner(async_ckpt=True, subdir="preempt",
                             events=pre_ev)
    _, _, rep_pre = pre_runner.train(root)
    res_runner = make_runner(async_ckpt=True, subdir="preempt")
    state_r, _, rep_res = res_runner.train(root, resume=True)
    resume_bitwise = _leaves_equal(state_r, state0)
    # rounds the resumed run had to RE-do: completed before the preempt
    # but not durable at the resume point (the drain makes this 0; a
    # hard kill without drain would make it ≤1 = the staleness bound)
    rounds_lost = rep_pre.wall_rounds - (rep_res.resume_round or 0)

    # ---- quality probes: multi-seed mean test MSE, full vs degraded --
    def mean_mse(events):
        tot, alive = 0.0, None
        for s in (7,) + probe_seeds:
            y, rep = elastic_run_average(
                jax.random.PRNGKey(s), train, test, cfg, m, devices=ndev,
                rule="weighted",
                elastic=ElasticConfig(round_iters=r_iters),
                events=list(events))
            tot += float(jnp.mean((y - test.y) ** 2))
            alive = rep.alive
        return tot / (1 + len(probe_seeds)), alive

    mse_full, alive_full = mean_mse(())
    mse_deg, alive_deg = mean_mse(loss_ev)
    n_seeds = 1 + len(probe_seeds)

    shutil.rmtree(work, ignore_errors=True)
    async_vs_sync = sec["elastic_async_ckpt"] / sec["elastic_sync_ckpt"] \
        - 1.0
    round_s = sec["elastic_no_ckpt"] / n_rounds
    async_overhead_per_round = (sec["elastic_async_ckpt"]
                                - sec["elastic_no_ckpt"]) / n_rounds
    results = {
        "no_ckpt_s": round(sec["elastic_no_ckpt"], 4),
        "sync_ckpt_s": round(sec["elastic_sync_ckpt"], 4),
        "async_ckpt_s": round(sec["elastic_async_ckpt"], 4),
        "em_round_s": round(round_s, 4),
        "async_vs_sync_frac": round(async_vs_sync, 4),
        "async_ckpt_overhead_ok": bool(async_vs_sync <= 0.05),
        "async_overhead_per_round_s": round(async_overhead_per_round, 4),
        "async_overhead_frac_of_round": round(
            async_overhead_per_round / round_s, 4) if round_s else None,
        "kill_device_survivors_bitwise_ok": bool(kill_bitwise),
        "chains_survived": int(len(survivors)),
        "zero_retraces_across_repack_ok": bool(zero_retrace),
        "preempt_resume_bitwise_ok": bool(resume_bitwise),
        "preempt_rounds_lost": int(rounds_lost),
        "preempt_rounds_lost_ok": bool(rounds_lost <= 1),
        "chains_full": int(sum(alive_full)),
        "chains_degraded": int(sum(alive_deg)),
        "test_mse_full_mean": round(mse_full, 4),
        "test_mse_degraded_mean": round(mse_deg, 4),
        "mse_seeds": n_seeds,
        "degraded_mse_guard_ok": bool(mse_deg <= 1.25 * mse_full),
    }

    return {
        "benchmark": "elastic preemption-tolerant ensemble (ISSUE 9)",
        "methodology": (
            f"Elastic runner at M={m} over a {ndev}-device simulated "
            f"pool, synthetic sLDA corpus [D_train={d_tr}, D_test={d_te},"
            f" W={w}, T={t}, N={n}], {iters} EM sweeps in "
            f"{n_rounds} rounds of {r_iters} (sweeps_per_launch={spl}).  "
            "The three timed rows run the IDENTICAL training loop and "
            "differ only in checkpoint policy: none, synchronous "
            "save-per-round (np.savez + fsync on the loop), async "
            "(boundary host snapshot + background atomic publish with "
            "the ≤1-round bounded-staleness wait).  Guard: async vs "
            "sync ≤ +5%.  Recovery probes (untimed): device loss at "
            f"round {n_rounds // 2} with no checkpoints must leave "
            "survivors bitwise-equal to the undisturbed run and retrace "
            "nothing on repack (supervisor trace counter == 1); preempt "
            "at the penultimate round + resume from the drained "
            "checkpoint must lose ≤1 EM round and end bitwise-equal to "
            f"never preempting.  Quality: {n_seeds}-seed-mean weighted-"
            "average test MSE of the quarantined-survivor ensemble must "
            "stay within 1.25x of the full ensemble (chain drop is "
            "EXACT under communication freedom).  One runner per timed "
            "row reused across reps (per-instance jit cache); MIN of "
            f"{reps} INTERLEAVED round-robin reps in ONE process; jnp "
            f"fast paths (use_pallas=False) on {jax.default_backend()}."),
        "platform": {"backend": jax.default_backend(),
                     "machine": platform.machine(),
                     "jax": jax.__version__},
        "shapes": {"d_train": d_tr, "d_test": d_te, "vocab": w,
                   "n_topics": t, "doc_len": n, "n_iters": iters,
                   "sweeps_per_launch": spl, "chains": m,
                   "round_iters": r_iters, "rounds": n_rounds,
                   "devices": ndev},
        "grid": grid,
        "results": results,
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="tiny-shape harness smoke (CI); writes to --out")
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--out", default=None,
                    help="output JSON (default BENCH_slda_elastic.json, "
                         "or /tmp/BENCH_slda_elastic_quick.json with "
                         "--quick)")
    args = ap.parse_args(argv)
    out = args.out or ("/tmp/BENCH_slda_elastic_quick.json" if args.quick
                       else "BENCH_slda_elastic.json")
    payload = run(quick=args.quick, reps=args.reps)
    with open(out, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    r = payload["results"]
    print(f"ckpt: none {r['no_ckpt_s']}s, sync {r['sync_ckpt_s']}s, "
          f"async {r['async_ckpt_s']}s (async vs sync "
          f"{r['async_vs_sync_frac'] * 100:+.1f}%, ok="
          f"{r['async_ckpt_overhead_ok']}); kill-device bitwise="
          f"{r['kill_device_survivors_bitwise_ok']} retrace0="
          f"{r['zero_retraces_across_repack_ok']}; resume bitwise="
          f"{r['preempt_resume_bitwise_ok']} lost="
          f"{r['preempt_rounds_lost']}; degraded mse "
          f"{r['test_mse_full_mean']} -> {r['test_mse_degraded_mean']} "
          f"(guard_ok={r['degraded_mse_guard_ok']}); wrote {out}")


if __name__ == "__main__":
    main()
