"""Paper Figure 7 — IMDB movie reviews / sentiment (binary label).

Same four-algorithm comparison as Figure 6 but with the binary-label
variant: the corpus follows the paper's IMDB setup (25k labeled reviews,
20k train / 5k test, binary sentiment = thresholded latent response) and
the metric is test-set prediction accuracy; Weighted Average weights by
training ACCURACY (Section III-C(d)).  `scale` shrinks for CI.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import SLDAConfig, ALGORITHMS
from repro.data import make_slda_corpus, train_test_split

M = 4


def run(scale: float = 0.02, n_topics: int = 16, n_iters: int = 30,
        seed: int = 1):
    n_docs = max(100, int(25000 * scale) // 10 * 10)
    vocab = max(200, int(8000 * scale * 2))
    n_train = int(n_docs * 0.8) // M * M
    doc_len = max(40, int(150 * min(1.0, scale * 20)))

    cfg = SLDAConfig(n_topics=n_topics, vocab_size=vocab, rho=0.25,
                     n_iters=n_iters, label_type="binary")
    key = jax.random.PRNGKey(seed)
    # heavy-tailed log-normal lengths, like real IMDB reviews (doc_len is
    # the max); padding_frac reported per row — see fig6_mdna.py
    corpus, _ = make_slda_corpus(key, n_docs, vocab, n_topics, doc_len,
                                 rho=0.25, label_type="binary",
                                 doc_len_dist="lognormal")
    train, test = train_test_split(corpus, n_train)
    padding_frac = round(1.0 - float(corpus.mask.mean()), 4)

    rows = []
    for name in ("nonparallel", "naive", "simple", "weighted"):
        fn = ALGORITHMS[name]
        if name == "nonparallel":
            jfn = jax.jit(fn, static_argnums=(3,))
            args = (jax.random.PRNGKey(seed + 1), train, test, cfg)
        else:
            jfn = jax.jit(fn, static_argnums=(3, 4))
            args = (jax.random.PRNGKey(seed + 1), train, test, cfg, M)
        yhat = jfn(*args)
        yhat.block_until_ready()
        t0 = time.time()
        yhat = jfn(*args)
        yhat.block_until_ready()
        wall = time.time() - t0
        modeled = wall if name == "nonparallel" else wall / M
        acc = float(jnp.mean(((yhat > 0.5) == (test.y > 0.5))
                             .astype(jnp.float32)))
        rows.append(dict(algorithm=name, wall_s=round(wall, 3),
                         modeled_s=round(modeled, 3),
                         test_acc=round(acc, 4),
                         padding_frac=padding_frac))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
