"""Kernel microbenchmarks: Pallas (interpret) correctness-path timing vs the
pure-jnp oracle, plus the jnp paths that matter for the training loop.

On this CPU container interpret-mode timing is NOT TPU performance — the
numbers document relative behaviour of the jnp paths (which do run under
XLA:CPU jit) and give a per-call sanity magnitude for the harness."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import SLDAConfig, init_state, phi_hat, topic_occupancy
from repro.data import make_slda_corpus
from repro.kernels import ops


def _time(fn, *args, reps=3):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / reps * 1e6          # µs


def _tok_rates(us, slot_tokens, real_tokens):
    """Padded-slot vs mask-weighted (effective) token throughput: the gap
    between the two IS the padding waste — visible in every sLDA perf row
    so the ragged execution layer's target stays measurable."""
    return (f"slot={slot_tokens / us:.2f}Mtok/s "
            f"eff={real_tokens / us:.2f}Mtok/s "
            f"(pad={1 - real_tokens / slot_tokens:.0%})")


def _occ_col(ntw):
    """Per-word topic occupancy of a count table [T, W] — the mean number
    of topics with N_tw > 0, i.e. the support width the sparse two-stage
    sampler exploits (DESIGN.md §Sparse-sampler).  Reported on every sLDA
    perf row so the dense/sparse crossover regime stays visible."""
    occ = topic_occupancy(jnp.swapaxes(ntw, -1, -2))        # [W]
    return (f" wocc={float(occ.mean()):.1f}/{ntw.shape[0]}"
            f"(max={int(occ.max())})")


def run():
    rows = []
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 8)

    # slda gibbs sweep — jnp path (the CPU benchmark path)
    cfg = SLDAConfig(n_topics=32, vocab_size=1000)
    corpus, _ = make_slda_corpus(ks[0], 64, 1000, 32, 64)
    state = init_state(ks[1], corpus, cfg)
    real_tok = float(corpus.mask.sum())
    slot_tok = float(corpus.tokens.size)
    uniforms = jax.random.uniform(ks[2], corpus.tokens.shape)
    inv_len = 1.0 / jnp.maximum(corpus.mask.sum(-1), 1.0)
    args = (corpus.tokens, corpus.mask, uniforms, state.z, state.ndt,
            corpus.y, inv_len, state.ntw, state.nt, state.eta)

    sweep_jnp = jax.jit(lambda *a: ops.slda_gibbs_sweep(
        *a, alpha=cfg.alpha, beta=cfg.beta, rho=cfg.rho, use_pallas=False))
    us = _time(sweep_jnp, *args)
    rows.append(("slda_gibbs_sweep_jnp_64x64", us,
                 _tok_rates(us, slot_tok, real_tok) + _occ_col(state.ntw)))

    # slda prediction sweeps — fused jnp fast path vs the seed-style
    # per-document vmap (all 25 test-time sweeps, the Weighted Average
    # hot path; see bench_slda_predict.py for the end-to-end numbers)
    n_burnin, n_samples = cfg.n_pred_burnin, cfg.n_pred_samples
    n_sweeps = n_burnin + n_samples
    phi = phi_hat(state, cfg)                       # smoothed φ̂, Eq. (3)
    seeds = jax.random.randint(ks[3], (corpus.n_docs,), 0, 2 ** 31 - 1,
                               jnp.int32)
    pred_fused = jax.jit(lambda *a: ops.slda_predict_sweeps(
        *a, alpha=cfg.alpha, n_burnin=n_burnin, n_samples=n_samples,
        use_pallas=False))
    pargs = (corpus.tokens, corpus.mask, state.z, state.ndt, phi, seeds)
    us_fused = _time(pred_fused, *pargs)
    rows.append((f"slda_predict_{n_sweeps}sweeps_fused_jnp_64x64",
                 us_fused,
                 _tok_rates(us_fused, slot_tok * n_sweeps,
                            real_tok * n_sweeps) + _occ_col(state.ntw)))

    # the same fused sweeps over a HEAVY-TAILED (log-normal) corpus,
    # padded path vs PER-BUCKET launches on the length-bucketed schedule
    # (§Ragged-execution): each launch padded to its bucket's own width,
    # so eff tok/s approaches the padded path's SLOT tok/s.  NB this is
    # the pallas-route execution shape; it only pays off when the token
    # loop is compute-bound AND padding is heavy — at the 64×64 uniform
    # shape above it is a ~0.65× LOSS (more scan dispatches, less work
    # each).  The core jnp route uses the STAIRCASE executor instead
    # (step count stays N_max — see bench_slda_ragged.py for end-to-end
    # numbers); this row documents the per-bucket form.
    from repro.core import bucket_corpus
    rag, _ = make_slda_corpus(ks[5], 256, 1000, 32, 128,
                              doc_len_dist="lognormal")
    rstate = init_state(ks[6], rag, cfg)
    rphi = phi_hat(rstate, cfg)
    rseeds = jax.random.randint(ks[7], (rag.n_docs,), 0, 2 ** 31 - 1,
                                jnp.int32)
    rreal = float(rag.mask.sum())
    rargs = (rag.tokens, rag.mask, rstate.z, rstate.ndt, rphi, rseeds)
    us_rpad = _time(pred_fused, *rargs)
    rows.append((f"slda_predict_{n_sweeps}sweeps_fused_jnp_lognormal"
                 f"_256x128", us_rpad,
                 _tok_rates(us_rpad, float(rag.tokens.size) * n_sweeps,
                            rreal * n_sweeps) + _occ_col(rstate.ntw)))

    bc = bucket_corpus(rag, 4)
    z0_b = bc.split_padded(rstate.z)
    nd_b = bc.split_docs(rstate.ndt)
    seeds_b = bc.split_docs(rseeds)
    stride = bc.ctr_stride

    def pred_bucketed(phi, *flat):
        zs, nds, ss = (flat[0::3], flat[1::3], flat[2::3])
        return [ops.slda_predict_sweeps(
            b.tokens, b.mask, z, nd, phi, s, alpha=cfg.alpha,
            n_burnin=n_burnin, n_samples=n_samples, use_pallas=False,
            ctr_stride=stride)[0]
            for b, z, nd, s in zip(bc.buckets, zs, nds, ss)]

    flat = [x for t in zip(z0_b, nd_b, seeds_b) for x in t]
    us_bkt = _time(jax.jit(pred_bucketed), rphi, *flat)
    rows.append((f"slda_predict_{n_sweeps}sweeps_bucketed_jnp_lognormal"
                 f"_256x128", us_bkt,
                 _tok_rates(us_bkt, float(bc.padded_tokens()) * n_sweeps,
                            rreal * n_sweeps)
                 + f" vs_padded={us_rpad / us_bkt:.2f}x"))

    # the one canonical reconstruction of the seed sampler lives in
    # bench_slda_predict — one baseline, two reports
    from .bench_slda_predict import _doc_predict_sweeps_seed
    log_phi = jnp.log(phi)
    pred_seed = jax.jit(lambda t, m, z, n: jax.vmap(
        _doc_predict_sweeps_seed, in_axes=(0, 0, 0, 0, 0, None, None))(
            t, m, jax.random.split(ks[4], corpus.n_docs), z, n,
            log_phi, cfg))
    us_seed = _time(pred_seed, corpus.tokens, corpus.mask, state.z, state.ndt)
    rows.append((f"slda_predict_{n_burnin + n_samples}sweeps_seed_vmap_64x64",
                 us_seed, f"fused_speedup={us_seed / us_fused:.2f}x"))

    # attention: blocked jnp (train path)
    q = jax.random.normal(ks[3], (2, 8, 512, 64), jnp.float32)
    k = jax.random.normal(ks[4], (2, 4, 512, 64), jnp.float32)
    v = jax.random.normal(ks[5], (2, 4, 512, 64), jnp.float32)
    attn = jax.jit(lambda q, k, v: ops.attention_blocked_jnp(
        q, k, v, causal=True, block_q=128))
    us = _time(attn, q, k, v)
    fl = 2 * 2 * 2 * 8 * 512 * 512 * 64
    rows.append(("attention_blocked_512", us, f"{fl / us / 1e3:.1f}MFLOP/s"))

    # ssd chunked (train path)
    x = jax.random.normal(ks[6], (2, 512, 8, 64)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[7], (2, 512, 8)))
    A = -jnp.exp(jax.random.normal(ks[0], (8,)) * 0.3)
    B = jax.random.normal(ks[1], (2, 512, 64)) * 0.5
    C = jax.random.normal(ks[2], (2, 512, 64)) * 0.5
    ssd = jax.jit(lambda *a: ops.ssd_chunked_jnp(*a, chunk=64))
    rows.append(("ssd_chunked_512", _time(ssd, x, dt, A, B, C), ""))

    return [dict(name=n, us_per_call=round(us, 1), derived=d)
            for n, us, d in rows]


if __name__ == "__main__":
    for r in run():
        print(r)
