"""Kernel microbenchmarks: Pallas (interpret) correctness-path timing vs the
pure-jnp oracle, plus the jnp paths that matter for the training loop.

On this CPU container interpret-mode timing is NOT TPU performance — the
numbers document relative behaviour of the jnp paths (which do run under
XLA:CPU jit) and give a per-call sanity magnitude for the harness."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import SLDAConfig, init_state, phi_hat
from repro.data import make_slda_corpus
from repro.kernels import ops


def _time(fn, *args, reps=3):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / reps * 1e6          # µs


def run():
    rows = []
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 8)

    # slda gibbs sweep — jnp path (the CPU benchmark path)
    cfg = SLDAConfig(n_topics=32, vocab_size=1000)
    corpus, _ = make_slda_corpus(ks[0], 64, 1000, 32, 64)
    state = init_state(ks[1], corpus, cfg)
    uniforms = jax.random.uniform(ks[2], corpus.tokens.shape)
    inv_len = 1.0 / jnp.maximum(corpus.mask.sum(-1), 1.0)
    args = (corpus.tokens, corpus.mask, uniforms, state.z, state.ndt,
            corpus.y, inv_len, state.ntw, state.nt, state.eta)

    sweep_jnp = jax.jit(lambda *a: ops.slda_gibbs_sweep(
        *a, alpha=cfg.alpha, beta=cfg.beta, rho=cfg.rho, use_pallas=False))
    rows.append(("slda_gibbs_sweep_jnp_64x64", _time(sweep_jnp, *args), ""))

    # slda prediction sweeps — fused jnp fast path vs the seed-style
    # per-document vmap (all 25 test-time sweeps, the Weighted Average
    # hot path; see bench_slda_predict.py for the end-to-end numbers)
    n_burnin, n_samples = cfg.n_pred_burnin, cfg.n_pred_samples
    phi = phi_hat(state, cfg)                       # smoothed φ̂, Eq. (3)
    seeds = jax.random.randint(ks[3], (corpus.n_docs,), 0, 2 ** 31 - 1,
                               jnp.int32)
    pred_fused = jax.jit(lambda *a: ops.slda_predict_sweeps(
        *a, alpha=cfg.alpha, n_burnin=n_burnin, n_samples=n_samples,
        use_pallas=False))
    pargs = (corpus.tokens, corpus.mask, state.z, state.ndt, phi, seeds)
    us_fused = _time(pred_fused, *pargs)
    rows.append((f"slda_predict_{n_burnin + n_samples}sweeps_fused_jnp_64x64",
                 us_fused, ""))

    # the one canonical reconstruction of the seed sampler lives in
    # bench_slda_predict — one baseline, two reports
    from .bench_slda_predict import _doc_predict_sweeps_seed
    log_phi = jnp.log(phi)
    pred_seed = jax.jit(lambda t, m, z, n: jax.vmap(
        _doc_predict_sweeps_seed, in_axes=(0, 0, 0, 0, 0, None, None))(
            t, m, jax.random.split(ks[4], corpus.n_docs), z, n,
            log_phi, cfg))
    us_seed = _time(pred_seed, corpus.tokens, corpus.mask, state.z, state.ndt)
    rows.append((f"slda_predict_{n_burnin + n_samples}sweeps_seed_vmap_64x64",
                 us_seed, f"fused_speedup={us_seed / us_fused:.2f}x"))

    # attention: blocked jnp (train path)
    q = jax.random.normal(ks[3], (2, 8, 512, 64), jnp.float32)
    k = jax.random.normal(ks[4], (2, 4, 512, 64), jnp.float32)
    v = jax.random.normal(ks[5], (2, 4, 512, 64), jnp.float32)
    attn = jax.jit(lambda q, k, v: ops.attention_blocked_jnp(
        q, k, v, causal=True, block_q=128))
    us = _time(attn, q, k, v)
    fl = 2 * 2 * 2 * 8 * 512 * 512 * 64
    rows.append(("attention_blocked_512", us, f"{fl / us / 1e3:.1f}MFLOP/s"))

    # ssd chunked (train path)
    x = jax.random.normal(ks[6], (2, 512, 8, 64)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[7], (2, 512, 8)))
    A = -jnp.exp(jax.random.normal(ks[0], (8,)) * 0.3)
    B = jax.random.normal(ks[1], (2, 512, 64)) * 0.5
    C = jax.random.normal(ks[2], (2, 512, 64)) * 0.5
    ssd = jax.jit(lambda *a: ops.ssd_chunked_jnp(*a, chunk=64))
    rows.append(("ssd_chunked_512", _time(ssd, x, dt, A, B, C), ""))

    return [dict(name=n, us_per_call=round(us, 1), derived=d)
            for n, us, d in rows]


if __name__ == "__main__":
    for r in run():
        print(r)
