"""Paper Figure 6 — MD&A / earnings-per-share (continuous label).

Compares the four algorithms of Section IV (Non-parallel, Naive
Combination, Simple Average, Weighted Average) on computation time and
test-set MSE.  The corpus is drawn from the sLDA generative process at the
paper's dimensions (4216 docs, 4238 phrases, near-normal continuous label
— Section IV-A1); `scale < 1` shrinks it proportionally for CI runs.

Timing on this 1-core container cannot show real 4-worker wall-clock, so
two times are reported per algorithm:
  wall_s      measured single-core wall time (all chains run serially)
  modeled_s   critical-path time with M parallel workers: the chain phase
              divides by M (chains share nothing — the paper's property),
              combine/prediction phases stay as measured.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import SLDAConfig, ALGORITHMS
from repro.data import make_slda_corpus, train_test_split

M = 4            # the paper's worker count (dual-core, 4 threads)


def run(scale: float = 0.1, n_topics: int = 16, n_iters: int = 30,
        seed: int = 0):
    n_docs = max(80, int(4216 * scale) // 8 * 8)
    vocab = max(200, int(4238 * scale))
    n_train = int(n_docs * 3000 / 4216) // M * M
    doc_len = max(40, int(120 * min(1.0, scale * 4)))

    cfg = SLDAConfig(n_topics=n_topics, vocab_size=vocab, rho=0.25,
                     n_iters=n_iters, label_type="continuous")
    key = jax.random.PRNGKey(seed)
    # heavy-tailed log-normal lengths — the shape of real MD&A filings
    # (doc_len becomes the max): most token slots are padding, which the
    # ragged execution layer reclaims (padding_frac reported per row)
    corpus, _ = make_slda_corpus(key, n_docs, vocab, n_topics, doc_len,
                                 rho=0.25, doc_len_dist="lognormal")
    train, test = train_test_split(corpus, n_train)
    var_y = float(jnp.var(test.y))
    padding_frac = round(1.0 - float(corpus.mask.mean()), 4)

    rows = []
    for name in ("nonparallel", "naive", "simple", "weighted"):
        fn = ALGORITHMS[name]
        if name == "nonparallel":
            jfn = jax.jit(fn, static_argnums=(3,))
            args = (jax.random.PRNGKey(seed + 1), train, test, cfg)
        else:
            jfn = jax.jit(fn, static_argnums=(3, 4))
            args = (jax.random.PRNGKey(seed + 1), train, test, cfg, M)
        yhat = jfn(*args)                        # compile
        yhat.block_until_ready()
        t0 = time.time()
        yhat = jfn(*args)
        yhat.block_until_ready()
        wall = time.time() - t0
        # chains dominate and are perfectly parallel; non-chain work is the
        # (small) combine, so the M-worker critical path ≈ wall / M for the
        # parallel algorithms (weighted also predicts the train set — that
        # part parallelizes too).
        modeled = wall if name == "nonparallel" else wall / M
        mse = float(jnp.mean((yhat - test.y) ** 2))
        rows.append(dict(algorithm=name, wall_s=round(wall, 3),
                         modeled_s=round(modeled, 3), test_mse=round(mse, 4),
                         r2=round(1 - mse / var_y, 4),
                         padding_frac=padding_frac))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
