"""Before/after wall-clock for the chain-batched parallel algorithms
(ISSUE 3): end-to-end Simple/Weighted Average at M ∈ {4, 8, 16}.

Baseline — the *vmap path*, reconstructed verbatim below from the
pre-chain-batching `core/parallel.py`: `jax.vmap(train_chain)` /
`jax.vmap(predict)` replaying the single-chain functions per chain, two
separate prediction launches for Weighted Average, at the repo-default
config (sweeps_per_launch=1 seed semantics).

Chain-batched — `core.parallel.ALGORITHMS` as shipped: the chain_axis
ops (grid-(M, B) kernels / folded & chain-mapped jnp twins), the fused
single test+train prediction pass, and the tuned fused-launch defaults
from BENCH_slda_train.json (sweeps_per_launch=8, product-form
multi-sweep sampling).  Same TOTAL sweeps on both sides — n_iters
training sweeps and n_pred_burnin+n_pred_samples prediction sweeps per
document per chain — and a 3-seed-mean test-MSE guard (within 15% of
baseline) pins the quality.

Parity rows at M=8 isolate the levers: the chain-batched path at
sweeps_per_launch=1 (bit-identical sampler to the baseline — pure
batching + predict-fusion effect) and the vmap baseline at
sweeps_per_launch=8 (fused launches without chain batching).

All rows run back-to-back in one process, INTERLEAVED round-robin
min-of-reps (this container shows ~2× cross-run wall-clock swings;
interleaving exposes every config to the same load profile and the min
discards interference spikes — the BENCH_slda_train.json methodology).
Writes BENCH_slda_parallel.json.

Run:  PYTHONPATH=src python -m benchmarks.bench_slda_parallel [--quick]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import platform
import time

import jax
import jax.numpy as jnp

from repro.core import (GibbsState, SLDAConfig, SLDAModel, combine,
                        init_state, partition, phi_hat, solve_eta, sweep,
                        zbar)
from repro.core.parallel import (run_simple_average, run_weighted_average,
                                 train_chains)
from repro.core.types import apply_count_deltas, counts_from_assignments
from repro.data import make_slda_corpus, train_test_split


# --------------------------------------------------------- vmap baseline
# Verbatim reconstruction of the pre-chain-batching core/parallel.py
# (PR 2 state), kept here so the "before" column stays measurable after
# the rewrite: one vmap of the single-chain train/predict per chain and
# two separate prediction passes for the Weighted Average weights.
# Since PR 5 the LIBRARY's train_chain/predict are themselves thin M=1
# wrappers over the chain-batched plan loop, so vmapping them would
# measure the "after" code twice — the old single-chain loops are
# rebuilt here from the still-public primitives (init_state/sweep/
# solve_eta and the non-chain ops), preserving the old key trees.

def _train_chain_pre(key, corpus, cfg):
    """The pre-plan single-chain EM loop (seed path at spl=1, fused
    non-chain launches at spl>1) — what jax.vmap(train_chain) ran
    before PR 5."""
    from repro.kernels import ops
    k_init, k_sweeps = jax.random.split(key)
    state0 = init_state(k_init, corpus, cfg)
    every = cfg.count_rebuild_every

    if cfg.sweeps_per_launch > 1:
        spl = cfg.sweeps_per_launch
        D = corpus.n_docs
        doc_block = min(cfg.train_doc_block, -(-D // 8) * 8)
        inv_len = 1.0 / jnp.maximum(corpus.lengths(), 1.0)

        def launch(state, k, it, n_sweeps):
            seeds = jax.random.randint(k, (D,), 0,
                                       jnp.iinfo(jnp.int32).max, jnp.int32)
            z, ndt = ops.slda_train_sweeps(
                corpus.tokens, corpus.mask, state.z, state.ndt, corpus.y,
                inv_len, state.ntw, state.nt, state.eta, seeds,
                alpha=cfg.alpha, beta=cfg.beta, rho=cfg.rho,
                n_sweeps=n_sweeps, supervised=True, doc_block=doc_block,
                use_pallas=cfg.use_pallas,
                product_form=cfg.product_form_sweeps)

            def rebuild(_):
                return counts_from_assignments(
                    corpus.tokens, corpus.mask, z, cfg.n_topics,
                    cfg.vocab_size)

            def incremental(_):
                ntw, nt = apply_count_deltas(
                    state.ntw, state.nt, corpus.tokens, corpus.mask,
                    state.z, z)
                return ndt, ntw, nt

            if every > 0:
                ndt, ntw, nt = jax.lax.cond(it % every == 0, rebuild,
                                            incremental, None)
            else:
                ndt, ntw, nt = incremental(None)
            state = GibbsState(z=z, ndt=ndt, ntw=ntw, nt=nt,
                               eta=state.eta)
            eta = solve_eta(zbar(state, corpus), corpus.y, cfg)
            return GibbsState(z, ndt, ntw, nt, eta)

        n_full, rem = divmod(cfg.n_iters, spl)
        keys = jax.random.split(k_sweeps, n_full + (1 if rem else 0))
        state = state0
        if n_full:
            state, _ = jax.lax.scan(
                lambda s, inp: (launch(s, inp[0], inp[1], spl), None),
                state, (keys[:n_full], jnp.arange(n_full)))
        if rem:
            state = launch(state, keys[-1], jnp.asarray(n_full), rem)
    else:
        def em_step(state, inp):
            k, it = inp
            rebuild = (it % every == 0) if every > 0 else False
            state = sweep(k, corpus, state, cfg, supervised=True,
                          exact_rebuild=rebuild)
            eta = solve_eta(zbar(state, corpus), corpus.y, cfg)
            return GibbsState(state.z, state.ndt, state.ntw, state.nt,
                              eta), None

        state, _ = jax.lax.scan(
            em_step, state0, (jax.random.split(k_sweeps, cfg.n_iters),
                              jnp.arange(cfg.n_iters)))

    yhat_tr = zbar(state, corpus) @ state.eta
    mse = jnp.mean((yhat_tr - corpus.y) ** 2)
    acc = jnp.mean(((yhat_tr > 0.5) == (corpus.y > 0.5))
                   .astype(jnp.float32))
    return state, SLDAModel(phi=phi_hat(state, cfg), eta=state.eta,
                            train_mse=mse, train_acc=acc)


def _predict_pre(key, model, corpus, cfg):
    """The pre-plan single-model fused prediction pass (non-chain op)."""
    from repro.kernels import ops
    k_init, k_seeds = jax.random.split(key)
    z0 = jax.random.randint(k_init, corpus.tokens.shape, 0, cfg.n_topics,
                            jnp.int32)
    d_idx = jnp.arange(corpus.n_docs)[:, None]
    ndt0 = jnp.zeros((corpus.n_docs, cfg.n_topics), jnp.float32) \
        .at[d_idx, z0].add(corpus.mask)
    seeds = jax.random.randint(k_seeds, (corpus.n_docs,), 0,
                               jnp.iinfo(jnp.int32).max, jnp.int32)
    ndt_avg, _ = ops.slda_predict_sweeps(
        corpus.tokens, corpus.mask, z0, ndt0, model.phi, seeds,
        alpha=cfg.alpha, n_burnin=cfg.n_pred_burnin,
        n_samples=cfg.n_pred_samples, doc_block=cfg.pred_doc_block,
        use_pallas=cfg.use_pallas)
    zb = ndt_avg / jnp.maximum(corpus.lengths(), 1.0)[:, None]
    return zb @ model.eta


def train_chains_vmap(key, shards, cfg):
    m = shards.tokens.shape[0]
    keys = jax.random.split(key, m)
    _, models = jax.vmap(_train_chain_pre, in_axes=(0, 0, None))(
        keys, shards, cfg)
    return models


def predict_chains_vmap(key, models, corpus, cfg):
    m = models.eta.shape[0]
    keys = jax.random.split(key, m)
    return jax.vmap(_predict_pre, in_axes=(0, 0, None, None))(
        keys, models, corpus, cfg)


def run_simple_vmap(key, train, test, cfg, m):
    k1, k2 = jax.random.split(key)
    models = train_chains_vmap(k1, partition(train, m), cfg)
    return combine.simple_average(predict_chains_vmap(k2, models, test, cfg))


def run_weighted_vmap(key, train, test, cfg, m):
    k1, k2, k3 = jax.random.split(key, 3)
    models = train_chains_vmap(k1, partition(train, m), cfg)
    yhat_te = predict_chains_vmap(k2, models, test, cfg)
    yhat_tr = predict_chains_vmap(k3, models, train, cfg)
    mse = ((yhat_tr - train.y[None, :]) ** 2).mean(-1)
    return combine.weighted_average(yhat_te, train_mse=mse)


# ------------------------------------------------------------- harness

def _timed_round_robin(fns, reps):
    """min-of-`reps`, INTERLEAVED round-robin (see module docstring)."""
    for fn in fns:                       # warm-up (compile excluded)
        jax.block_until_ready(fn())      # result dropped — keeps resident
    best = [float("inf")] * len(fns)     # memory flat across the run
    for _ in range(reps):
        for i, fn in enumerate(fns):
            t0 = time.time()
            out = fn()
            jax.block_until_ready(out)
            best[i] = min(best[i], time.time() - t0)
    return best


def run(quick: bool = False, reps: int = 3):
    if quick:   # harness smoke for CI — tiny shapes, one rep, one M
        d_tr, d_te, w, t, n, iters, spl, ms = 64, 32, 128, 8, 16, 6, 3, (2,)
        reps, probe_seeds = 1, ()
    else:
        d_tr, d_te, w, t, n, iters, spl, ms = 320, 192, 1000, 32, 64, 60, \
            8, (4, 8, 16)
        probe_seeds = (17, 18)
    base_cfg = SLDAConfig(n_topics=t, vocab_size=w, rho=0.25, n_iters=iters)
    tuned_cfg = dataclasses.replace(base_cfg, sweeps_per_launch=spl)
    corpus, _ = make_slda_corpus(jax.random.PRNGKey(0), d_tr + d_te, w, t,
                                 n, rho=0.25)
    train, test = train_test_split(corpus, d_tr)
    key = jax.random.PRNGKey(7)

    jb_s = jax.jit(run_simple_vmap, static_argnums=(3, 4))
    jb_w = jax.jit(run_weighted_vmap, static_argnums=(3, 4))
    jn_s = jax.jit(run_simple_average, static_argnums=(3, 4))
    jn_w = jax.jit(run_weighted_average, static_argnums=(3, 4))
    jb_t = jax.jit(train_chains_vmap, static_argnums=(2,))
    jn_t = jax.jit(train_chains, static_argnums=(2,))

    m8 = ms[1] if len(ms) > 1 else ms[0]
    rows = []
    fns = []
    for m in ms:
        rows += [("simple", "vmap_spl1", m), ("simple", "batched_tuned", m),
                 ("weighted", "vmap_spl1", m),
                 ("weighted", "batched_tuned", m)]
        fns += [lambda m=m: jb_s(key, train, test, base_cfg, m),
                lambda m=m: jn_s(key, train, test, tuned_cfg, m),
                lambda m=m: jb_w(key, train, test, base_cfg, m),
                lambda m=m: jn_w(key, train, test, tuned_cfg, m)]
    # parity rows: isolate chain-batching from the fused-launch tuning
    rows += [("weighted", "batched_spl1", m8), ("weighted", "vmap_spl8", m8),
             ("train_only", "vmap_spl1", m8),
             ("train_only", "batched_tuned", m8)]
    fns += [lambda: jn_w(key, train, test, base_cfg, m8),
            lambda: jb_w(key, train, test, tuned_cfg, m8),
            lambda: jb_t(key, partition(train, m8), base_cfg),
            lambda: jn_t(key, partition(train, m8), tuned_cfg)]

    times = _timed_round_robin(fns, reps=reps)
    grid = [{"algorithm": a, "impl": i, "chains": m,
             "seconds": round(s, 4)}
            for (a, i, m), s in zip(rows, times)]

    # quality probe: 3-seed mean test MSE at the headline point — the
    # per-seed spread swamps any single-seed comparison
    def mean_mse(fn, cfg):
        ys = [fn(jax.random.PRNGKey(s), train, test, cfg, m8)
              for s in (7,) + probe_seeds]
        return float(sum(float(jnp.mean((y - test.y) ** 2)) for y in ys)
                     / len(ys))

    mse_base = mean_mse(jb_w, base_cfg)
    mse_new = mean_mse(jn_w, tuned_cfg)

    sec = {(a, i, m): s for (a, i, m), s in zip(rows, times)}
    results = {
        "weighted_m8_vmap_s": round(sec[("weighted", "vmap_spl1", m8)], 4),
        "weighted_m8_batched_s": round(
            sec[("weighted", "batched_tuned", m8)], 4),
        "weighted_m8_speedup": round(
            sec[("weighted", "vmap_spl1", m8)]
            / sec[("weighted", "batched_tuned", m8)], 2),
        "simple_m8_speedup": round(
            sec[("simple", "vmap_spl1", m8)]
            / sec[("simple", "batched_tuned", m8)], 2),
        "speedup_by_chains": {
            str(m): round(sec[("weighted", "vmap_spl1", m)]
                          / sec[("weighted", "batched_tuned", m)], 2)
            for m in ms},
        "test_mse_vmap_3seed": round(mse_base, 4),
        "test_mse_batched_3seed": round(mse_new, 4),
        "mse_guard_ok": bool(mse_new <= 1.15 * mse_base),
        "tuned_defaults": {"sweeps_per_launch": spl,
                           "product_form_sweeps": True,
                           "fuse_weighted_predict": True},
    }

    return {
        "benchmark": "chain-batched parallel sLDA algorithms (ISSUE 3)",
        "methodology": (
            f"End-to-end Simple/Weighted Average (train {iters} EM sweeps "
            f"then predict, {base_cfg.n_pred_burnin}+"
            f"{base_cfg.n_pred_samples} sweeps/doc/chain) on a synthetic "
            f"sLDA corpus [D_train={d_tr}, D_test={d_te}, W={w}, T={t}, "
            f"N={n}] at M in {list(ms)} chains.  Baseline rows "
            "reconstruct the pre-chain-batching vmap path verbatim "
            "(jax.vmap(train_chain)/vmap(predict), two prediction "
            "launches for the Weighted Average weights, repo-default "
            "sweeps_per_launch=1).  Chain-batched rows run "
            "core.parallel.ALGORITHMS as shipped: chain_axis ops, ONE "
            "fused test+train prediction pass, tuned sweeps_per_launch="
            f"{spl} with product-form multi-sweep sampling "
            "(BENCH_slda_train.json tuned defaults).  Same total sweeps "
            "per document on both sides; 3-seed-mean test MSE guard "
            "within 15% of baseline.  Parity rows at M=8 isolate the "
            "levers (batched_spl1 = bit-identical sampler to baseline; "
            "vmap_spl8 = fused launches without chain batching).  All "
            f"rows jit-compiled, warm-up excluded, MIN of {reps} "
            "INTERLEAVED round-robin reps in ONE process (~2x container "
            "interference drift; the min discards spikes); jnp fast "
            f"paths (use_pallas=False) on {jax.default_backend()}.  "
            "Expect the ratio to peak at moderate M on small-cache CPU "
            "hosts: the folded prediction's per-token working set grows "
            "with M x D rows and falls out of cache around M=16 at these "
            "shapes (measured: the two-pass unfused batched form is no "
            "better there — the row fold itself saturates).  The TPU "
            "chain grid tiles through VMEM and does not have this "
            "cliff."),
        "platform": {"backend": jax.default_backend(),
                     "machine": platform.machine(),
                     "jax": jax.__version__},
        "shapes": {"d_train": d_tr, "d_test": d_te, "vocab": w,
                   "n_topics": t, "doc_len": n, "n_iters": iters,
                   "pred_sweeps": base_cfg.n_pred_burnin
                   + base_cfg.n_pred_samples, "chain_grid": list(ms)},
        "grid": grid,
        "results": results,
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="tiny-shape harness smoke (CI); writes to --out")
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--out", default=None,
                    help="output JSON (default BENCH_slda_parallel.json, "
                         "or /tmp/BENCH_slda_parallel_quick.json with "
                         "--quick)")
    args = ap.parse_args(argv)
    out = args.out or ("/tmp/BENCH_slda_parallel_quick.json" if args.quick
                       else "BENCH_slda_parallel.json")
    payload = run(quick=args.quick, reps=args.reps)
    with open(out, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    r = payload["results"]
    print(f"weighted M=8: vmap {r['weighted_m8_vmap_s']}s -> batched "
          f"{r['weighted_m8_batched_s']}s ({r['weighted_m8_speedup']}x); "
          f"by-M {r['speedup_by_chains']}; mse {r['test_mse_vmap_3seed']} "
          f"-> {r['test_mse_batched_3seed']} (guard_ok="
          f"{r['mse_guard_ok']}); wrote {out}")


if __name__ == "__main__":
    main()
