"""Before/after wall-clock for the chain-batched parallel algorithms
(ISSUE 3): end-to-end Simple/Weighted Average at M ∈ {4, 8, 16}.

Baseline — the *vmap path*, reconstructed verbatim below from the
pre-chain-batching `core/parallel.py`: `jax.vmap(train_chain)` /
`jax.vmap(predict)` replaying the single-chain functions per chain, two
separate prediction launches for Weighted Average, at the repo-default
config (sweeps_per_launch=1 seed semantics).

Chain-batched — `core.parallel.ALGORITHMS` as shipped: the chain_axis
ops (grid-(M, B) kernels / folded & chain-mapped jnp twins), the fused
single test+train prediction pass, and the tuned fused-launch defaults
from BENCH_slda_train.json (sweeps_per_launch=8, product-form
multi-sweep sampling).  Same TOTAL sweeps on both sides — n_iters
training sweeps and n_pred_burnin+n_pred_samples prediction sweeps per
document per chain — and a 3-seed-mean test-MSE guard (within 15% of
baseline) pins the quality.

Parity rows at M=8 isolate the levers: the chain-batched path at
sweeps_per_launch=1 (bit-identical sampler to the baseline — pure
batching + predict-fusion effect) and the vmap baseline at
sweeps_per_launch=8 (fused launches without chain batching).

All rows run back-to-back in one process, INTERLEAVED round-robin
min-of-reps (this container shows ~2× cross-run wall-clock swings;
interleaving exposes every config to the same load profile and the min
discards interference spikes — the BENCH_slda_train.json methodology).
Writes BENCH_slda_parallel.json.

Run:  PYTHONPATH=src python -m benchmarks.bench_slda_parallel [--quick]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import platform
import time

import jax
import jax.numpy as jnp

from repro.core import SLDAConfig, combine, partition, predict, train_chain
from repro.core.parallel import (run_simple_average, run_weighted_average,
                                 train_chains)
from repro.data import make_slda_corpus, train_test_split


# --------------------------------------------------------- vmap baseline
# Verbatim reconstruction of the pre-chain-batching core/parallel.py
# (PR 2 state), kept here so the "before" column stays measurable after
# the rewrite: one vmap of the single-chain train/predict per chain and
# two separate prediction passes for the Weighted Average weights.

def train_chains_vmap(key, shards, cfg):
    m = shards.tokens.shape[0]
    keys = jax.random.split(key, m)
    _, models = jax.vmap(train_chain, in_axes=(0, 0, None))(keys, shards, cfg)
    return models


def predict_chains_vmap(key, models, corpus, cfg):
    m = models.eta.shape[0]
    keys = jax.random.split(key, m)
    return jax.vmap(predict, in_axes=(0, 0, None, None))(keys, models,
                                                         corpus, cfg)


def run_simple_vmap(key, train, test, cfg, m):
    k1, k2 = jax.random.split(key)
    models = train_chains_vmap(k1, partition(train, m), cfg)
    return combine.simple_average(predict_chains_vmap(k2, models, test, cfg))


def run_weighted_vmap(key, train, test, cfg, m):
    k1, k2, k3 = jax.random.split(key, 3)
    models = train_chains_vmap(k1, partition(train, m), cfg)
    yhat_te = predict_chains_vmap(k2, models, test, cfg)
    yhat_tr = predict_chains_vmap(k3, models, train, cfg)
    mse = ((yhat_tr - train.y[None, :]) ** 2).mean(-1)
    return combine.weighted_average(yhat_te, train_mse=mse)


# ------------------------------------------------------------- harness

def _timed_round_robin(fns, reps):
    """min-of-`reps`, INTERLEAVED round-robin (see module docstring)."""
    for fn in fns:                       # warm-up (compile excluded)
        jax.block_until_ready(fn())      # result dropped — keeps resident
    best = [float("inf")] * len(fns)     # memory flat across the run
    for _ in range(reps):
        for i, fn in enumerate(fns):
            t0 = time.time()
            out = fn()
            jax.block_until_ready(out)
            best[i] = min(best[i], time.time() - t0)
    return best


def run(quick: bool = False, reps: int = 3):
    if quick:   # harness smoke for CI — tiny shapes, one rep, one M
        d_tr, d_te, w, t, n, iters, spl, ms = 64, 32, 128, 8, 16, 6, 3, (2,)
        reps, probe_seeds = 1, ()
    else:
        d_tr, d_te, w, t, n, iters, spl, ms = 320, 192, 1000, 32, 64, 60, \
            8, (4, 8, 16)
        probe_seeds = (17, 18)
    base_cfg = SLDAConfig(n_topics=t, vocab_size=w, rho=0.25, n_iters=iters)
    tuned_cfg = dataclasses.replace(base_cfg, sweeps_per_launch=spl)
    corpus, _ = make_slda_corpus(jax.random.PRNGKey(0), d_tr + d_te, w, t,
                                 n, rho=0.25)
    train, test = train_test_split(corpus, d_tr)
    key = jax.random.PRNGKey(7)

    jb_s = jax.jit(run_simple_vmap, static_argnums=(3, 4))
    jb_w = jax.jit(run_weighted_vmap, static_argnums=(3, 4))
    jn_s = jax.jit(run_simple_average, static_argnums=(3, 4))
    jn_w = jax.jit(run_weighted_average, static_argnums=(3, 4))
    jb_t = jax.jit(train_chains_vmap, static_argnums=(2,))
    jn_t = jax.jit(train_chains, static_argnums=(2,))

    m8 = ms[1] if len(ms) > 1 else ms[0]
    rows = []
    fns = []
    for m in ms:
        rows += [("simple", "vmap_spl1", m), ("simple", "batched_tuned", m),
                 ("weighted", "vmap_spl1", m),
                 ("weighted", "batched_tuned", m)]
        fns += [lambda m=m: jb_s(key, train, test, base_cfg, m),
                lambda m=m: jn_s(key, train, test, tuned_cfg, m),
                lambda m=m: jb_w(key, train, test, base_cfg, m),
                lambda m=m: jn_w(key, train, test, tuned_cfg, m)]
    # parity rows: isolate chain-batching from the fused-launch tuning
    rows += [("weighted", "batched_spl1", m8), ("weighted", "vmap_spl8", m8),
             ("train_only", "vmap_spl1", m8),
             ("train_only", "batched_tuned", m8)]
    fns += [lambda: jn_w(key, train, test, base_cfg, m8),
            lambda: jb_w(key, train, test, tuned_cfg, m8),
            lambda: jb_t(key, partition(train, m8), base_cfg),
            lambda: jn_t(key, partition(train, m8), tuned_cfg)]

    times = _timed_round_robin(fns, reps=reps)
    grid = [{"algorithm": a, "impl": i, "chains": m,
             "seconds": round(s, 4)}
            for (a, i, m), s in zip(rows, times)]

    # quality probe: 3-seed mean test MSE at the headline point — the
    # per-seed spread swamps any single-seed comparison
    def mean_mse(fn, cfg):
        ys = [fn(jax.random.PRNGKey(s), train, test, cfg, m8)
              for s in (7,) + probe_seeds]
        return float(sum(float(jnp.mean((y - test.y) ** 2)) for y in ys)
                     / len(ys))

    mse_base = mean_mse(jb_w, base_cfg)
    mse_new = mean_mse(jn_w, tuned_cfg)

    sec = {(a, i, m): s for (a, i, m), s in zip(rows, times)}
    results = {
        "weighted_m8_vmap_s": round(sec[("weighted", "vmap_spl1", m8)], 4),
        "weighted_m8_batched_s": round(
            sec[("weighted", "batched_tuned", m8)], 4),
        "weighted_m8_speedup": round(
            sec[("weighted", "vmap_spl1", m8)]
            / sec[("weighted", "batched_tuned", m8)], 2),
        "simple_m8_speedup": round(
            sec[("simple", "vmap_spl1", m8)]
            / sec[("simple", "batched_tuned", m8)], 2),
        "speedup_by_chains": {
            str(m): round(sec[("weighted", "vmap_spl1", m)]
                          / sec[("weighted", "batched_tuned", m)], 2)
            for m in ms},
        "test_mse_vmap_3seed": round(mse_base, 4),
        "test_mse_batched_3seed": round(mse_new, 4),
        "mse_guard_ok": bool(mse_new <= 1.15 * mse_base),
        "tuned_defaults": {"sweeps_per_launch": spl,
                           "product_form_sweeps": True,
                           "fuse_weighted_predict": True},
    }

    return {
        "benchmark": "chain-batched parallel sLDA algorithms (ISSUE 3)",
        "methodology": (
            f"End-to-end Simple/Weighted Average (train {iters} EM sweeps "
            f"then predict, {base_cfg.n_pred_burnin}+"
            f"{base_cfg.n_pred_samples} sweeps/doc/chain) on a synthetic "
            f"sLDA corpus [D_train={d_tr}, D_test={d_te}, W={w}, T={t}, "
            f"N={n}] at M in {list(ms)} chains.  Baseline rows "
            "reconstruct the pre-chain-batching vmap path verbatim "
            "(jax.vmap(train_chain)/vmap(predict), two prediction "
            "launches for the Weighted Average weights, repo-default "
            "sweeps_per_launch=1).  Chain-batched rows run "
            "core.parallel.ALGORITHMS as shipped: chain_axis ops, ONE "
            "fused test+train prediction pass, tuned sweeps_per_launch="
            f"{spl} with product-form multi-sweep sampling "
            "(BENCH_slda_train.json tuned defaults).  Same total sweeps "
            "per document on both sides; 3-seed-mean test MSE guard "
            "within 15% of baseline.  Parity rows at M=8 isolate the "
            "levers (batched_spl1 = bit-identical sampler to baseline; "
            "vmap_spl8 = fused launches without chain batching).  All "
            f"rows jit-compiled, warm-up excluded, MIN of {reps} "
            "INTERLEAVED round-robin reps in ONE process (~2x container "
            "interference drift; the min discards spikes); jnp fast "
            f"paths (use_pallas=False) on {jax.default_backend()}.  "
            "Expect the ratio to peak at moderate M on small-cache CPU "
            "hosts: the folded prediction's per-token working set grows "
            "with M x D rows and falls out of cache around M=16 at these "
            "shapes (measured: the two-pass unfused batched form is no "
            "better there — the row fold itself saturates).  The TPU "
            "chain grid tiles through VMEM and does not have this "
            "cliff."),
        "platform": {"backend": jax.default_backend(),
                     "machine": platform.machine(),
                     "jax": jax.__version__},
        "shapes": {"d_train": d_tr, "d_test": d_te, "vocab": w,
                   "n_topics": t, "doc_len": n, "n_iters": iters,
                   "pred_sweeps": base_cfg.n_pred_burnin
                   + base_cfg.n_pred_samples, "chain_grid": list(ms)},
        "grid": grid,
        "results": results,
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="tiny-shape harness smoke (CI); writes to --out")
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--out", default=None,
                    help="output JSON (default BENCH_slda_parallel.json, "
                         "or /tmp/BENCH_slda_parallel_quick.json with "
                         "--quick)")
    args = ap.parse_args(argv)
    out = args.out or ("/tmp/BENCH_slda_parallel_quick.json" if args.quick
                       else "BENCH_slda_parallel.json")
    payload = run(quick=args.quick, reps=args.reps)
    with open(out, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    r = payload["results"]
    print(f"weighted M=8: vmap {r['weighted_m8_vmap_s']}s -> batched "
          f"{r['weighted_m8_batched_s']}s ({r['weighted_m8_speedup']}x); "
          f"by-M {r['speedup_by_chains']}; mse {r['test_mse_vmap_3seed']} "
          f"-> {r['test_mse_batched_3seed']} (guard_ok="
          f"{r['mse_guard_ok']}); wrote {out}")


if __name__ == "__main__":
    main()
