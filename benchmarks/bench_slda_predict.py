"""Before/after wall-clock for the fused prediction path (ISSUE 1 tentpole).

Measures the end-to-end Weighted Average algorithm — the paper's slowest
variant, dominated by test-time Gibbs sweeps over BOTH the test set and
the full training set — with prediction routed through

  * the SEED implementation (reconstructed below verbatim: per-document
    `vmap` of a sweep scan, per-sweep threefry uniforms, log-space
    categorical with a lane-dim `log_phi[:, w]` column gather), and
  * the fused path (`kernels.ops.slda_predict_sweeps`: all sweeps in one
    scan, [W, T] row gather, matmul prefix sums, counter-hash PRNG).

Also reports predict-only timings for both.  Writes BENCH_slda_predict.json
(repo root by default) with the methodology embedded, so the perf
trajectory of this hot path is recorded run over run.

Run:  PYTHONPATH=src python -m benchmarks.bench_slda_predict [--scale 1.0]
"""
from __future__ import annotations

import argparse
import json
import platform
import time

import jax
import jax.numpy as jnp

from repro.core import SLDAConfig, run_weighted_average
from repro.core import parallel as parallel_mod
from repro.core.gibbs import init_state, phi_hat, zbar
from repro.core.regression import solve_eta
from repro.core.types import (Corpus, GibbsState, SLDAModel,
                              counts_from_assignments)
from repro.data import make_slda_corpus, train_test_split


# --------------------------------------------------------- seed baseline
# Verbatim reconstruction of the pre-fusion core/predict.py (seed commit),
# kept here so the "before" column stays measurable after the rewrite.

def _doc_predict_sweeps_seed(tokens, mask, key, z0, ndt0, log_phi, cfg):
    T = cfg.n_topics
    topic_iota = jnp.arange(T, dtype=jnp.int32)
    n_sweeps = cfg.n_pred_burnin + cfg.n_pred_samples

    def token_step(carry, inp):
        ndt_d = carry
        w, m, z_old, u = inp
        old_onehot = (topic_iota == z_old).astype(jnp.float32) * m
        ndt_d = ndt_d - old_onehot
        logp = jnp.log(ndt_d + cfg.alpha) + log_phi[:, w]
        p = jnp.exp(logp - jnp.max(logp))
        c = jnp.cumsum(p)
        z_new = jnp.sum((c < u * c[-1]).astype(jnp.int32))
        z_new = jnp.where(m > 0, z_new, z_old).astype(jnp.int32)
        ndt_d = ndt_d + (topic_iota == z_new).astype(jnp.float32) * m
        return ndt_d, z_new

    def sweep_step(carry, sweep_idx):
        z, ndt_d = carry
        us = jax.random.uniform(jax.random.fold_in(key, sweep_idx),
                                tokens.shape)
        ndt_d, z = jax.lax.scan(token_step, ndt_d, (tokens, mask, z, us))
        return (z, ndt_d), ndt_d

    (_, _), ndt_hist = jax.lax.scan(sweep_step, (z0, ndt0),
                                    jnp.arange(n_sweeps))
    keep = ndt_hist[cfg.n_pred_burnin:]
    return jnp.mean(keep, axis=0)


def predict_seed(key, model: SLDAModel, corpus: Corpus, cfg: SLDAConfig):
    k_init, k_sweeps = jax.random.split(key)
    z0 = jax.random.randint(k_init, corpus.tokens.shape, 0, cfg.n_topics,
                            jnp.int32)
    d_idx = jnp.arange(corpus.n_docs)[:, None]
    ndt0 = jnp.zeros((corpus.n_docs, cfg.n_topics), jnp.float32)
    ndt0 = ndt0.at[d_idx, z0].add(corpus.mask)
    doc_keys = jax.random.split(k_sweeps, corpus.n_docs)
    log_phi = jnp.log(model.phi)
    ndt_avg = jax.vmap(
        _doc_predict_sweeps_seed, in_axes=(0, 0, 0, 0, 0, None, None)
    )(corpus.tokens, corpus.mask, doc_keys, z0, ndt0, log_phi, cfg)
    zbar = ndt_avg / jnp.maximum(corpus.lengths(), 1.0)[:, None]
    return zbar @ model.eta


# Seed training loop: cumsum categorical in the sweep and a full
# counts_from_assignments re-scatter every iteration (no incremental
# deltas, no matmul prefix sums).

def _doc_sweep_seed(tokens, mask, uniforms, z, ndt, y, inv_len,
                    ntw, nt, eta, cfg, supervised):
    T = cfg.n_topics
    s0 = jnp.dot(ndt, eta)
    topic_iota = jnp.arange(T, dtype=jnp.int32)

    def step(carry, inp):
        ndt_d, s = carry
        w, m, z_old, u = inp
        old_onehot = (topic_iota == z_old).astype(jnp.float32) * m
        ndt_d = ndt_d - old_onehot
        s = s - eta[z_old] * m
        ntw_w = ntw[:, w] - old_onehot
        nt_m = nt - old_onehot
        logp = (jnp.log(ndt_d + cfg.alpha)
                + jnp.log(ntw_w + cfg.beta)
                - jnp.log(nt_m + cfg.vocab_size * cfg.beta))
        if supervised:
            mu_t = (s + eta) * inv_len
            logp = logp - 0.5 * (y - mu_t) ** 2 / cfg.rho
        p = jnp.exp(logp - jnp.max(logp))
        c = jnp.cumsum(p)
        z_new = jnp.sum((c < u * c[-1]).astype(jnp.int32))
        z_new = jnp.where(m > 0, z_new, z_old).astype(jnp.int32)
        new_onehot = (topic_iota == z_new).astype(jnp.float32) * m
        ndt_d = ndt_d + new_onehot
        s = s + eta[z_new] * m
        return (ndt_d, s), z_new

    (ndt, _), z_new = jax.lax.scan(step, (ndt, s0), (tokens, mask, z, uniforms))
    return z_new, ndt


def train_chain_seed(key, corpus: Corpus, cfg: SLDAConfig):
    k_init, k_sweeps = jax.random.split(key)
    state0 = init_state(k_init, corpus, cfg)
    inv_len = 1.0 / jnp.maximum(corpus.lengths(), 1.0)

    def em_step(state, k):
        uniforms = jax.random.uniform(k, corpus.tokens.shape)
        z, _ = jax.vmap(
            _doc_sweep_seed,
            in_axes=(0, 0, 0, 0, 0, 0, 0, None, None, None, None, None)
        )(corpus.tokens, corpus.mask, uniforms, state.z, state.ndt,
          corpus.y, inv_len, state.ntw, state.nt, state.eta, cfg, True)
        ndt, ntw, nt = counts_from_assignments(
            corpus.tokens, corpus.mask, z, cfg.n_topics, cfg.vocab_size)
        state = GibbsState(z=z, ndt=ndt, ntw=ntw, nt=nt, eta=state.eta)
        eta = solve_eta(zbar(state, corpus), corpus.y, cfg)
        return GibbsState(state.z, state.ndt, state.ntw, state.nt, eta), None

    state, _ = jax.lax.scan(em_step, state0,
                            jax.random.split(k_sweeps, cfg.n_iters))
    yhat_tr = zbar(state, corpus) @ state.eta
    mse = jnp.mean((yhat_tr - corpus.y) ** 2)
    acc = jnp.mean(((yhat_tr > 0.5) == (corpus.y > 0.5)).astype(jnp.float32))
    model = SLDAModel(phi=phi_hat(state, cfg), eta=state.eta,
                      train_mse=mse, train_acc=acc)
    return state, model


# ------------------------------------------------------------- harness

def _timed(fn, *args, reps):
    out = fn(*args)
    jax.block_until_ready(out)          # warm-up (compile excluded)
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / reps, out


def _make_weighted_average(train_chain_fn, predict_fn):
    """A run_weighted_average twin wired to explicit train/predict impls.

    Distinct FUNCTION OBJECTS per implementation pair — monkey-patching
    `parallel.predict` under `jax.jit` is unreliable because jit caches by
    the identity of the underlying callable, so a patched retrace can
    silently reuse the unpatched computation.
    """
    from repro.core import combine

    def wa(key, train: Corpus, test: Corpus, cfg: SLDAConfig, m: int):
        k1, k2, k3 = jax.random.split(key, 3)
        shards = parallel_mod.partition(train, m)
        keys = jax.random.split(k1, m)
        _, models = jax.vmap(train_chain_fn,
                             in_axes=(0, 0, None))(keys, shards, cfg)
        pred = jax.vmap(predict_fn, in_axes=(0, 0, None, None))
        yhat_te = pred(jax.random.split(k2, m), models, test, cfg)
        yhat_tr = pred(jax.random.split(k3, m), models, train, cfg)
        mse = ((yhat_tr - train.y[None, :]) ** 2).mean(-1)
        return combine.weighted_average(yhat_te, train_mse=mse)

    return wa


def run(scale: float = 1.0, reps: int = 3):
    """Returns the result dict (also what lands in the JSON)."""
    d_total = max(int(640 * scale), 64)
    cfg = SLDAConfig(n_topics=32, vocab_size=1000, n_iters=30, rho=0.25)
    m = 8   # the paper's regime: many communication-free chains, every one
            # of which predicts the full train set for the Eq. (9) weights
    # partition() needs d_train divisible by the chain count at any --scale
    d_train = max(int(d_total * 0.8) // m * m, m)
    corpus, _ = make_slda_corpus(jax.random.PRNGKey(0), d_total, 1000, 32,
                                 64, rho=0.25)
    train, test = train_test_split(corpus, d_train)
    key = jax.random.PRNGKey(7)

    results = {}

    # predict-only: one trained-shape model over the full training corpus
    phi = jax.random.dirichlet(jax.random.PRNGKey(1),
                               jnp.full((1000,), 0.01), (32,))
    model = SLDAModel(phi=phi,
                      eta=jax.random.normal(jax.random.PRNGKey(2), (32,)),
                      train_mse=jnp.zeros(()), train_acc=jnp.zeros(()))
    from repro.core.predict import predict as predict_fused
    for name, fn in (("seed", predict_seed), ("fused", predict_fused)):
        f = jax.jit(fn, static_argnums=(3,))
        s, _ = _timed(f, key, model, train, cfg, reps=reps)
        results[f"predict_only_{name}_s"] = round(s, 4)

    # end-to-end weighted average (train + test & full-train prediction):
    # the seed row uses BOTH halves of the seed hot path — the pre-fusion
    # predict and the cumsum/full-rebuild training sweep
    from repro.core.gibbs import train_chain as train_chain_cur
    wa_seed = jax.jit(_make_weighted_average(train_chain_seed, predict_seed),
                      static_argnums=(3, 4))
    wa_new = jax.jit(_make_weighted_average(train_chain_cur, predict_fused),
                     static_argnums=(3, 4))
    s, y_seed = _timed(wa_seed, key, train, test, cfg, m, reps=reps)
    results["weighted_average_seed_s"] = round(s, 4)
    s, y_new = _timed(wa_new, key, train, test, cfg, m, reps=reps)
    results["weighted_average_fused_s"] = round(s, 4)
    # cross-check: the public entry point matches the fused twin's timing
    s, _ = _timed(jax.jit(run_weighted_average, static_argnums=(3, 4)),
                  key, train, test, cfg, m, reps=reps)
    results["weighted_average_public_entry_s"] = round(s, 4)

    results["weighted_average_speedup"] = round(
        results["weighted_average_seed_s"]
        / results["weighted_average_fused_s"], 2)
    results["predict_only_speedup"] = round(
        results["predict_only_seed_s"] / results["predict_only_fused_s"], 2)
    results["test_mse_seed"] = round(float(jnp.mean((y_seed - test.y) ** 2)), 4)
    results["test_mse_fused"] = round(float(jnp.mean((y_new - test.y) ** 2)), 4)
    return {
        "benchmark": "slda_predict fused multi-sweep path (ISSUE 1)",
        "methodology": (
            f"run_weighted_average (train {cfg.n_iters} EM iters on {m} "
            "chains, then every chain predicts test + FULL train set, "
            "15 burn-in + 10 sample sweeps) on a synthetic sLDA corpus "
            f"[D={d_total} (train {d_train}), W=1000, T=32, N=64]; the seed "
            "row wires the algorithm to reconstructed seed implementations "
            "(per-doc vmap predict with threefry uniforms + "
            "cumsum-categorical training sweep with a full count re-scatter "
            "per iteration), the fused row to the current code, as distinct "
            "function objects (no monkey-patching: jit caches by callable "
            "identity); both jit-compiled, warm-up excluded, mean of "
            f"{reps} reps; jnp fast path (use_pallas=False) on "
            f"{jax.default_backend()}."),
        "platform": {"backend": jax.default_backend(),
                     "machine": platform.machine(),
                     "jax": jax.__version__},
        "shapes": {"d_total": d_total, "d_train": d_train, "vocab": 1000,
                   "n_topics": 32, "doc_len": 64, "chains": m,
                   "n_iters": cfg.n_iters,
                   "pred_sweeps": cfg.n_pred_burnin + cfg.n_pred_samples},
        "results": results,
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=1.0,
                    help="corpus-size multiplier (1.0 ≈ 1 min on CPU)")
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--out", default="BENCH_slda_predict.json")
    args = ap.parse_args(argv)
    payload = run(scale=args.scale, reps=args.reps)
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    r = payload["results"]
    print(f"weighted-average: seed {r['weighted_average_seed_s']}s → fused "
          f"{r['weighted_average_fused_s']}s "
          f"({r['weighted_average_speedup']}x); predict-only "
          f"{r['predict_only_speedup']}x; wrote {args.out}")


if __name__ == "__main__":
    main()
