"""Cost of fault tolerance + degraded-ensemble quality (ISSUE 6).

Two questions, one artifact:

  1. **Overhead** — what do the in-scan health checks (NaN/count/MSE-z
     probes at every EM boundary, `core.supervisor.chain_status`) cost
     on the hot path?  Supervised Weighted Average at M=8, checks ON vs
     checks OFF (same supervisor harness, same single-round schedule, no
     faults), plus the plain `run_weighted_average` reference.  The
     acceptance bar is ≤5% on the checks ON/OFF ratio — the probes are
     O(state) elementwise reductions against O(state · N) sweep work.

  2. **Degraded quality** — the paper's fault-isolation dividend: kill
     ⌈M/4⌉ chains mid-train (one-shot state loss, quarantine-only
     recovery) and combine the survivors.  Communication-freedom makes
     the drop EXACT, so M=8→6 should cost noise-level MSE; the guard is
     a 3-seed-mean band (degraded ≤ 1.25× full-ensemble MSE).

Timing reuses ONE ChainSupervisor instance per row across reps — the
supervisor jit-caches its round function per instance, so fresh
instances would re-trace inside the timed window.  All rows run
back-to-back in one process, INTERLEAVED round-robin min-of-reps (the
BENCH_slda_train.json methodology: this container shows ~2× cross-run
wall-clock swings; the min discards interference spikes).  Writes
BENCH_slda_robust.json.

Run:  PYTHONPATH=src python -m benchmarks.bench_slda_robust [--quick]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import platform
import time

import jax
import jax.numpy as jnp

from repro.core import HealthConfig, RecoveryPolicy, SLDAConfig
from repro.core.parallel import (_combine_weighted, _predict_chains_jit,
                                 run_weighted_average)
from repro.core.plan import build_schedule
from repro.core.supervisor import ChainSupervisor
from repro.core.types import partition
from repro.data import make_slda_corpus, train_test_split
from repro.testing import no_faults

CHECKS_OFF = HealthConfig(check_nan=False, check_counts=False,
                          check_mse=False)


def _supervised_weighted(sup: ChainSupervisor, key, train, test, cfg):
    """Weighted Average through a PREBUILT supervisor (jit caches warm
    after the first call) — the timed unit, and the quality-probe unit."""
    k1, k2 = jax.random.split(key)
    _, models, report = sup.train(jax.random.split(k1, sup.plan.n_chains))
    yhat_te = _predict_chains_jit(k2, models, build_schedule(test, cfg),
                                  cfg)
    k3 = jax.random.fold_in(k2, 1)
    yhat_tr = _predict_chains_jit(k3, models, build_schedule(train, cfg),
                                  cfg)
    return _combine_weighted(yhat_te, yhat_tr, train.y, cfg,
                             report.alive_mask()), report


def _timed_round_robin(fns, reps):
    """min-of-`reps`, INTERLEAVED round-robin (see module docstring)."""
    for fn in fns:                       # warm-up (compile excluded)
        jax.block_until_ready(fn())
    best = [float("inf")] * len(fns)
    for _ in range(reps):
        for i, fn in enumerate(fns):
            t0 = time.time()
            out = fn()
            jax.block_until_ready(out)
            best[i] = min(best[i], time.time() - t0)
    return best


def run(quick: bool = False, reps: int = 3):
    if quick:   # harness smoke for CI — tiny shapes, one rep
        d_tr, d_te, w, t, n, iters, spl, m = 64, 32, 128, 8, 16, 6, 3, 4
        reps, probe_seeds = 1, ()
    else:
        d_tr, d_te, w, t, n, iters, spl, m = 320, 192, 1000, 32, 64, 60, \
            8, 8
        probe_seeds = (17, 18)
    cfg = SLDAConfig(n_topics=t, vocab_size=w, rho=0.25, n_iters=iters,
                     sweeps_per_launch=spl)
    corpus, _ = make_slda_corpus(jax.random.PRNGKey(0), d_tr + d_te, w, t,
                                 n, rho=0.25)
    train, test = train_test_split(corpus, d_tr)
    key = jax.random.PRNGKey(7)
    shards = build_schedule(partition(train, m), cfg)
    quarantine_only = RecoveryPolicy(max_restarts=0, min_alive_frac=0.0)

    # kill ⌈M/4⌉ chains halfway through the EM boundaries (one-shot
    # state loss → quarantine; no checkpoint dir, so no restart path)
    n_kill = -(-m // 4)
    fp = no_faults(m)
    b_mid = ChainSupervisor(shards, cfg).plan.n_boundaries() // 2
    kill = fp.kill_step
    for c in range(n_kill):
        kill = kill.at[(c * m) // n_kill + 1].set(b_mid)
    fp = fp._replace(kill_step=kill)

    sup_on = ChainSupervisor(shards, cfg, health=HealthConfig())
    sup_off = ChainSupervisor(shards, cfg, health=CHECKS_OFF)
    sup_deg = ChainSupervisor(shards, cfg, health=HealthConfig(),
                              recovery=quarantine_only,
                              fault_hook=fp.hook())
    j_plain = jax.jit(run_weighted_average, static_argnums=(3, 4))

    rows = ["supervised_checks_on", "supervised_checks_off",
            "plain_weighted", "supervised_degraded"]
    fns = [lambda: _supervised_weighted(sup_on, key, train, test, cfg)[0],
           lambda: _supervised_weighted(sup_off, key, train, test, cfg)[0],
           lambda: j_plain(key, train, test, cfg, m),
           lambda: _supervised_weighted(sup_deg, key, train, test, cfg)[0]]
    times = _timed_round_robin(fns, reps=reps)
    sec = dict(zip(rows, times))
    grid = [{"row": r, "chains": m, "seconds": round(s, 4)}
            for r, s in zip(rows, times)]

    # quality probes: multi-seed mean test MSE, full vs degraded ensemble
    def mean_mse(sup):
        tot, alive = 0.0, None
        for s in (7,) + probe_seeds:
            y, rep = _supervised_weighted(sup, jax.random.PRNGKey(s),
                                          train, test, cfg)
            tot += float(jnp.mean((y - test.y) ** 2))
            alive = rep.alive
        return tot / (1 + len(probe_seeds)), alive

    mse_full, alive_full = mean_mse(sup_on)
    mse_deg, alive_deg = mean_mse(sup_deg)
    n_seeds = 1 + len(probe_seeds)

    overhead = sec["supervised_checks_on"] / sec["supervised_checks_off"] \
        - 1.0
    results = {
        "checks_on_s": round(sec["supervised_checks_on"], 4),
        "checks_off_s": round(sec["supervised_checks_off"], 4),
        "plain_weighted_s": round(sec["plain_weighted"], 4),
        "degraded_s": round(sec["supervised_degraded"], 4),
        "health_check_overhead_frac": round(overhead, 4),
        "health_check_overhead_ok": bool(overhead <= 0.05),
        "supervisor_vs_plain_frac": round(
            sec["supervised_checks_off"] / sec["plain_weighted"] - 1.0, 4),
        "chains_full": int(sum(alive_full)),
        "chains_degraded": int(sum(alive_deg)),
        "test_mse_full_mean": round(mse_full, 4),
        "test_mse_degraded_mean": round(mse_deg, 4),
        "mse_seeds": n_seeds,
        "degraded_mse_guard_ok": bool(mse_deg <= 1.25 * mse_full),
    }

    return {
        "benchmark": "fault-tolerant supervised ensemble (ISSUE 6)",
        "methodology": (
            f"Supervised Weighted Average at M={m} on a synthetic sLDA "
            f"corpus [D_train={d_tr}, D_test={d_te}, W={w}, T={t}, N={n}],"
            f" {iters} EM sweeps (sweeps_per_launch={spl}).  "
            "supervised_checks_on/off time the SAME ChainSupervisor "
            "harness (single round, no faults) with the in-scan health "
            "probes (NaN/count/MSE-z at every EM boundary) enabled vs "
            "compiled out — their ratio is the health-check overhead, "
            "bar 5%.  plain_weighted is core.parallel.run_weighted_"
            "average (no supervisor) for the harness-cost reference.  "
            f"supervised_degraded kills ceil(M/4)={n_kill} chains' state "
            f"at EM boundary {b_mid} (one-shot fault injection via "
            "repro.testing.faults) under quarantine-only recovery; the "
            f"{n_seeds}-seed-mean test MSE of the surviving "
            "sub-ensemble must stay within 1.25x of the full ensemble "
            "(chain drop is EXACT under communication freedom — "
            "DESIGN.md §Fault-model).  One supervisor instance per row "
            "reused across reps (per-instance jit cache keeps re-traces "
            f"out of the timed window); MIN of {reps} INTERLEAVED "
            "round-robin reps in ONE process; jnp fast paths "
            f"(use_pallas=False) on {jax.default_backend()}."),
        "platform": {"backend": jax.default_backend(),
                     "machine": platform.machine(),
                     "jax": jax.__version__},
        "shapes": {"d_train": d_tr, "d_test": d_te, "vocab": w,
                   "n_topics": t, "doc_len": n, "n_iters": iters,
                   "sweeps_per_launch": spl, "chains": m,
                   "chains_killed": n_kill, "kill_boundary": b_mid},
        "grid": grid,
        "results": results,
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="tiny-shape harness smoke (CI); writes to --out")
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--out", default=None,
                    help="output JSON (default BENCH_slda_robust.json, or "
                         "/tmp/BENCH_slda_robust_quick.json with --quick)")
    args = ap.parse_args(argv)
    out = args.out or ("/tmp/BENCH_slda_robust_quick.json" if args.quick
                       else "BENCH_slda_robust.json")
    payload = run(quick=args.quick, reps=args.reps)
    with open(out, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    r = payload["results"]
    print(f"health checks: {r['checks_off_s']}s -> {r['checks_on_s']}s "
          f"(+{r['health_check_overhead_frac'] * 100:.1f}%, ok="
          f"{r['health_check_overhead_ok']}); degraded "
          f"M={r['chains_full']}->{r['chains_degraded']}: mse "
          f"{r['test_mse_full_mean']} -> {r['test_mse_degraded_mean']} "
          f"(guard_ok={r['degraded_mse_guard_ok']}); wrote {out}")


if __name__ == "__main__":
    main()
