"""Dense vs sparse two-stage sampler A/B across topic counts (ISSUE 10).

The dense per-token draw is one [DB, T] x [T, T] prefix matmul —
O(T^2) MACs per token-block — so its cost explodes with T while the
number of topics a WORD actually occupies stays small on peaked
corpora.  The sparse two-stage draw (DESIGN.md §Sparse-sampler) spends
cap^2 + T*blk + nb^2 MACs instead: ~10K at T=512/cap=32 vs ~262K dense.

This bench measures `train_chain` end-to-end (the full fused stochastic-
EM loop, both modes plan-routed via `SLDAConfig.sampler_mode`) at
T ∈ {32, 128, 512} on a PEAKED-φ corpus (`phi_concentration` < 1: each
topic's mass on a handful of words — the published regime of sparse
LDA samplers).  Both modes run back-to-back interleaved in one process;
a 3-seed mean train-MSE guard asserts the sparse draw costs no model
quality (it is distributionally exact — any gap is seed noise, bounded
here).

It reports TWO speedup columns, because the backend it runs on is not
the backend the sparse draw targets:

  * `sparse_speedup` — measured wall-clock on this machine's jnp path.
    XLA-CPU strength-reduces the dense `p @ triu(T)` draw into a
    linear-cost running sum (profiled: the whole dense draw is ~5% of a
    T=512 launch, and dense launch time scales ~linearly in T), so the
    O(T²) contraction the sparse mode eliminates DOES NOT EXIST on this
    backend and dense wins at every T measured here.
  * `modeled_speedup` — the fig6/fig7 `modeled_s` idiom applied to the
    draw: per-token cycles on an explicit-contraction accelerator (MXU
    prefix matmuls + VPU element-wise pipeline, the cost model of the
    pallas kernel path).  THIS is the asymptotic shape the mode was
    built for — sparse >= 1.5x at T=512, >= 1.2x at T=128, and dense
    WINS at T=32 (a 32x32 contraction is already cheap; the bucketing
    overhead only amortizes at large T) — and why dense remains the
    default mode on every backend until the explicit-contraction path
    is the one running.

Run:  PYTHONPATH=src python -m benchmarks.bench_slda_sparse [--quick]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import platform
import time

import jax
import jax.numpy as jnp

from repro.core import (SLDAConfig, counts_from_assignments, init_state,
                        topic_occupancy, train_chain)
from repro.data import make_slda_corpus


MXU_MACS = 128 * 128   # systolic MACs/cycle (pallas guide: 128x128 MXU)
VPU_LANES = 8 * 128    # element-wise lanes/cycle (8x128 VPU)
VEC_PASSES = 10        # [DB, T] element-wise passes per token in the
                       # fused weight pipeline (count gather + own-token
                       # fixup + alpha/beta/nt normalisers + supervised
                       # exp factor + product), IDENTICAL in both modes


def modeled_cell(n_topics: int, cap: int):
    """Per-token draw cost on an explicit-contraction accelerator.

    Dense draw = one T² -MAC triu contraction per token; sparse draw =
    cap² (bucket prefix) + T·blk (fine residual prefixes) + nb² (coarse
    residual prefix) MACs, plus a T-lane residual mask and 2·cap bucket
    gathers on the VPU.  Modeled cycles = vector-lanes/VPU + MACs/MXU —
    the cost model of the pallas kernel path, where the contraction is
    explicit instead of strength-reduced away (see module docstring)."""
    from repro.kernels.sparse import residual_blocks
    cap = min(cap, n_topics)
    blk, nb = residual_blocks(n_topics)
    d_macs = n_topics * n_topics
    s_macs = cap * cap + n_topics * blk + nb * nb
    d_cyc = VEC_PASSES * n_topics / VPU_LANES + d_macs / MXU_MACS
    s_cyc = ((VEC_PASSES * n_topics + n_topics + 2 * cap) / VPU_LANES
             + s_macs / MXU_MACS)
    return {"draw_macs_dense": d_macs, "draw_macs_sparse": s_macs,
            "modeled_speedup": round(d_cyc / s_cyc, 2)}


def _timed_round_robin(fns, argsets, reps):
    """Min-of-`reps`, INTERLEAVED round-robin (see bench_slda_train.py:
    this container shows ~2x wall-clock interference drift on the scale
    of minutes; interleaving exposes every config to the same load and
    the min discards the spikes).  argsets is per-fn here — each T cell
    owns its corpus."""
    outs = []
    for fn, args in zip(fns, argsets):     # warm-up (compile excluded)
        outs.append(fn(*args))
        jax.block_until_ready(outs[-1])
    best = [float("inf")] * len(fns)
    for _ in range(reps):
        for i, (fn, args) in enumerate(zip(fns, argsets)):
            t0 = time.time()
            out = fn(*args)
            jax.block_until_ready(out)
            best[i] = min(best[i], time.time() - t0)
    return best, outs


def run(quick: bool = False, reps: int = 3):
    if quick:   # harness smoke for CI — tiny shapes, one rep
        topic_grid, d, n, w, n_iters, reps = [8, 16], 16, 12, 200, 4, 1
        seeds = (7,)
    else:
        topic_grid, d, n, w, n_iters = [32, 128, 512], 64, 48, 1000, 8
        seeds = (7, 17, 18)

    base = SLDAConfig(vocab_size=w, rho=0.25, n_iters=n_iters,
                      sweeps_per_launch=4)
    jit_train = jax.jit(train_chain, static_argnums=(2,))
    cells, fns, argsets = [], [], []
    for T in topic_grid:
        # peaked phi: most words live in FEW topics — the regime the
        # per-word topic index exploits
        corpus, _ = make_slda_corpus(jax.random.PRNGKey(0), d, w, T, n,
                                     rho=0.25, phi_concentration=0.15)
        cfg_d = dataclasses.replace(base, n_topics=T, sampler_mode="dense")
        cfg_s = dataclasses.replace(base, n_topics=T,
                                    sampler_mode="sparse")
        # converged-state occupancy estimate for the report: one short
        # dense run, then count occupied topics per word
        st = init_state(jax.random.PRNGKey(1), corpus, cfg_d)
        occ = topic_occupancy(jnp.swapaxes(st.ntw, -1, -2))
        cells.append({"n_topics": T,
                      "word_topic_occ_init_mean": round(
                          float(occ.mean()), 1),
                      "sparse_topic_cap": min(base.sparse_topic_cap, T),
                      **modeled_cell(T, base.sparse_topic_cap)})
        for cfg in (cfg_d, cfg_s):
            fns.append((lambda c: lambda k, corp: jit_train(k, corp, c))(
                cfg))
            argsets.append((jax.random.PRNGKey(seeds[0]), corpus))

    times, outs = _timed_round_robin(fns, argsets, reps=reps)

    def mean_mse(fn, corpus, first):
        mses = [first] + [
            float(fn(jax.random.PRNGKey(s), corpus)[1].train_mse)
            for s in seeds[1:]]
        return sum(mses) / len(mses)

    grid, guard_ok = [], True
    for i, cell in enumerate(cells):
        t_dense, t_sparse = times[2 * i], times[2 * i + 1]
        mse_d = mean_mse(fns[2 * i], argsets[2 * i][1],
                         float(outs[2 * i][1].train_mse))
        mse_s = mean_mse(fns[2 * i + 1], argsets[2 * i + 1][1],
                         float(outs[2 * i + 1][1].train_mse))
        # the sparse draw is distributionally exact: its mean fit must
        # stay within seed noise of dense (3-seed spread is ~20%)
        cell_ok = mse_s <= 1.25 * mse_d
        guard_ok = guard_ok and cell_ok
        grid.append({**cell,
                     "dense_s": round(t_dense, 4),
                     "sparse_s": round(t_sparse, 4),
                     "sparse_speedup": round(t_dense / t_sparse, 2),
                     "train_mse_dense": round(mse_d, 4),
                     "train_mse_sparse": round(mse_s, 4),
                     "mse_guard_ok": cell_ok})

    results = {
        "speedup_by_topics": {str(g["n_topics"]): g["sparse_speedup"]
                              for g in grid},
        "modeled_speedup_by_topics": {
            str(g["n_topics"]): g["modeled_speedup"] for g in grid},
        "mse_guard_ok": guard_ok,
        "dense_wins_small_t": grid[0]["sparse_speedup"] < 1.0,
        "routing_note": (
            "dense stays the default sampler_mode: it is bit-frozen to "
            "every prior release, wins at small T on every cost model, "
            "and wins at ALL T on this machine's XLA-CPU jnp path (the "
            "backend strength-reduces the dense triu draw to linear "
            "cost — see methodology).  The sparse mode targets the "
            "explicit per-token contraction of the pallas kernel path "
            "at large T (modeled_speedup_by_topics); opt in via "
            "SLDAConfig.sampler_mode"),
    }
    if not quick:
        # the acceptance shape, on the cost model the mode targets
        m = results["modeled_speedup_by_topics"]
        t_lo, t_mid, t_hi = (str(t) for t in topic_grid)
        results["modeled_shape_ok"] = bool(
            m[t_hi] >= 1.5 and m[t_mid] >= 1.2 and m[t_lo] < 1.0)

    return {
        "benchmark": "slda sparse two-stage sampler A/B (ISSUE 10)",
        "methodology": (
            f"train_chain ({n_iters} EM sweeps, sweeps_per_launch=4, "
            f"supervised) on synthetic PEAKED-phi sLDA corpora "
            f"[D={d}, W={w}, N={n}, phi_concentration=0.15] at "
            f"T in {topic_grid}; dense vs sparse differ ONLY in "
            "SLDAConfig.sampler_mode (both plan-routed through the same "
            "fused launches; sparse adds the launch-frozen per-word "
            "topic index + two-stage draw, DESIGN.md §Sparse-sampler).  "
            f"MIN of {reps} INTERLEAVED round-robin reps in ONE process, "
            "jit-compiled per distinct static cfg, warm-up excluded.  "
            f"MSE guard: mean train MSE over {len(seeds)} seeds; sparse "
            "must stay within 25% of dense per cell (the draw is "
            "distributionally exact, so any gap is seed noise).  jnp "
            f"fast path (use_pallas=False) on {jax.default_backend()}.  "
            "CAVEAT on the measured column: profiling shows XLA-CPU "
            "strength-reduces the dense p@triu(T) draw to a linear-cost "
            "running sum (dense launch time scales ~linearly in T; the "
            "draw is ~5% of a T=512 launch), so the O(T^2) contraction "
            "the sparse mode removes is absent on this backend and its "
            "index-gather overhead makes dense win every measured cell. "
            " The modeled_speedup column prices the same per-token work "
            "on an explicit-contraction accelerator (MXU 128x128 MACs + "
            "VPU 8x128 lanes per cycle, the pallas-kernel cost model): "
            "cycles = vector_lanes/1024 + draw_MACs/16384 per token, "
            f"with VEC_PASSES={VEC_PASSES} shared weight-pipeline "
            "passes in both modes — the fig6/fig7 modeled_s idiom."),
        "platform": {"backend": jax.default_backend(),
                     "machine": platform.machine(),
                     "jax": jax.__version__},
        "shapes": {"d": d, "vocab": w, "doc_len": n, "n_iters": n_iters,
                   "topic_grid": topic_grid, "phi_concentration": 0.15,
                   "sparse_topic_cap": base.sparse_topic_cap},
        "grid": grid,
        "results": results,
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="tiny harness smoke for CI (does not overwrite "
                         "the committed artifact)")
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--out", default=None,
                    help="output path (default BENCH_slda_sparse.json, "
                         "or /tmp/BENCH_slda_sparse_quick.json with "
                         "--quick)")
    args = ap.parse_args(argv)
    out = args.out or ("/tmp/BENCH_slda_sparse_quick.json" if args.quick
                       else "BENCH_slda_sparse.json")
    payload = run(quick=args.quick, reps=args.reps)
    with open(out, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    r = payload["results"]
    print(f"measured speedup by T: {r['speedup_by_topics']}; "
          f"modeled (contraction path): {r['modeled_speedup_by_topics']} "
          f"(mse guard {'ok' if r['mse_guard_ok'] else 'FAILED'}, "
          f"dense wins small T: {r['dense_wins_small_t']}, "
          f"modeled shape ok: {r.get('modeled_shape_ok', 'n/a')}); "
          f"wrote {out}")


if __name__ == "__main__":
    main()
