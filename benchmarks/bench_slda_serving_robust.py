"""Overload/fault behaviour of the hardened sLDA prediction service
(DESIGN.md §Serving-robustness, `serving/slda_service.py`).

Four sections, each with an asserted guard:

  burst      — a deterministic open-loop burst trace (steady → burst →
               tail arrivals) replayed under a VirtualClock with an
               injected per-dispatch delay, twice: WITH admission
               control + deadlines (bounded queue, EDF, expiry shed)
               and WITHOUT (serve everything).  Simulated-time p50/p99
               and shed rate per arm; ASSERTS the admission arm's p99
               stays within deadline + 2·dispatch and that the open arm's
               tail is worse — overload is shed, not absorbed into
               latency.
  overhead   — closed-loop real-clock serving with robust_checks on vs
               off (the table screen at load + the per-chain ŷ screen
               per dispatch), interleaved round-robin min-of-reps like
               bench_slda_robust; ASSERTS the checks cost <= 5%.
  reload     — hot checkpoint reload while serving: swap to a second
               trained ensemble mid-stream, then a drop/revive cycle;
               reports reload wall ms and ASSERTS zero retraces across
               the swap AND the cycle (models and chain_weights are jit
               arguments), plus (hash, epoch) cache invalidation.
  degraded   — M → M−2 exactness: a service that quarantined two
               poisoned chains at load serves a trace bit-identically
               (survivor rows and combined ŷ) to a clean service with
               the same chains manually dropped — the communication-free
               degradation guarantee at serving scale.

Writes BENCH_slda_serving_robust.json (or /tmp/..._quick.json with
--quick).

Run:  PYTHONPATH=src python -m benchmarks.bench_slda_serving_robust [--quick]
"""
from __future__ import annotations

import argparse
import json
import platform
import tempfile
import time

import jax
import numpy as np

from repro.checkpoint import save_checkpoint
from repro.core import SLDAConfig, partition, train_chains
from repro.serving import ServiceConfig, SLDAPredictionService, STATUS_OK
from repro.data import make_slda_corpus
from repro.testing import (VirtualClock, burst_trace, inject_dispatch_delay,
                           poison_model_table, replay_open_loop)

from benchmarks.bench_slda_serving import make_trace


def _pctl(xs, q):
    return float(np.percentile(np.asarray(xs), q)) if len(xs) else 0.0


def _serve_wall(svc, docs):
    t0 = time.perf_counter()
    rids = [svc.submit(d) for d in docs]
    svc.drain()
    return time.perf_counter() - t0, rids


def run(quick: bool = False, reps: int = 8):
    if quick:   # harness smoke for CI — tiny shapes
        d_tr, w, t, n, iters, m = 64, 128, 8, 48, 6, 2
        batch, n_buckets, n_req = 16, 3, 96
        n_drop = 1
    else:
        d_tr, w, t, n, iters, m = 512, 1000, 32, 256, 60, 8
        batch, n_buckets, n_req = 32, 4, 512
        n_drop = 2
    cfg = SLDAConfig(n_topics=t, vocab_size=w, rho=0.25, n_iters=iters)
    corpus, _ = make_slda_corpus(jax.random.PRNGKey(0), d_tr, w, t, n,
                                 rho=0.25, doc_len_dist="lognormal",
                                 len_sigma=1.0, len_skew=6.0)
    models = train_chains(jax.random.PRNGKey(1), partition(corpus, m), cfg)
    models_b = train_chains(jax.random.PRNGKey(5), partition(corpus, m), cfg)
    lens = np.asarray(corpus.mask.sum(-1)).astype(int)
    base = ServiceConfig.calibrated(lens, max_doc_len=n, batch_docs=batch,
                                    n_buckets=n_buckets)

    import dataclasses

    def service(mods=models, clock=None, **kw):
        return SLDAPredictionService(mods, cfg, dataclasses.replace(
            base, **kw), key=jax.random.PRNGKey(7), clock=clock)

    # ------------------------------------------------- 1. burst overload
    # calibrate the simulated dispatch time to the REAL per-flush wall so
    # the simulated service has the true capacity of this machine
    cal = service(cache_results=False)
    wall, _ = _serve_wall(cal, make_trace(9, 3 * batch, w, n,
                                          repeat_frac=0.0))
    disp_s = max(wall / max(cal.stats()["dispatches"], 1), 1e-4)
    cap = batch / disp_s                       # docs/s the service can do
    deadline = 8 * disp_s
    trace = burst_trace(0, w, n, base_rate=0.5 * cap, burst_rate=8 * cap,
                        n_steady=2 * batch, n_burst=8 * batch,
                        n_tail=2 * batch)

    def burst_arm(**kw):
        clock = VirtualClock()
        svc = service(clock=clock, auto_flush=False, cache_results=False,
                      **kw)
        inject_dispatch_delay(svc, disp_s)
        replay_open_loop(svc, trace, clock)
        res = list(svc._results.values())
        lat = [r.latency_s for r in res if r.status == STATUS_OK]
        return {
            "served": len(lat),
            "shed_frac": round(1.0 - len(lat) / len(res), 4),
            "latency_p50_s": round(_pctl(lat, 50), 4),
            "latency_p99_s": round(_pctl(lat, 99), 4),
        }

    admit = burst_arm(max_pending=2 * batch, default_deadline_s=deadline)
    open_ = burst_arm()
    assert admit["shed_frac"] > 0.0, "burst never tripped admission"
    assert open_["shed_frac"] == 0.0
    p99_bound = deadline + 2 * disp_s
    assert admit["latency_p99_s"] <= p99_bound, (
        f"admission p99 {admit['latency_p99_s']} exceeds policy bound "
        f"{p99_bound}")
    assert open_["latency_p99_s"] > admit["latency_p99_s"], (
        "open-loop tail should be worse than the admission-controlled arm")

    # --------------------------------------- 2. robust-checks overhead
    ab = make_trace(11, 4 * batch, w, n, repeat_frac=0.0)
    arms = [service(cache_results=False, robust_checks=True),
            service(cache_results=False, robust_checks=False)]
    for svc in arms:                          # warm-up (compile excluded)
        _serve_wall(svc, ab)
    best = [float("inf")] * len(arms)
    for _ in range(reps):                     # interleaved round-robin
        for i, svc in enumerate(arms):
            best[i] = min(best[i], _serve_wall(svc, ab)[0])
    overhead = best[0] / best[1] - 1.0
    checks_ok = bool(overhead <= 0.05)
    assert checks_ok, f"robust_checks overhead {overhead:.1%} > 5%"

    # ------------------------------------------ 3. reload while serving
    svc = service()
    stream = make_trace(13, 6 * batch, w, n, repeat_frac=0.0)
    _serve_wall(svc, stream[: 2 * batch])
    probe = stream[0]                          # dispatched + cached above
    assert svc.result(svc.submit(probe)).from_cache
    traces_before = svc.stats()["traces"]
    with tempfile.TemporaryDirectory() as ckpt:
        save_checkpoint(ckpt, 100, models_b)
        rep = svc.reload_from_checkpoint(ckpt)
    assert rep["ok"]
    reload_ms = rep["wall_s"] * 1e3
    miss = svc.submit(probe)
    svc.drain()
    assert not svc.result(miss).from_cache, (
        "epoch-keyed result cache failed to invalidate across the swap")
    _serve_wall(svc, stream[2 * batch: 4 * batch])
    for c in range(n_drop):                    # drop/revive cycle
        svc.drop_chain(c)
    _serve_wall(svc, stream[4 * batch: 5 * batch])
    for c in range(n_drop):
        svc.revive_chain(c)
    _serve_wall(svc, stream[5 * batch:])
    reload_retraces = svc.stats()["traces"] - traces_before
    assert reload_retraces == 0, (
        f"hot reload / drop-revive retraced {reload_retraces}x — models "
        "and chain_weights must ride as jit arguments")

    # --------------------------------------------- 4. degraded exactness
    deg_trace = make_trace(17, 4 * batch, w, n, repeat_frac=0.0)
    poisoned = models
    for c in range(n_drop):
        poisoned = poison_model_table(poisoned, c, "nan_phi")
    deg = service(poisoned, cache_results=False)   # quarantined at load
    ref = service(cache_results=False)
    for c in range(n_drop):
        ref.drop_chain(c)
    _, rids_a = _serve_wall(deg, deg_trace)
    _, rids_b = _serve_wall(ref, deg_trace)
    surv = list(range(n_drop, m))
    exact = True
    for ra, rb in zip(rids_a, rids_b):
        a, b = deg.result(ra), ref.result(rb)
        exact &= a.yhat == b.yhat
        exact &= bool(np.array_equal(a.yhat_chains[surv],
                                     b.yhat_chains[surv]))
    assert exact, "degraded ensemble deviates from clean drop — the " \
                  "quarantine path is not exact"
    assert deg.stats()["alive_chains"] == m - n_drop

    results = {
        "burst_with_admission": admit,
        "burst_open_loop": open_,
        "burst_requests": len(trace),
        "dispatch_s_calibrated": round(disp_s, 5),
        "deadline_s": round(deadline, 4),
        "p99_policy_bound_s": round(p99_bound, 4),
        "p99_bounded_ok": bool(admit["latency_p99_s"] <= p99_bound),
        "checks_on_wall_s": round(best[0], 4),
        "checks_off_wall_s": round(best[1], 4),
        "robust_checks_overhead": round(overhead, 4),
        "checks_overhead_ok": checks_ok,
        "reload_ms": round(reload_ms, 2),
        "reload_epoch": rep["epoch"],
        "reload_retraces": reload_retraces,
        "cache_invalidated_on_reload": True,
        "degraded_chains": f"{m}->{m - n_drop}",
        "degraded_exact_ok": bool(exact),
    }
    return {
        "benchmark": "overload/fault-hardened sLDA serving",
        "methodology": (
            "burst: a deterministic steady->burst->tail arrival trace "
            f"({len(trace)} requests, burst at 8x capacity) replayed "
            "open-loop under a VirtualClock with the per-dispatch delay "
            "calibrated to this machine's measured flush wall "
            f"({disp_s * 1e3:.1f} ms); the admission arm runs a "
            f"{2 * batch}-deep bounded queue + {deadline:.2f}s deadlines "
            "(EDF packing, expiry shed before slot assignment), the open "
            "arm serves everything.  p50/p99 are simulated seconds; the "
            "admission p99 is ASSERTED <= deadline + 2*dispatch.  "
            "overhead: closed-loop real-clock serving, robust_checks "
            f"on/off, interleaved round-robin min-of-{reps}; asserted "
            "<= 5%.  reload: mid-stream hot swap to a second trained "
            "ensemble + drop/revive cycle; retraces across both asserted "
            "0; (hash, epoch) cache invalidation asserted.  degraded: "
            f"{n_drop} NaN-poisoned chains auto-quarantined at load must "
            "serve bit-identically (survivor rows + combined) to a clean "
            f"service with the same chains dropped; jnp fast paths on "
            f"{jax.default_backend()}."),
        "platform": {"backend": jax.default_backend(),
                     "machine": platform.machine(),
                     "jax": jax.__version__},
        "shapes": {"d_train": d_tr, "vocab": w, "n_topics": t,
                   "max_len": n, "n_iters": iters, "chains": m,
                   "batch_docs": batch,
                   "pred_sweeps": cfg.n_pred_burnin + cfg.n_pred_samples},
        "results": results,
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="tiny-shape harness smoke (CI); writes to --out")
    ap.add_argument("--out", default=None,
                    help="output JSON (default "
                         "BENCH_slda_serving_robust.json, or /tmp/"
                         "BENCH_slda_serving_robust_quick.json with "
                         "--quick)")
    args = ap.parse_args(argv)
    out = args.out or ("/tmp/BENCH_slda_serving_robust_quick.json"
                       if args.quick else "BENCH_slda_serving_robust.json")
    payload = run(quick=args.quick)
    with open(out, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    r = payload["results"]
    print(f"serving-robust: burst p99 admit "
          f"{r['burst_with_admission']['latency_p99_s']}s (bound "
          f"{r['p99_policy_bound_s']}s, shed "
          f"{r['burst_with_admission']['shed_frac']}) vs open "
          f"{r['burst_open_loop']['latency_p99_s']}s; checks overhead "
          f"{r['robust_checks_overhead']:.1%}; reload {r['reload_ms']}ms "
          f"retraces {r['reload_retraces']}; degraded "
          f"{r['degraded_chains']} exact={r['degraded_exact_ok']}; "
          f"wrote {out}")


if __name__ == "__main__":
    main()
