"""Aggregate the dry-run artifacts into the §Roofline table.

Reads artifacts/dryrun/*.json (produced by repro.launch.dryrun) and prints
per (arch × shape × mesh): the three roofline terms, the dominant
bottleneck, MODEL_FLOPS/HLO_FLOPS, and the roofline fraction
(model-ideal compute time / dominant-term time).
"""
from __future__ import annotations

import glob
import json
import os

from repro.launch.hlo import HBM_BW, PEAK_FLOPS


def load(art_dir="artifacts/dryrun"):
    rows = []
    for path in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        d = json.load(open(path))
        base = os.path.basename(path)[:-5]
        d["tag"] = base.split("__", 1)[1] if "__" in base else ""
        n = d["n_chips"]
        ideal_s = d["model_flops"] / (n * PEAK_FLOPS)
        d["ideal_s"] = ideal_s
        # pessimistic memory term: per-instruction byte counting under
        # XLA:CPU's weak fusion (upper bound on traffic)
        bound_s = max(d["t_compute_s"], d["t_memory_s"], d["t_collective_s"])
        d["roofline_frac"] = ideal_s / bound_s if bound_s else 0.0
        # analytic memory floor: every live input byte (params + opt state
        # + batch/cache) read once, outputs written once — the classical
        # weights-traffic bound a fused TPU lowering approaches
        args_b = (d.get("bytes_per_device") or {}).get("arguments") or 0
        out_b = (d.get("bytes_per_device") or {}).get("output") or 0
        d["t_memory_lb_s"] = (args_b + out_b) / HBM_BW
        bound_lb = max(d["t_compute_s"], d["t_memory_lb_s"],
                       d["t_collective_s"])
        d["roofline_frac_fused"] = ideal_s / bound_lb if bound_lb else 0.0
        # padded-slot vs mask-weighted (effective) token throughput at the
        # dominant roofline bound — the gap between them is padding waste
        # (dense LM batches report real_token_frac=1.0; masked workloads
        # report their true fraction, making the waste a first-class
        # perf-row column)
        toks = d.get("tokens_per_step") or 0
        frac = d.get("real_token_frac", 1.0)
        d["slot_tok_s"] = toks / bound_s if bound_s else 0.0
        d["eff_tok_s"] = d["slot_tok_s"] * frac
        # per-word topic occupancy (sLDA dryruns report it; blank for the
        # transformer archs) — the support width that picks dense vs the
        # sparse two-stage sampler (DESIGN.md §Sparse-sampler)
        d["word_topic_occ"] = d.get("word_topic_occ", "")
        rows.append(d)
    return rows


def table(rows, keys=("arch", "shape", "multi_pod", "n_chains", "dominant",
                      "t_compute_s", "t_memory_s", "t_memory_lb_s",
                      "t_collective_s", "useful_flop_ratio",
                      "slot_tok_s", "eff_tok_s", "word_topic_occ",
                      "roofline_frac", "roofline_frac_fused",
                      "collective_bytes_cross_pod")):
    fmt = lambda v: (f"{v:.3g}" if isinstance(v, float) else str(v))
    header = " | ".join(keys)
    lines = [header, " | ".join("---" for _ in keys)]
    for d in sorted(rows, key=lambda r: (r["arch"], r["shape"],
                                         r["multi_pod"])):
        lines.append(" | ".join(fmt(d.get(k, "")) for k in keys))
    return "\n".join(lines)


def main():
    rows = load()
    base = [r for r in rows if not r["tag"]]
    perf = [r for r in rows if r["tag"]]
    print(f"{len(base)} baseline cells")
    print(table(base))
    if perf:
        print(f"\n{len(perf)} §Perf iteration cells")
        print(table(perf, keys=("arch", "shape", "tag", "t_compute_s",
                                "t_memory_s", "t_collective_s",
                                "roofline_frac_fused")))


if __name__ == "__main__":
    main()
