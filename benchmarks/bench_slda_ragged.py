"""Before/after wall-clock for ragged-corpus (length-bucketed) execution
(ISSUE 4): end-to-end Simple/Weighted Average at M=8 on a heavy-tailed
log-normal corpus (padding fraction ≥ 60%).

Baseline — the PADDED path as shipped after PR 3: chain-batched fused
launches at the tuned defaults (sweeps_per_launch=8, product-form
sampling, fused test+train Weighted Average prediction), every sweep
iterating all D × N_max token slots and masking the padding away.

Bucketed — the SAME algorithms routed through the ragged execution
layer (DESIGN.md §Ragged-execution): documents sorted by length and
grouped by the cost-model DP (`core.types.bucket_corpus`), the PRNG
counter stride pinned to the source max_len, inverse permutation
restoring original order.  On this CPU (jnp route) both phases run the
STAIRCASE executors — bucket widths walked as token-range segments
inside each sweep over the still-alive doc suffix, so the sequential
step count stays N_max while executed row-slots collapse to ≈ Σ true
tokens.  Same TOTAL sweeps per document on both sides.

A parity row runs the bucketed Weighted Average at sweeps_per_launch=1,
where bucketed execution is bit-identical per document to the padded
path (tests/test_ragged.py) — isolating pure schedule overhead from the
fused-family resampling.  A by-bucket-count sweep documents the
schedule-granularity tradeoff (more buckets = less intra-bucket padding
but more, smaller launches).

All rows run back-to-back in one process, INTERLEAVED round-robin
min-of-reps (this container shows ~2× cross-run wall-clock swings; the
min discards interference spikes — the BENCH_slda_train.json
methodology), with a 3-seed-mean test-MSE guard within 15% of baseline.
Writes BENCH_slda_ragged.json.

Run:  PYTHONPATH=src python -m benchmarks.bench_slda_ragged [--quick]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import platform
import time

import jax
import jax.numpy as jnp

from repro.core import SLDAConfig, build_schedule, partition, train_chains
from repro.core.parallel import (_train_chains_jit, run_simple_average,
                                 run_weighted_average)
from repro.data import make_slda_corpus, train_test_split


def _timed_round_robin(fns, reps):
    """min-of-`reps`, INTERLEAVED round-robin (see module docstring)."""
    for fn in fns:                       # warm-up (compile excluded)
        jax.block_until_ready(fn())
    best = [float("inf")] * len(fns)
    for _ in range(reps):
        for i, fn in enumerate(fns):
            t0 = time.time()
            out = fn()
            jax.block_until_ready(out)
            best[i] = min(best[i], time.time() - t0)
    return best


def run(quick: bool = False, reps: int = 3):
    if quick:   # harness smoke for CI — tiny shapes, one rep
        d_tr, d_te, w, t, n, iters, spl, m, nb = 64, 32, 128, 8, 48, 6, \
            3, 2, 4
        reps, probe_seeds, nb_sweep = 1, (), ()
    else:
        d_tr, d_te, w, t, n, iters, spl, m, nb = 512, 256, 1000, 32, 256, \
            60, 8, 8, 12
        probe_seeds, nb_sweep = (17, 18), (4, 8)
    base_cfg = SLDAConfig(n_topics=t, vocab_size=w, rho=0.25,
                          n_iters=iters, sweeps_per_launch=spl)
    bkt_cfg = dataclasses.replace(base_cfg, length_buckets=nb)
    # the paper's corpora are heavy-tailed; len_sigma=1 puts ~72% of the
    # [D, N_max] token grid in padding (the ISSUE-4 regime, ≥ 60%)
    corpus, _ = make_slda_corpus(jax.random.PRNGKey(0), d_tr + d_te, w, t,
                                 n, rho=0.25, doc_len_dist="lognormal",
                                 len_sigma=1.0, len_skew=6.0)
    train, test = train_test_split(corpus, d_tr)
    padding_frac = 1.0 - float(corpus.mask.mean())
    key = jax.random.PRNGKey(7)

    # schedule stats at the headline bucket count (the whole-corpus view;
    # the runners build their own shard/test schedules per phase)
    sched = build_schedule(corpus, bkt_cfg)
    slot_tok = corpus.tokens.size
    bkt_tok = sched.padded_tokens()
    real_tok = float(sched.real_tokens())

    jp_s = jax.jit(run_simple_average, static_argnums=(3, 4))
    jp_w = jax.jit(run_weighted_average, static_argnums=(3, 4))
    jp_t = jax.jit(train_chains, static_argnums=(2,))

    def train_bucketed(cfg):
        return _train_chains_jit(key,
                                 build_schedule(partition(train, m), cfg),
                                 cfg)

    # bucketed rows call the SAME unified entry points, un-jitted at the
    # top level (schedule construction needs concrete lengths): the
    # length_buckets>0 config routes them through the ragged plan cells
    spl1_pad = dataclasses.replace(base_cfg, sweeps_per_launch=1)
    spl1_bkt = dataclasses.replace(bkt_cfg, sweeps_per_launch=1)
    rows = [("weighted", "padded_tuned", nb),
            ("weighted", "bucketed_tuned", nb),
            ("simple", "padded_tuned", nb),
            ("simple", "bucketed_tuned", nb),
            ("train_only", "padded_tuned", nb),
            ("train_only", "bucketed_tuned", nb),
            ("weighted", "padded_spl1", 0),
            ("weighted", "bucketed_spl1", nb)]
    fns = [lambda: jp_w(key, train, test, base_cfg, m),
           lambda: run_weighted_average(key, train, test, bkt_cfg, m),
           lambda: jp_s(key, train, test, base_cfg, m),
           lambda: run_simple_average(key, train, test, bkt_cfg, m),
           lambda: jp_t(key, partition(train, m), base_cfg),
           lambda: train_bucketed(bkt_cfg),
           lambda: jp_w(key, train, test, spl1_pad, m),
           lambda: run_weighted_average(key, train, test, spl1_bkt, m)]
    for k_nb in nb_sweep:
        if k_nb == nb:
            continue
        c = dataclasses.replace(bkt_cfg, length_buckets=k_nb)
        rows.append(("weighted", "bucketed_tuned", k_nb))
        fns.append(lambda c=c: run_weighted_average(
            key, train, test, c, m))

    times = _timed_round_robin(fns, reps=reps)
    grid = [{"algorithm": a, "impl": i, "length_buckets": b,
             "seconds": round(s, 4)}
            for (a, i, b), s in zip(rows, times)]
    sec = {(a, i, b): s for (a, i, b), s in zip(rows, times)}

    # quality probe: 3-seed mean test MSE at the headline point
    def mean_mse(fn, cfg):
        ys = [fn(jax.random.PRNGKey(s), train, test, cfg, m)
              for s in (7,) + probe_seeds]
        return float(sum(float(jnp.mean((y - test.y) ** 2)) for y in ys)
                     / len(ys))

    mse_pad = mean_mse(jp_w, base_cfg)
    mse_bkt = mean_mse(run_weighted_average, bkt_cfg)

    results = {
        "padding_frac": round(padding_frac, 4),
        "slot_tokens": int(slot_tok),
        "bucketed_slot_tokens": int(bkt_tok),
        "real_tokens": int(real_tok),
        "schedule_widths": list(sched.widths),
        "schedule_counts": list(sched.counts),
        "chains": m,
        f"weighted_m{m}_padded_s": round(sec[("weighted", "padded_tuned",
                                              nb)], 4),
        f"weighted_m{m}_bucketed_s": round(
            sec[("weighted", "bucketed_tuned", nb)], 4),
        f"weighted_m{m}_speedup": round(
            sec[("weighted", "padded_tuned", nb)]
            / sec[("weighted", "bucketed_tuned", nb)], 2),
        f"simple_m{m}_speedup": round(
            sec[("simple", "padded_tuned", nb)]
            / sec[("simple", "bucketed_tuned", nb)], 2),
        "train_only_speedup": round(
            sec[("train_only", "padded_tuned", nb)]
            / sec[("train_only", "bucketed_tuned", nb)], 2),
        "weighted_spl1_speedup": round(
            sec[("weighted", "padded_spl1", 0)]
            / sec[("weighted", "bucketed_spl1", nb)], 2),
        "speedup_by_buckets": {
            str(b): round(sec[("weighted", "padded_tuned", nb)]
                          / sec[("weighted", "bucketed_tuned", b)], 2)
            for (a, i, b) in rows
            if a == "weighted" and i == "bucketed_tuned"},
        "test_mse_padded_3seed": round(mse_pad, 4),
        "test_mse_bucketed_3seed": round(mse_bkt, 4),
        "mse_guard_ok": bool(mse_bkt <= 1.15 * mse_pad),
        "tuned_defaults": {"length_buckets": nb, "bucket_token_block": 8,
                           "bucket_overhead_docs":
                               bkt_cfg.bucket_overhead_docs,
                           "sweeps_per_launch": spl},
    }

    return {
        "benchmark": "ragged-corpus length-bucketed execution (ISSUE 4)",
        "methodology": (
            f"End-to-end Simple/Weighted Average (train {iters} EM "
            f"sweeps then predict, {base_cfg.n_pred_burnin}+"
            f"{base_cfg.n_pred_samples} sweeps/doc/chain) at M={m} "
            f"chains on a log-normal synthetic sLDA corpus [D_train="
            f"{d_tr}, D_test={d_te}, W={w}, T={t}, N_max={n}, padding "
            f"{padding_frac:.0%}].  Padded rows run the PR 3 tuned "
            f"chain-batched path (sweeps_per_launch={spl}, product-form, "
            "fused test+train prediction) over the full D x N_max grid; "
            "bucketed rows run the SAME algorithms through the ragged "
            f"execution layer (length_buckets={nb}, per-bucket-padded "
            "fused launches, counter stride pinned to N_max, inverse "
            "permutation restoring order).  Same total sweeps per "
            "document on both sides; 3-seed-mean test-MSE guard within "
            "15% of baseline.  The spl1 parity rows compare the "
            "bit-identical-sampler regime (bucketed == padded per "
            "document, tests/test_ragged.py), isolating schedule "
            "overhead; speedup_by_buckets documents the granularity "
            "tradeoff.  All rows jit-compiled (bucketed runners jit "
            "their chain phases; schedule construction is timed in), "
            f"warm-up excluded, MIN of {reps} INTERLEAVED round-robin "
            "reps in ONE process (~2x container interference drift); "
            f"jnp fast paths (use_pallas=False) on "
            f"{jax.default_backend()}."),
        "platform": {"backend": jax.default_backend(),
                     "machine": platform.machine(),
                     "jax": jax.__version__},
        "shapes": {"d_train": d_tr, "d_test": d_te, "vocab": w,
                   "n_topics": t, "max_len": n, "n_iters": iters,
                   "chains": m,
                   "pred_sweeps": base_cfg.n_pred_burnin
                   + base_cfg.n_pred_samples},
        "grid": grid,
        "results": results,
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="tiny-shape harness smoke (CI); writes to --out")
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--out", default=None,
                    help="output JSON (default BENCH_slda_ragged.json, "
                         "or /tmp/BENCH_slda_ragged_quick.json with "
                         "--quick)")
    args = ap.parse_args(argv)
    out = args.out or ("/tmp/BENCH_slda_ragged_quick.json" if args.quick
                       else "BENCH_slda_ragged.json")
    payload = run(quick=args.quick, reps=args.reps)
    with open(out, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    r = payload["results"]
    m = r["chains"]
    print(f"weighted M={m}: padded {r[f'weighted_m{m}_padded_s']}s -> "
          f"bucketed {r[f'weighted_m{m}_bucketed_s']}s "
          f"({r[f'weighted_m{m}_speedup']}x) "
          f"at {r['padding_frac']:.0%} padding; by-buckets "
          f"{r['speedup_by_buckets']}; train {r['train_only_speedup']}x "
          f"spl1 {r['weighted_spl1_speedup']}x; mse "
          f"{r['test_mse_padded_3seed']} -> {r['test_mse_bucketed_3seed']} "
          f"(guard_ok={r['mse_guard_ok']}); wrote {out}")


if __name__ == "__main__":
    main()
