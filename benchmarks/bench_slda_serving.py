"""Latency/throughput of the continuous-batching sLDA prediction
service (ROADMAP item 1, `serving/slda_service.py`) under a
heavy-tailed log-normal request trace.

Two request engines serve the SAME trace over the SAME trained M-chain
ensemble:

  cached    — the production service: fixed slot layout (width ladder +
              per-rung quota calibrated from a traffic sample), plan
              cache holding DISTINCT jitted callables keyed on
              `ExecutionPlan.cache_key()`.  Steady-state dispatches
              reuse one compiled program — the benchmark ASSERTS the
              trace counter does not grow after warmup (retraces == 0).
  uncached  — the anti-pattern A/B: identical packing/dispatch, but a
              fresh `jax.jit` per flush, so every micro-batch pays a
              full retrace no matter how the static args hash.  The
              cached/uncached latency ratio is the price the plan cache
              removes.

The trace mixes fresh documents (log-normal lengths, the paper's
heavy-tailed profile) with content repeats; repeats exercise the
theta/ŷ result cache and are reported separately (a cache hit never
occupies a slot).  Latency is submit→result per request (queueing
inside the open micro-batch included — that's what a caller sees);
p50/p99 over the steady-state window plus docs/s throughput.

Exactness guard: for 3 seeds, the full trace is served by the cached
service AND replayed through the uncached plan-layer path; per-request
ŷ must match BITWISE (the serving machinery adds zero deviation versus
the offline bucketed plan path), and the 3-seed mean squared
difference is asserted to be exactly 0.0.

Writes BENCH_slda_serving.json (or /tmp/..._quick.json with --quick).

Run:  PYTHONPATH=src python -m benchmarks.bench_slda_serving [--quick]
"""
from __future__ import annotations

import argparse
import json
import platform
import time

import jax
import numpy as np

from repro.core import SLDAConfig, partition, train_chains
from repro.data import make_slda_corpus
from repro.serving import ServiceConfig, SLDAPredictionService
from repro.serving.slda_service import _combine_yhat


class _UncachedService(SLDAPredictionService):
    """The retrace-every-batch baseline: same packing, same plan layer,
    but a fresh jit (fresh, empty trace cache) per flush."""

    def _dispatch_fn(self, plan_key):
        self._trace_counts[plan_key] += 1        # count what we pay for
        rule = self.svc.combine

        def dispatch(keys, models, plan, chain_weights):
            zb = plan.predict_zbar(keys, models)
            yhat = jax.vmap(lambda z, e: z @ e)(zb, models.eta)
            return zb, yhat, _combine_yhat(rule, yhat, chain_weights,
                                           models.train_mse)

        return jax.jit(dispatch)


def make_trace(seed: int, n_req: int, vocab: int, max_len: int, *,
               len_sigma: float = 1.0, repeat_frac: float = 0.25):
    """Heavy-tailed request trace: log-normal lengths clipped to
    [1, max_len], with `repeat_frac` of requests re-submitting an
    earlier document verbatim (result-cache traffic)."""
    rng = np.random.default_rng(seed)
    mu = np.log(max(2.0, max_len / 6.0))
    docs = []
    for _ in range(n_req):
        if docs and rng.random() < repeat_frac:
            docs.append(docs[int(rng.integers(len(docs)))])
            continue
        L = int(np.clip(np.rint(rng.lognormal(mu, len_sigma)), 1, max_len))
        docs.append(rng.integers(0, vocab, size=L).astype(np.int32))
    return docs


def _serve(service, trace):
    """Closed-loop replay: submit as fast as the service accepts,
    drain at end.  Returns (wall_s, results in submit order)."""
    t0 = time.perf_counter()
    rids = [service.submit(d) for d in trace]
    service.drain()
    wall = time.perf_counter() - t0
    return wall, [service.result(r) for r in rids]


def _pctl(xs, q):
    return float(np.percentile(np.asarray(xs), q)) if len(xs) else 0.0


def run(quick: bool = False):
    if quick:   # harness smoke for CI — tiny shapes
        d_tr, w, t, n, iters, m = 64, 128, 8, 48, 6, 2
        batch, n_buckets, n_req, seeds = 16, 3, 96, (7, 17, 27)
    else:
        d_tr, w, t, n, iters, m = 512, 1000, 32, 256, 60, 8
        batch, n_buckets, n_req, seeds = 32, 4, 512, (7, 17, 27)
    cfg = SLDAConfig(n_topics=t, vocab_size=w, rho=0.25, n_iters=iters)
    corpus, _ = make_slda_corpus(jax.random.PRNGKey(0), d_tr, w, t, n,
                                 rho=0.25, doc_len_dist="lognormal",
                                 len_sigma=1.0, len_skew=6.0)
    models = train_chains(jax.random.PRNGKey(1), partition(corpus, m), cfg)
    lens = np.asarray(corpus.mask.sum(-1)).astype(int)
    svc_cfg = ServiceConfig.calibrated(lens, max_doc_len=n,
                                       batch_docs=batch,
                                       n_buckets=n_buckets)
    trace = make_trace(123, n_req, w, n)

    # ---- cached service: warmup batch, then the timed steady state
    svc = SLDAPredictionService(models, cfg, svc_cfg,
                                key=jax.random.PRNGKey(7))
    warm, steady = trace[:batch], trace[batch:]
    _serve(svc, warm)
    warm_traces = svc.stats()["traces"]
    wall, results = _serve(svc, steady)
    st = svc.stats()
    steady_retraces = st["traces"] - warm_traces
    assert steady_retraces == 0, (
        f"steady-state traffic retraced {steady_retraces}x — the plan "
        f"cache is broken (signatures: {st['traces_by_signature']})")
    fresh = [r.latency_s for r in results if not r.from_cache]
    hits = [r.latency_s for r in results if r.from_cache]

    # ---- uncached A/B over a slice (every batch retraces — pricey)
    ab = steady[: 4 * batch]
    un = _UncachedService(models, cfg, svc_cfg, key=jax.random.PRNGKey(7))
    un_wall, _ = _serve(un, ab)
    svc2 = SLDAPredictionService(models, cfg, svc_cfg,
                                 key=jax.random.PRNGKey(7))
    _serve(svc2, warm)                    # same warmup discipline
    ab_wall, _ = _serve(svc2, ab)

    # ---- 3-seed exactness guard vs the offline (uncached) plan path
    sq_diffs = []
    for s in seeds:
        a = SLDAPredictionService(models, cfg, svc_cfg,
                                  key=jax.random.PRNGKey(s))
        b = _UncachedService(models, cfg, svc_cfg,
                             key=jax.random.PRNGKey(s))
        _, ra = _serve(a, trace)
        _, rb = _serve(b, trace)
        ya = np.asarray([r.yhat for r in ra])
        yb = np.asarray([r.yhat for r in rb])
        assert np.array_equal(ya, yb), (
            f"seed {s}: served yhat deviates from the offline plan path")
        sq_diffs.append(float(np.mean((ya - yb) ** 2)))
    mse_vs_offline = float(np.mean(sq_diffs))
    assert mse_vs_offline == 0.0

    results_d = {
        "requests_steady": len(steady),
        "throughput_docs_per_s": round(len(steady) / wall, 2),
        "latency_p50_ms": round(_pctl(fresh, 50) * 1e3, 3),
        "latency_p99_ms": round(_pctl(fresh, 99) * 1e3, 3),
        "cache_hit_latency_p50_ms": round(_pctl(hits, 50) * 1e3, 4),
        "result_cache_hits": st["result_cache_hits"],
        "result_cache_hit_frac": round(len(hits) / len(results), 4),
        "steady_state_retraces": steady_retraces,
        "traces_total": st["traces"],
        "compiled_plans": st["compiled_plans"],
        "dispatches": st["dispatches"],
        "dummy_slot_frac": st["dummy_slot_frac"],
        "width_ladder": st["width_ladder"],
        "slot_quota": st["slot_quota"],
        "chains": m,
        "uncached_wall_s": round(un_wall, 4),
        "cached_wall_s": round(ab_wall, 4),
        "plan_cache_speedup": round(un_wall / ab_wall, 2),
        "mse_vs_offline_3seed": mse_vs_offline,
        "exact_match_ok": bool(mse_vs_offline == 0.0),
    }
    return {
        "benchmark": "continuous-batching sLDA prediction service",
        "methodology": (
            f"A {n_req}-request closed-loop trace (log-normal lengths, "
            f"max {n}, ~25% verbatim repeats) served by the M={m}-chain "
            f"ensemble through the fixed-slot micro-batcher (ladder "
            f"{list(svc_cfg.width_ladder)}, quota "
            f"{list(svc_cfg.slot_quota)}, {batch} slots/batch).  Latency "
            "is submit->result per request including in-batch queueing; "
            "p50/p99 over the post-warmup window, fresh dispatches only "
            "(result-cache hits reported separately).  The steady-state "
            "retrace count is ASSERTED zero — every dispatch after the "
            "first reuses the one compiled program cached by bucket "
            "signature.  The uncached A/B replays a slice through a "
            "fresh jax.jit per flush (full retrace per micro-batch); "
            "plan_cache_speedup is that ratio.  Exactness: for "
            f"{len(seeds)} seeds the full trace is replayed through the "
            "uncached offline plan path and per-request yhat must match "
            "bitwise (mse_vs_offline_3seed == 0.0, asserted); jnp fast "
            f"paths on {jax.default_backend()}."),
        "platform": {"backend": jax.default_backend(),
                     "machine": platform.machine(),
                     "jax": jax.__version__},
        "shapes": {"d_train": d_tr, "vocab": w, "n_topics": t,
                   "max_len": n, "n_iters": iters, "chains": m,
                   "batch_docs": batch, "n_requests": n_req,
                   "pred_sweeps": cfg.n_pred_burnin + cfg.n_pred_samples},
        "results": results_d,
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="tiny-shape harness smoke (CI); writes to --out")
    ap.add_argument("--out", default=None,
                    help="output JSON (default BENCH_slda_serving.json, "
                         "or /tmp/BENCH_slda_serving_quick.json with "
                         "--quick)")
    args = ap.parse_args(argv)
    out = args.out or ("/tmp/BENCH_slda_serving_quick.json" if args.quick
                       else "BENCH_slda_serving.json")
    payload = run(quick=args.quick)
    with open(out, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    r = payload["results"]
    print(f"serving M={r['chains']}: {r['throughput_docs_per_s']} docs/s, "
          f"p50 {r['latency_p50_ms']}ms p99 {r['latency_p99_ms']}ms "
          f"(cache-hit p50 {r['cache_hit_latency_p50_ms']}ms, "
          f"hit-frac {r['result_cache_hit_frac']}); steady retraces "
          f"{r['steady_state_retraces']}, plan-cache speedup "
          f"{r['plan_cache_speedup']}x; exact_match_ok="
          f"{r['exact_match_ok']}; wrote {out}")


if __name__ == "__main__":
    main()
