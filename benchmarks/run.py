"""Benchmark harness entry point — one registry entry per paper
figure/table or perf artifact.

  fig6  MD&A (continuous y): 4 algorithms × (time, test MSE)     [Fig. 6]
  fig7  IMDB (binary y): 4 algorithms × (time, test accuracy)    [Fig. 7]
  kernels  per-kernel µs/call
  roofline  aggregated dry-run roofline table (if artifacts exist)
  opt-in extras (--only): ablation, slda_predict, slda_train,
  slda_parallel, slda_ragged, slda_robust, slda_elastic, slda_serving,
  slda_serving_robust — the sLDA perf suites (quick shapes
  unless --full; headline A/B rows printed; run each bench module's
  own __main__ to write the JSON artifacts).

Every sLDA bench routes through the unified execution-plan entry
points (`core.plan.build_schedule` + the plan-driven `run_*`
orchestrators — DESIGN.md §Execution-plan), so a benched configuration
is exactly a dispatch-matrix cell; `python -m repro.launch.dryrun
--slda-plan` prints the plan a given config resolves to before paying
for a run.

Prints ``name,us_per_call,derived`` CSV rows plus per-figure detail.
Use --full for the paper-scale corpora (minutes on CPU).
"""
from __future__ import annotations

import argparse
import sys


def _bench_fig6(args):
    from . import fig6_mdna
    scale = 1.0 if args.full else 0.1
    for r in fig6_mdna.run(scale=scale):
        print(f"fig6_{r['algorithm']},{r['wall_s'] * 1e6:.0f},"
              f"mse={r['test_mse']};modeled_s={r['modeled_s']}")


def _bench_fig7(args):
    from . import fig7_imdb
    scale = 1.0 if args.full else 0.02
    for r in fig7_imdb.run(scale=scale):
        print(f"fig7_{r['algorithm']},{r['wall_s'] * 1e6:.0f},"
              f"acc={r['test_acc']};modeled_s={r['modeled_s']}")


def _bench_ablation(args):
    # beyond-paper: quality vs chain count (slow — opt-in)
    from . import ablation_chains
    for r in ablation_chains.run():
        print(f"ablation_m{r['m']}_{r['rule']},0,mse={r['mse']}")


def _bench_kernels(args):
    from . import kernels_bench
    for r in kernels_bench.run():
        print(f"kernel_{r['name']},{r['us_per_call']},{r['derived']}")


def _bench_slda_predict(args):
    # end-to-end before/after for the fused prediction path (slow —
    # trains 8 chains twice; opt-in).  `python -m
    # benchmarks.bench_slda_predict` writes the JSON artifact.
    from . import bench_slda_predict
    payload = bench_slda_predict.run(scale=1.0 if args.full else 0.25)
    r = payload["results"]
    for k in ("weighted_average_seed_s", "weighted_average_fused_s"):
        print(f"slda_predict_{k},{r[k] * 1e6:.0f},"
              f"speedup={r['weighted_average_speedup']}x")


def _bench_slda_train(args):
    from . import bench_slda_train
    r = bench_slda_train.run(scale=1.0 if args.full else 0.25,
                             reps=5 if args.full else 1)["results"]
    print(f"slda_train_chain,{r['train_chain_fused_s'] * 1e6:.0f},"
          f"speedup={r['train_chain_speedup']}x")


def _bench_slda_parallel(args):
    from . import bench_slda_parallel
    r = bench_slda_parallel.run(quick=not args.full)["results"]
    print(f"slda_parallel_weighted,"
          f"{r['weighted_m8_batched_s'] * 1e6:.0f},"
          f"speedup={r['weighted_m8_speedup']}x;"
          f"mse_guard_ok={r['mse_guard_ok']}")


def _bench_slda_ragged(args):
    from . import bench_slda_ragged
    payload = bench_slda_ragged.run(quick=not args.full)
    r, m = payload["results"], payload["results"]["chains"]
    print(f"slda_ragged_weighted,"
          f"{r[f'weighted_m{m}_bucketed_s'] * 1e6:.0f},"
          f"speedup={r[f'weighted_m{m}_speedup']}x;"
          f"padding={r['padding_frac']};mse_guard_ok={r['mse_guard_ok']}")


def _bench_slda_robust(args):
    from . import bench_slda_robust
    r = bench_slda_robust.run(quick=not args.full)["results"]
    print(f"slda_robust_checks_on,{r['checks_on_s'] * 1e6:.0f},"
          f"overhead={r['health_check_overhead_frac']};"
          f"overhead_ok={r['health_check_overhead_ok']};"
          f"degraded_mse_guard_ok={r['degraded_mse_guard_ok']}")


def _bench_slda_elastic(args):
    from . import bench_slda_elastic
    r = bench_slda_elastic.run(quick=not args.full)["results"]
    print(f"slda_elastic_async_ckpt,{r['async_ckpt_s'] * 1e6:.0f},"
          f"async_vs_sync={r['async_vs_sync_frac']};"
          f"async_ok={r['async_ckpt_overhead_ok']};"
          f"kill_bitwise_ok={r['kill_device_survivors_bitwise_ok']};"
          f"retrace0_ok={r['zero_retraces_across_repack_ok']};"
          f"resume_bitwise_ok={r['preempt_resume_bitwise_ok']};"
          f"rounds_lost={r['preempt_rounds_lost']};"
          f"degraded_mse_guard_ok={r['degraded_mse_guard_ok']}")


def _bench_slda_serving(args):
    from . import bench_slda_serving
    r = bench_slda_serving.run(quick=not args.full)["results"]
    print(f"slda_serving_p50,{r['latency_p50_ms'] * 1e3:.0f},"
          f"p99_ms={r['latency_p99_ms']};"
          f"docs_per_s={r['throughput_docs_per_s']};"
          f"retraces={r['steady_state_retraces']};"
          f"cache_speedup={r['plan_cache_speedup']}x;"
          f"exact_match_ok={r['exact_match_ok']}")


def _bench_slda_serving_robust(args):
    from . import bench_slda_serving_robust
    r = bench_slda_serving_robust.run(quick=not args.full)["results"]
    print(f"slda_serving_robust_p99,"
          f"{r['burst_with_admission']['latency_p99_s'] * 1e6:.0f},"
          f"p99_bounded_ok={r['p99_bounded_ok']};"
          f"shed_frac={r['burst_with_admission']['shed_frac']};"
          f"checks_overhead={r['robust_checks_overhead']};"
          f"checks_overhead_ok={r['checks_overhead_ok']};"
          f"reload_retraces={r['reload_retraces']};"
          f"degraded_exact_ok={r['degraded_exact_ok']}")


def _bench_slda_sparse(args):
    from . import bench_slda_sparse
    r = bench_slda_sparse.run(quick=not args.full)["results"]
    speed = ";".join(f"T{t}={s}x"
                     for t, s in r["speedup_by_topics"].items())
    modeled = ";".join(f"T{t}={s}x"
                       for t, s in r["modeled_speedup_by_topics"].items())
    print(f"slda_sparse,0,measured:{speed};modeled:{modeled};"
          f"mse_guard_ok={r['mse_guard_ok']};"
          f"dense_wins_small_t={r['dense_wins_small_t']}")


def _bench_roofline(args):
    try:
        from . import roofline
        rows = roofline.load()
        for d in rows:
            tag = (f"{d['arch']}_{d['shape']}_"
                   f"{'multi' if d['multi_pod'] else 'single'}")
            print(f"roofline_{tag},{d['compile_s'] * 1e6:.0f},"
                  f"dom={d['dominant']};frac={d['roofline_frac']:.3f}")
    except Exception as e:  # noqa: BLE001 — artifacts may not exist yet
        print(f"roofline_skipped,0,{e!r}", file=sys.stderr)


#: name → (runner, run_by_default) — opt-in extras run only via --only
BENCHES = {
    "fig6": (_bench_fig6, True),
    "fig7": (_bench_fig7, True),
    "ablation": (_bench_ablation, False),
    "kernels": (_bench_kernels, True),
    "slda_predict": (_bench_slda_predict, False),
    "slda_train": (_bench_slda_train, False),
    "slda_parallel": (_bench_slda_parallel, False),
    "slda_ragged": (_bench_slda_ragged, False),
    "slda_robust": (_bench_slda_robust, False),
    "slda_elastic": (_bench_slda_elastic, False),
    "slda_serving": (_bench_slda_serving, False),
    "slda_serving_robust": (_bench_slda_serving_robust, False),
    "slda_sparse": (_bench_slda_sparse, False),
    "roofline": (_bench_roofline, True),
}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale corpora (slow on CPU)")
    ap.add_argument("--only", default=None,
                    help="comma list from the registry: "
                         + ",".join(BENCHES))
    args = ap.parse_args(argv)
    only = set(args.only.split(",")) if args.only else None
    unknown = (only or set()) - set(BENCHES)
    if unknown:
        ap.error(f"unknown bench(es): {sorted(unknown)}")

    print("name,us_per_call,derived")
    for name, (fn, default_on) in BENCHES.items():
        if (only is None and default_on) or (only is not None
                                             and name in only):
            fn(args)


if __name__ == "__main__":
    main()
