"""Benchmark harness entry point — one function per paper figure/table.

  fig6  MD&A (continuous y): 4 algorithms × (time, test MSE)     [Fig. 6]
  fig7  IMDB (binary y): 4 algorithms × (time, test accuracy)    [Fig. 7]
  kernels  per-kernel µs/call
  slda_predict  fused-prediction before/after → BENCH_slda_predict.json
  roofline  aggregated dry-run roofline table (if artifacts exist)

Prints ``name,us_per_call,derived`` CSV rows plus per-figure detail.
Use --full for the paper-scale corpora (minutes on CPU).
"""
from __future__ import annotations

import argparse
import sys


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale corpora (slow on CPU)")
    ap.add_argument("--only", default=None,
                    help="comma list: fig6,fig7,kernels,roofline; opt-in "
                         "extras: ablation,slda_predict")
    args = ap.parse_args(argv)
    only = set(args.only.split(",")) if args.only else None

    print("name,us_per_call,derived")
    if only is None or "fig6" in only:
        from . import fig6_mdna
        scale = 1.0 if args.full else 0.1
        rows = fig6_mdna.run(scale=scale)
        for r in rows:
            print(f"fig6_{r['algorithm']},{r['wall_s'] * 1e6:.0f},"
                  f"mse={r['test_mse']};modeled_s={r['modeled_s']}")
    if only is None or "fig7" in only:
        from . import fig7_imdb
        scale = 1.0 if args.full else 0.02
        rows = fig7_imdb.run(scale=scale)
        for r in rows:
            print(f"fig7_{r['algorithm']},{r['wall_s'] * 1e6:.0f},"
                  f"acc={r['test_acc']};modeled_s={r['modeled_s']}")
    if only is not None and "ablation" in only:
        # beyond-paper: quality vs chain count (slow — opt-in)
        from . import ablation_chains
        for r in ablation_chains.run():
            print(f"ablation_m{r['m']}_{r['rule']},0,mse={r['mse']}")
    if only is None or "kernels" in only:
        from . import kernels_bench
        for r in kernels_bench.run():
            print(f"kernel_{r['name']},{r['us_per_call']},{r['derived']}")
    if only is not None and "slda_predict" in only:
        # end-to-end before/after for the fused prediction path (slow —
        # trains 8 chains twice; opt-in).  `python -m
        # benchmarks.bench_slda_predict` writes the JSON artifact.
        from . import bench_slda_predict
        payload = bench_slda_predict.run(scale=1.0 if args.full else 0.25)
        r = payload["results"]
        for k in ("weighted_average_seed_s", "weighted_average_fused_s"):
            print(f"slda_predict_{k},{r[k] * 1e6:.0f},"
                  f"speedup={r['weighted_average_speedup']}x")
    if only is None or "roofline" in only:
        try:
            from . import roofline
            rows = roofline.load()
            for d in rows:
                tag = (f"{d['arch']}_{d['shape']}_"
                       f"{'multi' if d['multi_pod'] else 'single'}")
                print(f"roofline_{tag},{d['compile_s'] * 1e6:.0f},"
                      f"dom={d['dominant']};frac={d['roofline_frac']:.3f}")
        except Exception as e:  # noqa: BLE001 — artifacts may not exist yet
            print(f"roofline_skipped,0,{e!r}", file=sys.stderr)


if __name__ == "__main__":
    main()
