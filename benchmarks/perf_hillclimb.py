"""§Perf hillclimb driver: re-lower + re-analyse the three chosen cells
under cumulative optimization switches, writing one artifact per iteration
(suffix `__itN_<name>`).  The hypothesis → change → before/after log lives
in EXPERIMENTS.md §Perf; this script produces the numbers.

Cells (chosen from the baseline table, see EXPERIMENTS.md §Roofline):
  A. internlm2-1.8b × train_4k × 1-pod   — 16 comm-free chains; the cell
     most representative of the paper's technique
  B. qwen2.5-32b × prefill_32k × 1-pod   — most collective-bound cell
  C. phi3.5-moe-42b × train_4k × 1-pod   — worst train roofline fraction

Run:  PYTHONPATH=src python -m benchmarks.perf_hillclimb [--cell A|B|C]
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

import argparse
import json
import time

PLANS = {
    "A": ("internlm2-1.8b", "train_4k", [
        ("it1_causal_skip", dict(opt_causal_attention=True)),
        ("it2_embed_repl", dict(opt_causal_attention=True,
                                opt_replicate_embed=True)),
        ("it3_remat_dots", dict(opt_causal_attention=True,
                                opt_replicate_embed=True,
                                remat_policy="dots")),
        # it1-3 learnings: tri-scan regressed memory; embed/remat no-ops
        # here.  it4 attacks the DOMINANT fused-view term: the per-q-block
        # dK/dV pair all-reduces inside the attention scan — kill the scan.
        ("it4_block4k", dict(opt_attn_block_q=4096)),
        # it5: cell-B learning applied here — internlm2's kv=8 < model=16
        # also gets its kv head_dim split → the [128,2] pair all-reduces.
        ("it5_head_shard", dict(opt_attn_block_q=4096,
                                opt_head_shard=True)),
    ]),
    "B": ("qwen2.5-32b", "prefill_32k", [
        ("it1_last_token", dict(opt_prefill_last_only=True)),
        ("it2_causal_skip", dict(opt_prefill_last_only=True,
                                 opt_causal_attention=True)),
        # it1/it2 learning: the 90 TB all-reduce is GSPMD sharding HEAD_DIM
        # (40 heads % 16 ≠ 0 → it splits hd, making attention einsums
        # partial-sum).  it3 pins heads to the model axis (padded 40→48).
        ("it3_head_shard", dict(opt_prefill_last_only=True,
                                opt_causal_attention=True,
                                opt_head_shard=True)),
    ]),
    "C": ("phi3.5-moe-42b-a6.6b", "train_4k", [
        ("it1_causal_skip", dict(opt_causal_attention=True)),
        ("it2_head_shard", dict(opt_causal_attention=True,
                                opt_head_shard=True)),
        ("it3_embed_repl", dict(opt_causal_attention=True,
                                opt_head_shard=True,
                                opt_replicate_embed=True)),
    ]),
}


def main():
    from repro.launch.dryrun import run_cell

    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", default=None, choices=sorted(PLANS))
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args()
    cells = [args.cell] if args.cell else sorted(PLANS)

    for cell in cells:
        arch, shape, iters = PLANS[cell]
        for name, overrides in iters:
            t0 = time.time()
            try:
                meta = run_cell(arch, shape, False, args.out, verbose=False,
                                dist_overrides=overrides,
                                tag_suffix=f"__{name}")
                print(f"PASS {cell} {name}: compute={meta['t_compute_s']:.3g}"
                      f" mem={meta['t_memory_s']:.3g}"
                      f" coll={meta['t_collective_s']:.3g}"
                      f" ({time.time() - t0:.0f}s)")
            except Exception as e:  # noqa: BLE001
                print(f"FAIL {cell} {name}: {e!r}")


if __name__ == "__main__":
    main()
