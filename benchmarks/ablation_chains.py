"""Ablation (beyond the paper's fixed M=4): prediction quality vs chain
count for each combination rule.

The paper's trade-off is implicit: more chains = more speedup but less
data per chain.  This sweep makes it explicit and adds the median rule.
Expectation from theory: Simple/Weighted degrade gracefully (ensemble
averaging compensates per-chain variance), Naive degrades *faster* with M
(more quasi-ergodic modes to disagree).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import SLDAConfig, ALGORITHMS, combine, partition, \
    predict_chains, train_chains
from repro.data import make_slda_corpus, train_test_split


def run(n_docs=512, vocab=300, n_topics=8, doc_len=60, n_iters=30, seed=0):
    cfg = SLDAConfig(n_topics=n_topics, vocab_size=vocab, rho=0.25,
                     n_iters=n_iters)
    corpus, _ = make_slda_corpus(jax.random.PRNGKey(seed), n_docs, vocab,
                                 n_topics, doc_len, rho=0.25)
    train, test = train_test_split(corpus, int(n_docs * 0.8) // 8 * 8)
    var_y = float(jnp.var(test.y))
    rows = []

    yhat = jax.jit(ALGORITHMS["nonparallel"], static_argnums=(3,))(
        jax.random.PRNGKey(seed + 1), train, test, cfg)
    rows.append(dict(m=1, rule="nonparallel",
                     mse=round(float(jnp.mean((yhat - test.y) ** 2)), 4)))

    for m in (2, 4, 8):
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed + 1), 3)
        models = jax.jit(train_chains, static_argnums=(2,))(
            k1, partition(train, m), cfg)
        yh = jax.jit(predict_chains, static_argnums=(3,))(
            k2, models, test, cfg)
        naive = jax.jit(ALGORITHMS["naive"], static_argnums=(3, 4))(
            k3, train, test, cfg, m)
        for rule, pred in (
                ("naive", naive),
                ("simple", combine.simple_average(yh)),
                ("weighted", combine.weighted_average(
                    yh, train_mse=models.train_mse)),
                ("median", combine.median(yh))):
            mse = float(jnp.mean((pred - test.y) ** 2))
            rows.append(dict(m=m, rule=rule, mse=round(mse, 4),
                             r2=round(1 - mse / var_y, 3)))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
