"""JSONL metric logging — append-only, crash-safe, restart-friendly
(re-logging a step after restart simply supersedes the earlier line)."""
from __future__ import annotations

import json
import os
import time


class MetricLogger:
    def __init__(self, path: str | None):
        self.path = path
        if path:
            os.makedirs(os.path.dirname(path) or ".", exist_ok=True)

    def log(self, step: int, **metrics):
        rec = {"step": step, "time": time.time()}
        for k, v in metrics.items():
            if hasattr(v, "tolist"):
                v = v.tolist()
            rec[k] = v
        if self.path:
            with open(self.path, "a") as f:
                f.write(json.dumps(rec) + "\n")
                f.flush()
        return rec

    def read(self):
        if not self.path or not os.path.exists(self.path):
            return []
        rows = {}
        with open(self.path) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    rec = json.loads(line)
                except json.JSONDecodeError:
                    continue           # trailing partial line after a crash
                rows[rec["step"]] = rec     # later lines supersede
        return [rows[s] for s in sorted(rows)]


def throughput_tokens_per_s(global_batch: int, seq_len: int,
                            step_seconds: float) -> float:
    return global_batch * seq_len / max(step_seconds, 1e-9)
