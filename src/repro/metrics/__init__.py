"""Metrics substrate: step logging + chain-ensemble health."""
from .log import MetricLogger, throughput_tokens_per_s
from .ensemble import chain_divergence, ensemble_health, robust_z

__all__ = ["MetricLogger", "throughput_tokens_per_s", "chain_divergence",
           "ensemble_health", "robust_z"]
