"""Chain-ensemble health.

Communication-free chains never synchronize, so operators need a cheap
signal for (a) a diverging/NaN chain that should be dropped from the
combine, and (b) ensemble collapse (chains too similar → no ensembling
benefit).  Both come from per-chain predictions on a tiny probe batch —
KBs of traffic, evaluated out-of-band, never touching the training path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def chain_divergence(logits) -> jnp.ndarray:
    """Mean pairwise symmetric KL between chains' token distributions.
    logits: [C, ..., V] → scalar per chain pair average [C, C]."""
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    p = jnp.exp(logp)
    # KL(i || j) averaged over all positions
    kl = jnp.einsum("c...v,d...v->cd",
                    p, logp) * -1.0 + jnp.einsum("c...v,c...v->c",
                                                 p, logp)[:, None]
    n = p.size // (p.shape[0] * p.shape[-1])
    kl = kl / n
    return 0.5 * (kl + kl.T)


def robust_z(values, valid=None, *, rel_floor: float = 0.0) -> jnp.ndarray:
    """Robust z-scores (median / MAD) of a 1-D statistic, jit-safe.

    Non-finite entries — and entries masked out by the optional boolean
    `valid` — are excluded from the location/scale estimate (nanmedian
    over the valid subset) and come back as +inf, so downstream
    `z < cut` tests treat them as maximal outliers.  `rel_floor` clamps
    the scale to at least `rel_floor · |median|` — with a handful of
    near-identical values the MAD degenerates to ~0 and any rounding
    jitter becomes an "outlier"; the floor makes the score mean "several
    times the typical level", which is what a divergence check wants.
    This is the ONE copy of the outlier score, shared by the out-of-band
    `ensemble_health` probe and the supervisor's in-scan train-MSE check
    (`core.supervisor` — where host-side `int()` casts are illegal)."""
    v = jnp.asarray(values, jnp.float32)
    ok = jnp.isfinite(v)
    if valid is not None:
        ok = ok & (valid > 0)
    vals = jnp.where(ok, v, jnp.nan)
    med = jnp.nanmedian(vals)
    mad = jnp.nanmedian(jnp.abs(vals - med))
    scale = jnp.maximum(1.4826 * mad, rel_floor * jnp.abs(med)) + 1e-9
    z = (v - med) / scale
    return jnp.where(ok & jnp.isfinite(z), z, jnp.inf)


def ensemble_health(per_chain_loss, logits=None, *, loss_z_cut: float = 4.0,
                    collapse_kl: float = 1e-3):
    """Returns (alive [C] float mask, report dict).

    A chain is marked dead if its probe loss is non-finite or further than
    `loss_z_cut` robust z-scores above the chain median (diverged).
    `collapsed` flags an ensemble whose surviving chains are nearly
    identical (median pairwise KL below `collapse_kl`)."""
    loss = jnp.asarray(per_chain_loss, jnp.float32)
    finite = jnp.isfinite(loss)
    z = robust_z(loss)
    alive = (finite & (z < loss_z_cut)).astype(jnp.float32)

    report = {"loss": loss, "z": z, "alive": alive, "collapsed": False}
    if logits is not None and int(alive.sum()) >= 2:
        kl = chain_divergence(logits)
        c = kl.shape[0]
        mask = (alive[:, None] * alive[None, :]
                * (1 - jnp.eye(c)))
        vals = jnp.where(mask > 0, kl, jnp.nan)
        med_kl = jnp.nanmedian(vals)
        report["median_pairwise_kl"] = med_kl
        report["collapsed"] = bool(med_kl < collapse_kl)
    return alive, report
