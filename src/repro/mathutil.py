"""Leaf-module numeric helpers shared by core and kernels.

Import-dependency-free (jax only): `core` must stay importable without
pulling the Pallas kernel stack, and `kernels` modules must be usable
without importing `core` — anything both sides need lives here.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def upper_tri_ones(n: int):
    """U[j, k] = 1 ⇔ j ≤ k: the prefix-sum-as-matmul contraction matrix.

    Single definition for every sLDA sampler (train + predict kernels,
    oracles, jnp fast paths): `p @ U` is rounding-critical — the bitwise
    kernel/ref/jnp equivalence the tests assert holds only while all
    paths share the exact same contraction.  Built from broadcasted_iota
    so it also lowers inside Pallas kernels.
    """
    return (jax.lax.broadcasted_iota(jnp.int32, (n, n), 0)
            <= jax.lax.broadcasted_iota(jnp.int32, (n, n), 1)
            ).astype(jnp.float32)
