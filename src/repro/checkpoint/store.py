"""Per-chain checkpoint store.

Chains share nothing (the paper's communication-free property), so the
checkpoint layout is **per-chain**: one .npz per chain per step plus a tiny
manifest.  Consequences the tests verify:

  * a chain failure never corrupts other chains' state — restart restores
    the survivors and the failed chain alone re-inits (fault isolation),
  * elastic rescale: restore onto MORE chains (new ones init fresh) or
    FEWER chains (a prefix of the ensemble) without touching the rest,
  * atomicity: writes go to a temp dir, fsync'd, then os.replace'd; a
    half-written checkpoint is never visible under its final name.

Format: flat {pytree-path: array} in numpy .npz — no pickle, portable.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import zipfile

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return {jax.tree_util.keystr(path): np.asarray(leaf)
            for path, leaf in flat}, treedef


def _chain_slice(tree, i):
    return jax.tree.map(lambda x: x[i] if hasattr(x, "ndim") and x.ndim > 0
                        else x, tree)


def save_checkpoint(ckpt_dir: str, step: int, state: dict, *,
                    n_chains: int | None = None, extra: dict | None = None):
    """state: pytree whose array leaves have a leading chain dim (scalars
    like the opt step counter are replicated into every chain file)."""
    if n_chains is None:
        n_chains = jax.tree.leaves(state)[0].shape[0]
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_")
    try:
        for i in range(n_chains):
            flat, _ = _flatten(_chain_slice(state, i))
            path = os.path.join(tmp, f"chain_{i:03d}.npz")
            with open(path, "wb") as f:
                np.savez(f, **flat)
                f.flush()
                os.fsync(f.fileno())
        manifest = {"step": step, "n_chains": n_chains,
                    "extra": extra or {}}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)          # atomic publish
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    return final


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_")
             and os.path.exists(os.path.join(ckpt_dir, d, "manifest.json"))]
    return max(steps) if steps else None


def list_chains(ckpt_dir: str, step: int) -> list[int]:
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    return sorted(int(f.split("_")[1].split(".")[0])
                  for f in os.listdir(d) if f.startswith("chain_"))


def _load_manifest(step_dir: str, step: int) -> dict:
    """Read + validate a step's manifest (handle closed promptly — the
    old `json.load(open(...))` leaked the fd until GC).  A manifest whose
    recorded step disagrees with the directory name means a torn or
    hand-copied checkpoint; restoring it silently would resume training
    from the wrong point, so fail loudly instead."""
    with open(os.path.join(step_dir, "manifest.json")) as f:
        manifest = json.load(f)
    if manifest.get("step") != step:
        raise ValueError(
            f"checkpoint manifest in {step_dir} records step "
            f"{manifest.get('step')!r}, expected {step} — torn or "
            "mislabelled checkpoint")
    return manifest


def _unflatten_into(template_chain, flat):
    paths, treedef = jax.tree_util.tree_flatten_with_path(template_chain)
    leaves = []
    for path, tmpl in paths:
        key = jax.tree_util.keystr(path)
        arr = flat[key]
        leaves.append(jnp.asarray(arr, dtype=tmpl.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def read_manifest(ckpt_dir: str, step: int) -> dict:
    """Public validated-manifest read — what a serving-tier reload uses
    to vet a checkpoint before paying to load any chain file.  Raises on
    a missing/torn/mislabelled manifest (`_load_manifest` contract)."""
    return _load_manifest(os.path.join(ckpt_dir, f"step_{step:08d}"), step)


def restore_checkpoint(ckpt_dir: str, step: int, template):
    """Restore all chains recorded in the manifest; template is a pytree
    with the target leading chain dim (its values are ignored).  The
    manifest's chain count must MATCH the template's — a hot-reloading
    service that silently changed ensemble size mid-stream would break
    every [M]-shaped jit signature downstream; elastic rescale is the
    explicit `restore_elastic` path."""
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    manifest = _load_manifest(d, step)
    n = manifest["n_chains"]
    target = jax.tree.leaves(template)[0].shape[0]
    if n != target:
        raise ValueError(
            f"checkpoint at step {step} holds {n} chains, template "
            f"expects {target} — use restore_elastic for rescale")
    chains = []
    tmpl0 = _chain_slice(template, 0)
    for i in range(n):
        with np.load(os.path.join(d, f"chain_{i:03d}.npz")) as z:
            chains.append(_unflatten_into(tmpl0, dict(z)))
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *chains)
    return stacked, manifest


def restore_chain(ckpt_dir: str, step: int, chain: int, template_chain):
    """Restore ONE chain's pytree slice (no leading chain dim) — the
    supervisor's restart path: a failed chain re-reads its own file and
    nobody else's.  Raises on a missing/corrupt/truncated file; the
    caller decides the fallback (fresh init per the recovery policy)."""
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    _load_manifest(d, step)
    with np.load(os.path.join(d, f"chain_{chain:03d}.npz")) as z:
        return _unflatten_into(template_chain, dict(z))


def restore_elastic(ckpt_dir: str, step: int, template, init_fn,
                    *, missing_ok: bool = True):
    """Elastic restore onto `template`'s chain count.

    Fewer target chains → restore a prefix.  More → missing chains come
    from `init_fn(chain_index)` (fresh ensemble members).  Corrupt or
    missing chain files likewise fall back to init_fn (fault isolation).
    """
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    manifest = _load_manifest(d, step)
    target = jax.tree.leaves(template)[0].shape[0]
    tmpl0 = _chain_slice(template, 0)
    chains, restored = [], []
    for i in range(target):
        path = os.path.join(d, f"chain_{i:03d}.npz")
        try:
            with np.load(path) as z:
                chains.append(_unflatten_into(tmpl0, dict(z)))
            restored.append(i)
        except (FileNotFoundError, KeyError, ValueError, OSError,
                zipfile.BadZipFile):   # truncated .npz = torn write
            if not missing_ok:
                raise
            chains.append(init_fn(i))
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *chains)
    return stacked, {"restored_chains": restored, "step": manifest["step"]}


class CheckpointManager:
    """Keeps the last `keep` checkpoints, saves every `interval` steps."""

    def __init__(self, ckpt_dir: str, interval: int = 100, keep: int = 3):
        self.dir = ckpt_dir
        self.interval = interval
        self.keep = keep
        os.makedirs(ckpt_dir, exist_ok=True)

    def maybe_save(self, step: int, state, extra=None):
        if step % self.interval:
            return None
        path = save_checkpoint(self.dir, step, state, extra=extra)
        self._gc()
        return path

    def _gc(self):
        steps = sorted(int(d.split("_")[1]) for d in os.listdir(self.dir)
                       if d.startswith("step_"))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)
