"""Per-chain checkpoint store.

Chains share nothing (the paper's communication-free property), so the
checkpoint layout is **per-chain**: one .npz per chain per step plus a tiny
manifest.  Consequences the tests verify:

  * a chain failure never corrupts other chains' state — restart restores
    the survivors and the failed chain alone re-inits (fault isolation),
  * elastic rescale: restore onto MORE chains (new ones init fresh) or
    FEWER chains (a prefix of the ensemble) without touching the rest,
  * atomicity: writes go to a temp dir, fsync'd, then os.replace'd; a
    half-written checkpoint is never visible under its final name.  The
    OVERWRITE path first renames the old step aside (never `rmtree`s the
    live dir — a crash between delete and publish would lose BOTH
    versions), publishes, fsyncs the parent directory so the rename is
    durable, and only then deletes the aside copy,
  * kill-anywhere leaves garbage that is swept, never trusted: orphaned
    `.tmp_*` write dirs and `.prev_*` aside dirs are reclaimed on manager
    init and at every GC (a `.prev_*` whose final step vanished is the
    crash-between-aside-and-publish window — it is renamed BACK, which
    restores the old checkpoint).

`AsyncCheckpointManager` moves the `np.savez` cost off the training loop:
the caller's `maybe_save` takes a host snapshot (device_get — the only
part that must see a quiescent state) and a background thread publishes
it through the same atomic `save_checkpoint`.  Bounded staleness: a new
save is not ACCEPTED until the previous one is durable, so at any point
the newest published step is at most one save interval behind the
training loop — resume after a crash loses at most one EM round.

Format: flat {pytree-path: array} in numpy .npz — no pickle, portable.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
import zipfile

import jax
import jax.numpy as jnp
import numpy as np

#: tmp dirs owned by an in-flight save_checkpoint of THIS process — the
#: stale-garbage sweep must never reclaim a dir another thread (e.g. the
#: async writer) is still filling.
_ACTIVE_TMP: set = set()
_ACTIVE_LOCK = threading.Lock()


class CheckpointNotFoundError(FileNotFoundError):
    """A requested checkpoint step does not exist (never written, or
    already garbage-collected).  Subclasses FileNotFoundError so callers
    that catch the raw OSError family keep working, but the message — and
    the `step` / `available_steps` attributes — name what WAS requested
    and what the store actually holds, so a serving reload or a restart
    path surfaces an actionable error instead of a bare ENOENT."""

    def __init__(self, ckpt_dir: str, step: int, available: list):
        self.step = step
        self.available_steps = list(available)
        super().__init__(
            f"no checkpoint for step {step} under {ckpt_dir!r}; "
            f"available steps: {self.available_steps or 'none'}")


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return {jax.tree_util.keystr(path): np.asarray(leaf)
            for path, leaf in flat}, treedef


def _chain_slice(tree, i):
    return jax.tree.map(lambda x: x[i] if hasattr(x, "ndim") and x.ndim > 0
                        else x, tree)


def _fsync_dir(path: str):
    """fsync a DIRECTORY so a rename inside it is durable — os.replace
    alone only orders the rename in page cache; a power cut could undo
    a 'published' checkpoint without this."""
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _list_steps(ckpt_dir: str) -> list:
    if not os.path.isdir(ckpt_dir):
        return []
    return sorted(int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
                  if d.startswith("step_"))


def _step_dir(ckpt_dir: str, step: int) -> str:
    """Resolve a step's directory or raise the typed not-found error."""
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    if not os.path.isdir(d):
        raise CheckpointNotFoundError(ckpt_dir, step, _list_steps(ckpt_dir))
    return d


def save_checkpoint(ckpt_dir: str, step: int, state: dict, *,
                    n_chains: int | None = None, extra: dict | None = None):
    """state: pytree whose array leaves have a leading chain dim (scalars
    like the opt step counter are replicated into every chain file)."""
    if n_chains is None:
        n_chains = jax.tree.leaves(state)[0].shape[0]
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    aside = os.path.join(ckpt_dir, f".prev_step_{step:08d}")
    tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_")
    with _ACTIVE_LOCK:
        _ACTIVE_TMP.add(tmp)
    try:
        for i in range(n_chains):
            flat, _ = _flatten(_chain_slice(state, i))
            path = os.path.join(tmp, f"chain_{i:03d}.npz")
            with open(path, "wb") as f:
                np.savez(f, **flat)
                f.flush()
                os.fsync(f.fileno())
        manifest = {"step": step, "n_chains": n_chains,
                    "extra": extra or {}}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        # publish: the OLD step (if any) is renamed ASIDE, never deleted
        # before the new one lands — a crash in the aside→publish window
        # leaves the old version recoverable (`_sweep_stale` renames it
        # back), so no window loses both versions.
        if os.path.isdir(aside):        # stale aside from an older crash
            shutil.rmtree(aside)
        had_old = os.path.exists(final)
        if had_old:
            os.replace(final, aside)
        os.replace(tmp, final)          # atomic publish
        _fsync_dir(ckpt_dir)            # make the rename(s) durable
        if had_old:
            shutil.rmtree(aside, ignore_errors=True)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    finally:
        with _ACTIVE_LOCK:
            _ACTIVE_TMP.discard(tmp)
    return final


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_")
             and os.path.exists(os.path.join(ckpt_dir, d, "manifest.json"))]
    return max(steps) if steps else None


def list_chains(ckpt_dir: str, step: int) -> list[int]:
    d = _step_dir(ckpt_dir, step)
    return sorted(int(f.split("_")[1].split(".")[0])
                  for f in os.listdir(d) if f.startswith("chain_"))


def _load_manifest(step_dir: str, step: int) -> dict:
    """Read + validate a step's manifest (handle closed promptly — the
    old `json.load(open(...))` leaked the fd until GC).  A manifest whose
    recorded step disagrees with the directory name means a torn or
    hand-copied checkpoint; restoring it silently would resume training
    from the wrong point, so fail loudly instead."""
    mpath = os.path.join(step_dir, "manifest.json")
    if not os.path.exists(mpath):
        ckpt_dir = os.path.dirname(step_dir)
        raise CheckpointNotFoundError(ckpt_dir, step, _list_steps(ckpt_dir))
    with open(mpath) as f:
        manifest = json.load(f)
    if manifest.get("step") != step:
        raise ValueError(
            f"checkpoint manifest in {step_dir} records step "
            f"{manifest.get('step')!r}, expected {step} — torn or "
            "mislabelled checkpoint")
    return manifest


def _unflatten_into(template_chain, flat):
    paths, treedef = jax.tree_util.tree_flatten_with_path(template_chain)
    leaves = []
    for path, tmpl in paths:
        key = jax.tree_util.keystr(path)
        arr = flat[key]
        leaves.append(jnp.asarray(arr, dtype=tmpl.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def read_manifest(ckpt_dir: str, step: int) -> dict:
    """Public validated-manifest read — what a serving-tier reload uses
    to vet a checkpoint before paying to load any chain file.  Raises
    `CheckpointNotFoundError` (naming the available steps) on a missing/
    GC'd step, ValueError on a torn/mislabelled manifest."""
    return _load_manifest(_step_dir(ckpt_dir, step), step)


def restore_checkpoint(ckpt_dir: str, step: int, template):
    """Restore all chains recorded in the manifest; template is a pytree
    with the target leading chain dim (its values are ignored).  The
    manifest's chain count must MATCH the template's — a hot-reloading
    service that silently changed ensemble size mid-stream would break
    every [M]-shaped jit signature downstream; elastic rescale is the
    explicit `restore_elastic` path."""
    d = _step_dir(ckpt_dir, step)
    manifest = _load_manifest(d, step)
    n = manifest["n_chains"]
    target = jax.tree.leaves(template)[0].shape[0]
    if n != target:
        raise ValueError(
            f"checkpoint at step {step} holds {n} chains, template "
            f"expects {target} — use restore_elastic for rescale")
    chains = []
    tmpl0 = _chain_slice(template, 0)
    for i in range(n):
        with np.load(os.path.join(d, f"chain_{i:03d}.npz")) as z:
            chains.append(_unflatten_into(tmpl0, dict(z)))
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *chains)
    return stacked, manifest


def restore_chain(ckpt_dir: str, step: int, chain: int, template_chain):
    """Restore ONE chain's pytree slice (no leading chain dim) — the
    supervisor's restart path: a failed chain re-reads its own file and
    nobody else's.  Raises on a missing/corrupt/truncated file; the
    caller decides the fallback (fresh init per the recovery policy)."""
    d = _step_dir(ckpt_dir, step)
    _load_manifest(d, step)
    with np.load(os.path.join(d, f"chain_{chain:03d}.npz")) as z:
        return _unflatten_into(template_chain, dict(z))


def restore_elastic(ckpt_dir: str, step: int, template, init_fn,
                    *, missing_ok: bool = True):
    """Elastic restore onto `template`'s chain count.

    Fewer target chains → restore a prefix.  More → missing chains come
    from `init_fn(chain_index)` (fresh ensemble members).  Corrupt or
    missing chain files likewise fall back to init_fn (fault isolation).
    """
    d = _step_dir(ckpt_dir, step)
    manifest = _load_manifest(d, step)
    target = jax.tree.leaves(template)[0].shape[0]
    tmpl0 = _chain_slice(template, 0)
    chains, restored = [], []
    for i in range(target):
        path = os.path.join(d, f"chain_{i:03d}.npz")
        try:
            with np.load(path) as z:
                chains.append(_unflatten_into(tmpl0, dict(z)))
            restored.append(i)
        except (FileNotFoundError, KeyError, ValueError, OSError,
                zipfile.BadZipFile):   # truncated .npz = torn write
            if not missing_ok:
                raise
            chains.append(init_fn(i))
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *chains)
    return stacked, {"restored_chains": restored, "step": manifest["step"],
                     "extra": manifest.get("extra", {})}


def sweep_stale(ckpt_dir: str) -> dict:
    """Reclaim crash garbage under `ckpt_dir` — safe to call any time
    (a single-writer store; in-flight tmp dirs of THIS process are
    registered and skipped):

      * `.tmp_*`  — a save killed mid-write; the dir never published, so
        it is pure garbage → removed,
      * `.prev_step_X` with `step_X` PRESENT — the crash hit after
        publish but before aside cleanup → the aside is garbage,
      * `.prev_step_X` with `step_X` MISSING — the crash hit between
        rename-aside and publish; the aside holds the only complete copy
        of that step → renamed BACK (the old checkpoint is restored).

    Returns {"removed_tmp": n, "removed_aside": n, "recovered": [steps]}.
    """
    out = {"removed_tmp": 0, "removed_aside": 0, "recovered": []}
    if not os.path.isdir(ckpt_dir):
        return out
    with _ACTIVE_LOCK:
        active = set(_ACTIVE_TMP)
    for name in os.listdir(ckpt_dir):
        path = os.path.join(ckpt_dir, name)
        if name.startswith(".tmp_") and path not in active:
            shutil.rmtree(path, ignore_errors=True)
            out["removed_tmp"] += 1
        elif name.startswith(".prev_step_"):
            final = os.path.join(ckpt_dir, name[len(".prev_"):])
            if os.path.isdir(final):
                shutil.rmtree(path, ignore_errors=True)
                out["removed_aside"] += 1
            else:
                os.replace(path, final)
                out["recovered"].append(int(name.rsplit("_", 1)[1]))
    return out


class CheckpointManager:
    """Keeps the last `keep` checkpoints, saves every `interval` steps.
    Crash garbage (orphaned `.tmp_*` / `.prev_*` dirs from a killed
    writer) is swept on init and at every GC — see `sweep_stale`."""

    def __init__(self, ckpt_dir: str, interval: int = 100, keep: int = 3):
        self.dir = ckpt_dir
        self.interval = interval
        self.keep = keep
        os.makedirs(ckpt_dir, exist_ok=True)
        sweep_stale(ckpt_dir)

    def maybe_save(self, step: int, state, extra=None):
        if step % self.interval:
            return None
        path = save_checkpoint(self.dir, step, state, extra=extra)
        self._gc()
        return path

    def latest_durable(self) -> int | None:
        """Newest PUBLISHED step — what a restart can actually restore
        (an in-flight write is invisible until its atomic publish)."""
        return latest_step(self.dir)

    def flush(self):
        """Synchronous manager: every accepted save is already durable."""

    def close(self):
        self.flush()

    def _gc(self):
        sweep_stale(self.dir)
        steps = sorted(int(d.split("_")[1]) for d in os.listdir(self.dir)
                       if d.startswith("step_"))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)


class AsyncCheckpointManager(CheckpointManager):
    """Background-writer checkpointing with a bounded-staleness
    guarantee (DESIGN.md §Elastic-training).

    `maybe_save` splits the save into the part that must block the
    training loop — `jax.device_get(state)`, a host snapshot of the
    round-boundary state — and the part that must not: serializing +
    fsync'ing the .npz files, which a daemon thread runs through the
    same crash-consistent `save_checkpoint` (atomic publish untouched,
    so kill-mid-write still never corrupts a published step).

    **Bounded staleness.**  A new save is not accepted until the
    previous one is DURABLE (`maybe_save` waits on the in-flight write
    before taking the next snapshot).  At any instant the newest
    published step is therefore at most one save interval older than
    the loop — with the elastic runtime's save-every-round cadence,
    resume after a crash loses at most ONE EM round.  The wait is
    normally free: the write overlaps the following round's compute,
    which is the whole point.

    **Graceful drain.**  `flush()` blocks until the in-flight write is
    published (the SIGTERM → flush → exit-resumable path); `close()`
    flushes and stops the writer.  A writer-thread failure is re-raised
    on the next `maybe_save`/`flush` — an async checkpoint that cannot
    persist must not fail silently.
    """

    def __init__(self, ckpt_dir: str, interval: int = 1, keep: int = 3):
        super().__init__(ckpt_dir, interval=interval, keep=keep)
        self._job = None            # (step, snapshot, extra) or None
        self._job_ready = threading.Event()   # a job is queued
        self._job_done = threading.Event()    # no job queued or writing
        self._job_done.set()
        self._stop = False
        self._error = None
        self._lock = threading.Lock()
        self.stats = {"writes": 0, "waits": 0, "wait_s": 0.0}
        self._thread = threading.Thread(
            target=self._writer, name="ckpt-writer", daemon=True)
        self._thread.start()

    # ---- writer thread ------------------------------------------------
    def _writer(self):
        while True:
            self._job_ready.wait()
            with self._lock:
                if self._stop and self._job is None:
                    return
                job, self._job = self._job, None
                self._job_ready.clear()
            if job is None:
                continue
            step, snap, extra = job
            try:
                save_checkpoint(self.dir, step, snap, extra=extra)
                self._gc()
                self.stats["writes"] += 1
            except BaseException as e:  # noqa: BLE001 — surfaced to caller
                with self._lock:
                    self._error = e
            finally:
                self._job_done.set()

    def _raise_pending_error(self):
        with self._lock:
            err, self._error = self._error, None
        if err is not None:
            raise err

    # ---- caller API ----------------------------------------------------
    def maybe_save(self, step: int, state, extra=None):
        """Snapshot `state` to host and enqueue the durable write.
        Returns the final path the write WILL publish (None off-interval).
        Blocks only until the PREVIOUS write is durable (staleness bound)
        and the host copy is taken — never for this write itself."""
        if step % self.interval:
            return None
        if not self._job_done.is_set():
            import time
            t0 = time.time()
            self._job_done.wait()
            self.stats["waits"] += 1
            self.stats["wait_s"] += time.time() - t0
        self._raise_pending_error()
        # the host-copy double buffer: np.array FORCES a fresh host
        # allocation per leaf (device_get alone can alias the caller's
        # buffer on CPU backends, which a donated/mutated buffer would
        # then corrupt mid-write); the writer owns this snapshot until
        # its publish, independent of anything the loop does next.
        snap = jax.tree.map(lambda x: np.array(jax.device_get(x)), state)
        with self._lock:
            self._job = (step, snap, extra)
            self._job_done.clear()
            self._job_ready.set()
        return os.path.join(self.dir, f"step_{step:08d}")

    def flush(self):
        """Block until the in-flight write (if any) is published —
        the graceful-drain half of the preemption protocol."""
        self._job_done.wait()
        self._raise_pending_error()

    def close(self):
        self.flush()
        with self._lock:
            self._stop = True
            self._job_ready.set()
        self._thread.join(timeout=30.0)
        self._raise_pending_error()
