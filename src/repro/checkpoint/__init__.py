"""Fault-tolerant checkpointing for communication-free chains."""
from .store import (save_checkpoint, restore_checkpoint, restore_chain,
                    latest_step, list_chains, read_manifest,
                    restore_elastic, sweep_stale, CheckpointManager,
                    AsyncCheckpointManager, CheckpointNotFoundError)

__all__ = ["save_checkpoint", "restore_checkpoint", "restore_chain",
           "latest_step", "list_chains", "read_manifest",
           "restore_elastic", "sweep_stale", "CheckpointManager",
           "AsyncCheckpointManager", "CheckpointNotFoundError"]
