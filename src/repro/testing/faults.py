"""Deterministic fault injection for the chain ensemble.

Chaos testing the supervisor (DESIGN.md §Fault-model) needs faults that
are (a) DETERMINISTIC — same seed, same fault, same boundary, so a
failure report reproduces bit-for-bit — and (b) JIT-COMPATIBLE, because
the supervisor's health probe runs inside the compiled EM scan and the
whole point is to test detection *there*, not in host-side wrappers.

A `FaultPlan` is therefore data, not control flow: per-chain int32
trigger steps (−1 = never), compared against the traced EM-boundary
index `it` inside the scan.  `FaultPlan.hook` plugs straight into
`ChainSupervisor(fault_hook=...)`, which composes it BEFORE the health
probe — an injected fault at boundary `it` is detectable at that same
boundary.

Fault semantics mirror how each failure class behaves in the wild:

  * `nan_eta_step` — PERSISTENT (fires at every boundary ≥ step): a
    genuinely diverged sampler re-produces NaN after any restart, so
    this is the fault that exhausts the restart budget and exercises
    the quarantine fallback.
  * `corrupt_counts_step` — PERSISTENT: ndt[c,0,0] += 7 (breaks the
    Σ ndt == Σ lengths invariant) and ntw[c,0,0] = −5 (breaks ntw ≥ 0);
    η stays finite, so ONLY the count probes can catch it.
  * `kill_step` — TRANSIENT (fires at exactly one boundary): a dead
    worker loses its in-memory state once (poisoned to NaN here) and
    also raises F_KILLED directly, the way a cluster runtime reports a
    lost worker out-of-band.  Restart-from-checkpoint fully recovers.
  * `straggle_step` — TRANSIENT, flag-only (F_STRAGGLER): a late chain
    is *correct*; nothing in its state may change.

State mutation + detection stay decoupled on purpose: NaN/count faults
set NO bits here — the health probes must find them (that is the test);
kill/straggle set F_KILLED/F_STRAGGLER because dead/late workers are
runtime-reported events with no state signature of their own.
"""
from __future__ import annotations

import os
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.supervisor import F_KILLED, F_STRAGGLER
from repro.core.types import GibbsState

_KINDS = ("nan", "corrupt", "kill", "straggle")


class FaultPlan(NamedTuple):
    """Per-chain trigger boundaries, [M] int32 each, −1 = never.  A
    NamedTuple of arrays — already a pytree, so a plan can close over a
    jitted round or ride through scan carries unchanged."""

    nan_eta_step: jnp.ndarray
    corrupt_counts_step: jnp.ndarray
    kill_step: jnp.ndarray
    straggle_step: jnp.ndarray

    def hook(self):
        """`em_hook`-shaped closure for `ChainSupervisor(fault_hook=)`."""
        return lambda state, it: inject(state, it, self)


def inject(state: GibbsState, it, fp: FaultPlan):
    """Apply `fp` at traced EM-boundary `it` → (state', bits [M] uint32).
    Pure jnp — runs inside the EM scan."""
    m = state.eta.shape[0]
    it = jnp.asarray(it)
    armed = lambda step: step >= 0

    # persistent divergence: η goes NaN at every boundary ≥ step
    nan_on = armed(fp.nan_eta_step) & (it >= fp.nan_eta_step)
    eta = jnp.where(nan_on[:, None], jnp.nan, state.eta)

    # persistent count corruption: finite but invariant-breaking
    cor = armed(fp.corrupt_counts_step) & (it >= fp.corrupt_counts_step)
    ndt = state.ndt.at[:, 0, 0].add(jnp.where(cor, 7.0, 0.0))
    ntw = state.ntw.at[:, 0, 0].set(
        jnp.where(cor, -5.0, state.ntw[:, 0, 0]))

    # one-shot kill: the worker's in-memory state is lost once
    kill = armed(fp.kill_step) & (it == fp.kill_step)
    eta = jnp.where(kill[:, None], jnp.nan, eta)
    ndt = jnp.where(kill[:, None, None], jnp.nan, ndt)

    strag = armed(fp.straggle_step) & (it == fp.straggle_step)
    bits = (jnp.where(kill, jnp.uint32(F_KILLED), jnp.uint32(0))
            | jnp.where(strag, jnp.uint32(F_STRAGGLER), jnp.uint32(0)))
    return GibbsState(z=state.z, ndt=ndt, ntw=ntw, nt=state.nt,
                      eta=eta), bits


# ------------------------------------------------------------ constructors

def no_faults(m: int) -> FaultPlan:
    never = jnp.full((m,), -1, jnp.int32)
    return FaultPlan(never, never, never, never)


def poison(m: int, chain: int, step: int, kind: str = "nan") -> FaultPlan:
    """One fault: `kind` on `chain` at EM boundary `step`."""
    if kind not in _KINDS:
        raise ValueError(f"kind must be one of {_KINDS}, got {kind!r}")
    field = {"nan": 0, "corrupt": 1, "kill": 2, "straggle": 3}[kind]
    cols = [jnp.full((m,), -1, jnp.int32) for _ in range(4)]
    cols[field] = cols[field].at[chain].set(step)
    return FaultPlan(*cols)


def random_fault_plan(key, m: int, n_boundaries: int, *,
                      p_fault: float = 0.3) -> FaultPlan:
    """Seed-driven chaos: each chain independently draws whether it
    faults (prob `p_fault`), which kind, and at which boundary.  Same
    key → same plan, bit-for-bit (threefry), so a chaos-test failure
    log names a key that reproduces it exactly."""
    k1, k2, k3 = jax.random.split(key, 3)
    hit = jax.random.bernoulli(k1, p_fault, (m,))
    kind = jax.random.randint(k2, (m,), 0, len(_KINDS))
    step = jax.random.randint(k3, (m,), 0, max(n_boundaries, 1))
    cols = [jnp.where(hit & (kind == i), step.astype(jnp.int32),
                      jnp.int32(-1)) for i in range(len(_KINDS))]
    return FaultPlan(*cols)


# ---------------------------------------------------- host-side storage fault

def truncate_chain_file(ckpt_dir: str, step: int, chain: int,
                        keep_bytes: int = 16) -> str:
    """Simulate a torn write / partial disk: truncate ONE chain's .npz in
    a published checkpoint to `keep_bytes`.  The manifest stays valid —
    exactly the half-damaged checkpoint `restore_elastic` and the
    supervisor's restart path must fault-isolate (every OTHER chain
    restores; this one falls back to fresh init)."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}",
                        f"chain_{chain:03d}.npz")
    size = os.path.getsize(path)
    with open(path, "r+b") as f:
        f.truncate(min(keep_bytes, size))
    return path
