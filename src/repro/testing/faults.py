"""Deterministic fault injection for the chain ensemble.

Chaos testing the supervisor (DESIGN.md §Fault-model) needs faults that
are (a) DETERMINISTIC — same seed, same fault, same boundary, so a
failure report reproduces bit-for-bit — and (b) JIT-COMPATIBLE, because
the supervisor's health probe runs inside the compiled EM scan and the
whole point is to test detection *there*, not in host-side wrappers.

A `FaultPlan` is therefore data, not control flow: per-chain int32
trigger steps (−1 = never), compared against the traced EM-boundary
index `it` inside the scan.  `FaultPlan.hook` plugs straight into
`ChainSupervisor(fault_hook=...)`, which composes it BEFORE the health
probe — an injected fault at boundary `it` is detectable at that same
boundary.

Fault semantics mirror how each failure class behaves in the wild:

  * `nan_eta_step` — PERSISTENT (fires at every boundary ≥ step): a
    genuinely diverged sampler re-produces NaN after any restart, so
    this is the fault that exhausts the restart budget and exercises
    the quarantine fallback.
  * `corrupt_counts_step` — PERSISTENT: ndt[c,0,0] += 7 (breaks the
    Σ ndt == Σ lengths invariant) and ntw[c,0,0] = −5 (breaks ntw ≥ 0);
    η stays finite, so ONLY the count probes can catch it.
  * `kill_step` — TRANSIENT (fires at exactly one boundary): a dead
    worker loses its in-memory state once (poisoned to NaN here) and
    also raises F_KILLED directly, the way a cluster runtime reports a
    lost worker out-of-band.  Restart-from-checkpoint fully recovers.
  * `straggle_step` — TRANSIENT, flag-only (F_STRAGGLER): a late chain
    is *correct*; nothing in its state may change.

State mutation + detection stay decoupled on purpose: NaN/count faults
set NO bits here — the health probes must find them (that is the test);
kill/straggle set F_KILLED/F_STRAGGLER because dead/late workers are
runtime-reported events with no state signature of their own.
"""
from __future__ import annotations

import os
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.supervisor import F_KILLED, F_STRAGGLER
from repro.core.types import GibbsState

_KINDS = ("nan", "corrupt", "kill", "straggle")


class FaultPlan(NamedTuple):
    """Per-chain trigger boundaries, [M] int32 each, −1 = never.  A
    NamedTuple of arrays — already a pytree, so a plan can close over a
    jitted round or ride through scan carries unchanged."""

    nan_eta_step: jnp.ndarray
    corrupt_counts_step: jnp.ndarray
    kill_step: jnp.ndarray
    straggle_step: jnp.ndarray

    def hook(self):
        """`em_hook`-shaped closure for `ChainSupervisor(fault_hook=)`."""
        return lambda state, it: inject(state, it, self)


def inject(state: GibbsState, it, fp: FaultPlan):
    """Apply `fp` at traced EM-boundary `it` → (state', bits [M] uint32).
    Pure jnp — runs inside the EM scan."""
    m = state.eta.shape[0]
    it = jnp.asarray(it)
    armed = lambda step: step >= 0

    # persistent divergence: η goes NaN at every boundary ≥ step
    nan_on = armed(fp.nan_eta_step) & (it >= fp.nan_eta_step)
    eta = jnp.where(nan_on[:, None], jnp.nan, state.eta)

    # persistent count corruption: finite but invariant-breaking
    cor = armed(fp.corrupt_counts_step) & (it >= fp.corrupt_counts_step)
    ndt = state.ndt.at[:, 0, 0].add(jnp.where(cor, 7.0, 0.0))
    ntw = state.ntw.at[:, 0, 0].set(
        jnp.where(cor, -5.0, state.ntw[:, 0, 0]))

    # one-shot kill: the worker's in-memory state is lost once
    kill = armed(fp.kill_step) & (it == fp.kill_step)
    eta = jnp.where(kill[:, None], jnp.nan, eta)
    ndt = jnp.where(kill[:, None, None], jnp.nan, ndt)

    strag = armed(fp.straggle_step) & (it == fp.straggle_step)
    bits = (jnp.where(kill, jnp.uint32(F_KILLED), jnp.uint32(0))
            | jnp.where(strag, jnp.uint32(F_STRAGGLER), jnp.uint32(0)))
    return GibbsState(z=state.z, ndt=ndt, ntw=ntw, nt=state.nt,
                      eta=eta), bits


# ------------------------------------------------------------ constructors

def no_faults(m: int) -> FaultPlan:
    never = jnp.full((m,), -1, jnp.int32)
    return FaultPlan(never, never, never, never)


def poison(m: int, chain: int, step: int, kind: str = "nan") -> FaultPlan:
    """One fault: `kind` on `chain` at EM boundary `step`."""
    if kind not in _KINDS:
        raise ValueError(f"kind must be one of {_KINDS}, got {kind!r}")
    field = {"nan": 0, "corrupt": 1, "kill": 2, "straggle": 3}[kind]
    cols = [jnp.full((m,), -1, jnp.int32) for _ in range(4)]
    cols[field] = cols[field].at[chain].set(step)
    return FaultPlan(*cols)


def random_fault_plan(key, m: int, n_boundaries: int, *,
                      p_fault: float = 0.3) -> FaultPlan:
    """Seed-driven chaos: each chain independently draws whether it
    faults (prob `p_fault`), which kind, and at which boundary.  Same
    key → same plan, bit-for-bit (threefry), so a chaos-test failure
    log names a key that reproduces it exactly."""
    k1, k2, k3 = jax.random.split(key, 3)
    hit = jax.random.bernoulli(k1, p_fault, (m,))
    kind = jax.random.randint(k2, (m,), 0, len(_KINDS))
    step = jax.random.randint(k3, (m,), 0, max(n_boundaries, 1))
    cols = [jnp.where(hit & (kind == i), step.astype(jnp.int32),
                      jnp.int32(-1)) for i in range(len(_KINDS))]
    return FaultPlan(*cols)


# ---------------------------------------------------- host-side storage fault

def truncate_chain_file(ckpt_dir: str, step: int, chain: int,
                        keep_bytes: int = 16) -> str:
    """Simulate a torn write / partial disk: truncate ONE chain's .npz in
    a published checkpoint to `keep_bytes`.  The manifest stays valid —
    exactly the half-damaged checkpoint `restore_elastic` and the
    supervisor's restart path must fault-isolate (every OTHER chain
    restores; this one falls back to fresh init)."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}",
                        f"chain_{chain:03d}.npz")
    size = os.path.getsize(path)
    with open(path, "r+b") as f:
        f.truncate(min(keep_bytes, size))
    return path


def mislabel_manifest(ckpt_dir: str, step: int, wrong_step: int) -> str:
    """Rewrite a published checkpoint's manifest to record the WRONG
    step — a hand-copied / torn checkpoint directory.  The serving
    reload path must reject it via `read_manifest`'s step validation
    rather than hot-swap a model trained to an unknown point."""
    import json
    path = os.path.join(ckpt_dir, f"step_{step:08d}", "manifest.json")
    with open(path) as f:
        manifest = json.load(f)
    manifest["step"] = wrong_step
    with open(path, "w") as f:
        json.dump(manifest, f)
    return path


# -------------------------------------------------------- serve-time faults
#
# The serving chaos suite (tests/test_serving_robust.py, DESIGN.md
# §Serving-robustness) needs the same determinism contract as the
# training faults above, but its failure classes live OUTSIDE the EM
# scan: poisoned model tables, slow dispatches, bursty arrivals.  Time
# itself is therefore injectable — `VirtualClock` + `replay_open_loop`
# make an overload scenario a pure function of (seed, trace), so a p99
# regression reproduces bit-for-bit with no real sleeping.

class VirtualClock:
    """Deterministic monotonic-ish clock for overload simulation.
    Plugs into `SLDAPredictionService(clock=...)`; every deadline,
    rate-limit and latency decision then reads simulated seconds."""

    def __init__(self, t0: float = 0.0):
        self._t = float(t0)

    def __call__(self) -> float:
        return self._t

    def now(self) -> float:
        return self._t

    def set(self, t: float):
        self._t = float(t)

    def advance(self, dt: float):
        self._t += float(dt)


def poison_model_table(models, chain: int, kind: str = "nan_phi"):
    """Corrupt ONE chain's serving tables (host-side — these are model
    EXPORTS, not in-scan state).  Kinds map 1:1 to the
    `core.supervisor.model_status` probes that must catch them:

      * "nan_phi"    — NaN in the topic-word table φ̂   → F_NAN_PHI
      * "nan_eta"    — NaN in the regression weights η  → F_NAN_ETA
      * "bad_rowsum" — φ̂ row no longer sums to 1       → F_PHI_ROWSUM
      * "nan_mse"    — non-finite train MSE (breaks
                       weighted combine)                → F_NAN_MSE
    """
    phi, eta = models.phi, models.eta
    mse = models.train_mse
    if kind == "nan_phi":
        phi = phi.at[chain, 0, 0].set(jnp.nan)
    elif kind == "nan_eta":
        eta = eta.at[chain, 0].set(jnp.nan)
    elif kind == "bad_rowsum":
        phi = phi.at[chain, 0, :].set(phi[chain, 0, :] * 3.0)
    elif kind == "nan_mse":
        mse = mse.at[chain].set(jnp.inf)
    else:
        raise ValueError(
            "kind must be one of ('nan_phi', 'nan_eta', 'bad_rowsum', "
            f"'nan_mse'), got {kind!r}")
    import dataclasses
    return dataclasses.replace(models, phi=phi, eta=eta, train_mse=mse)


def inject_dispatch_delay(service, delay_s: float):
    """Make every dispatch of `service` take `delay_s` extra seconds —
    a straggling accelerator.  Wraps the PLAN-CACHE lookup, not the
    jitted callables themselves, so the compiled fns (and the
    no-retrace property) are untouched; with a `VirtualClock` the
    delay advances simulated time and costs zero wall clock.  Returns
    an undo callable."""
    orig = service._dispatch_fn
    clock = service._clock

    def delayed(plan_key):
        fn = orig(plan_key)

        def run(*args):
            out = fn(*args)
            jax.block_until_ready(out)
            if isinstance(clock, VirtualClock):
                clock.advance(delay_s)
            else:
                import time
                time.sleep(delay_s)
            return out

        return run

    service._dispatch_fn = delayed

    def undo():
        service._dispatch_fn = orig

    return undo


def burst_trace(seed: int, vocab: int, max_len: int, *,
                base_rate: float, burst_rate: float, n_steady: int,
                n_burst: int, n_tail: int, len_lam: float = 12.0):
    """Deterministic open-loop arrival trace: steady Poisson-ish
    traffic at `base_rate` req/s, a burst at `burst_rate`, then a
    steady tail — the canonical overload shape.  Returns a list of
    (arrival_time_s, token_array) sorted by time.  Same seed → same
    trace, bit-for-bit."""
    rng = np.random.default_rng(seed)
    t, out = 0.0, []
    for n, rate in ((n_steady, base_rate), (n_burst, burst_rate),
                    (n_tail, base_rate)):
        for _ in range(n):
            t += rng.exponential(1.0 / rate)
            L = int(np.clip(rng.poisson(len_lam), 1, max_len))
            out.append((t, rng.integers(0, vocab, L).astype(np.int32)))
    return out


# ------------------------------------------------------- elastic-pool faults
#
# The elastic runtime (launch/elastic.py, DESIGN.md §Elastic-training)
# schedules chains onto a DYNAMIC device pool; its failure classes are
# environment events at round granularity — a device vanishing, a
# preemption notice, a device running slow — not state corruption (the
# chains themselves stay healthy; that is the whole point of
# communication-free elasticity).  Events are plain data on the round
# timeline, applied host-side at round boundaries, so a chaos run is a
# pure function of (seed, event list) and replays byte-identically.

class ElasticEvent(NamedTuple):
    """One environment event for the elastic runner's chaos timeline.

    kind      — "device_loss" (device leaves the pool; its chains
                restore from the last durable checkpoint, or are
                quarantined when no checkpoint dir exists),
                "preempt"     (SIGTERM-equivalent: drain checkpoints
                and exit resumable at the NEXT round boundary),
                "straggle"    (the device runs `delay_s` slow for
                `rounds` consecutive rounds — correct, merely late),
                "device_join" (a device joins the pool; chains repack
                over the grown pool at the boundary).
    at_round  — 0-based wall round at whose START the event applies.
    device    — pool index it targets (ignored for "preempt").
    delay_s   — extra simulated seconds per round ("straggle" only).
    rounds    — how many consecutive rounds the straggle lasts.
    """

    kind: str
    at_round: int
    device: int = 0
    delay_s: float = 0.0
    rounds: int = 1


_ELASTIC_KINDS = ("device_loss", "preempt", "straggle", "device_join")


def random_elastic_events(seed: int, *, n_rounds: int, n_devices: int,
                          n_events: int = 2,
                          kinds=("device_loss", "straggle")) -> list:
    """Seed-driven elastic chaos: `n_events` events drawn over the round
    timeline.  Same seed → same event list (numpy Philox), so a chaos
    failure names a seed that replays it exactly.  Device-loss events
    never drain the pool below one device."""
    for k in kinds:
        if k not in _ELASTIC_KINDS:
            raise ValueError(
                f"kinds must be among {_ELASTIC_KINDS}, got {k!r}")
    rng = np.random.default_rng(seed)
    events, losses = [], 0
    for _ in range(n_events):
        kind = kinds[int(rng.integers(0, len(kinds)))]
        if kind == "device_loss" and losses >= n_devices - 1:
            kind = "straggle"       # keep ≥1 device alive
        if kind == "device_loss":
            losses += 1
        events.append(ElasticEvent(
            kind=kind,
            at_round=int(rng.integers(1, max(n_rounds, 2))),
            device=int(rng.integers(0, n_devices)),
            delay_s=float(rng.uniform(0.5, 3.0)),
            rounds=int(rng.integers(1, 4))))
    return sorted(events, key=lambda e: e.at_round)


def replay_open_loop(service, trace, clock: VirtualClock):
    """Replay an arrival `trace` through `service` open-loop under a
    `VirtualClock` (discrete-event simulation — the service MUST be
    built with `auto_flush=False` and `clock=clock`).  The dispatcher
    drains full micro-batches whenever it is free; arrivals keep
    landing while a dispatch is in flight, which is what fills the
    bounded queue and expires deadlines under a burst.  Returns
    {req_id: arrival_time_s} for latency accounting."""
    if service.svc.auto_flush:
        raise ValueError("replay_open_loop needs auto_flush=False — "
                         "auto-flush serves synchronously at submit "
                         "time and no queueing can ever build up")
    batch = service.svc.batch_docs
    free_at = 0.0
    arrivals = {}
    for t_arr, doc in trace:
        # dispatcher catches up on everything it could run before t_arr
        while free_at <= t_arr and len(service._pending) >= batch:
            clock.set(free_at)
            service.flush()
            free_at = clock.now()
        clock.set(t_arr)
        rid = service.submit(doc)
        arrivals[rid] = t_arr
    clock.set(max(free_at, clock.now()))
    service.drain()
    return arrivals
