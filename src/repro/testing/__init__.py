"""Deterministic chaos-engineering harness for the chain ensemble."""
from .faults import (FaultPlan, inject, no_faults, poison,
                     random_fault_plan, truncate_chain_file)

__all__ = ["FaultPlan", "inject", "no_faults", "poison",
           "random_fault_plan", "truncate_chain_file"]
