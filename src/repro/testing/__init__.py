"""Deterministic chaos-engineering harness for the chain ensemble."""
from .faults import (ElasticEvent, FaultPlan, VirtualClock, burst_trace,
                     inject, inject_dispatch_delay, mislabel_manifest,
                     no_faults, poison, poison_model_table,
                     random_elastic_events, random_fault_plan,
                     replay_open_loop, truncate_chain_file)

__all__ = ["ElasticEvent", "FaultPlan", "VirtualClock", "burst_trace",
           "inject", "inject_dispatch_delay", "mislabel_manifest",
           "no_faults", "poison", "poison_model_table",
           "random_elastic_events", "random_fault_plan",
           "replay_open_loop", "truncate_chain_file"]
