"""AdamW with explicit per-chain semantics.

Every param leaf is [n_chains, ...]; all optimizer statistics keep that
leading dim and every reduction (grad-norm clip, metrics) is per-chain —
nothing crosses the chain axis, preserving the paper's communication-free
property at the optimizer level.

Distributed-optimization tricks included:
  * low-precision optimizer state (`opt_dtype="bfloat16"` halves m/v bytes;
    the update math still runs in fp32),
  * optional int8 stochastic-rounding gradient quantization
    (`grad_quant_bits=8`) emulating compressed gradient aggregation,
  * decoupled weight decay + warmup-cosine schedule.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    opt_dtype: str = "float32"       # "bfloat16" halves optimizer-state HBM
    grad_quant_bits: int = 0         # 0 = off; 8 = int8 stochastic rounding


def lr_schedule(cfg: OptConfig, step):
    step = jnp.asarray(step, jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1)
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init_opt_state(params, cfg: OptConfig):
    dt = jnp.dtype(cfg.opt_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def _chain_axis(path) -> int:
    """Chain dim position: leaves under 'layers_stacked' carry a leading
    layer dim (scanned stacks), so their chain dim is axis 1."""
    return 1 if any(isinstance(p, jax.tree_util.DictKey)
                    and p.key == "layers_stacked" for p in path) else 0


def _per_chain_sq(path, g):
    """Sum of squares per chain: [..., C, ...] → [C]."""
    ax = _chain_axis(path)
    axes = tuple(i for i in range(g.ndim) if i != ax)
    return jnp.sum(jnp.square(g.astype(jnp.float32)), axis=axes)


def global_norm_per_chain(grads):
    flat, _ = jax.tree_util.tree_flatten_with_path(grads)
    return jnp.sqrt(sum(_per_chain_sq(path, g) for path, g in flat))


def clip_by_global_norm_per_chain(grads, clip_norm):
    norm = global_norm_per_chain(grads)                     # [C]
    scale = jnp.minimum(1.0, clip_norm / (norm + 1e-9))     # [C]

    def apply(path, g):
        ax = _chain_axis(path)
        shape = [1] * g.ndim
        shape[ax] = -1
        s = scale.reshape(shape)
        return (g.astype(jnp.float32) * s).astype(g.dtype)

    return jax.tree_util.tree_map_with_path(apply, grads), norm


def quantize_grads(grads, key, bits: int = 8):
    """Per-tensor-scale stochastic-rounding quantization (error ≤ 1 ulp).
    Emulates int8 compressed all-reduce payloads; unbiased by construction."""
    qmax = 2.0 ** (bits - 1) - 1

    def q(path, g):
        k = jax.random.fold_in(key, hash(str(path)) % (2 ** 31))
        gf = g.astype(jnp.float32)
        scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / qmax
        scaled = gf / scale
        noise = jax.random.uniform(k, g.shape) - 0.5
        return (jnp.round(scaled + noise) * scale).astype(g.dtype)

    return jax.tree_util.tree_map_with_path(q, grads)


def adamw_update(params, grads, state, cfg: OptConfig):
    """One AdamW step.  Returns (params', state', metrics dict)."""
    grads, gnorm = clip_by_global_norm_per_chain(grads, cfg.clip_norm)
    step = state["step"] + 1
    lr = lr_schedule(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        mf = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        vf = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(gf)
        mhat, vhat = mf / bc1, vf / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * \
            p.astype(jnp.float32)
        p2 = p.astype(jnp.float32) - lr * delta
        return p2.astype(p.dtype), mf.astype(m.dtype), vf.astype(v.dtype)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    params2 = jax.tree.unflatten(tdef, [o[0] for o in out])
    m2 = jax.tree.unflatten(tdef, [o[1] for o in out])
    v2 = jax.tree.unflatten(tdef, [o[2] for o in out])
    return params2, {"m": m2, "v": v2, "step": step}, {"grad_norm": gnorm,
                                                       "lr": lr}
