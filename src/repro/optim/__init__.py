"""Optimizer substrate: AdamW with per-chain semantics."""
from .adamw import (OptConfig, init_opt_state, adamw_update, lr_schedule,
                    clip_by_global_norm_per_chain, quantize_grads)

__all__ = ["OptConfig", "init_opt_state", "adamw_update", "lr_schedule",
           "clip_by_global_norm_per_chain", "quantize_grads"]
