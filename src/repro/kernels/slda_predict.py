"""Pallas TPU kernel for the sLDA *prediction* sweeps — the true hot path.

The paper's slowest variant (Weighted Average, Section III-C(d)) spends its
time predicting: every chain must run `n_pred_burnin + n_pred_samples`
test-time Gibbs sweeps over BOTH the test set and the full training set.
The training kernel (slda_gibbs.py) launches once per sweep because the
topic-word table must be refreshed globally between sweeps; prediction has
no such barrier — φ̂ is frozen — so ALL sweeps for a document block fuse
into ONE kernel launch here (DESIGN.md §Predict-kernel).

Three things make the fused kernel cheap:

  * layout — φ̂ is stored transposed, ``phi_t [W, T]``, resident in VMEM,
    so the per-token access is a sublane-dim *row* gather (the same trick
    as the train kernel's ``ntw_t``);
  * no log/exp — prediction is unsupervised, p(z=t) ∝ (N_dt^{-dn}+α)·φ̂_tw,
    a product of positives, so the categorical is sampled from the plain
    product instead of a log-sum-exp (the Gaussian response term that
    forces the train kernel into log space does not appear at test time);
  * matmul prefix-sum — the inverse-CDF's cumulative sum is computed as
    ``p @ U`` with U upper-triangular ones: one [DB, T]·[T, T] contraction
    that lands on the MXU on TPU and on a single gemm call on XLA:CPU,
    instead of a fusion-breaking `cumsum` + reduce pair per token (the
    single biggest CPU win — the token loop is dispatch-bound, not
    FLOP-bound);
  * counter-based PRNG — per-token uniforms are derived in-kernel from a
    murmur3-style mix of (doc_seed, sweep·N + n).  The seed path
    pre-materialized a ``[D, n_sweeps, N]`` uniform tensor, a multi-GB
    allocation at the paper's corpus sizes (it OOMed the Fig. 6 run).  On
    real TPU hardware ``tpu_prng=True`` swaps in the native
    ``pltpu.prng_random_bits`` generator — one hardware stream per doc
    block, seeded from a murmur mix of the block's first per-document
    seed and the grid index, so the per-DOCUMENT seed contract holds only
    on the portable hash path (off by default; also not bit-reproducible
    against the hash).

Post-burn-in ``ndt`` averages are accumulated in-kernel, so the only
outputs are ``ndt_avg [D, T]`` and the final assignments ``z [D, N]``.

Grid: (D / doc_block,).  `ref.ref_slda_predict_sweeps` is the oracle;
`slda_predict_sweeps_jnp` below is the bit-identical batched-jnp CPU fast
path (the one the benchmarks measure on this container).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.mathutil import upper_tri_ones
from .sparse import build_topic_index, sparse_two_stage_draw

try:  # pltpu imports on CPU builds too; guard for exotic installs
    from jax.experimental.pallas import tpu as pltpu
except ImportError:  # pragma: no cover
    pltpu = None

# murmur3 finalizer constants (public domain, Austin Appleby)
_MIX1 = np.uint32(0x85EBCA6B)
_MIX2 = np.uint32(0xC2B2AE35)
_GOLDEN = np.uint32(0x9E3779B9)
_INV24 = np.float32(2.0 ** -24)

# φ̂-gather hoist gate of the jnp twin (bitwise-neutral, perf only).
# Chain folding multiplied the twin's document-row counts by M (PR 3), so
# the [N, D_rows, T] hoisted tensor now crosses the CPU cache budget long
# before the old 64 MB cap.  Interleaved A/B on this container (T=8,
# W=1000, 25 sweeps; hoist-on vs hoist-off as distinct jitted callables):
#   rows=64..256, N=64 (0.12-0.5 MB): hoist 1.23-1.27x FASTER
#   rows=512, N=64 (1 MB):            0.96x — break-even/loss
#   rows=1024..4096, N=128..256 (4-32 MB): 0.82-0.92x — clear loss
# so the win collapses right around ~1 MB: re-gathering φ̂ rows per sweep
# beats streaming a cache-busting tensor 25 times.  512 KB keeps the
# small single-chain shapes that motivated the hoist (PR 1) inside the
# gate and pushes every M-folded paper-scale shape out.
_HOIST_T_MAX = 16
_HOIST_BYTES_MAX = 512 * 2 ** 10


def counter_uniform(seed, ctr):
    """Counter-based uniform in [0, 1): murmur3-finalizer mix of (seed, ctr).

    Pure elementwise integer ops — identical results inside a Pallas kernel
    (interpret or compiled), under jit, and in plain numpy-style jnp, which
    is what lets the kernel, the batched-jnp fast path, and the ref oracle
    share uniforms bit-for-bit.  Broadcasts over both arguments.
    """
    x = jnp.asarray(seed).astype(jnp.uint32) ^ (
        jnp.asarray(ctr).astype(jnp.uint32) * _GOLDEN)
    x = (x ^ (x >> 16)) * _MIX1
    x = (x ^ (x >> 13)) * _MIX2
    x = x ^ (x >> 16)
    # top 24 bits → f32 in [0, 1); strictly < 1 so inverse-CDF stays in range
    return (x >> 8).astype(jnp.float32) * _INV24


def predict_uniforms(seeds, n_sweeps: int, n_tokens: int,
                     ctr_stride: int | None = None):
    """Materialize the full [D, n_sweeps, N] uniform tensor the kernel
    derives on the fly — for feeding the ref oracle in equivalence tests.
    (Never used in production: this allocation is exactly what the fused
    kernel exists to avoid.)

    ctr_stride is the per-sweep counter stride (default: n_tokens).  The
    length-bucketed execution layer keeps it pinned to the SOURCE corpus
    max_len while looping only a bucket's (smaller) padded width, so every
    (doc, sweep, token) triple draws the same uniform it would in the
    unbucketed launch (DESIGN.md §Ragged-execution)."""
    if ctr_stride is None:
        ctr_stride = n_tokens
    ctr = (jnp.arange(n_sweeps, dtype=jnp.int32)[:, None] * ctr_stride
           + jnp.arange(n_tokens, dtype=jnp.int32)[None, :])
    return counter_uniform(seeds[:, None, None], ctr[None])


def _predict_kernel(tokens_ref, mask_ref, seed_ref, z_ref, ndt_ref, phi_t_ref,
                    *refs, alpha: float, n_burnin: int, n_samples: int,
                    n_tokens: int, ctr_stride: int, tpu_prng: bool,
                    chain_grid: bool = False, sampler_mode: str = "dense"):
    # sparse mode appends the three per-word topic-index inputs (frozen
    # like φ̂ itself); unpacking on the static mode keeps the dense trace
    # byte-identical to every prior PR
    if sampler_mode == "sparse":
        idx_ref, vmask_ref, occm_ref, z_out_ref, avg_ref = refs
    else:
        z_out_ref, avg_ref = refs
    phi_t = phi_t_ref[...]                    # [W, T] resident in VMEM
    seeds = seed_ref[:, 0]                    # [DB]
    T = phi_t.shape[1]
    topic_iota = jax.lax.broadcasted_iota(jnp.int32, (1, T), 1)
    tri_u = upper_tri_ones(T)

    if tpu_prng:
        # one hardware stream per DOC BLOCK (the per-core PRNG is stateful,
        # so per-document seeds cannot be honored here — only the portable
        # hash path keeps that contract).  Mix the block's first seed with
        # the (flattened) grid position through the murmur finalizer so
        # that distinct blocks get structurally uncorrelated streams (a
        # plain `seed + program_id` collides whenever s_i + i == s_j + j).
        pid = pl.program_id(0)
        if chain_grid:
            pid = pid * pl.num_programs(1) + pl.program_id(1)
        mixed = seed_ref[0, 0].astype(jnp.uint32) ^ (
            pid.astype(jnp.uint32) * _GOLDEN)
        mixed = (mixed ^ (mixed >> 16)) * _MIX1
        mixed = (mixed ^ (mixed >> 13)) * _MIX2
        pltpu.prng_seed((mixed ^ (mixed >> 16)).astype(jnp.int32))

    z_out_ref[...] = z_ref[...]               # z persists across sweeps here
    ndt0 = ndt_ref[...]                       # [DB, T]

    def sweep_body(s, carry):
        ndt, acc = carry

        def token_step(n, ndt):
            w = tokens_ref[:, n]              # [DB] int32 word ids
            m = mask_ref[:, n]                # [DB]
            z_old = z_out_ref[:, n]           # [DB]
            if tpu_prng:
                bits = pltpu.bitcast(
                    pltpu.prng_random_bits(w.shape), jnp.uint32)
                u = (bits >> 8).astype(jnp.float32) * _INV24
            else:
                u = counter_uniform(seeds, s * ctr_stride + n)

            old = (topic_iota == z_old[:, None]).astype(jnp.float32) * m[:, None]
            ndt = ndt - old
            p = (ndt + alpha) * jnp.take(phi_t, w, axis=0)      # row gather
            if sampler_mode == "sparse":
                # two-stage sparse draw (rare stage-2 correction
                # predicated inside — kernels/sparse.py)
                z_new = sparse_two_stage_draw(
                    p, u, jnp.take(idx_ref[...], w, axis=0),
                    jnp.take(vmask_ref[...], w, axis=0),
                    jnp.take(occm_ref[...], w, axis=0))
            else:
                c = jnp.dot(p, tri_u)                           # prefix sums
                z_new = jnp.sum(
                    (c < (u * c[:, -1])[:, None]).astype(jnp.int32), axis=1)
            z_new = jnp.where(m > 0, z_new, z_old).astype(jnp.int32)
            ndt = ndt + (topic_iota == z_new[:, None]).astype(jnp.float32) \
                * m[:, None]
            z_out_ref[:, n] = z_new
            return ndt

        ndt = jax.lax.fori_loop(0, n_tokens, token_step, ndt)
        keep = (s >= n_burnin).astype(jnp.float32)
        return ndt, acc + keep * ndt

    _, acc = jax.lax.fori_loop(0, n_burnin + n_samples, sweep_body,
                               (ndt0, jnp.zeros_like(ndt0)))
    # explicit f32 reciprocal multiply: a literal `acc / n` is rewritten to
    # divide-or-reciprocal at XLA's whim, which costs 1 ulp of cross-path
    # reproducibility when n is not a power of two
    avg_ref[...] = acc * np.float32(1.0 / n_samples)


def slda_predict_sweeps_pallas(tokens, mask, seeds, z0, ndt0, phi_t, *,
                               alpha, n_burnin, n_samples, doc_block=8,
                               interpret=True, tpu_prng=False,
                               ctr_stride=None, sampler_mode="dense",
                               sparse_topic_cap=32, topic_index=None):
    """All prediction sweeps for every document in ONE launch per doc block.

    tokens/mask/z0: [D, N]; seeds: int32 [D]; ndt0: [D, T]; phi_t: [W, T].
    Returns (ndt_avg [D, T], z_final [D, N]).  D must be a multiple of
    doc_block (ops.py pads).  ctr_stride pins the PRNG counter stride
    (default N — see predict_uniforms).
    """
    D, N = tokens.shape
    T = ndt0.shape[-1]
    W = phi_t.shape[0]
    assert D % doc_block == 0, (D, doc_block)
    grid = (D // doc_block,)

    doc_spec = lambda cols: pl.BlockSpec((doc_block, cols), lambda i: (i, 0))
    full = lambda shape: pl.BlockSpec(shape, lambda i: tuple(0 for _ in shape))

    kernel = functools.partial(
        _predict_kernel, alpha=float(alpha), n_burnin=int(n_burnin),
        n_samples=int(n_samples), n_tokens=N,
        ctr_stride=int(N if ctr_stride is None else ctr_stride),
        tpu_prng=tpu_prng, sampler_mode=sampler_mode)

    in_specs = [doc_spec(N), doc_spec(N), doc_spec(1),
                doc_spec(N), doc_spec(T), full((W, T))]
    operands = [tokens, mask, seeds[:, None], z0, ndt0, phi_t]
    if sampler_mode == "sparse":
        if topic_index is None:
            topic_index = build_topic_index(phi_t, sparse_topic_cap)
        cap = topic_index[0].shape[-1]
        in_specs += [full((W, cap)), full((W, cap)), full((W, T))]
        operands += list(topic_index)

    z_final, ndt_avg = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=[doc_spec(N), doc_spec(T)],
        out_shape=[jax.ShapeDtypeStruct((D, N), jnp.int32),
                   jax.ShapeDtypeStruct((D, T), jnp.float32)],
        interpret=interpret,
    )(*operands)
    return ndt_avg, z_final


def slda_predict_sweeps_chains_pallas(tokens, mask, seeds, z0, ndt0, phi_t,
                                      *, alpha, n_burnin, n_samples,
                                      doc_block=8, interpret=True,
                                      tpu_prng=False, ctr_stride=None,
                                      sampler_mode="dense",
                                      sparse_topic_cap=32,
                                      topic_index=None):
    """Chain-batched fused prediction: grid (M, D/doc_block), ONE launch
    for all M chains of the paper's parallel algorithms.

    tokens/mask: [D, N] — SHARED across chains: the token/mask BlockSpecs
    ignore the chain grid index, so ONE [D, N] corpus feeds all M chains
    instead of an M-way replicated [M, D, N] copy (the Weighted Average
    work-set is the test set plus the full training set, re-swept once
    per chain — the paper's stated dominant cost).  The chain axis is
    the OUTER grid dim, so each chain's φ̂ block stays resident across
    that chain's doc blocks (the [W, T] table is the large operand; the
    [doc_block, N] token tile is re-fetched per grid step either way).
    Per-chain state rides `None`-squeezed specs: seeds [M, D]; z0
    [M, D, N]; ndt0 [M, D, T]; phi_t [M, W, T].  The kernel body is
    EXACTLY `_predict_kernel`, so each chain's output is bit-identical
    to its single-chain launch.
    Returns (ndt_avg [M, D, T], z_final [M, D, N]).
    """
    D, N = tokens.shape
    M = phi_t.shape[0]
    T = ndt0.shape[-1]
    W = phi_t.shape[1]
    assert D % doc_block == 0, (D, doc_block)
    grid = (M, D // doc_block)

    shared = lambda cols: pl.BlockSpec((doc_block, cols),
                                       lambda c, i: (i, 0))
    cdoc = lambda cols: pl.BlockSpec((None, doc_block, cols),
                                     lambda c, i: (c, i, 0))
    cfull = lambda shape: pl.BlockSpec(
        (None,) + shape, lambda c, i: (c,) + tuple(0 for _ in shape))

    kernel = functools.partial(
        _predict_kernel, alpha=float(alpha), n_burnin=int(n_burnin),
        n_samples=int(n_samples), n_tokens=N,
        ctr_stride=int(N if ctr_stride is None else ctr_stride),
        tpu_prng=tpu_prng, chain_grid=True, sampler_mode=sampler_mode)

    in_specs = [shared(N), shared(N), cdoc(1),
                cdoc(N), cdoc(T), cfull((W, T))]
    operands = [tokens, mask, seeds[..., None], z0, ndt0, phi_t]
    if sampler_mode == "sparse":
        if topic_index is None:
            topic_index = build_topic_index(phi_t, sparse_topic_cap)
        cap = topic_index[0].shape[-1]
        in_specs += [cfull((W, cap)), cfull((W, cap)), cfull((W, T))]
        operands += list(topic_index)

    z_final, ndt_avg = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=[cdoc(N), cdoc(T)],
        out_shape=[jax.ShapeDtypeStruct((M, D, N), jnp.int32),
                   jax.ShapeDtypeStruct((M, D, T), jnp.float32)],
        interpret=interpret,
    )(*operands)
    return ndt_avg, z_final


def slda_predict_sweeps_chains_jnp(tokens, mask, seeds, z0, ndt0, phi_t, *,
                                   alpha, n_burnin, n_samples, unroll=8,
                                   ctr_stride=None, sampler_mode="dense",
                                   sparse_topic_cap=32):
    """Chain-batched jnp twin: FOLD the chain axis into the document-row
    axis around one stacked table.

    Prediction's tables are frozen, so M chains over D documents are the
    same computation as one chain over M·D documents against a stacked
    `[M·W, T]` φ̂ with per-chain token-id offsets `w + c·W` — every
    per-token op becomes one flat [M·D, T] row op (flat row gather, one
    gemm) instead of M vmapped lanes with batched-operand gathers.
    Per-document ops are row-independent (the same property the
    kernel-vs-twin block tests already rely on), so the fold is
    bit-identical to vmapping the single-chain twin over chains
    (asserted in tests/test_chain_batched.py).

    tokens/mask: [D, N] (shared) or [M, D, N]; seeds [M, D]; z0
    [M, D, N]; ndt0 [M, D, T]; phi_t [M, W, T].
    Returns (ndt_avg [M, D, T], z_final [M, D, N]).
    """
    M, W, T = phi_t.shape
    if tokens.ndim == 2:
        D, N = tokens.shape
        off = (jnp.arange(M, dtype=jnp.int32) * W)[:, None, None]
        tok_f = (tokens[None] + off).reshape(M * D, N)
        mask_f = jnp.broadcast_to(mask, (M, D, N)).reshape(M * D, N)
    else:
        _, D, N = tokens.shape
        off = (jnp.arange(M, dtype=jnp.int32) * W)[:, None, None]
        tok_f = (tokens + off).reshape(M * D, N)
        mask_f = mask.reshape(M * D, N)
    ndt_avg, z_final = slda_predict_sweeps_jnp(
        tok_f, mask_f, seeds.reshape(M * D), z0.reshape(M * D, N),
        ndt0.reshape(M * D, T), phi_t.reshape(M * W, T),
        alpha=alpha, n_burnin=n_burnin, n_samples=n_samples, unroll=unroll,
        ctr_stride=ctr_stride, sampler_mode=sampler_mode,
        sparse_topic_cap=sparse_topic_cap)
    return ndt_avg.reshape(M, D, T), z_final.reshape(M, D, N)


def slda_predict_stair_jnp(seg_tokens, seg_mask, seg_z0, seg_row_start,
                           seg_tok_start, seeds, ndt0, phi_t, *, alpha,
                           n_burnin, n_samples, ctr_stride, unroll=8,
                           sampler_mode="dense", sparse_topic_cap=32):
    """STAIRCASE prediction twin — the ragged execution layer's CPU
    executor (DESIGN.md §Ragged-execution).

    Documents are sorted ASCENDING by length, so a length-bucket
    schedule's widths w_1 < … < w_K split the token axis into segments
    [w_{k-1}, w_k) in which the docs still alive are exactly the SUFFIX
    of rows starting at `seg_row_start[k]`.  One sweep walks the
    segments in order, each a lax.scan over that segment's positions on
    only the live rows — the total step count stays w_K = N_max (unlike
    per-bucket launches, which re-run the early positions per bucket and
    inflate Σ_b N_b sequential steps; that inflation is what makes
    per-bucket prediction LOSE on dispatch-bound CPU token loops), while
    the executed row-slots collapse to the staircase ≈ Σ true tokens.

    Per-token ops are row-independent and the counter uniforms use the
    GLOBAL token position (`seg_tok_start[k] + n` at stride ctr_stride),
    so per-document results are bit-identical to the padded twin for any
    schedule — same contract as the per-bucket launches
    (tests/test_ragged.py).

    seg_tokens/seg_mask/seg_z0: per-segment arrays [R_k, L_k] with
    R_k = R - seg_row_start[k] (rows are the flat doc axis — the caller
    folds chains doc-major so doc suffixes stay row suffixes);
    seeds: [R]; ndt0: [R, T]; phi_t: [W, T] (stacked [M·W, T] when
    chains are folded, with token ids pre-offset).
    Returns ndt_avg [R, T] (z is consumed internally; prediction's only
    product is the post-burn-in average).
    """
    R, T = ndt0.shape
    n_sweeps = n_burnin + n_samples
    topic_iota = jnp.arange(T, dtype=jnp.int32)[None, :]
    tri_u = upper_tri_ones(T)
    # φ̂ (possibly chain-stacked) is frozen, so the index is too; stacked
    # rows c·W + w equal the per-chain tables bit-for-bit
    if sampler_mode == "sparse":
        s_idx, s_vm, s_om = build_topic_index(phi_t, sparse_topic_cap)
    segs = []
    for tok, mk, z, r0, n0 in zip(seg_tokens, seg_mask, seg_z0,
                                  seg_row_start, seg_tok_start):
        L = tok.shape[-1]
        n_iota = jnp.arange(n0, n0 + L, dtype=jnp.int32)
        segs.append((tok.T, mk.T, int(r0), n_iota))  # token-major
    z_init = tuple(z.T for z in seg_z0)

    def one_sweep(carry, s):
        z_segs, ndt, acc = carry
        new_z = []
        for (tok_t, mask_t, r0, n_iota), z_t in zip(segs, z_segs):
            sub_seeds = seeds[r0:]

            def token_step(nd, inp):
                w, m, z_old, n = inp
                pw = jnp.take(phi_t, w, axis=0)
                u = counter_uniform(sub_seeds, s * ctr_stride + n)
                old = (topic_iota == z_old[:, None]).astype(jnp.float32) \
                    * m[:, None]
                nd = nd - old
                p = (nd + alpha) * pw
                if sampler_mode == "sparse":
                    z_new = sparse_two_stage_draw(
                        p, u, jnp.take(s_idx, w, axis=0),
                        jnp.take(s_vm, w, axis=0),
                        jnp.take(s_om, w, axis=0))
                else:
                    c = jnp.dot(p, tri_u)
                    z_new = jnp.sum(
                        (c < (u * c[:, -1])[:, None]).astype(jnp.int32),
                        axis=1)
                z_new = jnp.where(m > 0, z_new, z_old).astype(jnp.int32)
                nd = nd + (topic_iota == z_new[:, None]) \
                    .astype(jnp.float32) * m[:, None]
                return nd, z_new

            nd, z_t = jax.lax.scan(token_step, ndt[r0:],
                                   (tok_t, mask_t, z_t, n_iota),
                                   unroll=unroll)
            ndt = ndt.at[r0:].set(nd) if r0 else nd
            new_z.append(z_t)
        keep = (s >= n_burnin).astype(jnp.float32)
        return (tuple(new_z), ndt, acc + keep * ndt), None

    (_, _, acc), _ = jax.lax.scan(
        one_sweep, (z_init, ndt0, jnp.zeros_like(ndt0)),
        jnp.arange(n_sweeps, dtype=jnp.int32))
    # f32 reciprocal multiply, matching the fused kernel bit-for-bit
    return acc * np.float32(1.0 / n_samples)


def slda_predict_sweeps_jnp(tokens, mask, seeds, z0, ndt0, phi_t, *,
                            alpha, n_burnin, n_samples, unroll=8,
                            ctr_stride=None, sampler_mode="dense",
                            sparse_topic_cap=32):
    """Batched-jnp twin of the fused kernel — the CPU fast path.

    Same restructuring as the kernel, expressed as XLA-friendly jnp: all D
    documents advance in lockstep (one [D, T] vector op per token instead
    of a vmap of per-document scans), φ̂ is row-gathered from the
    transposed [W, T] layout, prefix sums are the same `p @ U` contraction,
    all sweeps fuse into one `lax.scan` (unrolled ×8: the token loop is
    dispatch-bound on CPU), and the uniforms come from the same counter
    hash — so no [D, S, N] tensor, no per-sweep threefry, no log/exp.
    Bit-identical to the interpret-mode kernel (shared op order + PRNG).

    For small topic counts (T ≤ 16, where the gemm no longer dominates,
    and only while the gathered [N, D, T] tensor stays cache-resident —
    see the _HOIST_* gate constants above, re-tuned for M-folded row
    counts) the φ̂ row gather is additionally hoisted out of the sweep
    loop so the sweeps share it instead of re-gathering every sweep.
    """
    D, N = tokens.shape
    if ctr_stride is None:
        ctr_stride = N
    n_sweeps = n_burnin + n_samples
    T = ndt0.shape[-1]
    topic_iota = jnp.arange(T, dtype=jnp.int32)[None, :]
    tok_t = tokens.T                           # [N, D] token-major for scan
    mask_t = mask.T
    n_iota = jnp.arange(N, dtype=jnp.int32)
    tri_u = upper_tri_ones(T)
    # hoist the sweep-invariant φ̂ gather when the [N, D, T] tensor is small
    # — small in T (where the gemm no longer dominates) AND in absolute
    # bytes, so paper-scale corpora never re-materialize the kind of
    # multi-GB tensor this module exists to avoid
    # sparse mode disables the hoist (the index gathers are per-token
    # anyway) and builds the frozen per-word index once per call
    hoist = (sampler_mode != "sparse" and T <= _HOIST_T_MAX
             and N * D * T * 4 <= _HOIST_BYTES_MAX)
    phi_w = jnp.take(phi_t, tok_t, axis=0) if hoist else None
    if sampler_mode == "sparse":
        s_idx, s_vm, s_om = build_topic_index(phi_t, sparse_topic_cap)

    def one_sweep(carry, s):
        z_t, ndt, acc = carry                  # [N, D], [D, T], [D, T]

        def token_step(ndt, inp):
            pw_or_w, m, z_old, n = inp         # [D(,T)], [D], [D], scalar
            pw = pw_or_w if hoist else jnp.take(phi_t, pw_or_w, axis=0)
            u = counter_uniform(seeds, s * ctr_stride + n)
            old = (topic_iota == z_old[:, None]).astype(jnp.float32) * m[:, None]
            ndt = ndt - old
            p = (ndt + alpha) * pw
            if sampler_mode == "sparse":
                z_new = sparse_two_stage_draw(
                    p, u, jnp.take(s_idx, pw_or_w, axis=0),
                    jnp.take(s_vm, pw_or_w, axis=0),
                    jnp.take(s_om, pw_or_w, axis=0))
            else:
                c = jnp.dot(p, tri_u)          # prefix sums on one gemm
                z_new = jnp.sum(
                    (c < (u * c[:, -1])[:, None]).astype(jnp.int32), axis=1)
            z_new = jnp.where(m > 0, z_new, z_old).astype(jnp.int32)
            ndt = ndt + (topic_iota == z_new[:, None]).astype(jnp.float32) \
                * m[:, None]
            return ndt, z_new

        xs = (phi_w if hoist else tok_t, mask_t, z_t, n_iota)
        ndt, z_t = jax.lax.scan(token_step, ndt, xs, unroll=unroll)
        keep = (s >= n_burnin).astype(jnp.float32)
        return (z_t, ndt, acc + keep * ndt), None

    (z_t, _, acc), _ = jax.lax.scan(
        one_sweep, (z0.T, ndt0, jnp.zeros_like(ndt0)),
        jnp.arange(n_sweeps, dtype=jnp.int32))
    # f32 reciprocal multiply, matching the kernel bit-for-bit
    return acc * np.float32(1.0 / n_samples), z_t.T
