"""Pallas TPU kernel for fused multi-sweep sLDA *training* launches.

PR 1 fused all prediction sweeps into one launch (slda_predict.py); this
module does the same for training, the other half of every chain's
wall-clock.  The seed training loop pays, per sweep: one kernel launch,
one `[D, N]` threefry uniforms materialization, and one host-visible
count refresh.  The fused path amortizes all three over
``n_sweeps = SLDAConfig.sweeps_per_launch`` Gibbs sweeps per launch.

What carries over from the predict kernel (DESIGN.md §Predict-kernel):

  * counter-hash PRNG — per-token uniforms from a murmur3-style mix of
    (doc_seed, sweep·N + n), shared bit-for-bit by kernel / jnp twin /
    oracle through `train_uniforms` (same contract as `predict_uniforms`);
  * transposed `[W, T]` row-gather layout for the topic-word table;
  * matmul prefix sums (`p @ U`, U upper-triangular ones) for the
    inverse-CDF categorical.

What is new — **in-kernel delayed-count refresh** (DESIGN.md
§Train-kernel): unlike prediction, training must refresh `ntw`/`nt`
between sweeps.  DESIGN.md §3's AD-LDA delayed-count argument already
treats the table as *stale within a sweep* and exact afterwards; the same
argument licenses keeping a block-local copy of the table in VMEM scratch
and applying the block's own ±1 deltas between the sweeps of one launch:

  * within a sweep the table is frozen (sweep-frozen lockstep documents,
    exactly the seed semantics);
  * between sweeps each `doc_block` applies ITS OWN documents' deltas to
    its local copy — exact per block, delayed across blocks until the
    launch ends and the host applies the exact global
    `apply_count_deltas(z_launch_start, z_final)` refresh;
  * the refresh is a **segmented one-hot matmul**: per token position the
    block's ±1 topic deltas land on the local table through one
    `[W, DB]·[DB, T]` contraction (an MXU op on TPU) instead of a
    sequential per-document row-update loop; a `pl.when` skips the
    contraction whenever no document in the block moved that token
    (Magnusson et al.: late in sampling nearly all tokens are unchanged).
    All products and partial sums are 0/±1 integers far below 2^24, so
    the matmul totals are EXACT and bit-identical to the twin's and
    oracle's scatter-adds regardless of accumulation order.

**Sampling form** — two, selected by ``product_form``:

  * log form (``product_form=False``, the seed semantics): p ∝
    exp(log(N_dt+α) + log(N_tw+β) − log(N_t+Wβ) − (y−μ)²/2ρ − max).
    `n_sweeps=1` launches keep this form so a single-sweep launch is
    exactly one seed-semantics sweep (bitwise: tests/test_train_kernel.py
    asserts agreement with the single-sweep `slda_gibbs` kernel under
    shared uniforms).
  * product form (``product_form=True``, the multi-sweep default):
    p ∝ (N_dt+α)·(N_tw+β)/(N_t+Wβ) · exp(g − max g) with
    g = −(y−μ_t)²/2ρ — the same categorical distribution (the inverse
    CDF normalizes away the scale) sampled from one `exp` per token
    instead of three `log`s, exactly how the predict kernel already
    samples its (unsupervised) product of positives.  Multi-sweep
    launches are already their own sampler family (counter-hash PRNG,
    block-delayed counts — statistically equivalent, not bit-equal to
    seed), so the cheaper form changes no contract; kernel, twin and
    oracle share it bit-for-bit.

Grids: ``(D/doc_block,)`` single-chain, ``(M, D/doc_block)`` in the
chain-batched form (`slda_train_sweeps_chains_pallas`): the leading grid
dimension walks the M independent chains of the paper's parallel
algorithms, each grid cell reading ITS chain's `ntw/nt/eta/seed` blocks
(`None`-squeezed BlockSpecs).  `ref.ref_slda_train_sweeps` is the oracle;
`slda_train_sweeps_jnp` below is the bit-identical blocked-jnp CPU fast
path (what the benchmarks measure on this container) and
`slda_train_sweeps_chains_jnp` its chain-batched form.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.mathutil import upper_tri_ones
from .slda_predict import _GOLDEN, _INV24, _MIX1, _MIX2, counter_uniform
from .slda_predict import predict_uniforms as _uniforms_tensor
from .sparse import build_topic_index, sparse_two_stage_draw

try:  # pltpu imports on CPU builds too; guard for exotic installs
    from jax.experimental.pallas import tpu as pltpu
except ImportError:  # pragma: no cover
    pltpu = None


def train_uniforms(seeds, n_sweeps: int, n_tokens: int,
                   ctr_stride: int | None = None):
    """Materialize the [D, n_sweeps, N] uniforms the fused train paths
    derive on the fly — the shared-uniforms contract for driving the ref
    oracle (and the seed single-sweep path) in equivalence tests.  Same
    counter layout (and ctr_stride semantics) as `predict_uniforms`;
    never used in production."""
    return _uniforms_tensor(seeds, n_sweeps, n_tokens, ctr_stride)


def _train_kernel(tokens_ref, mask_ref, seed_ref, z_ref, ndt_ref, y_ref,
                  invlen_ref, ntw_t_ref, nt_ref, eta_ref, *refs,
                  alpha: float, beta: float, rho: float, supervised: bool,
                  n_sweeps: int, n_tokens: int, ctr_stride: int,
                  vocab_size: int, tpu_prng: bool, product_form: bool,
                  chain_grid: bool, sampler_mode: str = "dense"):
    # sparse mode appends three LAUNCH-frozen topic-index inputs (built
    # by the wrapper from the entry table — in-launch count evolution
    # never rebuilds them; exactness does not depend on index freshness).
    # Unpacking on the static mode keeps the dense trace byte-identical.
    if sampler_mode == "sparse":
        (idx_ref, vmask_ref, occm_ref,
         z_out_ref, ndt_out_ref, ntw_scratch) = refs
    else:
        z_out_ref, ndt_out_ref, ntw_scratch = refs
    eta = eta_ref[0, :]                       # [T]
    seeds = seed_ref[:, 0]                    # [DB]
    y = y_ref[:, 0]                           # [DB]
    inv_len = invlen_ref[:, 0]                # [DB]
    T = eta.shape[0]
    DB = tokens_ref.shape[0]
    topic_iota = jax.lax.broadcasted_iota(jnp.int32, (1, T), 1)
    tri_u = upper_tri_ones(T)

    if tpu_prng:
        # one hardware stream per doc block, murmur-mixed with the
        # (flattened) grid index (same caveats as the predict kernel: the
        # per-DOCUMENT seed contract holds only on the portable hash path)
        pid = pl.program_id(0)
        if chain_grid:
            pid = pid * pl.num_programs(1) + pl.program_id(1)
        mixed = seed_ref[0, 0].astype(jnp.uint32) ^ (
            pid.astype(jnp.uint32) * _GOLDEN)
        mixed = (mixed ^ (mixed >> 16)) * _MIX1
        mixed = (mixed ^ (mixed >> 13)) * _MIX2
        pltpu.prng_seed((mixed ^ (mixed >> 16)).astype(jnp.int32))

    ntw_scratch[...] = ntw_t_ref[...]         # [W, T] block-local copy
    z_out_ref[...] = z_ref[...]               # z persists across sweeps

    def sweep_body(s, carry):
        ndt_start, nt = carry                 # [DB, T], [T] sweep-frozen
        ntw_t = ntw_scratch[...]              # frozen snapshot for the sweep
        z_prev = z_out_ref[...]               # [DB, N] sweep-start z
        s0 = ndt_start @ eta                  # [DB] running Σ_t η_t N_dt

        def token_step(n, carry2):
            ndt, st = carry2
            w = tokens_ref[:, n]              # [DB] int32 word ids
            m = mask_ref[:, n]                # [DB]
            z_old = z_out_ref[:, n]           # [DB]
            if tpu_prng:
                bits = pltpu.bitcast(
                    pltpu.prng_random_bits(w.shape), jnp.uint32)
                u = (bits >> 8).astype(jnp.float32) * _INV24
            else:
                u = counter_uniform(seeds, s * ctr_stride + n)

            old = (topic_iota == z_old[:, None]).astype(jnp.float32) \
                * m[:, None]
            ndt = ndt - old
            st = st - jnp.take(eta, z_old) * m

            ntw_w = jnp.take(ntw_t, w, axis=0) - old    # [DB, T], -dn exact
            if product_form:
                p = (ndt + alpha) * (ntw_w + beta) \
                    / (nt[None, :] - old + vocab_size * beta)
                if supervised:
                    mu_t = (st[:, None] + eta[None, :]) * inv_len[:, None]
                    g = -0.5 * (y[:, None] - mu_t) ** 2 / rho
                    p = p * jnp.exp(g - jnp.max(g, axis=1, keepdims=True))
            else:
                logp = (jnp.log(ndt + alpha)
                        + jnp.log(ntw_w + beta)
                        - jnp.log(nt[None, :] - old + vocab_size * beta))
                if supervised:
                    mu_t = (st[:, None] + eta[None, :]) * inv_len[:, None]
                    logp = logp - 0.5 * (y[:, None] - mu_t) ** 2 / rho
                p = jnp.exp(logp - jnp.max(logp, axis=1, keepdims=True))

            if sampler_mode == "sparse":
                # two-stage sparse draw; the rare stage-2 correction is
                # predicated inside (lax.cond — the value-returning form
                # of pl.when, bitwise-equal to the branch-free select)
                z_new = sparse_two_stage_draw(
                    p, u, jnp.take(idx_ref[...], w, axis=0),
                    jnp.take(vmask_ref[...], w, axis=0),
                    jnp.take(occm_ref[...], w, axis=0))
            else:
                c = jnp.dot(p, tri_u)                   # prefix sums
                z_new = jnp.sum(
                    (c < (u * c[:, -1])[:, None]).astype(jnp.int32), axis=1)
            z_new = jnp.where(m > 0, z_new, z_old).astype(jnp.int32)

            ndt = ndt + (topic_iota == z_new[:, None]).astype(jnp.float32) \
                * m[:, None]
            st = st + jnp.take(eta, z_new) * m
            z_out_ref[:, n] = z_new
            return ndt, st

        ndt, _ = jax.lax.fori_loop(0, n_tokens, token_step, (ndt_start, s0))

        # block-local delayed-count refresh as a segmented one-hot matmul:
        # for each token position the block's ±1 topic deltas reach the
        # local table through one [W, DB]·[DB, T] contraction — 0/±1
        # integer products with integer partial sums ≪ 2^24, so the totals
        # are EXACT and order-independent (bit-identical to the twin's and
        # oracle's scatter-adds).  Skipped after the final sweep (the
        # local table is not an output) and — per token — whenever no
        # document in the block moved (the common case late in sampling).
        @pl.when(s < n_sweeps - 1)
        def _refresh():
            vocab_iota = jax.lax.broadcasted_iota(
                jnp.int32, (vocab_size, DB), 0)

            def refresh_token(n, _):
                w = tokens_ref[:, n]
                m = mask_ref[:, n]
                zo = z_prev[:, n]
                zn = z_out_ref[:, n]
                moved = (zo != zn) & (m > 0)

                @pl.when(jnp.any(moved))
                def _mm():
                    mv = moved.astype(jnp.float32)            # [DB]
                    sel = (vocab_iota == w[None, :]) \
                        .astype(jnp.float32)                  # [W, DB]
                    dvec = ((topic_iota == zn[:, None]).astype(jnp.float32)
                            - (topic_iota == zo[:, None])
                            .astype(jnp.float32)) * mv[:, None]  # [DB, T]
                    ntw_scratch[...] = ntw_scratch[...] + jnp.dot(sel, dvec)
                return 0
            jax.lax.fori_loop(0, n_tokens, refresh_token, 0)

        # Δnt is the column-sum of the block's ndt deltas — exact, no
        # per-token work (±1.0 f32 adds are lossless at these magnitudes)
        return ndt, nt + jnp.sum(ndt - ndt_start, axis=0)

    ndt_final, _ = jax.lax.fori_loop(0, n_sweeps, sweep_body,
                                     (ndt_ref[...], nt_ref[0, :]))
    ndt_out_ref[...] = ndt_final


def slda_train_sweeps_pallas(tokens, mask, seeds, z0, ndt0, y, inv_len,
                             ntw_t, nt, eta, *, alpha, beta, rho,
                             supervised=True, n_sweeps=1, doc_block=8,
                             interpret=True, tpu_prng=False,
                             product_form=False, ctr_stride=None,
                             sampler_mode="dense", sparse_topic_cap=32,
                             topic_index=None):
    """All `n_sweeps` training sweeps for a doc block in ONE launch.

    tokens/mask/z0: [D, N]; seeds: int32 [D]; ndt0: [D, T]; y/inv_len: [D];
    ntw_t: [W, T] (row-gather layout); nt/eta: [T].  D must be a multiple
    of doc_block (ops.py pads).  Returns (z_final [D, N], ndt_final [D, T]);
    the caller refreshes the global tables from (z0, z_final).
    ctr_stride pins the PRNG counter stride (default N — see
    slda_predict.predict_uniforms).  sampler_mode="sparse" routes the
    per-token draw through the two-stage sparse draw against a
    launch-frozen per-word topic index (built here from `ntw_t`, or
    passed pre-built as `topic_index=(idx, vmask, occm)`).
    """
    D, N = tokens.shape
    T = ndt0.shape[-1]
    W = ntw_t.shape[0]
    assert D % doc_block == 0, (D, doc_block)
    grid = (D // doc_block,)

    doc_spec = lambda cols: pl.BlockSpec((doc_block, cols), lambda i: (i, 0))
    full = lambda shape: pl.BlockSpec(shape, lambda i: tuple(0 for _ in shape))

    kernel = functools.partial(
        _train_kernel, alpha=float(alpha), beta=float(beta), rho=float(rho),
        supervised=supervised, n_sweeps=int(n_sweeps), n_tokens=N,
        ctr_stride=int(N if ctr_stride is None else ctr_stride),
        vocab_size=W, tpu_prng=tpu_prng, product_form=product_form,
        chain_grid=False, sampler_mode=sampler_mode)

    in_specs = [doc_spec(N), doc_spec(N), doc_spec(1), doc_spec(N),
                doc_spec(T), doc_spec(1), doc_spec(1),
                full((W, T)), full((1, T)), full((1, T))]
    operands = [tokens, mask, seeds[:, None], z0, ndt0, y[:, None],
                inv_len[:, None], ntw_t, nt[None, :], eta[None, :]]
    if sampler_mode == "sparse":
        if topic_index is None:
            topic_index = build_topic_index(ntw_t, sparse_topic_cap)
        cap = topic_index[0].shape[-1]
        in_specs += [full((W, cap)), full((W, cap)), full((W, T))]
        operands += list(topic_index)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=[doc_spec(N), doc_spec(T)],
        out_shape=[jax.ShapeDtypeStruct((D, N), jnp.int32),
                   jax.ShapeDtypeStruct((D, T), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((W, T), jnp.float32)],
        interpret=interpret,
    )(*operands)


def slda_train_sweeps_chains_pallas(tokens, mask, seeds, z0, ndt0, y,
                                    inv_len, ntw_t, nt, eta, *, alpha, beta,
                                    rho, supervised=True, n_sweeps=1,
                                    doc_block=8, interpret=True,
                                    tpu_prng=False, product_form=False,
                                    ctr_stride=None, sampler_mode="dense",
                                    sparse_topic_cap=32, topic_index=None):
    """Chain-batched fused train launch: grid (M, D/doc_block).

    One pallas_call runs all M independent chains: tokens/mask/z0
    [M, D, N]; seeds [M, D]; ndt0 [M, D, T]; y/inv_len [M, D]; ntw_t
    [M, W, T]; nt/eta [M, T].  The leading grid dimension selects the
    chain; every per-chain input is carved with a `None`-squeezed
    BlockSpec so the kernel body is EXACTLY `_train_kernel` — same ops,
    same order, bit-identical per chain to the single-chain launch.
    Returns (z_final [M, D, N], ndt_final [M, D, T]).
    """
    M, D, N = tokens.shape
    T = ndt0.shape[-1]
    W = ntw_t.shape[1]
    assert D % doc_block == 0, (D, doc_block)
    grid = (M, D // doc_block)

    cdoc = lambda cols: pl.BlockSpec((None, doc_block, cols),
                                     lambda c, i: (c, i, 0))
    cfull = lambda shape: pl.BlockSpec(
        (None,) + shape, lambda c, i: (c,) + tuple(0 for _ in shape))

    kernel = functools.partial(
        _train_kernel, alpha=float(alpha), beta=float(beta), rho=float(rho),
        supervised=supervised, n_sweeps=int(n_sweeps), n_tokens=N,
        ctr_stride=int(N if ctr_stride is None else ctr_stride),
        vocab_size=W, tpu_prng=tpu_prng, product_form=product_form,
        chain_grid=True, sampler_mode=sampler_mode)

    in_specs = [cdoc(N), cdoc(N), cdoc(1), cdoc(N),
                cdoc(T), cdoc(1), cdoc(1),
                cfull((W, T)), cfull((1, T)), cfull((1, T))]
    operands = [tokens, mask, seeds[..., None], z0, ndt0, y[..., None],
                inv_len[..., None], ntw_t, nt[:, None, :], eta[:, None, :]]
    if sampler_mode == "sparse":
        if topic_index is None:
            topic_index = build_topic_index(ntw_t, sparse_topic_cap)
        cap = topic_index[0].shape[-1]
        in_specs += [cfull((W, cap)), cfull((W, cap)), cfull((W, T))]
        operands += list(topic_index)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=[cdoc(N), cdoc(T)],
        out_shape=[jax.ShapeDtypeStruct((M, D, N), jnp.int32),
                   jax.ShapeDtypeStruct((M, D, T), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((W, T), jnp.float32)],
        interpret=interpret,
    )(*operands)


def slda_train_sweeps_jnp(tokens, mask, seeds, z0, ndt0, y, inv_len,
                          ntw_t, nt, eta, *, alpha, beta, rho,
                          supervised=True, n_sweeps=1, doc_block=8,
                          unroll=8, product_form=False, ctr_stride=None,
                          sampler_mode="dense", sparse_topic_cap=32,
                          topic_index=None):
    """Blocked-jnp twin of the fused train kernel — the CPU fast path.

    Same restructuring expressed as XLA-friendly jnp: a vmap over doc
    blocks, each block's documents advancing in lockstep (one [DB, T]
    vector op per token, identical op order to the kernel so the bits
    match), the token scan unrolled ×8, and the block-local between-sweep
    refresh as a scalar 2-scatter over the block's tokens (same exact
    integer arithmetic as the kernel's segmented one-hot matmul, so the
    tables agree bit-for-bit regardless of accumulation order).

    In product form (the multi-sweep default) the per-token work is one
    row gather + one `exp`, mirroring the kernel verbatim.  The log form
    (seed semantics, `n_sweeps=1` launches) keeps two twin-only rewrites
    that cut the CPU transcendental count while preserving the bits:

      * hoisted log tables — `log(ntw+β)` / `log(nt+Wβ)` are sweep-frozen,
        so they are computed ONCE per sweep ([W, T] + [T] logs) and row-
        gathered per token; the only entry the -dn exclusion touches is
        the document's own (w, z_old) cell, which gets a scalar fixup
        `log((v-1)+β)`.  Bitwise-safe because `(v - 0.0) + β ≡ v + β` in
        IEEE f32, so every element equals the kernel's
        `log((v - old) + β)` exactly;
      * the token loop is a `lax.scan` unrolled ×8 (dispatch-bound).

    Memory: each block carries its own [W, T] count copy (plus a log-table
    copy in log form), so the live footprint is ~2·(D/doc_block)·W·T
    floats — larger doc_block is both faster (fewer vmap lanes) and *less*
    delayed (fewer blocks); core.gibbs clamps it to the corpus size.
    """
    D, N = tokens.shape
    if ctr_stride is None:
        ctr_stride = N
    T = ndt0.shape[-1]
    W = ntw_t.shape[0]
    assert D % doc_block == 0, (D, doc_block)
    B = D // doc_block
    topic_iota = jnp.arange(T, dtype=jnp.int32)[None, :]
    tri_u = upper_tri_ones(T)
    n_iota = jnp.arange(N, dtype=jnp.int32)
    # sparse mode: LAUNCH-frozen index from the entry table, shared by
    # all blocks — exactly the kernel's extra-input contract
    if sampler_mode == "sparse" and topic_index is None:
        topic_index = build_topic_index(ntw_t, sparse_topic_cap)
    s_idx, s_vm, s_om = topic_index if topic_index is not None else (
        None, None, None)

    blk = lambda a: a.reshape((B, doc_block) + a.shape[1:])

    def block_fn(tok_b, mask_b, seed_b, z_b, ndt_b, y_b, il_b):
        tok_t = tok_b.T                        # [N, DB] token-major for scan
        mask_t = mask_b.T
        w_flat = tok_b.ravel()                 # [DB*N] for the refresh

        def one_sweep(carry, s, refresh=True):
            z_t, ndt_start, ntw_loc, nt_loc = carry
            s0 = ndt_start @ eta
            if not product_form:
                # sweep-frozen hoisted log tables (see docstring: bit-equal
                # to the kernel's per-token logs as (v - 0.0) + β ≡ v + β)
                log_ntw = jnp.log(ntw_loc + beta)      # [W, T]
                log_nt = jnp.log(nt_loc + W * beta)    # [T]

            def token_step(carry2, inp):
                ndt, st = carry2
                w, m, z_old, n = inp
                u = counter_uniform(seed_b, s * ctr_stride + n)
                own = (topic_iota == z_old[:, None]) & (m[:, None] > 0)
                old = own.astype(jnp.float32)
                ndt = ndt - old
                st = st - jnp.take(eta, z_old) * m
                if product_form:
                    ntw_w = jnp.take(ntw_loc, w, axis=0) - old
                    p = (ndt + alpha) * (ntw_w + beta) \
                        / (nt_loc[None, :] - old + W * beta)
                    if supervised:
                        mu_t = (st[:, None] + eta[None, :]) * il_b[:, None]
                        g = -0.5 * (y_b[:, None] - mu_t) ** 2 / rho
                        p = p * jnp.exp(g - jnp.max(g, axis=1, keepdims=True))
                else:
                    # own-token -dn fixups: one scalar log per document
                    v_own = ntw_loc[w, z_old]          # [DB]
                    fix_ntw = jnp.log((v_own - 1.0) + beta)
                    fix_nt = jnp.log((jnp.take(nt_loc, z_old) - 1.0)
                                     + W * beta)
                    lw = jnp.where(own, fix_ntw[:, None],
                                   jnp.take(log_ntw, w, axis=0))
                    ln = jnp.where(own, fix_nt[:, None], log_nt[None, :])
                    logp = jnp.log(ndt + alpha) + lw - ln
                    if supervised:
                        mu_t = (st[:, None] + eta[None, :]) * il_b[:, None]
                        logp = logp - 0.5 * (y_b[:, None] - mu_t) ** 2 / rho
                    p = jnp.exp(logp - jnp.max(logp, axis=1, keepdims=True))
                if sampler_mode == "sparse":
                    z_new = sparse_two_stage_draw(
                        p, u, jnp.take(s_idx, w, axis=0),
                        jnp.take(s_vm, w, axis=0),
                        jnp.take(s_om, w, axis=0))
                else:
                    c = jnp.dot(p, tri_u)
                    z_new = jnp.sum(
                        (c < (u * c[:, -1])[:, None]).astype(jnp.int32),
                        axis=1)
                z_new = jnp.where(m > 0, z_new, z_old).astype(jnp.int32)
                ndt = ndt + (topic_iota == z_new[:, None]) \
                    .astype(jnp.float32) * m[:, None]
                st = st + jnp.take(eta, z_new) * m
                return (ndt, st), z_new

            (ndt, _), z_t_new = jax.lax.scan(
                token_step, (ndt_start, s0), (tok_t, mask_t, z_t, n_iota),
                unroll=unroll)

            # block-local delayed-count refresh: scalar ±1 2-scatter over
            # the block's changed tokens (exact; see module docstring).
            # Skipped after the final sweep — the tables are not outputs —
            # mirroring the kernel's pl.when (bits unchanged)
            if refresh:
                zo = z_t.T.ravel()
                zn = z_t_new.T.ravel()
                changed = mask_b.ravel() * (zn != zo).astype(jnp.float32)
                ntw_loc = (ntw_loc.at[w_flat, zo].add(-changed)
                           .at[w_flat, zn].add(changed))
                nt_loc = nt_loc + jnp.sum(ndt - ndt_start, axis=0)
            return (z_t_new, ndt, ntw_loc, nt_loc), None

        carry = (z_b.T, ndt_b, ntw_t, nt)
        if n_sweeps > 1:
            carry, _ = jax.lax.scan(
                one_sweep, carry, jnp.arange(n_sweeps - 1, dtype=jnp.int32))
        (z_t, ndt_b, _, _), _ = one_sweep(
            carry, jnp.int32(n_sweeps - 1), refresh=False)
        return z_t.T, ndt_b

    z_fin, ndt_fin = jax.vmap(block_fn)(
        blk(tokens), blk(mask), blk(seeds), blk(z0), blk(ndt0), blk(y),
        blk(inv_len))
    return (z_fin.reshape(D, N).astype(jnp.int32),
            ndt_fin.reshape(D, T))


def slda_train_stair_jnp(seg_tokens, seg_mask, seg_z0, seg_row_start,
                         seg_tok_start, seeds, ndt0, y, inv_len,
                         ntw_t_stack, nt, eta, chain_of_row, *, alpha,
                         beta, rho, vocab_size, ctr_stride,
                         supervised=True, n_sweeps=1, product_form=False,
                         unroll=8, sampler_mode="dense",
                         sparse_topic_cap=32, topic_index=None):
    """STAIRCASE fused-training twin — the ragged layer's CPU executor
    for multi-sweep launches (DESIGN.md §Ragged-execution).

    Same stair walk as `slda_predict_stair_jnp`: docs sorted ASCENDING
    by length, chains folded DOC-MAJOR (row r = d·M + c) so each token
    segment [w_{k-1}, w_k) runs on the still-alive row SUFFIX — the
    sequential step count per sweep stays N_max while executed slots
    collapse to the staircase.  Chains fold around ONE stacked
    `[M·W, T]` topic-word table (token ids pre-offset by `c·W`) exactly
    like the prediction fold; the per-chain `nt`/η are row-gathered once
    per sweep (both sweep-frozen).

    Between in-launch sweeps the table refreshes from ALL rows' changed
    tokens — the block partition here is the WHOLE corpus, i.e. the
    doc_block→D limit of the §Train-kernel delayed-count family (least
    delayed; the per-sweep refresh is exact globally, like the seed
    path's between-sweep refresh, while the counter-hash PRNG and the
    in-launch frozen η keep it a fused-family member).  As everywhere,
    at n_sweeps=1 no refresh runs and per-document results are
    bit-identical to the padded op under any schedule.

    seg_tokens/seg_mask/seg_z0: per-segment [R_k, L_k] (tokens
    pre-offset into the stacked vocab); seeds/y/inv_len: [R] folded;
    ndt0: [R, T]; ntw_t_stack: [M·W, T]; nt/eta: [M, T];
    chain_of_row: int32 [R].  Returns (z_segs_final, ndt_final [R, T]);
    the caller refreshes the global tables from (z0, z_final).
    """
    R, T = ndt0.shape
    W = vocab_size
    topic_iota = jnp.arange(T, dtype=jnp.int32)[None, :]
    tri_u = upper_tri_ones(T)
    eta_rows = jnp.take(eta, chain_of_row, axis=0)        # [R, T] frozen
    # sparse mode: launch-frozen index over the STACKED [M·W, T] table —
    # row c·W + w matches the per-chain tables bit-for-bit, so the draw
    # agrees with the blocks executor under the same uniforms
    if sampler_mode == "sparse" and topic_index is None:
        topic_index = build_topic_index(ntw_t_stack, sparse_topic_cap)
    s_idx, s_vm, s_om = topic_index if topic_index is not None else (
        None, None, None)
    segs = []
    for tok, mk, r0, n0 in zip(seg_tokens, seg_mask, seg_row_start,
                               seg_tok_start):
        L = tok.shape[-1]
        n_iota = jnp.arange(n0, n0 + L, dtype=jnp.int32)
        segs.append((tok.T, mk.T, int(r0), n_iota))       # token-major
    z_init = tuple(z.T for z in seg_z0)

    def one_sweep(carry, s, refresh=True):
        z_segs, ndt_start, ntw_loc, nt_loc = carry
        nt_rows = jnp.take(nt_loc, chain_of_row, axis=0)  # [R, T] frozen
        st0 = jnp.sum(ndt_start * eta_rows, axis=-1)      # [R]
        if not product_form:
            # sweep-frozen hoisted log tables + own-token scalar fixups
            # (bit-equal to per-token logs — see slda_train_sweeps_jnp)
            log_ntw = jnp.log(ntw_loc + beta)             # [M·W, T]
            log_nt_rows = jnp.log(nt_rows + W * beta)     # [R, T]
        ndt, st = ndt_start, st0
        new_z = []
        for (tok_t, mask_t, r0, n_iota), z_t in zip(segs, z_segs):
            sub = lambda a: a[r0:] if r0 else a
            seeds_s, y_s, il_s = sub(seeds), sub(y), sub(inv_len)
            eta_s, nt_rows_s = sub(eta_rows), sub(nt_rows)
            if not product_form:
                log_nt_s = sub(log_nt_rows)
            take_eta = lambda zz: jnp.take_along_axis(
                eta_s, zz[:, None], axis=1)[:, 0]

            def token_step(carry2, inp):
                nd, stt = carry2
                w, m, z_old, n = inp
                u = counter_uniform(seeds_s, s * ctr_stride + n)
                own = (topic_iota == z_old[:, None]) & (m[:, None] > 0)
                old = own.astype(jnp.float32)
                nd = nd - old
                stt = stt - take_eta(z_old) * m
                if product_form:
                    ntw_w = jnp.take(ntw_loc, w, axis=0) - old
                    p = (nd + alpha) * (ntw_w + beta) \
                        / (nt_rows_s - old + W * beta)
                    if supervised:
                        mu_t = (stt[:, None] + eta_s) * il_s[:, None]
                        g = -0.5 * (y_s[:, None] - mu_t) ** 2 / rho
                        p = p * jnp.exp(g - jnp.max(g, axis=1,
                                                    keepdims=True))
                else:
                    v_own = ntw_loc[w, z_old]             # [Rk]
                    fix_ntw = jnp.log((v_own - 1.0) + beta)
                    nt_own = jnp.take_along_axis(
                        nt_rows_s, z_old[:, None], axis=1)[:, 0]
                    fix_nt = jnp.log((nt_own - 1.0) + W * beta)
                    lw = jnp.where(own, fix_ntw[:, None],
                                   jnp.take(log_ntw, w, axis=0))
                    ln = jnp.where(own, fix_nt[:, None], log_nt_s)
                    logp = jnp.log(nd + alpha) + lw - ln
                    if supervised:
                        mu_t = (stt[:, None] + eta_s) * il_s[:, None]
                        logp = logp - 0.5 * (y_s[:, None] - mu_t) ** 2 \
                            / rho
                    p = jnp.exp(logp - jnp.max(logp, axis=1,
                                               keepdims=True))
                if sampler_mode == "sparse":
                    z_new = sparse_two_stage_draw(
                        p, u, jnp.take(s_idx, w, axis=0),
                        jnp.take(s_vm, w, axis=0),
                        jnp.take(s_om, w, axis=0))
                else:
                    c = jnp.dot(p, tri_u)
                    z_new = jnp.sum(
                        (c < (u * c[:, -1])[:, None]).astype(jnp.int32),
                        axis=1)
                z_new = jnp.where(m > 0, z_new, z_old).astype(jnp.int32)
                nd = nd + (topic_iota == z_new[:, None]) \
                    .astype(jnp.float32) * m[:, None]
                stt = stt + take_eta(z_new) * m
                return (nd, stt), z_new

            (nd, stt), z_t = jax.lax.scan(
                token_step, (sub(ndt), sub(st)),
                (tok_t, mask_t, z_t, n_iota), unroll=unroll)
            ndt = ndt.at[r0:].set(nd) if r0 else nd
            st = st.at[r0:].set(stt) if r0 else stt
            new_z.append(z_t)

        if refresh:  # whole-corpus delayed-count refresh (exact scatter)
            for (tok_t, mask_t, r0, _), zo_t, zn_t in zip(segs, z_segs,
                                                          new_z):
                w_f = tok_t.ravel()
                zo_f, zn_f = zo_t.ravel(), zn_t.ravel()
                changed = mask_t.ravel() * (zn_f != zo_f) \
                    .astype(jnp.float32)
                ntw_loc = (ntw_loc.at[w_f, zo_f].add(-changed)
                           .at[w_f, zn_f].add(changed))
            nt_loc = nt_loc + jnp.zeros_like(nt_loc) \
                .at[chain_of_row].add(ndt - ndt_start)
        return (tuple(new_z), ndt, ntw_loc, nt_loc), None

    carry = (z_init, ndt0, ntw_t_stack, nt)
    if n_sweeps > 1:
        carry, _ = jax.lax.scan(
            one_sweep, carry, jnp.arange(n_sweeps - 1, dtype=jnp.int32))
    (z_segs, ndt, _, _), _ = one_sweep(
        carry, jnp.int32(n_sweeps - 1), refresh=False)
    return tuple(z.T for z in z_segs), ndt


def slda_train_sweeps_chains_jnp(tokens, mask, seeds, z0, ndt0, y, inv_len,
                                 ntw_t, nt, eta, *, alpha, beta, rho,
                                 supervised=True, n_sweeps=1, doc_block=8,
                                 unroll=8, product_form=False,
                                 ctr_stride=None, sampler_mode="dense",
                                 sparse_topic_cap=32):
    """Chain-batched jnp twin: all inputs carry a leading chain dim M
    (tokens [M, D, N], ntw_t [M, W, T], nt/eta [M, T], ...).

    Unlike prediction — where the chains fold into the document-row axis
    around ONE stacked table (slda_predict.slda_predict_sweeps_chains_jnp)
    — each training chain's table EVOLVES separately between sweeps, so
    the chain axis folds into the block-vmap axis instead: the twin maps
    `block_fn` over chains × blocks in one jitted op.  Expressed as the
    vmap of the single-chain twin, which makes bit-identity to the
    vmapped path hold BY CONSTRUCTION (same jaxpr) while XLA still sees
    one fused [M·B]-lane program — the restructuring the chain grid buys
    on TPU comes from `slda_train_sweeps_chains_pallas`.
    """
    fn = functools.partial(
        slda_train_sweeps_jnp, alpha=alpha, beta=beta, rho=rho,
        supervised=supervised, n_sweeps=n_sweeps, doc_block=doc_block,
        unroll=unroll, product_form=product_form, ctr_stride=ctr_stride,
        sampler_mode=sampler_mode, sparse_topic_cap=sparse_topic_cap)
    return jax.vmap(fn)(tokens, mask, seeds, z0, ndt0, y, inv_len,
                        ntw_t, nt, eta)
