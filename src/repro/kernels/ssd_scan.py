"""Mamba-2 SSD (state-space duality) chunked scan as a Pallas TPU kernel.

The SSD insight: a chunk of the linear recurrence

    h_t = exp(A·dt_t)·h_{t-1} + dt_t·x_t ⊗ B_t ,    y_t = C_t·h_t

splits into an intra-chunk quadratic term (an L×L masked-decay matmul —
MXU work) plus an inter-chunk state carry (rank-N).  The chunk dimension is
the minor grid axis, so it runs sequentially per (batch, head) and the
running state [P, N] persists in VMEM scratch across grid steps — the
cross-chunk recurrence costs no HBM traffic at all.

Grid: (B, H, S / CHUNK).  Blocks: x, y [1,1,L,P]; dt [1,1,L];
B, C [1,L,N] (shared across heads, fetched once per head-sweep); A [H] in
SMEM.  All matmuls are [L,N]·[N,L], [L,L]·[L,P], [P,L]·[L,N] — lane/MXU
aligned for L, P, N multiples of 128/ hardware tiling (ops.py pads).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(a_ref, x_ref, dt_ref, b_ref, c_ref, y_ref, state_scr,
                *, chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _reset():
        state_scr[...] = jnp.zeros_like(state_scr)

    A = a_ref[0, 0]                                  # scalar (this head)
    x = x_ref[0, 0].astype(jnp.float32)              # [L, P]
    dt = dt_ref[0, 0].astype(jnp.float32)            # [L]
    Bm = b_ref[0].astype(jnp.float32)                # [L, N]
    Cm = c_ref[0].astype(jnp.float32)                # [L, N]
    L = chunk

    a = A * dt                                       # [L]  (A < 0, dt > 0)
    cum = jnp.cumsum(a)                              # [L]

    # ---- intra-chunk (quadratic, MXU) ----
    G = Cm @ Bm.T                                    # [L, L]
    rows = cum[:, None] - cum[None, :]               # exp(cum_t - cum_s)
    tri = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1) <= \
          jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    M = jnp.where(tri, jnp.exp(rows), 0.0) * dt[None, :]
    y = (G * M) @ x                                  # [L, P]

    # ---- inter-chunk (state carry) ----
    h0 = state_scr[...]                              # [P, N]
    y = y + jnp.exp(cum)[:, None] * (Cm @ h0.T)      # [L,N]·[N,P]

    # ---- state update ----
    w = jnp.exp(cum[-1] - cum) * dt                  # [L]
    state_scr[...] = h0 * jnp.exp(cum[-1]) + (x.T * w[None, :]) @ Bm

    y_ref[0, 0] = y.astype(y_ref.dtype)


def ssd_scan(x, dt, A, B, C, *, chunk=64, interpret=True):
    """x: [b, s, h, p]; dt: [b, s, h]; A: [h]; B, C: [b, s, n] → y like x.

    s % chunk == 0 (ops.py pads).  Matches ref.ref_ssd.
    """
    b, s, h, p = x.shape
    n = B.shape[-1]
    assert s % chunk == 0, (s, chunk)
    grid = (b, h, s // chunk)

    xt = jnp.swapaxes(x, 1, 2)                       # [b, h, s, p]
    dtt = jnp.swapaxes(dt, 1, 2)                     # [b, h, s]

    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    yt = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda bi, hi, ci: (hi, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, chunk, p), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1, chunk), lambda bi, hi, ci: (bi, hi, ci)),
            pl.BlockSpec((1, chunk, n), lambda bi, hi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, chunk, n), lambda bi, hi, ci: (bi, ci, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, chunk, p),
                               lambda bi, hi, ci: (bi, hi, ci, 0)),
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        out_shape=jax.ShapeDtypeStruct((b, h, s, p), x.dtype),
        interpret=interpret,
    )(A[:, None].astype(jnp.float32), xt, dtt, B, C)
    return jnp.swapaxes(yt, 1, 2)


def ssd_decode_step(state, x_t, dt_t, A, B_t, C_t):
    """One-token SSD state update (serving path; pure jnp — bandwidth-bound,
    no kernel warranted).  state: [b, h, p, n]; x_t: [b, h, p];
    dt_t: [b, h]; A: [h]; B_t, C_t: [b, n].  Returns (state', y_t [b,h,p])."""
    decay = jnp.exp(A[None, :] * dt_t)                            # [b, h]
    upd = (dt_t[..., None] * x_t)[..., None] * B_t[:, None, None, :]
    state = state * decay[..., None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", state, C_t)
    return state, y
