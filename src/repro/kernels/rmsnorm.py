"""Fused RMSNorm Pallas kernel (single pass over rows, scale applied in
VMEM — saves one HBM round-trip vs. unfused mean/rsqrt/mul chains)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps) * w_ref[...]).astype(o_ref.dtype)


def rmsnorm(x, w, *, eps=1e-6, block_rows=128, interpret=True):
    """x: [..., D]; w: [D].  Row-blocked fused RMSNorm."""
    orig_shape = x.shape
    d = orig_shape[-1]
    x2 = x.reshape(-1, d)
    rows = x2.shape[0]
    br = min(block_rows, rows)
    pad = (-rows) % br
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=float(eps)),
        grid=(x2.shape[0] // br,),
        in_specs=[pl.BlockSpec((br, d), lambda i: (i, 0)),
                  pl.BlockSpec((1, d), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2.shape, x.dtype),
        interpret=interpret,
    )(x2, w[None, :])
    if pad:
        out = out[:rows]
    return out.reshape(orig_shape)
