"""Sparse two-stage categorical draw — the O(T)-per-token sampler core.

Every dense sLDA sampler in the repo draws `z ~ p` through the matmul
prefix sum `c = p @ triu(T)`, an O(T²)-per-token contraction that
dominates the sweep at large T.  This module replaces ONLY the draw:
the exact dense weights `p` are still produced per token (all O(T)
vector work is unchanged — the supervised Gaussian factor depends on
the document and token, so no per-word precomputation of `p` survives),
then split by the word's occupancy index into

  * a **sparse bucket** `sv = take_along(p, idx) · vmask` over the
    word's top-`cap` occupied topics (the index is built once per
    launch from the sweep-frozen table — `core.types
    .topic_occupancy_index`), drawn through a `cap²` prefix sum, and
  * a **residual bucket** `rv = p · (1 − occm)` holding everything the
    index missed, drawn hierarchically: block totals (`nb = ⌈T/B⌉`
    blocks of `B` topics) pick the block through an `nb²` prefix sum,
    then a `B²` prefix sum picks within the block.

`scatter(sv) + rv == p` holds exactly in float32 for ANY index content
(the argsort index entries are distinct; invalid slots carry
`vmask = 0` and are excluded from `occm`), so a stale index changes
which bucket serves a topic — never the sampled distribution.  Stage 2
(the residual draw) fires only when the target mass lands past the
sparse bucket, which after burn-in on a peaked corpus is rare; it is
predicated (`lax.cond` here, `pl.when` in the kernels) and
bitwise-identical to the branch-free form because the selected value
when every row stays in-bucket is the stage-1 pick verbatim.

Collapse contract (what the ref oracle asserts against the dense
sampler): with the identity index `idx = arange(T)`, `cap = T`,
`vmask = occm = 1`, the residual mass is exactly zero, the sparse
prefix sum is exactly the dense `p @ triu(T)`, and the draw is
**bitwise equal** to the dense draw under the same uniform.  Away from
collapse the draw is distributionally exact at the same float32
rounding granularity as the dense draw (both resolve ties/rounding at
the `u·total` boundary in the same strict-`<` way).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.mathutil import upper_tri_ones


def residual_blocks(n_topics: int) -> tuple[int, int]:
    """(block width B, block count nb) of the hierarchical residual draw."""
    blk = min(16, n_topics)
    return blk, -(-n_topics // blk)


def sparse_two_stage_draw(p, u, idx, vmask, occm):
    """Draw z ~ Categorical(p) through the two-stage sparse decomposition.

    p     [..., T]    exact dense weights (ANY leading dims: doc block,
                      chain, scan row — shared by all callers)
    u     [...]       ONE uniform per row — the same uniform budget as
                      the dense draw, so `ctr_stride` accounting and
                      bucketed/padded PRNG parity carry over unchanged
    idx   [..., cap]  per-word topic index rows (int32, distinct entries)
    vmask [..., cap]  1 for valid index slots, else 0
    occm  [..., T]    0/1 membership mask of the valid indexed topics

    Returns int32 z in [0, T).  Bitwise-identical across the pallas
    kernels, jnp twins, stair twins, and the ref oracle — they all call
    exactly this function.
    """
    t_dim = p.shape[-1]
    cap = idx.shape[-1]
    blk, nb = residual_blocks(t_dim)

    sv = jnp.take_along_axis(p, idx, axis=-1) * vmask
    rv = p * (1.0 - occm)
    cs = jnp.dot(sv, upper_tri_ones(cap))
    q_s = cs[..., -1]

    pad = nb * blk - t_dim
    if pad:
        rv = jnp.concatenate(
            [rv, jnp.zeros(rv.shape[:-1] + (pad,), rv.dtype)], axis=-1)
    rblk = rv.reshape(rv.shape[:-1] + (nb, blk))
    # block totals taken from the SAME triu contraction as the fine
    # prefix, so the coarse pick can never overshoot its fine block
    cfine = jnp.dot(rblk, upper_tri_ones(blk))          # [..., nb, blk]
    rsum = cfine[..., -1]
    cr = jnp.dot(rsum, upper_tri_ones(nb))              # [..., nb]
    q_r = cr[..., -1]

    tgt = u * (q_s + q_r)
    # q_r == 0 covers the collapse/fully-indexed case where rounding of
    # u·q_s up to q_s would otherwise spill into an empty residual
    in_s = (tgt < q_s) | (q_r <= 0.0)
    k_s = jnp.minimum(
        jnp.sum((cs < tgt[..., None]).astype(jnp.int32), axis=-1), cap - 1)
    z_s = jnp.take_along_axis(idx, k_s[..., None], axis=-1)[..., 0]

    def _correct(_):
        tr = tgt - q_s
        jb = jnp.minimum(
            jnp.sum((cr < tr[..., None]).astype(jnp.int32), axis=-1), nb - 1)
        cr0 = jnp.concatenate([jnp.zeros_like(cr[..., :1]), cr], axis=-1)
        rem = tr - jnp.take_along_axis(cr0, jb[..., None], axis=-1)[..., 0]
        cf = jnp.take_along_axis(
            cfine, jb[..., None, None], axis=-2)[..., 0, :]
        k_f = jnp.minimum(
            jnp.sum((cf < rem[..., None]).astype(jnp.int32), axis=-1),
            blk - 1)
        z_r = jnp.minimum(jb * blk + k_f, t_dim - 1)
        return jnp.where(in_s, z_s, z_r)

    z = jax.lax.cond(jnp.all(in_s), lambda _: z_s, _correct, None)
    return z.astype(jnp.int32)


def build_topic_index(table_t, cap: int):
    """Launch-boundary index build from a word-major `[..., W, T]` table.

    Thin lazy-import wrapper over `core.types.topic_occupancy_index`
    (the `ops._interpret` pattern: kernels modules stay importable
    without the core package on the module path)."""
    from repro.core.types import topic_occupancy_index
    return topic_occupancy_index(table_t, cap)


def gather_index_rows(w, idx, vmask, occm):
    """Gather the per-token index rows for a word vector `w` [...]: the
    `[W, ·]` tables become `[..., ·]` rows aligned with `w` — the same
    `jnp.take(axis=0)` the kernels already use for the ntw gather."""
    return (jnp.take(idx, w, axis=0), jnp.take(vmask, w, axis=0),
            jnp.take(occm, w, axis=0))
