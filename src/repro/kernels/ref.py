"""Pure-jnp oracles for every Pallas kernel in this package.

Each `ref_*` is the semantic ground truth the kernels are sweep-tested
against (tests/test_kernels.py).  They are also the CPU fallback path used
by `ops.py` when shapes don't meet the kernels' tiling constraints.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.mathutil import upper_tri_ones
from repro.kernels.sparse import build_topic_index, sparse_two_stage_draw


# ------------------------------------------------------------- slda_gibbs

def ref_slda_gibbs_sweep(tokens, mask, uniforms, z, ndt, y, inv_len,
                         ntw_t, nt, eta, alpha, beta, rho, supervised: bool,
                         *, product_form: bool = False,
                         sampler_mode: str = "dense",
                         sparse_topic_cap: int = 32, topic_index=None):
    """Document-parallel sLDA Gibbs sweep with sweep-frozen ntw (AD-LDA).

    tokens/mask/uniforms/z : [D, N]; ndt [D, T]; y/inv_len [D];
    ntw_t [W, T] (note: transposed — row-gather layout); nt [T]; eta [T].
    Returns (z_new [D, N], ndt_new [D, T]).
    Matches repro.core.gibbs._doc_sweep exactly at product_form=False;
    product_form=True samples the same categorical from the plain product
    of positives times one Gaussian `exp` (the fused multi-sweep form —
    see slda_train.py module docstring).

    sampler_mode="sparse" keeps the per-token weights p bit-identical
    and replaces ONLY the draw with the two-stage sparse draw
    (kernels/sparse.py): the per-word occupancy index is built from the
    sweep-frozen `ntw_t` (or taken from `topic_index=(idx, vmask, occm)`
    when a fused caller pins a launch-frozen index), and the draw is
    distributionally exact for any index content.
    """
    T = ndt.shape[-1]
    W = ntw_t.shape[0]
    topic_iota = jnp.arange(T, dtype=jnp.int32)
    tri_u = upper_tri_ones(T)
    if sampler_mode == "sparse" and topic_index is None:
        topic_index = build_topic_index(ntw_t, sparse_topic_cap)
    s_idx, s_vm, s_om = topic_index if topic_index is not None else (
        None, None, None)

    def doc(tokens_d, mask_d, us_d, z_d, ndt_d, y_d, il_d):
        s0 = jnp.dot(ndt_d, eta)

        def step(carry, inp):
            ndt_d, s = carry
            w, m, z_old, u = inp
            old = (topic_iota == z_old).astype(jnp.float32) * m
            ndt_d = ndt_d - old
            s = s - eta[z_old] * m
            if product_form:
                p = (ndt_d + alpha) * (ntw_t[w] - old + beta) \
                    / (nt - old + W * beta)
                if supervised:
                    mu_t = (s + eta) * il_d
                    g = -0.5 * (y_d - mu_t) ** 2 / rho
                    p = p * jnp.exp(g - jnp.max(g))
            else:
                logp = (jnp.log(ndt_d + alpha)
                        + jnp.log(ntw_t[w] - old + beta)
                        - jnp.log(nt - old + W * beta))
                if supervised:
                    mu_t = (s + eta) * il_d
                    logp = logp - 0.5 * (y_d - mu_t) ** 2 / rho
                p = jnp.exp(logp - jnp.max(logp))
            if sampler_mode == "sparse":
                z_new = sparse_two_stage_draw(p, u, s_idx[w], s_vm[w],
                                              s_om[w])
            else:
                c = jnp.dot(p, tri_u)  # prefix sums, rounding-matched
                z_new = jnp.sum((c < u * c[-1]).astype(jnp.int32))
            z_new = jnp.where(m > 0, z_new, z_old).astype(jnp.int32)
            new = (topic_iota == z_new).astype(jnp.float32) * m
            return (ndt_d + new, s + eta[z_new] * m), z_new

        (ndt_d, _), z_new = jax.lax.scan(step, (ndt_d, s0),
                                         (tokens_d, mask_d, z_d, us_d))
        return z_new, ndt_d

    return jax.vmap(doc)(tokens, mask, uniforms, z, ndt, y, inv_len)


# ------------------------------------------------------------- slda_train

def ref_slda_train_sweeps(tokens, mask, uniforms, z0, ndt0, y, inv_len,
                          ntw_t, nt, eta, alpha, beta, rho,
                          supervised: bool, doc_block: int,
                          *, product_form: bool = False,
                          sampler_mode: str = "dense",
                          sparse_topic_cap: int = 32):
    """Fused multi-sweep TRAINING oracle with EXPLICIT uniforms and the
    per-block delayed-count refresh semantics (DESIGN.md §Train-kernel).

    tokens/mask/z0 : [D, N]; uniforms [D, S, N]; ndt0 [D, T]; y/inv_len
    [D]; ntw_t [W, T] (row-gather layout); nt/eta [T].  Each `doc_block`
    of documents carries its own copy of the topic-word table: every sweep
    is one `ref_slda_gibbs_sweep` against the block-local sweep-frozen
    tables, followed by a ±1 scatter of the block's own reassignments
    (exact per block, delayed across blocks — the AD-LDA argument of
    DESIGN.md §3 applied inside the launch).  The block partition pads D
    up to a doc_block multiple exactly like `ops.slda_train_sweeps`, so
    the padded-block structure — which is part of the semantics here,
    unlike prediction — matches the kernel's.

    Returns (z_final [D, N], ndt_final [D, T]); global `ntw`/`nt` are the
    caller's to refresh from (z0, z_final).
    """
    D, N = tokens.shape
    T = ndt0.shape[-1]
    S = uniforms.shape[1]
    pad = (-D) % doc_block
    if pad:
        pad2 = lambda a: jnp.pad(a, ((0, pad),) + ((0, 0),) * (a.ndim - 1))
        tokens, mask, uniforms, z0, ndt0, y, inv_len = map(
            pad2, (tokens, mask, uniforms, z0, ndt0, y, inv_len))
    B = (D + pad) // doc_block
    blk = lambda a: a.reshape((B, doc_block) + a.shape[1:])
    # sparse mode: index LAUNCH-frozen, built once from the entry table —
    # exactly the kernels' contract (in-launch count evolution never
    # rebuilds it; exactness does not depend on index freshness)
    topic_index = (build_topic_index(ntw_t, sparse_topic_cap)
                   if sampler_mode == "sparse" else None)

    def block_fn(tok_b, mask_b, us_b, z_b, ndt_b, y_b, il_b):
        w_flat = tok_b.ravel()

        def sweep_step(carry, us_s):
            z_b, ndt_b, ntw_loc, nt_loc = carry
            z_new, ndt_new = ref_slda_gibbs_sweep(
                tok_b, mask_b, us_s, z_b, ndt_b, y_b, il_b,
                ntw_loc, nt_loc, eta, alpha, beta, rho, supervised,
                product_form=product_form, sampler_mode=sampler_mode,
                topic_index=topic_index)
            zo, zn = z_b.ravel(), z_new.ravel()
            changed = mask_b.ravel() * (zn != zo).astype(jnp.float32)
            ntw_loc = (ntw_loc.at[w_flat, zo].add(-changed)
                       .at[w_flat, zn].add(changed))
            nt_loc = nt_loc + jnp.sum(ndt_new - ndt_b, axis=0)
            return (z_new, ndt_new, ntw_loc, nt_loc), None

        (z_b, ndt_b, _, _), _ = jax.lax.scan(
            sweep_step, (z_b, ndt_b, ntw_t, nt),
            jnp.moveaxis(us_b, 1, 0))          # [DB, S, N] → [S, DB, N]
        return z_b, ndt_b

    z_fin, ndt_fin = jax.vmap(block_fn)(
        blk(tokens), blk(mask), blk(uniforms), blk(z0), blk(ndt0), blk(y),
        blk(inv_len))
    z_fin = z_fin.reshape(D + pad, N)[:D]
    return z_fin.astype(jnp.int32), ndt_fin.reshape(D + pad, T)[:D]


def ref_slda_train_sweeps_chains(tokens, mask, uniforms, z0, ndt0, y,
                                 inv_len, ntw_t, nt, eta, alpha, beta, rho,
                                 supervised: bool, doc_block: int,
                                 *, product_form: bool = False,
                                 sampler_mode: str = "dense",
                                 sparse_topic_cap: int = 32):
    """Chain-batched training oracle: a plain vmap of the single-chain
    oracle over the leading chain dim — the clearest statement of the
    semantics the chain-gridded kernel and twin must reproduce (each
    chain evolves exactly as if launched alone).  All inputs carry a
    leading M: tokens [M, D, N], uniforms [M, D, S, N], ntw_t [M, W, T],
    nt/eta [M, T], ..."""
    fn = lambda *a: ref_slda_train_sweeps(
        *a, alpha, beta, rho, supervised, doc_block,
        product_form=product_form, sampler_mode=sampler_mode,
        sparse_topic_cap=sparse_topic_cap)
    return jax.vmap(fn)(tokens, mask, uniforms, z0, ndt0, y, inv_len,
                        ntw_t, nt, eta)


# ----------------------------------------------------------- slda_predict

def ref_slda_predict_sweeps(tokens, mask, uniforms, z0, ndt0, phi_t,
                            alpha, n_burnin: int, *,
                            sampler_mode: str = "dense",
                            sparse_topic_cap: int = 32):
    """Fused prediction-sweep oracle with EXPLICIT uniforms.

    tokens/mask/z0 : [D, N]; uniforms [D, S, N] (S = burnin + samples);
    ndt0 [D, T]; phi_t [W, T] (row-gather layout).
    Runs all S unsupervised test-time sweeps per document under frozen φ̂,
        p(z=t | ·) ∝ (N_dt^{-dn} + α) · φ̂_{t,w}
    and returns (ndt_avg [D, T], z_final [D, N]) where ndt_avg is the mean
    doc-topic count over the post-burn-in sweeps.  The kernel and the
    batched-jnp fast path derive the uniforms from a counter hash
    (slda_predict.predict_uniforms materializes the same tensor for tests).
    """
    T = ndt0.shape[-1]
    S = uniforms.shape[1]
    n_samples = S - n_burnin
    topic_iota = jnp.arange(T, dtype=jnp.int32)
    tri_u = upper_tri_ones(T)
    # φ̂ is frozen for the whole prediction, so the index is too
    topic_index = (build_topic_index(phi_t, sparse_topic_cap)
                   if sampler_mode == "sparse" else None)
    s_idx, s_vm, s_om = topic_index if topic_index is not None else (
        None, None, None)

    def doc(tokens_d, mask_d, us_d, z_d, ndt_d):
        def token_step(ndt_d, inp):
            w, m, z_old, u = inp
            old = (topic_iota == z_old).astype(jnp.float32) * m
            ndt_d = ndt_d - old
            p = (ndt_d + alpha) * phi_t[w]
            if sampler_mode == "sparse":
                z_new = sparse_two_stage_draw(p, u, s_idx[w], s_vm[w],
                                              s_om[w])
            else:
                # prefix sums as the same upper-triangular contraction
                # the kernel uses, so the comparison rounds identically
                c = jnp.dot(p, tri_u)
                z_new = jnp.sum((c < u * c[-1]).astype(jnp.int32))
            z_new = jnp.where(m > 0, z_new, z_old).astype(jnp.int32)
            ndt_d = ndt_d + (topic_iota == z_new).astype(jnp.float32) * m
            return ndt_d, z_new

        def sweep_step(carry, inp):
            z_d, ndt_d, acc = carry
            s, us_s = inp
            ndt_d, z_d = jax.lax.scan(token_step, ndt_d,
                                      (tokens_d, mask_d, z_d, us_s))
            keep = (s >= n_burnin).astype(jnp.float32)
            return (z_d, ndt_d, acc + keep * ndt_d), None

        (z_d, _, acc), _ = jax.lax.scan(
            sweep_step, (z_d, ndt_d, jnp.zeros_like(ndt_d)),
            (jnp.arange(S, dtype=jnp.int32), us_d))
        # f32 reciprocal multiply, matching the fused kernel bit-for-bit
        return acc * np.float32(1.0 / n_samples), z_d

    return jax.vmap(doc)(tokens, mask, uniforms, z0, ndt0)


def ref_slda_predict_sweeps_chains(tokens, mask, uniforms, z0, ndt0, phi_t,
                                   alpha, n_burnin: int, *,
                                   sampler_mode: str = "dense",
                                   sparse_topic_cap: int = 32):
    """Chain-batched prediction oracle: vmap of the single-chain oracle
    over the leading chain dim.  tokens/mask [D, N] are SHARED across
    chains (the corpus every chain predicts); uniforms [M, D, S, N];
    z0 [M, D, N]; ndt0 [M, D, T]; phi_t [M, W, T]."""
    fn = lambda us, z, nd, ph: ref_slda_predict_sweeps(
        tokens, mask, us, z, nd, ph, alpha, n_burnin,
        sampler_mode=sampler_mode, sparse_topic_cap=sparse_topic_cap)
    return jax.vmap(fn)(uniforms, z0, ndt0, phi_t)


# -------------------------------------------------------- flash_attention

def ref_attention(q, k, v, *, causal: bool = True, scale: float | None = None,
                  kv_len: jnp.ndarray | None = None):
    """Plain softmax attention oracle.

    q: [B, Hq, Sq, Dh]; k, v: [B, Hkv, Sk, Dh] with Hq % Hkv == 0 (GQA).
    kv_len: optional [B] valid KV prefix lengths (decode against a cache).
    """
    B, Hq, Sq, Dh = q.shape
    Hkv = k.shape[1]
    rep = Hq // Hkv
    if scale is None:
        scale = Dh ** -0.5
    k = jnp.repeat(k, rep, axis=1)
    v = jnp.repeat(v, rep, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    Sk = k.shape[2]
    if causal and Sq > 1:
        qi = jnp.arange(Sq)[:, None] + (Sk - Sq)
        ki = jnp.arange(Sk)[None, :]
        logits = jnp.where(ki <= qi, logits, -jnp.inf)
    if kv_len is not None:
        valid = jnp.arange(Sk)[None, None, None, :] < kv_len[:, None, None, None]
        logits = jnp.where(valid, logits, -jnp.inf)
    out = jnp.einsum("bhqk,bhkd->bhqd", jax.nn.softmax(logits, axis=-1),
                     v.astype(jnp.float32))
    return out.astype(q.dtype)


# -------------------------------------------------------------- ssd_scan

def ref_ssd(x, dt, A, B, C, *, chunk: int = 64):
    """Mamba-2 SSD (state-space duality) oracle — naive sequential scan.

    x : [b, s, h, p]   inputs (already gated/projected)
    dt: [b, s, h]      softplus'd step sizes (>0)
    A : [h]            negative decay rates (A < 0)
    B : [b, s, n]      input projection (shared across heads, mamba2 style)
    C : [b, s, n]      output projection
    Returns y: [b, s, h, p].
    State h_t = exp(A·dt_t)·h_{t-1} + dt_t·B_t xᵀ_t ;  y_t = C_t·h_t.
    """
    b, s, h, p = x.shape
    n = B.shape[-1]

    def scan_one(x_b, dt_b, B_b, C_b):
        def step(state, inp):
            x_t, dt_t, B_t, C_t = inp          # [h,p], [h], [n], [n]
            decay = jnp.exp(A * dt_t)          # [h]
            upd = (dt_t[:, None] * x_t)[:, :, None] * B_t[None, None, :]  # [h,p,n]
            state = state * decay[:, None, None] + upd
            y_t = jnp.einsum("hpn,n->hp", state, C_t)
            return state, y_t
        init = jnp.zeros((h, p, n), jnp.float32)
        _, y = jax.lax.scan(step, init, (x_b.astype(jnp.float32),
                                         dt_b.astype(jnp.float32),
                                         B_b.astype(jnp.float32),
                                         C_b.astype(jnp.float32)))
        return y

    return jax.vmap(scan_one)(x, dt, B, C).astype(x.dtype)


# -------------------------------------------------------------- rmsnorm

def ref_rmsnorm(x, w, eps: float = 1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x.astype(jnp.float32) * jax.lax.rsqrt(var + eps) * w).astype(x.dtype)
