"""Pallas TPU kernel for the sLDA collapsed-Gibbs sweep (the paper's hot loop).

TPU adaptation (DESIGN.md §3): the token loop is inherently sequential, but
  * the per-token categorical over T topics vectorizes onto the lane
    dimension (T = 128 fills a VREG lane exactly), and
  * a block of DOC_BLOCK documents is swept in lockstep on the sublane
    dimension — documents are independent within a sweep because the
    topic-word table is sweep-frozen (AD-LDA delayed counts).

Layout: the topic-word table is stored transposed, ``ntw_t [W, T]``, so the
per-token access is a *row* gather (sublane-dim dynamic index), which the
TPU supports natively; a column gather on the lane dim would not map.  The
whole table lives in VMEM (sLDA vocabularies are small — the paper's is
4238 phrases; W·T·4B ≈ 2 MB at T=128).

Grid: (D / DOC_BLOCK,).  One grid cell sweeps DOC_BLOCK documents
end-to-end and writes back their new assignments and doc-topic counts.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.mathutil import upper_tri_ones
from .sparse import build_topic_index, sparse_two_stage_draw


def _gibbs_kernel(tokens_ref, mask_ref, unif_ref, z_ref, ndt_ref,
                  y_ref, invlen_ref, ntw_t_ref, nt_ref, eta_ref, *refs,
                  alpha: float, beta: float, rho: float,
                  supervised: bool, n_tokens: int, vocab_size: int,
                  sampler_mode: str = "dense"):
    # sparse mode appends the three sweep-frozen topic-index inputs;
    # unpacking on the static mode keeps the dense trace byte-identical
    if sampler_mode == "sparse":
        idx_ref, vmask_ref, occm_ref, z_out_ref, ndt_out_ref = refs
    else:
        z_out_ref, ndt_out_ref = refs
    eta = eta_ref[0, :]                       # [T]
    nt = nt_ref[0, :]                         # [T]
    ntw_t = ntw_t_ref[...]                    # [W, T] resident in VMEM
    y = y_ref[:, 0]                           # [DB]
    inv_len = invlen_ref[:, 0]                # [DB]
    T = eta.shape[0]
    topic_iota = jax.lax.broadcasted_iota(jnp.int32, (1, T), 1)
    tri_u = upper_tri_ones(T)   # prefix-sum-as-matmul (see slda_predict.py)

    ndt0 = ndt_ref[...]                       # [DB, T]
    s0 = ndt0 @ eta                           # [DB]  running Σ_t η_t N_dt

    def token_step(n, carry):
        ndt, s = carry
        w = tokens_ref[:, n]                  # [DB] int32 word ids
        m = mask_ref[:, n]                    # [DB]
        u = unif_ref[:, n]                    # [DB]
        z_old = z_ref[:, n]                   # [DB]

        old = (topic_iota == z_old[:, None]).astype(jnp.float32) * m[:, None]
        ndt = ndt - old
        s = s - jnp.take(eta, z_old) * m

        ntw_w = jnp.take(ntw_t, w, axis=0) - old        # [DB, T], -dn exact
        logp = (jnp.log(ndt + alpha)
                + jnp.log(ntw_w + beta)
                - jnp.log(nt[None, :] - old + vocab_size * beta))
        if supervised:
            mu_t = (s[:, None] + eta[None, :]) * inv_len[:, None]
            logp = logp - 0.5 * (y[:, None] - mu_t) ** 2 / rho

        p = jnp.exp(logp - jnp.max(logp, axis=1, keepdims=True))
        if sampler_mode == "sparse":
            z_new = sparse_two_stage_draw(
                p, u, jnp.take(idx_ref[...], w, axis=0),
                jnp.take(vmask_ref[...], w, axis=0),
                jnp.take(occm_ref[...], w, axis=0))
        else:
            c = jnp.dot(p, tri_u)
            z_new = jnp.sum(
                (c < (u * c[:, -1])[:, None]).astype(jnp.int32), axis=1)
        z_new = jnp.where(m > 0, z_new, z_old).astype(jnp.int32)

        new = (topic_iota == z_new[:, None]).astype(jnp.float32) * m[:, None]
        ndt = ndt + new
        s = s + jnp.take(eta, z_new) * m
        z_out_ref[:, n] = z_new
        return ndt, s

    ndt, _ = jax.lax.fori_loop(0, n_tokens, token_step, (ndt0, s0))
    ndt_out_ref[...] = ndt


def slda_gibbs_sweep_pallas(tokens, mask, uniforms, z, ndt, y, inv_len,
                            ntw_t, nt, eta, *, alpha, beta, rho,
                            supervised=True, doc_block=8, interpret=True,
                            sampler_mode="dense", sparse_topic_cap=32,
                            topic_index=None):
    """Blocked document-parallel Gibbs sweep.  Shapes as in ref.py.

    D must be a multiple of doc_block (ops.py pads).  Returns (z_new, ndt_new).
    sampler_mode="sparse" routes the draw through the two-stage sparse
    draw against the per-word topic index of the sweep-frozen `ntw_t`
    (built here unless passed pre-built as `topic_index`).
    """
    D, N = tokens.shape
    T = ndt.shape[-1]
    W = ntw_t.shape[0]
    assert D % doc_block == 0, (D, doc_block)
    grid = (D // doc_block,)

    doc_spec = lambda cols: pl.BlockSpec((doc_block, cols), lambda i: (i, 0))
    full = lambda shape: pl.BlockSpec(shape, lambda i: tuple(0 for _ in shape))

    kernel = functools.partial(
        _gibbs_kernel, alpha=float(alpha), beta=float(beta), rho=float(rho),
        supervised=supervised, n_tokens=N, vocab_size=W,
        sampler_mode=sampler_mode)

    in_specs = [doc_spec(N), doc_spec(N), doc_spec(N), doc_spec(N),
                doc_spec(T), doc_spec(1), doc_spec(1),
                full((W, T)), full((1, T)), full((1, T))]
    operands = [tokens, mask, uniforms, z, ndt, y[:, None],
                inv_len[:, None], ntw_t, nt[None, :], eta[None, :]]
    if sampler_mode == "sparse":
        if topic_index is None:
            topic_index = build_topic_index(ntw_t, sparse_topic_cap)
        cap = topic_index[0].shape[-1]
        in_specs += [full((W, cap)), full((W, cap)), full((W, T))]
        operands += list(topic_index)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=[doc_spec(N), doc_spec(T)],
        out_shape=[jax.ShapeDtypeStruct((D, N), jnp.int32),
                   jax.ShapeDtypeStruct((D, T), jnp.float32)],
        interpret=interpret,
    )(*operands)
