"""Public jit'd wrappers around the Pallas kernels.

Each op pads its inputs to the kernels' tiling constraints, dispatches to
the kernel (interpret-mode on CPU, compiled on TPU), and exposes a
`use_pallas=False` escape hatch to the pure-jnp oracle in ref.py.  The
model zoo and the sLDA core call ONLY these entry points.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .flash_attention import flash_attention
from .rmsnorm import rmsnorm as _rmsnorm_kernel
from .slda_gibbs import slda_gibbs_sweep_pallas
from .slda_predict import (slda_predict_sweeps_chains_jnp,
                           slda_predict_sweeps_chains_pallas,
                           slda_predict_sweeps_jnp,
                           slda_predict_sweeps_pallas)
from .slda_train import (slda_train_sweeps_chains_jnp,
                         slda_train_sweeps_chains_pallas,
                         slda_train_sweeps_jnp,
                         slda_train_sweeps_pallas)
from .sparse import (build_topic_index,  # noqa: F401 (re-export)
                     sparse_two_stage_draw)
from .ssd_scan import ssd_scan, ssd_decode_step  # noqa: F401 (re-export)


def _interpret() -> bool:
    # the ONE platform predicate (shared with SLDAConfig.resolve_backend
    # and the launch runner's auto_pallas flip)
    from repro.core.types import devices_support_pallas
    return not devices_support_pallas()


# §Perf trace-time switches (set by the launcher before lowering; the
# baseline lowering keeps all of them off — see EXPERIMENTS.md §Perf)
OPT = {
    "causal_skip": False,     # triangular-scan causal attention (~2× flops)
    "block_q": 0,             # 0 = default (512); S = no scan → attention
                              # backward psums dK/dV once per layer instead
                              # of once per q block
    "head_shard_axes": None,  # (chain_spec, dp_spec): constrain q/k/v to
                              # HEAD-aligned model sharding — prevents
                              # GSPMD from sharding head_dim (which turns
                              # every attention einsum into a partial-sum
                              # all-reduce of logits-sized tensors)
    "probs_bf16": False,      # store attention probabilities in bf16
                              # (softmax stats stay f32) — halves the
                              # dominant [bq, S] intermediate traffic
    "moe_ep_axes": None,      # chain_spec: constrain the MoE dispatch
                              # buffers to P(chain, 'model', ...) — forces
                              # true expert parallelism instead of letting
                              # GSPMD replicate the buffers (cross-pod!)
}


# ------------------------------------------------------------- slda gibbs

def slda_gibbs_sweep(tokens, mask, uniforms, z, ndt, y, inv_len, ntw, nt,
                     eta, *, alpha, beta, rho, supervised=True,
                     doc_block=8, use_pallas=True, chain_axis=False,
                     sampler_mode="dense", sparse_topic_cap=32):
    """Document-parallel sLDA Gibbs sweep. ntw: [T, W] (un-transposed —
    the row-gather [W, T] layout is an internal kernel detail).

    chain_axis=True runs M independent chains in one call: every array
    gains a leading chain dim (tokens [M, D, N], ntw [M, T, W], nt/eta
    [M, T], ...).  Per-chain results are bit-identical to the unbatched
    call — the jnp route vmaps the per-document oracle over chains and
    the pallas route batches the kernel's grid (tests assert both
    against the nested-vmap core sweep exactly)."""
    if chain_axis:
        fn = functools.partial(
            slda_gibbs_sweep, alpha=alpha, beta=beta, rho=rho,
            supervised=supervised, doc_block=doc_block,
            use_pallas=use_pallas, sampler_mode=sampler_mode,
            sparse_topic_cap=sparse_topic_cap)
        return jax.vmap(fn)(tokens, mask, uniforms, z, ndt, y, inv_len,
                            ntw, nt, eta)
    ntw_t = ntw.T
    if not use_pallas:
        z2, ndt2 = ref.ref_slda_gibbs_sweep(
            tokens, mask, uniforms, z, ndt, y, inv_len, ntw_t, nt, eta,
            alpha, beta, rho, supervised, sampler_mode=sampler_mode,
            sparse_topic_cap=sparse_topic_cap)
        return z2, ndt2
    D = tokens.shape[0]
    pad = (-D) % doc_block
    if pad:
        pad2 = lambda a: jnp.pad(a, ((0, pad),) + ((0, 0),) * (a.ndim - 1))
        tokens, mask, uniforms, z, ndt, y, inv_len = map(
            pad2, (tokens, mask, uniforms, z, ndt, y, inv_len))
    z2, ndt2 = slda_gibbs_sweep_pallas(
        tokens, mask, uniforms, z, ndt, y, inv_len, ntw_t, nt, eta,
        alpha=alpha, beta=beta, rho=rho, supervised=supervised,
        doc_block=doc_block, interpret=_interpret(),
        sampler_mode=sampler_mode, sparse_topic_cap=sparse_topic_cap)
    if pad:
        z2, ndt2 = z2[:D], ndt2[:D]
    return z2, ndt2


# ------------------------------------------------------------- slda train

def slda_train_sweeps(tokens, mask, z0, ndt0, y, inv_len, ntw, nt, eta,
                      seeds, *, alpha, beta, rho, n_sweeps, supervised=True,
                      doc_block=8, use_pallas=True, tpu_prng=False,
                      unroll=8, product_form=False, chain_axis=False,
                      ctr_stride=None, sampler_mode="dense",
                      sparse_topic_cap=32):
    """`n_sweeps` training Gibbs sweeps in one fused launch per doc block.

    ntw: [T, W] (un-transposed — the row-gather [W, T] layout is an
    internal kernel detail); seeds: int32 [D] per-document PRNG seeds.
    Returns (z_final [D, N], ndt_final [D, T]).  The topic-word table
    refreshes *block-locally* between the launch's sweeps (delayed counts
    across blocks, DESIGN.md §Train-kernel) — the caller applies the
    exact global refresh from (z0, z_final) afterwards, e.g. via
    `core.types.apply_count_deltas`.  At n_sweeps=1 the launch is exactly
    one seed-semantics sweep (keep product_form=False there to preserve
    the seed sampling bits).

    product_form=True samples the categorical from the plain product of
    positives times one Gaussian `exp` instead of three `log`s — same
    distribution, cheaper transcendentals; the multi-sweep fused chain
    path enables it via `SLDAConfig.product_form_sweeps` (see
    slda_train.py).  Kernel, twin and oracle share either form
    bit-for-bit.

    chain_axis=True runs M independent chains in ONE launch — the
    chain-batched form (DESIGN.md §Chain-batched): every array gains a
    leading chain dim (tokens [M, D, N], ntw [M, T, W], nt/eta [M, T],
    seeds [M, D], ...), the pallas route becomes one grid-(M, B) kernel
    launch, and each chain's result is bit-identical to its unbatched
    call.

    use_pallas=False routes to the blocked-jnp fast path, bit-identical
    to the interpret-mode kernel (shared counter-hash PRNG + op order).
    The doc_block is part of the *semantics* here (it sets the delayed-
    count granularity), so both routes pad D to a doc_block multiple and
    share the same block partition.

    ctr_stride pins the per-sweep PRNG counter stride (default: the
    padded token width N).  The length-bucketed execution layer
    (DESIGN.md §Ragged-execution) passes the SOURCE corpus max_len here
    while looping only each bucket's smaller width, so every (doc,
    sweep, token) triple draws the same uniform as the unbucketed launch.
    """
    d_axis = 1 if chain_axis else 0
    ntw_t = jnp.swapaxes(ntw, -1, -2)
    D = tokens.shape[d_axis]
    pad = (-D) % doc_block
    if pad:
        pad2 = lambda a: jnp.pad(
            a, ((0, 0),) * d_axis + ((0, pad),)
            + ((0, 0),) * (a.ndim - 1 - d_axis))
        tokens, mask, z0, ndt0, y, inv_len, seeds = map(
            pad2, (tokens, mask, z0, ndt0, y, inv_len, seeds))
    kw = dict(alpha=alpha, beta=beta, rho=rho, supervised=supervised,
              n_sweeps=n_sweeps, doc_block=doc_block,
              product_form=product_form, ctr_stride=ctr_stride,
              sampler_mode=sampler_mode, sparse_topic_cap=sparse_topic_cap)
    if use_pallas:
        fn = (slda_train_sweeps_chains_pallas if chain_axis
              else slda_train_sweeps_pallas)
        z2, ndt2 = fn(tokens, mask, seeds, z0, ndt0, y, inv_len, ntw_t,
                      nt, eta, interpret=_interpret(), tpu_prng=tpu_prng,
                      **kw)
    else:
        fn = (slda_train_sweeps_chains_jnp if chain_axis
              else slda_train_sweeps_jnp)
        z2, ndt2 = fn(tokens, mask, seeds, z0, ndt0, y, inv_len, ntw_t,
                      nt, eta, unroll=unroll, **kw)
    if pad:
        sl = (slice(None),) * d_axis + (slice(None, D),)
        z2, ndt2 = z2[sl], ndt2[sl]
    return z2, ndt2


# ----------------------------------------------------------- slda predict

def slda_predict_sweeps(tokens, mask, z0, ndt0, phi, seeds, *, alpha,
                        n_burnin, n_samples, doc_block=8, use_pallas=True,
                        tpu_prng=False, chain_axis=False, ctr_stride=None,
                        sampler_mode="dense", sparse_topic_cap=32):
    """All `n_burnin + n_samples` test-time Gibbs sweeps in one fused pass.

    phi: [T, W] (un-transposed — the row-gather [W, T] layout is an
    internal kernel detail); seeds: int32 [D] per-document PRNG seeds.
    Returns (ndt_avg [D, T], z_final [D, N]).

    chain_axis=True is the chain-batched form (DESIGN.md §Chain-batched):
    phi [M, T, W], seeds [M, D], z0 [M, D, N], ndt0 [M, D, T], while
    tokens/mask may stay [D, N] — the corpus every chain predicts is
    SHARED, so the pallas route reads one token tile per doc block for
    all M chains (grid (M, B)) and the jnp route folds the chains into
    the document-row axis around one stacked [M·W, T] table.  Per-chain
    results are bit-identical to the unbatched call; returns
    (ndt_avg [M, D, T], z_final [M, D, N]).

    use_pallas=False routes to the batched-jnp fast path, which is
    bit-identical to the interpret-mode kernel (shared counter-hash PRNG
    and op order).  tpu_prng=True uses the native TPU PRNG inside the
    compiled kernel (faster on hardware; one stream per doc block, so the
    per-document seeds are honored only by the hash path, and results are
    not reproducible against it).

    ctr_stride pins the per-sweep PRNG counter stride (default: the
    padded token width N); the length-bucketed execution layer passes
    the source corpus max_len (DESIGN.md §Ragged-execution).
    """
    phi_t = jnp.swapaxes(phi, -1, -2)
    kw = dict(alpha=alpha, n_burnin=n_burnin, n_samples=n_samples,
              ctr_stride=ctr_stride, sampler_mode=sampler_mode,
              sparse_topic_cap=sparse_topic_cap)
    if not use_pallas:
        fn = (slda_predict_sweeps_chains_jnp if chain_axis
              else slda_predict_sweeps_jnp)
        return fn(tokens, mask, seeds, z0, ndt0, phi_t, **kw)
    d_axis = 1 if chain_axis else 0
    D = z0.shape[d_axis]
    pad = (-D) % doc_block
    if pad:
        padk = lambda k: lambda a: jnp.pad(
            a, ((0, 0),) * k + ((0, pad),) + ((0, 0),) * (a.ndim - 1 - k))
        tokens, mask = map(padk(tokens.ndim - 2), (tokens, mask))
        z0, ndt0, seeds = map(padk(d_axis), (z0, ndt0, seeds))
    if chain_axis:
        if tokens.ndim == 3:   # per-chain corpora: fall back to batching
            fn = functools.partial(
                slda_predict_sweeps_pallas, doc_block=doc_block,
                interpret=_interpret(), tpu_prng=tpu_prng, **kw)
            ndt_avg, z_final = jax.vmap(fn)(tokens, mask, seeds, z0, ndt0,
                                            phi_t)
        else:
            ndt_avg, z_final = slda_predict_sweeps_chains_pallas(
                tokens, mask, seeds, z0, ndt0, phi_t, doc_block=doc_block,
                interpret=_interpret(), tpu_prng=tpu_prng, **kw)
    else:
        ndt_avg, z_final = slda_predict_sweeps_pallas(
            tokens, mask, seeds, z0, ndt0, phi_t, doc_block=doc_block,
            interpret=_interpret(), tpu_prng=tpu_prng, **kw)
    if pad:
        sl = (slice(None),) * d_axis + (slice(None, D),)
        ndt_avg, z_final = ndt_avg[sl], z_final[sl]
    return ndt_avg, z_final


# -------------------------------------------------------------- attention

def attention_blocked_jnp(q, k, v, *, causal=True, scale=None, kv_len=None,
                          block_q=512):
    """Memory-bounded pure-jnp attention: lax.scan over q blocks, full-S
    logits per block.  Same math as the flash kernel but expressed as plain
    einsums, so XLA's SPMD partitioner can shard it (batch / heads) — this
    is the distributed lowering path; the Pallas kernel is the on-chip TPU
    hot path (see DESIGN.md §6)."""
    B, Hq, Sq, Dh = q.shape
    _, Hkv, Sk, _ = k.shape
    g = Hq // Hkv
    if scale is None:
        scale = Dh ** -0.5
    bq = min(block_q, Sq)
    pad = (-Sq) % bq
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0))) if pad else q
    nb = qp.shape[2] // bq
    qb = jnp.moveaxis(qp.reshape(B, Hkv, g, nb, bq, Dh), 3, 0)  # [nb,B,Hkv,g,bq,Dh]
    kg = k.reshape(B, Hkv, Sk, Dh)
    vg = v.reshape(B, Hkv, Sk, Dh)
    ks_idx = jnp.arange(Sk)
    valid = (ks_idx[None, :] < kv_len[:, None]) if kv_len is not None else None

    def blk(carry, inp):
        qi, qblk = inp
        s = jnp.einsum("bhgqd,bhkd->bhgqk", qblk.astype(jnp.float32),
                       kg.astype(jnp.float32)) * scale
        rows = qi * bq + jnp.arange(bq) + (Sk - Sq)
        mask = jnp.ones((bq, Sk), bool)
        if causal:
            mask &= ks_idx[None, :] <= rows[:, None]
        if valid is not None:
            mask = mask[None] & valid[:, None, :]
            mask = mask[:, None, None]
        else:
            mask = mask[None, None, None]
        s = jnp.where(mask, s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        if OPT["probs_bf16"]:
            o = jnp.einsum("bhgqk,bhkd->bhgqd", p.astype(jnp.bfloat16),
                           vg.astype(jnp.bfloat16),
                           preferred_element_type=jnp.float32)
        else:
            o = jnp.einsum("bhgqk,bhkd->bhgqd", p, vg.astype(jnp.float32))
        return carry, o.astype(q.dtype)

    _, ob = jax.lax.scan(blk, 0, (jnp.arange(nb), qb))
    out = jnp.moveaxis(ob, 0, 3).reshape(B, Hq, Sq + pad, Dh)
    return out[:, :, :Sq] if pad else out


def attention_triangular_jnp(q, k, v, *, scale=None, block=512,
                             probs_dtype=jnp.bfloat16):
    """Causal attention as a scan over the LOWER-TRIANGULAR (i, j≤i) block
    pairs with online softmax — ~2× fewer FLOPs/bytes than the full-square
    blocked path (the static-shape analogue of the Pallas kernel's
    `pl.when` causal skip).  Probabilities are stored in `probs_dtype`
    (softmax stats stay f32) — halves the dominant [bq, S] intermediate
    traffic.  §Perf optimization; ops.attention(opt_causal=True) selects it.
    """
    B, Hq, S, Dh = q.shape
    Hkv = k.shape[1]
    g = Hq // Hkv
    if scale is None:
        scale = Dh ** -0.5
    bq = min(block, S)
    pad = (-S) % bq
    if pad:
        qp = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
        kp = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    else:
        qp, kp, vp = q, k, v
    Sp = qp.shape[2]
    nb = Sp // bq
    q5 = qp.reshape(B, Hkv, g, Sp, Dh)

    # lower-triangular pair list, i-major so each i's stats stream in order
    pairs = [(i, j) for i in range(nb) for j in range(i + 1)]
    ii = jnp.asarray([p[0] for p in pairs], jnp.int32)
    jj = jnp.asarray([p[1] for p in pairs], jnp.int32)
    tri = (jnp.arange(bq)[None, :] <= jnp.arange(bq)[:, None])

    def step(carry, ij):
        out, acc, m, l = carry
        i, j = ij
        qb = jax.lax.dynamic_slice_in_dim(q5, i * bq, bq, 3)  # [B,Hkv,g,bq,Dh]
        kb = jax.lax.dynamic_slice_in_dim(kp, j * bq, bq, 2)  # [B,Hkv,bq,Dh]
        vb = jax.lax.dynamic_slice_in_dim(vp, j * bq, bq, 2)

        fresh = (j == 0)
        m0 = jnp.where(fresh, jnp.full_like(m, -1e30), m)
        l0 = jnp.where(fresh, jnp.zeros_like(l), l)
        a0 = jnp.where(fresh, jnp.zeros_like(acc), acc)

        s = jnp.einsum("bhgqd,bhkd->bhgqk", qb.astype(jnp.float32),
                       kb.astype(jnp.float32)) * scale
        s = jnp.where((i == j) & ~tri, -1e30, s)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m0, m_cur)
        p = jnp.exp(s - m_new).astype(probs_dtype)
        corr = jnp.exp(m0 - m_new)
        l_new = l0 * corr + jnp.sum(p.astype(jnp.float32), -1, keepdims=True)
        a_new = a0 * corr + jnp.einsum(
            "bhgqk,bhkd->bhgqd", p, vb.astype(probs_dtype)).astype(jnp.float32)

        done = (i == j)             # last j for this i → publish block i
        blk = (a_new / jnp.maximum(l_new, 1e-30)).astype(out.dtype)
        # O(block) conditional write: re-write the current content when not
        # done, so traffic stays per-block (XLA updates the carry in place)
        cur = jax.lax.dynamic_slice_in_dim(out, i * bq, bq, 3)
        out = jax.lax.dynamic_update_slice_in_dim(
            out, jnp.where(done, blk, cur), i * bq, 3)
        return (out, a_new, m_new, l_new), None

    out0 = jnp.zeros_like(q5)
    acc0 = jnp.zeros((B, Hkv, g, bq, Dh), jnp.float32)
    m0 = jnp.full((B, Hkv, g, bq, 1), -1e30, jnp.float32)
    l0 = jnp.zeros((B, Hkv, g, bq, 1), jnp.float32)
    (out, _, _, _), _ = jax.lax.scan(step, (out0, acc0, m0, l0), (ii, jj))
    out = out.reshape(B, Hq, Sp, Dh)
    return out[:, :, :S] if pad else out


def attention(q, k, v, *, causal=True, scale=None, kv_len=None,
              block_q=128, block_k=128, use_pallas=True, opt_causal=False):
    """Flash attention with GQA.  q: [B,Hq,Sq,Dh]; k/v: [B,Hkv,Sk,Dh].

    use_pallas=False routes to the partitionable blocked-jnp paths (decode
    with Sq == 1 short-circuits to the plain einsum oracle);
    opt_causal=True selects the triangular-scan §Perf variant."""
    if not use_pallas:
        if q.shape[2] == 1:
            return ref.ref_attention(q, k, v, causal=causal, scale=scale,
                                     kv_len=kv_len)
        if ((opt_causal or OPT["causal_skip"]) and causal and kv_len is None
                and q.shape[2] == k.shape[2]):
            return attention_triangular_jnp(q, k, v, scale=scale)
        return attention_blocked_jnp(q, k, v, causal=causal, scale=scale,
                                     kv_len=kv_len,
                                     block_q=OPT["block_q"] or 512)
    B, Hq, Sq, Dh = q.shape
    Sk = k.shape[2]
    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    pq, pk = (-Sq) % bq, (-Sk) % bk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))
        if kv_len is None:
            kv_len = jnp.full((B,), Sk, jnp.int32)   # mask the padded tail
    out = flash_attention(q, k, v, causal=causal, scale=scale, kv_len=kv_len,
                          block_q=bq, block_k=bk, interpret=_interpret())
    return out[:, :, :Sq] if pq else out


# -------------------------------------------------------------------- ssd

def ssd_chunked_jnp(x, dt, A, B, C, *, chunk=64):
    """Chunked SSD as plain einsums + a scan over chunks — the SPMD-
    partitionable twin of the Pallas kernel (identical chunk algebra)."""
    b, s, h, p = x.shape
    n = B.shape[-1]
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    L = chunk
    nc = x.shape[1] // L
    # [nc, b, L, ...] chunk-major for the scan
    xc = jnp.moveaxis(x.reshape(b, nc, L, h, p), 1, 0).astype(jnp.float32)
    dtc = jnp.moveaxis(dt.reshape(b, nc, L, h), 1, 0).astype(jnp.float32)
    Bc = jnp.moveaxis(B.reshape(b, nc, L, n), 1, 0).astype(jnp.float32)
    Cc = jnp.moveaxis(C.reshape(b, nc, L, n), 1, 0).astype(jnp.float32)
    tri = (jnp.arange(L)[None, :] <= jnp.arange(L)[:, None])

    def step(state, inp):
        xk, dk, bk, ck = inp                     # [b,L,h,p],[b,L,h],[b,L,n]
        a = A[None, None, :] * dk                # [b, L, h]
        cum = jnp.cumsum(a, axis=1)
        G = jnp.einsum("bln,bmn->blm", ck, bk)   # [b, L, L]
        Mdec = jnp.exp(cum[:, :, None, :] - cum[:, None, :, :])   # [b,L,L,h]
        M = jnp.where(tri[None, :, :, None], Mdec, 0.0) * dk[:, None]
        y = jnp.einsum("blm,blmh,bmhp->blhp", G, M, xk)
        y = y + jnp.exp(cum)[..., None] * jnp.einsum(
            "bln,bhpn->blhp", ck, state)
        w = jnp.exp(cum[:, -1:, :] - cum) * dk   # [b, L, h]
        state = state * jnp.exp(cum[:, -1])[:, :, None, None] + jnp.einsum(
            "blhp,blh,bln->bhpn", xk, w, bk)
        return state, y

    init = jnp.zeros((b, h, p, n), jnp.float32)
    _, yc = jax.lax.scan(step, init, (xc, dtc, Bc, Cc))
    y = jnp.moveaxis(yc, 0, 1).reshape(b, nc * L, h, p).astype(x.dtype)
    return y[:, :s] if pad else y


def ssd(x, dt, A, B, C, *, chunk=64, use_pallas=True):
    """Mamba-2 SSD scan.  x: [b,s,h,p]; dt: [b,s,h]; A: [h]; B/C: [b,s,n].

    use_pallas=False routes to the partitionable chunked-jnp path."""
    if not use_pallas:
        return ssd_chunked_jnp(x, dt, A, B, C, chunk=chunk)
    s = x.shape[1]
    ch = min(chunk, s)
    pad = (-s) % ch
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    y = ssd_scan(x, dt, A, B, C, chunk=ch, interpret=_interpret())
    return y[:, :s] if pad else y


# ---------------------------------------------------------------- rmsnorm

def rmsnorm(x, w, *, eps=1e-6, use_pallas=True):
    if not use_pallas:
        return ref.ref_rmsnorm(x, w, eps)
    return _rmsnorm_kernel(x, w, eps=eps, interpret=_interpret())
