"""Blocked (flash) causal attention for TPU, with native GQA.

Grid: (batch, q_heads, Sq/BQ, Sk/BK) — the KV-block dimension is minor, so
it executes sequentially per q block and the online-softmax running state
(m, l, acc) lives in VMEM scratch across KV steps.  GQA is handled in the
BlockSpec index maps: the K/V index maps divide the q-head index by the
group size, so KV tiles are fetched once per group — no materialized
`jnp.repeat` (that is the whole point of GQA's bandwidth saving).

Causal tiles entirely above the diagonal are skipped with `pl.when` (the
standard ~2× FLOP win).  A per-batch `kv_len` input masks the padded tail
of a KV cache for decode; it rides in SMEM as a (1,1) block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(kvlen_ref, q_ref, k_ref, v_ref, o_ref,
                  m_scr, l_scr, acc_scr,
                  *, scale: float, causal: bool, bq: int, bk: int,
                  sq: int, sk: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # Global row/col offsets of this tile.  The causal offset aligns q to
    # the END of the *valid* kv prefix (decode: 1 new token vs a long,
    # possibly right-padded cache), so it is dynamic in kv_len.
    kv_len = kvlen_ref[0, 0]
    q_off = qi * bq + (kv_len - sq)
    k_off = ki * bk

    def body():
        q = q_ref[0, 0].astype(jnp.float32)              # [BQ, Dh]
        k = k_ref[0, 0].astype(jnp.float32)              # [BK, Dh]
        v = v_ref[0, 0].astype(jnp.float32)              # [BK, Dh]
        s = (q @ k.T) * scale                            # [BQ, BK]

        rows = jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + q_off
        cols = jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1) + k_off
        mask = cols < kv_len
        if causal:
            mask &= cols <= rows
        s = jnp.where(mask, s, NEG_INF)

        m_prev, l_prev = m_scr[...], l_scr[...]          # [BQ, 1]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                           # [BQ, BK]
        corr = jnp.exp(m_prev - m_new)                   # [BQ, 1]
        l_scr[...] = l_prev * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + p @ v
        m_scr[...] = m_new

    if causal:
        # skip tiles strictly above the causal diagonal
        pl.when(k_off <= q_off + bq - 1)(body)
    else:
        body()

    @pl.when(ki == nk - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal=True, scale=None, kv_len=None,
                    block_q=128, block_k=128, interpret=True):
    """q: [B, Hq, Sq, Dh]; k/v: [B, Hkv, Sk, Dh]; kv_len: optional [B] int32.

    Sq % block_q == 0 and Sk % block_k == 0 (ops.py pads); Hq % Hkv == 0.
    """
    B, Hq, Sq, Dh = q.shape
    _, Hkv, Sk, _ = k.shape
    assert Hq % Hkv == 0
    rep = Hq // Hkv
    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, Sk, bq, bk)
    if scale is None:
        scale = Dh ** -0.5
    if kv_len is None:
        kv_len = jnp.full((B,), Sk, jnp.int32)

    grid = (B, Hq, Sq // bq, Sk // bk)
    kernel = functools.partial(_flash_kernel, scale=float(scale),
                               causal=causal, bq=bq, bk=bk, sq=Sq, sk=Sk)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, h, i, j: (b, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, bq, Dh), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, Dh), lambda b, h, i, j: (b, h // rep, j, 0)),
            pl.BlockSpec((1, 1, bk, Dh), lambda b, h, i, j: (b, h // rep, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, Dh), lambda b, h, i, j: (b, h, i, 0)),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, Dh), jnp.float32),
        ],
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sq, Dh), q.dtype),
        interpret=interpret,
    )(kv_len[:, None].astype(jnp.int32), q, k, v)
