"""Pallas TPU kernels for the framework's compute hot-spots.

  slda_gibbs      — the paper's hot loop: document-blocked collapsed-Gibbs
                    sweep, topic dim on lanes, doc block on sublanes
  slda_predict    — fused multi-sweep test-time sampler: all prediction
                    sweeps in one launch, counter-hash in-kernel PRNG;
                    chain-batched grid (M, blocks) feeding ONE shared
                    corpus to all M chains (no M-way replication)
  slda_train      — fused multi-sweep TRAINING launch: k sweeps per
                    launch with an in-kernel block-local delayed-count
                    refresh of the topic-word table (VMEM scratch,
                    segmented one-hot matmul); chain-batched grid
                    (M, blocks) runs all M chains in one launch
  flash_attention — blocked causal attention with native GQA index maps
  ssd_scan        — Mamba-2 chunked state-space scan (state in VMEM scratch)
  rmsnorm         — fused row-blocked RMSNorm

Use through `repro.kernels.ops` (padding + CPU-interpret dispatch); oracles
in `repro.kernels.ref`.
"""
from . import ops, ref

__all__ = ["ops", "ref"]
