"""Collapsed Gibbs sampling for sLDA (stochastic EM), JAX-native.

Sampling model (Eq. 1 of the paper): the probability of assigning topic t to
token w_{d,n} is

    p(z=t | ·) ∝ N(y_d; μ_{d,n,t}, ρ) · (N_dt^{-dn}+α)/(N_d^{-dn}+Tα)
                                      · (N_tw^{-dn}+β)/(N_t^{-dn}+Wβ)

Parallel structure (see DESIGN.md §3):
  * token loop inside a document is an exact sequential `lax.scan`
    (vectorized over the topic dimension),
  * documents are swept in parallel (vmap) with the topic-word table frozen
    for the sweep and refreshed exactly afterwards (AD-LDA delayed counts),
  * chains never talk to each other — that is the paper's contribution and
    it lives one level up, in `parallel.py`.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.mathutil import upper_tri_ones
from .types import (Corpus, GibbsState, SLDAConfig, SLDAModel,
                    apply_count_deltas, counts_from_assignments)


def init_state(key: jax.Array, corpus: Corpus, cfg: SLDAConfig) -> GibbsState:
    """Uniform-random topic init; counts derived exactly from z."""
    z = jax.random.randint(key, corpus.tokens.shape, 0, cfg.n_topics, jnp.int32)
    ndt, ntw, nt = counts_from_assignments(
        corpus.tokens, corpus.mask, z, cfg.n_topics, cfg.vocab_size)
    eta = jnp.full((cfg.n_topics,), cfg.mu, jnp.float32)
    return GibbsState(z=z, ndt=ndt, ntw=ntw, nt=nt, eta=eta)


def _doc_sweep(tokens, mask, uniforms, z, ndt, y, inv_len,
               ntw, nt, eta, cfg: SLDAConfig, supervised: bool):
    """One exact sequential Gibbs sweep over the tokens of ONE document.

    ntw/nt are the sweep-frozen global tables; the document's own current
    token is subtracted on the fly so the -dn counts are exact w.r.t. this
    document.  Returns (new z, new ndt).
    """
    T = cfg.n_topics
    s0 = jnp.dot(ndt, eta)            # running  Σ_t η_t N_dt  statistic
    topic_iota = jnp.arange(T, dtype=jnp.int32)
    # prefix-sum-as-matmul: one gemm instead of a fusion-breaking cumsum,
    # the same contraction as the Pallas kernel
    tri_u = upper_tri_ones(T)

    def step(carry, inp):
        ndt_d, s = carry
        w, m, z_old, u = inp
        old_onehot = (topic_iota == z_old).astype(jnp.float32) * m
        ndt_d = ndt_d - old_onehot                      # remove current token
        s = s - eta[z_old] * m

        # log p(t) over all T topics, Eq. (1)
        ntw_w = ntw[:, w] - old_onehot                  # -dn for own token
        nt_m = nt - old_onehot
        logp = (jnp.log(ndt_d + cfg.alpha)
                + jnp.log(ntw_w + cfg.beta)
                - jnp.log(nt_m + cfg.vocab_size * cfg.beta))
        if supervised:
            mu_t = (s + eta) * inv_len                  # mean if z_{d,n}=t
            logp = logp - 0.5 * (y - mu_t) ** 2 / cfg.rho

        # categorical sample from the given uniform (branch-free inverse-CDF)
        p = jnp.exp(logp - jnp.max(logp))
        c = jnp.dot(p, tri_u)
        z_new = jnp.sum((c < u * c[-1]).astype(jnp.int32))
        z_new = jnp.where(m > 0, z_new, z_old).astype(jnp.int32)

        new_onehot = (topic_iota == z_new).astype(jnp.float32) * m
        ndt_d = ndt_d + new_onehot
        s = s + eta[z_new] * m
        return (ndt_d, s), z_new

    (ndt, _), z_new = jax.lax.scan(step, (ndt, s0), (tokens, mask, z, uniforms))
    return z_new, ndt


def sweep(key: jax.Array, corpus: Corpus, state: GibbsState,
          cfg: SLDAConfig, supervised: bool = True,
          exact_rebuild=True) -> GibbsState:
    """One document-parallel sweep + count refresh.

    The per-document sweep already maintains `ndt` exactly, so it is taken
    from the sweep output directly.  The global tables refresh two ways:
    `exact_rebuild=True` re-scatters ntw/nt from scratch (seed behaviour,
    and the periodic drift bound); `False` applies the exact (z_old, z_new)
    delta updates only.  A traced bool selects at runtime via `lax.cond`
    (train_chain drives this with `cfg.count_rebuild_every`).
    """
    uniforms = jax.random.uniform(key, corpus.tokens.shape)
    inv_len = 1.0 / jnp.maximum(corpus.lengths(), 1.0)
    if cfg.use_pallas or cfg.sampler_mode == "sparse":
        # sparse mode lives in the kernels layer for BOTH backends: the
        # two-stage draw against the sweep-frozen topic index is shared
        # by kernel, jnp twin and oracle (the vmap path below is the
        # dense-only seed sweep and stays bit-frozen).
        from repro.kernels import ops  # local import: kernels are optional
        z, ndt = ops.slda_gibbs_sweep(
            corpus.tokens, corpus.mask, uniforms, state.z, state.ndt,
            corpus.y, inv_len, state.ntw, state.nt, state.eta,
            alpha=cfg.alpha, beta=cfg.beta, rho=cfg.rho, supervised=supervised,
            use_pallas=cfg.use_pallas, sampler_mode=cfg.sampler_mode,
            sparse_topic_cap=cfg.sparse_topic_cap)
    else:
        z, ndt = jax.vmap(
            _doc_sweep,
            in_axes=(0, 0, 0, 0, 0, 0, 0, None, None, None, None, None)
        )(corpus.tokens, corpus.mask, uniforms, state.z, state.ndt,
          corpus.y, inv_len, state.ntw, state.nt, state.eta, cfg, supervised)

    def rebuild():
        ndt_r, ntw, nt = counts_from_assignments(
            corpus.tokens, corpus.mask, z, cfg.n_topics, cfg.vocab_size)
        return ndt_r, ntw, nt

    def incremental():
        ntw, nt = apply_count_deltas(state.ntw, state.nt, corpus.tokens,
                                     corpus.mask, state.z, z)
        return ndt, ntw, nt

    if isinstance(exact_rebuild, bool):
        ndt, ntw, nt = rebuild() if exact_rebuild else incremental()
    else:
        ndt, ntw, nt = jax.lax.cond(exact_rebuild, rebuild, incremental)
    return GibbsState(z=z, ndt=ndt, ntw=ntw, nt=nt, eta=state.eta)


def zbar(state: GibbsState, corpus: Corpus) -> jax.Array:
    """Empirical topic distribution  z̄_d  of each document."""
    return state.ndt / jnp.maximum(corpus.lengths(), 1.0)[:, None]


def phi_hat(state: GibbsState, cfg: SLDAConfig) -> jax.Array:
    """Smoothed topic-word distributions, Eq. (3)."""
    return (state.ntw + cfg.beta) / (state.nt[:, None] + cfg.vocab_size * cfg.beta)


def train_chain(key: jax.Array, corpus: Corpus, cfg: SLDAConfig) -> tuple[GibbsState, SLDAModel]:
    """Full stochastic-EM loop for ONE chain on ONE (sub-)corpus.

    Alternates Gibbs sweeps over z with the ridge solve for η (Eq. 2).
    `cfg.sweeps_per_launch = 1` is the seed path: one sweep per η solve,
    threefry uniforms, globally sweep-frozen counts.  `> 1` fuses that
    many sweeps into each `ops.slda_train_sweeps` launch (η solve stays
    between launches).  Fully jit-able; contains no collectives — chains
    run communication-free.

    Thin wrapper over the unified execution plan (DESIGN.md
    §Execution-plan): a single chain is M=1 through the chain-batched
    loop — bit-identical to the old dedicated single-chain path, which
    is deleted.  `corpus` may be a `BucketedCorpus` (DESIGN.md
    §Ragged-execution): sweeps then run over the length-bucketed
    schedule — bit-identical per document at sweeps_per_launch=1; at
    sweeps_per_launch>1 on the jnp route the plan picks the STAIRCASE
    executor (the stair form of the single-chain fused train path).
    """
    from .plan import build_plan   # local import: plan sits above gibbs
    plan = build_plan(corpus, cfg, chained=True)
    state, model = plan.train(key[None])
    return jax.tree.map(lambda a: a[0], (state, model))
