"""Collapsed Gibbs sampling for sLDA (stochastic EM), JAX-native.

Sampling model (Eq. 1 of the paper): the probability of assigning topic t to
token w_{d,n} is

    p(z=t | ·) ∝ N(y_d; μ_{d,n,t}, ρ) · (N_dt^{-dn}+α)/(N_d^{-dn}+Tα)
                                      · (N_tw^{-dn}+β)/(N_t^{-dn}+Wβ)

Parallel structure (see DESIGN.md §3):
  * token loop inside a document is an exact sequential `lax.scan`
    (vectorized over the topic dimension),
  * documents are swept in parallel (vmap) with the topic-word table frozen
    for the sweep and refreshed exactly afterwards (AD-LDA delayed counts),
  * chains never talk to each other — that is the paper's contribution and
    it lives one level up, in `parallel.py`.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.mathutil import upper_tri_ones
from .types import (BucketedCorpus, Corpus, GibbsState, SLDAConfig,
                    SLDAModel, apply_count_deltas, counts_from_assignments)
from .regression import solve_eta


def init_state(key: jax.Array, corpus: Corpus, cfg: SLDAConfig) -> GibbsState:
    """Uniform-random topic init; counts derived exactly from z."""
    z = jax.random.randint(key, corpus.tokens.shape, 0, cfg.n_topics, jnp.int32)
    ndt, ntw, nt = counts_from_assignments(
        corpus.tokens, corpus.mask, z, cfg.n_topics, cfg.vocab_size)
    eta = jnp.full((cfg.n_topics,), cfg.mu, jnp.float32)
    return GibbsState(z=z, ndt=ndt, ntw=ntw, nt=nt, eta=eta)


def _doc_sweep(tokens, mask, uniforms, z, ndt, y, inv_len,
               ntw, nt, eta, cfg: SLDAConfig, supervised: bool):
    """One exact sequential Gibbs sweep over the tokens of ONE document.

    ntw/nt are the sweep-frozen global tables; the document's own current
    token is subtracted on the fly so the -dn counts are exact w.r.t. this
    document.  Returns (new z, new ndt).
    """
    T = cfg.n_topics
    s0 = jnp.dot(ndt, eta)            # running  Σ_t η_t N_dt  statistic
    topic_iota = jnp.arange(T, dtype=jnp.int32)
    # prefix-sum-as-matmul: one gemm instead of a fusion-breaking cumsum,
    # the same contraction as the Pallas kernel
    tri_u = upper_tri_ones(T)

    def step(carry, inp):
        ndt_d, s = carry
        w, m, z_old, u = inp
        old_onehot = (topic_iota == z_old).astype(jnp.float32) * m
        ndt_d = ndt_d - old_onehot                      # remove current token
        s = s - eta[z_old] * m

        # log p(t) over all T topics, Eq. (1)
        ntw_w = ntw[:, w] - old_onehot                  # -dn for own token
        nt_m = nt - old_onehot
        logp = (jnp.log(ndt_d + cfg.alpha)
                + jnp.log(ntw_w + cfg.beta)
                - jnp.log(nt_m + cfg.vocab_size * cfg.beta))
        if supervised:
            mu_t = (s + eta) * inv_len                  # mean if z_{d,n}=t
            logp = logp - 0.5 * (y - mu_t) ** 2 / cfg.rho

        # categorical sample from the given uniform (branch-free inverse-CDF)
        p = jnp.exp(logp - jnp.max(logp))
        c = jnp.dot(p, tri_u)
        z_new = jnp.sum((c < u * c[-1]).astype(jnp.int32))
        z_new = jnp.where(m > 0, z_new, z_old).astype(jnp.int32)

        new_onehot = (topic_iota == z_new).astype(jnp.float32) * m
        ndt_d = ndt_d + new_onehot
        s = s + eta[z_new] * m
        return (ndt_d, s), z_new

    (ndt, _), z_new = jax.lax.scan(step, (ndt, s0), (tokens, mask, z, uniforms))
    return z_new, ndt


def sweep(key: jax.Array, corpus: Corpus, state: GibbsState,
          cfg: SLDAConfig, supervised: bool = True,
          exact_rebuild=True) -> GibbsState:
    """One document-parallel sweep + count refresh.

    The per-document sweep already maintains `ndt` exactly, so it is taken
    from the sweep output directly.  The global tables refresh two ways:
    `exact_rebuild=True` re-scatters ntw/nt from scratch (seed behaviour,
    and the periodic drift bound); `False` applies the exact (z_old, z_new)
    delta updates only.  A traced bool selects at runtime via `lax.cond`
    (train_chain drives this with `cfg.count_rebuild_every`).
    """
    uniforms = jax.random.uniform(key, corpus.tokens.shape)
    inv_len = 1.0 / jnp.maximum(corpus.lengths(), 1.0)
    if cfg.use_pallas:
        from repro.kernels import ops  # local import: kernels are optional
        z, ndt = ops.slda_gibbs_sweep(
            corpus.tokens, corpus.mask, uniforms, state.z, state.ndt,
            corpus.y, inv_len, state.ntw, state.nt, state.eta,
            alpha=cfg.alpha, beta=cfg.beta, rho=cfg.rho, supervised=supervised)
    else:
        z, ndt = jax.vmap(
            _doc_sweep,
            in_axes=(0, 0, 0, 0, 0, 0, 0, None, None, None, None, None)
        )(corpus.tokens, corpus.mask, uniforms, state.z, state.ndt,
          corpus.y, inv_len, state.ntw, state.nt, state.eta, cfg, supervised)

    def rebuild():
        ndt_r, ntw, nt = counts_from_assignments(
            corpus.tokens, corpus.mask, z, cfg.n_topics, cfg.vocab_size)
        return ndt_r, ntw, nt

    def incremental():
        ntw, nt = apply_count_deltas(state.ntw, state.nt, corpus.tokens,
                                     corpus.mask, state.z, z)
        return ndt, ntw, nt

    if isinstance(exact_rebuild, bool):
        ndt, ntw, nt = rebuild() if exact_rebuild else incremental()
    else:
        ndt, ntw, nt = jax.lax.cond(exact_rebuild, rebuild, incremental)
    return GibbsState(z=z, ndt=ndt, ntw=ntw, nt=nt, eta=state.eta)


def zbar(state: GibbsState, corpus: Corpus) -> jax.Array:
    """Empirical topic distribution  z̄_d  of each document."""
    return state.ndt / jnp.maximum(corpus.lengths(), 1.0)[:, None]


def phi_hat(state: GibbsState, cfg: SLDAConfig) -> jax.Array:
    """Smoothed topic-word distributions, Eq. (3)."""
    return (state.ntw + cfg.beta) / (state.nt[:, None] + cfg.vocab_size * cfg.beta)


def _train_chain_fused(k_sweeps: jax.Array, corpus: Corpus,
                       state0: GibbsState, cfg: SLDAConfig) -> GibbsState:
    """Stochastic-EM via the fused multi-sweep launch (sweeps_per_launch>1).

    Each launch runs `spl` Gibbs sweeps through `ops.slda_train_sweeps`
    (counter-hash PRNG, block-local delayed counts between in-launch
    sweeps, DESIGN.md §Train-kernel); between launches the global tables
    refresh exactly — compacted deltas with a periodic
    `count_rebuild_every` re-scatter, both exact — and η re-solves.
    Total sweeps stay cfg.n_iters: a remainder launch mops up when
    n_iters is not a multiple of spl.
    """
    spl = cfg.sweeps_per_launch
    every = cfg.count_rebuild_every
    D = corpus.n_docs
    # clamp the block to the corpus (rounded to the sublane tile) so a
    # small shard doesn't pad up to a mostly-empty block
    doc_block = min(cfg.train_doc_block, -(-D // 8) * 8)
    inv_len = 1.0 / jnp.maximum(corpus.lengths(), 1.0)
    from repro.kernels import ops  # local import: kernels are optional

    def launch(state: GibbsState, k, it, n_sweeps: int) -> GibbsState:
        seeds = jax.random.randint(k, (D,), 0, jnp.iinfo(jnp.int32).max,
                                   jnp.int32)
        z, ndt = ops.slda_train_sweeps(
            corpus.tokens, corpus.mask, state.z, state.ndt, corpus.y,
            inv_len, state.ntw, state.nt, state.eta, seeds,
            alpha=cfg.alpha, beta=cfg.beta, rho=cfg.rho,
            n_sweeps=n_sweeps, supervised=True,
            doc_block=doc_block, use_pallas=cfg.use_pallas,
            product_form=cfg.product_form_sweeps)

        def rebuild(_):
            return counts_from_assignments(corpus.tokens, corpus.mask, z,
                                           cfg.n_topics, cfg.vocab_size)

        def incremental(_):
            ntw, nt = apply_count_deltas(state.ntw, state.nt, corpus.tokens,
                                         corpus.mask, state.z, z)
            return ndt, ntw, nt

        # exact global refresh from (z_launch_start, z_final); periodic
        # full rebuild on the count_rebuild_every cadence (in launches)
        if every > 0:
            ndt, ntw, nt = jax.lax.cond(it % every == 0, rebuild,
                                        incremental, None)
        else:
            ndt, ntw, nt = incremental(None)
        state = GibbsState(z=z, ndt=ndt, ntw=ntw, nt=nt, eta=state.eta)
        eta = solve_eta(zbar(state, corpus), corpus.y, cfg)
        return GibbsState(z, ndt, ntw, nt, eta)

    n_full, rem = divmod(cfg.n_iters, spl)
    keys = jax.random.split(k_sweeps, n_full + (1 if rem else 0))
    state = state0
    if n_full:
        state, _ = jax.lax.scan(
            lambda s, inp: (launch(s, inp[0], inp[1], spl), None),
            state, (keys[:n_full], jnp.arange(n_full)))
    if rem:  # remainder launch keeps total sweeps == n_iters exactly
        state = launch(state, keys[-1], jnp.asarray(n_full), rem)
    return state


# ------------------------------------------------ bucketed (ragged) path

def _init_state_bucketed(key: jax.Array, bc: BucketedCorpus,
                         cfg: SLDAConfig):
    """init_state on a length-bucketed corpus: the SAME `[D, max_len]`
    threefry draw as the padded path (so bit-identity holds per doc),
    carved along the schedule.  Returns (state, z_fill) where state.z is
    a tuple of per-bucket assignment arrays and z_fill keeps the init
    values of the all-padding token slots beyond each bucket's width
    (what the padded path would have left untouched)."""
    z_fill = jax.random.randint(key, (bc.n_docs, bc.ctr_stride), 0,
                                cfg.n_topics, jnp.int32)
    z_b = tuple(bc.split_padded(z_fill))
    ndt_pieces, ntw = [], jnp.zeros((cfg.n_topics, cfg.vocab_size),
                                    jnp.float32)
    for b, zb in zip(bc.buckets, z_b):
        nd, nw, _ = counts_from_assignments(b.tokens, b.mask, zb,
                                            cfg.n_topics, cfg.vocab_size)
        ndt_pieces.append(nd)
        ntw = ntw + nw               # ±1 integer adds — exact in any order
    eta = jnp.full((cfg.n_topics,), cfg.mu, jnp.float32)
    state = GibbsState(z=z_b, ndt=bc.merge_docs(ndt_pieces), ntw=ntw,
                       nt=jnp.sum(ntw, axis=-1), eta=eta)
    return state, z_fill


def _refresh_bucketed(bc: BucketedCorpus, z_old_b, z_new_b, ndt, ntw, nt,
                      cfg: SLDAConfig, rebuild_now):
    """Exact global (ndt, ntw, nt) refresh across buckets — rebuild and
    incremental forms, both exact (all updates are ±1 integers)."""
    def rebuild(_):
        ntw2 = jnp.zeros_like(ntw)
        pieces = []
        for b, zb in zip(bc.buckets, z_new_b):
            nd, nw, _ = counts_from_assignments(b.tokens, b.mask, zb,
                                                cfg.n_topics,
                                                cfg.vocab_size)
            pieces.append(nd)
            ntw2 = ntw2 + nw
        return bc.merge_docs(pieces), ntw2, jnp.sum(ntw2, axis=-1)

    def incremental(_):
        ntw2, nt2 = ntw, nt
        for b, zo, zn in zip(bc.buckets, z_old_b, z_new_b):
            ntw2, nt2 = apply_count_deltas(ntw2, nt2, b.tokens, b.mask,
                                           zo, zn)
        return ndt, ntw2, nt2

    if isinstance(rebuild_now, bool):
        return rebuild(None) if rebuild_now else incremental(None)
    return jax.lax.cond(rebuild_now, rebuild, incremental, None)


def _train_chain_bucketed(key: jax.Array, bc: BucketedCorpus,
                          cfg: SLDAConfig):
    """train_chain over a length-bucketed schedule (DESIGN.md
    §Ragged-execution): every sweep/launch runs once per bucket at the
    bucket's own padded width, while ndt/η/y stay in ORIGINAL document
    order at each EM boundary so all cross-document reductions (η solve,
    MSE) see the padded path's operand order.  At sweeps_per_launch=1
    this is bit-identical per document to the padded train_chain (same
    threefry uniforms sliced along the schedule); at >1 it is the fused
    sampler family with the bucket-local block partition."""
    from repro.kernels import ops  # local import: kernels are optional

    k_init, k_sweeps = jax.random.split(key)
    state0, z_fill = _init_state_bucketed(k_init, bc, cfg)
    every = cfg.count_rebuild_every
    D, S = bc.n_docs, bc.ctr_stride
    y = bc.y
    lengths = jnp.maximum(bc.lengths(), 1.0)
    inv_len = 1.0 / lengths
    inv_len_b = bc.split_docs(inv_len)

    def em_boundary(state, z_new_b, ndt_pieces, rebuild_now):
        ndt, ntw, nt = _refresh_bucketed(
            bc, state.z, z_new_b, bc.merge_docs(ndt_pieces), state.ntw,
            state.nt, cfg, rebuild_now)
        eta = solve_eta(ndt / lengths[:, None], y, cfg)
        return GibbsState(z=tuple(z_new_b), ndt=ndt, ntw=ntw, nt=nt,
                          eta=eta)

    if cfg.sweeps_per_launch > 1:
        spl = cfg.sweeps_per_launch

        def launch(state, k, it, n_sweeps):
            seeds = jax.random.randint(k, (D,), 0,
                                       jnp.iinfo(jnp.int32).max, jnp.int32)
            seeds_b = bc.split_docs(seeds)
            ndt_b = bc.split_docs(state.ndt)
            z_new_b, ndt_pieces = [], []
            for b, zb, ndb, sb, ilb in zip(bc.buckets, state.z, ndt_b,
                                           seeds_b, inv_len_b):
                db = min(cfg.train_doc_block, -(-b.tokens.shape[0] // 8) * 8)
                z2, nd2 = ops.slda_train_sweeps(
                    b.tokens, b.mask, zb, ndb, b.y, ilb, state.ntw,
                    state.nt, state.eta, sb, alpha=cfg.alpha,
                    beta=cfg.beta, rho=cfg.rho, n_sweeps=n_sweeps,
                    supervised=True, doc_block=db,
                    use_pallas=cfg.use_pallas,
                    product_form=cfg.product_form_sweeps, ctr_stride=S)
                z_new_b.append(z2)
                ndt_pieces.append(nd2)
            rebuild_now = (it % every == 0) if every > 0 else False
            return em_boundary(state, z_new_b, ndt_pieces, rebuild_now)

        n_full, rem = divmod(cfg.n_iters, spl)
        keys = jax.random.split(k_sweeps, n_full + (1 if rem else 0))
        state = state0
        if n_full:
            state, _ = jax.lax.scan(
                lambda s, inp: (launch(s, inp[0], inp[1], spl), None),
                state, (keys[:n_full], jnp.arange(n_full)))
        if rem:
            state = launch(state, keys[-1], jnp.asarray(n_full), rem)
    else:
        def em_step(state, inp):
            k, it = inp
            uniforms = jax.random.uniform(k, (D, S))  # the padded draw
            u_b = bc.split_padded(uniforms)
            ndt_b = bc.split_docs(state.ndt)
            z_new_b, ndt_pieces = [], []
            for b, ub, zb, ndb, ilb in zip(bc.buckets, u_b, state.z,
                                           ndt_b, inv_len_b):
                z2, nd2 = ops.slda_gibbs_sweep(
                    b.tokens, b.mask, ub, zb, ndb, b.y, ilb, state.ntw,
                    state.nt, state.eta, alpha=cfg.alpha, beta=cfg.beta,
                    rho=cfg.rho, supervised=True,
                    use_pallas=cfg.use_pallas)
                z_new_b.append(z2)
                ndt_pieces.append(nd2)
            rebuild_now = (it % every == 0) if every > 0 else False
            return em_boundary(state, z_new_b, ndt_pieces,
                               rebuild_now), None

        state, _ = jax.lax.scan(
            em_step, state0, (jax.random.split(k_sweeps, cfg.n_iters),
                              jnp.arange(cfg.n_iters)))

    zb = state.ndt / lengths[:, None]
    yhat_tr = zb @ state.eta
    mse = jnp.mean((yhat_tr - y) ** 2)
    acc = jnp.mean(((yhat_tr > 0.5) == (y > 0.5)).astype(jnp.float32))
    model = SLDAModel(phi=phi_hat(state, cfg), eta=state.eta,
                      train_mse=mse, train_acc=acc)
    state = GibbsState(z=bc.merge_padded(state.z, z_fill), ndt=state.ndt,
                       ntw=state.ntw, nt=state.nt, eta=state.eta)
    return state, model


def train_chain(key: jax.Array, corpus: Corpus, cfg: SLDAConfig) -> tuple[GibbsState, SLDAModel]:
    """Full stochastic-EM loop for ONE chain on ONE (sub-)corpus.

    Alternates Gibbs sweeps over z with the ridge solve for η (Eq. 2).
    `cfg.sweeps_per_launch = 1` is the seed path: one sweep per η solve,
    threefry uniforms, globally sweep-frozen counts.  `> 1` fuses that
    many sweeps into each `ops.slda_train_sweeps` launch (η solve stays
    between launches).  Fully jit-able; contains no collectives — chains
    run communication-free.

    `corpus` may be a `BucketedCorpus` (DESIGN.md §Ragged-execution):
    sweeps then run once per length bucket at the bucket's own padded
    width — bit-identical per document at sweeps_per_launch=1, the
    bucket-partitioned fused sampler family above it.
    """
    if isinstance(corpus, BucketedCorpus):
        return _train_chain_bucketed(key, corpus, cfg)
    k_init, k_sweeps = jax.random.split(key)
    state0 = init_state(k_init, corpus, cfg)
    every = cfg.count_rebuild_every

    if cfg.sweeps_per_launch > 1:
        state = _train_chain_fused(k_sweeps, corpus, state0, cfg)
    else:
        def em_step(state, inp):
            k, it = inp
            # incremental delta refresh between periodic exact rebuilds
            rebuild = (it % every == 0) if every > 0 else False
            state = sweep(k, corpus, state, cfg, supervised=True,
                          exact_rebuild=rebuild)
            eta = solve_eta(zbar(state, corpus), corpus.y, cfg)
            return GibbsState(state.z, state.ndt, state.ntw, state.nt,
                              eta), None

        state, _ = jax.lax.scan(
            em_step, state0, (jax.random.split(k_sweeps, cfg.n_iters),
                              jnp.arange(cfg.n_iters)))

    yhat_tr = zbar(state, corpus) @ state.eta
    mse = jnp.mean((yhat_tr - corpus.y) ** 2)
    acc = jnp.mean(((yhat_tr > 0.5) == (corpus.y > 0.5)).astype(jnp.float32))
    model = SLDAModel(phi=phi_hat(state, cfg), eta=state.eta,
                      train_mse=mse, train_acc=acc)
    return state, model
