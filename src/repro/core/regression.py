"""The M-step of sLDA's stochastic EM: the regression parameters η.

Maximizing Eq. (2),

    L(η) = -1/(2ρ) Σ_d (y_d - ηᵀ z̄_d)² - 1/(2σ) Σ_t (η_t - μ)²,

is ridge regression with prior mean μ; the closed form is

    (Z̄ᵀZ̄/ρ + I/σ) η = Z̄ᵀ y / ρ + μ/σ.

T is small (tens), so a dense solve is exact and cheap.
"""
from __future__ import annotations

import jax.numpy as jnp

from .types import SLDAConfig


def solve_eta(zbar: jnp.ndarray, y: jnp.ndarray, cfg: SLDAConfig) -> jnp.ndarray:
    T = zbar.shape[-1]
    gram = zbar.T @ zbar / cfg.rho + jnp.eye(T, dtype=zbar.dtype) / cfg.sigma
    rhs = zbar.T @ y / cfg.rho + cfg.mu / cfg.sigma
    return jnp.linalg.solve(gram, rhs)


def solve_eta_ols(zbar: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """Plain OLS (tiny jitter for rank safety) — the paper's Naive
    Combination step 3(a) fits η by *ordinary* linear regression on the
    pooled sub-samples."""
    T = zbar.shape[-1]
    gram = zbar.T @ zbar + 1e-6 * jnp.eye(T, dtype=zbar.dtype)
    return jnp.linalg.solve(gram, zbar.T @ y)
