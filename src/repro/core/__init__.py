"""Paper core: communication-free embarrassingly parallel MCMC for sLDA."""
from .types import (BucketedCorpus, Corpus, GibbsState, SLDAConfig,
                    SLDAModel, apply_count_deltas, bucket_corpus,
                    bucket_signature, counts_from_assignments,
                    devices_support_pallas, partition, topic_occupancy,
                    topic_occupancy_index)
from .gibbs import init_state, sweep, train_chain, zbar, phi_hat
from .regression import solve_eta, solve_eta_ols
from .plan import ExecutionPlan, as_bucketed, build_plan, build_schedule
from .predict import predict
from .combine import simple_average, weighted_average, median, all_dead, \
    COMBINERS
from .parallel import (ALGORITHMS, train_chains, predict_chains,
                       run_nonparallel, run_naive, run_simple_average,
                       run_weighted_average)
from .supervisor import (ChainSupervisor, EnsembleHealthError, HealthConfig,
                         RecoveryPolicy, SupervisorReport, chain_status,
                         describe_status, model_status,
                         supervised_run_average)

__all__ = [
    "BucketedCorpus", "Corpus", "GibbsState", "SLDAConfig", "SLDAModel",
    "apply_count_deltas", "bucket_corpus", "bucket_signature",
    "counts_from_assignments",
    "devices_support_pallas", "init_state", "sweep", "train_chain",
    "topic_occupancy", "topic_occupancy_index",
    "zbar", "phi_hat", "solve_eta", "solve_eta_ols",
    "ExecutionPlan", "as_bucketed", "build_plan", "build_schedule",
    "predict", "simple_average", "weighted_average", "median", "all_dead",
    "COMBINERS", "ALGORITHMS", "partition", "train_chains",
    "predict_chains", "run_nonparallel", "run_naive", "run_simple_average",
    "run_weighted_average",
    "ChainSupervisor", "EnsembleHealthError", "HealthConfig",
    "RecoveryPolicy", "SupervisorReport", "chain_status", "describe_status",
    "model_status", "supervised_run_average",
]
