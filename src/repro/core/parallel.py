"""The four algorithms of Section IV, sharing one sampler.

  non-parallel      one chain on the full training corpus (paper benchmark 1)
  naive             M chains; pool the *sampled topics* as if drawn on the
                    full corpus, fit (η, φ) globally, predict once
                    (paper benchmark 2 — exhibits quasi-ergodicity)
  simple-average    M chains; each predicts the test set; Eq. (7) combine
  weighted-average  M chains; each predicts test AND full train set (for the
                    weights); Eq. (8)-(9) combine

Chains are CHAIN-BATCHED here (single-host form): the M independent
chains run through the `chain_axis` forms of `kernels.ops` — one fused
launch (or one folded/nested-vmap jnp op) carries all M chains instead
of replaying the single-chain path under `jax.vmap` per chain
(DESIGN.md §Chain-batched).  At `sweeps_per_launch=1` the batched EM
loop reproduces `jax.vmap(train_chain)` BIT-FOR-BIT (same threefry key
tree, same sweep op order — asserted in tests/test_chain_batched.py);
at `sweeps_per_launch>1` it is the fused multi-sweep sampler family of
DESIGN.md §Train-kernel, chain-batched.

The multi-device form — `shard_map` over the mesh's chain axis with
zero collectives until the final prediction gather, and
`chains_per_device` local chains per mesh slice riding these same
chain-batched entry points — lives in `repro.launch.slda_parallel`.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import combine
from .gibbs import init_state, phi_hat, train_chain
from .predict import predict
from .regression import solve_eta, solve_eta_ols
from .types import (Corpus, GibbsState, SLDAConfig, SLDAModel,
                    apply_count_deltas, counts_from_assignments)


def partition(corpus: Corpus, m: int) -> Corpus:
    """Split a corpus into M equal shards: [D, ...] → [M, D/M, ...].

    The paper partitions uniformly at random; callers should pre-shuffle.
    D must be divisible by M (pad the corpus if not).
    """
    if corpus.n_docs % m:
        raise ValueError(f"{corpus.n_docs} docs not divisible by {m} shards")
    reshape = lambda x: x.reshape((m, corpus.n_docs // m) + x.shape[1:])
    return Corpus(tokens=reshape(corpus.tokens), mask=reshape(corpus.mask),
                  y=reshape(corpus.y))


# ----------------------------------------------- chain-batched training

def _refresh_and_solve(z, ndt, state, shards, cfg, rebuild_now):
    """Exact global count refresh (rebuild or incremental deltas, both
    exact) followed by the per-chain η ridge solve — one EM boundary,
    batched over the chain axis."""
    def rebuild(_):
        return jax.vmap(lambda t, m_, zz: counts_from_assignments(
            t, m_, zz, cfg.n_topics, cfg.vocab_size))(
            shards.tokens, shards.mask, z)

    def incremental(_):
        ntw, nt = jax.vmap(apply_count_deltas)(
            state.ntw, state.nt, shards.tokens, shards.mask, state.z, z)
        return ndt, ntw, nt

    if isinstance(rebuild_now, bool):
        ndt, ntw, nt = rebuild(None) if rebuild_now else incremental(None)
    else:
        ndt, ntw, nt = jax.lax.cond(rebuild_now, rebuild, incremental, None)
    lengths = jnp.maximum(shards.mask.sum(-1), 1.0)
    eta = jax.vmap(lambda nd, l, yy: solve_eta(nd / l[:, None], yy, cfg))(
        ndt, lengths, shards.y)
    return GibbsState(z=z, ndt=ndt, ntw=ntw, nt=nt, eta=eta)


def _train_chains_seed(k_sweeps, shards, state0, cfg: SLDAConfig):
    """Chain-batched stochastic EM at sweeps_per_launch=1: per-sweep
    threefry uniforms, seed-semantics sweep, η solve every sweep —
    bit-identical to `jax.vmap(train_chain)` (the per-chain key tree and
    every op are the vmapped ones; only the sweep itself runs through
    the chain_axis op)."""
    from repro.kernels import ops  # local import: kernels are optional
    every = cfg.count_rebuild_every
    inv_len = 1.0 / jnp.maximum(shards.mask.sum(-1), 1.0)

    def em_step(state, inp):
        ks, it = inp
        uniforms = jax.vmap(
            lambda k: jax.random.uniform(k, shards.tokens.shape[1:]))(ks)
        z, ndt = ops.slda_gibbs_sweep(
            shards.tokens, shards.mask, uniforms, state.z, state.ndt,
            shards.y, inv_len, state.ntw, state.nt, state.eta,
            alpha=cfg.alpha, beta=cfg.beta, rho=cfg.rho, supervised=True,
            use_pallas=cfg.use_pallas, chain_axis=True)
        rebuild_now = (it % every == 0) if every > 0 else False
        return _refresh_and_solve(z, ndt, state, shards, cfg,
                                  rebuild_now), None

    keys = jax.vmap(lambda k: jax.random.split(k, cfg.n_iters))(k_sweeps)
    state, _ = jax.lax.scan(em_step, state0,
                            (jnp.moveaxis(keys, 0, 1),
                             jnp.arange(cfg.n_iters)))
    return state


def _train_chains_fused(k_sweeps, shards, state0, cfg: SLDAConfig):
    """Chain-batched stochastic EM via fused multi-sweep launches: ONE
    grid-(M, B) kernel launch (or one chain-batched jnp op) runs
    `sweeps_per_launch` sweeps for ALL chains; the exact global refresh
    and the η solves happen between launches (chain-batched mirror of
    `gibbs._train_chain_fused`)."""
    from repro.kernels import ops  # local import: kernels are optional
    spl = cfg.sweeps_per_launch
    every = cfg.count_rebuild_every
    d_m = shards.tokens.shape[1]
    doc_block = min(cfg.train_doc_block, -(-d_m // 8) * 8)
    inv_len = 1.0 / jnp.maximum(shards.mask.sum(-1), 1.0)

    def launch(state, ks, it, n_sweeps: int):
        seeds = jax.vmap(lambda k: jax.random.randint(
            k, (d_m,), 0, jnp.iinfo(jnp.int32).max, jnp.int32))(ks)
        z, ndt = ops.slda_train_sweeps(
            shards.tokens, shards.mask, state.z, state.ndt, shards.y,
            inv_len, state.ntw, state.nt, state.eta, seeds,
            alpha=cfg.alpha, beta=cfg.beta, rho=cfg.rho,
            n_sweeps=n_sweeps, supervised=True, doc_block=doc_block,
            use_pallas=cfg.use_pallas,
            product_form=cfg.product_form_sweeps, chain_axis=True)
        rebuild_now = (it % every == 0) if every > 0 else False
        return _refresh_and_solve(z, ndt, state, shards, cfg, rebuild_now)

    n_full, rem = divmod(cfg.n_iters, spl)
    keys = jax.vmap(lambda k: jax.random.split(
        k, n_full + (1 if rem else 0)))(k_sweeps)
    keys = jnp.moveaxis(keys, 0, 1)
    state = state0
    if n_full:
        state, _ = jax.lax.scan(
            lambda s, inp: (launch(s, inp[0], inp[1], spl), None),
            state, (keys[:n_full], jnp.arange(n_full)))
    if rem:  # remainder launch keeps total sweeps == n_iters exactly
        state = launch(state, keys[-1], jnp.asarray(n_full), rem)
    return state


def _export_models(state: GibbsState, shards: Corpus,
                   cfg: SLDAConfig) -> SLDAModel:
    """Per-chain (φ̂, η̂, train MSE/acc) — what crosses the chain boundary."""
    lengths = jnp.maximum(shards.mask.sum(-1), 1.0)
    zb = state.ndt / lengths[..., None]
    yhat = jax.vmap(lambda z, e: z @ e)(zb, state.eta)
    mse = jax.vmap(lambda yh, yy: jnp.mean((yh - yy) ** 2))(yhat, shards.y)
    acc = jax.vmap(lambda yh, yy: jnp.mean(
        ((yh > 0.5) == (yy > 0.5)).astype(jnp.float32)))(yhat, shards.y)
    phi = jax.vmap(lambda s: phi_hat(s, cfg))(state)
    return SLDAModel(phi=phi, eta=state.eta, train_mse=mse, train_acc=acc)


def train_chains_keyed(keys: jax.Array, shards: Corpus, cfg: SLDAConfig):
    """Train M independent chains (no communication) from explicit
    per-chain keys [M] — the entry the multi-device runner uses with
    fold_in-derived keys.  shards is [M, D/M, ...].  Returns
    (GibbsState, SLDAModel), each with leading chain dim."""
    ks = jax.vmap(jax.random.split)(keys)             # [M, 2, key]
    state0 = jax.vmap(lambda k, c: init_state(k, c, cfg))(ks[:, 0], shards)
    if cfg.sweeps_per_launch > 1:
        state = _train_chains_fused(ks[:, 1], shards, state0, cfg)
    else:
        state = _train_chains_seed(ks[:, 1], shards, state0, cfg)
    return state, _export_models(state, shards, cfg)


def train_chains(key: jax.Array, shards: Corpus, cfg: SLDAConfig):
    """Train M independent chains (no communication). shards is [M, D/M, ...]."""
    m = shards.tokens.shape[0]
    _, models = train_chains_keyed(jax.random.split(key, m), shards, cfg)
    return models  # SLDAModel with leading chain dim [M, ...]


# --------------------------------------------- chain-batched prediction

def predict_chains_keyed(keys: jax.Array, models: SLDAModel, corpus: Corpus,
                         cfg: SLDAConfig) -> jnp.ndarray:
    """Every chain predicts every document of `corpus` → [M, D], from
    explicit per-chain keys [M].  One chain-batched fused pass: the
    corpus is SHARED across chains (one token tile per doc block on the
    kernel path, one folded row-op on the jnp path)."""
    from repro.kernels import ops  # local import (DESIGN.md §1)
    D = corpus.n_docs
    ks = jax.vmap(jax.random.split)(keys)             # [M, 2, key]
    z0 = jax.vmap(lambda k: jax.random.randint(
        k, corpus.tokens.shape, 0, cfg.n_topics, jnp.int32))(ks[:, 0])
    seeds = jax.vmap(lambda k: jax.random.randint(
        k, (D,), 0, jnp.iinfo(jnp.int32).max, jnp.int32))(ks[:, 1])
    d_idx = jnp.arange(D)[:, None]
    ndt0 = jax.vmap(lambda z: jnp.zeros((D, cfg.n_topics), jnp.float32)
                    .at[d_idx, z].add(corpus.mask))(z0)
    ndt_avg, _ = ops.slda_predict_sweeps(
        corpus.tokens, corpus.mask, z0, ndt0, models.phi, seeds,
        alpha=cfg.alpha, n_burnin=cfg.n_pred_burnin,
        n_samples=cfg.n_pred_samples, doc_block=cfg.pred_doc_block,
        use_pallas=cfg.use_pallas, chain_axis=True)
    zb = jax.vmap(lambda nd: nd / jnp.maximum(corpus.lengths(),
                                              1.0)[:, None])(ndt_avg)
    return jax.vmap(lambda z, e: z @ e)(zb, models.eta)   # Eq. (5) per chain


def predict_chains(key: jax.Array, models: SLDAModel, corpus: Corpus,
                   cfg: SLDAConfig) -> jnp.ndarray:
    """Every chain predicts every document of `corpus` → [M, D]."""
    m = models.eta.shape[0]
    return predict_chains_keyed(jax.random.split(key, m), models, corpus,
                                cfg)


def _concat_corpora(a: Corpus, b: Corpus) -> Corpus:
    """Stack two corpora along the doc axis (padding to a common max_len)
    so one fused prediction pass covers both."""
    n = max(a.max_len, b.max_len)
    padn = lambda x, w: jnp.pad(x, ((0, 0), (0, w))) if w else x
    return Corpus(
        tokens=jnp.concatenate([padn(a.tokens, n - a.max_len),
                                padn(b.tokens, n - b.max_len)]),
        mask=jnp.concatenate([padn(a.mask, n - a.max_len),
                              padn(b.mask, n - b.max_len)]),
        y=jnp.concatenate([a.y, b.y]))


# ---------------------------------------------------------------- algorithms

def run_nonparallel(key, train: Corpus, test: Corpus, cfg: SLDAConfig):
    k1, k2 = jax.random.split(key)
    _, model = train_chain(k1, train, cfg)
    return predict(k2, model, test, cfg)


def run_naive(key, train: Corpus, test: Corpus, cfg: SLDAConfig, m: int):
    """Naive Combination: pool sub-sampled topics, then fit + predict once."""
    k1, k2, k3 = jax.random.split(key, 3)
    shards = partition(train, m)
    keys = jax.random.split(k1, m)
    states, _ = train_chains_keyed(keys, shards, cfg)

    # step 3: treat the union of sub-samples as one global sample
    lengths = jnp.maximum(shards.mask.sum(-1), 1.0)          # [M, D/M]
    zbar_all = (states.ndt / lengths[..., None]).reshape(-1, cfg.n_topics)
    eta = solve_eta_ols(zbar_all, shards.y.reshape(-1))      # 3(a): OLS
    ntw = states.ntw.sum(0)                                  # 3(b): pooled φ
    phi = (ntw + cfg.beta) / (ntw.sum(-1, keepdims=True) + cfg.vocab_size * cfg.beta)
    model = SLDAModel(phi=phi, eta=eta,
                      train_mse=jnp.zeros(()), train_acc=jnp.zeros(()))
    return predict(k3, model, test, cfg)


def run_simple_average(key, train: Corpus, test: Corpus, cfg: SLDAConfig,
                       m: int, alive=None):
    k1, k2 = jax.random.split(key)
    models = train_chains(k1, partition(train, m), cfg)
    yhat = predict_chains(k2, models, test, cfg)             # [M, D_test]
    return combine.simple_average(yhat, alive=alive)


def run_weighted_average(key, train: Corpus, test: Corpus, cfg: SLDAConfig,
                         m: int, alive=None):
    """The weights use the *full training set* MSE/accuracy of each local
    model (Section III-C(d)) — this extra full-train prediction pass is why
    the paper reports Weighted Average as the slowest algorithm.  With
    `cfg.fuse_weighted_predict` (the default) the test and train passes
    run as ONE chain-batched fused pass over the concatenated corpus —
    same sweeps per document, half the sequential token-loop launches."""
    k1, k2, k3 = jax.random.split(key, 3)
    models = train_chains(k1, partition(train, m), cfg)
    if cfg.fuse_weighted_predict:
        both = _concat_corpora(test, train)
        yhat = predict_chains(k2, models, both, cfg)         # [M, D_te+D_tr]
        yhat_te, yhat_tr = yhat[:, :test.n_docs], yhat[:, test.n_docs:]
    else:
        yhat_te = predict_chains(k2, models, test, cfg)      # [M, D_test]
        yhat_tr = predict_chains(k3, models, train, cfg)     # [M, D_train]
    if cfg.label_type == "binary":
        acc = ((yhat_tr > 0.5) == (train.y[None, :] > 0.5)).mean(-1)
        return combine.weighted_average(yhat_te, train_acc=acc, alive=alive)
    mse = ((yhat_tr - train.y[None, :]) ** 2).mean(-1)
    return combine.weighted_average(yhat_te, train_mse=mse, alive=alive)


ALGORITHMS = {
    "nonparallel": run_nonparallel,
    "naive": run_naive,
    "simple": run_simple_average,
    "weighted": run_weighted_average,
}
