"""The four algorithms of Section IV, sharing one sampler.

  non-parallel      one chain on the full training corpus (paper benchmark 1)
  naive             M chains; pool the *sampled topics* as if drawn on the
                    full corpus, fit (η, φ) globally, predict once
                    (paper benchmark 2 — exhibits quasi-ergodicity)
  simple-average    M chains; each predicts the test set; Eq. (7) combine
  weighted-average  M chains; each predicts test AND full train set (for the
                    weights); Eq. (8)-(9) combine

Chains are CHAIN-BATCHED here (single-host form): the M independent
chains run through the `chain_axis` forms of `kernels.ops` — one fused
launch (or one folded/nested-vmap jnp op) carries all M chains instead
of replaying the single-chain path under `jax.vmap` per chain
(DESIGN.md §Chain-batched).  At `sweeps_per_launch=1` the batched EM
loop reproduces `jax.vmap(train_chain)` BIT-FOR-BIT (same threefry key
tree, same sweep op order — asserted in tests/test_chain_batched.py);
at `sweeps_per_launch>1` it is the fused multi-sweep sampler family of
DESIGN.md §Train-kernel, chain-batched.

The multi-device form — `shard_map` over the mesh's chain axis with
zero collectives until the final prediction gather, and
`chains_per_device` local chains per mesh slice riding these same
chain-batched entry points — lives in `repro.launch.slda_parallel`.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from . import combine
from .gibbs import init_state, phi_hat, train_chain
from .predict import predict
from .regression import solve_eta, solve_eta_ols
from .types import (BucketedCorpus, Corpus, GibbsState, SLDAConfig,
                    SLDAModel, _stair_segments, _take_docs,
                    _unstair_segments, apply_count_deltas, bucket_corpus,
                    counts_from_assignments)


def partition(corpus: Corpus, m: int) -> Corpus:
    """Split a corpus into M equal shards: [D, ...] → [M, D/M, ...].

    The paper partitions uniformly at random; callers should pre-shuffle.
    D must be divisible by M (pad the corpus if not).
    """
    if corpus.n_docs % m:
        raise ValueError(f"{corpus.n_docs} docs not divisible by {m} shards")
    reshape = lambda x: x.reshape((m, corpus.n_docs // m) + x.shape[1:])
    return Corpus(tokens=reshape(corpus.tokens), mask=reshape(corpus.mask),
                  y=reshape(corpus.y))


# ----------------------------------------------- chain-batched training

def _refresh_and_solve(z, ndt, state, shards, cfg, rebuild_now):
    """Exact global count refresh (rebuild or incremental deltas, both
    exact) followed by the per-chain η ridge solve — one EM boundary,
    batched over the chain axis."""
    def rebuild(_):
        return jax.vmap(lambda t, m_, zz: counts_from_assignments(
            t, m_, zz, cfg.n_topics, cfg.vocab_size))(
            shards.tokens, shards.mask, z)

    def incremental(_):
        ntw, nt = jax.vmap(apply_count_deltas)(
            state.ntw, state.nt, shards.tokens, shards.mask, state.z, z)
        return ndt, ntw, nt

    if isinstance(rebuild_now, bool):
        ndt, ntw, nt = rebuild(None) if rebuild_now else incremental(None)
    else:
        ndt, ntw, nt = jax.lax.cond(rebuild_now, rebuild, incremental, None)
    lengths = jnp.maximum(shards.mask.sum(-1), 1.0)
    eta = jax.vmap(lambda nd, l, yy: solve_eta(nd / l[:, None], yy, cfg))(
        ndt, lengths, shards.y)
    return GibbsState(z=z, ndt=ndt, ntw=ntw, nt=nt, eta=eta)


def _train_chains_seed(k_sweeps, shards, state0, cfg: SLDAConfig):
    """Chain-batched stochastic EM at sweeps_per_launch=1: per-sweep
    threefry uniforms, seed-semantics sweep, η solve every sweep —
    bit-identical to `jax.vmap(train_chain)` (the per-chain key tree and
    every op are the vmapped ones; only the sweep itself runs through
    the chain_axis op)."""
    from repro.kernels import ops  # local import: kernels are optional
    every = cfg.count_rebuild_every
    inv_len = 1.0 / jnp.maximum(shards.mask.sum(-1), 1.0)

    def em_step(state, inp):
        ks, it = inp
        uniforms = jax.vmap(
            lambda k: jax.random.uniform(k, shards.tokens.shape[1:]))(ks)
        z, ndt = ops.slda_gibbs_sweep(
            shards.tokens, shards.mask, uniforms, state.z, state.ndt,
            shards.y, inv_len, state.ntw, state.nt, state.eta,
            alpha=cfg.alpha, beta=cfg.beta, rho=cfg.rho, supervised=True,
            use_pallas=cfg.use_pallas, chain_axis=True)
        rebuild_now = (it % every == 0) if every > 0 else False
        return _refresh_and_solve(z, ndt, state, shards, cfg,
                                  rebuild_now), None

    keys = jax.vmap(lambda k: jax.random.split(k, cfg.n_iters))(k_sweeps)
    state, _ = jax.lax.scan(em_step, state0,
                            (jnp.moveaxis(keys, 0, 1),
                             jnp.arange(cfg.n_iters)))
    return state


def _train_chains_fused(k_sweeps, shards, state0, cfg: SLDAConfig):
    """Chain-batched stochastic EM via fused multi-sweep launches: ONE
    grid-(M, B) kernel launch (or one chain-batched jnp op) runs
    `sweeps_per_launch` sweeps for ALL chains; the exact global refresh
    and the η solves happen between launches (chain-batched mirror of
    `gibbs._train_chain_fused`)."""
    from repro.kernels import ops  # local import: kernels are optional
    spl = cfg.sweeps_per_launch
    every = cfg.count_rebuild_every
    d_m = shards.tokens.shape[1]
    doc_block = min(cfg.train_doc_block, -(-d_m // 8) * 8)
    inv_len = 1.0 / jnp.maximum(shards.mask.sum(-1), 1.0)

    def launch(state, ks, it, n_sweeps: int):
        seeds = jax.vmap(lambda k: jax.random.randint(
            k, (d_m,), 0, jnp.iinfo(jnp.int32).max, jnp.int32))(ks)
        z, ndt = ops.slda_train_sweeps(
            shards.tokens, shards.mask, state.z, state.ndt, shards.y,
            inv_len, state.ntw, state.nt, state.eta, seeds,
            alpha=cfg.alpha, beta=cfg.beta, rho=cfg.rho,
            n_sweeps=n_sweeps, supervised=True, doc_block=doc_block,
            use_pallas=cfg.use_pallas,
            product_form=cfg.product_form_sweeps, chain_axis=True)
        rebuild_now = (it % every == 0) if every > 0 else False
        return _refresh_and_solve(z, ndt, state, shards, cfg, rebuild_now)

    n_full, rem = divmod(cfg.n_iters, spl)
    keys = jax.vmap(lambda k: jax.random.split(
        k, n_full + (1 if rem else 0)))(k_sweeps)
    keys = jnp.moveaxis(keys, 0, 1)
    state = state0
    if n_full:
        state, _ = jax.lax.scan(
            lambda s, inp: (launch(s, inp[0], inp[1], spl), None),
            state, (keys[:n_full], jnp.arange(n_full)))
    if rem:  # remainder launch keeps total sweeps == n_iters exactly
        state = launch(state, keys[-1], jnp.asarray(n_full), rem)
    return state


def _export_models(state: GibbsState, shards, cfg: SLDAConfig) -> SLDAModel:
    """Per-chain (φ̂, η̂, train MSE/acc) — what crosses the chain boundary.
    `shards` may be a Corpus or a BucketedCorpus — both expose original-
    order lengths()/y, so the export reductions are order-identical."""
    lengths = jnp.maximum(shards.lengths(), 1.0)
    zb = state.ndt / lengths[..., None]
    yhat = jax.vmap(lambda z, e: z @ e)(zb, state.eta)
    mse = jax.vmap(lambda yh, yy: jnp.mean((yh - yy) ** 2))(yhat, shards.y)
    acc = jax.vmap(lambda yh, yy: jnp.mean(
        ((yh > 0.5) == (yy > 0.5)).astype(jnp.float32)))(yhat, shards.y)
    phi = jax.vmap(lambda s: phi_hat(s, cfg))(state)
    return SLDAModel(phi=phi, eta=state.eta, train_mse=mse, train_acc=acc)


# ------------------------------------- bucketed (ragged) chain batching

def _init_states_bucketed(keys_init, bc: BucketedCorpus, cfg: SLDAConfig):
    """vmap(init_state) over a chain-sharded bucketed schedule: the same
    per-chain [D/M, max_len] threefry draws as the padded path, carved
    along each chain's schedule.  Returns (state, z_fill); state.z is a
    tuple of per-bucket [M, D_b, N_b] arrays, state.ndt is [M, D/M, T]
    in ORIGINAL order."""
    d_m, S = bc.perm.shape[-1], bc.ctr_stride
    z_fill = jax.vmap(lambda k: jax.random.randint(
        k, (d_m, S), 0, cfg.n_topics, jnp.int32))(keys_init)
    z_b = tuple(bc.split_padded(z_fill))
    counts = lambda b, zb: jax.vmap(
        lambda t, m_, zz: counts_from_assignments(
            t, m_, zz, cfg.n_topics, cfg.vocab_size))(b.tokens, b.mask, zb)
    pieces, ntw = [], 0.0
    for b, zb in zip(bc.buckets, z_b):
        nd, nw, _ = counts(b, zb)
        pieces.append(nd)
        ntw = ntw + nw               # ±1 integer adds — exact in any order
    eta = jnp.full((keys_init.shape[0], cfg.n_topics), cfg.mu, jnp.float32)
    state = GibbsState(z=z_b, ndt=bc.merge_docs(pieces), ntw=ntw,
                       nt=jnp.sum(ntw, axis=-1), eta=eta)
    return state, z_fill


def _refresh_and_solve_bucketed(z_new_b, ndt, state, bc: BucketedCorpus,
                                cfg: SLDAConfig, rebuild_now):
    """_refresh_and_solve across buckets: exact global refresh (either
    form), then the per-chain η solve on ORIGINAL-order rows."""
    def rebuild(_):
        ntw2, pieces = 0.0, []
        for b, zb in zip(bc.buckets, z_new_b):
            nd, nw, _ = jax.vmap(
                lambda t, m_, zz: counts_from_assignments(
                    t, m_, zz, cfg.n_topics, cfg.vocab_size))(
                b.tokens, b.mask, zb)
            pieces.append(nd)
            ntw2 = ntw2 + nw
        return bc.merge_docs(pieces), ntw2, jnp.sum(ntw2, axis=-1)

    def incremental(_):
        ntw2, nt2 = state.ntw, state.nt
        for b, zo, zn in zip(bc.buckets, state.z, z_new_b):
            ntw2, nt2 = jax.vmap(apply_count_deltas)(
                ntw2, nt2, b.tokens, b.mask, zo, zn)
        return ndt, ntw2, nt2

    if isinstance(rebuild_now, bool):
        ndt, ntw, nt = rebuild(None) if rebuild_now else incremental(None)
    else:
        ndt, ntw, nt = jax.lax.cond(rebuild_now, rebuild, incremental, None)
    lengths = jnp.maximum(bc.lengths(), 1.0)
    eta = jax.vmap(lambda nd, l, yy: solve_eta(nd / l[:, None], yy, cfg))(
        ndt, lengths, bc.y)
    return GibbsState(z=tuple(z_new_b), ndt=ndt, ntw=ntw, nt=nt, eta=eta)


def _train_chains_seed_bucketed(k_sweeps, bc: BucketedCorpus, state0,
                                cfg: SLDAConfig):
    """_train_chains_seed over the bucketed schedule — per-sweep threefry
    uniforms drawn at the padded [M, D/M, max_len] shape (bit-identity)
    and sliced along each chain's schedule."""
    from repro.kernels import ops  # local import: kernels are optional
    every = cfg.count_rebuild_every
    d_m, S = bc.perm.shape[-1], bc.ctr_stride
    inv_len_b = bc.split_docs(1.0 / jnp.maximum(bc.lengths(), 1.0))

    def em_step(state, inp):
        ks, it = inp
        uniforms = jax.vmap(lambda k: jax.random.uniform(k, (d_m, S)))(ks)
        u_b = bc.split_padded(uniforms)
        ndt_b = bc.split_docs(state.ndt)
        z_new_b, pieces = [], []
        for b, ub, zb, ndb, ilb in zip(bc.buckets, u_b, state.z, ndt_b,
                                       inv_len_b):
            z2, nd2 = ops.slda_gibbs_sweep(
                b.tokens, b.mask, ub, zb, ndb, b.y, ilb, state.ntw,
                state.nt, state.eta, alpha=cfg.alpha, beta=cfg.beta,
                rho=cfg.rho, supervised=True, use_pallas=cfg.use_pallas,
                chain_axis=True)
            z_new_b.append(z2)
            pieces.append(nd2)
        rebuild_now = (it % every == 0) if every > 0 else False
        return _refresh_and_solve_bucketed(
            z_new_b, bc.merge_docs(pieces), state, bc, cfg,
            rebuild_now), None

    keys = jax.vmap(lambda k: jax.random.split(k, cfg.n_iters))(k_sweeps)
    state, _ = jax.lax.scan(em_step, state0,
                            (jnp.moveaxis(keys, 0, 1),
                             jnp.arange(cfg.n_iters)))
    return state


def _train_chains_fused_stair(k_sweeps, bc: BucketedCorpus, state0,
                              cfg: SLDAConfig):
    """The STAIRCASE fused trainer (jnp route of the ragged layer): one
    `slda_train_stair_jnp` call per EM boundary runs all in-launch
    sweeps for ALL chains — chains folded doc-major around a stacked
    [M·W, T] table, token segments walked over the live doc suffix, so
    per-sweep step count stays N_max while slots collapse to the
    staircase.  The in-launch delayed-count partition is the WHOLE
    corpus (doc_block→D limit — least delayed member of the fused
    family); state stays in bucket layout between launches, ndt/η in
    ORIGINAL order at every boundary as usual."""
    from repro.kernels.slda_train import slda_train_stair_jnp
    spl = cfg.sweeps_per_launch
    every = cfg.count_rebuild_every
    M = bc.n_chains
    d_m, S = bc.perm.shape[-1], bc.ctr_stride
    T, W = cfg.n_topics, cfg.vocab_size
    fold = lambda a: jnp.swapaxes(a, 0, 1).reshape((-1,) + a.shape[2:])
    unfold = lambda a: jnp.swapaxes(
        a.reshape((-1, M) + a.shape[1:]), 0, 1)
    sort = lambda a: _take_docs(a, bc.perm, 1)
    unsort = lambda a: _take_docs(a, bc.inv_perm, 1)

    off = (jnp.arange(M, dtype=jnp.int32) * W)[:, None, None]
    tok_segs = [fold(s + off) for s in _stair_segments(
        bc, [b.tokens for b in bc.buckets])]
    mask_segs = [fold(s) for s in _stair_segments(
        bc, [b.mask for b in bc.buckets])]
    starts = np.cumsum([0] + list(bc.counts))
    seg_r0 = [int(s) * M for s in starts[:-1]]
    seg_n0 = [0] + list(bc.widths[:-1])
    chain_of_row = jnp.tile(jnp.arange(M, dtype=jnp.int32), d_m)
    y_f = fold(jnp.concatenate([b.y for b in bc.buckets], axis=1))
    il_f = fold(jnp.concatenate(
        [1.0 / jnp.maximum(b.mask.sum(-1), 1.0) for b in bc.buckets],
        axis=1))

    def launch(state, ks, it, n_sweeps: int):
        seeds = jax.vmap(lambda k: jax.random.randint(
            k, (d_m,), 0, jnp.iinfo(jnp.int32).max, jnp.int32))(ks)
        z_segs = [fold(s) for s in _stair_segments(bc, state.z)]
        z_segs_f, ndt_f = slda_train_stair_jnp(
            tok_segs, mask_segs, z_segs, seg_r0, seg_n0,
            fold(sort(seeds)), fold(sort(state.ndt)), y_f, il_f,
            jnp.swapaxes(state.ntw, 1, 2).reshape(M * W, T), state.nt,
            state.eta, chain_of_row, alpha=cfg.alpha, beta=cfg.beta,
            rho=cfg.rho, vocab_size=W, ctr_stride=S, supervised=True,
            n_sweeps=n_sweeps, product_form=cfg.product_form_sweeps)
        z_new_b = _unstair_segments(bc, [unfold(z) for z in z_segs_f])
        ndt = unsort(unfold(ndt_f))
        rebuild_now = (it % every == 0) if every > 0 else False
        return _refresh_and_solve_bucketed(z_new_b, ndt, state, bc, cfg,
                                           rebuild_now)

    n_full, rem = divmod(cfg.n_iters, spl)
    keys = jax.vmap(lambda k: jax.random.split(
        k, n_full + (1 if rem else 0)))(k_sweeps)
    keys = jnp.moveaxis(keys, 0, 1)
    state = state0
    if n_full:
        state, _ = jax.lax.scan(
            lambda s, inp: (launch(s, inp[0], inp[1], spl), None),
            state, (keys[:n_full], jnp.arange(n_full)))
    if rem:  # remainder launch keeps total sweeps == n_iters exactly
        state = launch(state, keys[-1], jnp.asarray(n_full), rem)
    return state


def _train_chains_fused_bucketed(k_sweeps, bc: BucketedCorpus, state0,
                                 cfg: SLDAConfig):
    """_train_chains_fused over the bucketed schedule.  jnp route: the
    STAIRCASE trainer (`_train_chains_fused_stair`).  pallas route: one
    chain-batched fused launch per bucket per EM boundary, each at its
    bucket's padded width with the PRNG counter stride pinned to the
    source max_len."""
    if not cfg.use_pallas:
        return _train_chains_fused_stair(k_sweeps, bc, state0, cfg)
    from repro.kernels import ops  # local import: kernels are optional
    spl = cfg.sweeps_per_launch
    every = cfg.count_rebuild_every
    d_m, S = bc.perm.shape[-1], bc.ctr_stride
    inv_len_b = bc.split_docs(1.0 / jnp.maximum(bc.lengths(), 1.0))

    def launch(state, ks, it, n_sweeps: int):
        seeds = jax.vmap(lambda k: jax.random.randint(
            k, (d_m,), 0, jnp.iinfo(jnp.int32).max, jnp.int32))(ks)
        seeds_b = bc.split_docs(seeds)
        ndt_b = bc.split_docs(state.ndt)
        z_new_b, pieces = [], []
        for b, zb, ndb, sb, ilb in zip(bc.buckets, state.z, ndt_b,
                                       seeds_b, inv_len_b):
            db = min(cfg.train_doc_block, -(-b.tokens.shape[1] // 8) * 8)
            z2, nd2 = ops.slda_train_sweeps(
                b.tokens, b.mask, zb, ndb, b.y, ilb, state.ntw, state.nt,
                state.eta, sb, alpha=cfg.alpha, beta=cfg.beta,
                rho=cfg.rho, n_sweeps=n_sweeps, supervised=True,
                doc_block=db, use_pallas=cfg.use_pallas,
                product_form=cfg.product_form_sweeps, chain_axis=True,
                ctr_stride=S)
            z_new_b.append(z2)
            pieces.append(nd2)
        rebuild_now = (it % every == 0) if every > 0 else False
        return _refresh_and_solve_bucketed(
            z_new_b, bc.merge_docs(pieces), state, bc, cfg, rebuild_now)

    n_full, rem = divmod(cfg.n_iters, spl)
    keys = jax.vmap(lambda k: jax.random.split(
        k, n_full + (1 if rem else 0)))(k_sweeps)
    keys = jnp.moveaxis(keys, 0, 1)
    state = state0
    if n_full:
        state, _ = jax.lax.scan(
            lambda s, inp: (launch(s, inp[0], inp[1], spl), None),
            state, (keys[:n_full], jnp.arange(n_full)))
    if rem:  # remainder launch keeps total sweeps == n_iters exactly
        state = launch(state, keys[-1], jnp.asarray(n_full), rem)
    return state


def train_chains_keyed(keys: jax.Array, shards, cfg: SLDAConfig):
    """Train M independent chains (no communication) from explicit
    per-chain keys [M] — the entry the multi-device runner uses with
    fold_in-derived keys.  shards is [M, D/M, ...] — a Corpus, or a
    BucketedCorpus built from one (`bucket_corpus(partition(...))`) for
    the ragged execution layer.  Returns (GibbsState, SLDAModel), each
    with leading chain dim."""
    ks = jax.vmap(jax.random.split)(keys)             # [M, 2, key]
    if isinstance(shards, BucketedCorpus):
        state0, z_fill = _init_states_bucketed(ks[:, 0], shards, cfg)
        if cfg.sweeps_per_launch > 1:
            state = _train_chains_fused_bucketed(ks[:, 1], shards, state0,
                                                 cfg)
        else:
            state = _train_chains_seed_bucketed(ks[:, 1], shards, state0,
                                                cfg)
        models = _export_models(state, shards, cfg)
        state = GibbsState(z=shards.merge_padded(state.z, z_fill),
                           ndt=state.ndt, ntw=state.ntw, nt=state.nt,
                           eta=state.eta)
        return state, models
    state0 = jax.vmap(lambda k, c: init_state(k, c, cfg))(ks[:, 0], shards)
    if cfg.sweeps_per_launch > 1:
        state = _train_chains_fused(ks[:, 1], shards, state0, cfg)
    else:
        state = _train_chains_seed(ks[:, 1], shards, state0, cfg)
    return state, _export_models(state, shards, cfg)


def train_chains(key: jax.Array, shards, cfg: SLDAConfig):
    """Train M independent chains (no communication). shards is [M, D/M, ...]."""
    m = (shards.n_chains if isinstance(shards, BucketedCorpus)
         else shards.tokens.shape[0])
    _, models = train_chains_keyed(jax.random.split(key, m), shards, cfg)
    return models  # SLDAModel with leading chain dim [M, ...]


# --------------------------------------------- chain-batched prediction

def _predict_chains_bucketed(keys, models: SLDAModel, bc: BucketedCorpus,
                             cfg: SLDAConfig) -> jnp.ndarray:
    """predict_chains over the bucketed schedule: the STAIRCASE executor
    on the jnp route (chains folded doc-major around one stacked table),
    one chain-batched fused pass per bucket on the pallas route.  Either
    way ndt averages merge back to ORIGINAL document order —
    bit-identical per document to the padded pass
    (tests/test_ragged.py)."""
    from .predict import bucketed_predict_pallas, stair_predict
    D, S = bc.n_docs, bc.ctr_stride
    ks = jax.vmap(jax.random.split)(keys)             # [M, 2, key]
    z0 = jax.vmap(lambda k: jax.random.randint(
        k, (D, S), 0, cfg.n_topics, jnp.int32))(ks[:, 0])
    seeds = jax.vmap(lambda k: jax.random.randint(
        k, (D,), 0, jnp.iinfo(jnp.int32).max, jnp.int32))(ks[:, 1])
    run = stair_predict if not cfg.use_pallas else bucketed_predict_pallas
    ndt_avg = run(bc, models.phi, z0, seeds, cfg)     # [M, D, T] original
    lengths = jnp.maximum(bc.lengths(), 1.0)
    zb = jax.vmap(lambda nd: nd / lengths[:, None])(ndt_avg)
    return jax.vmap(lambda z, e: z @ e)(zb, models.eta)   # Eq. (5)


def predict_chains_keyed(keys: jax.Array, models: SLDAModel, corpus,
                         cfg: SLDAConfig) -> jnp.ndarray:
    """Every chain predicts every document of `corpus` → [M, D], from
    explicit per-chain keys [M].  One chain-batched fused pass: the
    corpus is SHARED across chains (one token tile per doc block on the
    kernel path, one folded row-op on the jnp path).  A `BucketedCorpus`
    routes through the ragged execution layer (one pass per bucket)."""
    from repro.kernels import ops  # local import (DESIGN.md §1)
    if isinstance(corpus, BucketedCorpus):
        return _predict_chains_bucketed(keys, models, corpus, cfg)
    D = corpus.n_docs
    ks = jax.vmap(jax.random.split)(keys)             # [M, 2, key]
    z0 = jax.vmap(lambda k: jax.random.randint(
        k, corpus.tokens.shape, 0, cfg.n_topics, jnp.int32))(ks[:, 0])
    seeds = jax.vmap(lambda k: jax.random.randint(
        k, (D,), 0, jnp.iinfo(jnp.int32).max, jnp.int32))(ks[:, 1])
    d_idx = jnp.arange(D)[:, None]
    ndt0 = jax.vmap(lambda z: jnp.zeros((D, cfg.n_topics), jnp.float32)
                    .at[d_idx, z].add(corpus.mask))(z0)
    ndt_avg, _ = ops.slda_predict_sweeps(
        corpus.tokens, corpus.mask, z0, ndt0, models.phi, seeds,
        alpha=cfg.alpha, n_burnin=cfg.n_pred_burnin,
        n_samples=cfg.n_pred_samples, doc_block=cfg.pred_doc_block,
        use_pallas=cfg.use_pallas, chain_axis=True)
    zb = jax.vmap(lambda nd: nd / jnp.maximum(corpus.lengths(),
                                              1.0)[:, None])(ndt_avg)
    return jax.vmap(lambda z, e: z @ e)(zb, models.eta)   # Eq. (5) per chain


def predict_chains(key: jax.Array, models: SLDAModel, corpus: Corpus,
                   cfg: SLDAConfig) -> jnp.ndarray:
    """Every chain predicts every document of `corpus` → [M, D]."""
    m = models.eta.shape[0]
    return predict_chains_keyed(jax.random.split(key, m), models, corpus,
                                cfg)


def _concat_corpora(a: Corpus, b: Corpus) -> Corpus:
    """Stack two corpora along the doc axis (padding to a common max_len)
    so one fused prediction pass covers both."""
    n = max(a.max_len, b.max_len)
    padn = lambda x, w: jnp.pad(x, ((0, 0), (0, w))) if w else x
    return Corpus(
        tokens=jnp.concatenate([padn(a.tokens, n - a.max_len),
                                padn(b.tokens, n - b.max_len)]),
        mask=jnp.concatenate([padn(a.mask, n - a.max_len),
                              padn(b.mask, n - b.max_len)]),
        y=jnp.concatenate([a.y, b.y]))


# ---------------------------------------------------------------- algorithms

def run_nonparallel(key, train: Corpus, test: Corpus, cfg: SLDAConfig):
    k1, k2 = jax.random.split(key)
    _, model = train_chain(k1, train, cfg)
    return predict(k2, model, test, cfg)


def run_naive(key, train: Corpus, test: Corpus, cfg: SLDAConfig, m: int):
    """Naive Combination: pool sub-sampled topics, then fit + predict once."""
    k1, k2, k3 = jax.random.split(key, 3)
    shards = partition(train, m)
    keys = jax.random.split(k1, m)
    states, _ = train_chains_keyed(keys, shards, cfg)

    # step 3: treat the union of sub-samples as one global sample
    lengths = jnp.maximum(shards.mask.sum(-1), 1.0)          # [M, D/M]
    zbar_all = (states.ndt / lengths[..., None]).reshape(-1, cfg.n_topics)
    eta = solve_eta_ols(zbar_all, shards.y.reshape(-1))      # 3(a): OLS
    ntw = states.ntw.sum(0)                                  # 3(b): pooled φ
    phi = (ntw + cfg.beta) / (ntw.sum(-1, keepdims=True) + cfg.vocab_size * cfg.beta)
    model = SLDAModel(phi=phi, eta=eta,
                      train_mse=jnp.zeros(()), train_acc=jnp.zeros(()))
    return predict(k3, model, test, cfg)


def run_simple_average(key, train: Corpus, test: Corpus, cfg: SLDAConfig,
                       m: int, alive=None):
    k1, k2 = jax.random.split(key)
    models = train_chains(k1, partition(train, m), cfg)
    yhat = predict_chains(k2, models, test, cfg)             # [M, D_test]
    return combine.simple_average(yhat, alive=alive)


def _combine_weighted(yhat_te, yhat_tr, train_y, cfg: SLDAConfig, alive):
    """Eq. (8)-(9): weight each chain's test predictions by its
    full-training-set accuracy (binary) or MSE (continuous) — the ONE
    copy of the weighting rule, shared by the padded and bucketed
    Weighted Average runners."""
    if cfg.label_type == "binary":
        acc = ((yhat_tr > 0.5) == (train_y[None, :] > 0.5)).mean(-1)
        return combine.weighted_average(yhat_te, train_acc=acc, alive=alive)
    mse = ((yhat_tr - train_y[None, :]) ** 2).mean(-1)
    return combine.weighted_average(yhat_te, train_mse=mse, alive=alive)


def run_weighted_average(key, train: Corpus, test: Corpus, cfg: SLDAConfig,
                         m: int, alive=None):
    """The weights use the *full training set* MSE/accuracy of each local
    model (Section III-C(d)) — this extra full-train prediction pass is why
    the paper reports Weighted Average as the slowest algorithm.  With
    `cfg.fuse_weighted_predict` (the default) the test and train passes
    run as ONE chain-batched fused pass over the concatenated corpus —
    same sweeps per document, half the sequential token-loop launches."""
    k1, k2, k3 = jax.random.split(key, 3)
    models = train_chains(k1, partition(train, m), cfg)
    if cfg.fuse_weighted_predict:
        both = _concat_corpora(test, train)
        yhat = predict_chains(k2, models, both, cfg)         # [M, D_te+D_tr]
        yhat_te, yhat_tr = yhat[:, :test.n_docs], yhat[:, test.n_docs:]
    else:
        yhat_te = predict_chains(k2, models, test, cfg)      # [M, D_test]
        yhat_tr = predict_chains(k3, models, train, cfg)     # [M, D_train]
    return _combine_weighted(yhat_te, yhat_tr, train.y, cfg, alive)


# --------------------------------------- bucketed (ragged) entry points
# Host-side orchestrators: the bucket schedules are built from CONCRETE
# corpora (shapes are data-dependent), then every chain phase runs
# through these module-level jits — so call them OUTSIDE jit.  At
# sweeps_per_launch=1 each is bit-identical to its padded counterpart
# (tests/test_ragged.py); the speedup comes from sweep compute scaling
# with Σ true tokens instead of D × max_len (BENCH_slda_ragged.json).

_train_chains_jit = jax.jit(train_chains, static_argnums=(2,))
_predict_chains_jit = jax.jit(predict_chains, static_argnums=(3,))


def _schedule(corpus: Corpus, cfg: SLDAConfig) -> BucketedCorpus:
    return bucket_corpus(corpus, cfg.length_buckets or 8,
                         token_block=cfg.bucket_token_block,
                         overhead_docs=cfg.bucket_overhead_docs)


def run_simple_average_bucketed(key, train: Corpus, test: Corpus,
                                cfg: SLDAConfig, m: int, alive=None):
    """run_simple_average over the ragged execution layer."""
    k1, k2 = jax.random.split(key)
    models = _train_chains_jit(k1, _schedule(partition(train, m), cfg), cfg)
    yhat = _predict_chains_jit(k2, models, _schedule(test, cfg), cfg)
    return combine.simple_average(yhat, alive=alive)


def run_weighted_average_bucketed(key, train: Corpus, test: Corpus,
                                  cfg: SLDAConfig, m: int, alive=None):
    """run_weighted_average over the ragged execution layer — the
    paper's slowest algorithm, and the one with the most padded-slot
    waste to reclaim (its dominant cost re-sweeps the test set PLUS the
    full training set once per chain)."""
    k1, k2, k3 = jax.random.split(key, 3)
    models = _train_chains_jit(k1, _schedule(partition(train, m), cfg), cfg)
    if cfg.fuse_weighted_predict:
        both = _concat_corpora(test, train)
        yhat = _predict_chains_jit(k2, models, _schedule(both, cfg), cfg)
        yhat_te, yhat_tr = yhat[:, :test.n_docs], yhat[:, test.n_docs:]
    else:
        yhat_te = _predict_chains_jit(k2, models, _schedule(test, cfg), cfg)
        yhat_tr = _predict_chains_jit(k3, models, _schedule(train, cfg),
                                      cfg)
    return _combine_weighted(yhat_te, yhat_tr, train.y, cfg, alive)


ALGORITHMS = {
    "nonparallel": run_nonparallel,
    "naive": run_naive,
    "simple": run_simple_average,
    "weighted": run_weighted_average,
}
