"""The four algorithms of Section IV, sharing one sampler.

  non-parallel      one chain on the full training corpus (paper benchmark 1)
  naive             M chains; pool the *sampled topics* as if drawn on the
                    full corpus, fit (η, φ) globally, predict once
                    (paper benchmark 2 — exhibits quasi-ergodicity)
  simple-average    M chains; each predicts the test set; Eq. (7) combine
  weighted-average  M chains; each predicts test AND full train set (for the
                    weights); Eq. (8)-(9) combine

Chains are mapped with `vmap` here (single-host form).  The multi-device
form — `shard_map` over the mesh's chain axis with zero collectives until
the final prediction gather — lives in `repro.launch.slda_parallel` and
reuses these same per-chain functions unchanged.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import combine
from .gibbs import train_chain
from .predict import predict
from .regression import solve_eta_ols
from .types import Corpus, SLDAConfig, SLDAModel


def partition(corpus: Corpus, m: int) -> Corpus:
    """Split a corpus into M equal shards: [D, ...] → [M, D/M, ...].

    The paper partitions uniformly at random; callers should pre-shuffle.
    D must be divisible by M (pad the corpus if not).
    """
    if corpus.n_docs % m:
        raise ValueError(f"{corpus.n_docs} docs not divisible by {m} shards")
    reshape = lambda x: x.reshape((m, corpus.n_docs // m) + x.shape[1:])
    return Corpus(tokens=reshape(corpus.tokens), mask=reshape(corpus.mask),
                  y=reshape(corpus.y))


def train_chains(key: jax.Array, shards: Corpus, cfg: SLDAConfig):
    """Train M independent chains (no communication). shards is [M, D/M, ...]."""
    m = shards.tokens.shape[0]
    keys = jax.random.split(key, m)
    _, models = jax.vmap(train_chain, in_axes=(0, 0, None))(keys, shards, cfg)
    return models  # SLDAModel with leading chain dim [M, ...]


def predict_chains(key: jax.Array, models: SLDAModel, corpus: Corpus,
                   cfg: SLDAConfig) -> jnp.ndarray:
    """Every chain predicts every document of `corpus` → [M, D]."""
    m = models.eta.shape[0]
    keys = jax.random.split(key, m)
    return jax.vmap(predict, in_axes=(0, 0, None, None))(keys, models, corpus, cfg)


# ---------------------------------------------------------------- algorithms

def run_nonparallel(key, train: Corpus, test: Corpus, cfg: SLDAConfig):
    k1, k2 = jax.random.split(key)
    _, model = train_chain(k1, train, cfg)
    return predict(k2, model, test, cfg)


def run_naive(key, train: Corpus, test: Corpus, cfg: SLDAConfig, m: int):
    """Naive Combination: pool sub-sampled topics, then fit + predict once."""
    k1, k2, k3 = jax.random.split(key, 3)
    shards = partition(train, m)
    keys = jax.random.split(k1, m)
    states, _ = jax.vmap(train_chain, in_axes=(0, 0, None))(keys, shards, cfg)

    # step 3: treat the union of sub-samples as one global sample
    lengths = jnp.maximum(shards.mask.sum(-1), 1.0)          # [M, D/M]
    zbar_all = (states.ndt / lengths[..., None]).reshape(-1, cfg.n_topics)
    eta = solve_eta_ols(zbar_all, shards.y.reshape(-1))      # 3(a): OLS
    ntw = states.ntw.sum(0)                                  # 3(b): pooled φ
    phi = (ntw + cfg.beta) / (ntw.sum(-1, keepdims=True) + cfg.vocab_size * cfg.beta)
    model = SLDAModel(phi=phi, eta=eta,
                      train_mse=jnp.zeros(()), train_acc=jnp.zeros(()))
    return predict(k3, model, test, cfg)


def run_simple_average(key, train: Corpus, test: Corpus, cfg: SLDAConfig,
                       m: int, alive=None):
    k1, k2 = jax.random.split(key)
    models = train_chains(k1, partition(train, m), cfg)
    yhat = predict_chains(k2, models, test, cfg)             # [M, D_test]
    return combine.simple_average(yhat, alive=alive)


def run_weighted_average(key, train: Corpus, test: Corpus, cfg: SLDAConfig,
                         m: int, alive=None):
    """The weights use the *full training set* MSE/accuracy of each local
    model (Section III-C(d)) — this extra full-train prediction pass is why
    the paper reports Weighted Average as the slowest algorithm."""
    k1, k2, k3 = jax.random.split(key, 3)
    models = train_chains(k1, partition(train, m), cfg)
    yhat_te = predict_chains(k2, models, test, cfg)          # [M, D_test]
    yhat_tr = predict_chains(k3, models, train, cfg)         # [M, D_train]
    if cfg.label_type == "binary":
        acc = ((yhat_tr > 0.5) == (train.y[None, :] > 0.5)).mean(-1)
        return combine.weighted_average(yhat_te, train_acc=acc, alive=alive)
    mse = ((yhat_tr - train.y[None, :]) ** 2).mean(-1)
    return combine.weighted_average(yhat_te, train_mse=mse, alive=alive)


ALGORITHMS = {
    "nonparallel": run_nonparallel,
    "naive": run_naive,
    "simple": run_simple_average,
    "weighted": run_weighted_average,
}
