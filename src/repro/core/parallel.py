"""The four algorithms of Section IV, sharing ONE plan-driven sampler.

  non-parallel      one chain on the full training corpus (paper benchmark 1)
  naive             M chains; pool the *sampled topics* as if drawn on the
                    full corpus, fit (η, φ) globally, predict once
                    (paper benchmark 2 — exhibits quasi-ergodicity)
  simple-average    M chains; each predicts the test set; Eq. (7) combine
  weighted-average  M chains; each predicts test AND full train set (for the
                    weights); Eq. (8)-(9) combine

Every entry point here is a thin wrapper over the unified execution
plan (`core.plan`, DESIGN.md §Execution-plan): `build_schedule` decides
the data layout (padded = the degenerate 1-bucket schedule; length
bucketing when `cfg.length_buckets > 0` — built host-side, outside
jit), and `ExecutionPlan` owns the routing (executor, chain batching,
sweeps-per-launch schedule, refresh cadence).  The EM loop exists
exactly once, in `plan.py`; there are no per-layout twins left.

At `sweeps_per_launch=1` the chain-batched loop reproduces the seed
semantics BIT-FOR-BIT for every (layout × backend × M) cell
(tests/test_dispatch_matrix.py); at `>1` it is the fused multi-sweep
sampler family of DESIGN.md §Train-kernel.

The multi-device form — `shard_map` over the mesh's chain axis with
zero collectives until the final prediction gather, and
`chains_per_device` local chains per mesh slice riding these same
entry points (one plan built per shard) — lives in
`repro.launch.slda_parallel`.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import combine
from .gibbs import train_chain
from .plan import build_plan, build_schedule
from .predict import predict
from .regression import solve_eta_ols
from .types import (BucketedCorpus, Corpus, SLDAConfig, SLDAModel,
                    _concat_corpora, partition)


# ----------------------------------------------- chain-batched training

def train_chains_keyed(keys: jax.Array, shards, cfg: SLDAConfig):
    """Train M independent chains (no communication) from explicit
    per-chain keys [M] — the entry the multi-device runner uses with
    fold_in-derived keys.  shards is [M, D/M, ...] — a Corpus, or a
    BucketedCorpus built from one (`build_schedule(partition(...))`)
    for the ragged execution layer.  Returns (GibbsState, SLDAModel),
    each with leading chain dim."""
    return build_plan(shards, cfg).train(keys)


def train_chains(key: jax.Array, shards, cfg: SLDAConfig):
    """Train M independent chains (no communication). shards is [M, D/M, ...]."""
    m = (shards.n_chains if isinstance(shards, BucketedCorpus)
         else shards.tokens.shape[0])
    _, models = train_chains_keyed(jax.random.split(key, m), shards, cfg)
    return models  # SLDAModel with leading chain dim [M, ...]


# --------------------------------------------- chain-batched prediction

def predict_chains_keyed(keys: jax.Array, models: SLDAModel, corpus,
                         cfg: SLDAConfig) -> jnp.ndarray:
    """Every chain predicts every document of `corpus` → [M, D], from
    explicit per-chain keys [M].  The corpus is SHARED across chains
    (one token tile per doc block on the kernel path, one folded
    row-op on the jnp path); a `BucketedCorpus` routes through the
    ragged execution layer."""
    return build_plan(corpus, cfg).predict(keys, models)


def predict_chains(key: jax.Array, models: SLDAModel, corpus,
                   cfg: SLDAConfig) -> jnp.ndarray:
    """Every chain predicts every document of `corpus` → [M, D]."""
    m = models.eta.shape[0]
    return predict_chains_keyed(jax.random.split(key, m), models, corpus,
                                cfg)


# ---------------------------------------------------------------- algorithms
# Host-side orchestrators: schedules are built from CONCRETE corpora
# when cfg.length_buckets > 0 (shapes are data-dependent — call the
# orchestrators OUTSIDE jit then), while the padded degenerate schedule
# is shape-only, so with length_buckets == 0 each orchestrator stays
# fully jit-able.  The chain phases run through these module-level jits
# either way; at sweeps_per_launch=1 the bucketed run is bit-identical
# to the padded one (tests/test_dispatch_matrix.py) and the speedup
# comes from sweep compute scaling with Σ true tokens
# (BENCH_slda_ragged.json).

_train_chain_jit = jax.jit(train_chain, static_argnums=(2,))
_train_chains_jit = jax.jit(train_chains, static_argnums=(2,))
_train_chains_keyed_jit = jax.jit(train_chains_keyed, static_argnums=(2,))
_predict_chains_jit = jax.jit(predict_chains, static_argnums=(3,))
_predict_jit = jax.jit(predict, static_argnums=(3,))


def run_nonparallel(key, train: Corpus, test: Corpus, cfg: SLDAConfig):
    k1, k2 = jax.random.split(key)
    _, model = _train_chain_jit(k1, build_schedule(train, cfg), cfg)
    return _predict_jit(k2, model, build_schedule(test, cfg), cfg)


def run_naive(key, train: Corpus, test: Corpus, cfg: SLDAConfig, m: int):
    """Naive Combination: pool sub-sampled topics, then fit + predict once."""
    k1, k2, k3 = jax.random.split(key, 3)
    shards = build_schedule(partition(train, m), cfg)
    keys = jax.random.split(k1, m)
    states, _ = _train_chains_keyed_jit(keys, shards, cfg)

    # step 3: treat the union of sub-samples as one global sample
    lengths = jnp.maximum(shards.lengths(), 1.0)             # [M, D/M]
    zbar_all = (states.ndt / lengths[..., None]).reshape(-1, cfg.n_topics)
    eta = solve_eta_ols(zbar_all, shards.y.reshape(-1))      # 3(a): OLS
    ntw = states.ntw.sum(0)                                  # 3(b): pooled φ
    phi = (ntw + cfg.beta) / (ntw.sum(-1, keepdims=True) + cfg.vocab_size * cfg.beta)
    model = SLDAModel(phi=phi, eta=eta,
                      train_mse=jnp.zeros(()), train_acc=jnp.zeros(()))
    return _predict_jit(k3, model, build_schedule(test, cfg), cfg)


def run_simple_average(key, train: Corpus, test: Corpus, cfg: SLDAConfig,
                       m: int, alive=None):
    k1, k2 = jax.random.split(key)
    models = _train_chains_jit(k1, build_schedule(partition(train, m), cfg),
                               cfg)
    yhat = _predict_chains_jit(k2, models, build_schedule(test, cfg), cfg)
    return combine.simple_average(yhat, alive=alive)


def _combine_weighted(yhat_te, yhat_tr, train_y, cfg: SLDAConfig, alive):
    """Eq. (8)-(9): weight each chain's test predictions by its
    full-training-set accuracy (binary) or MSE (continuous) — the ONE
    copy of the weighting rule."""
    if cfg.label_type == "binary":
        acc = ((yhat_tr > 0.5) == (train_y[None, :] > 0.5)).mean(-1)
        return combine.weighted_average(yhat_te, train_acc=acc, alive=alive)
    mse = ((yhat_tr - train_y[None, :]) ** 2).mean(-1)
    return combine.weighted_average(yhat_te, train_mse=mse, alive=alive)


def run_weighted_average(key, train: Corpus, test: Corpus, cfg: SLDAConfig,
                         m: int, alive=None):
    """The weights use the *full training set* MSE/accuracy of each local
    model (Section III-C(d)) — this extra full-train prediction pass is why
    the paper reports Weighted Average as the slowest algorithm.  With
    `cfg.fuse_weighted_predict` (the default) the test and train passes
    run as ONE chain-batched fused pass over the concatenated corpus —
    same sweeps per document, half the sequential token-loop launches."""
    k1, k2, k3 = jax.random.split(key, 3)
    models = _train_chains_jit(k1, build_schedule(partition(train, m), cfg),
                               cfg)
    if cfg.fuse_weighted_predict:
        both = _concat_corpora(test, train)
        yhat = _predict_chains_jit(k2, models, build_schedule(both, cfg),
                                   cfg)
        yhat_te, yhat_tr = yhat[:, :test.n_docs], yhat[:, test.n_docs:]
    else:
        yhat_te = _predict_chains_jit(k2, models,
                                      build_schedule(test, cfg), cfg)
        yhat_tr = _predict_chains_jit(k3, models,
                                      build_schedule(train, cfg), cfg)
    return _combine_weighted(yhat_te, yhat_tr, train.y, cfg, alive)


ALGORITHMS = {
    "nonparallel": run_nonparallel,
    "naive": run_naive,
    "simple": run_simple_average,
    "weighted": run_weighted_average,
}
