"""Core datatypes for sLDA and its embarrassingly parallel runner.

Everything is a registered pytree so it can flow through jit / vmap /
shard_map without ceremony.  Counts are kept in float32: they are small
integers in practice and float math keeps the samplers branch-free.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

Array = Any


def _pytree(cls):
    """Register a dataclass as a pytree (all fields are children)."""
    fields = [f.name for f in dataclasses.fields(cls)]
    jax.tree_util.register_pytree_with_keys(
        cls,
        lambda obj: (
            [(jax.tree_util.GetAttrKey(n), getattr(obj, n)) for n in fields],
            None,
        ),
        lambda _, children: cls(*children),
    )
    return cls


@dataclasses.dataclass(frozen=True)
class SLDAConfig:
    """Hyperparameters of supervised LDA (McAuliffe & Blei 2008 notation)."""

    n_topics: int = 32
    vocab_size: int = 1024
    alpha: float = 0.1       # Dir prior on doc-topic θ_d
    beta: float = 0.01       # Dir prior on topic-word φ_t
    rho: float = 0.5         # response noise  y_d ~ N(ηᵀ z̄_d, ρ)
    mu: float = 0.0          # prior mean of η_t
    sigma: float = 10.0      # prior variance of η_t
    label_type: str = "continuous"   # "continuous" | "binary"
    n_iters: int = 60        # stochastic-EM iterations (Gibbs sweep + η solve)
    n_pred_burnin: int = 15  # test-time Gibbs burn-in sweeps
    n_pred_samples: int = 10 # test-time sweeps averaged for z̄
    use_pallas: bool = False # route sweeps through the slda TPU kernels
    pred_doc_block: int = 8  # doc block of the fused prediction kernel
    count_rebuild_every: int = 16  # exact ntw/nt rebuild cadence during
                             # training: iterations in between apply exact
                             # (z_old, z_new) delta updates instead of the
                             # full scatter; the periodic rebuild bounds
                             # float32 accumulation drift.  0 = never
                             # rebuild, 1 = rebuild every sweep (seed
                             # behaviour).


@_pytree
@dataclasses.dataclass
class Corpus:
    """A padded bag of documents.

    tokens  : int32[D, N]  word ids, padding value arbitrary where mask==0
    mask    : float32[D, N] 1.0 on real tokens
    y       : float32[D]   document labels (binary labels stored as 0/1)
    """

    tokens: Array
    mask: Array
    y: Array

    @property
    def n_docs(self) -> int:
        return self.tokens.shape[0]

    @property
    def max_len(self) -> int:
        return self.tokens.shape[1]

    def lengths(self) -> Array:
        return jnp.sum(self.mask, axis=-1)


@_pytree
@dataclasses.dataclass
class GibbsState:
    """Mutable state of one collapsed-Gibbs sLDA chain."""

    z: Array       # int32[D, N]   token-topic assignments
    ndt: Array     # float32[D, T] doc-topic counts
    ntw: Array     # float32[T, W] topic-word counts
    nt: Array      # float32[T]    topic totals
    eta: Array     # float32[T]    regression weights


@_pytree
@dataclasses.dataclass
class SLDAModel:
    """What a trained chain exports: enough to predict, nothing more.

    This is the only thing that ever crosses a chain boundary — it is what
    makes the parallel algorithm communication-free during training.
    """

    phi: Array     # float32[T, W] topic-word distributions  φ̂
    eta: Array     # float32[T]    regression weights        η̂
    train_mse: Array   # float32[] training-set MSE (Weighted Average weight)
    train_acc: Array   # float32[] training-set accuracy (binary labels)


def counts_from_assignments(tokens: Array, mask: Array, z: Array,
                            n_topics: int, vocab_size: int):
    """Exact (ndt, ntw, nt) from the current assignments. Used to refresh the
    delayed topic-word table between document-parallel sweeps."""
    d_idx = jnp.arange(tokens.shape[0])[:, None]
    ndt = jnp.zeros((tokens.shape[0], n_topics), jnp.float32)
    ndt = ndt.at[d_idx, z].add(mask)
    ntw = jnp.zeros((n_topics, vocab_size), jnp.float32)
    ntw = ntw.at[z, tokens].add(mask)
    return ndt, ntw, jnp.sum(ntw, axis=-1)


def apply_count_deltas(ntw: Array, nt: Array, tokens: Array, mask: Array,
                       z_old: Array, z_new: Array):
    """Exact incremental (ntw, nt) refresh from one sweep's reassignments.

    Only tokens whose topic actually changed carry weight, so the scatter
    moves ±1 for the (typically small, late in sampling) changed set and
    leaves everything else untouched — the delta form of the AD-LDA count
    refresh (cf. Magnusson et al., sparse partially collapsed samplers).
    Counts stay exact: ±1.0 float32 updates are lossless below 2^24, and
    `SLDAConfig.count_rebuild_every` bounds drift beyond that.
    """
    changed = mask * (z_new != z_old).astype(mask.dtype)
    ntw = ntw.at[z_old, tokens].add(-changed).at[z_new, tokens].add(changed)
    nt = (nt + jnp.zeros_like(nt).at[z_new].add(changed)
          - jnp.zeros_like(nt).at[z_old].add(changed))
    return ntw, nt
