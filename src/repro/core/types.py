"""Core datatypes for sLDA and its embarrassingly parallel runner.

Everything is a registered pytree so it can flow through jit / vmap /
shard_map without ceremony.  Counts are kept in float32: they are small
integers in practice and float math keeps the samplers branch-free.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

Array = Any


def _pytree(cls):
    """Register a dataclass as a pytree (all fields are children)."""
    fields = [f.name for f in dataclasses.fields(cls)]
    jax.tree_util.register_pytree_with_keys(
        cls,
        lambda obj: (
            [(jax.tree_util.GetAttrKey(n), getattr(obj, n)) for n in fields],
            None,
        ),
        lambda _, children: cls(*children),
    )
    return cls


@dataclasses.dataclass(frozen=True)
class SLDAConfig:
    """Hyperparameters of supervised LDA (McAuliffe & Blei 2008 notation)."""

    n_topics: int = 32
    vocab_size: int = 1024
    alpha: float = 0.1       # Dir prior on doc-topic θ_d
    beta: float = 0.01       # Dir prior on topic-word φ_t
    rho: float = 0.5         # response noise  y_d ~ N(ηᵀ z̄_d, ρ)
    mu: float = 0.0          # prior mean of η_t
    sigma: float = 10.0      # prior variance of η_t
    label_type: str = "continuous"   # "continuous" | "binary"
    n_iters: int = 60        # stochastic-EM iterations (Gibbs sweep + η solve)
    n_pred_burnin: int = 15  # test-time Gibbs burn-in sweeps
    n_pred_samples: int = 10 # test-time sweeps averaged for z̄
    use_pallas: bool = False # route sweeps through the slda TPU kernels
    pred_doc_block: int = 8  # doc block of the fused prediction kernel
    count_rebuild_every: int = 16  # exact ntw/nt rebuild cadence during
                             # training: iterations in between apply exact
                             # (z_old, z_new) delta updates instead of the
                             # full scatter; the periodic rebuild bounds
                             # float32 accumulation drift.  0 = never
                             # rebuild, 1 = rebuild every sweep (seed
                             # behaviour).  Cadence counts LAUNCHES when
                             # sweeps_per_launch > 1.  Either refresh form
                             # is exact, so this knob is perf-only
                             # (BENCH_slda_train.json records the sweep).
    sweeps_per_launch: int = 1  # training Gibbs sweeps fused into one
                             # kernel launch / scan body.  1 = seed
                             # semantics (threefry uniforms, η solve every
                             # sweep, globally sweep-frozen counts).  >1
                             # routes train_chain through the fused
                             # kernels/slda_train.py path: counter-hash
                             # PRNG, η solve between launches, and the
                             # AD-LDA block-local delayed-count refresh
                             # between in-launch sweeps (DESIGN.md
                             # §Train-kernel; tuned value in
                             # BENCH_slda_train.json).
    train_doc_block: int = 128  # doc block of the fused train kernel —
                             # also the delayed-count granularity
                             # (semantics, not just tiling, when
                             # sweeps_per_launch>1).  Bigger blocks are
                             # faster on CPU (fewer vmap lanes) AND less
                             # delayed (fewer blocks to defer across);
                             # train_chain clamps it to the corpus size.
    product_form_sweeps: bool = True  # fused multi-sweep launches
                             # (sweeps_per_launch > 1) sample the
                             # categorical from the plain product of
                             # positives times ONE Gaussian exp instead
                             # of three logs — same distribution, ~3x
                             # fewer transcendentals per token (the way
                             # the predict kernel already samples).
                             # Never applies at sweeps_per_launch=1,
                             # which keeps the seed log-form bits
                             # (DESIGN.md §Chain-batched).
    fuse_weighted_predict: bool = True  # Weighted Average predicts the
                             # test set and the full training set in ONE
                             # chain-batched fused pass over the
                             # concatenated corpus instead of two
                             # launches — same sweeps per document,
                             # half the sequential token-loop steps
                             # (the M x prediction pass is the paper's
                             # stated dominant cost).
    chains_per_device: int = 1  # launch-level knob: the shard_map
                             # runner trains chains_per_device chains
                             # per mesh slice through the chain-batched
                             # ops, so M = mesh axis x chains_per_device
                             # decouples the paper's M from the device
                             # count (still zero collectives until the
                             # final prediction gather).


@_pytree
@dataclasses.dataclass
class Corpus:
    """A padded bag of documents.

    tokens  : int32[D, N]  word ids, padding value arbitrary where mask==0
    mask    : float32[D, N] 1.0 on real tokens
    y       : float32[D]   document labels (binary labels stored as 0/1)
    """

    tokens: Array
    mask: Array
    y: Array

    @property
    def n_docs(self) -> int:
        return self.tokens.shape[0]

    @property
    def max_len(self) -> int:
        return self.tokens.shape[1]

    def lengths(self) -> Array:
        return jnp.sum(self.mask, axis=-1)


@_pytree
@dataclasses.dataclass
class GibbsState:
    """Mutable state of one collapsed-Gibbs sLDA chain."""

    z: Array       # int32[D, N]   token-topic assignments
    ndt: Array     # float32[D, T] doc-topic counts
    ntw: Array     # float32[T, W] topic-word counts
    nt: Array      # float32[T]    topic totals
    eta: Array     # float32[T]    regression weights


@_pytree
@dataclasses.dataclass
class SLDAModel:
    """What a trained chain exports: enough to predict, nothing more.

    This is the only thing that ever crosses a chain boundary — it is what
    makes the parallel algorithm communication-free during training.
    """

    phi: Array     # float32[T, W] topic-word distributions  φ̂
    eta: Array     # float32[T]    regression weights        η̂
    train_mse: Array   # float32[] training-set MSE (Weighted Average weight)
    train_acc: Array   # float32[] training-set accuracy (binary labels)


def counts_from_assignments(tokens: Array, mask: Array, z: Array,
                            n_topics: int, vocab_size: int):
    """Exact (ndt, ntw, nt) from the current assignments. Used to refresh the
    delayed topic-word table between document-parallel sweeps."""
    d_idx = jnp.arange(tokens.shape[0])[:, None]
    ndt = jnp.zeros((tokens.shape[0], n_topics), jnp.float32)
    ndt = ndt.at[d_idx, z].add(mask)
    ntw = jnp.zeros((n_topics, vocab_size), jnp.float32)
    ntw = ntw.at[z, tokens].add(mask)
    return ndt, ntw, jnp.sum(ntw, axis=-1)


def apply_count_deltas(ntw: Array, nt: Array, tokens: Array, mask: Array,
                       z_old: Array, z_new: Array, cap: int | None = None):
    """Exact incremental (ntw, nt) refresh from one sweep's reassignments.

    Only tokens whose topic actually changed carry weight (typically few,
    late in sampling — Magnusson et al., sparse partially collapsed
    samplers), so the scatter is issued in **changed-token compaction**
    form: gather the positions where `z_old != z_new` into a static-width
    buffer of `cap` slots and scatter only those ±1 updates, instead of a
    dense [D·N]-index 2-scatter that is mostly zero-weight no-ops.  If a
    sweep reassigns more than `cap` tokens (early sweeps), a `lax.cond`
    falls back to the dense form — exactness never depends on the cap.

    cap=None picks the backend's measured winner: max(128, D·N/8) slots
    where scatter cost scales with the index count (TPU/GPU), the dense
    form on CPU — on XLA:CPU the nonzero+gather overhead makes the
    compacted branch ~3× a dense scatter even at 5 % change
    (DESIGN.md §Train-kernel).  Pass `cap=0` to force dense, or an
    explicit slot count to force compaction.  Counts stay exact either
    way: ±1.0 float32 updates are lossless below 2^24, and
    `SLDAConfig.count_rebuild_every` bounds drift beyond that.
    """
    changed = mask * (z_new != z_old).astype(mask.dtype)
    flat = changed.ravel()
    total = flat.shape[0]
    if cap is None:
        cap = 0 if jax.default_backend() == "cpu" else max(128, total // 8)
    cap = int(min(cap, total))

    def dense(_):
        ntw2 = (ntw.at[z_old, tokens].add(-changed)
                .at[z_new, tokens].add(changed))
        nt2 = (nt + jnp.zeros_like(nt).at[z_new].add(changed)
               - jnp.zeros_like(nt).at[z_old].add(changed))
        return ntw2, nt2

    if cap <= 0 or cap >= total:
        return dense(None)

    n_changed = jnp.sum(flat > 0)
    w_all, zo_all, zn_all = tokens.ravel(), z_old.ravel(), z_new.ravel()

    def sparse(_):
        idx = jnp.nonzero(flat > 0, size=cap, fill_value=0)[0]
        wt = (jnp.arange(cap) < n_changed).astype(ntw.dtype)
        w, zo, zn = w_all[idx], zo_all[idx], zn_all[idx]
        ntw2 = ntw.at[zo, w].add(-wt).at[zn, w].add(wt)
        nt2 = (nt + jnp.zeros_like(nt).at[zn].add(wt)
               - jnp.zeros_like(nt).at[zo].add(wt))
        return ntw2, nt2

    return jax.lax.cond(n_changed <= cap, sparse, dense, None)
