"""Core datatypes for sLDA and its embarrassingly parallel runner.

Everything is a registered pytree so it can flow through jit / vmap /
shard_map without ceremony.  Counts are kept in float32: they are small
integers in practice and float math keeps the samplers branch-free.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

Array = Any


def _pytree(cls):
    """Register a dataclass as a pytree (all fields are children)."""
    fields = [f.name for f in dataclasses.fields(cls)]
    jax.tree_util.register_pytree_with_keys(
        cls,
        lambda obj: (
            [(jax.tree_util.GetAttrKey(n), getattr(obj, n)) for n in fields],
            None,
        ),
        lambda _, children: cls(*children),
    )
    return cls


@dataclasses.dataclass(frozen=True)
class SLDAConfig:
    """Hyperparameters of supervised LDA (McAuliffe & Blei 2008 notation)."""

    n_topics: int = 32
    vocab_size: int = 1024
    alpha: float = 0.1       # Dir prior on doc-topic θ_d
    beta: float = 0.01       # Dir prior on topic-word φ_t
    rho: float = 0.5         # response noise  y_d ~ N(ηᵀ z̄_d, ρ)
    mu: float = 0.0          # prior mean of η_t
    sigma: float = 10.0      # prior variance of η_t
    label_type: str = "continuous"   # "continuous" | "binary"
    n_iters: int = 60        # stochastic-EM iterations (Gibbs sweep + η solve)
    n_pred_burnin: int = 15  # test-time Gibbs burn-in sweeps
    n_pred_samples: int = 10 # test-time sweeps averaged for z̄
    use_pallas: bool = False # route sweeps through the slda TPU kernels
    pred_doc_block: int = 8  # doc block of the fused prediction kernel
    count_rebuild_every: int = 16  # exact ntw/nt rebuild cadence during
                             # training: iterations in between apply exact
                             # (z_old, z_new) delta updates instead of the
                             # full scatter; the periodic rebuild bounds
                             # float32 accumulation drift.  0 = never
                             # rebuild, 1 = rebuild every sweep (seed
                             # behaviour).  Cadence counts LAUNCHES when
                             # sweeps_per_launch > 1.  Either refresh form
                             # is exact, so this knob is perf-only
                             # (BENCH_slda_train.json records the sweep).
    sweeps_per_launch: int = 1  # training Gibbs sweeps fused into one
                             # kernel launch / scan body.  1 = seed
                             # semantics (threefry uniforms, η solve every
                             # sweep, globally sweep-frozen counts).  >1
                             # routes train_chain through the fused
                             # kernels/slda_train.py path: counter-hash
                             # PRNG, η solve between launches, and the
                             # AD-LDA block-local delayed-count refresh
                             # between in-launch sweeps (DESIGN.md
                             # §Train-kernel; tuned value in
                             # BENCH_slda_train.json).
    train_doc_block: int = 128  # doc block of the fused train kernel —
                             # also the delayed-count granularity
                             # (semantics, not just tiling, when
                             # sweeps_per_launch>1).  Bigger blocks are
                             # faster on CPU (fewer vmap lanes) AND less
                             # delayed (fewer blocks to defer across);
                             # train_chain clamps it to the corpus size.
    product_form_sweeps: bool = True  # fused multi-sweep launches
                             # (sweeps_per_launch > 1) sample the
                             # categorical from the plain product of
                             # positives times ONE Gaussian exp instead
                             # of three logs — same distribution, ~3x
                             # fewer transcendentals per token (the way
                             # the predict kernel already samples).
                             # Never applies at sweeps_per_launch=1,
                             # which keeps the seed log-form bits
                             # (DESIGN.md §Chain-batched).
    fuse_weighted_predict: bool = True  # Weighted Average predicts the
                             # test set and the full training set in ONE
                             # chain-batched fused pass over the
                             # concatenated corpus instead of two
                             # launches — same sweeps per document,
                             # half the sequential token-loop steps
                             # (the M x prediction pass is the paper's
                             # stated dominant cost).
    length_buckets: int = 0  # ragged-corpus execution (DESIGN.md
                             # §Ragged-execution): number of length
                             # buckets the bucketed entry points
                             # (`bucket_corpus`, the *_bucketed runners,
                             # launch/slda_parallel) split a corpus
                             # into, each padded to its own token-block-
                             # rounded max instead of the global max, so
                             # sweep compute scales with Σ true tokens.
                             # 0 keeps the padded path.  Schedules are
                             # built from concrete lengths (outside
                             # jit); the padded core paths ignore this
                             # knob.  Bit-identical per document to the
                             # padded path at sweeps_per_launch=1.
    bucket_token_block: int = 8  # bucket widths round up to this many
                             # tokens (sublane-friendly; smaller = less
                             # intra-bucket padding, more distinct
                             # widths to compile)
    bucket_overhead_docs: float = 0.0  # per-bucket fixed cost, in
                             # document rows, fed to the schedule DP
                             # (`bucket_corpus`).  The jnp-route STAIR
                             # executors walk the bucket widths as
                             # token-range segments inside each sweep
                             # (step count stays N_max), so extra
                             # buckets are nearly free there — measured
                             # best at 0 (BENCH_slda_ragged.json;
                             # `length_buckets` still caps the count).
                             # The per-bucket launch route (pallas)
                             # re-runs its token loop per bucket, where
                             # a step costs ~a hundred folded doc rows
                             # on CPU — raise this knob if that route
                             # is the hot one.  0 minimizes padded
                             # slots alone.
    chains_per_device: int = 1  # launch-level knob: the shard_map
                             # runner trains chains_per_device chains
                             # per mesh slice through the chain-batched
                             # ops, so M = mesh axis x chains_per_device
                             # decouples the paper's M from the device
                             # count (still zero collectives until the
                             # final prediction gather).
    sampler_mode: str = "dense"  # per-token categorical draw strategy
                             # (DESIGN.md §Sparse-sampler): "dense" —
                             # the seed draw, O(T²) matmul prefix sum
                             # per token, bit-identical to every prior
                             # PR; "sparse" — the two-stage draw: a
                             # sparse bucket over the word's occupied
                             # topics (per-word index built at launch /
                             # refresh boundaries, `sparse_topic_cap`
                             # wide) plus a blocked hierarchical draw
                             # over the residual mass, distributionally
                             # exact for ANY index content and
                             # bitwise-reproducible within the mode
                             # (kernel ≡ twin ≡ oracle).  One uniform
                             # per token either way, so `ctr_stride`
                             # accounting and bucketed/padded parity
                             # carry over unchanged.
    sparse_topic_cap: int = 32  # width of the per-word topic index the
                             # sparse sampler gathers through (top-cap
                             # occupied topics per word).  Exactness
                             # never depends on it — overflow mass is
                             # simply drawn through the residual stage —
                             # so it is perf-only; clamped to n_topics.

    def resolve_backend(self, devices=None) -> str:
        """The ONE backend-routing decision (DESIGN.md §Execution-plan).

        Returns "jnp" (the batched-jnp twins — the CPU fast path),
        "pallas" (compiled kernels — every device is a TPU), or
        "pallas-interpret" (use_pallas forced on a non-TPU backend —
        correct but slow; what the kernel-parity tests exercise).
        `devices=None` asks the default backend; the multi-device
        runner passes its mesh's devices.
        """
        if not self.use_pallas:
            return "jnp"
        return ("pallas" if devices_support_pallas(devices)
                else "pallas-interpret")


def devices_support_pallas(devices=None) -> bool:
    """True when every target device compiles the sLDA Pallas kernels
    natively (TPU).  Shared predicate behind `SLDAConfig.resolve_backend`,
    `kernels.ops`' interpret-mode switch, and the launch runner's
    auto_pallas flip — the one copy of the platform check."""
    if devices is None:
        return jax.default_backend() == "tpu"
    return all(d.platform == "tpu" for d in devices)


@_pytree
@dataclasses.dataclass
class Corpus:
    """A padded bag of documents.

    tokens  : int32[D, N]  word ids, padding value arbitrary where mask==0
    mask    : float32[D, N] 1.0 on real tokens
    y       : float32[D]   document labels (binary labels stored as 0/1)
    """

    tokens: Array
    mask: Array
    y: Array

    @property
    def n_docs(self) -> int:
        return self.tokens.shape[0]

    @property
    def max_len(self) -> int:
        return self.tokens.shape[1]

    def lengths(self) -> Array:
        return jnp.sum(self.mask, axis=-1)


@_pytree
@dataclasses.dataclass
class GibbsState:
    """Mutable state of one collapsed-Gibbs sLDA chain."""

    z: Array       # int32[D, N]   token-topic assignments
    ndt: Array     # float32[D, T] doc-topic counts
    ntw: Array     # float32[T, W] topic-word counts
    nt: Array      # float32[T]    topic totals
    eta: Array     # float32[T]    regression weights


@_pytree
@dataclasses.dataclass
class SLDAModel:
    """What a trained chain exports: enough to predict, nothing more.

    This is the only thing that ever crosses a chain boundary — it is what
    makes the parallel algorithm communication-free during training.
    """

    phi: Array     # float32[T, W] topic-word distributions  φ̂
    eta: Array     # float32[T]    regression weights        η̂
    train_mse: Array   # float32[] training-set MSE (Weighted Average weight)
    train_acc: Array   # float32[] training-set accuracy (binary labels)


# ------------------------------------------------- ragged execution layer

def _take_docs(arr, idx, d_axis):
    """Gather document rows: idx [D'] (any d_axis) or [M, D'] (then the
    doc axis is 1 and arr carries the matching leading chain dim)."""
    if idx.ndim == 1:
        return jnp.take(arr, idx, axis=d_axis)
    assert d_axis == 1, d_axis
    return jax.vmap(lambda a, i: jnp.take(a, i, axis=0))(arr, idx)


@dataclasses.dataclass
class BucketedCorpus:
    """A corpus reorganized for length-bucketed (ragged) execution.

    Documents are sorted by true length and grouped into buckets; bucket
    `b` holds a contiguous run of the sorted order, padded to its OWN
    token width `widths[b]` (a token_block multiple of the longest doc in
    the bucket) instead of the global max.  The fused train/predict
    launches then run once per bucket, so sweep compute and padded
    memory scale with Σ_b D_b·N_b ≈ Σ true tokens rather than D·N_max
    (DESIGN.md §Ragged-execution).

    buckets   : per-bucket `Corpus` (tokens [.., D_b, N_b]), rows in
                sorted order; a leading chain dim M rides along when the
                source was a chain-sharded corpus [M, D, N].
    perm      : int32 [D] (or [M, D]) — sorted position i holds original
                document perm[i].
    inv_perm  : int32 [D] (or [M, D]) — original document d sits at
                sorted position inv_perm[d].
    ctr_stride: static int — the SOURCE corpus max_len.  Pinned as the
                PRNG counter stride of every bucketed launch so each
                (doc, sweep, token) triple draws the uniform it would in
                the unbucketed launch; with per-document hash seeds this
                is what makes bucketed execution bit-identical per
                document (the inverse-permutation contract: outputs are
                restored to original order via `merge_docs`).

    Registered as a pytree whose static aux is `ctr_stride` plus the
    bucket structure, so it can be passed through jit/shard_map; the
    schedule itself must be BUILT from concrete arrays (`bucket_corpus`).
    """

    buckets: tuple
    perm: Array
    inv_perm: Array
    ctr_stride: int
    identity: bool = False   # static: the DEGENERATE 1-bucket schedule
                             # with an identity permutation (the padded
                             # path as a plan cell — core.plan.as_bucketed).
                             # Row plumbing is a no-op then, so the
                             # degenerate plan compiles to exactly the
                             # padded program (same bits, zero gather
                             # overhead).

    @property
    def _trivial(self) -> bool:
        return self.identity and len(self.buckets) == 1

    # ---- static schedule facts (shapes only — safe under tracing)

    @property
    def widths(self) -> tuple:
        return tuple(b.tokens.shape[-1] for b in self.buckets)

    @property
    def counts(self) -> tuple:
        return tuple(b.tokens.shape[-2] for b in self.buckets)

    @property
    def n_docs(self) -> int:
        return sum(self.counts)

    @property
    def n_chains(self):
        """Leading chain dim of a chain-sharded schedule (None if flat)."""
        t = self.buckets[0].tokens
        return t.shape[0] if t.ndim == 3 else None

    @property
    def max_len(self) -> int:
        return self.ctr_stride

    def padded_tokens(self) -> int:
        """Token-loop slots the bucketed schedule executes (per chain)."""
        return sum(d * w for d, w in zip(self.counts, self.widths))

    def real_tokens(self) -> Array:
        return sum(b.mask.sum() for b in self.buckets)

    def lengths(self) -> Array:
        """True doc lengths in ORIGINAL order, [D] (or [M, D])."""
        d_axis = self.perm.ndim - 1
        return self.merge_docs([b.mask.sum(-1) for b in self.buckets],
                               d_axis=d_axis)

    @property
    def y(self) -> Array:
        """Labels in ORIGINAL order (buckets store them sorted)."""
        return self.merge_docs([b.y for b in self.buckets],
                               d_axis=self.perm.ndim - 1)

    # ---- row plumbing between original order and the bucketed layout

    def split_docs(self, arr, d_axis=None):
        """Original-order doc rows [.., D, ...] → per-bucket pieces."""
        if self._trivial:
            return [arr]
        if d_axis is None:
            d_axis = self.perm.ndim - 1
        srt = _take_docs(arr, self.perm, d_axis)
        out, o = [], 0
        for c in self.counts:
            sl = (slice(None),) * d_axis + (slice(o, o + c),)
            out.append(srt[sl])
            o += c
        return out

    def merge_docs(self, pieces, d_axis=None):
        """Per-bucket doc rows → one array in ORIGINAL order."""
        pieces = list(pieces)
        if self._trivial:
            return pieces[0]
        if d_axis is None:
            d_axis = self.perm.ndim - 1
        return _take_docs(jnp.concatenate(pieces, axis=d_axis),
                          self.inv_perm, d_axis)

    def split_padded(self, arr, d_axis=None):
        """[.., D, ctr_stride] original order → per-bucket [.., D_b, N_b]
        (rows gathered, token tail truncated to the bucket width)."""
        if self._trivial and self.widths[0] == self.ctr_stride:
            return [arr]
        if d_axis is None:
            d_axis = self.perm.ndim - 1
        return [p[..., :w] for p, w in zip(self.split_docs(arr, d_axis),
                                           self.widths)]

    def merge_padded(self, pieces, fill, d_axis=None):
        """Per-bucket [.., D_b, N_b] → [.., D, ctr_stride] original order;
        token columns beyond each bucket's width come from `fill`
        (original order) — they are all-padding slots, which the
        unbucketed launch leaves at their input values."""
        pieces = list(pieces)
        if self._trivial and pieces[0].shape[-1] == self.ctr_stride:
            return pieces[0]
        if d_axis is None:
            d_axis = self.perm.ndim - 1
        fills = self.split_docs(fill, d_axis)
        full = [jnp.concatenate([p, f[..., p.shape[-1]:]], axis=-1)
                for p, f in zip(pieces, fills)]
        return self.merge_docs(full, d_axis)


jax.tree_util.register_pytree_node(
    BucketedCorpus,
    lambda bc: ((bc.buckets, bc.perm, bc.inv_perm),
                (bc.ctr_stride, bc.identity)),
    lambda aux, ch: BucketedCorpus(buckets=tuple(ch[0]), perm=ch[1],
                                   inv_perm=ch[2], ctr_stride=aux[0],
                                   identity=aux[1]),
)


def bucket_signature(bc: BucketedCorpus) -> tuple:
    """The static shape signature of a bucketed schedule — everything
    the corpus contributes to a compiled program's identity: one
    (width, count) pair per bucket plus the PRNG counter stride, the
    chain layout, and the degenerate-identity flag.  Hashable; two
    schedules with equal signatures trace to identical programs, so a
    prediction program compiled for one micro-batch serves every later
    batch with the same signature (the serving plan-cache key —
    serving/slda_service.py)."""
    return (tuple(zip(bc.widths, bc.counts)), bc.ctr_stride,
            bc.n_chains, bc.identity)


def _dp_bucket_cuts(segs, max_buckets: int, overhead: float):
    """Optimal contiguous grouping of width segments into ≤ max_buckets
    buckets, minimizing the modeled sweep cost Σ_b (D_b + overhead)·N_b.

    segs: [(count, width), ...] with strictly increasing widths (docs
    sorted by length, compressed to runs of equal rounded width — a cut
    inside a run can never pay, so these are the only candidate cuts).
    `overhead` is the per-bucket fixed cost in document-row units: each
    extra bucket re-runs the sequential token loop for its width, and on
    CPU a scan step has a fixed cost worth ~a hundred folded doc rows
    (measured in BENCH_slda_ragged.json — equal-count quantile buckets
    lose exactly because they ignore this term).  overhead=0 minimizes
    padded slots alone (maximal fragmentation up to max_buckets).
    """
    S = len(segs)
    max_b = max(1, min(max_buckets, S))
    pref = [0]
    for c, _ in segs:
        pref.append(pref[-1] + c)
    INF = float("inf")
    # dp[b][j]: best cost of covering the first j segments with b buckets
    dp = [[INF] * (S + 1) for _ in range(max_b + 1)]
    cut = [[0] * (S + 1) for _ in range(max_b + 1)]
    dp[0][0] = 0.0
    for b in range(1, max_b + 1):
        for j in range(1, S + 1):
            w = segs[j - 1][1]
            for i in range(j):
                if dp[b - 1][i] == INF:
                    continue
                c = dp[b - 1][i] + (pref[j] - pref[i] + overhead) * w
                if c < dp[b][j]:
                    dp[b][j] = c
                    cut[b][j] = i
    b_best = min(range(1, max_b + 1), key=lambda b: dp[b][S])
    bounds, j = [], S
    for b in range(b_best, 0, -1):
        bounds.append(j)
        j = cut[b][j]
    return list(reversed(bounds))                   # segment end indices


def bucket_corpus(corpus: Corpus, n_buckets: int = 8, *,
                  token_block: int = 8,
                  overhead_docs: float = 96.0) -> BucketedCorpus:
    """Build the length-bucketed schedule for `corpus` (host-side).

    Documents are stably argsorted by true length (per chain for a
    chain-sharded [M, D, N] corpus — every chain shares the same bucket
    SIZES so the chain-batched grids stay rectangular, while each chain
    gets its own permutation) and partitioned into AT MOST `n_buckets`
    contiguous groups by a cost-model DP (`_dp_bucket_cuts`): each
    group is padded to its token_block-rounded max length (max across
    chains), and the partition minimizes Σ_b (D_b + overhead_docs)·N_b
    — padded slots plus the per-bucket token-loop overhead, so heavy
    tails get cut off into their own (small) wide bucket instead of
    fragmenting the bulk into equal-count quantiles.  The degenerate
    all-same-length corpus collapses to ONE bucket (the padded path
    plus a no-op permutation).

    Shapes are data-dependent, so this runs on CONCRETE arrays only —
    call it outside jit (the result is a pytree you can pass in).
    """
    try:
        mask = np.asarray(corpus.mask)
    except jax.errors.TracerArrayConversionError as e:  # pragma: no cover
        raise ValueError(
            "bucket_corpus needs concrete lengths — build the schedule "
            "outside jit and pass the BucketedCorpus in") from e
    lens = mask.sum(-1).astype(np.int64)             # [D] or [M, D]
    chain = lens.ndim == 2
    D = lens.shape[-1]
    src_n = corpus.tokens.shape[-1]
    nb = max(1, min(int(n_buckets), D))

    perm = np.argsort(lens, axis=-1, kind="stable").astype(np.int32)
    lens_sorted = np.take_along_axis(lens, perm, axis=-1)

    # per sorted position: the rounded width it needs (max across chains
    # — each chain's sorted lengths ascend, so the column max ascends)
    colmax = lens_sorted.max(axis=0) if chain else lens_sorted
    round_w = np.minimum(
        src_n, np.maximum(token_block,
                          -(-colmax // token_block) * token_block))
    # compress to runs of equal width — the only candidate cut points
    segs = []
    for w in round_w:
        if segs and segs[-1][1] == int(w):
            segs[-1][0] += 1
        else:
            segs.append([1, int(w)])
    segs = [(c, w) for c, w in segs]
    ends = _dp_bucket_cuts(segs, nb, float(overhead_docs))
    widths, counts, o = [], [], 0
    for e in ends:
        cnt = sum(c for c, _ in segs[o:e])
        widths.append(segs[e - 1][1])
        counts.append(cnt)
        o = e

    inv_perm = np.argsort(perm, axis=-1, kind="stable").astype(np.int32)
    perm_j = jnp.asarray(perm)
    d_axis = 1 if chain else 0
    srt = lambda x: _take_docs(x, perm_j, d_axis)
    tok_s, mask_s, y_s = srt(corpus.tokens), srt(corpus.mask), srt(corpus.y)
    buckets, o = [], 0
    for c, w in zip(counts, widths):
        sl = (slice(None),) * d_axis + (slice(o, o + c), slice(None, w))
        buckets.append(Corpus(tokens=tok_s[sl], mask=mask_s[sl],
                              y=y_s[sl[:-1]]))
        o += c
    return BucketedCorpus(buckets=tuple(buckets), perm=perm_j,
                          inv_perm=jnp.asarray(inv_perm),
                          ctr_stride=src_n)


def partition(corpus: Corpus, m: int) -> Corpus:
    """Split a corpus into M equal shards: [D, ...] → [M, D/M, ...].

    The paper partitions uniformly at random; callers should pre-shuffle.
    D must be divisible by M (pad the corpus if not).
    """
    if corpus.n_docs % m:
        raise ValueError(f"{corpus.n_docs} docs not divisible by {m} shards")
    reshape = lambda x: x.reshape((m, corpus.n_docs // m) + x.shape[1:])
    return Corpus(tokens=reshape(corpus.tokens), mask=reshape(corpus.mask),
                  y=reshape(corpus.y))


def _concat_corpora(a: Corpus, b: Corpus) -> Corpus:
    """Stack two corpora along the doc axis (padding to a common max_len)
    so one fused prediction pass covers both."""
    n = max(a.max_len, b.max_len)
    padn = lambda x, w: jnp.pad(x, ((0, 0), (0, w))) if w else x
    return Corpus(
        tokens=jnp.concatenate([padn(a.tokens, n - a.max_len),
                                padn(b.tokens, n - b.max_len)]),
        mask=jnp.concatenate([padn(a.mask, n - a.max_len),
                              padn(b.mask, n - b.max_len)]),
        y=jnp.concatenate([a.y, b.y]))


def _stair_segments(bc, pieces):
    """Per-bucket token-padded pieces [.., D_b, N_b] → stair segments:
    segment k holds token columns [w_{k-1}, w_k) of buckets k..K (the
    docs still alive there — a suffix of the sorted order)."""
    out, w_prev = [], 0
    for k, w in enumerate(bc.widths):
        out.append(jnp.concatenate([p[..., w_prev:w] for p in pieces[k:]],
                                   axis=-2))
        w_prev = w
    return out


def _unstair_segments(bc, segs):
    """Inverse of _stair_segments: stair segments [.., D_k, L_k] back to
    per-bucket token-padded pieces [.., D_b, N_b]."""
    starts = np.cumsum([0] + list(bc.counts))
    out = []
    for j, c in enumerate(bc.counts):
        cols = []
        for k in range(j + 1):
            a = int(starts[j] - starts[k])
            cols.append(segs[k][..., a:a + c, :])
        out.append(jnp.concatenate(cols, axis=-1))
    return out


def counts_from_assignments(tokens: Array, mask: Array, z: Array,
                            n_topics: int, vocab_size: int):
    """Exact (ndt, ntw, nt) from the current assignments. Used to refresh the
    delayed topic-word table between document-parallel sweeps."""
    d_idx = jnp.arange(tokens.shape[0])[:, None]
    ndt = jnp.zeros((tokens.shape[0], n_topics), jnp.float32)
    ndt = ndt.at[d_idx, z].add(mask)
    ntw = jnp.zeros((n_topics, vocab_size), jnp.float32)
    ntw = ntw.at[z, tokens].add(mask)
    return ndt, ntw, jnp.sum(ntw, axis=-1)


def apply_count_deltas(ntw: Array, nt: Array, tokens: Array, mask: Array,
                       z_old: Array, z_new: Array, cap: int | None = None):
    """Exact incremental (ntw, nt) refresh from one sweep's reassignments.

    Only tokens whose topic actually changed carry weight (typically few,
    late in sampling — Magnusson et al., sparse partially collapsed
    samplers), so the scatter is issued in **changed-token compaction**
    form: gather the positions where `z_old != z_new` into a static-width
    buffer of `cap` slots and scatter only those ±1 updates, instead of a
    dense [D·N]-index 2-scatter that is mostly zero-weight no-ops.  If a
    sweep reassigns more than `cap` tokens (early sweeps), a `lax.cond`
    falls back to the dense form — exactness never depends on the cap.

    cap=None picks the backend's measured winner: max(128, D·N/8) slots
    where scatter cost scales with the index count (TPU/GPU), the dense
    form on CPU — on XLA:CPU the nonzero+gather overhead makes the
    compacted branch ~3× a dense scatter even at 5 % change
    (DESIGN.md §Train-kernel).  Pass `cap=0` to force dense, or an
    explicit slot count to force compaction.  Counts stay exact either
    way: ±1.0 float32 updates are lossless below 2^24, and
    `SLDAConfig.count_rebuild_every` bounds drift beyond that.
    """
    changed = mask * (z_new != z_old).astype(mask.dtype)
    flat = changed.ravel()
    total = flat.shape[0]
    if cap is None:
        cap = 0 if jax.default_backend() == "cpu" else max(128, total // 8)
    cap = int(min(cap, total))

    def dense(_):
        ntw2 = (ntw.at[z_old, tokens].add(-changed)
                .at[z_new, tokens].add(changed))
        nt2 = (nt + jnp.zeros_like(nt).at[z_new].add(changed)
               - jnp.zeros_like(nt).at[z_old].add(changed))
        return ntw2, nt2

    if cap <= 0 or cap >= total:
        return dense(None)

    n_changed = jnp.sum(flat > 0)
    w_all, zo_all, zn_all = tokens.ravel(), z_old.ravel(), z_new.ravel()

    def sparse(_):
        idx = jnp.nonzero(flat > 0, size=cap, fill_value=0)[0]
        wt = (jnp.arange(cap) < n_changed).astype(ntw.dtype)
        w, zo, zn = w_all[idx], zo_all[idx], zn_all[idx]
        ntw2 = ntw.at[zo, w].add(-wt).at[zn, w].add(wt)
        nt2 = (nt + jnp.zeros_like(nt).at[zn].add(wt)
               - jnp.zeros_like(nt).at[zo].add(wt))
        return ntw2, nt2

    return jax.lax.cond(n_changed <= cap, sparse, dense, None)


def topic_occupancy_index(table_t: Array, cap: int):
    """Per-word top-`cap` occupied-topic index for the sparse sampler.

    `table_t` is any `[..., W, T]` word-major table — `ntw` transposed for
    training, `phi_t` (or a chain-stacked `[M·W, T]` stair table) for
    prediction.  Returns `(idx, vmask, occm)`:

      * ``idx``   int32 `[..., W, cap]` — the word's top-`cap` topics by
        mass (argsort keeps the entries DISTINCT, which is what makes the
        support split below an identity);
      * ``vmask`` f32 `[..., W, cap]` — 1 where the indexed entry carries
        positive mass, 0 for slots past the word's true occupancy;
      * ``occm``  f32 `[..., W, T]` — the dense 0/1 membership mask of the
        valid indexed topics.

    The sparse draw splits the exact dense weights p as
    ``sv = take_along(p, idx)·vmask`` (sparse bucket) and
    ``rv = p·(1−occm)`` (residual); scatter(sv)+rv == p holds exactly in
    float32 for ANY index content, so a stale index (built from the
    launch-frozen table while counts evolve in-launch) changes WHICH
    bucket serves a topic, never the distribution.  `cap` is perf-only
    and clamped to T.
    """
    *lead, w_dim, t_dim = table_t.shape
    cap = int(min(cap, t_dim))
    flat = table_t.reshape((-1, w_dim, t_dim))
    idx = jnp.argsort(-flat, axis=-1)[..., :cap].astype(jnp.int32)
    vals = jnp.take_along_axis(flat, idx, axis=-1)
    vmask = (vals > 0).astype(jnp.float32)
    b = jnp.arange(flat.shape[0])[:, None, None]
    w = jnp.arange(w_dim)[None, :, None]
    # idx entries are distinct per word, so add == set on the zero init
    occm = jnp.zeros(flat.shape, jnp.float32).at[b, w, idx].add(vmask)
    shape = tuple(lead) + (w_dim,)
    return (idx.reshape(shape + (cap,)), vmask.reshape(shape + (cap,)),
            occm.reshape(shape + (t_dim,)))


def topic_occupancy(table_t: Array) -> Array:
    """Number of positive-mass topics per word (`[..., W]`), for the
    bench occupancy column and the dry-run why-lines."""
    return jnp.sum((table_t > 0).astype(jnp.int32), axis=-1)
