"""Test-time prediction for sLDA, Eqs. (4)–(5).

Given a trained model (φ̂, η̂), sample topic assignments for the *test*
documents under

    p(z=t | ·) ∝ (N_dt^{-dn}+α)/(N_d^{-dn}+Tα) · φ̂_{t,w}

(unsupervised — the test label is what we are predicting), then report

    ŷ_d = η̂ᵀ z̄_d,    z̄ averaged over the last `n_pred_samples` sweeps
                       after `n_pred_burnin` burn-in sweeps

(averaging over samples follows Nguyen et al. 2014, which the paper builds
its MCMC procedure on).

All sweeps run through the fused multi-sweep path in
`kernels.ops.slda_predict_sweeps` (DESIGN.md §Predict-kernel): one launch
per document block, φ̂ row-gathered from the transposed [W, T] layout, and
per-token uniforms derived from a counter-based hash of a per-document
seed — precomputing [D, n_sweeps, N] uniforms up front is a multi-GB
allocation at the paper's corpus sizes (found the hard way: the
paper-scale Fig. 6 run OOMed).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .types import (BucketedCorpus, Corpus, SLDAConfig, SLDAModel,
                    _stair_segments, _take_docs)


def stair_predict(bc: BucketedCorpus, phi, z0, seeds, cfg: SLDAConfig):
    """Run the STAIRCASE prediction executor over a bucketed schedule of
    a SHARED corpus (DESIGN.md §Ragged-execution) — the jnp-route
    counterpart of the per-bucket fused launches: same schedule, but the
    bucket widths become token-range segments walked inside each sweep
    over the still-alive doc suffix, so the sequential step count stays
    N_max while executed slots collapse to the staircase.

    phi [M, T, W]; z0 [M, D, ctr_stride]; seeds [M, D] — all in
    ORIGINAL doc order (M may be 1; ndt0 is derived from z0 and the
    bucket masks, the same bits as the padded scatter).  Chains are
    folded
    DOC-MAJOR (row r = d·M + c) around one stacked [M·W, T] table so doc
    suffixes stay row suffixes.  Returns ndt_avg [M, D, T], original
    order — bit-identical per document to the padded chains twin.
    """
    from repro.kernels.slda_predict import slda_predict_stair_jnp

    M, T, W = phi.shape
    D, S = bc.n_docs, bc.ctr_stride
    assert bc.n_chains is None, "stair_predict wants a shared corpus"
    phi_t = jnp.swapaxes(phi, -1, -2).reshape(M * W, T)
    off = jnp.arange(M, dtype=jnp.int32) * W
    fold = lambda a: jnp.swapaxes(a, 0, 1).reshape((D * M,) + a.shape[2:])
    sort = lambda a: _take_docs(a, bc.perm, 1)
    seeds_f = fold(sort(seeds))
    z0_b = bc.split_padded(z0, d_axis=1)          # [M, Db, Nb] sorted
    ndt0_f = fold(jnp.concatenate(
        [jax.vmap(lambda z: jnp.zeros((b.tokens.shape[0], T), jnp.float32)
                  .at[jnp.arange(b.tokens.shape[0])[:, None], z]
                  .add(b.mask))(zb)
         for b, zb in zip(bc.buckets, z0_b)], axis=1))

    starts = np.cumsum([0] + list(bc.counts))
    seg_r0 = [int(s) * M for s in starts[:-1]]
    seg_n0 = [0] + list(bc.widths[:-1])
    # shared segment slicing (types._stair_segments), then the doc-major
    # chain fold with per-chain vocab offsets on the token ids
    seg_tok = [(tk[:, None, :] + off[None, :, None])
               .reshape(tk.shape[0] * M, tk.shape[1])
               for tk in _stair_segments(bc, [b.tokens
                                              for b in bc.buckets])]
    seg_mask = [jnp.broadcast_to(mk[:, None, :], mk.shape[:1] + (M,)
                                 + mk.shape[1:]).reshape(-1, mk.shape[1])
                for mk in _stair_segments(bc, [b.mask
                                               for b in bc.buckets])]
    seg_z0 = [jnp.swapaxes(zk, 0, 1).reshape(-1, zk.shape[-1])
              for zk in _stair_segments(bc, z0_b)]

    avg_f = slda_predict_stair_jnp(
        seg_tok, seg_mask, seg_z0, seg_r0, seg_n0, seeds_f, ndt0_f, phi_t,
        alpha=cfg.alpha, n_burnin=cfg.n_pred_burnin,
        n_samples=cfg.n_pred_samples, ctr_stride=S)
    avg_sorted = jnp.swapaxes(avg_f.reshape(D, M, T), 0, 1)
    return _take_docs(avg_sorted, bc.inv_perm, 1)     # [M, D, T] original


def bucketed_predict_pallas(bc: BucketedCorpus, phi, z0, seeds,
                            cfg: SLDAConfig):
    """Pallas-route ragged prediction: one chain-batched fused launch per
    length bucket over a SHARED corpus, each at the bucket's width with
    the counter stride pinned (the ONE copy of the per-bucket loop —
    single-chain callers pass M=1).  Same chain-form signature and
    return as `stair_predict`: phi [M, T, W]; z0 [M, D, ctr_stride];
    seeds [M, D] — ndt_avg [M, D, T] in ORIGINAL doc order."""
    from repro.kernels import ops

    S = bc.ctr_stride
    z0_b = bc.split_padded(z0, d_axis=1)
    seeds_b = bc.split_docs(seeds, d_axis=1)
    avgs = []
    for b, z0b, sb in zip(bc.buckets, z0_b, seeds_b):
        d_idx = jnp.arange(b.tokens.shape[0])[:, None]
        ndt0 = jax.vmap(
            lambda z: jnp.zeros((b.tokens.shape[0], cfg.n_topics),
                                jnp.float32).at[d_idx, z].add(b.mask))(z0b)
        avg, _ = ops.slda_predict_sweeps(
            b.tokens, b.mask, z0b, ndt0, phi, sb,
            alpha=cfg.alpha, n_burnin=cfg.n_pred_burnin,
            n_samples=cfg.n_pred_samples, doc_block=cfg.pred_doc_block,
            use_pallas=True, chain_axis=True, ctr_stride=S)
        avgs.append(avg)
    return bc.merge_docs(avgs, d_axis=1)              # [M, D, T] original


def predict(key: jax.Array, model: SLDAModel, corpus: Corpus,
            cfg: SLDAConfig) -> jax.Array:
    """ŷ for every document in `corpus` under `model`. jit-able, local.

    `corpus` may be a `BucketedCorpus` (DESIGN.md §Ragged-execution):
    the fused pass then runs once per length bucket — compute scaling
    with Σ true tokens instead of D·max_len — and is bit-identical per
    document to the padded path (frozen φ̂ makes prediction document-
    independent, and the schedule pins the PRNG counter stride)."""
    # local import keeps the kernels package off core's module-import
    # path; unlike the training sweep, BOTH predict routes (pallas and
    # the batched-jnp fast path) live behind kernels.ops (DESIGN.md §1)
    from repro.kernels import ops

    if isinstance(corpus, BucketedCorpus):
        return _predict_bucketed(key, model, corpus, cfg)

    k_init, k_seeds = jax.random.split(key)
    z0 = jax.random.randint(k_init, corpus.tokens.shape, 0, cfg.n_topics,
                            jnp.int32)
    d_idx = jnp.arange(corpus.n_docs)[:, None]
    ndt0 = jnp.zeros((corpus.n_docs, cfg.n_topics), jnp.float32)
    ndt0 = ndt0.at[d_idx, z0].add(corpus.mask)
    seeds = jax.random.randint(k_seeds, (corpus.n_docs,), 0,
                               jnp.iinfo(jnp.int32).max, jnp.int32)

    ndt_avg, _ = ops.slda_predict_sweeps(
        corpus.tokens, corpus.mask, z0, ndt0, model.phi, seeds,
        alpha=cfg.alpha, n_burnin=cfg.n_pred_burnin,
        n_samples=cfg.n_pred_samples, doc_block=cfg.pred_doc_block,
        use_pallas=cfg.use_pallas)

    zbar = ndt_avg / jnp.maximum(corpus.lengths(), 1.0)[:, None]
    return zbar @ model.eta          # Eq. (5)


def _predict_bucketed(key: jax.Array, model: SLDAModel, bc: BucketedCorpus,
                      cfg: SLDAConfig) -> jax.Array:
    """Ragged prediction: the STAIRCASE executor on the jnp route, one
    fused launch per bucket on the pallas route.  Either way ndt
    averages are merged back to ORIGINAL document order before ŷ, so
    every reduction downstream sees the same operand order as the padded
    path (the bit-identity contract — tests/test_ragged.py)."""
    D, S = bc.n_docs, bc.ctr_stride
    k_init, k_seeds = jax.random.split(key)
    # same draws as the padded path: z0 [D, max_len] + seeds [D] in
    # original order, then carved along the schedule
    z0 = jax.random.randint(k_init, (D, S), 0, cfg.n_topics, jnp.int32)
    seeds = jax.random.randint(k_seeds, (D,), 0,
                               jnp.iinfo(jnp.int32).max, jnp.int32)
    run = stair_predict if not cfg.use_pallas else bucketed_predict_pallas
    ndt_avg = run(bc, model.phi[None], z0[None], seeds[None], cfg)[0]
    zbar = ndt_avg / jnp.maximum(bc.lengths(), 1.0)[:, None]
    return zbar @ model.eta          # Eq. (5)
