"""Test-time prediction for sLDA, Eqs. (4)–(5).

Given a trained model (φ̂, η̂), sample topic assignments for the *test*
documents under

    p(z=t | ·) ∝ (N_dt^{-dn}+α)/(N_d^{-dn}+Tα) · φ̂_{t,w}

(unsupervised — the test label is what we are predicting), then report

    ŷ_d = η̂ᵀ z̄_d,    z̄ averaged over the last `n_pred_samples` sweeps
                       after `n_pred_burnin` burn-in sweeps

(averaging over samples follows Nguyen et al. 2014, which the paper builds
its MCMC procedure on).

`predict` is a thin wrapper over the unified execution plan
(DESIGN.md §Execution-plan): a single model is M=1 through the
chain-batched prediction executors — per-bucket fused launches
(`kernels.ops.slda_predict_sweeps`, one launch per doc block, φ̂
row-gathered from the transposed [W, T] layout, per-token uniforms from
a counter-based hash of a per-document seed) on the pallas route and
for the degenerate padded schedule, the STAIRCASE twin for multi-bucket
jnp plans.  Precomputing [D, n_sweeps, N] uniforms up front is a
multi-GB allocation at the paper's corpus sizes (found the hard way:
the paper-scale Fig. 6 run OOMed) — hence the counter-hash PRNG.
"""
from __future__ import annotations

import jax

from .types import Corpus, SLDAConfig, SLDAModel


def predict(key: jax.Array, model: SLDAModel, corpus: Corpus,
            cfg: SLDAConfig) -> jax.Array:
    """ŷ for every document in `corpus` under `model`. jit-able, local.

    `corpus` may be a `BucketedCorpus` (DESIGN.md §Ragged-execution):
    the fused pass then runs over the length-bucketed schedule —
    compute scaling with Σ true tokens instead of D·max_len — and is
    bit-identical per document to the padded path (frozen φ̂ makes
    prediction document-independent, and the schedule pins the PRNG
    counter stride)."""
    from .plan import build_plan
    plan = build_plan(corpus, cfg)
    models = jax.tree.map(lambda a: a[None], model)
    return plan.predict(key[None], models)[0]
