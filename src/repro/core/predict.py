"""Test-time prediction for sLDA, Eqs. (4)–(5).

Given a trained model (φ̂, η̂), sample topic assignments for the *test*
documents under

    p(z=t | ·) ∝ (N_dt^{-dn}+α)/(N_d^{-dn}+Tα) · φ̂_{t,w}

(unsupervised — the test label is what we are predicting), then report

    ŷ_d = η̂ᵀ z̄_d,    z̄ averaged over the last `n_pred_samples` sweeps
                       after `n_pred_burnin` burn-in sweeps

(averaging over samples follows Nguyen et al. 2014, which the paper builds
its MCMC procedure on).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .types import Corpus, SLDAConfig, SLDAModel


def _doc_predict_sweeps(tokens, mask, key, z0, ndt0, log_phi, cfg: SLDAConfig):
    """All prediction sweeps for one document; ndt is exact per token, φ̂ is
    fixed so there is no cross-document state at all.

    Uniforms are derived per sweep from a folded key INSIDE the scan —
    precomputing [D, n_sweeps, N] uniforms up front is a multi-GB
    allocation at the paper's corpus sizes (found the hard way: the
    paper-scale Fig. 6 run OOMed)."""
    T = cfg.n_topics
    topic_iota = jnp.arange(T, dtype=jnp.int32)
    n_sweeps = cfg.n_pred_burnin + cfg.n_pred_samples

    def token_step(carry, inp):
        ndt_d = carry
        w, m, z_old, u = inp
        old_onehot = (topic_iota == z_old).astype(jnp.float32) * m
        ndt_d = ndt_d - old_onehot
        logp = jnp.log(ndt_d + cfg.alpha) + log_phi[:, w]
        p = jnp.exp(logp - jnp.max(logp))
        c = jnp.cumsum(p)
        z_new = jnp.sum((c < u * c[-1]).astype(jnp.int32))
        z_new = jnp.where(m > 0, z_new, z_old).astype(jnp.int32)
        ndt_d = ndt_d + (topic_iota == z_new).astype(jnp.float32) * m
        return ndt_d, z_new

    def sweep_step(carry, sweep_idx):
        z, ndt_d = carry
        us = jax.random.uniform(jax.random.fold_in(key, sweep_idx),
                                tokens.shape)
        ndt_d, z = jax.lax.scan(token_step, ndt_d, (tokens, mask, z, us))
        return (z, ndt_d), ndt_d

    (_, _), ndt_hist = jax.lax.scan(sweep_step, (z0, ndt0),
                                    jnp.arange(n_sweeps))
    # average z̄ over the post-burn-in sweeps
    keep = ndt_hist[cfg.n_pred_burnin:]
    return jnp.mean(keep, axis=0)


def predict(key: jax.Array, model: SLDAModel, corpus: Corpus,
            cfg: SLDAConfig) -> jax.Array:
    """ŷ for every document in `corpus` under `model`. jit-able, local."""
    k_init, k_sweeps = jax.random.split(key)
    z0 = jax.random.randint(k_init, corpus.tokens.shape, 0, cfg.n_topics, jnp.int32)
    d_idx = jnp.arange(corpus.n_docs)[:, None]
    ndt0 = jnp.zeros((corpus.n_docs, cfg.n_topics), jnp.float32)
    ndt0 = ndt0.at[d_idx, z0].add(corpus.mask)
    doc_keys = jax.random.split(k_sweeps, corpus.n_docs)

    log_phi = jnp.log(model.phi)
    ndt_avg = jax.vmap(
        _doc_predict_sweeps, in_axes=(0, 0, 0, 0, 0, None, None)
    )(corpus.tokens, corpus.mask, doc_keys, z0, ndt0, log_phi, cfg)

    zbar = ndt_avg / jnp.maximum(corpus.lengths(), 1.0)[:, None]
    return zbar @ model.eta          # Eq. (5)
