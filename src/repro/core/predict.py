"""Test-time prediction for sLDA, Eqs. (4)–(5).

Given a trained model (φ̂, η̂), sample topic assignments for the *test*
documents under

    p(z=t | ·) ∝ (N_dt^{-dn}+α)/(N_d^{-dn}+Tα) · φ̂_{t,w}

(unsupervised — the test label is what we are predicting), then report

    ŷ_d = η̂ᵀ z̄_d,    z̄ averaged over the last `n_pred_samples` sweeps
                       after `n_pred_burnin` burn-in sweeps

(averaging over samples follows Nguyen et al. 2014, which the paper builds
its MCMC procedure on).

All sweeps run through the fused multi-sweep path in
`kernels.ops.slda_predict_sweeps` (DESIGN.md §Predict-kernel): one launch
per document block, φ̂ row-gathered from the transposed [W, T] layout, and
per-token uniforms derived from a counter-based hash of a per-document
seed — precomputing [D, n_sweeps, N] uniforms up front is a multi-GB
allocation at the paper's corpus sizes (found the hard way: the
paper-scale Fig. 6 run OOMed).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .types import Corpus, SLDAConfig, SLDAModel


def predict(key: jax.Array, model: SLDAModel, corpus: Corpus,
            cfg: SLDAConfig) -> jax.Array:
    """ŷ for every document in `corpus` under `model`. jit-able, local."""
    # local import keeps the kernels package off core's module-import
    # path; unlike the training sweep, BOTH predict routes (pallas and
    # the batched-jnp fast path) live behind kernels.ops (DESIGN.md §1)
    from repro.kernels import ops

    k_init, k_seeds = jax.random.split(key)
    z0 = jax.random.randint(k_init, corpus.tokens.shape, 0, cfg.n_topics,
                            jnp.int32)
    d_idx = jnp.arange(corpus.n_docs)[:, None]
    ndt0 = jnp.zeros((corpus.n_docs, cfg.n_topics), jnp.float32)
    ndt0 = ndt0.at[d_idx, z0].add(corpus.mask)
    seeds = jax.random.randint(k_seeds, (corpus.n_docs,), 0,
                               jnp.iinfo(jnp.int32).max, jnp.int32)

    ndt_avg, _ = ops.slda_predict_sweeps(
        corpus.tokens, corpus.mask, z0, ndt0, model.phi, seeds,
        alpha=cfg.alpha, n_burnin=cfg.n_pred_burnin,
        n_samples=cfg.n_pred_samples, doc_block=cfg.pred_doc_block,
        use_pallas=cfg.use_pallas)

    zbar = ndt_avg / jnp.maximum(corpus.lengths(), 1.0)[:, None]
    return zbar @ model.eta          # Eq. (5)
