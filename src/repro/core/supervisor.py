"""Chain supervisor: health checks, quarantine, checkpointed restart.

The paper's central property — M chains that never communicate — is also
a fault-isolation guarantee: a NaN-poisoned, diverged, or dead chain can
be quarantined or restarted without touching any other chain, and the
ensemble prediction degrades EXACTLY (not approximately) through the
alive-masks of `core.combine` (DESIGN.md §Fault-model).  Industrial
topic-model deployments treat worker failure as routine (Zheng et al.,
Model-Parallel Inference for Big Topic Models); this layer cashes the
guarantee in:

  * **in-loop health checks** compiled into the EM scan via
    `ExecutionPlan.train_em(em_hook=...)`: per-chain NaN/Inf flags on
    η/ntw/ndt, cheap count-invariant probes (Σ ndt == Σ lengths,
    min ntw ≥ 0), and a train-MSE robust-z outlier score
    (`metrics.robust_z` — the same statistic as the out-of-band
    `ensemble_health` probe), accumulated into a per-chain uint32
    status vector with ZERO extra host syncs inside the scan and
    surfaced only at round boundaries;
  * **quarantine**: an unhealthy chain gets `alive=False`, threaded
    through every combine rule — because chains never communicate, the
    surviving sub-ensemble's prediction is bit-identical to one that
    never contained the dead chain;
  * **recovery**: bounded restart-from-checkpoint with exponential
    backoff (`checkpoint.restore_chain`), reseeding the restarted
    chain's PRNG lane (a fresh `fold_in` epoch → a distinct counter
    stream, so a transient failure is not deterministically replayed);
    when the restart budget is exhausted — or no checkpoint directory
    was given — the policy falls back to quarantine-only.

Decision table (see DESIGN.md §Fault-model for the taxonomy):

  fault class                 bits                       action
  --------------------------- -------------------------- ----------------
  NaN/Inf state               F_NAN_{ETA,NTW,NDT}        restart → quarantine
  count-invariant violation   F_NDT_SUM, F_NTW_NEG       restart → quarantine
  dead worker                 F_KILLED                   restart → quarantine
  statistical divergence      F_MSE_OUTLIER              quarantine only
  straggler                   F_STRAGGLER                flag only (serving
                                                         drops at combine)

Hard faults mean the chain's *state* is unusable — restart from the last
checkpoint is the only way to recover the lane.  A diverged-but-finite
chain is functional (dropping it is exact, restarting it would just
re-run the same posterior), and a straggler is correct, merely late.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import (CheckpointManager, latest_step, restore_chain)
from repro.metrics.ensemble import robust_z

from . import combine
from .plan import ExecutionPlan, build_plan, build_schedule
from .types import GibbsState, SLDAConfig, _concat_corpora, partition

# ---------------------------------------------------- per-chain status bits

F_NAN_ETA = 1 << 0       # non-finite regression weights η
F_NAN_NTW = 1 << 1       # non-finite topic-word counts
F_NAN_NDT = 1 << 2       # non-finite doc-topic counts
F_NDT_SUM = 1 << 3       # Σ ndt drifted from Σ true lengths
F_NTW_NEG = 1 << 4       # negative topic-word count
F_MSE_OUTLIER = 1 << 5   # train-MSE robust-z outlier (diverged)
F_KILLED = 1 << 6        # dead worker (reported by the fault/runtime layer)
F_STRAGGLER = 1 << 7     # late worker (flag only)

# serve-time bits (model-table screening + dispatch health — the serving
# tier's half of the taxonomy, DESIGN.md §Serving-robustness)
F_NAN_PHI = 1 << 8       # non-finite topic-word table φ̂
F_PHI_ROWSUM = 1 << 9    # φ̂ rows are not probability distributions
F_NAN_MSE = 1 << 10      # non-finite/negative train MSE (breaks weighting)
F_NAN_YHAT = 1 << 11     # non-finite served prediction at dispatch

#: state-corrupting faults — restart-from-checkpoint is worth trying
HARD_FAULTS = (F_NAN_ETA | F_NAN_NTW | F_NAN_NDT | F_NDT_SUM | F_NTW_NEG
               | F_KILLED)
#: statistical faults — the lane is functional, quarantine is exact
SOFT_FAULTS = F_MSE_OUTLIER
#: model-table faults — a chain whose exported model trips one of these
#: cannot serve; the prediction service quarantines it at (re)load
MODEL_FAULTS = F_NAN_PHI | F_PHI_ROWSUM | F_NAN_ETA | F_NAN_MSE

_BIT_NAMES = {
    F_NAN_ETA: "nan_eta", F_NAN_NTW: "nan_ntw", F_NAN_NDT: "nan_ndt",
    F_NDT_SUM: "ndt_sum", F_NTW_NEG: "ntw_neg",
    F_MSE_OUTLIER: "mse_outlier", F_KILLED: "killed",
    F_STRAGGLER: "straggler",
    F_NAN_PHI: "nan_phi", F_PHI_ROWSUM: "phi_rowsum",
    F_NAN_MSE: "nan_mse", F_NAN_YHAT: "nan_yhat",
}

_FRESH_SALT = 0x5EED      # fold_in salt of the fresh-init key lane


def describe_status(bits: int) -> list:
    """Human-readable names of the set status bits."""
    return [name for bit, name in _BIT_NAMES.items() if bits & bit]


class EnsembleHealthError(RuntimeError):
    """Raised when the alive fraction falls below
    `RecoveryPolicy.min_alive_frac` — the ensemble is no longer
    trustworthy and the operator must intervene."""


# ----------------------------------------------------------- configuration

@dataclasses.dataclass(frozen=True)
class HealthConfig:
    """What the in-scan probe checks at every EM boundary.  All checks
    are O(state) elementwise reductions — no host syncs, no collectives;
    the measured hot-path overhead is in BENCH_slda_robust.json."""

    check_nan: bool = True
    check_counts: bool = True
    check_mse: bool = True
    count_tol: float = 0.5   # counts are exact ±1 float32 adds; any
                             # drift beyond rounding is corruption
    mse_z_cut: float = 6.0   # robust z on per-chain train MSE across the
                             # ALIVE ensemble; conservative — shards
                             # differ in difficulty and quarantine of a
                             # soft fault is irreversible
    mse_rel_floor: float = 0.5   # scale floor as a fraction of the median
                                 # MSE: small ensembles with near-equal
                                 # MSEs have MAD ≈ 0, and an unfloored z
                                 # flags rounding jitter; with the floor
                                 # a chain must sit ≳(1 + cut·floor)×
                                 # the median MSE to count as diverged
    mse_warmup: int = 8      # EM boundaries before the MSE probe arms:
                             # burn-in MSEs swing wildly chain-to-chain
                             # and the latched status would quarantine
                             # chains for transients that converge away

    @property
    def enabled(self) -> bool:
        return self.check_nan or self.check_counts or self.check_mse


@dataclasses.dataclass(frozen=True)
class RecoveryPolicy:
    """What to do about an unhealthy chain (see the module decision
    table).  Restarts are per-chain and bounded; exhausting the budget
    falls back to quarantine-only, which is always exact."""

    max_restarts: int = 2
    backoff_base: float = 0.0    # seconds; sleep backoff_base · 2^k
                                 # before the k-th restart (0 = none —
                                 # in-process restarts need no settle
                                 # time; real cluster relaunches do)
    min_alive_frac: float = 0.25  # below this, raise EnsembleHealthError

    def backoff_s(self, n_prior_restarts: int) -> float:
        return self.backoff_base * (2.0 ** n_prior_restarts)


# --------------------------------------------------------- the in-scan probe

def _flag(bad, flag):
    return jnp.where(bad, jnp.uint32(flag), jnp.uint32(0))


def chain_status(plan: ExecutionPlan, state: GibbsState,
                 health: HealthConfig, alive, it=None) -> jnp.ndarray:
    """Per-chain status bits [M] uint32 from the chain-batched state —
    pure jnp, safe inside the EM scan.  `alive` [M] float masks which
    chains participate in the cross-chain MSE statistic (a quarantined
    lane keeps running garbage and must not skew the median); `it`, when
    given (traced EM-boundary index), arms the MSE probe only after
    `health.mse_warmup` boundaries."""
    bc = plan.corpus
    m = state.eta.shape[0]
    status = jnp.zeros((m,), jnp.uint32)
    if health.check_nan:
        fin = lambda x: jnp.isfinite(x).reshape(m, -1).all(axis=-1)
        status |= _flag(~fin(state.eta), F_NAN_ETA)
        status |= _flag(~fin(state.ntw), F_NAN_NTW)
        status |= _flag(~fin(state.ndt), F_NAN_NDT)
    if health.check_counts:
        tokens = bc.lengths().sum(-1)                    # [M] true tokens
        ndt_sum = state.ndt.reshape(m, -1).sum(-1)
        # NaN-poisoned counts make the comparison False → flag fires too
        ok_sum = jnp.abs(ndt_sum - tokens) <= health.count_tol
        status |= _flag(~ok_sum, F_NDT_SUM)
        ntw_min = state.ntw.reshape(m, -1).min(-1)
        status |= _flag(~(ntw_min >= -health.count_tol), F_NTW_NEG)
    if health.check_mse and m >= 3:
        lengths = jnp.maximum(bc.lengths(), 1.0)
        yhat = jnp.einsum("mdt,mt->md", state.ndt / lengths[..., None],
                          state.eta)
        mse = jnp.mean((yhat - bc.y) ** 2, axis=-1)
        z = robust_z(mse, valid=alive, rel_floor=health.mse_rel_floor)
        outlier = z >= health.mse_z_cut
        if it is not None:
            outlier = outlier & (jnp.asarray(it) >= health.mse_warmup)
        status |= _flag(outlier, F_MSE_OUTLIER)
    return status


def model_status(models, *, rowsum_tol: float = 1e-3) -> jnp.ndarray:
    """Per-chain status bits [M] uint32 screening an exported
    `SLDAModel` (chain-stacked leaves) — the serve-time twin of
    `chain_status`, run by the prediction service at model (re)load.
    Pure jnp, cheap (O(model) elementwise reductions):

      * NaN/Inf in φ̂ or η̂ (`F_NAN_PHI` / `F_NAN_ETA`),
      * φ̂ count invariants: every topic row is a probability
        distribution — non-negative, Σ_w φ̂[t, w] ≈ 1 (`F_PHI_ROWSUM`;
        a NaN-poisoned row also fails the comparison, same trick as the
        in-scan count probes),
      * non-finite or negative train MSE (`F_NAN_MSE` — it is the
        Weighted Average weight, so corruption here skews every
        combine, not just one chain's own prediction).

    A chain with any `MODEL_FAULTS` bit cannot serve; quarantining it
    at load is EXACT for the usual communication-free reason."""
    m = models.eta.shape[0]
    status = jnp.zeros((m,), jnp.uint32)
    fin = lambda x: jnp.isfinite(x).reshape(m, -1).all(axis=-1)
    status |= _flag(~fin(models.eta), F_NAN_ETA)
    status |= _flag(~fin(models.phi), F_NAN_PHI)
    rowsum = models.phi.sum(-1)                         # [M, T]
    rows_ok = (jnp.abs(rowsum - 1.0) <= rowsum_tol).all(-1)
    nonneg = (models.phi.reshape(m, -1).min(-1) >= -rowsum_tol)
    status |= _flag(~(rows_ok & nonneg), F_PHI_ROWSUM)
    mse_ok = jnp.isfinite(models.train_mse) & (models.train_mse >= 0.0)
    status |= _flag(~mse_ok, F_NAN_MSE)
    return status


# -------------------------------------------------------------- supervisor

@dataclasses.dataclass
class SupervisorReport:
    """What a supervised run observed: the final alive mask (feed it to
    the combine rules), latched per-chain status bits, restart counts,
    and a per-round event history."""

    alive: np.ndarray          # [M] bool
    status: np.ndarray         # [M] uint32, OR of every round
    restarts: np.ndarray       # [M] int32
    rounds: int
    history: list
    yhat_chains: np.ndarray = None   # [M, D_test], set by supervised_run

    def alive_mask(self) -> jnp.ndarray:
        return jnp.asarray(self.alive, jnp.float32)

    def quarantined(self) -> list:
        return [int(c) for c in np.nonzero(~self.alive)[0]]


class ChainSupervisor:
    """Wraps the chain-batched EM loop with health checks, quarantine,
    and checkpointed restart (module docstring).  Training is split into
    ROUNDS of `round_iters` EM iterations; inside a round everything is
    one compiled scan (health flags accumulate on-device), and rounds
    are the only points where the host reads the [M] status vector,
    takes a checkpoint, and applies the recovery policy.

    `fault_hook(state, it) -> (state, bits)` is the deterministic
    fault-injection attachment point (`repro.testing.faults`) — it runs
    inside the scan BEFORE the health probe, so an injected fault at
    boundary `it` is detectable at that same boundary."""

    def __init__(self, shards, cfg: SLDAConfig, *, health=None,
                 recovery=None, ckpt_dir=None, round_iters=None,
                 fault_hook=None, backend=None, keep_checkpoints=2):
        self.cfg = cfg
        self.health = health or HealthConfig()
        self.recovery = recovery or RecoveryPolicy()
        self.ckpt_dir = ckpt_dir
        self.plan = build_plan(shards, cfg, backend)
        assert self.plan.n_chains is not None, \
            "supervisor wants a chain-sharded schedule ([M, D/M, ...])"
        # default: ONE round — pure in-scan checking, no mid-train host
        # sync; checkpointed restart needs round_iters (and ckpt_dir)
        r = cfg.n_iters if round_iters is None else max(1, round_iters)
        n_full, rem = divmod(cfg.n_iters, r)
        self._round_sizes = [r] * n_full + ([rem] if rem else [])
        self._manager = (CheckpointManager(ckpt_dir, interval=1,
                                           keep=keep_checkpoints)
                         if ckpt_dir is not None else None)
        self._fault_hook = fault_hook
        self._init = jax.jit(lambda p, k: p.init_states(k))
        self._run_round = jax.jit(self._round_fn)
        #: times the round function was TRACED (not called) — a Python
        #: side effect inside the traced body, so steady-state rounds
        #: leave it untouched and an elastic repack can assert "zero
        #: retraces" by watching this stay constant (the same trick the
        #: serving plan cache uses)
        self.round_traces = 0

    # ---- one compiled round: EM scan with the composed hook inside
    def _round_fn(self, plan, keys, state, alive, it0):
        health, fault_hook = self.health, self._fault_hook
        self.round_traces += 1          # trace-time only — see __init__

        def hook(st, it, status):
            bits = jnp.zeros_like(status)
            if fault_hook is not None:
                st, fb = fault_hook(st, it)
                bits = bits | fb.astype(jnp.uint32)
            if health.enabled:
                bits = bits | chain_status(plan, st, health, alive, it)
            return st, status | bits

        status0 = jnp.zeros((alive.shape[0],), jnp.uint32)
        return plan.train_em(keys, state, em_hook=hook, status0=status0,
                             it_offset=it0)

    def _fold_keys(self, base, epoch, rnd):
        """Per-round per-chain keys: fold the chain's RESTART EPOCH in
        first, then the round index — a restarted chain's lane moves to
        a distinct counter stream and never deterministically replays
        the sweeps that led to the failure.

        `rnd` may be a scalar (every chain at the same logical round —
        the supervisor's wall-aligned loop) or an [M] array of PER-CHAIN
        round indices — the elastic runner's catch-up path, where a
        chain restored after device loss replays ITS OWN round-r stream
        while the survivors advance; fold_in(k, r) bits are identical
        either way, so the two cases are bitwise-interchangeable."""
        m = base.shape[0]
        rnd_arr = jnp.broadcast_to(jnp.asarray(rnd, jnp.int32), (m,))
        return jax.vmap(lambda k, e, r: jax.random.fold_in(
            jax.random.fold_in(k, e), r))(base, jnp.asarray(epoch), rnd_arr)

    def _restart_chain(self, state, c, base, epoch, events):
        """Restore chain c alone from the latest checkpoint; a corrupt or
        truncated chain file is fault-isolated to a fresh re-init of that
        one lane (the `restore_elastic` contract, per chain)."""
        step = (latest_step(self.ckpt_dir)
                if self.ckpt_dir is not None else None)
        tmpl = jax.tree.map(lambda x: x[c], state)
        chain_state, action = None, None
        if step is not None:
            try:
                chain_state = restore_chain(self.ckpt_dir, step, c, tmpl)
                action = f"restart_from_step_{step}"
            except Exception as e:  # noqa: BLE001 — corrupt file isolation
                events.append({"chain": c, "action": "checkpoint_corrupt",
                               "error": repr(e)})
        if chain_state is None:
            keys = jax.vmap(lambda k, e: jax.random.fold_in(
                k, _FRESH_SALT + e))(base, jnp.asarray(epoch))
            fresh, _ = self._init(self.plan, keys)
            chain_state = jax.tree.map(lambda x: x[c], fresh)
            action = "restart_fresh_init"
        events.append({"chain": c, "action": action})
        return jax.tree.map(lambda x, xc: x.at[c].set(xc), state,
                            chain_state)

    # ---- reusable pieces (the elastic runtime drives these directly) --

    def make_round_plan(self, r_iters: int) -> ExecutionPlan:
        """A plan for one round of `r_iters` EM iterations.  Same corpus
        and backend → same jit cache entry for every same-sized round."""
        return ExecutionPlan(
            corpus=self.plan.corpus,
            cfg=dataclasses.replace(self.cfg, n_iters=r_iters),
            backend=self.plan.backend)

    def run_round(self, round_plan, keys, state, alive, boundary_off):
        """One compiled round; returns (state, status [M] uint32 on
        host).  The ONLY host sync per round is the status read."""
        state, status = self._run_round(
            round_plan, keys, state, jnp.asarray(alive, jnp.float32),
            boundary_off)
        return state, np.asarray(jax.device_get(status), np.uint32)

    def _apply_recovery(self, state, status_np, *, alive, epoch, restarts,
                        grace, base, events):
        """Apply the recovery policy to one round's status vector.
        Mutates the host-side bookkeeping arrays (alive/epoch/restarts/
        grace) in place and returns the possibly-patched state; the
        caller owns the per-round grace decrement."""
        recovery = self.recovery
        for c in range(len(status_np)):
            bits = int(status_np[c])
            if grace[c] > 0:
                # a chain restarted from a checkpoint lags the
                # ensemble by up to one round — its worse-but-
                # converging MSE is expected, not divergence
                bits &= ~SOFT_FAULTS
            if not alive[c] or bits == 0 or not (bits & ~F_STRAGGLER):
                continue
            restartable = (bool(bits & HARD_FAULTS)
                           and restarts[c] < recovery.max_restarts
                           and self._manager is not None)
            if restartable:
                wait = recovery.backoff_s(int(restarts[c]))
                if wait > 0:
                    time.sleep(wait)
                state = self._restart_chain(state, c, base, epoch, events)
                restarts[c] += 1
                epoch[c] += 1
                grace[c] = 2    # caller decrements → one full round
            else:
                alive[c] = False
                events.append({"chain": c, "action": "quarantine",
                               "status": describe_status(bits)})
        return state

    def _check_min_alive(self, alive, latched):
        if alive.mean() < self.recovery.min_alive_frac:
            raise EnsembleHealthError(
                f"only {int(alive.sum())}/{len(alive)} chains alive "
                f"(min_alive_frac={self.recovery.min_alive_frac}); "
                f"latched status: "
                f"{[describe_status(int(s)) for s in latched]}")

    def train(self, keys):
        """Supervised chain-batched training from per-chain keys [M].
        Returns (GibbsState, SLDAModel, SupervisorReport) — state/models
        as `ExecutionPlan.train`, plus the report whose `alive` mask the
        caller MUST thread into the combine (quarantined lanes contain
        garbage by design)."""
        plan, recovery = self.plan, self.recovery
        m = plan.n_chains
        ks = jax.vmap(jax.random.split)(keys)
        state, z_fill = self._init(plan, ks[:, 0])
        base = ks[:, 1]
        alive = np.ones(m, bool)
        epoch = np.zeros(m, np.int32)
        restarts = np.zeros(m, np.int32)
        grace = np.zeros(m, np.int32)   # rounds of soft-fault amnesty a
                                        # restarted chain gets while it
                                        # catches up to the ensemble
        latched = np.zeros(m, np.uint32)
        history = []
        it_done, boundary_off = 0, 0
        for rnd, r_iters in enumerate(self._round_sizes):
            if self._manager is not None:
                self._manager.maybe_save(it_done, state)
            round_plan = self.make_round_plan(r_iters)
            state, status_np = self.run_round(
                round_plan, self._fold_keys(base, epoch, rnd), state,
                alive, boundary_off)
            events = []
            state = self._apply_recovery(
                state, status_np, alive=alive, epoch=epoch,
                restarts=restarts, grace=grace, base=base, events=events)
            grace = np.maximum(grace - 1, 0)
            latched |= status_np
            history.append({"round": rnd, "em_iters_done": it_done + r_iters,
                            "status": [int(s) for s in status_np],
                            "events": events})
            self._check_min_alive(alive, latched)
            boundary_off += round_plan.n_boundaries()
            it_done += r_iters
        models = plan._export(state)
        state = GibbsState(z=plan.corpus.merge_padded(state.z, z_fill),
                           ndt=state.ndt, ntw=state.ntw, nt=state.nt,
                           eta=state.eta)
        report = SupervisorReport(alive=alive, status=latched,
                                  restarts=restarts,
                                  rounds=len(self._round_sizes),
                                  history=history)
        return state, models, report


# --------------------------------------------- supervised end-to-end runs

def supervised_run_average(key, train, test, cfg: SLDAConfig, m: int, *,
                           rule: str = "weighted", health=None,
                           recovery=None, ckpt_dir=None, round_iters=None,
                           fault_hook=None):
    """The fault-tolerant form of `core.parallel.run_*_average`: train M
    chains under the supervisor, predict with every chain, and combine
    with the supervisor's alive mask — a quarantined chain can never
    contaminate ŷ (its predictions are excluded EXACTLY by
    `core.combine`).  Returns (ŷ [D_test], SupervisorReport); the
    per-chain test predictions ride along as `report.yhat_chains`."""
    from .parallel import (_combine_weighted, _predict_chains_jit)
    k1, k2 = jax.random.split(key)
    shards = build_schedule(partition(train, m), cfg)
    sup = ChainSupervisor(shards, cfg, health=health, recovery=recovery,
                          ckpt_dir=ckpt_dir, round_iters=round_iters,
                          fault_hook=fault_hook)
    _, models, report = sup.train(jax.random.split(k1, m))
    alive = report.alive_mask()
    if rule == "weighted" and cfg.fuse_weighted_predict:
        both = _concat_corpora(test, train)
        yhat = _predict_chains_jit(k2, models, build_schedule(both, cfg),
                                   cfg)
        yhat_te, yhat_tr = yhat[:, :test.n_docs], yhat[:, test.n_docs:]
    else:
        yhat_te = _predict_chains_jit(k2, models,
                                      build_schedule(test, cfg), cfg)
        yhat_tr = None
    report.yhat_chains = np.asarray(jax.device_get(yhat_te))
    if rule == "simple":
        return combine.simple_average(yhat_te, alive=alive), report
    if rule == "median":
        return combine.median(yhat_te, alive=alive), report
    if rule == "weighted":
        if yhat_tr is None:
            k3 = jax.random.fold_in(k2, 1)
            yhat_tr = _predict_chains_jit(k3, models,
                                          build_schedule(train, cfg), cfg)
        return _combine_weighted(yhat_te, yhat_tr, train.y, cfg,
                                 alive), report
    raise ValueError(rule)
