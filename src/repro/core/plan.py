"""The unified execution-plan layer: ONE dispatch path for
(padded | bucketed) × (single | chain-batched) × (pallas | jnp)
(DESIGN.md §Execution-plan).

The paper's communication-free algorithms are a single stochastic-EM
loop with four combine rules; before this layer the repo implemented
that loop once per (layout, chain-batching, backend, fusing) cell.  An
`ExecutionPlan` separates the *schedule* (data layout, partitioning —
Magnusson et al.; Yan et al., Towards Big Topic Modeling) from the
*sampler*:

  * every corpus is canonicalized to a `BucketedCorpus` — padded
    execution is the DEGENERATE 1-bucket schedule with an identity
    permutation and `ctr_stride = max_len`, so the padded code paths
    stop being special (and the degenerate wrap is shape-only, hence
    traceable under jit, unlike real bucketing);
  * every chain layout is chain-batched — a single chain is M=1
    through the chain_axis kernels (bit-identical to the old
    single-chain path, which is deleted);
  * the plan owns all routing: executor ("blocks" per-bucket fused
    launches on the pallas route and for 1-bucket jnp, "stair" stacked
    twins for multi-bucket jnp), the sweeps-per-launch schedule
    (n_full full launches + one remainder), and the count-refresh
    cadence.

Exactness contract (tests/test_dispatch_matrix.py): at
sweeps_per_launch=1 every cell is bit-identical per document to the
seed-semantics reference (threefry uniforms, η solve every sweep) under
any bucketing/permutation — the `ctr_stride` PRNG pinning of
DESIGN.md §Ragged-execution.  At sweeps_per_launch>1 each cell is its
own member of the fused sampler family (statistically equivalent; the
bucket partition doubles as the delayed-count partition).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from .regression import solve_eta
from .types import (BucketedCorpus, Corpus, GibbsState, SLDAConfig,
                    SLDAModel, _stair_segments, _take_docs,
                    _unstair_segments, apply_count_deltas, bucket_corpus,
                    bucket_signature, counts_from_assignments)


# ------------------------------------------------------- canonicalization

def as_bucketed(corpus) -> BucketedCorpus:
    """Canonicalize to the degenerate 1-bucket schedule (identity
    permutation, `ctr_stride = max_len`) — the padded path as a plan
    cell.  Shape-only, so it is traceable under jit; a `BucketedCorpus`
    passes through untouched."""
    if isinstance(corpus, BucketedCorpus):
        return corpus
    d_axis = corpus.tokens.ndim - 2            # 0 flat, 1 chain-sharded
    D = corpus.tokens.shape[d_axis]
    perm = jnp.broadcast_to(jnp.arange(D, dtype=jnp.int32),
                            corpus.tokens.shape[:d_axis] + (D,))
    return BucketedCorpus(buckets=(corpus,), perm=perm, inv_perm=perm,
                          ctr_stride=corpus.tokens.shape[-1],
                          identity=True)


def build_schedule(corpus, cfg: SLDAConfig) -> BucketedCorpus:
    """cfg-driven schedule construction: real length bucketing when
    `cfg.length_buckets > 0` (host-side — needs concrete lengths), the
    degenerate padded wrap otherwise.  Already-bucketed corpora pass
    through, so orchestrators can call this unconditionally."""
    if isinstance(corpus, BucketedCorpus):
        return corpus
    if cfg.length_buckets > 0:
        return bucket_corpus(corpus, cfg.length_buckets,
                             token_block=cfg.bucket_token_block,
                             overhead_docs=cfg.bucket_overhead_docs)
    return as_bucketed(corpus)


def _lift_chain(bc: BucketedCorpus) -> BucketedCorpus:
    """Flat schedule [D, ...] → chain-sharded [1, D, ...] (M=1)."""
    if bc.n_chains is not None:
        return bc
    buckets = tuple(Corpus(tokens=b.tokens[None], mask=b.mask[None],
                           y=b.y[None]) for b in bc.buckets)
    return BucketedCorpus(buckets=buckets, perm=bc.perm[None],
                          inv_perm=bc.inv_perm[None],
                          ctr_stride=bc.ctr_stride, identity=bc.identity)


def _stair_layout(bc: BucketedCorpus, m: int, vocab_size: int):
    """The doc-major chain fold of the STAIRCASE executors — the ONE
    copy of the layout math shared by stair train and stair predict:
    row r = d·M + c (doc suffixes stay row suffixes), per-chain vocab
    offsets into the stacked [M·W, T] table, and per-segment first-row
    / first-token offsets.  Returns
    (fold, unfold, sort, unsort, seg_r0, seg_n0, off)."""
    fold = lambda a: jnp.swapaxes(a, 0, 1).reshape((-1,) + a.shape[2:])
    unfold = lambda a: jnp.swapaxes(a.reshape((-1, m) + a.shape[1:]),
                                    0, 1)
    sort = lambda a: _take_docs(a, bc.perm, 1)
    unsort = lambda a: _take_docs(a, bc.inv_perm, 1)
    starts = np.cumsum([0] + list(bc.counts))
    seg_r0 = [int(s) * m for s in starts[:-1]]
    seg_n0 = [0] + list(bc.widths[:-1])
    off = jnp.arange(m, dtype=jnp.int32) * vocab_size
    return fold, unfold, sort, unsort, seg_r0, seg_n0, off


def build_plan(corpus, cfg: SLDAConfig, backend: str | None = None,
               *, chained: bool = False) -> "ExecutionPlan":
    """Build the plan for `(corpus, cfg, backend)` — all routing happens
    here, once.  `corpus` may be a padded `Corpus` (flat or chain-
    sharded) or a `BucketedCorpus`; it is canonicalized, NOT re-bucketed
    (schedules are data-dependent — build them with `build_schedule`,
    outside jit).  `chained=True` lifts a flat corpus to M=1 so the
    chain-batched loop applies.  `backend=None` resolves from the
    config and the default device (`SLDAConfig.resolve_backend`)."""
    if backend is None:
        backend = cfg.resolve_backend()
    bc = as_bucketed(corpus)
    if chained:
        bc = _lift_chain(bc)
    return ExecutionPlan(corpus=bc, cfg=cfg, backend=backend)


# ----------------------------------------------------------------- plan

@dataclasses.dataclass
class ExecutionPlan:
    """A canonical schedule plus every static routing decision, built
    once from `(corpus, cfg, backend)`.  Registered pytree: the
    schedule arrays are children, `(cfg, backend)` static aux — so a
    plan flows through jit/shard_map and its routing participates in
    the jit cache key."""

    corpus: BucketedCorpus
    cfg: SLDAConfig
    backend: str            # "jnp" | "pallas" | "pallas-interpret"

    # ---- routing (static)

    @property
    def use_pallas(self) -> bool:
        return self.backend != "jnp"

    @property
    def executor(self) -> str:
        """"blocks": one fused launch per bucket (the pallas route, and
        the degenerate 1-bucket jnp plan == the padded twins).
        "stair": the stacked staircase twins — multi-bucket jnp, where
        per-bucket launches would re-run the token loop per bucket
        (measured loser on CPU; BENCH_slda_ragged.json)."""
        if self.use_pallas or len(self.corpus.buckets) == 1:
            return "blocks"
        return "stair"

    @property
    def n_chains(self):
        return self.corpus.n_chains

    def sweep_schedule(self) -> tuple:
        """(sweeps_per_launch, n_full_launches, remainder_sweeps) —
        total sweeps stay exactly cfg.n_iters."""
        spl = self.cfg.sweeps_per_launch
        if spl <= 1:
            return 1, self.cfg.n_iters, 0
        n_full, rem = divmod(self.cfg.n_iters, spl)
        return spl, n_full, rem

    def train_doc_block(self, n_bucket_docs: int) -> int:
        """Fused-train doc block, clamped to the bucket (rounded to the
        sublane tile) so a small bucket doesn't pad to an empty block.
        Part of the SEMANTICS at spl>1 (the delayed-count partition)."""
        return min(self.cfg.train_doc_block, -(-n_bucket_docs // 8) * 8)

    def cache_key(self) -> tuple:
        """Everything a compiled program's identity depends on: the
        schedule's static shape signature (`types.bucket_signature`)
        plus `(cfg, backend)`.  Two plans with equal cache keys trace
        to identical programs — the serving layer's plan-cache key.
        NOTE the cache must hold DISTINCT jitted callables keyed on
        this (jit identity): a fresh `jax.jit(fn)` per request owns a
        fresh, empty trace cache and retraces every call no matter how
        the static args hash (serving/slda_service.py)."""
        return (bucket_signature(self.corpus), self.cfg, self.backend)

    def describe(self) -> dict:
        """The plan, human-readable — what launch/dryrun.py prints so a
        user can see WHY a route was picked before paying for a run."""
        bc, cfg = self.corpus, self.cfg
        spl, n_full, rem = self.sweep_schedule()
        slot = bc.padded_tokens()                  # per chain
        real = float(bc.real_tokens()) / (self.n_chains or 1)
        src_slots = bc.n_docs * bc.ctr_stride
        return {
            "backend": self.backend,
            "executor": self.executor,
            "chains": self.n_chains or 1,
            "docs_per_chain": bc.n_docs,
            "buckets": len(bc.buckets),
            "bucket_widths": list(bc.widths),
            "bucket_counts": list(bc.counts),
            "ctr_stride": bc.ctr_stride,
            "sweeps_per_launch": spl,
            "launches": n_full + (1 if rem else 0),
            "remainder_sweeps": rem,
            "count_refresh": ("rebuild every "
                              f"{cfg.count_rebuild_every} launches"
                              if cfg.count_rebuild_every > 0
                              else "incremental deltas only"),
            "slot_tokens_per_sweep": int(slot),
            "real_tokens_per_sweep": int(real),
            "padded_slot_frac": round(1.0 - real / max(src_slots, 1), 4),
            "slot_vs_effective_tok_ratio": round(slot / max(real, 1.0), 3),
            "sampler_mode": cfg.sampler_mode,
            "sparse_topic_cap": min(cfg.sparse_topic_cap, cfg.n_topics),
        }

    # ---- the ONE chain-batched EM loop -----------------------------

    def init_states(self, keys_init):
        """Chain-batched init over the schedule: the SAME per-chain
        [D, ctr_stride] threefry draw as the padded path, carved along
        each chain's schedule.  Returns (state, z_fill): state.z is a
        tuple of per-bucket [M, D_b, N_b] assignments, state.ndt is
        [M, D, T] in ORIGINAL order, z_fill keeps the init values of
        the all-padding slots beyond each bucket's width."""
        bc, cfg = self.corpus, self.cfg
        d_m, S = bc.perm.shape[-1], bc.ctr_stride
        z_fill = jax.vmap(lambda k: jax.random.randint(
            k, (d_m, S), 0, cfg.n_topics, jnp.int32))(keys_init)
        z_b = tuple(bc.split_padded(z_fill))
        counts = lambda b, zb: jax.vmap(
            lambda t, m_, zz: counts_from_assignments(
                t, m_, zz, cfg.n_topics, cfg.vocab_size))(b.tokens,
                                                          b.mask, zb)
        pieces, ntw = [], 0.0
        for b, zb in zip(bc.buckets, z_b):
            nd, nw, _ = counts(b, zb)
            pieces.append(nd)
            ntw = ntw + nw           # ±1 integer adds — exact in any order
        eta = jnp.full((keys_init.shape[0], cfg.n_topics), cfg.mu,
                       jnp.float32)
        state = GibbsState(z=z_b, ndt=bc.merge_docs(pieces), ntw=ntw,
                           nt=jnp.sum(ntw, axis=-1), eta=eta)
        return state, z_fill

    def _refresh_and_solve(self, z_new_b, ndt, state, rebuild_now):
        """THE EM boundary (the one copy): exact global count refresh —
        full rebuild or incremental (z_old, z_new) deltas, both exact —
        then the per-chain η ridge solve on ORIGINAL-order rows."""
        bc, cfg = self.corpus, self.cfg

        def rebuild(_):
            ntw2, pieces = 0.0, []
            for b, zb in zip(bc.buckets, z_new_b):
                nd, nw, _ = jax.vmap(
                    lambda t, m_, zz: counts_from_assignments(
                        t, m_, zz, cfg.n_topics, cfg.vocab_size))(
                    b.tokens, b.mask, zb)
                pieces.append(nd)
                ntw2 = ntw2 + nw
            return bc.merge_docs(pieces), ntw2, jnp.sum(ntw2, axis=-1)

        def incremental(_):
            ntw2, nt2 = state.ntw, state.nt
            for b, zo, zn in zip(bc.buckets, state.z, z_new_b):
                ntw2, nt2 = jax.vmap(apply_count_deltas)(
                    ntw2, nt2, b.tokens, b.mask, zo, zn)
            return ndt, ntw2, nt2

        if isinstance(rebuild_now, bool):
            ndt, ntw, nt = rebuild(None) if rebuild_now else \
                incremental(None)
        else:
            ndt, ntw, nt = jax.lax.cond(rebuild_now, rebuild, incremental,
                                        None)
        lengths = jnp.maximum(bc.lengths(), 1.0)
        eta = jax.vmap(lambda nd, l, yy: solve_eta(nd / l[:, None], yy,
                                                   self.cfg))(
            ndt, lengths, bc.y)
        return GibbsState(z=tuple(z_new_b), ndt=ndt, ntw=ntw, nt=nt,
                          eta=eta)

    def _inv_len_b(self):
        """Per-bucket 1/len rows — schedule-invariant; hoisted by
        train_em so the scan closes over it as a constant instead of
        re-deriving it every EM step."""
        bc = self.corpus
        return bc.split_docs(1.0 / jnp.maximum(bc.lengths(), 1.0))

    def _seed_sweep(self, state, ks, inv_len_b):
        """One seed-semantics sweep (spl=1): per-sweep threefry uniforms
        drawn at the padded [M, D, ctr_stride] shape (the bit-identity
        contract) and sliced along the schedule; one chain_axis sweep op
        per bucket."""
        from repro.kernels import ops   # local import (DESIGN.md §1)
        bc, cfg = self.corpus, self.cfg
        d_m, S = bc.perm.shape[-1], bc.ctr_stride
        uniforms = jax.vmap(lambda k: jax.random.uniform(k, (d_m, S)))(ks)
        u_b = bc.split_padded(uniforms)
        ndt_b = bc.split_docs(state.ndt)
        z_new_b, pieces = [], []
        for b, ub, zb, ndb, ilb in zip(bc.buckets, u_b, state.z, ndt_b,
                                       inv_len_b):
            z2, nd2 = ops.slda_gibbs_sweep(
                b.tokens, b.mask, ub, zb, ndb, b.y, ilb, state.ntw,
                state.nt, state.eta, alpha=cfg.alpha, beta=cfg.beta,
                rho=cfg.rho, supervised=True, use_pallas=self.use_pallas,
                chain_axis=True, sampler_mode=cfg.sampler_mode,
                sparse_topic_cap=cfg.sparse_topic_cap)
            z_new_b.append(z2)
            pieces.append(nd2)
        return z_new_b, bc.merge_docs(pieces)

    def _blocks_launch(self, state, ks, it, n_sweeps, inv_len_b):
        """One fused multi-sweep launch per bucket (chain grids intact,
        PRNG counter stride pinned to the source max_len) + EM boundary."""
        from repro.kernels import ops   # local import (DESIGN.md §1)
        bc, cfg = self.corpus, self.cfg
        d_m, S = bc.perm.shape[-1], bc.ctr_stride
        seeds = jax.vmap(lambda k: jax.random.randint(
            k, (d_m,), 0, jnp.iinfo(jnp.int32).max, jnp.int32))(ks)
        seeds_b = bc.split_docs(seeds)
        ndt_b = bc.split_docs(state.ndt)
        z_new_b, pieces = [], []
        for b, zb, ndb, sb, ilb in zip(bc.buckets, state.z, ndt_b,
                                       seeds_b, inv_len_b):
            z2, nd2 = ops.slda_train_sweeps(
                b.tokens, b.mask, zb, ndb, b.y, ilb, state.ntw, state.nt,
                state.eta, sb, alpha=cfg.alpha, beta=cfg.beta,
                rho=cfg.rho, n_sweeps=n_sweeps, supervised=True,
                doc_block=self.train_doc_block(b.tokens.shape[1]),
                use_pallas=self.use_pallas,
                product_form=cfg.product_form_sweeps, chain_axis=True,
                ctr_stride=S, sampler_mode=cfg.sampler_mode,
                sparse_topic_cap=cfg.sparse_topic_cap)
            z_new_b.append(z2)
            pieces.append(nd2)
        rebuild_now = self._rebuild_now(it)
        return self._refresh_and_solve(z_new_b, bc.merge_docs(pieces),
                                       state, rebuild_now)

    def _stair_staging(self):
        """Schedule-invariant staging of the stair trainer — the folded
        token/mask segments, per-row chain ids, folded y and 1/len —
        computed ONCE per trace (train_em hoists it so the launch scan
        closes over it as constants instead of re-folding the corpus
        every EM launch, which is what the pre-plan code did too)."""
        bc, cfg = self.corpus, self.cfg
        M, W = bc.n_chains, cfg.vocab_size
        d_m = bc.perm.shape[-1]
        (fold, unfold, sort, unsort, seg_r0, seg_n0,
         off) = _stair_layout(bc, M, W)
        return dict(
            fold=fold, unfold=unfold, sort=sort, unsort=unsort,
            seg_r0=seg_r0, seg_n0=seg_n0,
            tok_segs=[fold(s + off[:, None, None]) for s in
                      _stair_segments(bc, [b.tokens for b in bc.buckets])],
            mask_segs=[fold(s) for s in
                       _stair_segments(bc, [b.mask for b in bc.buckets])],
            chain_of_row=jnp.tile(jnp.arange(M, dtype=jnp.int32), d_m),
            y_f=fold(jnp.concatenate([b.y for b in bc.buckets], axis=1)),
            il_f=fold(jnp.concatenate(
                [1.0 / jnp.maximum(b.mask.sum(-1), 1.0)
                 for b in bc.buckets], axis=1)),
        )

    def _stair_launch(self, state, ks, it, n_sweeps, staging):
        """One STAIRCASE fused launch runs all in-launch sweeps for ALL
        chains (jnp route, multi-bucket): chains folded doc-major around
        a stacked [M·W, T] table, bucket widths walked as token-range
        segments over the live doc suffix — per-sweep step count stays
        N_max while slots collapse to the staircase.  The in-launch
        delayed-count partition is the WHOLE corpus (doc_block→D limit
        of the fused family)."""
        from repro.kernels.slda_train import slda_train_stair_jnp
        bc, cfg = self.corpus, self.cfg
        M = bc.n_chains
        d_m, S = bc.perm.shape[-1], bc.ctr_stride
        T, W = cfg.n_topics, cfg.vocab_size
        st = staging
        fold, unfold = st["fold"], st["unfold"]
        sort, unsort = st["sort"], st["unsort"]

        seeds = jax.vmap(lambda k: jax.random.randint(
            k, (d_m,), 0, jnp.iinfo(jnp.int32).max, jnp.int32))(ks)
        z_segs = [fold(s) for s in _stair_segments(bc, state.z)]
        z_segs_f, ndt_f = slda_train_stair_jnp(
            st["tok_segs"], st["mask_segs"], z_segs, st["seg_r0"],
            st["seg_n0"], fold(sort(seeds)), fold(sort(state.ndt)),
            st["y_f"], st["il_f"],
            jnp.swapaxes(state.ntw, 1, 2).reshape(M * W, T), state.nt,
            state.eta, st["chain_of_row"], alpha=cfg.alpha, beta=cfg.beta,
            rho=cfg.rho, vocab_size=W, ctr_stride=S, supervised=True,
            n_sweeps=n_sweeps, product_form=cfg.product_form_sweeps,
            sampler_mode=cfg.sampler_mode,
            sparse_topic_cap=cfg.sparse_topic_cap)
        z_new_b = _unstair_segments(bc, [unfold(z) for z in z_segs_f])
        ndt = unsort(unfold(ndt_f))
        return self._refresh_and_solve(z_new_b, ndt, state,
                                       self._rebuild_now(it))

    def _rebuild_now(self, it):
        every = self.cfg.count_rebuild_every
        return (it % every == 0) if every > 0 else False

    def n_boundaries(self) -> int:
        """EM boundaries this plan executes (count refresh + η solve
        points): one per sweep at spl=1, one per launch at spl>1 —
        the granularity at which an `em_hook` observes the state."""
        _, n_full, rem = self.sweep_schedule()
        return n_full + (1 if rem else 0)

    def train_em(self, k_sweeps, state0, *, em_hook=None, status0=None,
                 it_offset=0):
        """The stochastic-EM loop — the one copy.  spl=1 runs the seed
        path (threefry uniforms, η solve every sweep); spl>1 runs the
        fused-launch schedule through the plan's executor, with a
        remainder launch keeping total sweeps == cfg.n_iters exactly.

        `em_hook(state, it, status) -> (state, status)`, when given, is
        called at EVERY EM boundary *inside* the scan — the supervisor
        layer's attachment point (DESIGN.md §Fault-model): fault
        injection mutates the state, health probes fold per-chain flags
        into `status` (initialised from `status0`), all with zero extra
        host syncs; the accumulated status surfaces only in the return
        value `(state, status)`.  `it` is the EM-boundary index (sweep
        index at spl=1, launch index at spl>1) plus `it_offset`, which
        also offsets the count-rebuild cadence so a supervisor running
        the loop round-by-round keeps the single-run cadence.  With
        `em_hook=None` the loop is byte-for-byte the pre-hook program
        and returns `state` alone."""
        spl, n_full, rem = self.sweep_schedule()
        if spl == 1:
            inv_len_b = self._inv_len_b()   # hoisted: scan constant

            def em_step(carry, inp):
                state, status = carry
                ks, it = inp
                z_new_b, ndt = self._seed_sweep(state, ks, inv_len_b)
                state = self._refresh_and_solve(
                    z_new_b, ndt, state, self._rebuild_now(it))
                if em_hook is not None:
                    state, status = em_hook(state, it, status)
                return (state, status), None

            keys = jnp.moveaxis(jax.vmap(lambda k: jax.random.split(
                k, n_full))(k_sweeps), 0, 1)
            (state, status), _ = jax.lax.scan(
                em_step, (state0, status0),
                (keys, jnp.arange(n_full) + it_offset))
            return state if em_hook is None else (state, status)

        # schedule-invariant staging is hoisted HERE, once per trace —
        # the launch closures see it as scan constants
        if self.executor == "stair":
            launch = functools.partial(self._stair_launch,
                                       staging=self._stair_staging())
        else:
            launch = functools.partial(self._blocks_launch,
                                       inv_len_b=self._inv_len_b())
        keys = jnp.moveaxis(jax.vmap(lambda k: jax.random.split(
            k, n_full + (1 if rem else 0)))(k_sweeps), 0, 1)

        def launch_step(carry, inp):
            state, status = carry
            state = launch(state, inp[0], inp[1], spl)
            if em_hook is not None:
                state, status = em_hook(state, inp[1], status)
            return (state, status), None

        state, status = state0, status0
        if n_full:
            (state, status), _ = jax.lax.scan(
                launch_step, (state, status),
                (keys[:n_full], jnp.arange(n_full) + it_offset))
        if rem:
            it = jnp.asarray(n_full) + it_offset
            state = launch(state, keys[-1], it, rem)
            if em_hook is not None:
                state, status = em_hook(state, it, status)
        return state if em_hook is None else (state, status)

    def _export(self, state) -> SLDAModel:
        """Per-chain (φ̂, η̂, train MSE/acc) — what crosses the chain
        boundary; ORIGINAL-order rows so reductions match the padded
        operand order."""
        from .gibbs import phi_hat   # lazy: gibbs lazily imports plan
        bc, cfg = self.corpus, self.cfg
        lengths = jnp.maximum(bc.lengths(), 1.0)
        zb = state.ndt / lengths[..., None]
        yhat = jax.vmap(lambda z, e: z @ e)(zb, state.eta)
        y = bc.y
        mse = jax.vmap(lambda yh, yy: jnp.mean((yh - yy) ** 2))(yhat, y)
        acc = jax.vmap(lambda yh, yy: jnp.mean(
            ((yh > 0.5) == (yy > 0.5)).astype(jnp.float32)))(yhat, y)
        phi = jax.vmap(lambda s: phi_hat(s, cfg))(state)
        return SLDAModel(phi=phi, eta=state.eta, train_mse=mse,
                         train_acc=acc)

    def train(self, keys):
        """Full chain-batched training from explicit per-chain keys [M]
        (the entry the multi-device runner uses with fold_in-derived
        keys).  Returns (GibbsState, SLDAModel), each with leading chain
        dim; state.z is merged back to padded [M, D, ctr_stride] in
        ORIGINAL order against the init draw."""
        assert self.n_chains is not None, \
            "train wants a chain-sharded schedule (use chained=True)"
        ks = jax.vmap(jax.random.split)(keys)           # [M, 2, key]
        state0, z_fill = self.init_states(ks[:, 0])
        state = self.train_em(ks[:, 1], state0)
        models = self._export(state)
        state = GibbsState(z=self.corpus.merge_padded(state.z, z_fill),
                           ndt=state.ndt, ntw=state.ntw, nt=state.nt,
                           eta=state.eta)
        return state, models

    # ---- prediction ------------------------------------------------

    def _predict_blocks(self, phi, z0, seeds):
        """Per-bucket chain-batched fused prediction launches over a
        SHARED corpus, counter stride pinned (the pallas route, and the
        degenerate 1-bucket jnp plan == the padded twins)."""
        from repro.kernels import ops   # local import (DESIGN.md §1)
        bc, cfg = self.corpus, self.cfg
        S = bc.ctr_stride
        z0_b = bc.split_padded(z0, d_axis=1)
        seeds_b = bc.split_docs(seeds, d_axis=1)
        avgs = []
        for b, z0b, sb in zip(bc.buckets, z0_b, seeds_b):
            d_idx = jnp.arange(b.tokens.shape[0])[:, None]
            ndt0 = jax.vmap(
                lambda z: jnp.zeros((b.tokens.shape[0], cfg.n_topics),
                                    jnp.float32)
                .at[d_idx, z].add(b.mask))(z0b)
            avg, _ = ops.slda_predict_sweeps(
                b.tokens, b.mask, z0b, ndt0, phi, sb,
                alpha=cfg.alpha, n_burnin=cfg.n_pred_burnin,
                n_samples=cfg.n_pred_samples,
                doc_block=cfg.pred_doc_block,
                use_pallas=self.use_pallas, chain_axis=True, ctr_stride=S,
                sampler_mode=cfg.sampler_mode,
                sparse_topic_cap=cfg.sparse_topic_cap)
            avgs.append(avg)
        return bc.merge_docs(avgs, d_axis=1)         # [M, D, T] original

    def _predict_stair(self, phi, z0, seeds):
        """The STAIRCASE prediction executor (jnp route, multi-bucket):
        chains folded DOC-MAJOR (row r = d·M + c) around one stacked
        [M·W, T] table so doc suffixes stay row suffixes; bucket widths
        walked as token-range segments inside each sweep — sequential
        step count stays N_max while executed slots collapse to the
        staircase."""
        from repro.kernels.slda_predict import slda_predict_stair_jnp
        bc, cfg = self.corpus, self.cfg
        M, T, W = phi.shape
        D, S = bc.n_docs, bc.ctr_stride
        phi_t = jnp.swapaxes(phi, -1, -2).reshape(M * W, T)
        # shared fold/offset math with the stair trainer (_stair_layout);
        # token/mask segments differ only in that the corpus here is
        # SHARED across chains (broadcast instead of per-chain fold)
        fold, _, sort, _, seg_r0, seg_n0, off = _stair_layout(bc, M, W)
        seeds_f = fold(sort(seeds))
        z0_b = bc.split_padded(z0, d_axis=1)         # [M, Db, Nb] sorted
        ndt0_f = fold(jnp.concatenate(
            [jax.vmap(lambda z: jnp.zeros((b.tokens.shape[0], T),
                                          jnp.float32)
                      .at[jnp.arange(b.tokens.shape[0])[:, None], z]
                      .add(b.mask))(zb)
             for b, zb in zip(bc.buckets, z0_b)], axis=1))

        seg_tok = [(tk[:, None, :] + off[None, :, None])
                   .reshape(tk.shape[0] * M, tk.shape[1])
                   for tk in _stair_segments(bc, [b.tokens
                                                  for b in bc.buckets])]
        seg_mask = [jnp.broadcast_to(mk[:, None, :], mk.shape[:1] + (M,)
                                     + mk.shape[1:])
                    .reshape(-1, mk.shape[1])
                    for mk in _stair_segments(bc, [b.mask
                                                   for b in bc.buckets])]
        seg_z0 = [jnp.swapaxes(zk, 0, 1).reshape(-1, zk.shape[-1])
                  for zk in _stair_segments(bc, z0_b)]

        avg_f = slda_predict_stair_jnp(
            seg_tok, seg_mask, seg_z0, seg_r0, seg_n0, seeds_f, ndt0_f,
            phi_t, alpha=cfg.alpha, n_burnin=cfg.n_pred_burnin,
            n_samples=cfg.n_pred_samples, ctr_stride=S,
            sampler_mode=cfg.sampler_mode,
            sparse_topic_cap=cfg.sparse_topic_cap)
        avg_sorted = jnp.swapaxes(avg_f.reshape(D, M, T), 0, 1)
        return _take_docs(avg_sorted, bc.inv_perm, 1)   # [M, D, T] orig

    def predict_zbar(self, keys, models: SLDAModel):
        """Per-chain posterior-mean topic mixtures z̄ [M, D, T]
        (ORIGINAL doc order) for every document of the plan's (SHARED)
        corpus, from explicit per-chain keys [M] — the serving entry:
        a prediction service caches z̄ per document and re-derives
        ŷ = z̄ᵀη̂ under whatever alive mask is CURRENT, so a mid-stream
        drop/revive stays exact for cached results too
        (serving/slda_service.py)."""
        bc, cfg = self.corpus, self.cfg
        assert bc.n_chains is None, \
            "predict wants a shared (flat) corpus schedule"
        D, S = bc.n_docs, bc.ctr_stride
        ks = jax.vmap(jax.random.split)(keys)           # [M, 2, key]
        z0 = jax.vmap(lambda k: jax.random.randint(
            k, (D, S), 0, cfg.n_topics, jnp.int32))(ks[:, 0])
        seeds = jax.vmap(lambda k: jax.random.randint(
            k, (D,), 0, jnp.iinfo(jnp.int32).max, jnp.int32))(ks[:, 1])
        run = (self._predict_stair if self.executor == "stair"
               else self._predict_blocks)
        ndt_avg = run(models.phi, z0, seeds)            # [M, D, T] orig
        lengths = jnp.maximum(bc.lengths(), 1.0)
        return jax.vmap(lambda nd: nd / lengths[:, None])(ndt_avg)

    def predict(self, keys, models: SLDAModel):
        """Every chain predicts every document of the plan's (SHARED)
        corpus → ŷ [M, D], from explicit per-chain keys [M].  Same key
        tree as the deleted per-path implementations, so every cell is
        bit-identical to the path it replaced."""
        zb = self.predict_zbar(keys, models)
        return jax.vmap(lambda z, e: z @ e)(zb, models.eta)   # Eq. (5)


jax.tree_util.register_pytree_node(
    ExecutionPlan,
    lambda p: ((p.corpus,), (p.cfg, p.backend)),
    lambda aux, ch: ExecutionPlan(corpus=ch[0], cfg=aux[0], backend=aux[1]),
)
