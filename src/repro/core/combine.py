"""Combination rules — the heart of the paper.

The paper's insight: combining *sub-posteriors* of topics fails
(quasi-ergodicity — one posterior mode per topic permutation, chains lock
into different modes), but combining *sub-predictions* is sound because the
label is one-dimensional and unimodal.  Section III-C:

  Simple Average    ŷ = (1/M) Σ_m ŷ^(m)                         (Eq. 7)
  Weighted Average  ŷ = Σ_m w^(m) ŷ^(m),
                    w^(m) ∝ 1/MSE_train^(m)  (continuous labels)  (Eq. 8-9)
                    w^(m) ∝ acc_train^(m)    (binary labels)

Extensions beyond the paper (flagged as such):
  Median            ŷ = median_m ŷ^(m)    — robust combination in the spirit
                    of Minsker et al. (2014)'s median posterior, applied at
                    the prediction level where it is trivially valid.

All rules accept a per-chain `alive` mask: a crashed or straggling chain is
simply dropped and the weights renormalize over survivors.  This is the
fault-tolerance dividend of communication-free training (DESIGN.md §4).
"""
from __future__ import annotations

import jax.numpy as jnp

_EPS = 1e-12


def _alive(yhat: jnp.ndarray, alive) -> jnp.ndarray:
    if alive is None:
        return jnp.ones((yhat.shape[0],), yhat.dtype)
    return alive.astype(yhat.dtype)


def simple_average(yhat: jnp.ndarray, alive=None) -> jnp.ndarray:
    """yhat: [M, D_test] per-chain predictions → [D_test]."""
    a = _alive(yhat, alive)
    return (a[:, None] * yhat).sum(0) / jnp.maximum(a.sum(), 1.0)


def weighted_average(yhat: jnp.ndarray, train_mse: jnp.ndarray = None,
                     train_acc: jnp.ndarray = None, alive=None) -> jnp.ndarray:
    """Weights from inverse training MSE (continuous) or training accuracy
    (binary); exactly one of train_mse / train_acc must be given."""
    a = _alive(yhat, alive)
    if (train_mse is None) == (train_acc is None):
        raise ValueError("pass exactly one of train_mse / train_acc")
    raw = 1.0 / (train_mse + _EPS) if train_mse is not None else train_acc
    w = raw * a
    w = w / jnp.maximum(w.sum(), _EPS)
    return w @ yhat


def median(yhat: jnp.ndarray, alive=None) -> jnp.ndarray:
    """[extension] robust elementwise median over alive chains."""
    a = _alive(yhat, alive)
    # push dead chains to +inf/-inf symmetrically so they never win the median
    big = jnp.nanmax(jnp.abs(yhat)) + 1.0
    lo = jnp.where(a[:, None] > 0, yhat, -big)
    hi = jnp.where(a[:, None] > 0, yhat, big)
    # average of median over lo-padded and hi-padded cancels the padding bias
    return 0.5 * (jnp.median(lo, axis=0) + jnp.median(hi, axis=0))


COMBINERS = {
    "simple": simple_average,
    "weighted": weighted_average,
    "median": median,
}
