"""Combination rules — the heart of the paper.

The paper's insight: combining *sub-posteriors* of topics fails
(quasi-ergodicity — one posterior mode per topic permutation, chains lock
into different modes), but combining *sub-predictions* is sound because the
label is one-dimensional and unimodal.  Section III-C:

  Simple Average    ŷ = (1/M) Σ_m ŷ^(m)                         (Eq. 7)
  Weighted Average  ŷ = Σ_m w^(m) ŷ^(m),
                    w^(m) ∝ 1/MSE_train^(m)  (continuous labels)  (Eq. 8-9)
                    w^(m) ∝ acc_train^(m)    (binary labels)

Extensions beyond the paper (flagged as such):
  Median            ŷ = median_m ŷ^(m)    — robust combination in the spirit
                    of Minsker et al. (2014)'s median posterior, applied at
                    the prediction level where it is trivially valid.

All rules accept a per-chain `alive` mask: a crashed or straggling chain is
simply dropped and the weights renormalize over survivors.  This is the
fault-tolerance dividend of communication-free training (DESIGN.md
§Fault-model): because chains never communicate, dropping one is EXACT —
the surviving sub-ensemble's combined prediction is bit-identical to an
ensemble that never contained the dead chain.

Quarantine safety: a dead chain's predictions and weights are zeroed via
`where` BEFORE any reduction, so a NaN/Inf-poisoned chain can never
contaminate the combine (0 * NaN is NaN — a plain mask-multiply is not
enough).  An all-dead mask falls back to the UNMASKED combine and warns
when the mask is concrete: returning the data-dependent answer is more
useful than the renormalize-by-zero NaN it used to produce, and callers
who must fail hard can check `all_dead(alive)` themselves.
"""
from __future__ import annotations

import warnings

import jax.numpy as jnp
import numpy as np

_EPS = 1e-12


def all_dead(alive) -> bool:
    """Host-side check for the degenerate mask (None counts as alive)."""
    return alive is not None and float(np.asarray(alive).sum()) == 0.0


def _alive(yhat: jnp.ndarray, alive):
    """The ONE copy of the alive-mask semantics.  Returns
    `(mask, yhat_safe)`: the effective mask (all-ones fallback when every
    chain is dead) and the predictions with dead rows zeroed so poison
    cannot propagate through the reductions."""
    if alive is None:
        return jnp.ones((yhat.shape[0],), yhat.dtype), yhat
    a = alive.astype(yhat.dtype)
    try:                       # concrete mask → warn on the fallback
        if float(np.asarray(a).sum()) == 0.0:
            warnings.warn("combine: all-dead alive mask — falling back "
                          "to the unmasked combine", RuntimeWarning,
                          stacklevel=3)
    except Exception:          # traced under jit — no host warning possible
        pass
    a = jnp.where(a.sum() > 0, a, jnp.ones_like(a))
    return a, jnp.where(a[:, None] > 0, yhat, 0.0)


def simple_average(yhat: jnp.ndarray, alive=None) -> jnp.ndarray:
    """yhat: [M, D_test] per-chain predictions → [D_test]."""
    a, safe = _alive(yhat, alive)
    return (a[:, None] * safe).sum(0) / jnp.maximum(a.sum(), 1.0)


def weighted_average(yhat: jnp.ndarray, train_mse: jnp.ndarray = None,
                     train_acc: jnp.ndarray = None, alive=None) -> jnp.ndarray:
    """Weights from inverse training MSE (continuous) or training accuracy
    (binary); exactly one of train_mse / train_acc must be given.  A dead
    or non-finite-weight chain contributes exactly zero — its (possibly
    NaN) statistic is excluded via `where`, not multiplied by zero."""
    a, safe = _alive(yhat, alive)
    if (train_mse is None) == (train_acc is None):
        raise ValueError("pass exactly one of train_mse / train_acc")
    raw = 1.0 / (train_mse + _EPS) if train_mse is not None else train_acc
    w = jnp.where((a > 0) & jnp.isfinite(raw), raw, 0.0)
    w = w / jnp.maximum(w.sum(), _EPS)
    return w @ safe


def median(yhat: jnp.ndarray, alive=None) -> jnp.ndarray:
    """[extension] robust elementwise median over alive chains.

    Dead chains are sorted to the top and the median indices are computed
    from the ALIVE count, so dropping a chain via `alive` equals removing
    it — exactly.  (An earlier version averaged medians over ±big-padded
    copies, which mis-locates the median whenever the padding straddles
    it, e.g. one survivor out of two chains came back halved.)  All-dead
    falls back to the unmasked median like the other rules (`_alive`).
    """
    a, safe = _alive(yhat, alive)
    big = jnp.nanmax(jnp.abs(safe)) + 1.0
    s = jnp.sort(jnp.where(a[:, None] > 0, safe, big), axis=0)
    n = jnp.sum(a > 0).astype(jnp.int32)
    m = yhat.shape[0]
    i0 = jnp.clip((n - 1) // 2, 0, m - 1)
    i1 = jnp.clip(n // 2, 0, m - 1)
    return 0.5 * (jnp.take(s, i0, axis=0) + jnp.take(s, i1, axis=0))


COMBINERS = {
    "simple": simple_average,
    "weighted": weighted_average,
    "median": median,
}
