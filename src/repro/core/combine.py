"""Combination rules — the heart of the paper.

The paper's insight: combining *sub-posteriors* of topics fails
(quasi-ergodicity — one posterior mode per topic permutation, chains lock
into different modes), but combining *sub-predictions* is sound because the
label is one-dimensional and unimodal.  Section III-C:

  Simple Average    ŷ = (1/M) Σ_m ŷ^(m)                         (Eq. 7)
  Weighted Average  ŷ = Σ_m w^(m) ŷ^(m),
                    w^(m) ∝ 1/MSE_train^(m)  (continuous labels)  (Eq. 8-9)
                    w^(m) ∝ acc_train^(m)    (binary labels)

Extensions beyond the paper (flagged as such):
  Median            ŷ = median_m ŷ^(m)    — robust combination in the spirit
                    of Minsker et al. (2014)'s median posterior, applied at
                    the prediction level where it is trivially valid.

All rules accept a per-chain `alive` mask: a crashed or straggling chain is
simply dropped and the weights renormalize over survivors.  This is the
fault-tolerance dividend of communication-free training (DESIGN.md §4).
"""
from __future__ import annotations

import jax.numpy as jnp

_EPS = 1e-12


def _alive(yhat: jnp.ndarray, alive) -> jnp.ndarray:
    if alive is None:
        return jnp.ones((yhat.shape[0],), yhat.dtype)
    return alive.astype(yhat.dtype)


def simple_average(yhat: jnp.ndarray, alive=None) -> jnp.ndarray:
    """yhat: [M, D_test] per-chain predictions → [D_test]."""
    a = _alive(yhat, alive)
    return (a[:, None] * yhat).sum(0) / jnp.maximum(a.sum(), 1.0)


def weighted_average(yhat: jnp.ndarray, train_mse: jnp.ndarray = None,
                     train_acc: jnp.ndarray = None, alive=None) -> jnp.ndarray:
    """Weights from inverse training MSE (continuous) or training accuracy
    (binary); exactly one of train_mse / train_acc must be given."""
    a = _alive(yhat, alive)
    if (train_mse is None) == (train_acc is None):
        raise ValueError("pass exactly one of train_mse / train_acc")
    raw = 1.0 / (train_mse + _EPS) if train_mse is not None else train_acc
    w = raw * a
    w = w / jnp.maximum(w.sum(), _EPS)
    return w @ yhat


def median(yhat: jnp.ndarray, alive=None) -> jnp.ndarray:
    """[extension] robust elementwise median over alive chains.

    Dead chains are sorted to the top and the median indices are computed
    from the ALIVE count, so dropping a chain via `alive` equals removing
    it — exactly.  (An earlier version averaged medians over ±big-padded
    copies, which mis-locates the median whenever the padding straddles
    it, e.g. one survivor out of two chains came back halved.)  All-dead
    degrades to 0.0, matching the other rules.
    """
    a = _alive(yhat, alive)
    big = jnp.nanmax(jnp.abs(yhat)) + 1.0
    s = jnp.sort(jnp.where(a[:, None] > 0, yhat, big), axis=0)
    n = jnp.sum(a > 0).astype(jnp.int32)
    m = yhat.shape[0]
    i0 = jnp.clip((n - 1) // 2, 0, m - 1)
    i1 = jnp.clip(n // 2, 0, m - 1)
    med = 0.5 * (jnp.take(s, i0, axis=0) + jnp.take(s, i1, axis=0))
    return jnp.where(n > 0, med, jnp.zeros_like(med))


COMBINERS = {
    "simple": simple_average,
    "weighted": weighted_average,
    "median": median,
}
