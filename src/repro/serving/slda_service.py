"""Continuous-batching sLDA prediction service (ROADMAP item 1).

The paper's zero-communication chains make per-request fan-out to M
chains embarrassingly parallel; this module is the serving surface that
routes real request traffic through the PR 5 `ExecutionPlan` layer:

  * **micro-batcher** — incoming ragged documents accumulate into
    fixed-shape micro-batches.  Every batch has the SAME slot layout: a
    width *ladder* of bucket rungs (ascending token widths, the last
    rung always `max_doc_len`) with a fixed per-rung slot *quota*
    (`calibrate_slots` picks both from a sample of the traffic's length
    distribution via the same cost-model DP that `bucket_corpus` uses).
    A document occupies one slot of the smallest rung that fits it
    (escalating to a wider rung when its own is full); unused slots are
    masked-out dummies.  The payoff: every dispatch has ONE static
    bucket signature, so steady-state traffic never retraces.

  * **retrace-free plan cache** — compiled programs are cached as
    DISTINCT `jax.jit` callables in a dict keyed on
    `ExecutionPlan.cache_key()` (the bucket-width signature +
    (cfg, backend)).  This is jit *identity*, not static-arg hashing: a
    fresh `jax.jit(fn)` per request owns a fresh, empty trace cache and
    retraces every call no matter how the static args hash — the cache
    must hold the callables themselves.  A trace counter incremented
    from the traced function body (a Python side effect that fires once
    per trace, never per call) makes the no-retrace property observable
    and assertable (tests, BENCH_slda_serving.json).

  * **result cache** — per-document posterior-mean topic mixtures z̄
    (theta) and per-chain ŷ are cached by content hash; a repeat
    document is served without occupying a slot.  The cache stores
    PER-CHAIN values, never the combined scalar, so…

  * **mid-stream drop/revive is exact** — `chain_weights` rides as a
    jit ARGUMENT of every cached callable (dropping a chain cannot
    retrace), and combination happens under the weights current at
    serve time — for fresh batches inside the compiled dispatch, for
    cache hits on the host via the same `core.combine` rules.  Because
    chains share nothing, serving the surviving sub-ensemble is
    bit-identical to an ensemble that never contained the dead chain
    (DESIGN.md §Fault-model).

Numerical contract: a dispatch is exactly `plan.predict_zbar` over the
micro-batch corpus — the serving machinery (slot packing, caches,
combine plumbing) adds ZERO deviation versus calling the plan layer
directly, and the bucketed slot layout is bit-identical per document to
the padded (`bucketed=False`) layout by the `ctr_stride` pinning of
DESIGN.md §Ragged-execution (tests/test_slda_serving.py).

Robustness layer (DESIGN.md §Serving-robustness): the service survives
traffic and faults without ever giving up the contracts above —

  * **admission control + deadlines** — the pending queue is bounded
    (`max_pending`), a token bucket rate-limits intake
    (`rate_limit_per_s`/`rate_burst`), and every request may carry a
    deadline.  Over-limit requests are SHED with a typed `Result`
    status (never an opaque exception), `_pack` orders pending work
    earliest-deadline-first, and an expired request is shed BEFORE it
    can occupy a slot.  `drain(deadline_s=...)` bounds how long a
    shutdown/flush storm can run.
  * **serve-time health + degraded mode** — model tables are screened
    with `core.supervisor.model_status` at load and at every hot
    reload, and per-chain ŷ is screened at dispatch
    (`robust_checks`); an unhealthy chain is auto-quarantined through
    the same `chain_weights`-as-jit-argument path as a manual
    `drop_chain`, so degradation is EXACT (survivors bit-identical to
    a clean service) and retrace-free.  An all-dead ensemble falls
    back to the unmasked combine + RuntimeWarning (`core.combine`'s
    PR 6 semantics) instead of dividing by zero.
  * **hot checkpoint reload** — `reload_from_checkpoint` performs an
    epoch-versioned atomic model swap: validate the manifest, load,
    screen, THEN swap; a torn/`BadZipFile`/mislabelled/wrong-M
    checkpoint is rejected with the old epoch kept serving.  The
    result cache is keyed on (content hash, model epoch), so a swap
    can never serve stale predictions, and because models ride as jit
    ARGUMENTS a swap never retraces.
"""
from __future__ import annotations

import collections
import dataclasses
import hashlib
import math
import time
import zipfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import latest_step, restore_checkpoint
from repro.core.combine import median, simple_average, weighted_average
from repro.core.plan import as_bucketed, build_plan
from repro.core.supervisor import (MODEL_FAULTS, F_NAN_YHAT,
                                   describe_status, model_status)
from repro.core.types import (BucketedCorpus, Corpus, SLDAConfig, SLDAModel,
                              _dp_bucket_cuts)

# ------------------------------------------------------- typed outcomes

#: `Result.status` values — every submitted request id resolves to ONE
#: of these (invalid documents are the exception: they raise
#: `InvalidDocument` and never get an id).
STATUS_OK = "ok"
STATUS_SHED_QUEUE = "shed_queue_full"    # bounded queue at capacity
STATUS_SHED_RATE = "shed_rate_limit"     # token bucket empty
STATUS_EXPIRED = "expired"               # deadline passed before dispatch
SHED_STATUSES = (STATUS_SHED_QUEUE, STATUS_SHED_RATE, STATUS_EXPIRED)


class InvalidDocument(ValueError):
    """Typed `submit()` rejection — the request can NEVER be served
    (malformed payload), as opposed to the shed statuses (well-formed
    but dropped by overload policy).  `reason` is one of "empty_doc",
    "doc_too_long", "bad_token_id"; catching plain ValueError keeps
    working."""

    def __init__(self, reason: str, detail: str):
        super().__init__(f"{reason}: {detail}")
        self.reason = reason


# ------------------------------------------------------------ calibration

def calibrate_slots(lengths, batch_docs: int, max_doc_len: int, *,
                    n_buckets: int = 4, token_block: int = 8,
                    overhead_docs: float = 0.0):
    """Pick the service's (width ladder, slot quota) from a sample of
    document lengths — the same cost-model DP as `bucket_corpus`
    (`_dp_bucket_cuts`: minimize Σ_b (D_b + overhead)·N_b over
    contiguous cuts of the sorted length profile), then scale the
    bucket document counts to `batch_docs` slots by largest remainder.
    The widest rung is forced to `max_doc_len` (and keeps ≥1 slot) so
    every admissible request fits some rung.  Returns
    (widths, quota) — equal-length tuples, sum(quota) == batch_docs."""
    lens = np.clip(np.asarray(lengths).ravel(), 1, max_doc_len)
    if batch_docs < 1:
        raise ValueError("batch_docs must be >= 1")
    lens_sorted = np.sort(lens)
    round_w = np.minimum(
        max_doc_len,
        np.maximum(token_block, -(-lens_sorted // token_block)
                   * token_block)).astype(int)
    segs = []
    for w in round_w:
        if segs and segs[-1][1] == int(w):
            segs[-1][0] += 1
        else:
            segs.append([1, int(w)])
    segs = [(c, w) for c, w in segs]
    ends = _dp_bucket_cuts(segs, max(1, min(n_buckets, batch_docs)),
                           float(overhead_docs))
    widths, counts, o = [], [], 0
    for e in ends:
        counts.append(sum(c for c, _ in segs[o:e]))
        widths.append(segs[e - 1][1])
        o = e
    widths[-1] = max_doc_len

    # largest-remainder scaling of counts → quota, each rung >= 1 slot
    total = float(sum(counts))
    raw = [batch_docs * c / total for c in counts]
    quota = [max(1, int(f)) for f in raw]
    while sum(quota) > batch_docs:        # too many rungs for the slots:
        widths.pop(0)                     # merge the narrowest rung up
        quota.pop(0)
        raw.pop(0)
    rema = sorted(range(len(quota)), key=lambda i: raw[i] - int(raw[i]),
                  reverse=True)
    i = 0
    while sum(quota) < batch_docs:
        quota[rema[i % len(quota)]] += 1
        i += 1
    return tuple(widths), tuple(quota)


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    """Static configuration of the prediction service (hashable — part
    of every cached program's closure)."""

    max_doc_len: int = 256        # admission limit == PRNG ctr_stride
    batch_docs: int = 32          # slots per micro-batch
    width_ladder: tuple = ()      # ascending rung widths; () = 1 rung
                                  # at max_doc_len (the padded layout)
    slot_quota: tuple = ()        # slots per rung; () = all batch_docs
                                  # on the single rung
    combine: str = "weighted"     # "simple" | "weighted" | "median"
    bucketed: bool = True         # False = dispatch the padded
                                  # degenerate schedule (parity twin /
                                  # A-B baseline); the ladder still
                                  # packs, only the dispatch layout
                                  # changes — bit-identical outputs
    cache_results: bool = True    # theta/ŷ result cache on content hash
    max_cached_results: int = 4096

    # ---- robustness policy (DESIGN.md §Serving-robustness)
    max_pending: int = 0          # queue bound; 0 = unbounded.  New
                                  # submissions shed (typed) at the cap
    default_deadline_s: float = 0.0   # per-request deadline when the
                                  # caller gives none; 0 = no deadline
    rate_limit_per_s: float = 0.0     # token-bucket admission rate;
                                  # 0 = off
    rate_burst: int = 0           # bucket capacity; 0 = batch_docs
    robust_checks: bool = True    # screen model tables at (re)load and
                                  # per-chain ŷ at dispatch; False is
                                  # the checks-off A/B baseline
    auto_flush: bool = True       # False = caller-driven flush (open-
                                  # loop serving; lets a dispatcher that
                                  # fell behind exercise the queue bound)

    def __post_init__(self):
        ladder = self.width_ladder or (self.max_doc_len,)
        quota = self.slot_quota or (self.batch_docs,)
        if len(ladder) != len(quota):
            raise ValueError("width_ladder and slot_quota lengths differ")
        if list(ladder) != sorted(set(ladder)):
            raise ValueError("width_ladder must strictly ascend")
        if ladder[-1] != self.max_doc_len:
            raise ValueError("widest rung must equal max_doc_len")
        if sum(quota) != self.batch_docs or min(quota) < 1:
            raise ValueError("slot_quota must sum to batch_docs, each >=1")
        if self.max_pending and self.max_pending < self.batch_docs:
            raise ValueError("max_pending must be 0 (unbounded) or >= "
                             "batch_docs — a bound below one micro-batch "
                             "could never fill a dispatch")
        if self.rate_limit_per_s < 0 or self.default_deadline_s < 0 \
                or self.rate_burst < 0:
            raise ValueError("rate/deadline knobs must be >= 0")
        object.__setattr__(self, "width_ladder", tuple(ladder))
        object.__setattr__(self, "slot_quota", tuple(quota))

    @classmethod
    def calibrated(cls, lengths, *, max_doc_len: int = 256,
                   batch_docs: int = 32, n_buckets: int = 4,
                   token_block: int = 8, overhead_docs: float = 0.0,
                   **kw) -> "ServiceConfig":
        """Build a config whose slot layout fits a traffic sample."""
        widths, quota = calibrate_slots(
            lengths, batch_docs, max_doc_len, n_buckets=n_buckets,
            token_block=token_block, overhead_docs=overhead_docs)
        return cls(max_doc_len=max_doc_len, batch_docs=batch_docs,
                   width_ladder=widths, slot_quota=quota, **kw)


@dataclasses.dataclass
class Result:
    """One served prediction.  Per-chain values are kept so the
    combined scalar can be re-derived under any later alive mask.
    A shed/expired request resolves to a Result too (`status` in
    `SHED_STATUSES`, `yhat` = NaN, per-chain fields None) — overload is
    a typed outcome, never a KeyError."""

    req_id: int
    yhat: float              # combined ŷ under the weights AT SERVE TIME
    yhat_chains: np.ndarray  # [M] per-chain ŷ (None when shed)
    zbar: np.ndarray         # [M, T] per-chain posterior-mean θ (None
                             # when shed)
    latency_s: float
    from_cache: bool
    status: str = STATUS_OK


def _combine_yhat(rule: str, yhat, chain_weights, train_mse):
    """The ONE combine used for fresh batches (inside the compiled
    dispatch) and cache hits (host side) — `core.combine` semantics,
    alive mask = nonzero chain weight."""
    alive = (chain_weights > 0).astype(yhat.dtype)
    if rule == "weighted":
        return weighted_average(yhat, train_mse=train_mse, alive=alive)
    if rule == "median":
        return median(yhat, alive=alive)
    if rule == "simple":
        return simple_average(yhat, alive=alive)
    raise ValueError(f"unknown combine rule {rule!r}")


# ---------------------------------------------------------------- service

class SLDAPredictionService:
    """Continuous-batching prediction over a trained M-chain ensemble.

      svc = SLDAPredictionService(models, cfg, ServiceConfig.calibrated(
                lengths_sample, max_doc_len=256, batch_docs=32))
      rid = svc.submit(token_ids)          # auto-flushes at batch_docs
      svc.drain()                          # force out partial batches
      svc.result(rid).yhat

    `models` is a chain-stacked `SLDAModel` ([M, ...] leaves, e.g. from
    `train_chains`).  All dispatches run through the `ExecutionPlan`
    layer; see the module docstring for the caching/exactness story.
    """

    def __init__(self, models: SLDAModel, cfg: SLDAConfig,
                 svc: ServiceConfig, *, key=None, chain_weights=None,
                 backend: str | None = None, clock=None):
        self.models = models
        self.cfg = cfg
        self.svc = svc
        self.n_chains = int(models.eta.shape[0])
        self.backend = backend if backend is not None \
            else cfg.resolve_backend()
        self.key = key if key is not None else jax.random.PRNGKey(0)
        self.chain_weights = (jnp.ones((self.n_chains,), jnp.float32)
                              if chain_weights is None
                              else jnp.asarray(chain_weights, jnp.float32))
        self._plan_cache = {}                   # cache_key → jitted fn
        self._trace_counts = collections.Counter()   # cache_key → traces
        self._results = {}                      # req_id → Result
        # (content hash, model epoch) → (zbar, yhat): the epoch in the
        # key is what keeps a hot reload from serving stale predictions
        self._result_cache = collections.OrderedDict()
        # (req_id, np tokens, t_submit, absolute deadline or +inf)
        self._pending = collections.deque()
        self._next_id = 0
        self._batches = 0
        self._stats = collections.Counter()
        # injectable clock (VirtualClock in the chaos suite) — every
        # deadline/rate decision reads THIS, so overload behaviour is
        # replayable deterministically
        self._clock = clock if clock is not None else time.perf_counter
        self._model_epoch = 0                   # bumps on every hot swap
        self._ckpt_step = None                  # step of the live epoch
        self._health = np.zeros(self.n_chains, np.uint32)  # latched flags
        burst = svc.rate_burst or svc.batch_docs
        self._tokens = float(burst)             # token bucket, full start
        self._bucket_t = self._clock()
        if svc.robust_checks:
            self._screen_models(models, source="init")

    @property
    def chain_weights(self):
        return self._chain_weights

    @chain_weights.setter
    def chain_weights(self, w):
        """Keep a host-side mirror in sync — the dispatch-time health
        screen reads weights EVERY flush, and a device→host transfer
        per micro-batch is exactly the kind of overhead the <=5%
        checks budget can't afford."""
        self._chain_weights = w
        self._w_host = np.asarray(w)

    def _screen_models(self, models, *, source: str):
        """Latch `model_status` flags and quarantine chains whose
        TABLES are unhealthy (NaN/Inf φ̂ or η, broken φ̂ row sums,
        unusable train MSE).  Quarantine multiplies the weight by the
        alive mask, so operator-zeroed chains stay zeroed."""
        status = np.array(model_status(models))
        self._health = status
        bad = (status & MODEL_FAULTS) != 0
        if bad.any():
            self._stats["load_quarantines"] += int(bad.sum())
            self.chain_weights = self.chain_weights \
                * jnp.asarray(~bad, jnp.float32)
        return status

    def _take_token(self) -> bool:
        """Token-bucket admission: refill at `rate_limit_per_s` up to
        the burst capacity, spend one per admitted request.  Always
        True when rate limiting is off."""
        rate = self.svc.rate_limit_per_s
        if rate <= 0:
            return True
        now = self._clock()
        burst = self.svc.rate_burst or self.svc.batch_docs
        self._tokens = min(float(burst),
                           self._tokens + (now - self._bucket_t) * rate)
        self._bucket_t = now
        if self._tokens < 1.0:
            return False
        self._tokens -= 1.0
        return True

    def _shed(self, rid: int, status: str, t0: float) -> int:
        """Resolve a request to a typed shed Result (DESIGN.md
        §Serving-robustness: overload is an outcome, not an
        exception)."""
        self._results[rid] = Result(
            req_id=rid, yhat=float("nan"), yhat_chains=None, zbar=None,
            latency_s=self._clock() - t0, from_cache=False, status=status)
        self._stats[status] += 1
        return rid

    # ------------------------------------------------------------ intake

    def submit(self, tokens, *, deadline_s: float | None = None) -> int:
        """Enqueue one ragged document (int token ids, 1-D).  Returns a
        request id; auto-flushes whenever a full micro-batch is
        pending.  A content-hash repeat is served straight from the
        result cache (no slot), combined under the CURRENT weights.

        Admission order: validate (raises `InvalidDocument` — malformed
        payloads never consume a request id or a rate token), result
        cache, rate limit, queue bound.  `deadline_s` is a per-request
        latency budget from now (falls back to
        `svc.default_deadline_s`; 0/None = no deadline); a request
        whose deadline lapses before dispatch resolves to a typed
        `STATUS_EXPIRED` Result instead of occupying a slot."""
        toks = np.asarray(tokens, np.int32).ravel()
        if toks.size < 1:
            self._stats["rejected_invalid"] += 1
            raise InvalidDocument("empty_doc", "document has no tokens")
        if toks.size > self.svc.max_doc_len:
            self._stats["rejected_invalid"] += 1
            raise InvalidDocument(
                "doc_too_long",
                f"doc length {toks.size} > max_doc_len "
                f"{self.svc.max_doc_len}")
        if toks.min() < 0 or toks.max() >= self.cfg.vocab_size:
            self._stats["rejected_invalid"] += 1
            raise InvalidDocument(
                "bad_token_id",
                f"token ids must lie in [0, {self.cfg.vocab_size}) "
                f"(got min {int(toks.min())}, max {int(toks.max())})")
        rid = self._next_id
        self._next_id += 1
        t0 = self._clock()
        if self.svc.cache_results:
            h = hashlib.blake2b(toks.tobytes(), digest_size=16).digest()
            hit = self._result_cache.get((h, self._model_epoch))
            if hit is not None:
                self._result_cache.move_to_end((h, self._model_epoch))
                zbar, yhat = hit
                comb = float(_combine_yhat(
                    self.svc.combine, jnp.asarray(yhat)[:, None],
                    self.chain_weights, self.models.train_mse)[0])
                self._results[rid] = Result(
                    req_id=rid, yhat=comb, yhat_chains=yhat, zbar=zbar,
                    latency_s=self._clock() - t0, from_cache=True)
                self._stats["cache_hits"] += 1
                return rid
        if not self._take_token():
            return self._shed(rid, STATUS_SHED_RATE, t0)
        if self.svc.max_pending \
                and len(self._pending) >= self.svc.max_pending:
            return self._shed(rid, STATUS_SHED_QUEUE, t0)
        if deadline_s is None:
            deadline_s = self.svc.default_deadline_s
        deadline = t0 + deadline_s if deadline_s else math.inf
        self._pending.append((rid, toks, t0, deadline))
        if self.svc.auto_flush:
            while len(self._pending) >= self.svc.batch_docs:
                self.flush()
        return rid

    # ----------------------------------------------------------- packing

    def _pack(self):
        """Pack pending docs into the fixed slot layout.  Two
        robustness steps run FIRST: requests whose deadline already
        lapsed are shed (`STATUS_EXPIRED`) before they can waste a
        slot, and survivors are ordered earliest-deadline-first
        (ties broken by request id, so deadline-free traffic — every
        deadline +inf — reduces to the original FIFO order).  Each doc
        then takes a free slot of the smallest rung that fits it,
        escalating to wider rungs when its own is full; docs that fit
        nowhere stay pending for the next batch.  Returns (per-rung
        doc lists, n_placed)."""
        ladder, quota = self.svc.width_ladder, self.svc.slot_quota
        now = self._clock()
        live = []
        while self._pending:
            item = self._pending.popleft()
            if item[3] < now:
                self._shed(item[0], STATUS_EXPIRED, item[2])
                continue
            live.append(item)
        live.sort(key=lambda it: (it[3], it[0]))    # EDF, FIFO fallback
        free = list(quota)
        placed = [[] for _ in ladder]
        leftover = collections.deque()
        n = 0
        for item in live:
            L = item[1].size
            rung = next(i for i, w in enumerate(ladder) if w >= L)
            slot = next((i for i in range(rung, len(ladder))
                         if free[i] > 0), None)
            if slot is None:
                leftover.append(item)
                continue
            free[slot] -= 1
            placed[slot].append(item)
            n += 1
        self._pending = leftover
        return placed, n

    def _build_schedule(self, placed):
        """Slot lists → (BucketedCorpus, slot_meta).  The micro-batch's
        ORIGINAL doc order is the rung-major slot order (real docs
        first, dummies after, per rung), so perm == identity and the
        padded twin (`bucketed=False`) sees the exact same rows —
        that's what makes the two layouts bit-comparable per slot.
        slot_meta[d] is (req_id, t_submit) or None for a dummy."""
        ladder, quota = self.svc.width_ladder, self.svc.slot_quota
        S = self.svc.max_doc_len
        meta, buckets = [], []
        tok_rows, mask_rows = [], []
        for w, q, docs in zip(ladder, quota, placed):
            bt = np.zeros((q, w), np.int32)
            bm = np.zeros((q, w), np.float32)
            for i, (rid, toks, t0, _deadline) in enumerate(docs):
                bt[i, :toks.size] = toks
                bm[i, :toks.size] = 1.0
                meta.append((rid, t0))
            meta.extend([None] * (q - len(docs)))
            buckets.append(Corpus(tokens=jnp.asarray(bt),
                                  mask=jnp.asarray(bm),
                                  y=jnp.zeros((q,), jnp.float32)))
            tok_rows.append(np.pad(bt, ((0, 0), (0, S - w))))
            mask_rows.append(np.pad(bm, ((0, 0), (0, S - w))))
        if self.svc.bucketed:
            D = self.svc.batch_docs
            perm = jnp.arange(D, dtype=jnp.int32)
            bc = BucketedCorpus(buckets=tuple(buckets), perm=perm,
                                inv_perm=perm, ctr_stride=S)
        else:
            bc = as_bucketed(Corpus(
                tokens=jnp.asarray(np.concatenate(tok_rows)),
                mask=jnp.asarray(np.concatenate(mask_rows)),
                y=jnp.zeros((self.svc.batch_docs,), jnp.float32)))
        return bc, meta

    # ---------------------------------------------------------- dispatch

    def _dispatch_fn(self, plan_key):
        """The retrace-free plan cache: one DISTINCT jitted callable
        per `ExecutionPlan.cache_key()`, created once and reused for
        every micro-batch with that signature (jit identity — a fresh
        `jax.jit` per batch would own a fresh trace cache and retrace
        every dispatch).  The Python body increments the trace counter
        — a side effect that fires per TRACE, never per compiled call —
        so `stats()['traces']` growing under steady-state traffic is a
        test failure, not a guess."""
        fn = self._plan_cache.get(plan_key)
        if fn is not None:
            return fn
        rule, counts = self.svc.combine, self._trace_counts

        def dispatch(keys, models, plan, chain_weights):
            counts[plan_key] += 1           # fires once per trace
            zb = plan.predict_zbar(keys, models)      # [M, D, T]
            yhat = jax.vmap(lambda z, e: z @ e)(zb, models.eta)
            comb = _combine_yhat(rule, yhat, chain_weights,
                                 models.train_mse)
            return zb, yhat, comb

        fn = jax.jit(dispatch)
        self._plan_cache[plan_key] = fn
        return fn

    def set_sampler_mode(self, mode: str):
        """Switch the per-token draw mode for subsequent dispatches.
        The cfg is part of `ExecutionPlan.cache_key()`, so the next
        flush under the new mode allocates a DISTINCT jitted callable;
        programs compiled for the old mode stay cached (switching back
        is free).  Results are unaffected in distribution — the sparse
        two-stage draw is exact (DESIGN.md §Sparse-sampler)."""
        if mode not in ("dense", "sparse"):
            raise ValueError(f"unknown sampler_mode {mode!r}")
        self.cfg = dataclasses.replace(self.cfg, sampler_mode=mode)

    def flush(self):
        """Dispatch one micro-batch from the pending queue (no-op when
        empty).  Returns the req_ids completed by this batch (shed ids
        resolve through `result()`, not this list)."""
        if not self._pending:
            return []
        placed, n = self._pack()
        if n == 0:      # every pending request expired — nothing to run
            return []
        bc, meta = self._build_schedule(placed)
        plan = build_plan(bc, self.cfg, self.backend)
        fn = self._dispatch_fn(plan.cache_key())
        keys = jax.random.split(
            jax.random.fold_in(self.key, self._batches), self.n_chains)
        self._batches += 1
        zb, yhat, comb = fn(keys, self.models, plan, self.chain_weights)
        jax.block_until_ready(comb)
        t_done = self._clock()
        zb, yhat, comb = np.asarray(zb), np.asarray(yhat), np.asarray(comb)
        real = [d for d, slot in enumerate(meta) if slot is not None]
        if self.svc.robust_checks and real:
            comb = self._screen_dispatch(yhat, comb, real)
        done = []
        for d, slot in enumerate(meta):
            if slot is None:
                self._stats["dummy_slots"] += 1
                continue
            rid, t0 = slot
            self._results[rid] = Result(
                req_id=rid, yhat=float(comb[d]), yhat_chains=yhat[:, d],
                zbar=zb[:, d], latency_s=t_done - t0, from_cache=False)
            done.append(rid)
            if self.svc.cache_results:
                h = hashlib.blake2b(
                    np.ascontiguousarray(
                        bc_tokens_row(bc, d)).tobytes(),
                    digest_size=16).digest()
                self._result_cache[(h, self._model_epoch)] = \
                    (zb[:, d], yhat[:, d])
                while len(self._result_cache) > self.svc.max_cached_results:
                    self._result_cache.popitem(last=False)
        self._stats["dispatches"] += 1
        self._stats["docs_dispatched"] += n
        return done

    def _screen_dispatch(self, yhat, comb, real):
        """Per-chain ŷ health screen at dispatch: a chain producing a
        non-finite prediction on any REAL slot (dummies are masked
        noise) is quarantined through the same weights path as a
        manual `drop_chain` — exact and retrace-free — and the batch
        is recombined host-side under the corrected mask, so the
        poison never reaches a caller."""
        w = self._w_host
        bad = ~np.isfinite(yhat[:, real]).all(axis=1) & (w > 0)
        if not bad.any():
            return comb
        for c in np.flatnonzero(bad):
            self._health[c] |= F_NAN_YHAT
            self.drop_chain(int(c))
            self._stats["dispatch_quarantines"] += 1
        return np.asarray(_combine_yhat(
            self.svc.combine, jnp.asarray(yhat), self.chain_weights,
            self.models.train_mse))

    def drain(self, deadline_s: float | None = None):
        """Flush until the pending queue is empty (partial batches pad
        with dummy slots).  `deadline_s` bounds the wall time spent
        draining — on timeout the remaining requests STAY pending
        (they are not shed; a later flush/drain can still serve them),
        so a shutdown storm cannot hang the caller."""
        t0 = self._clock()
        done = []
        while self._pending:
            if deadline_s is not None and self._clock() - t0 > deadline_s:
                self._stats["drain_timeouts"] += 1
                break
            done.extend(self.flush())
        return done

    # ----------------------------------------------------------- results

    def result(self, req_id: int) -> Result:
        return self._results[req_id]

    def combined(self, req_id: int) -> float:
        """Re-derive the combined ŷ for a served request under the
        CURRENT chain weights — exact under any drop/revive since the
        per-chain values never depended on other chains.  When every
        chain is dead this inherits `core.combine`'s all-dead
        fallback: unmasked combine + RuntimeWarning, never a NaN from
        a 0/0."""
        r = self._results[req_id]
        if r.status != STATUS_OK:
            raise ValueError(
                f"request {req_id} was not served (status {r.status!r})"
                " — no per-chain values to combine")
        return float(_combine_yhat(
            self.svc.combine, jnp.asarray(r.yhat_chains)[:, None],
            self.chain_weights, self.models.train_mse)[0])

    # ---------------------------------------------- ensemble maintenance

    def drop_chain(self, idx: int):
        """Serving-time straggler/failure cut — zero the chain's weight.
        Reaches every CACHED plan without retracing (weights are a jit
        argument), and is exact: chains share nothing, so the surviving
        combine equals an ensemble that never held the chain."""
        self.chain_weights = self.chain_weights.at[idx].set(0.0)

    def revive_chain(self, idx: int, weight: float = 1.0):
        """Undo a drop — the replica came back.  Exact for the same
        reason the drop is.  Also clears the chain's latched health
        flags (an operator revive is an assertion the replica is
        healthy again; the next dispatch re-screens anyway)."""
        self.chain_weights = self.chain_weights.at[idx].set(weight)
        self._health[idx] = 0

    def reload_from_checkpoint(self, ckpt_dir: str,
                               step: int | None = None) -> dict:
        """Hot model swap — epoch-versioned and atomic from the
        caller's view (DESIGN.md §Serving-robustness reload protocol):

          validate manifest → load all chains → screen tables → swap.

        Any failure before the swap (missing/torn/`BadZipFile`
        checkpoint, mislabelled manifest, chain-count mismatch, or a
        checkpoint with NO healthy chain) REJECTS the reload: the old
        models keep serving under the old epoch, and the report says
        why.  On success the model epoch bumps — which invalidates
        every result-cache entry by key, no scan needed — healthy
        chains (re)enter the ensemble and unhealthy ones are
        quarantined.  Models ride as jit ARGUMENTS with unchanged
        shapes, so a swap can never retrace."""
        t0 = self._clock()

        def _reject(reason: str) -> dict:
            self._stats["reloads_rejected"] += 1
            return {"ok": False, "reason": reason,
                    "epoch": self._model_epoch,
                    "ckpt_step": self._ckpt_step,
                    "wall_s": self._clock() - t0}

        if step is None:
            step = latest_step(ckpt_dir)
            if step is None:
                return _reject(f"no checkpoint under {ckpt_dir!r}")
        try:
            models, manifest = restore_checkpoint(
                ckpt_dir, step, self.models)
        except (FileNotFoundError, KeyError, ValueError, OSError,
                zipfile.BadZipFile) as e:   # truncated .npz = torn write
            return _reject(f"{type(e).__name__}: {e}")
        quarantined = []
        if self.svc.robust_checks:
            status = np.array(model_status(models))
            bad = (status & MODEL_FAULTS) != 0
            if bad.all():
                return _reject("all_chains_unhealthy")
            quarantined = [int(c) for c in np.flatnonzero(bad)]
            self._health = status
            alive = (~bad).astype(np.float32)
        else:
            alive = np.ones(self.n_chains, np.float32)
        # point of no return — everything below is pure assignment
        self.models = models
        self._model_epoch += 1
        self._ckpt_step = int(manifest["step"])
        self.chain_weights = jnp.asarray(alive, jnp.float32)
        self._stats["reloads_ok"] += 1
        if quarantined:
            self._stats["load_quarantines"] += len(quarantined)
        return {"ok": True, "epoch": self._model_epoch,
                "ckpt_step": self._ckpt_step,
                "quarantined_chains": quarantined,
                "wall_s": self._clock() - t0}

    # ------------------------------------------------------------- stats

    def stats(self) -> dict:
        """Counters the benchmark/tests assert on — most importantly
        `traces`: total times any cached dispatch was (re)traced.
        Steady-state traffic must not grow it."""
        sig_traces = {str(k[0]): v for k, v in self._trace_counts.items()}
        slot_total = max(self._stats["dispatches"], 1) \
            * self.svc.batch_docs
        alive = np.asarray(self.chain_weights) > 0
        return {
            "traces": int(sum(self._trace_counts.values())),
            "compiled_plans": len(self._plan_cache),
            "plan_cache_keys": len(self._plan_cache),
            # the active per-token draw mode — part of every plan cache
            # key (cfg is in ExecutionPlan.cache_key()), so switching it
            # allocates a DISTINCT jitted callable (test_slda_serving)
            "sampler_mode": self.cfg.sampler_mode,
            "traces_by_signature": sig_traces,
            "dispatches": int(self._stats["dispatches"]),
            "docs_dispatched": int(self._stats["docs_dispatched"]),
            "dummy_slots": int(self._stats["dummy_slots"]),
            "dummy_slot_frac": round(
                self._stats["dummy_slots"]
                / (slot_total if self._stats["dispatches"] else 1), 4),
            "result_cache_hits": int(self._stats["cache_hits"]),
            "result_cache_size": len(self._result_cache),
            "pending": len(self._pending),
            "width_ladder": list(self.svc.width_ladder),
            "slot_quota": list(self.svc.slot_quota),
            "bucketed": self.svc.bucketed,
            "backend": self.backend,
            # robustness observability (ISSUE 8: queue depth, shed/
            # reject counters, model epoch, per-chain health)
            "queue_depth": len(self._pending),
            "shed_queue_full": int(self._stats[STATUS_SHED_QUEUE]),
            "shed_rate_limit": int(self._stats[STATUS_SHED_RATE]),
            "expired": int(self._stats[STATUS_EXPIRED]),
            "rejected_invalid": int(self._stats["rejected_invalid"]),
            "drain_timeouts": int(self._stats["drain_timeouts"]),
            "dispatch_quarantines": int(
                self._stats["dispatch_quarantines"]),
            "load_quarantines": int(self._stats["load_quarantines"]),
            "reloads_ok": int(self._stats["reloads_ok"]),
            "reloads_rejected": int(self._stats["reloads_rejected"]),
            "model_epoch": self._model_epoch,
            "ckpt_step": self._ckpt_step,
            "alive_chains": int(alive.sum()),
            "chain_health": [describe_status(int(s))
                             for s in self._health],
        }

    def describe(self) -> dict:
        """The serving plan, human-readable — slot layout, signature,
        and what a dispatch compiles to (`launch/dryrun.py
        --slda-serve`)."""
        dummy = [(0, np.zeros(1, np.int32), 0.0, math.inf)]
        placed = [[] for _ in self.svc.width_ladder]
        placed[0] = dummy
        bc, _ = self._build_schedule(placed)
        plan = build_plan(bc, self.cfg, self.backend)
        d = plan.describe()
        d["cache_key_signature"] = str(plan.cache_key()[0])
        d["width_ladder"] = list(self.svc.width_ladder)
        d["slot_quota"] = list(self.svc.slot_quota)
        d["combine"] = self.svc.combine
        d["chains"] = self.n_chains
        d["robustness"] = {
            "max_pending": self.svc.max_pending,
            "default_deadline_s": self.svc.default_deadline_s,
            "rate_limit_per_s": self.svc.rate_limit_per_s,
            "rate_burst": self.svc.rate_burst or self.svc.batch_docs,
            "robust_checks": self.svc.robust_checks,
            "auto_flush": self.svc.auto_flush,
            "scheduling": "earliest-deadline-first (FIFO when no "
                          "deadlines)",
            "shed_statuses": list(SHED_STATUSES),
            "model_epoch": self._model_epoch,
        }
        return d


def bc_tokens_row(bc: BucketedCorpus, d: int) -> np.ndarray:
    """Original-order row d of a schedule whose perm is the identity —
    the service's content-hash source (un-padded to the TRUE length so
    a repeat submission hashes equal regardless of its rung)."""
    o = 0
    for b in bc.buckets:
        q = b.tokens.shape[0]
        if d < o + q:
            row = np.asarray(b.tokens[d - o])
            m = np.asarray(b.mask[d - o]).astype(bool)
            return row[: int(m.sum())]
        o += q
    raise IndexError(d)
