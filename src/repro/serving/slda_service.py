"""Continuous-batching sLDA prediction service (ROADMAP item 1).

The paper's zero-communication chains make per-request fan-out to M
chains embarrassingly parallel; this module is the serving surface that
routes real request traffic through the PR 5 `ExecutionPlan` layer:

  * **micro-batcher** — incoming ragged documents accumulate into
    fixed-shape micro-batches.  Every batch has the SAME slot layout: a
    width *ladder* of bucket rungs (ascending token widths, the last
    rung always `max_doc_len`) with a fixed per-rung slot *quota*
    (`calibrate_slots` picks both from a sample of the traffic's length
    distribution via the same cost-model DP that `bucket_corpus` uses).
    A document occupies one slot of the smallest rung that fits it
    (escalating to a wider rung when its own is full); unused slots are
    masked-out dummies.  The payoff: every dispatch has ONE static
    bucket signature, so steady-state traffic never retraces.

  * **retrace-free plan cache** — compiled programs are cached as
    DISTINCT `jax.jit` callables in a dict keyed on
    `ExecutionPlan.cache_key()` (the bucket-width signature +
    (cfg, backend)).  This is jit *identity*, not static-arg hashing: a
    fresh `jax.jit(fn)` per request owns a fresh, empty trace cache and
    retraces every call no matter how the static args hash — the cache
    must hold the callables themselves.  A trace counter incremented
    from the traced function body (a Python side effect that fires once
    per trace, never per call) makes the no-retrace property observable
    and assertable (tests, BENCH_slda_serving.json).

  * **result cache** — per-document posterior-mean topic mixtures z̄
    (theta) and per-chain ŷ are cached by content hash; a repeat
    document is served without occupying a slot.  The cache stores
    PER-CHAIN values, never the combined scalar, so…

  * **mid-stream drop/revive is exact** — `chain_weights` rides as a
    jit ARGUMENT of every cached callable (dropping a chain cannot
    retrace), and combination happens under the weights current at
    serve time — for fresh batches inside the compiled dispatch, for
    cache hits on the host via the same `core.combine` rules.  Because
    chains share nothing, serving the surviving sub-ensemble is
    bit-identical to an ensemble that never contained the dead chain
    (DESIGN.md §Fault-model).

Numerical contract: a dispatch is exactly `plan.predict_zbar` over the
micro-batch corpus — the serving machinery (slot packing, caches,
combine plumbing) adds ZERO deviation versus calling the plan layer
directly, and the bucketed slot layout is bit-identical per document to
the padded (`bucketed=False`) layout by the `ctr_stride` pinning of
DESIGN.md §Ragged-execution (tests/test_slda_serving.py).
"""
from __future__ import annotations

import collections
import dataclasses
import hashlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.combine import median, simple_average, weighted_average
from repro.core.plan import as_bucketed, build_plan
from repro.core.types import (BucketedCorpus, Corpus, SLDAConfig, SLDAModel,
                              _dp_bucket_cuts)


# ------------------------------------------------------------ calibration

def calibrate_slots(lengths, batch_docs: int, max_doc_len: int, *,
                    n_buckets: int = 4, token_block: int = 8,
                    overhead_docs: float = 0.0):
    """Pick the service's (width ladder, slot quota) from a sample of
    document lengths — the same cost-model DP as `bucket_corpus`
    (`_dp_bucket_cuts`: minimize Σ_b (D_b + overhead)·N_b over
    contiguous cuts of the sorted length profile), then scale the
    bucket document counts to `batch_docs` slots by largest remainder.
    The widest rung is forced to `max_doc_len` (and keeps ≥1 slot) so
    every admissible request fits some rung.  Returns
    (widths, quota) — equal-length tuples, sum(quota) == batch_docs."""
    lens = np.clip(np.asarray(lengths).ravel(), 1, max_doc_len)
    if batch_docs < 1:
        raise ValueError("batch_docs must be >= 1")
    lens_sorted = np.sort(lens)
    round_w = np.minimum(
        max_doc_len,
        np.maximum(token_block, -(-lens_sorted // token_block)
                   * token_block)).astype(int)
    segs = []
    for w in round_w:
        if segs and segs[-1][1] == int(w):
            segs[-1][0] += 1
        else:
            segs.append([1, int(w)])
    segs = [(c, w) for c, w in segs]
    ends = _dp_bucket_cuts(segs, max(1, min(n_buckets, batch_docs)),
                           float(overhead_docs))
    widths, counts, o = [], [], 0
    for e in ends:
        counts.append(sum(c for c, _ in segs[o:e]))
        widths.append(segs[e - 1][1])
        o = e
    widths[-1] = max_doc_len

    # largest-remainder scaling of counts → quota, each rung >= 1 slot
    total = float(sum(counts))
    raw = [batch_docs * c / total for c in counts]
    quota = [max(1, int(f)) for f in raw]
    while sum(quota) > batch_docs:        # too many rungs for the slots:
        widths.pop(0)                     # merge the narrowest rung up
        quota.pop(0)
        raw.pop(0)
    rema = sorted(range(len(quota)), key=lambda i: raw[i] - int(raw[i]),
                  reverse=True)
    i = 0
    while sum(quota) < batch_docs:
        quota[rema[i % len(quota)]] += 1
        i += 1
    return tuple(widths), tuple(quota)


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    """Static configuration of the prediction service (hashable — part
    of every cached program's closure)."""

    max_doc_len: int = 256        # admission limit == PRNG ctr_stride
    batch_docs: int = 32          # slots per micro-batch
    width_ladder: tuple = ()      # ascending rung widths; () = 1 rung
                                  # at max_doc_len (the padded layout)
    slot_quota: tuple = ()        # slots per rung; () = all batch_docs
                                  # on the single rung
    combine: str = "weighted"     # "simple" | "weighted" | "median"
    bucketed: bool = True         # False = dispatch the padded
                                  # degenerate schedule (parity twin /
                                  # A-B baseline); the ladder still
                                  # packs, only the dispatch layout
                                  # changes — bit-identical outputs
    cache_results: bool = True    # theta/ŷ result cache on content hash
    max_cached_results: int = 4096

    def __post_init__(self):
        ladder = self.width_ladder or (self.max_doc_len,)
        quota = self.slot_quota or (self.batch_docs,)
        if len(ladder) != len(quota):
            raise ValueError("width_ladder and slot_quota lengths differ")
        if list(ladder) != sorted(set(ladder)):
            raise ValueError("width_ladder must strictly ascend")
        if ladder[-1] != self.max_doc_len:
            raise ValueError("widest rung must equal max_doc_len")
        if sum(quota) != self.batch_docs or min(quota) < 1:
            raise ValueError("slot_quota must sum to batch_docs, each >=1")
        object.__setattr__(self, "width_ladder", tuple(ladder))
        object.__setattr__(self, "slot_quota", tuple(quota))

    @classmethod
    def calibrated(cls, lengths, *, max_doc_len: int = 256,
                   batch_docs: int = 32, n_buckets: int = 4,
                   token_block: int = 8, overhead_docs: float = 0.0,
                   **kw) -> "ServiceConfig":
        """Build a config whose slot layout fits a traffic sample."""
        widths, quota = calibrate_slots(
            lengths, batch_docs, max_doc_len, n_buckets=n_buckets,
            token_block=token_block, overhead_docs=overhead_docs)
        return cls(max_doc_len=max_doc_len, batch_docs=batch_docs,
                   width_ladder=widths, slot_quota=quota, **kw)


@dataclasses.dataclass
class Result:
    """One served prediction.  Per-chain values are kept so the
    combined scalar can be re-derived under any later alive mask."""

    req_id: int
    yhat: float              # combined ŷ under the weights AT SERVE TIME
    yhat_chains: np.ndarray  # [M] per-chain ŷ
    zbar: np.ndarray         # [M, T] per-chain posterior-mean θ
    latency_s: float
    from_cache: bool


def _combine_yhat(rule: str, yhat, chain_weights, train_mse):
    """The ONE combine used for fresh batches (inside the compiled
    dispatch) and cache hits (host side) — `core.combine` semantics,
    alive mask = nonzero chain weight."""
    alive = (chain_weights > 0).astype(yhat.dtype)
    if rule == "weighted":
        return weighted_average(yhat, train_mse=train_mse, alive=alive)
    if rule == "median":
        return median(yhat, alive=alive)
    if rule == "simple":
        return simple_average(yhat, alive=alive)
    raise ValueError(f"unknown combine rule {rule!r}")


# ---------------------------------------------------------------- service

class SLDAPredictionService:
    """Continuous-batching prediction over a trained M-chain ensemble.

      svc = SLDAPredictionService(models, cfg, ServiceConfig.calibrated(
                lengths_sample, max_doc_len=256, batch_docs=32))
      rid = svc.submit(token_ids)          # auto-flushes at batch_docs
      svc.drain()                          # force out partial batches
      svc.result(rid).yhat

    `models` is a chain-stacked `SLDAModel` ([M, ...] leaves, e.g. from
    `train_chains`).  All dispatches run through the `ExecutionPlan`
    layer; see the module docstring for the caching/exactness story.
    """

    def __init__(self, models: SLDAModel, cfg: SLDAConfig,
                 svc: ServiceConfig, *, key=None, chain_weights=None,
                 backend: str | None = None):
        self.models = models
        self.cfg = cfg
        self.svc = svc
        self.n_chains = int(models.eta.shape[0])
        self.backend = backend if backend is not None \
            else cfg.resolve_backend()
        self.key = key if key is not None else jax.random.PRNGKey(0)
        self.chain_weights = (jnp.ones((self.n_chains,), jnp.float32)
                              if chain_weights is None
                              else jnp.asarray(chain_weights, jnp.float32))
        self._plan_cache = {}                   # cache_key → jitted fn
        self._trace_counts = collections.Counter()   # cache_key → traces
        self._results = {}                      # req_id → Result
        self._result_cache = collections.OrderedDict()  # hash → (zbar, yhat)
        self._pending = collections.deque()     # (req_id, np tokens, t_sub)
        self._next_id = 0
        self._batches = 0
        self._stats = collections.Counter()

    # ------------------------------------------------------------ intake

    def submit(self, tokens) -> int:
        """Enqueue one ragged document (int token ids, 1-D).  Returns a
        request id; auto-flushes whenever a full micro-batch is
        pending.  A content-hash repeat is served straight from the
        result cache (no slot), combined under the CURRENT weights."""
        toks = np.asarray(tokens, np.int32).ravel()
        if not 1 <= toks.size <= self.svc.max_doc_len:
            raise ValueError(
                f"doc length {toks.size} outside [1, "
                f"{self.svc.max_doc_len}]")
        if toks.min() < 0 or toks.max() >= self.cfg.vocab_size:
            raise ValueError("token id outside the model's vocab")
        rid = self._next_id
        self._next_id += 1
        t0 = time.perf_counter()
        if self.svc.cache_results:
            h = hashlib.blake2b(toks.tobytes(), digest_size=16).digest()
            hit = self._result_cache.get(h)
            if hit is not None:
                self._result_cache.move_to_end(h)
                zbar, yhat = hit
                comb = float(_combine_yhat(
                    self.svc.combine, jnp.asarray(yhat)[:, None],
                    self.chain_weights, self.models.train_mse)[0])
                self._results[rid] = Result(
                    req_id=rid, yhat=comb, yhat_chains=yhat, zbar=zbar,
                    latency_s=time.perf_counter() - t0, from_cache=True)
                self._stats["cache_hits"] += 1
                return rid
        self._pending.append((rid, toks, t0))
        while len(self._pending) >= self.svc.batch_docs:
            self.flush()
        return rid

    # ----------------------------------------------------------- packing

    def _pack(self):
        """FIFO-pack pending docs into the fixed slot layout: each doc
        takes a free slot of the smallest rung that fits it, escalating
        to wider rungs when its own is full; docs that fit nowhere stay
        pending for the next batch.  Returns (per-rung doc lists,
        n_placed)."""
        ladder, quota = self.svc.width_ladder, self.svc.slot_quota
        free = list(quota)
        placed = [[] for _ in ladder]
        leftover = collections.deque()
        n = 0
        while self._pending:
            item = self._pending.popleft()
            L = item[1].size
            rung = next(i for i, w in enumerate(ladder) if w >= L)
            slot = next((i for i in range(rung, len(ladder))
                         if free[i] > 0), None)
            if slot is None:
                leftover.append(item)
                continue
            free[slot] -= 1
            placed[slot].append(item)
            n += 1
        self._pending = leftover
        return placed, n

    def _build_schedule(self, placed):
        """Slot lists → (BucketedCorpus, slot_meta).  The micro-batch's
        ORIGINAL doc order is the rung-major slot order (real docs
        first, dummies after, per rung), so perm == identity and the
        padded twin (`bucketed=False`) sees the exact same rows —
        that's what makes the two layouts bit-comparable per slot.
        slot_meta[d] is (req_id, t_submit) or None for a dummy."""
        ladder, quota = self.svc.width_ladder, self.svc.slot_quota
        S = self.svc.max_doc_len
        meta, buckets = [], []
        tok_rows, mask_rows = [], []
        for w, q, docs in zip(ladder, quota, placed):
            bt = np.zeros((q, w), np.int32)
            bm = np.zeros((q, w), np.float32)
            for i, (rid, toks, t0) in enumerate(docs):
                bt[i, :toks.size] = toks
                bm[i, :toks.size] = 1.0
                meta.append((rid, t0))
            meta.extend([None] * (q - len(docs)))
            buckets.append(Corpus(tokens=jnp.asarray(bt),
                                  mask=jnp.asarray(bm),
                                  y=jnp.zeros((q,), jnp.float32)))
            tok_rows.append(np.pad(bt, ((0, 0), (0, S - w))))
            mask_rows.append(np.pad(bm, ((0, 0), (0, S - w))))
        if self.svc.bucketed:
            D = self.svc.batch_docs
            perm = jnp.arange(D, dtype=jnp.int32)
            bc = BucketedCorpus(buckets=tuple(buckets), perm=perm,
                                inv_perm=perm, ctr_stride=S)
        else:
            bc = as_bucketed(Corpus(
                tokens=jnp.asarray(np.concatenate(tok_rows)),
                mask=jnp.asarray(np.concatenate(mask_rows)),
                y=jnp.zeros((self.svc.batch_docs,), jnp.float32)))
        return bc, meta

    # ---------------------------------------------------------- dispatch

    def _dispatch_fn(self, plan_key):
        """The retrace-free plan cache: one DISTINCT jitted callable
        per `ExecutionPlan.cache_key()`, created once and reused for
        every micro-batch with that signature (jit identity — a fresh
        `jax.jit` per batch would own a fresh trace cache and retrace
        every dispatch).  The Python body increments the trace counter
        — a side effect that fires per TRACE, never per compiled call —
        so `stats()['traces']` growing under steady-state traffic is a
        test failure, not a guess."""
        fn = self._plan_cache.get(plan_key)
        if fn is not None:
            return fn
        rule, counts = self.svc.combine, self._trace_counts

        def dispatch(keys, models, plan, chain_weights):
            counts[plan_key] += 1           # fires once per trace
            zb = plan.predict_zbar(keys, models)      # [M, D, T]
            yhat = jax.vmap(lambda z, e: z @ e)(zb, models.eta)
            comb = _combine_yhat(rule, yhat, chain_weights,
                                 models.train_mse)
            return zb, yhat, comb

        fn = jax.jit(dispatch)
        self._plan_cache[plan_key] = fn
        return fn

    def flush(self):
        """Dispatch one micro-batch from the pending queue (no-op when
        empty).  Returns the req_ids completed by this batch."""
        if not self._pending:
            return []
        placed, n = self._pack()
        if n == 0:                      # cannot happen: ladder covers
            return []                   # every admissible length
        bc, meta = self._build_schedule(placed)
        plan = build_plan(bc, self.cfg, self.backend)
        fn = self._dispatch_fn(plan.cache_key())
        keys = jax.random.split(
            jax.random.fold_in(self.key, self._batches), self.n_chains)
        self._batches += 1
        zb, yhat, comb = fn(keys, self.models, plan, self.chain_weights)
        jax.block_until_ready(comb)
        t_done = time.perf_counter()
        zb, yhat, comb = np.asarray(zb), np.asarray(yhat), np.asarray(comb)
        done = []
        for d, slot in enumerate(meta):
            if slot is None:
                self._stats["dummy_slots"] += 1
                continue
            rid, t0 = slot
            self._results[rid] = Result(
                req_id=rid, yhat=float(comb[d]), yhat_chains=yhat[:, d],
                zbar=zb[:, d], latency_s=t_done - t0, from_cache=False)
            done.append(rid)
            if self.svc.cache_results:
                h = hashlib.blake2b(
                    np.ascontiguousarray(
                        bc_tokens_row(bc, d)).tobytes(),
                    digest_size=16).digest()
                self._result_cache[h] = (zb[:, d], yhat[:, d])
                while len(self._result_cache) > self.svc.max_cached_results:
                    self._result_cache.popitem(last=False)
        self._stats["dispatches"] += 1
        self._stats["docs_dispatched"] += n
        return done

    def drain(self):
        """Flush until the pending queue is empty (partial batches pad
        with dummy slots)."""
        done = []
        while self._pending:
            done.extend(self.flush())
        return done

    # ----------------------------------------------------------- results

    def result(self, req_id: int) -> Result:
        return self._results[req_id]

    def combined(self, req_id: int) -> float:
        """Re-derive the combined ŷ for a served request under the
        CURRENT chain weights — exact under any drop/revive since the
        per-chain values never depended on other chains."""
        r = self._results[req_id]
        return float(_combine_yhat(
            self.svc.combine, jnp.asarray(r.yhat_chains)[:, None],
            self.chain_weights, self.models.train_mse)[0])

    # ---------------------------------------------- ensemble maintenance

    def drop_chain(self, idx: int):
        """Serving-time straggler/failure cut — zero the chain's weight.
        Reaches every CACHED plan without retracing (weights are a jit
        argument), and is exact: chains share nothing, so the surviving
        combine equals an ensemble that never held the chain."""
        self.chain_weights = self.chain_weights.at[idx].set(0.0)

    def revive_chain(self, idx: int, weight: float = 1.0):
        """Undo a drop — the replica came back.  Exact for the same
        reason the drop is."""
        self.chain_weights = self.chain_weights.at[idx].set(weight)

    # ------------------------------------------------------------- stats

    def stats(self) -> dict:
        """Counters the benchmark/tests assert on — most importantly
        `traces`: total times any cached dispatch was (re)traced.
        Steady-state traffic must not grow it."""
        sig_traces = {str(k[0]): v for k, v in self._trace_counts.items()}
        slot_total = max(self._stats["dispatches"], 1) \
            * self.svc.batch_docs
        return {
            "traces": int(sum(self._trace_counts.values())),
            "compiled_plans": len(self._plan_cache),
            "traces_by_signature": sig_traces,
            "dispatches": int(self._stats["dispatches"]),
            "docs_dispatched": int(self._stats["docs_dispatched"]),
            "dummy_slots": int(self._stats["dummy_slots"]),
            "dummy_slot_frac": round(
                self._stats["dummy_slots"]
                / (slot_total if self._stats["dispatches"] else 1), 4),
            "result_cache_hits": int(self._stats["cache_hits"]),
            "result_cache_size": len(self._result_cache),
            "pending": len(self._pending),
            "width_ladder": list(self.svc.width_ladder),
            "slot_quota": list(self.svc.slot_quota),
            "bucketed": self.svc.bucketed,
            "backend": self.backend,
        }

    def describe(self) -> dict:
        """The serving plan, human-readable — slot layout, signature,
        and what a dispatch compiles to (`launch/dryrun.py
        --slda-serve`)."""
        dummy = [(0, np.zeros(1, np.int32), 0.0)]
        placed = [[] for _ in self.svc.width_ladder]
        placed[0] = dummy
        bc, _ = self._build_schedule(placed)
        plan = build_plan(bc, self.cfg, self.backend)
        d = plan.describe()
        d["cache_key_signature"] = str(plan.cache_key()[0])
        d["width_ladder"] = list(self.svc.width_ladder)
        d["slot_quota"] = list(self.svc.slot_quota)
        d["combine"] = self.svc.combine
        d["chains"] = self.n_chains
        return d


def bc_tokens_row(bc: BucketedCorpus, d: int) -> np.ndarray:
    """Original-order row d of a schedule whose perm is the identity —
    the service's content-hash source (un-padded to the TRUE length so
    a repeat submission hashes equal regardless of its rung)."""
    o = 0
    for b in bc.buckets:
        q = b.tokens.shape[0]
        if d < o + q:
            row = np.asarray(b.tokens[d - o])
            m = np.asarray(b.mask[d - o]).astype(bool)
            return row[: int(m.sum())]
        o += q
    raise IndexError(d)
