"""Batched generation engine with the paper's prediction combination at the
token level.

A `ServingEngine` owns params + a slot-based KV/SSM cache: requests occupy
fixed batch slots (continuous-batching-lite — a finished slot is re-armed
with the next request without touching the others, possible because the
cache update is per-slot).  Per-step next-token distributions from the
n_chains replicas are combined with Simple/Weighted Average (Eqs. 7/9);
a per-chain `alive` mask implements serving-time straggler/failure cuts.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import ModelConfig, decode_step, forward, init_cache


@dataclasses.dataclass(frozen=True)
class GenerationConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0          # 0 = greedy
    top_k: int = 0                    # 0 = off
    combine: str = "simple"           # "simple" | "weighted" | "none"
    eos_id: int = -1                  # -1 = never stop early


def sample_token(key, logits, temperature: float = 0.0, top_k: int = 0):
    """logits: [..., V] → token ids [...].

    Top-k keeps EXACTLY k candidates: the survivors are the indices
    `jax.lax.top_k` returns (ties at the k-th value broken by index
    order), not a value-threshold mask — `logits < kth` keeps every
    candidate tied at the threshold, which over-samples flat
    distributions.  k is clamped to the vocab, so top_k >= V degrades
    to plain sampling instead of raising."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / temperature
    if top_k > 0:
        k = min(top_k, logits.shape[-1])
        vals, idx = jax.lax.top_k(logits, k)
        logits = jnp.put_along_axis(jnp.full_like(logits, -1e30), idx,
                                    vals, axis=-1, inplace=False)
    return jax.random.categorical(key, logits).astype(jnp.int32)


class ServingEngine:
    """Greedy/sampled generation over a fixed slot batch."""

    def __init__(self, cfg: ModelConfig, params, *, n_chains: int,
                 batch_slots: int, max_len: int, gen: GenerationConfig,
                 chain_weights=None, compute_dtype=jnp.float32,
                 use_pallas: bool = False):
        self.cfg = cfg
        self.params = params
        self.gen = gen
        self.n_chains = n_chains
        self.batch = batch_slots
        self.max_len = max_len
        self.compute_dtype = compute_dtype
        self.use_pallas = use_pallas
        self.chain_weights = (jnp.ones((n_chains,)) if chain_weights is None
                              else jnp.asarray(chain_weights))
        self.cache = init_cache(cfg, n_chains, batch_slots, max_len,
                                compute_dtype)
        self._decode = jax.jit(self._decode_impl)

    # ------------------------------------------------------------- internals
    def _combine(self, logits, chain_weights):
        """[c, b, 1, V] → [b, V] per the configured rule.

        Both rules honor the alive mask implied by `chain_weights`
        (`drop_chain` zeroes a chain's weight): Simple Average is the
        masked mean over SURVIVING chains, renormalized like
        `core.combine.simple_average` — a plain `probs.mean(0)` would
        silently keep dead chains in the mix.  "none" serves the first
        ALIVE chain for the same reason: an unconditional `logits[0]`
        would keep serving chain 0's logits after `drop_chain(0)`
        (all-dead falls back to chain 0, matching `core.combine`'s
        unmasked fallback)."""
        if self.gen.combine == "none" or self.n_chains == 1:
            first_alive = jnp.argmax(chain_weights > 0)
            return logits[first_alive, :, 0].astype(jnp.float32)
        probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
        if self.gen.combine == "simple":
            alive = (chain_weights > 0).astype(jnp.float32)
            # all-dead → unmasked mean (core.combine's PR 6 fallback),
            # traced-safe: a zero mask would otherwise mix to zeros and
            # serve log(1e-30) garbage uniformly
            alive = jnp.where(alive.sum() > 0, alive,
                              jnp.ones_like(alive))
            mix = jnp.einsum("c,cbsv->bsv", alive, probs) \
                / jnp.maximum(alive.sum(), 1.0)
        else:
            w = jnp.where(chain_weights.sum() > 0, chain_weights,
                          jnp.ones_like(chain_weights))
            w = w / jnp.maximum(w.sum(), 1e-9)
            mix = jnp.einsum("c,cbsv->bsv", w, probs)
        return jnp.log(jnp.maximum(mix[:, 0], 1e-30))

    def _decode_impl(self, params, cache, tokens, key, chain_weights):
        # chain_weights rides as a jit ARGUMENT, not a closed-over
        # constant, so a drop_chain between steps reaches the compiled fn
        logits, cache = decode_step(params, cache, {"tokens": tokens},
                                    self.cfg, compute_dtype=self.compute_dtype,
                                    use_pallas=self.use_pallas)
        mixed = self._combine(logits, chain_weights)       # [b, V]
        nxt = sample_token(key, mixed, self.gen.temperature, self.gen.top_k)
        toks = jnp.broadcast_to(nxt[None, :, None],
                                (self.n_chains, self.batch, 1)).astype(jnp.int32)
        return toks, cache, nxt

    # ---------------------------------------------------------------- public
    def prefill(self, prompts):
        """prompts: int32[b, s0] — runs the prompt through decode steps so
        every chain's cache is primed (simple, exact; a fused prefill path
        exists via models.forward for long prompts)."""
        toks = jnp.broadcast_to(prompts[None], (self.n_chains,) +
                                prompts.shape).astype(jnp.int32)
        for t in range(prompts.shape[1]):
            step = toks[:, :, t:t + 1]
            _, self.cache, _ = self._decode(self.params, self.cache, step,
                                            jax.random.PRNGKey(0),
                                            self.chain_weights)
        return toks[:, :, -1:]

    def generate(self, prompts, key=None):
        """prompts: int32[b, s0] → generated int32[b, max_new_tokens].

        With `gen.eos_id >= 0` a slot that emits EOS is FROZEN: its
        remaining output columns are eos_id, and the token fed back to
        the model stays eos_id (slots are independent, so freezing one
        never perturbs the others).  The step loop breaks as soon as
        every slot has finished — the per-slot early stop of
        continuous batching — and the output is still always
        [b, max_new_tokens], eos-padded."""
        key = key if key is not None else jax.random.PRNGKey(0)
        eos = self.gen.eos_id
        last = self.prefill(prompts)
        out = []
        tok = last
        done = jnp.zeros((prompts.shape[0],), bool)
        for i in range(self.gen.max_new_tokens):
            key, sub = jax.random.split(key)
            tok, self.cache, nxt = self._decode(self.params, self.cache,
                                                tok, sub, self.chain_weights)
            if eos >= 0:
                nxt = jnp.where(done, eos, nxt)            # freeze finished
                tok = jnp.broadcast_to(
                    nxt[None, :, None],
                    (self.n_chains, self.batch, 1)).astype(jnp.int32)
                done = done | (nxt == eos)
            out.append(nxt)
            if eos >= 0 and bool(done.all()):              # all slots done
                pad = jnp.full_like(nxt, eos)
                out.extend([pad] * (self.gen.max_new_tokens - i - 1))
                break
        return jnp.stack(out, axis=1)                      # [b, T_new]

    def drop_chain(self, idx: int):
        """Serving-time straggler/failure cut: zero a chain's weight; the
        combiner renormalizes (the paper's alive-mask semantics)."""
        self.chain_weights = self.chain_weights.at[idx].set(0.0)

    def revive_chain(self, idx: int, weight: float = 1.0):
        """Undo a drop (the replica came back): restore the chain's
        combine weight.  Exact for the same reason the drop is — chains
        share nothing, so re-adding one only changes the mix weights."""
        self.chain_weights = self.chain_weights.at[idx].set(weight)

    def quarantine_unhealthy(self, per_chain_loss, logits=None, *,
                             loss_z_cut: float = 4.0):
        """Serving-side health cut: drop every chain whose probe loss is
        non-finite or a robust-z outlier (`metrics.ensemble_health` — the
        same statistic the training supervisor uses).  Multiplies the
        weights by the alive mask, so an operator-set weight of 0 stays
        0.  Returns the health report."""
        from repro.metrics import ensemble_health
        alive, report = ensemble_health(per_chain_loss, logits,
                                        loss_z_cut=loss_z_cut)
        self.chain_weights = self.chain_weights * alive
        return report
