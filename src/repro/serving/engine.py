"""Batched generation engine with the paper's prediction combination at the
token level.

A `ServingEngine` owns params + a slot-based KV/SSM cache: requests occupy
fixed batch slots (continuous-batching-lite — a finished slot is re-armed
with the next request without touching the others, possible because the
cache update is per-slot).  Per-step next-token distributions from the
n_chains replicas are combined with Simple/Weighted Average (Eqs. 7/9);
a per-chain `alive` mask implements serving-time straggler/failure cuts.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import ModelConfig, decode_step, forward, init_cache


@dataclasses.dataclass(frozen=True)
class GenerationConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0          # 0 = greedy
    top_k: int = 0                    # 0 = off
    combine: str = "simple"           # "simple" | "weighted" | "none"
    eos_id: int = -1                  # -1 = never stop early


def sample_token(key, logits, temperature: float = 0.0, top_k: int = 0):
    """logits: [..., V] → token ids [...]."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / temperature
    if top_k > 0:
        kth = jnp.sort(logits, axis=-1)[..., -top_k][..., None]
        logits = jnp.where(logits < kth, -1e30, logits)
    return jax.random.categorical(key, logits).astype(jnp.int32)


class ServingEngine:
    """Greedy/sampled generation over a fixed slot batch."""

    def __init__(self, cfg: ModelConfig, params, *, n_chains: int,
                 batch_slots: int, max_len: int, gen: GenerationConfig,
                 chain_weights=None, compute_dtype=jnp.float32,
                 use_pallas: bool = False):
        self.cfg = cfg
        self.params = params
        self.gen = gen
        self.n_chains = n_chains
        self.batch = batch_slots
        self.max_len = max_len
        self.compute_dtype = compute_dtype
        self.use_pallas = use_pallas
        self.chain_weights = (jnp.ones((n_chains,)) if chain_weights is None
                              else jnp.asarray(chain_weights))
        self.cache = init_cache(cfg, n_chains, batch_slots, max_len,
                                compute_dtype)
        self._decode = jax.jit(self._decode_impl)

    # ------------------------------------------------------------- internals
    def _combine(self, logits, chain_weights):
        """[c, b, 1, V] → [b, V] per the configured rule.

        Both rules honor the alive mask implied by `chain_weights`
        (`drop_chain` zeroes a chain's weight): Simple Average is the
        masked mean over SURVIVING chains, renormalized like
        `core.combine.simple_average` — a plain `probs.mean(0)` would
        silently keep dead chains in the mix."""
        if self.gen.combine == "none" or self.n_chains == 1:
            return logits[0, :, 0].astype(jnp.float32)
        probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
        if self.gen.combine == "simple":
            alive = (chain_weights > 0).astype(jnp.float32)
            mix = jnp.einsum("c,cbsv->bsv", alive, probs) \
                / jnp.maximum(alive.sum(), 1.0)
        else:
            w = chain_weights / jnp.maximum(chain_weights.sum(), 1e-9)
            mix = jnp.einsum("c,cbsv->bsv", w, probs)
        return jnp.log(jnp.maximum(mix[:, 0], 1e-30))

    def _decode_impl(self, params, cache, tokens, key, chain_weights):
        # chain_weights rides as a jit ARGUMENT, not a closed-over
        # constant, so a drop_chain between steps reaches the compiled fn
        logits, cache = decode_step(params, cache, {"tokens": tokens},
                                    self.cfg, compute_dtype=self.compute_dtype,
                                    use_pallas=self.use_pallas)
        mixed = self._combine(logits, chain_weights)       # [b, V]
        nxt = sample_token(key, mixed, self.gen.temperature, self.gen.top_k)
        toks = jnp.broadcast_to(nxt[None, :, None],
                                (self.n_chains, self.batch, 1)).astype(jnp.int32)
        return toks, cache, nxt

    # ---------------------------------------------------------------- public
    def prefill(self, prompts):
        """prompts: int32[b, s0] — runs the prompt through decode steps so
        every chain's cache is primed (simple, exact; a fused prefill path
        exists via models.forward for long prompts)."""
        toks = jnp.broadcast_to(prompts[None], (self.n_chains,) +
                                prompts.shape).astype(jnp.int32)
        for t in range(prompts.shape[1]):
            step = toks[:, :, t:t + 1]
            _, self.cache, _ = self._decode(self.params, self.cache, step,
                                            jax.random.PRNGKey(0),
                                            self.chain_weights)
        return toks[:, :, -1:]

    def generate(self, prompts, key=None):
        """prompts: int32[b, s0] → generated int32[b, max_new_tokens]."""
        key = key if key is not None else jax.random.PRNGKey(0)
        last = self.prefill(prompts)
        out = []
        tok = last
        for i in range(self.gen.max_new_tokens):
            key, sub = jax.random.split(key)
            tok, self.cache, nxt = self._decode(self.params, self.cache,
                                                tok, sub, self.chain_weights)
            out.append(nxt)
        return jnp.stack(out, axis=1)                      # [b, T_new]

    def drop_chain(self, idx: int):
        """Serving-time straggler/failure cut: zero a chain's weight; the
        combiner renormalizes (the paper's alive-mask semantics)."""
        self.chain_weights = self.chain_weights.at[idx].set(0.0)

    def revive_chain(self, idx: int, weight: float = 1.0):
        """Undo a drop (the replica came back): restore the chain's
        combine weight.  Exact for the same reason the drop is — chains
        share nothing, so re-adding one only changes the mix weights."""
        self.chain_weights = self.chain_weights.at[idx].set(weight)

    def quarantine_unhealthy(self, per_chain_loss, logits=None, *,
                             loss_z_cut: float = 4.0):
        """Serving-side health cut: drop every chain whose probe loss is
        non-finite or a robust-z outlier (`metrics.ensemble_health` — the
        same statistic the training supervisor uses).  Multiplies the
        weights by the alive mask, so an operator-set weight of 0 stays
        0.  Returns the health report."""
        from repro.metrics import ensemble_health
        alive, report = ensemble_health(per_chain_loss, logits,
                                        loss_z_cut=loss_z_cut)
        self.chain_weights = self.chain_weights * alive
        return report
