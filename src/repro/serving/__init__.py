"""Serving substrate: batched generation with chain-ensemble combination."""
from .engine import GenerationConfig, ServingEngine, sample_token

__all__ = ["GenerationConfig", "ServingEngine", "sample_token"]
