"""Serving substrate: batched generation with chain-ensemble combination,
plus the continuous-batching sLDA prediction service (ROADMAP item 1)
with its robustness layer (DESIGN.md §Serving-robustness)."""
from .engine import GenerationConfig, ServingEngine, sample_token
from .slda_service import (InvalidDocument, Result, ServiceConfig,
                           SLDAPredictionService, calibrate_slots,
                           SHED_STATUSES, STATUS_EXPIRED, STATUS_OK,
                           STATUS_SHED_QUEUE, STATUS_SHED_RATE)

__all__ = ["GenerationConfig", "ServingEngine", "sample_token",
           "InvalidDocument", "Result", "ServiceConfig",
           "SLDAPredictionService", "calibrate_slots",
           "SHED_STATUSES", "STATUS_EXPIRED", "STATUS_OK",
           "STATUS_SHED_QUEUE", "STATUS_SHED_RATE"]
