"""Serving substrate: batched generation with chain-ensemble combination,
plus the continuous-batching sLDA prediction service (ROADMAP item 1)."""
from .engine import GenerationConfig, ServingEngine, sample_token
from .slda_service import (Result, ServiceConfig, SLDAPredictionService,
                           calibrate_slots)

__all__ = ["GenerationConfig", "ServingEngine", "sample_token",
           "Result", "ServiceConfig", "SLDAPredictionService",
           "calibrate_slots"]
