"""Shared building blocks.  Every activation/weight carries an explicit
leading chain dim `c` — the paper's communication-free ensemble axis — so
einsum strings spell it out and sharding specs can target it directly."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops


def uniform_init(key, shape, scale, dtype=jnp.float32):
    return jax.random.uniform(key, shape, dtype, -1.0, 1.0) * scale


def dense_init(key, fan_in, shape, dtype=jnp.float32):
    return jax.random.normal(key, shape, dtype) * (fan_in ** -0.5)


def rmsnorm(x, w, eps):
    """x: [..., D]; w: [c, D] broadcast over the chain dim explicitly."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    while w.ndim < x.ndim:
        w = w[:, None]
    return (xf * jax.lax.rsqrt(var + eps) * w).astype(x.dtype)


def rope(x, positions, theta):
    """Rotary embedding.  x: [c, b, s, h, hd]; positions: [c, b, s]."""
    hd = x.shape[-1]
    freqs = theta ** (-jnp.arange(0, hd // 2, dtype=jnp.float32) / (hd // 2))
    ang = positions[..., None].astype(jnp.float32) * freqs     # [c,b,s,hd/2]
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------- MLP

def init_mlp(key, d_model, d_ff, n_chains, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, d_model, (n_chains, d_model, d_ff), dtype),
        "w_up": dense_init(k2, d_model, (n_chains, d_model, d_ff), dtype),
        "w_down": dense_init(k3, d_ff, (n_chains, d_ff, d_model), dtype),
    }


def mlp(params, x, compute_dtype):
    """SwiGLU.  x: [c, b, s, D] → [c, b, s, D]."""
    wg = params["w_gate"].astype(compute_dtype)
    wu = params["w_up"].astype(compute_dtype)
    wd = params["w_down"].astype(compute_dtype)
    g = jnp.einsum("cbsd,cdf->cbsf", x, wg)
    u = jnp.einsum("cbsd,cdf->cbsf", x, wu)
    return jnp.einsum("cbsf,cfd->cbsd", jax.nn.silu(g) * u, wd)


# ----------------------------------------------------------- embeddings

def init_embedding(key, vocab, d_model, n_chains, dtype):
    return {"table": dense_init(key, 1, (n_chains, vocab, d_model), dtype)}


def embed(params, tokens, compute_dtype):
    """tokens: [c, b, s] → [c, b, s, D] (one-hot free gather)."""
    tbl = params["table"].astype(compute_dtype)
    c = tokens.shape[0]
    return jax.vmap(lambda t, e: jnp.take(e, t, axis=0))(
        tokens.reshape(c, -1), tbl).reshape(tokens.shape + tbl.shape[-1:])


def unembed(params, x, compute_dtype):
    return jnp.einsum("cbsd,cvd->cbsv", x,
                      params["table"].astype(compute_dtype))


def cross_entropy(logits, targets, z_weight: float = 0.0):
    """Per-chain mean CE.  logits: [c,b,s,V]; targets: [c,b,s] → loss [c].

    Includes optional z-loss (stabilises large-vocab training)."""
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(logits.astype(jnp.float32),
                               targets[..., None], axis=-1)[..., 0]
    ce = lse - gold
    if z_weight:
        ce = ce + z_weight * jnp.square(lse)
    return jnp.mean(ce, axis=(1, 2))
