"""GQA attention with RoPE, optional QKV bias (qwen2) and qk-norm (qwen3);
train path uses the flash kernel, decode path updates a KV cache in place."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops
from .config import ModelConfig
from .layers import dense_init, rmsnorm, rope


def init_attention(key, cfg: ModelConfig, n_chains: int, dtype):
    D, H, Hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], D, (n_chains, D, H * hd), dtype),
        "wk": dense_init(ks[1], D, (n_chains, D, Hkv * hd), dtype),
        "wv": dense_init(ks[2], D, (n_chains, D, Hkv * hd), dtype),
        "wo": dense_init(ks[3], H * hd, (n_chains, H * hd, D), dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((n_chains, H * hd), dtype)
        p["bk"] = jnp.zeros((n_chains, Hkv * hd), dtype)
        p["bv"] = jnp.zeros((n_chains, Hkv * hd), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((n_chains, hd), jnp.float32)
        p["k_norm"] = jnp.ones((n_chains, hd), jnp.float32)
    return p


def attention(params, x, cfg: ModelConfig, *, positions, cache=None,
              compute_dtype=jnp.bfloat16, use_pallas=True):
    """x: [c, b, s, D].  cache: None (train, causal full-seq) or a dict
    {"k","v": [c,b,Hkv,S_cache,hd], "len": [c,b]} for single-token decode.
    Returns (out [c,b,s,D], new_cache)."""
    c, b, s, D = x.shape
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd

    q = jnp.einsum("cbsd,cdh->cbsh", x, params["wq"].astype(compute_dtype))
    k = jnp.einsum("cbsd,cdh->cbsh", x, params["wk"].astype(compute_dtype))
    v = jnp.einsum("cbsd,cdh->cbsh", x, params["wv"].astype(compute_dtype))
    if cfg.qkv_bias:
        q = q + params["bq"].astype(compute_dtype)[:, None, None]
        k = k + params["bk"].astype(compute_dtype)[:, None, None]
        v = v + params["bv"].astype(compute_dtype)[:, None, None]
    q = q.reshape(c, b, s, H, hd)
    k = k.reshape(c, b, s, Hkv, hd)
    v = v.reshape(c, b, s, Hkv, hd)
    if ops.OPT["head_shard_axes"] is not None:
        # §Perf: pin heads (not head_dim) to the model axis — uneven head
        # counts just pad; a sharded head_dim would make every attention
        # einsum a partial-sum all-reduce of logits-sized tensors
        from jax.sharding import PartitionSpec as P
        ca, da = ops.OPT["head_shard_axes"]
        spec = P(ca, da, None, "model", None)
        q = jax.lax.with_sharding_constraint(q, spec)
        k = jax.lax.with_sharding_constraint(k, spec)
        v = jax.lax.with_sharding_constraint(v, spec)
    if cfg.qk_norm:
        q = rmsnorm(q, params["q_norm"], cfg.norm_eps).astype(compute_dtype)
        k = rmsnorm(k, params["k_norm"], cfg.norm_eps).astype(compute_dtype)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)

    # [c,b,s,H,hd] → [(c b), H, s, hd] for the kernel
    fold = lambda t: jnp.swapaxes(t, 2, 3).reshape(c * b, t.shape[3], s, hd)

    new_cache = None
    if cache is None:
        out = ops.attention(fold(q), fold(k), fold(v), causal=True,
                            use_pallas=use_pallas)
    else:
        # decode: append this step's k/v at position `len`, attend to prefix
        assert s == 1
        idx = cache["len"]                                   # [c, b]
        k_cache = jax.lax.dynamic_update_slice_in_dim  # noqa: F841 (doc)
        ci = jnp.arange(c)[:, None]
        bi = jnp.arange(b)[None, :]
        kc = cache["k"].at[ci, bi, :, idx].set(
            jnp.swapaxes(k, 2, 3)[:, :, :, 0].astype(cache["k"].dtype))
        vc = cache["v"].at[ci, bi, :, idx].set(
            jnp.swapaxes(v, 2, 3)[:, :, :, 0].astype(cache["v"].dtype))
        new_cache = {"k": kc, "v": vc, "len": idx + 1}
        S = kc.shape[3]
        out = ops.attention(
            fold(q),
            kc.reshape(c * b, Hkv, S, hd).astype(compute_dtype),
            vc.reshape(c * b, Hkv, S, hd).astype(compute_dtype),
            causal=True, kv_len=(idx + 1).reshape(c * b),
            use_pallas=use_pallas)

    out = jnp.swapaxes(out.reshape(c, b, H, s, hd), 2, 3).reshape(c, b, s, H * hd)
    out = jnp.einsum("cbsh,chd->cbsd", out, params["wo"].astype(compute_dtype))
    return out, new_cache


def init_kv_cache(cfg: ModelConfig, n_chains, batch, max_len, dtype):
    Hkv, hd = cfg.n_kv_heads, cfg.hd
    return {
        "k": jnp.zeros((n_chains, batch, Hkv, max_len, hd), dtype),
        "v": jnp.zeros((n_chains, batch, Hkv, max_len, hd), dtype),
        "len": jnp.zeros((n_chains, batch), jnp.int32),
    }
