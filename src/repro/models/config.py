"""Model configuration for the unified architecture zoo.

One `ModelConfig` describes every assigned architecture: dense GQA
transformers, MoE (with optional Arctic-style dense residual), Mamba-2 SSM
stacks, Zamba2 hybrids (Mamba backbone + a shared attention block), and
VLM/audio variants whose modality frontends are stubs per the assignment.

The per-layer structure is a `layer_pattern` string, one char per layer:
  'A' — attention + (MLP | MoE)   (MoE if n_experts > 0)
  'M' — Mamba-2 mixer block
Zamba2's shared attention block is orthogonal: `shared_attn_every = k`
applies ONE parameter-shared attention+MLP block after every k-th layer.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                 # 0 → d_model // n_heads
    qkv_bias: bool = False            # qwen2-family
    qk_norm: bool = False             # qwen3-family
    rope_theta: float = 1e6
    # --- MoE ---
    n_experts: int = 0
    moe_top_k: int = 2
    moe_d_ff: int = 0                 # per-expert hidden dim
    moe_dense_d_ff: int = 0           # Arctic: dense residual MLP alongside MoE
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01   # load-balance loss weight
    # --- SSM / hybrid ---
    layer_pattern: str = ""           # "" → 'A' * n_layers
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    conv_kernel: int = 4
    shared_attn_every: int = 0        # Zamba2: shared block cadence (0 = off)
    # --- modality frontend (STUB per assignment: precomputed embeddings) ---
    frontend: str = "none"            # "none" | "vision" | "audio"
    n_patches: int = 256              # vision: patches prepended per image
    # --- misc ---
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    sliding_window: int = 0           # 0 = full attention
    scan_layers: bool = False         # lax.scan over stacked layer params
                                      # (homogeneous 'A' stacks only) —
                                      # collapses compile time for deep nets

    # ------------------------------------------------------------- derived
    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def pattern(self) -> str:
        p = self.layer_pattern or "A" * self.n_layers
        assert len(p) == self.n_layers, (self.name, len(p), self.n_layers)
        return p

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def d_inner(self) -> int:          # Mamba-2 inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def attention_free(self) -> bool:
        return "A" not in self.pattern and self.shared_attn_every == 0

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for the long_500k shape (see DESIGN.md §5)."""
        return self.attention_free or (
            "M" in self.pattern) or self.sliding_window > 0

    # ------------------------------------------------------ parameter count
    def param_count(self) -> int:
        """Exact parameter count of this config (used for 6·N·D roofline)."""
        D, V, hd = self.d_model, self.vocab_size, self.hd
        n = V * D                                     # embedding
        if not self.tie_embeddings:
            n += V * D                                # lm head
        attn = (D * self.n_heads * hd + 2 * D * self.n_kv_heads * hd
                + self.n_heads * hd * D)
        if self.qkv_bias:
            attn += (self.n_heads + 2 * self.n_kv_heads) * hd
        if self.qk_norm:
            attn += 2 * hd
        mlp = 3 * D * self.d_ff
        moe = (self.n_experts * 3 * D * self.moe_d_ff
               + D * self.n_experts                   # router
               + 3 * D * self.moe_dense_d_ff)
        di, S = self.d_inner, self.ssm_state
        mamba = (D * (2 * di + 2 * S + self.ssm_heads)   # in_proj
                 + self.conv_kernel * (di + 2 * S)       # depthwise conv
                 + 2 * self.ssm_heads                    # A_log, dt_bias
                 + di                                    # ssd out norm
                 + di * D)                               # out_proj
        for ch in self.pattern:
            n += D                                       # pre-norm
            if ch == "A":
                n += attn + D + (moe if self.is_moe else mlp)
            else:
                n += mamba
        if self.shared_attn_every:
            n += attn + mlp + 2 * D                      # one shared block
        n += D                                           # final norm
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: top-k experts only) for 6·N_active·D."""
        if not self.is_moe:
            return self.param_count()
        full_moe = self.n_experts * 3 * self.d_model * self.moe_d_ff
        act_moe = self.moe_top_k * 3 * self.d_model * self.moe_d_ff
        n_moe_layers = self.pattern.count("A")
        return self.param_count() - n_moe_layers * (full_moe - act_moe)
