"""Top-k token-choice MoE with capacity-buffer dispatch (GShard-style, but
scatter-based rather than the quadratic dispatch-einsum).

Route: softmax → top-k → renormalize.  Tokens are sorted by expert id, each
token gets a position-in-expert slot, tokens beyond an expert's capacity
  C_e = ceil(tokens · top_k / E) · capacity_factor
are dropped (their residual passes through — standard).  The dispatch
buffer is [c, E, C_e, D]; sharding E over the mesh's model axis makes this
expert parallelism: the scatter into the buffer IS the all-to-all.

Arctic's `moe_dense_d_ff` adds a small dense residual MLP in parallel.
Aux load-balance loss (Switch-style) is returned for the train loss.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import dense_init, init_mlp, mlp


def init_moe(key, cfg: ModelConfig, n_chains: int, dtype):
    D, E, F = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], D, (n_chains, D, E), jnp.float32),
        "w_gate": dense_init(ks[1], D, (n_chains, E, D, F), dtype),
        "w_up": dense_init(ks[2], D, (n_chains, E, D, F), dtype),
        "w_down": dense_init(ks[3], F, (n_chains, E, F, D), dtype),
    }
    if cfg.moe_dense_d_ff:
        p["dense"] = init_mlp(ks[4], D, cfg.moe_dense_d_ff, n_chains, dtype)
    return p


def _capacity(n_tokens: int, cfg: ModelConfig) -> int:
    per = math.ceil(n_tokens * cfg.moe_top_k / cfg.n_experts)
    return max(8, int(per * cfg.capacity_factor))


def moe(params, x, cfg: ModelConfig, compute_dtype):
    """x: [c, b, s, D] → (y [c, b, s, D], aux_loss [c])."""
    c, b, s, D = x.shape
    E, K = cfg.n_experts, cfg.moe_top_k
    T = b * s
    C = _capacity(T, cfg)
    xt = x.reshape(c, T, D)

    logits = jnp.einsum("ctd,cde->cte", xt.astype(jnp.float32),
                        params["router"])
    probs = jax.nn.softmax(logits, axis=-1)                    # [c, T, E]
    gate, eidx = jax.lax.top_k(probs, K)                       # [c, T, K]
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # Switch aux loss: E · Σ_e (fraction routed to e) · (mean prob of e)
    frac = jnp.mean(jax.nn.one_hot(eidx[..., 0], E, dtype=jnp.float32), 1)
    aux = E * jnp.sum(frac * jnp.mean(probs, axis=1), axis=-1)  # [c]

    # ---- slot bookkeeping: T·K slots, sorted by expert id ----
    slot_e = eidx.reshape(c, T * K)                            # [c, TK]
    order = jnp.argsort(slot_e, axis=-1)
    sorted_e = jnp.take_along_axis(slot_e, order, axis=-1)
    # position of each sorted slot within its expert group
    pos = jnp.arange(T * K)[None, :] - jax.vmap(
        lambda se: jnp.searchsorted(se, se, side="left"))(sorted_e)
    keep = pos < C
    tok_of_slot = order // K                                   # token index

    # ---- dispatch: scatter tokens into the [E, C, D] buffer ----
    def dispatch_one(xt_c, se, ps, kp, tos):
        buf = jnp.zeros((E, C, D), compute_dtype)
        upd = jnp.where(kp[:, None], xt_c[tos].astype(compute_dtype), 0)
        return buf.at[se, jnp.minimum(ps, C - 1)].add(upd, mode="drop")

    buf = jax.vmap(dispatch_one)(xt, sorted_e, pos, keep, tok_of_slot)
    from repro.kernels import ops as _ops
    if _ops.OPT["moe_ep_axes"] is not None:
        # §Perf: pin the dispatch buffer to expert parallelism over the
        # model axis (the scatter above IS the all-to-all); otherwise GSPMD
        # may replicate it — across pods on the multi-pod mesh
        from jax.sharding import PartitionSpec as P
        ca = _ops.OPT["moe_ep_axes"]
        buf = jax.lax.with_sharding_constraint(
            buf, P(ca, "model", None, None))

    # ---- expert compute (batched over E — MXU-dense) ----
    wg = params["w_gate"].astype(compute_dtype)
    wu = params["w_up"].astype(compute_dtype)
    wd = params["w_down"].astype(compute_dtype)
    g = jnp.einsum("cekd,cedf->cekf", buf, wg)
    u = jnp.einsum("cekd,cedf->cekf", buf, wu)
    out_buf = jnp.einsum("cekf,cefd->cekd", jax.nn.silu(g) * u, wd)
    if _ops.OPT["moe_ep_axes"] is not None:
        from jax.sharding import PartitionSpec as P
        out_buf = jax.lax.with_sharding_constraint(
            out_buf, P(_ops.OPT["moe_ep_axes"], "model", None, None))

    # ---- combine: gather slots back, weight by gates, sum over K ----
    sorted_gate = jnp.take_along_axis(gate.reshape(c, T * K), order, axis=-1)

    def combine_one(ob, se, ps, kp, tos, sg):
        vals = ob[se, jnp.minimum(ps, C - 1)]                  # [TK, D]
        vals = jnp.where(kp[:, None], vals, 0) * sg[:, None]
        return jnp.zeros((T, D), compute_dtype).at[tos].add(
            vals.astype(compute_dtype))

    y = jax.vmap(combine_one)(out_buf, sorted_e, pos, keep, tok_of_slot,
                              sorted_gate)
    y = y.reshape(c, b, s, D)

    if cfg.moe_dense_d_ff:                                     # Arctic residual
        y = y + mlp(params["dense"], x, compute_dtype)
    return y, aux
