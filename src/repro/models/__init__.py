"""Unified model zoo: dense / MoE / SSM / hybrid decoders, one interface."""
from .config import ModelConfig
from .transformer import (init_params, forward, loss_fn, init_cache,
                          decode_step)

__all__ = ["ModelConfig", "init_params", "forward", "loss_fn", "init_cache",
           "decode_step"]
