"""The unified decoder: dense / MoE / SSM / hybrid / stub-frontend models
behind one `init_params` / `forward` / `decode_step` interface.

Chain dim convention: every param leaf is [n_chains, ...], every activation
[n_chains, batch, ...].  Chains are the paper's communication-free ensemble
axis — nothing in this module ever reduces across it.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .attention import attention, init_attention, init_kv_cache
from .config import ModelConfig
from .layers import (cross_entropy, dense_init, embed, init_embedding,
                     init_mlp, mlp, rmsnorm, unembed)
from .moe import init_moe, moe
from .ssm import init_mamba, init_ssm_cache, mamba


def _init_layer(key, kind: str, cfg: ModelConfig, C: int, param_dtype):
    lp = {"norm1": jnp.ones((C, cfg.d_model), jnp.float32)}
    k1, k2 = jax.random.split(key)
    if kind == "A":
        lp["attn"] = init_attention(k1, cfg, C, param_dtype)
        lp["norm2"] = jnp.ones((C, cfg.d_model), jnp.float32)
        if cfg.is_moe:
            lp["moe"] = init_moe(k2, cfg, C, param_dtype)
        else:
            lp["mlp"] = init_mlp(k2, cfg.d_model, cfg.d_ff, C, param_dtype)
    elif kind == "M":
        lp["mamba"] = init_mamba(k1, cfg, C, param_dtype)
    else:
        raise ValueError(f"unknown layer kind {kind!r}")
    return lp


def init_params(key, cfg: ModelConfig, n_chains: int = 1,
                param_dtype=jnp.float32):
    """Full parameter pytree, chain dim leading on every leaf.

    With cfg.scan_layers the per-layer trees are STACKED (leaves
    [L, C, ...]) and the forward pass scans over them — compile time stays
    O(1) in depth instead of O(L)."""
    ks = iter(jax.random.split(key, 4 * cfg.n_layers + 8))
    C = n_chains
    p = {"embed": init_embedding(next(ks), cfg.vocab_size, cfg.d_model, C,
                                 param_dtype),
         "final_norm": jnp.ones((C, cfg.d_model), jnp.float32),
         "layers": []}
    if cfg.scan_layers:
        assert set(cfg.pattern) == {"A"} and not cfg.shared_attn_every, \
            "scan_layers requires a homogeneous attention stack"
        layer_keys = jax.random.split(next(ks), cfg.n_layers)
        layers = [_init_layer(k, "A", cfg, C, param_dtype)
                  for k in layer_keys]
        p["layers_stacked"] = jax.tree.map(lambda *xs: jnp.stack(xs),
                                           *layers)
        del p["layers"]
        if not cfg.tie_embeddings:
            p["lm_head"] = dense_init(next(ks), cfg.d_model,
                                      (C, cfg.d_model, cfg.vocab_size),
                                      param_dtype)
        if cfg.frontend != "none":
            p["frontend_proj"] = dense_init(next(ks), cfg.d_model,
                                            (C, cfg.d_model, cfg.d_model),
                                            param_dtype)
        return p
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(next(ks), cfg.d_model,
                                  (C, cfg.d_model, cfg.vocab_size), param_dtype)
    if cfg.frontend != "none":
        # stub frontend: a single projection of precomputed embeddings
        p["frontend_proj"] = dense_init(next(ks), cfg.d_model,
                                        (C, cfg.d_model, cfg.d_model),
                                        param_dtype)
    for ch in cfg.pattern:
        lp = {"norm1": jnp.ones((C, cfg.d_model), jnp.float32)}
        if ch == "A":
            lp["attn"] = init_attention(next(ks), cfg, C, param_dtype)
            lp["norm2"] = jnp.ones((C, cfg.d_model), jnp.float32)
            if cfg.is_moe:
                lp["moe"] = init_moe(next(ks), cfg, C, param_dtype)
            else:
                lp["mlp"] = init_mlp(next(ks), cfg.d_model, cfg.d_ff, C,
                                     param_dtype)
        elif ch == "M":
            lp["mamba"] = init_mamba(next(ks), cfg, C, param_dtype)
        else:
            raise ValueError(f"unknown layer kind {ch!r}")
        p["layers"].append(lp)
    if cfg.shared_attn_every:
        p["shared"] = {
            "norm1": jnp.ones((C, cfg.d_model), jnp.float32),
            "attn": init_attention(next(ks), cfg, C, param_dtype),
            "norm2": jnp.ones((C, cfg.d_model), jnp.float32),
            "mlp": init_mlp(next(ks), cfg.d_model, cfg.d_ff, C, param_dtype),
        }
    return p


def _shared_block(params, x, cfg, positions, compute_dtype, use_pallas):
    h, _ = attention(params["attn"], rmsnorm(x, params["norm1"], cfg.norm_eps)
                     .astype(compute_dtype), cfg, positions=positions,
                     compute_dtype=compute_dtype, use_pallas=use_pallas)
    x = x + h
    x = x + mlp(params["mlp"], rmsnorm(x, params["norm2"], cfg.norm_eps)
                .astype(compute_dtype), compute_dtype)
    return x


def _ckpt(fn, policy: str, **kw):
    """remat wrapper: 'full' recomputes everything; 'dots' saves matmul
    outputs (§Perf: trades HBM for ~25% less recompute FLOPs)."""
    if policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
            **kw)
    return jax.checkpoint(fn, **kw)


def forward(params, batch, cfg: ModelConfig, *, compute_dtype=jnp.bfloat16,
            use_pallas=True, remat=True, remat_policy="full",
            last_token_only=False):
    """Train-time forward.  batch: {"tokens": [c,b,s]} (+ "embeds"
    [c,b,p,D] for stub frontends).  Returns (logits [c,b,s,V], aux [c]).

    last_token_only: emit logits for the final position only — the serving
    prefill path (§Perf: avoids materializing the [b, s, V] logits tensor,
    which at 32k × 152k vocab is 100s of GB)."""
    tokens = batch["tokens"]
    x = embed(params["embed"], tokens, compute_dtype)
    if cfg.frontend != "none":
        emb = batch["embeds"].astype(compute_dtype)
        emb = jnp.einsum("cbpd,cde->cbpe", emb,
                         params["frontend_proj"].astype(compute_dtype))
        if cfg.frontend == "vision":
            x = jnp.concatenate([emb, x], axis=2)     # prepend patch embeds
        else:                                          # audio: frame-aligned
            x = x + emb
    c, b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None, None],
                                 (c, b, s))
    aux_total = jnp.zeros((c,), jnp.float32)

    def run_layer(lp, kind, x):
        aux = jnp.zeros((c,), jnp.float32)
        if kind == "A":
            h, _ = attention(lp["attn"],
                             rmsnorm(x, lp["norm1"], cfg.norm_eps)
                             .astype(compute_dtype), cfg, positions=positions,
                             compute_dtype=compute_dtype,
                             use_pallas=use_pallas)
            x = x + h
            inner = rmsnorm(x, lp["norm2"], cfg.norm_eps).astype(compute_dtype)
            if cfg.is_moe:
                h, aux = moe(lp["moe"], inner, cfg, compute_dtype)
            else:
                h = mlp(lp["mlp"], inner, compute_dtype)
            x = x + h
        else:
            h, _ = mamba(lp["mamba"],
                         rmsnorm(x, lp["norm1"], cfg.norm_eps)
                         .astype(compute_dtype), cfg,
                         compute_dtype=compute_dtype, use_pallas=use_pallas)
            x = x + h
        return x, aux

    if cfg.scan_layers:
        def body(x, lp):
            x, aux = run_layer(lp, "A", x)
            return x, aux
        if remat:
            body = _ckpt(body, remat_policy)
        x, auxs = jax.lax.scan(body, x, params["layers_stacked"])
        aux_total = aux_total + auxs.sum(0)
        x = rmsnorm(x, params["final_norm"], cfg.norm_eps).astype(compute_dtype)
        if cfg.frontend == "vision":
            x = x[:, :, -tokens.shape[2]:]
        if last_token_only:
            x = x[:, :, -1:]
        if cfg.tie_embeddings:
            logits = unembed(params["embed"], x, compute_dtype)
        else:
            logits = jnp.einsum("cbsd,cdv->cbsv", x,
                                params["lm_head"].astype(compute_dtype))
        return logits, aux_total

    for i, (lp, kind) in enumerate(zip(params["layers"], cfg.pattern)):
        fn = run_layer
        if remat:
            fn = _ckpt(run_layer, remat_policy, static_argnums=(1,))
        x, aux = fn(lp, kind, x)
        aux_total = aux_total + aux
        if cfg.shared_attn_every and (i + 1) % cfg.shared_attn_every == 0:
            def blk(sp, x, pos):
                return _shared_block(sp, x, cfg, pos, compute_dtype,
                                     use_pallas)
            if remat:
                blk = _ckpt(blk, remat_policy)
            x = blk(params["shared"], x, positions)

    x = rmsnorm(x, params["final_norm"], cfg.norm_eps).astype(compute_dtype)
    if cfg.frontend == "vision":
        x = x[:, :, -tokens.shape[2]:]     # logits over text positions only
    if last_token_only:
        x = x[:, :, -1:]
    if cfg.tie_embeddings:
        logits = unembed(params["embed"], x, compute_dtype)
    else:
        logits = jnp.einsum("cbsd,cdv->cbsv", x,
                            params["lm_head"].astype(compute_dtype))
    return logits, aux_total


def loss_fn(params, batch, cfg: ModelConfig, *, compute_dtype=jnp.bfloat16,
            use_pallas=True, remat=True, remat_policy="full"):
    """Per-chain loss [c] — never reduced across chains."""
    logits, aux = forward(params, batch, cfg, compute_dtype=compute_dtype,
                          use_pallas=use_pallas, remat=remat,
                          remat_policy=remat_policy)
    ce = cross_entropy(logits, batch["targets"])
    return ce + cfg.router_aux_weight * aux if cfg.is_moe else ce


# ------------------------------------------------------------------ serving

def init_cache(cfg: ModelConfig, n_chains, batch, max_len, dtype=jnp.bfloat16):
    if cfg.scan_layers:
        one = init_kv_cache(cfg, n_chains, batch, max_len, dtype)
        stacked = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (cfg.n_layers,) + x.shape), one)
        return {"layers_stacked": stacked,
                "pos": jnp.zeros((n_chains, batch), jnp.int32)}
    layers = []
    for ch in cfg.pattern:
        if ch == "A":
            layers.append(init_kv_cache(cfg, n_chains, batch, max_len, dtype))
        else:
            layers.append(init_ssm_cache(cfg, n_chains, batch, dtype))
    cache = {"layers": layers, "pos": jnp.zeros((n_chains, batch), jnp.int32)}
    if cfg.shared_attn_every:
        n_shared = cfg.n_layers // cfg.shared_attn_every
        cache["shared"] = [init_kv_cache(cfg, n_chains, batch, max_len, dtype)
                           for _ in range(n_shared)]
    return cache


def decode_step(params, cache, batch, cfg: ModelConfig, *,
                compute_dtype=jnp.bfloat16, use_pallas=True):
    """One decode step.  batch: {"tokens": [c,b,1], optional "embeds"
    [c,b,1,D] (audio frame conditioning)} → (logits [c,b,1,V], cache')."""
    tokens = batch["tokens"] if isinstance(batch, dict) else batch
    x = embed(params["embed"], tokens, compute_dtype)
    if isinstance(batch, dict) and "embeds" in batch:
        x = x + jnp.einsum("cbpd,cde->cbpe",
                           batch["embeds"].astype(compute_dtype),
                           params["frontend_proj"].astype(compute_dtype))
    c, b, s, _ = x.shape
    pos_scalar = cache["pos"]                      # [c, b]
    positions = pos_scalar[:, :, None]

    if cfg.scan_layers:
        def body(x, inp):
            lp, lc = inp
            h, nc = attention(lp["attn"],
                              rmsnorm(x, lp["norm1"], cfg.norm_eps)
                              .astype(compute_dtype), cfg,
                              positions=positions, cache=lc,
                              compute_dtype=compute_dtype,
                              use_pallas=use_pallas)
            x = x + h
            inner = rmsnorm(x, lp["norm2"], cfg.norm_eps).astype(compute_dtype)
            if cfg.is_moe:
                h, _ = moe(lp["moe"], inner, cfg, compute_dtype)
            else:
                h = mlp(lp["mlp"], inner, compute_dtype)
            return x + h, nc

        x, new_stack = jax.lax.scan(
            body, x, (params["layers_stacked"], cache["layers_stacked"]))
        x = rmsnorm(x, params["final_norm"], cfg.norm_eps).astype(compute_dtype)
        if cfg.tie_embeddings:
            logits = unembed(params["embed"], x, compute_dtype)
        else:
            logits = jnp.einsum("cbsd,cdv->cbsv", x,
                                params["lm_head"].astype(compute_dtype))
        return logits, {"layers_stacked": new_stack, "pos": pos_scalar + 1}

    new_layers = []
    shared_i = 0
    new_shared = list(cache.get("shared", []))
    for i, (lp, kind) in enumerate(zip(params["layers"], cfg.pattern)):
        if kind == "A":
            h, nc = attention(lp["attn"],
                              rmsnorm(x, lp["norm1"], cfg.norm_eps)
                              .astype(compute_dtype), cfg,
                              positions=positions, cache=cache["layers"][i],
                              compute_dtype=compute_dtype,
                              use_pallas=use_pallas)
            x = x + h
            inner = rmsnorm(x, lp["norm2"], cfg.norm_eps).astype(compute_dtype)
            if cfg.is_moe:
                h, _ = moe(lp["moe"], inner, cfg, compute_dtype)
            else:
                h = mlp(lp["mlp"], inner, compute_dtype)
            x = x + h
        else:
            h, nc = mamba(lp["mamba"],
                          rmsnorm(x, lp["norm1"], cfg.norm_eps)
                          .astype(compute_dtype), cfg,
                          cache=cache["layers"][i],
                          compute_dtype=compute_dtype, use_pallas=use_pallas)
            x = x + h
        new_layers.append(nc)
        if cfg.shared_attn_every and (i + 1) % cfg.shared_attn_every == 0:
            sp = params["shared"]
            h, nsc = attention(sp["attn"],
                               rmsnorm(x, sp["norm1"], cfg.norm_eps)
                               .astype(compute_dtype), cfg,
                               positions=positions,
                               cache=cache["shared"][shared_i],
                               compute_dtype=compute_dtype,
                               use_pallas=use_pallas)
            x = x + h
            x = x + mlp(sp["mlp"], rmsnorm(x, sp["norm2"], cfg.norm_eps)
                        .astype(compute_dtype), compute_dtype)
            new_shared[shared_i] = nsc
            shared_i += 1

    x = rmsnorm(x, params["final_norm"], cfg.norm_eps).astype(compute_dtype)
    if cfg.tie_embeddings:
        logits = unembed(params["embed"], x, compute_dtype)
    else:
        logits = jnp.einsum("cbsd,cdv->cbsv", x,
                            params["lm_head"].astype(compute_dtype))
    new_cache = {"layers": new_layers, "pos": pos_scalar + 1}
    if cfg.shared_attn_every:
        new_cache["shared"] = new_shared
    return logits, new_cache
