"""Mamba-2 mixer block (SSD), including the depthwise causal conv and the
decode path that carries (conv_state, ssm_state) instead of a KV cache.

The usual fused in_proj [D → 2·di + 2·N + H] is SPLIT into per-role
projections (wz / wx / wbc / wdt) so each shards cleanly over the tensor-
parallel axis without boundary-crossing reshards; depthwise conv splits the
same way (exactly equivalent math — depthwise is per-channel)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops
from .config import ModelConfig
from .layers import dense_init, rmsnorm


def init_mamba(key, cfg: ModelConfig, n_chains: int, dtype):
    D, di, N, H = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    Kc = cfg.conv_kernel
    ks = jax.random.split(key, 7)
    return {
        "wz": dense_init(ks[0], D, (n_chains, D, di), dtype),
        "wx": dense_init(ks[1], D, (n_chains, D, di), dtype),
        "wbc": dense_init(ks[2], D, (n_chains, D, 2 * N), dtype),
        "wdt": dense_init(ks[3], D, (n_chains, D, H), dtype),
        "conv_x": dense_init(ks[4], Kc, (n_chains, Kc, di), dtype),
        "conv_bc": dense_init(ks[5], Kc, (n_chains, Kc, 2 * N), dtype),
        "conv_b_x": jnp.zeros((n_chains, di), dtype),
        "conv_b_bc": jnp.zeros((n_chains, 2 * N), dtype),
        "A_log": jnp.zeros((n_chains, H), jnp.float32),      # A = -exp(A_log)
        "dt_bias": jnp.zeros((n_chains, H), jnp.float32),
        "out_norm": jnp.ones((n_chains, di), jnp.float32),
        "out_proj": dense_init(ks[6], di, (n_chains, di, D), dtype),
    }


def _causal_conv(u, w, b):
    """Depthwise causal conv over seq.  u: [c,b,s,ch]; w: [c,K,ch]."""
    K = w.shape[1]
    pad = jnp.pad(u, ((0, 0), (0, 0), (K - 1, 0), (0, 0)))
    out = sum(pad[:, :, i:i + u.shape[2], :] * w[:, None, None, i, :]
              for i in range(K))
    return jax.nn.silu(out + b[:, None, None, :])


def mamba(params, x, cfg: ModelConfig, *, cache=None,
          compute_dtype=jnp.bfloat16, use_pallas=True):
    """x: [c, b, s, D] → (y, new_cache).  cache (decode): dict with
    conv_x: [c,b,K-1,di], conv_bc: [c,b,K-1,2N], ssm: [c,b,H,P,N]."""
    c, b, s, D = x.shape
    di, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    cd = compute_dtype
    z = jnp.einsum("cbsd,cdi->cbsi", x, params["wz"].astype(cd))
    xs = jnp.einsum("cbsd,cdi->cbsi", x, params["wx"].astype(cd))
    bc = jnp.einsum("cbsd,cdn->cbsn", x, params["wbc"].astype(cd))
    dt = jnp.einsum("cbsd,cdh->cbsh", x, params["wdt"].astype(cd))
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"][:, None, None, :])   # [c,b,s,H]
    A = -jnp.exp(params["A_log"])                                  # [c, H]

    new_cache = None
    if cache is None:
        xs = _causal_conv(xs, params["conv_x"].astype(cd),
                          params["conv_b_x"].astype(cd))
        bc = _causal_conv(bc, params["conv_bc"].astype(cd),
                          params["conv_b_bc"].astype(cd))
        Bm = bc[..., :N].astype(jnp.float32)
        Cm = bc[..., N:].astype(jnp.float32)
        y = jax.vmap(lambda xc, dc, ac, bv, cv: ops.ssd(
            xc, dc, ac, bv, cv, use_pallas=use_pallas))(
                xs.reshape(c, b, s, H, P), dt, A, Bm, Cm)
        y = y.reshape(c, b, s, di).astype(cd)
    else:
        assert s == 1
        hist_x = jnp.concatenate([cache["conv_x"], xs], axis=2)
        hist_bc = jnp.concatenate([cache["conv_bc"], bc], axis=2)
        xs1 = jax.nn.silu(
            jnp.einsum("cbki,cki->cbi", hist_x, params["conv_x"].astype(cd))
            + params["conv_b_x"].astype(cd)[:, None])
        bc1 = jax.nn.silu(
            jnp.einsum("cbkn,ckn->cbn", hist_bc, params["conv_bc"].astype(cd))
            + params["conv_b_bc"].astype(cd)[:, None])
        B1 = bc1[..., :N].astype(jnp.float32)
        C1 = bc1[..., N:].astype(jnp.float32)
        ssm, y1 = jax.vmap(ops.ssd_decode_step)(
            cache["ssm"], xs1.reshape(c, b, H, P).astype(jnp.float32),
            dt[:, :, 0], A, B1, C1)
        y = y1.reshape(c, b, 1, di).astype(cd)
        new_cache = {"conv_x": hist_x[:, :, 1:], "conv_bc": hist_bc[:, :, 1:],
                     "ssm": ssm}

    # gated RMSNorm (mamba2's norm(y * silu(z)))
    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(cd),
                params["out_norm"], cfg.norm_eps).astype(cd)
    return jnp.einsum("cbsi,cid->cbsd", y,
                      params["out_proj"].astype(cd)), new_cache


def init_ssm_cache(cfg: ModelConfig, n_chains, batch, dtype):
    di, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    K = cfg.conv_kernel
    return {
        "conv_x": jnp.zeros((n_chains, batch, K - 1, di), dtype),
        "conv_bc": jnp.zeros((n_chains, batch, K - 1, 2 * N), dtype),
        "ssm": jnp.zeros((n_chains, batch, H, P, N), jnp.float32),
    }
