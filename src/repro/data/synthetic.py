"""Synthetic corpora drawn from the sLDA generative process itself.

The container is offline, so the paper's two datasets (SEC 10-K MD&A and
Kaggle IMDB reviews) are regenerated synthetically **at the paper's
published dimensions** (Section IV-A).  Since the paper's claims are about
the *sampler* (quasi-ergodicity of naive combination, parity of prediction
combination), sampling the data from the model the sampler assumes is the
correct oracle: any algorithmic failure shows up undiluted.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import Corpus


def make_slda_corpus(key: jax.Array, n_docs: int, vocab_size: int,
                     n_topics: int, doc_len: int, *,
                     alpha: float = 0.1, beta: float = 0.01,
                     phi_concentration: float = 1.0,
                     rho: float = 0.25, eta_scale: float = 2.0,
                     label_type: str = "continuous",
                     var_len: bool = True,
                     doc_len_dist: str = "uniform",
                     len_sigma: float = 0.75,
                     len_skew: float = 4.0) -> tuple[Corpus, jnp.ndarray]:
    """Sample a corpus from the sLDA generative process (Section III-B).

    Returns (corpus, true_eta).  Binary labels follow the paper's note: the
    latent continuous response is thresholded at its median (the paper
    models the logit of the label as Gaussian).

    phi_concentration scales the Dirichlet concentration of the topic-word
    distributions: φ_t ~ Dir(beta · phi_concentration).  1.0 (default) is
    bit-identical to the historical draw; < 1 gives PEAKED topics — each
    topic's mass on a handful of words, so each word occurs in few topics
    (low per-word topic occupancy, the regime where the sparse two-stage
    sampler wins, DESIGN.md §Sparse-sampler); > 1 flattens toward uniform
    (high occupancy — dense territory).

    doc_len_dist picks the length distribution over [.., doc_len]:
      * "uniform"   — uniform in [doc_len//2, doc_len] when var_len
                      (the historical default; mild ~25% padding);
      * "lognormal" — LogNormal(log(doc_len/len_skew), len_sigma) clipped
                      to [4, doc_len]: the heavy-tailed shape of real
                      text (the paper's MD&A filings and IMDB reviews),
                      median ≈ doc_len/len_skew so most of the [D, N]
                      token grid is padding (≈70% at the defaults) —
                      what the ragged execution layer reclaims
                      (DESIGN.md §Ragged-execution).
    """
    ks = jax.random.split(key, 6)
    phi = jax.random.dirichlet(
        ks[0], jnp.full((vocab_size,), beta * phi_concentration), (n_topics,))
    eta = jax.random.normal(ks[1], (n_topics,)) * eta_scale
    theta = jax.random.dirichlet(ks[2], jnp.full((n_topics,), alpha), (n_docs,))

    z = jax.random.categorical(
        ks[3], jnp.log(theta)[:, None, :], shape=(n_docs, doc_len))    # [D, N]
    # token sampling via per-topic inverse CDF: naively indexing
    # log(phi)[z] materializes a [D, N, V] tensor (≈8.5 GB at the paper's
    # corpus size — OOMed); instead binary-search one shared u per token
    # against each topic's CDF and select by z: [T, D, N] ints only.
    cdf = jnp.cumsum(phi, axis=-1)                                     # [T, V]
    u = jax.random.uniform(ks[4], (n_docs, doc_len))
    by_topic = jax.vmap(
        lambda row: jnp.searchsorted(row, u).astype(jnp.int32))(cdf)
    tokens = jnp.take_along_axis(
        by_topic.reshape(n_topics, -1), z.reshape(1, -1), axis=0
    ).reshape(n_docs, doc_len)
    tokens = jnp.clip(tokens, 0, vocab_size - 1)

    if doc_len_dist == "lognormal":
        g = jax.random.normal(ks[5], (n_docs,))
        lens = jnp.exp(jnp.log(doc_len / len_skew) + len_sigma * g)
        lens = jnp.clip(jnp.round(lens), min(4, doc_len), doc_len)
        lens = lens.astype(jnp.int32)
        mask = (jnp.arange(doc_len)[None, :] < lens[:, None]).astype(jnp.float32)
    elif var_len:  # ragged lengths in [doc_len//2, doc_len], like real text
        lens = jax.random.randint(ks[5], (n_docs,), doc_len // 2, doc_len + 1)
        mask = (jnp.arange(doc_len)[None, :] < lens[:, None]).astype(jnp.float32)
    else:
        mask = jnp.ones((n_docs, doc_len), jnp.float32)

    nd = jnp.maximum(mask.sum(-1), 1.0)
    onehot = jax.nn.one_hot(z, n_topics) * mask[..., None]
    zbar = onehot.sum(1) / nd[:, None]
    noise = jax.random.normal(jax.random.fold_in(key, 7), (n_docs,))
    y = zbar @ eta + jnp.sqrt(rho) * noise
    if label_type == "binary":
        y = (y > jnp.median(y)).astype(jnp.float32)

    return Corpus(tokens=tokens.astype(jnp.int32), mask=mask, y=y), eta


def shuffle_corpus(key: jax.Array, corpus: Corpus) -> Corpus:
    perm = jax.random.permutation(key, corpus.n_docs)
    return Corpus(tokens=corpus.tokens[perm], mask=corpus.mask[perm],
                  y=corpus.y[perm])


def train_test_split(corpus: Corpus, n_train: int) -> tuple[Corpus, Corpus]:
    take = lambda x, a, b: x[a:b]
    tr = Corpus(*(take(x, 0, n_train) for x in
                  (corpus.tokens, corpus.mask, corpus.y)))
    te = Corpus(*(take(x, n_train, corpus.n_docs) for x in
                  (corpus.tokens, corpus.mask, corpus.y)))
    return tr, te
