"""LM-side data pipeline: deterministic synthetic token streams.

Real deployments plug a tokenized dataset in here; the interface is a plain
iterator of {tokens, targets} dicts so the training loop is agnostic.  The
synthetic stream is seeded and reproducible, which the checkpoint/restart
tests rely on (restart must resume the stream at the right step).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def synthetic_lm_batch(seed: int, step: int, batch: int, seq_len: int,
                       vocab_size: int) -> dict:
    """One deterministic LM batch keyed by (seed, step) — restartable."""
    rng = np.random.default_rng(np.uint64(seed) * np.uint64(1_000_003) + np.uint64(step))
    toks = rng.integers(0, vocab_size, (batch, seq_len + 1), dtype=np.int32)
    return {"tokens": jnp.asarray(toks[:, :-1]),
            "targets": jnp.asarray(toks[:, 1:])}


def lm_batch_iterator(seed: int, batch: int, seq_len: int, vocab_size: int,
                      start_step: int = 0):
    """Infinite restartable iterator; `start_step` resumes mid-stream."""
    step = start_step
    while True:
        yield step, synthetic_lm_batch(seed, step, batch, seq_len, vocab_size)
        step += 1
