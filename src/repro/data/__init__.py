"""Data pipeline: synthetic sLDA corpora + LM token batching."""
from .synthetic import make_slda_corpus, train_test_split, shuffle_corpus
from .lm import lm_batch_iterator, synthetic_lm_batch

__all__ = ["make_slda_corpus", "train_test_split", "shuffle_corpus",
           "lm_batch_iterator", "synthetic_lm_batch"]
