"""jit-able train / prefill / decode steps, built per (arch × dist) config.

train_step: microbatch gradient accumulation (lax.scan), per-chain loss and
grad-clip, AdamW.  Nothing reduces over the chain dim — the communication-
free property is structural, and the dry-run HLO proves it (no collectives
over the chain mesh axes).

decode_step: optionally combines per-chain logits with the paper's
Simple/Weighted Average rules (serving-time ensemble = the paper's Eq. 6).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import ModelConfig, decode_step as model_decode
from repro.models import forward, loss_fn
from repro.optim import OptConfig, adamw_update
from .sharding import DistConfig


def make_train_step(cfg: ModelConfig, dist: DistConfig, opt: OptConfig):
    cd = jnp.dtype(dist.compute_dtype)

    def loss_total(params, mb):
        per_chain = loss_fn(params, mb, cfg, compute_dtype=cd,
                            use_pallas=dist.use_pallas, remat=dist.remat,
                            remat_policy=dist.remat_policy)
        return per_chain.sum(), per_chain      # chains are independent

    def train_step(params, opt_state, batch):
        a = dist.accum_steps
        if a == 1:
            (_, per_chain), grads = jax.value_and_grad(
                loss_total, has_aux=True)(params, batch)
        else:
            def micro(carry, mb):
                g_acc, l_acc = carry
                (_, l), g = jax.value_and_grad(loss_total, has_aux=True)(
                    params, mb)
                g_acc = jax.tree.map(
                    lambda x, y: x + y.astype(jnp.float32), g_acc, g)
                return (g_acc, l_acc + l), None

            # [C, B, ...] → [A, C, B/A, ...] microbatch-major for the scan
            def split(x):
                c, b = x.shape[:2]
                return jnp.moveaxis(
                    x.reshape((c, a, b // a) + x.shape[2:]), 1, 0)

            mbs = jax.tree.map(split, batch)
            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
            c = jax.tree.leaves(params)[0].shape[0]
            (grads, per_chain), _ = jax.lax.scan(
                micro, (g0, jnp.zeros((c,), jnp.float32)), mbs)
            grads = jax.tree.map(lambda g: g / a, grads)
            per_chain = per_chain / a

        params2, opt2, metrics = adamw_update(params, grads, opt_state, opt)
        metrics["loss"] = per_chain
        return params2, opt2, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, dist: DistConfig):
    cd = jnp.dtype(dist.compute_dtype)

    def prefill_step(params, batch):
        logits, _ = forward(params, batch, cfg, compute_dtype=cd,
                            use_pallas=dist.use_pallas, remat=False,
                            last_token_only=dist.opt_prefill_last_only)
        return logits

    return prefill_step


def make_decode_step(cfg: ModelConfig, dist: DistConfig,
                     combine: str = "none"):
    """combine: "none" (per-chain logits out) | "simple" | "weighted".
    Weighted expects batch["chain_weights"]: [C] (e.g. inverse validation
    loss — the LM analogue of the paper's inverse training MSE)."""
    cd = jnp.dtype(dist.compute_dtype)

    def step(params, cache, batch):
        logits, new_cache = model_decode(params, cache, batch, cfg,
                                         compute_dtype=cd,
                                         use_pallas=dist.use_pallas)
        if combine == "none":
            return logits, new_cache
        # the paper's prediction combination, applied to token distributions
        probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
        if combine == "simple":
            mix = jnp.mean(probs, axis=0)                      # Eq. (7)
        else:
            w = batch["chain_weights"]
            w = w / jnp.maximum(w.sum(), 1e-9)
            mix = jnp.einsum("c,cbsv->bsv", w, probs)          # Eq. (9)
        return jnp.log(jnp.maximum(mix, 1e-30)), new_cache

    return step
