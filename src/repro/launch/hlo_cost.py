"""Loop-aware HLO cost model.

XLA's HloCostAnalysis (and therefore `compiled.cost_analysis()`) counts a
`while` body ONCE, so anything under a `lax.scan` — microbatch
accumulation, blocked attention, SSD chunk scans — is undercounted by its
trip count.  This module re-derives the three roofline quantities from the
optimized HLO text with loop expansion:

  flops       2·M·N·K of every dot, resolved through operand shape lookup
              (matmul-only compute model — standard MFU practice)
  hbm bytes   per-instruction output+operand bytes in non-fused
              computations (fusion internals don't touch HBM); gathers
              count output+indices, not the full gathered operand
  collective  payload per op kind (all-reduce 2×, reduce-scatter ×group),
              split intra-pod vs cross-pod via replica_groups expansion

`while` trip counts are recovered from the largest integer constant in the
loop's condition computation (exact for lax.scan's counted loops).
"""
from __future__ import annotations

import dataclasses
import math
import re

import numpy as np

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "u64": 8,
}
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.-]+)\s*=\s*(\(?[^=]*?\)?)\s*([\w-]+)\((.*)$")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.-]+)\s*\(.*\)\s*->.*\{")
_OPERAND_RE = re.compile(r"%([\w.-]+)")
_ATTR_CALL_RE = re.compile(r"(calls|body|condition|to_apply)=%?([\w.-]+)")
_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([0-9,]+)\](?:T\(([0-9,]+)\))?")
_EXPL_RE = re.compile(r"replica_groups=\{(\{[0-9,{}]*\})\}")
_CONST_RE = re.compile(r"=\s*[a-z0-9]+\[\]\s*constant\((\d+)\)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_NO_TRAFFIC = {"parameter", "constant", "get-tuple-element", "bitcast",
               "tuple", "iota", "after-all", "partition-id", "replica-id",
               "reshape", "copy-start", "copy-done", "opt-barrier"}


def _parse_shapes(text: str):
    """[(bytes, dims)] of every shape literal in `text`."""
    out = []
    for dtype, dims_s in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        dims = [int(d) for d in dims_s.split(",") if d]
        out.append((_DTYPE_BYTES[dtype] * math.prod(dims), dims))
    return out


def _groups(line: str):
    m = _IOTA_RE.search(line)
    if m:
        g, n = int(m.group(1)), int(m.group(2))
        dims = [int(x) for x in m.group(3).split(",")]
        ids = np.arange(math.prod(dims)).reshape(dims)
        if m.group(4):
            ids = ids.transpose([int(x) for x in m.group(4).split(",")])
        return ids.reshape(g, n)
    m = _EXPL_RE.search(line)
    if m:
        rows = re.findall(r"\{([0-9,]+)\}", m.group(1))
        parsed = [[int(x) for x in r.split(",") if x] for r in rows]
        width = max((len(p) for p in parsed), default=0)
        if width:
            return np.array([p for p in parsed if len(p) == width])
    return None


@dataclasses.dataclass
class Instr:
    name: str
    op: str
    out_bytes: int
    out_dims: list
    operands: list
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    instrs: list = dataclasses.field(default_factory=list)
    shape_of: dict = dataclasses.field(default_factory=dict)


_COMMENT_RE = re.compile(r"/\*.*?\*/")


def parse_module(text: str):
    comps, cur, entry = {}, None, None
    for line in text.splitlines():
        # XLA prints /*index=N*/ comments inside big tuple shapes — the
        # '=' inside them breaks instruction parsing, so strip them first
        if "/*" in line:
            line = _COMMENT_RE.sub("", line)
        hdr = _COMP_HDR_RE.match(line)
        if hdr and "{" in line:
            cur = Computation(hdr.group(1))
            comps[cur.name] = cur
            if line.startswith("ENTRY"):
                entry = cur.name
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, shape_part, op, rest = m.groups()
        shapes = _parse_shapes(shape_part)
        out_bytes = sum(s for s, _ in shapes)
        out_dims = shapes[0][1] if len(shapes) == 1 else []
        args = rest.split(")", 1)[0]
        operands = _OPERAND_RE.findall(args)
        ins = Instr(name, op, out_bytes, out_dims, operands, line.strip())
        cur.instrs.append(ins)
        cur.shape_of[name] = shapes
    return comps, entry


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_cross_pod: float = 0.0
    coll_by_kind: dict = dataclasses.field(default_factory=dict)
    coll_count: int = 0
    unknown_trip_loops: int = 0

    def __iadd__(self, o):
        self.flops += o.flops
        self.hbm_bytes += o.hbm_bytes
        self.coll_bytes += o.coll_bytes
        self.coll_cross_pod += o.coll_cross_pod
        self.coll_count += o.coll_count
        self.unknown_trip_loops += o.unknown_trip_loops
        for k, v in o.coll_by_kind.items():
            self.coll_by_kind[k] = self.coll_by_kind.get(k, 0.0) + v
        return self

    def scaled(self, f):
        return Cost(self.flops * f, self.hbm_bytes * f, self.coll_bytes * f,
                    self.coll_cross_pod * f,
                    {k: v * f for k, v in self.coll_by_kind.items()},
                    self.coll_count * f, self.unknown_trip_loops)


def _trip_count(comps, cond_name: str) -> int | None:
    cond = comps.get(cond_name)
    if cond is None:
        return None
    consts = [int(m.group(1)) for i in cond.instrs
              for m in [_CONST_RE.search(i.line)] if m]
    return max(consts) if consts else None


class HloCost:
    def __init__(self, text: str, pod_size: int = 256):
        self.comps, self.entry = parse_module(text)
        self.pod_size = pod_size
        self._fused = set()
        for comp in self.comps.values():
            for ins in comp.instrs:
                if ins.op == "fusion":
                    m = _ATTR_CALL_RE.search(ins.line)
                    if m:
                        self._fused.add(m.group(2))
        self._memo = {}

    # ------------------------------------------------------------- per-op
    def _instr_cost(self, comp: Computation, ins: Instr, fused: bool) -> Cost:
        c = Cost()
        op = ins.op
        if op == "dot":
            k = 1
            m = _CONTRACT_RE.search(ins.line)
            if m and ins.operands:
                lhs_shapes = comp.shape_of.get(ins.operands[0])
                if lhs_shapes:
                    dims = lhs_shapes[0][1]
                    for d in (int(x) for x in m.group(1).split(",") if x):
                        if d < len(dims):
                            k *= dims[d]
            out_elems = math.prod(ins.out_dims) if ins.out_dims else 0
            c.flops += 2.0 * out_elems * k
        base_op = op[:-6] if op.endswith("-start") else op
        if base_op in _COLLECTIVES and not op.endswith("-done"):
            out_b = ins.out_bytes
            if op.endswith("-start"):
                out_b = out_b // 2       # start tuples carry (in, out)
            groups = _groups(ins.line)
            gsize = groups.shape[1] if groups is not None else 1
            payload = {"all-reduce": 2 * out_b,
                       "all-gather": out_b,
                       "reduce-scatter": out_b * gsize,
                       "all-to-all": out_b,
                       "collective-permute": out_b}[base_op]
            c.coll_bytes += payload
            c.coll_count += 1
            c.coll_by_kind[base_op] = c.coll_by_kind.get(base_op, 0) + payload
            if groups is not None and (groups // self.pod_size !=
                                       groups[:, :1] // self.pod_size).any():
                c.coll_cross_pod += payload
        # HBM traffic: skip fusion internals and no-traffic ops
        if not fused and op not in _NO_TRAFFIC:
            if op in ("gather", "dynamic-slice"):
                idx_b = sum(sum(s for s, _ in comp.shape_of.get(o, []))
                            for o in ins.operands[1:])
                c.hbm_bytes += ins.out_bytes + idx_b
            elif op in ("scatter", "dynamic-update-slice"):
                # in-place update (XLA aliases the operand buffer in
                # loops): traffic ≈ read+write of the updated window, not
                # the whole buffer
                upd = sum(sum(s for s, _ in comp.shape_of.get(o, []))
                          for o in ins.operands[1:])
                c.hbm_bytes += 2 * upd
            else:
                in_b = sum(sum(s for s, _ in comp.shape_of.get(o, []))
                           for o in ins.operands)
                c.hbm_bytes += ins.out_bytes + in_b
        return c

    # ------------------------------------------------------ per-computation
    def comp_cost(self, name: str) -> Cost:
        if name in self._memo:
            return self._memo[name]
        comp = self.comps.get(name)
        total = Cost()
        if comp is None:
            return total
        fused = name in self._fused
        self._memo[name] = total          # break cycles defensively
        for ins in comp.instrs:
            total += self._instr_cost(comp, ins, fused)
            calls = dict((k, v) for k, v in _ATTR_CALL_RE.findall(ins.line))
            if ins.op == "while":
                body = calls.get("body")
                cond = calls.get("condition")
                trip = _trip_count(self.comps, cond) if cond else None
                if trip is None:
                    trip = 1
                    total.unknown_trip_loops += 1
                inner = Cost()
                if body:
                    inner += self.comp_cost(body)
                if cond:
                    inner += self.comp_cost(cond)
                total += inner.scaled(trip)
            elif ins.op in ("fusion", "call", "custom-call", "conditional",
                            "map"):
                for key in ("calls", "to_apply"):
                    if key in calls:
                        total += self.comp_cost(calls[key])
            # reduce/sort `to_apply` bodies are O(1)-sized — skipped
        return total

    def total(self) -> Cost:
        return self.comp_cost(self.entry)
