"""Post-compile HLO analysis: collective traffic + roofline terms.

`cost_analysis()` gives FLOPs and HBM bytes but NOT collective bytes, so we
parse the optimized HLO text.  XLA prints collectives as

  %all-reduce.N = (f32[...], ...) all-reduce(%ref, ...), channel_id=...,
      replica_groups=[G,N]<=[T]T(perm) | {{0,1},{2,3}}, ...

Operands are refs (no shapes), so payloads derive from the OUTPUT shape:
  all-reduce          2 × out        (ring traffic per device ≈ 2× payload)
  all-gather          out            (output is the gathered full tensor)
  reduce-scatter      out × group    (input = group_size × output)
  all-to-all          out
  collective-permute  out

replica_groups (both explicit and iota forms) are expanded to split traffic
into intra-pod (ICI) vs cross-pod (DCN) — the quantity the paper's
communication-free chains drive to zero.
"""
from __future__ import annotations

import dataclasses
import math
import re

import numpy as np

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]\{?")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_OP_RE = re.compile(
    r"=\s*(.*?)\s+(" + "|".join(_COLLECTIVES) + r")(-start|-done)?\(")
_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([0-9,]+)\](?:T\(([0-9,]+)\))?")
_EXPL_RE = re.compile(r"replica_groups=\{(\{[0-9,{}]*\})\}")


def _shape_bytes(text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _groups(line: str):
    """Expand replica_groups to a [G, N] int array, or None."""
    m = _IOTA_RE.search(line)
    if m:
        g, n = int(m.group(1)), int(m.group(2))
        dims = [int(x) for x in m.group(3).split(",")]
        ids = np.arange(math.prod(dims)).reshape(dims)
        if m.group(4):
            ids = ids.transpose([int(x) for x in m.group(4).split(",")])
        return ids.reshape(g, n)
    m = _EXPL_RE.search(line)
    if m:
        rows = re.findall(r"\{([0-9,]+)\}", m.group(1))
        parsed = [[int(x) for x in r.split(",") if x] for r in rows]
        width = max((len(p) for p in parsed), default=0)
        if width == 0:
            return None
        return np.array([p for p in parsed if len(p) == width])
    return None


@dataclasses.dataclass
class CollectiveStats:
    bytes_total: float = 0.0
    bytes_cross_pod: float = 0.0
    count: int = 0
    by_kind: dict = dataclasses.field(default_factory=dict)


def collective_stats(hlo_text: str, pod_size: int = 256) -> CollectiveStats:
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = _OP_RE.search(stripped)
        if not m or m.group(3) == "-done":
            continue
        kind = m.group(2)
        out_b = _shape_bytes(m.group(1))
        groups = _groups(stripped)
        gsize = groups.shape[1] if groups is not None else 1
        payload = {"all-reduce": 2 * out_b,
                   "all-gather": out_b,
                   "reduce-scatter": out_b * gsize,
                   "all-to-all": out_b,
                   "collective-permute": out_b}[kind]
        stats.bytes_total += payload
        stats.count += 1
        stats.by_kind[kind] = stats.by_kind.get(kind, 0.0) + payload
        if groups is not None and (groups // pod_size !=
                                   groups[:, :1] // pod_size).any():
            stats.bytes_cross_pod += payload
    return stats


# --------------------------------------------------------------- roofline

PEAK_FLOPS = 197e12        # bf16 / chip (TPU v5e)
HBM_BW = 819e9             # bytes/s / chip
ICI_BW = 50e9              # bytes/s / link


def roofline_terms(flops: float, hbm_bytes: float, coll_bytes: float,
                   n_chips: int, per_device: bool = True) -> dict:
    """Three roofline terms in seconds.  XLA reports the PARTITIONED
    (per-device) module, so flops/bytes are already per-chip; the parsed
    collective payload is likewise the per-device program's traffic."""
    div = 1 if per_device else n_chips
    t_compute = flops / div / PEAK_FLOPS
    t_memory = hbm_bytes / div / HBM_BW
    t_coll = coll_bytes / div / ICI_BW
    dominant = max(("compute", t_compute), ("memory", t_memory),
                   ("collective", t_coll), key=lambda kv: kv[1])[0]
    return {"t_compute_s": t_compute, "t_memory_s": t_memory,
            "t_collective_s": t_coll, "dominant": dominant}
