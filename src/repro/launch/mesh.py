"""Production meshes.

Defined as FUNCTIONS (never module-level constants) so importing this
module never touches jax device state — the dry-run must set
XLA_FLAGS=--xla_force_host_platform_device_count=512 before the first jax
device query, and smoke tests must keep seeing 1 device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; multi_pod stacks 2 pods → 512 chips.

    Axis semantics (DESIGN.md §4):
      pod   — the communication-free chain boundary for large models
              (no collectives cross it during training)
      data  — within-chain data parallelism / FSDP, or chain axis for
              small models (16 chains per pod)
      model — tensor/expert parallelism
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Single-process CPU mesh for tests/examples: every axis size 1 except
    data, which takes all local devices."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))
