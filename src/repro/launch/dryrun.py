import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape) cell on the
production meshes and extract memory / cost / collective analyses.

This is the proof that the distribution config is coherent without real
hardware: a sharding mismatch, an OOM-at-compile or an unsupported
collective fails the compile, and the compiled artifact feeds §Roofline.

Usage:
  python -m repro.launch.dryrun --arch internlm2-1.8b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out artifacts/]
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, RUNS, SHAPES, cells_for, input_specs
from repro.models import ModelConfig, init_cache, init_params
from repro.optim import OptConfig, init_opt_state
from .hlo import collective_stats, roofline_terms
from .hlo_cost import HloCost
from .mesh import make_production_mesh
from .sharding import (DistConfig, batch_specs, cache_specs, named,
                       opt_state_specs, param_specs)
from .steps import make_decode_step, make_prefill_step, make_train_step


def _spec_struct(tree, dtype_map=None):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def build_cell(arch: str, shape_name: str, multi_pod: bool,
               chains_override: int | None = None,
               dist_overrides: dict | None = None):
    """Returns (lowered, meta) for one dry-run cell.

    chains_override forces a chain count — e.g. n_chains=1 on the 2-pod
    mesh is the standard cross-pod data-parallel BASELINE against which
    the paper's communication-free chains are quantified.
    dist_overrides tweaks DistConfig fields (§Perf switches)."""
    cfg: ModelConfig = ARCHS[arch]
    run = RUNS[arch]
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size

    if chains_override is not None:
        n_chains = chains_override
    elif shape.kind == "train":
        n_chains = run["chains_multi" if multi_pod else "chains_single"]
    else:
        n_chains = 1          # serving default: single replica per mesh
    dist = DistConfig(
        n_chains=n_chains, fsdp=run["fsdp"],
        accum_steps=run["accum_steps"] if shape.kind == "train" else 1,
        param_dtype=run["param_dtype"], opt_dtype=run["opt_dtype"],
        use_pallas=False, **(dist_overrides or {}))
    from repro.kernels import ops as _ops
    from .sharding import chain_axes as _ca, dp_axes as _da, _maybe
    _ops.OPT["causal_skip"] = dist.opt_causal_attention
    _ops.OPT["block_q"] = dist.opt_attn_block_q
    _ops.OPT["head_shard_axes"] = (
        (_maybe(_ca(mesh, n_chains)), _maybe(_da(mesh, n_chains)))
        if dist.opt_head_shard else None)
    _ops.OPT["probs_bf16"] = dist.opt_probs_bf16
    _ops.OPT["moe_ep_axes"] = (_maybe(_ca(mesh, n_chains))
                               if dist.opt_moe_ep else None)
    pdt = jnp.dtype(dist.param_dtype)

    params_struct = jax.eval_shape(
        lambda k: init_params(k, cfg, n_chains, pdt),
        jax.ShapeDtypeStruct((2,), jnp.uint32))
    pspecs = param_specs(params_struct, mesh, dist)
    batch_struct = input_specs(cfg, shape, n_chains)
    bspecs = batch_specs(batch_struct, mesh, dist,
                         replicated_serve=shape.kind != "train")

    with mesh:
        if shape.kind == "train":
            opt = OptConfig(opt_dtype=dist.opt_dtype)
            opt_struct = jax.eval_shape(
                lambda p: init_opt_state(p, opt), params_struct)
            ospecs = opt_state_specs(pspecs, mesh)
            step = make_train_step(cfg, dist, opt)
            metrics_specs = None    # let the compiler place small outputs
            lowered = jax.jit(
                step,
                in_shardings=(named(pspecs, mesh), named(ospecs, mesh),
                              named(bspecs, mesh)),
                out_shardings=(named(pspecs, mesh), named(ospecs, mesh),
                               None),
            ).lower(params_struct, opt_struct, batch_struct)
        elif shape.kind == "prefill":
            step = make_prefill_step(cfg, dist)
            lowered = jax.jit(
                step, in_shardings=(named(pspecs, mesh), named(bspecs, mesh)),
            ).lower(params_struct, batch_struct)
        else:                       # decode
            b = shape.global_batch
            cache_struct = jax.eval_shape(
                lambda: init_cache(cfg, n_chains, b, shape.seq_len,
                                   jnp.bfloat16))
            cspecs = cache_specs(cache_struct, mesh, dist)
            step = make_decode_step(cfg, dist, combine="none")
            lowered = jax.jit(
                step,
                in_shardings=(named(pspecs, mesh), named(cspecs, mesh),
                              named(bspecs, mesh)),
                out_shardings=(None, named(cspecs, mesh)),
            ).lower(params_struct, cache_struct, batch_struct)

    meta = dict(arch=arch, shape=shape_name, kind=shape.kind,
                multi_pod=multi_pod, n_chips=n_chips, n_chains=n_chains,
                fsdp=dist.fsdp, accum=dist.accum_steps,
                param_dtype=dist.param_dtype, opt_dtype=dist.opt_dtype,
                params=cfg.param_count(),
                active_params=cfg.active_param_count())
    return lowered, meta


def analyze(lowered, meta, *, verbose=True):
    t0 = time.time()
    compiled = lowered.compile()
    meta["compile_s"] = round(time.time() - t0, 1)

    mem = compiled.memory_analysis()
    ca = compiled.cost_analysis()
    meta["bytes_per_device"] = {
        "arguments": getattr(mem, "argument_size_in_bytes", None),
        "output": getattr(mem, "output_size_in_bytes", None),
        "temp": getattr(mem, "temp_size_in_bytes", None),
        "peak": getattr(mem, "peak_memory_in_bytes", None),
    }
    # raw XLA numbers (loop bodies counted ONCE — kept for reference)
    meta["xla_flops_raw"] = float(ca.get("flops", 0.0))
    meta["xla_bytes_raw"] = float(ca.get("bytes accessed", 0.0))
    # loop-aware cost model (see hlo_cost.py) — the roofline source
    text = compiled.as_text()
    cost = HloCost(text, pod_size=256).total()
    stats = collective_stats(text, pod_size=256)   # static (spec) count
    meta["hlo_flops"] = cost.flops
    meta["hlo_bytes"] = cost.hbm_bytes
    meta["collective_bytes"] = cost.coll_bytes
    meta["collective_bytes_cross_pod"] = cost.coll_cross_pod
    meta["collective_count"] = cost.coll_count
    meta["collective_by_kind"] = {k: float(v)
                                  for k, v in cost.coll_by_kind.items()}
    meta["collective_bytes_static"] = stats.bytes_total
    meta["unknown_trip_loops"] = cost.unknown_trip_loops
    # XLA reports the PARTITIONED (per-device) module → per_device=True
    terms = roofline_terms(cost.flops, cost.hbm_bytes, cost.coll_bytes,
                           meta["n_chips"])
    meta.update(terms)
    # useful-FLOP ratio: 6·N·D for train, 2·N·D per generated token
    toks = {"train": SHAPES[meta["shape"]].global_batch *
                     SHAPES[meta["shape"]].seq_len,
            "prefill": SHAPES[meta["shape"]].global_batch *
                       SHAPES[meta["shape"]].seq_len,
            "decode": SHAPES[meta["shape"]].global_batch}[meta["kind"]]
    mult = 6 if meta["kind"] == "train" else 2
    meta["model_flops"] = mult * meta["active_params"] * toks
    # padded-slot token count per step + the mask-weighted fraction of it
    # that is real (LM batches here are dense → 1.0; masked workloads
    # must report honestly so roofline.py can show effective tok/s next
    # to padded-slot tok/s — the padding-waste column)
    meta["tokens_per_step"] = toks
    meta["real_token_frac"] = 1.0
    whole_flops = cost.flops * meta["n_chips"]
    meta["useful_flop_ratio"] = (meta["model_flops"] / whole_flops
                                 if whole_flops else 0.0)
    if verbose:
        print(json.dumps({k: v for k, v in meta.items()
                          if k not in ("collective_by_kind",)}, indent=1))
    return meta


def run_cell(arch, shape_name, multi_pod, out_dir=None, verbose=True,
             chains_override=None, tag_suffix="", dist_overrides=None):
    lowered, meta = build_cell(arch, shape_name, multi_pod, chains_override,
                               dist_overrides)
    meta = analyze(lowered, meta, verbose=verbose)
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        tag = (f"{arch}_{shape_name}_{'multi' if multi_pod else 'single'}"
               f"{tag_suffix}")
        with open(os.path.join(out_dir, tag + ".json"), "w") as f:
            json.dump(meta, f, indent=1)
    return meta


def slda_plan_report(args):
    """Print the chosen sLDA `ExecutionPlan` for a corpus of the given
    shape — executor, bucket widths, spl schedule, refresh cadence, and
    the predicted padded-slot vs effective token work — so a user can
    see WHY a route was picked before paying for a run (DESIGN.md
    §Execution-plan).  The corpus is synthetic (the paper's heavy-tailed
    log-normal length profile) but the plan depends only on lengths and
    the config, so the report transfers to any corpus with the same
    shape."""
    from repro.core import SLDAConfig, build_plan, build_schedule, partition
    from repro.data import make_slda_corpus

    cfg = SLDAConfig(n_topics=args.slda_topics, vocab_size=args.slda_vocab,
                     length_buckets=args.slda_buckets,
                     sweeps_per_launch=args.slda_spl,
                     use_pallas=args.slda_pallas,
                     sampler_mode=args.slda_sampler,
                     sparse_topic_cap=args.slda_topic_cap)
    corpus, _ = make_slda_corpus(
        jax.random.PRNGKey(0), args.slda_docs, args.slda_vocab,
        args.slda_topics, args.slda_maxlen,
        phi_concentration=args.slda_phi_conc,
        doc_len_dist="lognormal" if args.slda_len_sigma > 0 else "uniform",
        len_sigma=args.slda_len_sigma or 1.0)
    m = args.slda_chains
    train_plan = build_plan(
        build_schedule(partition(corpus, m), cfg), cfg)
    predict_plan = build_plan(build_schedule(corpus, cfg), cfg)
    report = {
        "backend_resolution": cfg.resolve_backend(),
        "train_plan": train_plan.describe(),
        "predict_plan": predict_plan.describe(),
    }
    d = train_plan.describe()
    why = []
    why.append(f"backend={train_plan.backend}: "
               + ("use_pallas off -> batched-jnp twins"
                  if not cfg.use_pallas else
                  ("all devices TPU -> compiled kernels"
                   if train_plan.backend == "pallas"
                   else "use_pallas forced on non-TPU -> interpret mode")))
    if d["buckets"] == 1:
        why.append("1 bucket (length_buckets=0 or uniform lengths) -> "
                   "padded degenerate schedule; per-bucket 'blocks' "
                   "executor == the padded fused launches")
    elif train_plan.executor == "stair":
        why.append(f"{d['buckets']} buckets on the jnp route -> STAIR "
                   "executor (per-bucket launches would re-run the "
                   "token loop per bucket; stair keeps step count at "
                   "N_max while slots collapse to the staircase)")
    else:
        why.append(f"{d['buckets']} buckets on the pallas route -> one "
                   "fused launch per bucket (chain grids intact)")
    n_rem = d["remainder_sweeps"]
    why.append(f"spl schedule: {d['launches'] - (1 if n_rem else 0)} "
               f"launches x {d['sweeps_per_launch']} sweeps"
               + (f" + one {n_rem}-sweep remainder launch" if n_rem
                  else "")
               + f" (total sweeps stay exact); {d['count_refresh']}")
    why.append(f"predicted work per chain-sweep: "
               f"{d['slot_tokens_per_sweep']} executed slot-tokens vs "
               f"{d['real_tokens_per_sweep']} real (effective tok/s = "
               f"slot tok/s / {d['slot_vs_effective_tok_ratio']}); the "
               f"padded path would execute "
               f"{d['docs_per_chain'] * d['ctr_stride']} slots")
    # sampler-mode routing: estimate the per-word topic occupancy of THIS
    # corpus (a uniform-random assignment init, the same state training
    # starts from) — the support width the sparse two-stage draw exploits
    from repro.core import (counts_from_assignments, topic_occupancy)
    T = cfg.n_topics
    z0 = jax.random.randint(jax.random.PRNGKey(1), corpus.tokens.shape,
                            0, T, jnp.int32)
    _, ntw0, _ = counts_from_assignments(corpus.tokens, corpus.mask, z0,
                                         T, cfg.vocab_size)
    occ = topic_occupancy(jnp.swapaxes(ntw0, -1, -2))
    occ_mean = float(jnp.mean(occ))
    cap = d["sparse_topic_cap"]
    report["estimated_word_topic_occupancy"] = {
        "mean": round(occ_mean, 2), "max": int(jnp.max(occ)),
        "n_topics": T, "note": "at uniform init; converged models on "
        "peaked corpora sit far lower"}
    if d["sampler_mode"] == "sparse":
        why.append(
            f"sampler=sparse: two-stage draw over a cap={cap} topic "
            f"bucket + blocked residual instead of the dense O(T^2) "
            f"prefix matmul — distributionally exact for any occupancy; "
            f"estimated word-topic occupancy {occ_mean:.1f}/{T} at init "
            + ("(<= cap: stage 2 rarely fires)" if occ_mean <= cap
               else "(> cap: residual corrections more frequent until "
                    "counts concentrate)"))
        if T <= 32:
            why.append(f"NOTE T={T} is small — the dense draw's single "
                       f"{T}x{T} matmul is already cheap; sparse wins "
                       "from T~128 up (BENCH_slda_sparse.json)")
    else:
        why.append(
            f"sampler=dense: exact O(T) per-token draw via one {T}x{T} "
            f"prefix matmul — bit-identical to every prior release; "
            f"--slda-sampler sparse pays off when T is large and the "
            f"word-topic occupancy (est. {occ_mean:.1f}/{T} at init) "
            f"stays well under T")
    # supervisor plan (DESIGN.md §Fault-model): what the fault-tolerant
    # runtime would check and how it would recover, for this plan
    from repro.core import HealthConfig, RecoveryPolicy
    health, rec = HealthConfig(), RecoveryPolicy(
        max_restarts=args.slda_restarts, min_alive_frac=args.slda_min_alive)
    n_bound = train_plan.n_boundaries()
    checks = [n for n, on in [("nan", health.check_nan),
                              ("counts", health.check_counts),
                              ("mse-z", health.check_mse)] if on]
    report["supervisor"] = {
        "health_checks": checks,
        "em_boundaries": n_bound,
        "mse_z_cut": health.mse_z_cut,
        "mse_warmup_boundaries": health.mse_warmup,
        "max_restarts_per_chain": rec.max_restarts,
        "backoff_base_s": rec.backoff_base,
        "min_alive_frac": rec.min_alive_frac,
    }
    why.append(f"supervisor: health checks [{', '.join(checks)}] compiled "
               f"into the EM scan at each of the {n_bound} boundaries "
               f"(zero extra host syncs); hard faults get up to "
               f"{rec.max_restarts} checkpointed restarts per chain "
               f"(backoff {rec.backoff_base}s base), then quarantine — "
               f"exact chain drop, run aborts below "
               f"{rec.min_alive_frac:.0%} alive")
    report["why"] = why
    print(json.dumps(report, indent=1))
    return report


def slda_serve_report(args):
    """Print what the continuous-batching prediction service would run
    for a traffic profile of the given shape — the calibrated slot
    layout (width ladder + per-rung quota), the ONE bucket signature
    every micro-batch dispatches under, and the plan that signature
    compiles to — before paying to stand the service up (the serving
    twin of --slda-plan; DESIGN.md §Serving)."""
    from repro.core import SLDAConfig, partition, train_chains
    from repro.data import make_slda_corpus
    from repro.serving import (STATUS_SHED_QUEUE, ServiceConfig,
                               SLDAPredictionService)

    cfg = SLDAConfig(n_topics=args.slda_topics, vocab_size=args.slda_vocab,
                     n_iters=1, use_pallas=args.slda_pallas)
    corpus, _ = make_slda_corpus(
        jax.random.PRNGKey(0), args.slda_docs, args.slda_vocab,
        args.slda_topics, args.slda_maxlen,
        doc_len_dist="lognormal" if args.slda_len_sigma > 0 else "uniform",
        len_sigma=args.slda_len_sigma or 1.0)
    lens = corpus.mask.sum(-1).astype(int)
    svc_cfg = ServiceConfig.calibrated(
        lens, max_doc_len=args.slda_maxlen, batch_docs=args.slda_batch_docs,
        n_buckets=args.slda_buckets,
        max_pending=args.slda_max_pending,
        default_deadline_s=args.slda_deadline_ms / 1e3,
        rate_limit_per_s=args.slda_rate)
    # a 1-sweep trained ensemble is enough — the serving plan depends
    # only on the slot layout, the config, and the chain count
    models = train_chains(jax.random.PRNGKey(1),
                          partition(corpus, args.slda_chains), cfg)
    svc = SLDAPredictionService(models, cfg, svc_cfg)
    report = {"service": svc.describe()}
    d = report["service"]
    frac = [q / args.slda_batch_docs for q in svc_cfg.slot_quota]
    why = [
        f"calibrated ladder {list(svc_cfg.width_ladder)} / quota "
        f"{list(svc_cfg.slot_quota)} from the traffic length sample "
        f"(same cost-model DP as bucket_corpus); slot shares "
        f"{[round(f, 2) for f in frac]}",
        "every micro-batch fills this ONE layout (dummies mask unused "
        "slots), so every dispatch has the single bucket signature "
        f"{d['cache_key_signature']} — the plan cache compiles once and "
        "steady-state traffic never retraces",
        f"dispatch = plan.predict over {args.slda_batch_docs} slots x "
        f"M={args.slda_chains} chains, combine={svc_cfg.combine}; "
        "chain_weights is a jit argument, so drop/revive of a chain "
        "mid-stream reweights the served combine without retracing",
    ]
    # robustness policy (DESIGN.md §Serving-robustness): what the
    # service will do under overload, model faults, and hot reload —
    # printed here so the admission/deadline/reload contract is visible
    # before the service is stood up
    rb = d["robustness"]
    why.append(
        "admission: "
        + (f"pending queue capped at {rb['max_pending']} docs "
           f"(overflow -> typed '{STATUS_SHED_QUEUE}' Result)"
           if rb["max_pending"] else "pending queue UNBOUNDED "
           "(--slda-max-pending to cap; overload then grows latency, "
           "never sheds)")
        + (f"; token bucket {rb['rate_limit_per_s']}/s burst "
           f"{rb['rate_burst']}" if rb["rate_limit_per_s"] else
           "; no rate limit"))
    why.append(
        "deadlines: "
        + (f"default {1e3 * rb['default_deadline_s']:.0f}ms per request"
           if rb["default_deadline_s"] else "none by default "
           "(per-request via submit(deadline_s=...))")
        + f"; packing is {rb['scheduling']}, expired requests shed "
        "BEFORE occupying a slot")
    why.append(
        "degraded mode: model tables screened at load/reload and "
        "per-chain yhat screened at dispatch (robust_checks="
        f"{rb['robust_checks']}); a faulty chain is quarantined by "
        "zeroing its jit-argument weight — survivors' outputs are "
        "bit-identical to a service built without the chain "
        "(communication-free exactness), all-dead falls back to the "
        "unmasked combine with a RuntimeWarning")
    why.append(
        "hot reload: reload_from_checkpoint swaps models atomically "
        "(validate manifest -> screen tables -> swap), bumps "
        f"model_epoch (now {rb['model_epoch']}) to invalidate the "
        "result cache by key; torn/mislabelled checkpoints are "
        "rejected with the old epoch still serving, and the swap "
        "never retraces (models ride as jit arguments)")
    report["why"] = why
    print(json.dumps(report, indent=1))
    return report


def slda_elastic_report(args):
    """Print what the elastic ensemble runtime would do for an M-chain
    run over the given device pool — the initial chain placement, the
    round/deadline policy, and the checkpoint/staleness contract — so
    the membership protocol is visible before paying for a run (the
    elastic twin of --slda-plan; DESIGN.md §Elastic-training).  Pure
    bookkeeping: nothing is trained or compiled here."""
    from repro.core import SLDAConfig
    from repro.launch.elastic import ElasticConfig, compute_placement

    cfg = SLDAConfig(n_topics=args.slda_topics, vocab_size=args.slda_vocab,
                     length_buckets=args.slda_buckets,
                     sweeps_per_launch=args.slda_spl,
                     use_pallas=args.slda_pallas)
    el = ElasticConfig(
        round_iters=args.slda_round_iters,
        async_ckpt=not args.slda_sync_ckpt,
        ckpt_every=args.slda_ckpt_every,
        deadline_s=args.slda_elastic_deadline_s or None,
        straggle_rounds=args.slda_straggle_rounds,
        speculative_replace=args.slda_speculative)
    if cfg.n_iters % el.round_iters:
        raise SystemExit(f"--slda-round-iters {el.round_iters} must "
                         f"divide n_iters {cfg.n_iters}")
    m, ndev = args.slda_chains, args.slda_devices
    n_rounds = cfg.n_iters // el.round_iters
    placement = compute_placement(range(m), range(ndev))
    report = {
        "chains": m,
        "devices": ndev,
        "placement": {str(d): list(cs) for d, cs in placement.items()},
        "rounds": {"n_rounds": n_rounds,
                   "round_iters": el.round_iters,
                   "deadline_s": el.deadline_s,
                   "straggle_rounds": el.straggle_rounds,
                   "speculative_replace": el.speculative_replace},
        "checkpointing": {"mode": "async" if el.async_ckpt else "sync",
                          "ckpt_every_rounds": el.ckpt_every,
                          "keep_checkpoints": el.keep_checkpoints,
                          "max_resume_rewind_rounds": el.ckpt_every,
                          "catch_up": el.catch_up},
    }
    why = [
        f"placement: {m} chains balanced over {ndev} devices "
        f"({[len(v) for v in placement.values()]} per device); chains "
        "never communicate, so placement is pure bookkeeping — the "
        "compiled [M]-wide round is placement-blind and repack after "
        "device loss/join NEVER retraces",
        f"rounds: n_iters={cfg.n_iters} split into {n_rounds} EM rounds "
        f"of {el.round_iters} iters; membership changes, deadline "
        "checks, and checkpoints all land on round boundaries — inside "
        "a round the schedule is exactly the single-run schedule, so "
        "per-chain streams are bit-identical to a fresh run with the "
        "surviving layout",
        "deadlines: "
        + (f"round deadline {el.deadline_s}s on the virtual clock; a "
           f"device that misses it has its chains flagged F_STRAGGLER "
           f"(latched in the status word), and {el.straggle_rounds} "
           "consecutive misses evict the device from the pool"
           if el.deadline_s else
           "no round deadline (--slda-elastic-deadline-s to set one; "
           "stragglers then only stretch the round)")
        + ("; speculative_replace ON — a flagged device's chains move "
           "to the least-loaded on-time device at the next boundary, "
           "state untouched" if el.speculative_replace else ""),
        f"checkpointing: {'ASYNC double-buffered' if el.async_ckpt else 'synchronous'} "
        f"writer every {el.ckpt_every} round(s), keep last "
        f"{el.keep_checkpoints}; a new snapshot is not accepted until "
        "the previous one is durable, so resume after preempt/crash "
        f"rewinds at most {el.ckpt_every} round(s) (bounded staleness); "
        "SIGTERM drains with one final synchronous save",
        "recovery: device loss restores victims from the newest durable "
        "step (in-flight write flushed first so all victims see the "
        "same step)"
        + (" and replays them forward per-chain to the surviving "
           "chains' round — catch-up keys fold (chain, epoch, round), "
           "so the replayed stream is bitwise the original"
           if el.catch_up else "; catch_up OFF — victims quarantine "
           "instead of replaying"),
    ]
    report["why"] = why
    print(json.dumps(report, indent=1))
    return report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--chains", type=int, default=None,
                    help="override chain count (e.g. 1 = standard DP "
                         "baseline on the multi-pod mesh)")
    ap.add_argument("--tag", default="", help="artifact filename suffix")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--slda-plan", action="store_true",
                    help="print the sLDA ExecutionPlan for the given "
                         "corpus shape (see slda_plan_report) and exit")
    ap.add_argument("--slda-serve", action="store_true",
                    help="print the continuous-batching prediction "
                         "service's slot layout + cached plan for the "
                         "given traffic shape (see slda_serve_report) "
                         "and exit")
    ap.add_argument("--slda-elastic", action="store_true",
                    help="print the elastic ensemble runtime's chain "
                         "placement, round-deadline policy, and "
                         "checkpoint/staleness contract (see "
                         "slda_elastic_report) and exit")
    ap.add_argument("--slda-devices", type=int, default=4,
                    help="--slda-elastic: size of the initial device "
                         "pool")
    ap.add_argument("--slda-round-iters", type=int, default=2,
                    help="--slda-elastic: Gibbs iters per EM round "
                         "(must divide n_iters; membership changes, "
                         "deadlines, and checkpoints land on round "
                         "boundaries)")
    ap.add_argument("--slda-ckpt-every", type=int, default=1,
                    help="--slda-elastic: checkpoint cadence in rounds "
                         "(= the resume-rewind bound)")
    ap.add_argument("--slda-sync-ckpt", action="store_true",
                    help="--slda-elastic: block the round loop on "
                         "checkpoint writes instead of the async "
                         "double-buffered writer")
    ap.add_argument("--slda-elastic-deadline-s", type=float, default=0.0,
                    help="--slda-elastic: round deadline on the "
                         "virtual clock (0 = none; misses flag "
                         "F_STRAGGLER, repeats evict the device)")
    ap.add_argument("--slda-straggle-rounds", type=int, default=2,
                    help="--slda-elastic: consecutive deadline misses "
                         "before a device is evicted from the pool")
    ap.add_argument("--slda-speculative", action="store_true",
                    help="--slda-elastic: move a flagged device's "
                         "chains to the least-loaded on-time device "
                         "at the next boundary")
    ap.add_argument("--slda-batch-docs", type=int, default=32,
                    help="--slda-serve: slots per micro-batch")
    ap.add_argument("--slda-max-pending", type=int, default=128,
                    help="--slda-serve: pending-queue bound (0 = "
                         "unbounded; overflow sheds with a typed "
                         "Result, never an exception)")
    ap.add_argument("--slda-deadline-ms", type=float, default=0.0,
                    help="--slda-serve: default per-request deadline "
                         "(0 = none; expired requests shed before "
                         "occupying a batch slot)")
    ap.add_argument("--slda-rate", type=float, default=0.0,
                    help="--slda-serve: token-bucket admission rate "
                         "in docs/s (0 = no rate limit)")
    ap.add_argument("--slda-docs", type=int, default=512)
    ap.add_argument("--slda-maxlen", type=int, default=256)
    ap.add_argument("--slda-chains", type=int, default=8)
    ap.add_argument("--slda-buckets", type=int, default=8)
    ap.add_argument("--slda-spl", type=int, default=8)
    ap.add_argument("--slda-vocab", type=int, default=1000)
    ap.add_argument("--slda-topics", type=int, default=32)
    ap.add_argument("--slda-len-sigma", type=float, default=1.0)
    ap.add_argument("--slda-pallas", action="store_true")
    ap.add_argument("--slda-sampler", choices=("dense", "sparse"),
                    default="dense",
                    help="per-token draw: dense O(T) inverse-CDF or the "
                         "sparse two-stage draw over the per-word topic "
                         "index (DESIGN.md §Sparse-sampler)")
    ap.add_argument("--slda-topic-cap", type=int, default=32,
                    help="sparse-sampler bucket capacity (clamped to T)")
    ap.add_argument("--slda-phi-conc", type=float, default=1.0,
                    help="synthetic-corpus topic concentration "
                         "(<1 = peaked phi = low word-topic occupancy)")
    ap.add_argument("--slda-restarts", type=int, default=2,
                    help="supervisor restart budget per chain")
    ap.add_argument("--slda-min-alive", type=float, default=0.25,
                    help="abort threshold on the alive chain fraction")
    args = ap.parse_args()

    if args.slda_plan:
        slda_plan_report(args)
        return
    if args.slda_serve:
        slda_serve_report(args)
        return
    if args.slda_elastic:
        slda_elastic_report(args)
        return

    if args.all:
        archs = sorted(ARCHS)
    elif args.arch:
        archs = [args.arch]
    else:
        ap.error("--arch or --all required")

    meshes = [False, True] if (args.both_meshes or args.all) else \
        [args.multi_pod]
    failures = []
    for arch in archs:
        shapes = [args.shape] if args.shape else cells_for(ARCHS[arch])
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch} × {shape} × {'2-pod' if mp else '1-pod'}"
                try:
                    t0 = time.time()
                    run_cell(arch, shape, mp, args.out, verbose=False,
                             chains_override=args.chains,
                             tag_suffix=args.tag)
                    print(f"PASS {tag}  ({time.time() - t0:.0f}s)")
                except Exception as e:  # noqa: BLE001 — report and continue
                    failures.append((tag, repr(e)))
                    print(f"FAIL {tag}: {e}")
                    traceback.print_exc()
    print(f"\n{len(failures)} failures")
    for tag, err in failures:
        print(" ", tag, err)
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
